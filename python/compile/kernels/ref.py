"""Pure-jnp oracles for the L1 Pallas kernels.

These are the correctness references the pytest suite checks the kernels
against (exact equality — both sides are integer arithmetic).
"""

import jax
import jax.numpy as jnp

from .escmax import NEG_DEAD, NEG_INF  # re-exported for tests  # noqa: F401


def slice_gemm_ref(a8, b8):
    """int32 exact GEMM oracle for kernels.slice_gemm."""
    return jax.lax.dot_general(
        a8.astype(jnp.int32),
        b8.astype(jnp.int32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def escmax_ref(amax, amin, bmax, bmin):
    """Tropical GEMM oracle for kernels.escmax (dense einsum formulation)."""
    c1 = amax[:, :, None] + bmin[None, :, :]
    c2 = amin[:, :, None] + bmax[None, :, :]
    cand = jnp.maximum(c1, c2)
    dead = (amax[:, :, None] == NEG_INF) | (bmax[None, :, :] == NEG_INF)
    return jnp.max(jnp.where(dead, NEG_DEAD, cand), axis=1)
