"""L1 Pallas kernel: INT8 slice-product GEMM (the Tensor-Core analogue).

Computes P = A8 @ B8 with A8 int8[m,k], B8 int8[k,n], P int32[m,n].

Hardware adaptation (paper targets NVIDIA INT8 Tensor Cores; see DESIGN.md
§Hardware-Adaptation): the threadblock tiling of the paper's CUTLASS kernels
becomes a 3-D Pallas grid with BlockSpec index maps expressing the HBM<->VMEM
schedule; the warp-level s8 MMA becomes a `dot_general` on int8 tiles with
int32 accumulation, which the MXU executes natively on TPU.  Tile sizes
default to the MXU's 128-lane geometry, shrinking for small problems.

MUST be lowered with interpret=True in this environment: real TPU lowering
emits a Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-friendly default tiles: 128x128 output tile, 128-deep K panels.
# VMEM footprint per step: (TM*TK + TK*TN) int8 + TM*TN int32
#   = 2*128*128 + 128*128*4 = 96 KiB  « 16 MiB VMEM, leaving room for
# double-buffering the A/B tiles while the MXU consumes the previous pair.
TILE_M = 128
TILE_N = 128
TILE_K = 128


def _pick(tile: int, dim: int) -> int:
    """Largest power-of-two tile <= `tile` that divides `dim`."""
    t = min(tile, dim)
    while dim % t != 0:
        t //= 2
    return max(t, 1)


def _kernel(a_ref, b_ref, o_ref):
    # int8 x int8 -> int32: exact as long as k <= 2^17 (|d| <= 128 products).
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...].astype(jnp.int32)
    b = b_ref[...].astype(jnp.int32)
    o_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def slice_gemm(a8, b8, *, interpret=True):
    """P int32[m,n] = a8 int8[m,k] @ b8 int8[k,n], exact integer GEMM."""
    m, k = a8.shape
    k2, n = b8.shape
    assert k == k2, (a8.shape, b8.shape)
    tm, tn, tk = _pick(TILE_M, m), _pick(TILE_N, n), _pick(TILE_K, k)
    grid = (m // tm, n // tn, k // tk)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tk, tn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(a8, b8)
