"""L1 Pallas kernel: max-plus "exponent GEMM" for the coarsened ESC (§4).

Computes E int32[m,n] = max_i max( Amax[:,i]+Bmin[i,:], Amin[:,i]+Bmax[i,:] )
over the coarsened k-blocks i — the tropical-semiring analogue of a GEMM.

This is the paper's CUTLASS+DPX kernel (§5.2) re-thought for the session's
substrate: DPX max/min instructions map onto VPU elementwise max with an
explicit k-reduction in the kernel body; coarsening by block size b along k
makes the pass cost (1/b) of the real GEMM.  Lowered with interpret=True.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .slice_gemm import _pick

TILE_M = 128
TILE_N = 128

# Exponent sentinel for zero entries (mirrors ozaki.ZERO_EXP): a zero loses
# every max and wins every min, which only lowers the z_r estimate — the
# safe (conservative) direction.
NEG_INF = -(1 << 24)

# Marker for *dead* block pairs (one side entirely zero: no products exist).
# Strictly below any sentinel-contaminated candidate (>= 2*NEG_INF), so the
# runtime can distinguish "exactly-zero dot product" (ESC := 0) from
# "zero-contaminated estimate" (huge ESC -> conservative fallback).
NEG_DEAD = -(1 << 30)


def _kernel(amax_ref, amin_ref, bmax_ref, bmin_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, NEG_DEAD)

    amax = amax_ref[...]  # (tm, tk) int32 block maxima of row exponents
    amin = amin_ref[...]
    bmax = bmax_ref[...]  # (tk, tn)
    bmin = bmin_ref[...]
    # max-plus "product": for each coarse block l, candidate exponents
    # Amax+Bmin and Amin+Bmax (the two safe underestimates of z_r; §4).
    c1 = amax[:, :, None] + bmin[None, :, :]
    c2 = amin[:, :, None] + bmax[None, :, :]
    cand = jnp.maximum(c1, c2)
    # Block pairs with an all-zero side contribute nothing.
    dead = (amax[:, :, None] == NEG_INF) | (bmax[None, :, :] == NEG_INF)
    cand = jnp.where(dead, NEG_DEAD, cand)
    o_ref[...] = jnp.maximum(o_ref[...], jnp.max(cand, axis=1))


@functools.partial(jax.jit, static_argnames=("interpret",))
def escmax(amax, amin, bmax, bmin, *, interpret=True):
    """Tropical GEMM over coarse blocks.

    amax/amin: int32[m, kb] per-row, per-k-block exponent max/min of A.
    bmax/bmin: int32[kb, n] per-col, per-k-block exponent max/min of B.
    Returns E int32[m, n], the coarsened estimate of exp(z_r) per dot
    product (never an overestimate of the exact value; §4 proof).
    """
    m, kb = amax.shape
    kb2, n = bmax.shape
    assert kb == kb2
    tm, tn, tk = _pick(TILE_M, m), _pick(TILE_N, n), _pick(TILE_M, kb)
    grid = (m // tm, n // tn, kb // tk)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tk, tn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((tk, tn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(amax, amin, bmax, bmin)
