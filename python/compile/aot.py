"""AOT compile path: lower the L2 graphs to HLO *text* artifacts.

HLO text (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the Rust `xla` crate) rejects; the text parser reassigns
ids and round-trips cleanly.  See /opt/xla-example/README.md.

Usage: python -m compile.aot --out-dir ../artifacts [--sizes 64,128,256]
       [--slices 3,4,5,6,7,8] [--big-sizes 512] [--big-slices 7,8]

Writes one artifact per (kind, size[, slices]) plus `manifest.txt` with
lines `kind n slices path` (slices = 0 for non-gemm kinds).  The Rust
registry (`rust/src/runtime/registry.rs`) parses the manifest.
"""

import argparse
import os
import time

import jax
jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(n):
    return jax.ShapeDtypeStruct((n, n), jnp.float64)


def emit(fn, specs, path):
    t0 = time.time()
    text = to_hlo_text(jax.jit(fn).lower(*specs))
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text) / 1e6:.2f} MB, {time.time() - t0:.1f}s)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--sizes", default="64,128,256")
    ap.add_argument("--slices", default="3,4,5,6,7,8,9,10")
    ap.add_argument("--big-sizes", default="512")
    ap.add_argument("--big-slices", default="7,8")
    args = ap.parse_args()

    out = args.out_dir
    os.makedirs(out, exist_ok=True)

    def parse(s):
        return [int(x) for x in s.split(",") if x]

    grid = [(n, parse(args.slices)) for n in parse(args.sizes)]
    grid += [(n, parse(args.big_slices)) for n in parse(args.big_sizes)]

    manifest = []
    for n, slice_list in grid:
        print(f"n={n}:")
        fname = f"dgemm_n{n}.hlo.txt"
        emit(model.dgemm, [_spec(n), _spec(n)], os.path.join(out, fname))
        manifest.append(f"dgemm {n} 0 {fname}")

        fname = f"scan_esc_n{n}.hlo.txt"
        emit(
            lambda a, b: model.scan_esc(a, b),
            [_spec(n), _spec(n)],
            os.path.join(out, fname),
        )
        manifest.append(f"scan {n} 0 {fname}")

        for s in slice_list:
            fname = f"ozaki_gemm_n{n}_s{s}.hlo.txt"
            emit(
                lambda a, b, s=s: model.emulated_gemm(a, b, s),
                [_spec(n), _spec(n)],
                os.path.join(out, fname),
            )
            manifest.append(f"gemm {n} {s} {fname}")

    with open(os.path.join(out, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"manifest: {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
