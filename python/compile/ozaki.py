"""Ozaki-I decomposition with the paper's unsigned slice encoding (L2, JAX).

FP64 matrices are split into `s` INT8 slice matrices per operand.  Only the
leading slice carries a sign; sub-leading slices use the full 8-bit range,
re-expressed in two's-complement s8 via the value redistribution of §3 of
the paper (`d in [128,255] -> d-256`, carry `+1` to the next-higher slice).

Conventions (mirrors `rust/src/ozaki/slicing.rs`, cross-validated by tests):

* Per-row (A) / per-column (B) scaling.  With `e = frexp`-exponent of the
  row/col max (so `|a| < 2^e` for the whole row), the fixed-point window is
  `v = a * 2^sigma`, `sigma = 8*(s-1) + 6 - e`.  The leading digit then
  satisfies `|L0| <= 64` *including* the remap carry (one headroom bit).
* Digits are extracted MSB-first with round-to-negative-infinity, giving a
  non-negative remainder — exactly the paper's construction.
* Effective mantissa bits: `8*s - 2` (sign + headroom).  FP64 (53-bit)
  fidelity needs s = 7 slices, vs 8 for naive signed slicing — the paper's
  22%-compute-reduction claim (§3).

Everything here is trace-safe jnp; it lowers into the AOT HLO artifacts.
"""

import jax
import jax.numpy as jnp

# Exponent of zero entries: below any real FP64 exponent (min subnormal
# exponent is -1073 in frexp convention) so zero rows/blocks never win a max.
ZERO_EXP = -(1 << 24)

# Headroom accounting: 1 sign bit + 1 carry-headroom bit per slice vector.
HEADROOM_BITS = 2


def effective_bits(slices: int) -> int:
    """Effective mantissa bits captured by `slices` INT8 slices."""
    return 8 * slices - HEADROOM_BITS


def slices_for_bits(mantissa_bits: int) -> int:
    """Minimum slice count whose effective bits cover `mantissa_bits`."""
    return -(-(mantissa_bits + HEADROOM_BITS) // 8)


def frexp_exponent(x):
    """Exponent e with |x| < 2^e (frexp convention); ZERO_EXP for x == 0.

    Implemented with bit manipulation rather than jnp.frexp so that the
    lowered HLO is pure integer ops (cheap on the scan path) and — crucially
    — immune to XLA CPU's DAZ/FTZ: float comparisons treat subnormals as
    zero on this backend, so zero detection MUST happen in the integer
    domain (`mag == 0`) for the ESC of subnormal-containing inputs to be
    correct.  (The int->f64 conversion of the raw mantissa used for the
    subnormal branch produces a *normal* float, so it is FTZ-safe too.)
    """
    bits = jax.lax.bitcast_convert_type(x, jnp.uint64)
    mag = bits & jnp.uint64(0x7FFF_FFFF_FFFF_FFFF)  # drop sign
    raw = ((mag >> 52) & jnp.uint64(0x7FF)).astype(jnp.int32)
    mant = mag & jnp.uint64((1 << 52) - 1)
    # Normal numbers: value in [2^(raw-1023), 2^(raw-1022)) => e = raw - 1022.
    normal_e = raw - 1022
    # Subnormals: value = mant * 2^-1074, highest set bit h => e = h + 1 - 1074.
    # floor(log2(mant)) via conversion to f64 (exact for < 2^53).
    mant_f = mant.astype(jnp.float64)
    mbits = jax.lax.bitcast_convert_type(mant_f, jnp.uint64)
    mexp = ((mbits >> 52) & jnp.uint64(0x7FF)).astype(jnp.int32) - 1023
    sub_e = mexp + 1 - 1074
    e = jnp.where(raw == 0, sub_e, normal_e)
    return jnp.where(mag == jnp.uint64(0), jnp.int32(ZERO_EXP), e)


def _digits_unsigned(v, slices):
    """Base-256 digits of the scaled value `v`, unsigned encoding.

    v is a real with |v| < 2^(8*(slices-1) + 6).  Returns a list of `slices`
    int32 arrays (digit values in s8 range after the two's-complement remap,
    MSB first).

    Digits are extracted on the **magnitude** and the sign is applied by
    negating the digit vector: extracting on the signed value would borrow
    (`floor(-eps) = -1`, `r = 2^w - |v|`), which f64 cannot represent for
    elements far below the row max and silently destroys their low bits.
    Each magnitude step strips a *leading* bit field of |v| — exact in f64.
    Mirrors rust/src/ozaki/slicing.rs::extract_digits.
    """
    av = jnp.abs(v)
    neg = v < 0.0
    w = float(2 ** (8 * (slices - 1)))
    lead = jnp.floor(av / w)
    digits = [lead]
    r = av - lead * w
    for t in range(1, slices):
        wt = float(2 ** (8 * (slices - 1 - t)))
        d = jnp.floor(r / wt)
        r = r - d * wt
        digits.append(d)
    digits = [jnp.where(neg, -d, d) for d in digits]
    # Two's-complement remap, LSB -> MSB: d > 127 => d -= 256 with a +1
    # carry up (symmetrically d < -128 => d += 256, carry -1).
    for t in range(slices - 1, 0, -1):
        hi = digits[t] > 127.0
        lo = digits[t] < -128.0
        digits[t] = digits[t] - jnp.where(hi, 256.0, 0.0) + jnp.where(lo, 256.0, 0.0)
        digits[t - 1] = digits[t - 1] + jnp.where(hi, 1.0, 0.0) - jnp.where(lo, 1.0, 0.0)
    return [d.astype(jnp.int32) for d in digits]


def slice_rows(a, slices):
    """Decompose A (m,k) along rows.

    Returns (slice_tensor int8[slices, m, k], row_scale_exp int32[m]) where
    a[i, j] ~= sum_t slice[t, i, j] * 2^(8*(slices-1-t) - sigma_i) and
    sigma_i = 8*(slices-1) + 6 - row_max_exp[i].
    """
    e = frexp_exponent(a)
    emax = jnp.max(e, axis=1)  # (m,)
    # All-zero rows: any sigma works (digits are all zero); pick exp 0.
    emax_safe = jnp.where(emax == ZERO_EXP, 0, emax)
    sigma = (8 * (slices - 1) + 6) - emax_safe  # (m,) int32
    # sigma can exceed 1023 for rows of tiny/subnormal values; 2^sigma would
    # overflow f64 as a single factor, so scale in two exact halves.
    half = sigma // 2
    v = a * exp2i(half)[:, None] * exp2i(sigma - half)[:, None]
    digits = _digits_unsigned(v, slices)
    st = jnp.stack([d.astype(jnp.int8) for d in digits])  # (s, m, k)
    return st, sigma


def slice_cols(b, slices):
    """Decompose B (k,n) along columns; see slice_rows."""
    st, sigma = slice_rows(b.T, slices)
    return jnp.transpose(st, (0, 2, 1)), sigma


def exp2i(e):
    """Exact 2^e for integer-array e in [-1022, 1023], by assembling the
    f64 bit pattern directly.  jnp.exp2 goes through a polynomial on XLA
    CPU and is NOT exact (exp2(26) != 2^26 bit-for-bit), which silently
    corrupts the fixed-point window; never use it for scale factors.
    """
    bits = ((e.astype(jnp.int64) + 1023) << 52).astype(jnp.uint64)
    return jax.lax.bitcast_convert_type(bits, jnp.float64)


def _two_sum(a, b):
    """Error-free sum (Knuth, branch-free): a + b = s + e exactly."""
    s = a + b
    bb = s - a
    e = (a - (s - bb)) + (b - bb)
    return s, e


def recompose(partials, sigma_a, sigma_b, slices):
    """Recombine slice-pair products into FP64.

    `partials` maps (t, u) -> int32[m, n] product of A-slice t and B-slice u
    for t + u <= slices - 1 (Ozaki-I triangular truncation).  Result:
    C[i,j] = sum_{t,u} P[t,u][i,j] * 2^(16*(slices-1) - 8*(t+u))
             * 2^(-sigma_a[i] - sigma_b[j]).

    Partial products are grouped by q = t+u and accumulated smallest weight
    first with a **compensated** (two_sum) accumulator: level sums reach
    ~(|A||B|)_ij individually while the true result can be much smaller
    after cross-level cancellation; plain f64 accumulation would leave a
    poly(s,k)*eps*(|A||B|) error above the Grade A slope.  Mirrors
    rust/src/ozaki/recompose.rs operation-for-operation.
    """
    m = sigma_a.shape[0]
    n = sigma_b.shape[0]
    by_q = {}
    for (t, u), p in partials.items():
        by_q.setdefault(t + u, []).append(p)
    hi = jnp.zeros((m, n), dtype=jnp.float64)
    lo = jnp.zeros((m, n), dtype=jnp.float64)
    for q in sorted(by_q.keys(), reverse=True):  # smallest weight first
        s_q = by_q[q][0].astype(jnp.float64)
        for p in by_q[q][1:]:
            s_q = s_q + p.astype(jnp.float64)  # exact: |sum| < 2^53
        x = s_q * float(2 ** (16 * (slices - 1) - 8 * q))  # exact pow2 scale
        hi, e = _two_sum(hi, x)
        lo = lo + e
    # Undo the row/col scaling.  |sigma| can exceed 1074, where 2^-sigma
    # underflows to zero as a single f64 factor, so apply each operand's
    # scale in two exact power-of-two halves.  Interleaving row/col halves
    # keeps every intermediate free of spurious overflow/underflow for any
    # mix of large-row/small-col scalings (see rust/src/ozaki/recompose.rs
    # for the matching argument).
    ha = sigma_a // 2
    hb = sigma_b // 2
    for f in (
        exp2i(-ha)[:, None],
        exp2i(-hb)[None, :],
        exp2i(-(sigma_a - ha))[:, None],
        exp2i(-(sigma_b - hb))[None, :],
    ):
        hi = hi * f
        lo = lo * f
    return hi + lo
