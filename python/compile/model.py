"""L2 JAX graphs: emulated DGEMM, fused safety-scan + coarsened ESC, and the
native-FP64 fallback graph.

These are the computations `aot.py` lowers to HLO text for the Rust runtime.
Everything is static-shape and trace-safe; Python never runs at request time.
"""

import jax
jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp

from . import ozaki
from .kernels.escmax import NEG_DEAD, NEG_INF, escmax
from .kernels.slice_gemm import slice_gemm

# Coarsening block length b along k for the ESC estimate (§4).  Cost of the
# max-plus pass is 1/b of a real GEMM; 64 matches the paper's "few percent"
# overhead target while keeping the estimate tight on Test-2-style inputs.
ESC_BLOCK = 64


def dgemm(a, b):
    """Native FP64 GEMM — the fallback target and baseline."""
    return jnp.matmul(a, b)


def emulated_gemm(a, b, slices: int, *, interpret=True):
    """Ozaki-I emulated DGEMM with the unsigned slice encoding (§3).

    a: f64[m,k], b: f64[k,n] -> f64[m,n].  `slices` is static: one AOT
    artifact per slice count; ADP (Rust) picks the artifact at run time.
    """
    a_sl, sigma_a = ozaki.slice_rows(a, slices)
    b_sl, sigma_b = ozaki.slice_cols(b, slices)
    partials = {}
    for t in range(slices):
        for u in range(slices - t):  # Ozaki-I triangular truncation
            partials[(t, u)] = slice_gemm(
                a_sl[t], b_sl[u], interpret=interpret
            )
    return ozaki.recompose(partials, sigma_a, sigma_b, slices)


# Identity padding for ragged k: -inf for the max reduction, +big for the
# min (a padded entry must never win either reduction; fully-padded blocks
# end up amax == NEG_INF and are dead-masked by the kernel).
_MIN_PAD = 1 << 24


def _block_minmax_rows(e, block):
    """Per-row, per-k-block exponent max/min. e: int32[m,k] -> int32[m,ceil(k/b)]."""
    m, k = e.shape
    nb = -(-k // block)
    pad = nb * block - k
    emax_in = jnp.pad(e, ((0, 0), (0, pad)), constant_values=NEG_INF)
    emin_in = jnp.pad(e, ((0, 0), (0, pad)), constant_values=_MIN_PAD)
    return (
        jnp.max(emax_in.reshape(m, nb, block), axis=2),
        jnp.min(emin_in.reshape(m, nb, block), axis=2),
    )


def scan_esc(a, b, *, block=ESC_BLOCK, interpret=True):
    """Fused pre-processing pass of §5.1/§5.2: NaN/Inf scan + coarsened ESC.

    Returns int32[4]: (has_nan, has_inf, esc, required_bits_for_53).
    The whole decision input is a 4-word result, so the Rust coordinator
    never re-reads the matrices — the "GPU-resident, no host-device sync"
    property of §5.4 translated to this substrate.
    """
    bad_a = jnp.isnan(a).any() | jnp.isnan(b).any()
    inf_a = jnp.isinf(a).any() | jnp.isinf(b).any()

    ea = ozaki.frexp_exponent(a)           # int32[m,k]
    eb = ozaki.frexp_exponent(b.T)         # int32[n,k] (column-major view)
    amax, amin = _block_minmax_rows(ea, block)
    bmax_t, bmin_t = _block_minmax_rows(eb, block)
    e_est = escmax(amax, amin, bmax_t.T, bmin_t.T, interpret=interpret)

    row_max = jnp.max(ea, axis=1)          # exp(x_p) per row
    col_max = jnp.max(eb, axis=1)          # exp(y_q) per col
    esc_ij = row_max[:, None] + col_max[None, :] - e_est + 1  # +1: §4 margin
    # Dot products with no overlapping nonzeros are exactly zero under
    # emulation: their ESC is 0 by definition.  Same for all-zero rows/cols.
    # (Zero-*contaminated* estimates stay above NEG_DEAD//2 and produce a
    # huge, conservative ESC instead — see kernels/escmax.py.)
    dead = (e_est < NEG_DEAD // 2) | (row_max[:, None] < NEG_INF // 2) \
        | (col_max[None, :] < NEG_INF // 2)
    esc_ij = jnp.where(dead, 0, esc_ij)
    esc = jnp.maximum(jnp.max(esc_ij), 0)

    bits53 = 53 + esc + 1
    return jnp.stack([
        bad_a.astype(jnp.int32),
        inf_a.astype(jnp.int32),
        esc.astype(jnp.int32),
        bits53.astype(jnp.int32),
    ])


def exact_esc(a, b):
    """Uncoarsened ESC oracle (O(mnk)); reference for tests only."""
    ea = ozaki.frexp_exponent(a).astype(jnp.int64)
    eb = ozaki.frexp_exponent(b).astype(jnp.int64)
    z = ea[:, :, None] + eb[None, :, :]                       # (m,k,n)
    z_r = jnp.max(z, axis=1)                                  # (m,n)
    row_max = jnp.max(ea, axis=1)
    col_max = jnp.max(eb, axis=0)
    esc_ij = row_max[:, None] + col_max[None, :] - z_r + 1
    dead = (z_r < NEG_INF // 2) | (row_max[:, None] < NEG_INF // 2) \
        | (col_max[None, :] < NEG_INF // 2)
    esc_ij = jnp.where(dead, 0, esc_ij)
    return jnp.maximum(jnp.max(esc_ij), 0).astype(jnp.int32)
