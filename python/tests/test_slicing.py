"""L2 slicing correctness: unsigned encoding round-trip and invariants."""

import jax
jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import ozaki


import math


def reconstruct(st_, sigma, slices):
    """Rebuild the matrix from its slices (test helper).

    Uses math.fsum (exactly-rounded) per element: a plain f64 sum of digit
    contributions spanning an 8*slices-bit window would itself round and
    mask slicing exactness.
    """
    st_ = np.array(st_, dtype=np.int64)
    sigma = np.array(sigma)
    _, m, k = st_.shape
    out = np.zeros((m, k))
    for i in range(m):
        for j in range(k):
            terms = [
                math.ldexp(float(st_[t, i, j]), 8 * (slices - 1 - t) - int(sigma[i]))
                for t in range(slices)
            ]
            out[i, j] = math.fsum(terms)
    return out


@pytest.mark.parametrize("slices", [2, 3, 5, 7])
def test_roundtrip_uniform(slices):
    rng = np.random.default_rng(slices)
    a = rng.uniform(-4.0, 4.0, (8, 16))
    st_, sigma = ozaki.slice_rows(jnp.asarray(a), slices)
    rec = reconstruct(np.array(st_), sigma, slices)
    tol = 2.0 ** (-ozaki.effective_bits(slices) + 1) * np.abs(a).max(axis=1, keepdims=True)
    assert (np.abs(rec - a) <= tol).all()


def test_exact_at_7_slices():
    # 54 effective bits cover the full 53-bit significand of row maxima and
    # anything sharing their exponent window.
    rng = np.random.default_rng(1)
    a = rng.uniform(0.5, 1.0, (4, 8))  # single binade -> all exact
    st_, sigma = ozaki.slice_rows(jnp.asarray(a), 7)
    rec = reconstruct(np.array(st_), sigma, 7)
    np.testing.assert_array_equal(rec, a)


def test_slices_fit_int8():
    rng = np.random.default_rng(2)
    # adversarial: values just below powers of two maximize digit carries
    a = np.concatenate([
        np.nextafter(2.0 ** rng.integers(-10, 10, (4, 8)), 0.0),
        rng.uniform(-1, 1, (4, 8)),
    ], axis=1)
    for slices in (2, 4, 7):
        st_, _ = ozaki.slice_rows(jnp.asarray(a), slices)
        arr = np.array(st_, dtype=np.int32)
        assert arr.min() >= -128 and arr.max() <= 127


def test_zero_and_negzero_rows():
    a = np.array([[0.0, -0.0, 0.0], [1.0, 0.0, -2.0]])
    st_, sigma = ozaki.slice_rows(jnp.asarray(a), 4)
    arr = np.array(st_)
    assert (arr[:, 0, :] == 0).all()
    rec = reconstruct(arr, sigma, 4)
    assert rec[0].tolist() == [0.0, 0.0, 0.0]


def test_per_row_scaling_independent():
    a = np.array([[1.0, 0.5], [1e160, 2e160]])
    st_, sigma = ozaki.slice_rows(jnp.asarray(a), 7)
    rec = reconstruct(np.array(st_), np.array(sigma), 7)
    np.testing.assert_allclose(rec, a, rtol=2e-16)
    assert int(sigma[0]) != int(sigma[1])


def test_frexp_exponent_matches_numpy():
    vals = np.array([1.0, 0.5, 0.75, 3.0, 1e300, 1e-300, -2.5, 5e-324, 0.0])
    got = np.array(ozaki.frexp_exponent(jnp.asarray(vals)))
    _, want = np.frexp(vals)
    # numpy frexp of 0 gives e=0; ours uses the sentinel
    want[vals == 0] = ozaki.ZERO_EXP
    np.testing.assert_array_equal(got, want)


@settings(max_examples=30, deadline=None)
@given(
    slices=st.integers(2, 9),
    seed=st.integers(0, 2**31),
    scale_exp=st.integers(-200, 200),
)
def test_roundtrip_hypothesis(slices, seed, scale_exp):
    rng = np.random.default_rng(seed)
    a = rng.uniform(-1.0, 1.0, (3, 12)) * (2.0 ** scale_exp)
    st_, sigma = ozaki.slice_rows(jnp.asarray(a), slices)
    rec = reconstruct(np.array(st_), np.array(sigma), slices)
    tol = 2.0 ** (-ozaki.effective_bits(slices) + 1) * np.abs(a).max(axis=1, keepdims=True)
    assert (np.abs(rec - a) <= tol + 0.0).all()


def test_slices_for_bits_consistency():
    assert ozaki.slices_for_bits(53) == 7
    for s in range(1, 20):
        assert ozaki.slices_for_bits(ozaki.effective_bits(s)) == s
