"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Both sides are integer arithmetic, so the comparison is exact equality.
Hypothesis sweeps shapes, seeds and value ranges.
"""

import jax
jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.escmax import NEG_INF, escmax
from compile.kernels.slice_gemm import slice_gemm


def rand_i8(rng, shape):
    return jnp.asarray(rng.integers(-128, 128, shape, dtype=np.int64).astype(np.int8))


@pytest.mark.parametrize("m,k,n", [(8, 8, 8), (16, 32, 8), (64, 64, 64), (128, 64, 32)])
def test_slice_gemm_matches_ref(m, k, n):
    rng = np.random.default_rng(m * 1000 + k * 10 + n)
    a8, b8 = rand_i8(rng, (m, k)), rand_i8(rng, (k, n))
    got = np.array(slice_gemm(a8, b8))
    want = np.array(ref.slice_gemm_ref(a8, b8))
    np.testing.assert_array_equal(got, want)


def test_slice_gemm_extreme_values():
    # all -128 x all -128: maximum-magnitude accumulation
    k = 256
    a8 = jnp.full((4, k), -128, dtype=jnp.int8)
    b8 = jnp.full((k, 4), -128, dtype=jnp.int8)
    got = np.array(slice_gemm(a8, b8))
    assert (got == 128 * 128 * k).all()


def test_slice_gemm_identity_pattern():
    n = 32
    eye = jnp.eye(n, dtype=jnp.int8)
    rng = np.random.default_rng(0)
    b8 = rand_i8(rng, (n, n))
    got = np.array(slice_gemm(eye, b8))
    np.testing.assert_array_equal(got, np.array(b8, dtype=np.int32))


@settings(max_examples=25, deadline=None)
@given(
    m=st.sampled_from([1, 2, 4, 8, 16, 64]),
    k=st.sampled_from([1, 4, 16, 64, 128]),
    n=st.sampled_from([1, 2, 8, 32, 64]),
    seed=st.integers(0, 2**31),
)
def test_slice_gemm_hypothesis(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a8, b8 = rand_i8(rng, (m, k)), rand_i8(rng, (k, n))
    np.testing.assert_array_equal(
        np.array(slice_gemm(a8, b8)), np.array(ref.slice_gemm_ref(a8, b8))
    )


def rand_exps(rng, shape, span=40, zero_frac=0.1):
    e = rng.integers(-span, span, shape).astype(np.int32)
    zeros = rng.random(shape) < zero_frac
    e[zeros] = NEG_INF
    return e


@pytest.mark.parametrize("m,kb,n", [(8, 2, 8), (16, 4, 16), (64, 8, 32)])
def test_escmax_matches_ref(m, kb, n):
    rng = np.random.default_rng(kb + m)
    amax = rand_exps(rng, (m, kb))
    amin = np.minimum(amax, rand_exps(rng, (m, kb)))
    bmax = rand_exps(rng, (kb, n))
    bmin = np.minimum(bmax, rand_exps(rng, (kb, n)))
    args = [jnp.asarray(x) for x in (amax, amin, bmax, bmin)]
    got = np.array(escmax(*args))
    want = np.array(ref.escmax_ref(*args))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=20, deadline=None)
@given(
    m=st.sampled_from([1, 4, 8, 32]),
    kb=st.sampled_from([1, 2, 8, 16]),
    n=st.sampled_from([1, 4, 16]),
    seed=st.integers(0, 2**31),
    zero_frac=st.floats(0.0, 0.9),
)
def test_escmax_hypothesis(m, kb, n, seed, zero_frac):
    rng = np.random.default_rng(seed)
    amax = rand_exps(rng, (m, kb), zero_frac=zero_frac)
    amin = np.minimum(amax, rand_exps(rng, (m, kb), zero_frac=zero_frac))
    bmax = rand_exps(rng, (kb, n), zero_frac=zero_frac)
    bmin = np.minimum(bmax, rand_exps(rng, (kb, n), zero_frac=zero_frac))
    args = [jnp.asarray(x) for x in (amax, amin, bmax, bmin)]
    np.testing.assert_array_equal(np.array(escmax(*args)), np.array(ref.escmax_ref(*args)))
