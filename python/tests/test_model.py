"""L2 model correctness: emulated DGEMM vs FP64 reference, scan+ESC graph."""

import jax
jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model, ozaki


def grade_a_err(C, A, B):
    """Max componentwise error scaled by (|A||B|)_ij."""
    denom = np.abs(A) @ np.abs(B)
    return np.max(np.abs(C - A @ B) / np.where(denom == 0, 1, denom))


@pytest.mark.parametrize("n,s", [(16, 7), (64, 7), (64, 8), (32, 9)])
def test_emulated_gemm_fp64_grade(n, s):
    rng = np.random.default_rng(n + s)
    A = rng.uniform(-1, 1, (n, n))
    B = rng.uniform(-1, 1, (n, n))
    C = np.array(model.emulated_gemm(jnp.asarray(A), jnp.asarray(B), s))
    assert grade_a_err(C, A, B) < (n + 4) * 2.3e-16


def test_error_decreases_with_slices():
    rng = np.random.default_rng(5)
    A = rng.uniform(-1, 1, (24, 24))
    B = rng.uniform(-1, 1, (24, 24))
    errs = [
        grade_a_err(np.array(model.emulated_gemm(jnp.asarray(A), jnp.asarray(B), s)), A, B)
        for s in (2, 4, 6)
    ]
    assert errs[0] > errs[1] > errs[2]


def test_wide_span_with_esc_sized_slices():
    rng = np.random.default_rng(6)
    D = 2.0 ** rng.integers(-30, 30, 32)
    A = rng.uniform(1, 2, (32, 32)) * D
    B = (rng.uniform(1, 2, (32, 32)).T / D).T
    out = np.array(model.scan_esc(jnp.asarray(A), jnp.asarray(B), block=8))
    esc = int(out[2])
    exact = int(model.exact_esc(jnp.asarray(A), jnp.asarray(B)))
    assert esc >= exact  # safety: coarse never below exact
    s = ozaki.slices_for_bits(53 + esc + 1)
    C = np.array(model.emulated_gemm(jnp.asarray(A), jnp.asarray(B), s))
    assert grade_a_err(C, A, B) < 40 * 2.3e-16


def test_scan_flags():
    rng = np.random.default_rng(7)
    A = rng.uniform(-1, 1, (16, 16))
    B = rng.uniform(-1, 1, (16, 16))
    out = np.array(model.scan_esc(jnp.asarray(A), jnp.asarray(B)))
    assert out[0] == 0 and out[1] == 0
    A2 = A.copy(); A2[3, 3] = np.nan
    assert model.scan_esc(jnp.asarray(A2), jnp.asarray(B))[0] == 1
    B2 = B.copy(); B2[0, 0] = -np.inf
    assert model.scan_esc(jnp.asarray(A), jnp.asarray(B2))[1] == 1


def test_scan_esc_required_bits_field():
    rng = np.random.default_rng(8)
    A = rng.uniform(1, 2, (16, 16))
    B = rng.uniform(1, 2, (16, 16))
    out = np.array(model.scan_esc(jnp.asarray(A), jnp.asarray(B), block=4))
    assert out[3] == 53 + out[2] + 1


def test_zero_matrices():
    Z = jnp.zeros((16, 16))
    out = np.array(model.scan_esc(Z, Z))
    assert out[2] == 0  # dead dot products: ESC 0
    C = np.array(model.emulated_gemm(Z, Z, 7))
    assert (C == 0).all()


def test_negative_zero_treated_as_zero():
    A = jnp.asarray([[-0.0, 1.0], [2.0, -0.0]])
    B = jnp.asarray([[3.0, -0.0], [-0.0, 4.0]])
    C = np.array(model.emulated_gemm(A, B, 7))
    np.testing.assert_array_equal(np.abs(C), np.abs(np.array(A) @ np.array(B)))


def test_permutation_invariance_bitwise():
    # fixed-point emulation is summation-order invariant (§4)
    rng = np.random.default_rng(9)
    A = rng.uniform(-2, 2, (8, 12))
    B = rng.uniform(-2, 2, (12, 8))
    perm = rng.permutation(12)
    C1 = np.array(model.emulated_gemm(jnp.asarray(A), jnp.asarray(B), 6))
    C2 = np.array(model.emulated_gemm(jnp.asarray(A[:, perm]), jnp.asarray(B[perm, :]), 6))
    np.testing.assert_array_equal(C1, C2)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**31),
    span=st.integers(0, 25),
    s_extra=st.integers(0, 2),
)
def test_esc_sized_accuracy_hypothesis(seed, span, s_extra):
    rng = np.random.default_rng(seed)
    A = rng.uniform(-2, 2, (12, 16)) * 2.0 ** rng.integers(-span, span + 1, (12, 16))
    B = rng.uniform(-2, 2, (16, 12)) * 2.0 ** rng.integers(-span, span + 1, (16, 12))
    out = np.array(model.scan_esc(jnp.asarray(A), jnp.asarray(B), block=8))
    s = ozaki.slices_for_bits(53 + int(out[2]) + 1) + s_extra
    C = np.array(model.emulated_gemm(jnp.asarray(A), jnp.asarray(B), s))
    assert grade_a_err(C, A, B) < 40 * 2.3e-16
