//! Application-level integration (§7.3 / Fig 7): blocked Householder QR
//! with the trailing-matrix update dispatched to ADP-enabled GEMM.
//!
//! Runs the same factorization with three backends — native FP64, fixed
//! 7-slice emulation (no guardrails), and ADP dynamic — and compares
//! residuals, orthogonality, and the ADP slice-count distribution, for a
//! well-conditioned matrix and for one with a graded column scaling (which
//! forces ADP to vary its slice counts).
//!
//! ```sh
//! cargo run --release --offline --example adaptive_qr [n] [panel]
//! ```

use adp_dgemm::coordinator::heuristic::AlwaysEmulate;
use adp_dgemm::coordinator::{AdpConfig, AdpEngine};
use adp_dgemm::linalg::{blocked_qr, GemmBackend, Matrix, NativeGemm};
use adp_dgemm::ozaki::{emulated_gemm, OzakiConfig};
use adp_dgemm::util::Rng;

struct FixedEmulation(usize);
impl GemmBackend for FixedEmulation {
    fn gemm(&mut self, a: &Matrix, b: &Matrix) -> Matrix {
        emulated_gemm(a, b, &OzakiConfig::new(self.0))
    }
    fn name(&self) -> &'static str {
        "fixed-7-slice"
    }
}

fn main() {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(192);
    let panel: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(32);
    let mut rng = Rng::new(7);

    for (label, a) in [
        ("uniform(-1,1)", Matrix::uniform(n, n, -1.0, 1.0, &mut rng)),
        ("graded columns (2^(j/8))", {
            let mut m = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
            for j in 0..n {
                let s = 2f64.powi(j as i32 / 8 - (n as i32) / 16);
                for i in 0..n {
                    *m.at_mut(i, j) *= s;
                }
            }
            m
        }),
    ] {
        println!("=== QR n={n} panel={panel}: {label} ===");

        let t = std::time::Instant::now();
        let (qr, stats) = blocked_qr(&a, panel, &mut NativeGemm);
        println!(
            "  native-fp64    : {:>7.1} ms  residual {:.3e}  orth {:.3e}  ({} trailing GEMMs)",
            t.elapsed().as_secs_f64() * 1e3,
            qr.residual(&a),
            qr.orthogonality(),
            stats.gemm_calls
        );

        let t = std::time::Instant::now();
        let (qr, _) = blocked_qr(&a, panel, &mut FixedEmulation(7));
        println!(
            "  fixed-7-slices : {:>7.1} ms  residual {:.3e}  orth {:.3e}  (no guardrails)",
            t.elapsed().as_secs_f64() * 1e3,
            qr.residual(&a),
            qr.orthogonality()
        );

        let mut engine = AdpEngine::new(
            AdpConfig::fp64().with_heuristic(Box::new(AlwaysEmulate)).with_runtime(None),
        );
        let t = std::time::Instant::now();
        let (qr, _) = blocked_qr(&a, panel, &mut engine);
        let snap = engine.metrics.snapshot();
        println!(
            "  adp-dynamic    : {:>7.1} ms  residual {:.3e}  orth {:.3e}",
            t.elapsed().as_secs_f64() * 1e3,
            qr.residual(&a),
            qr.orthogonality()
        );
        println!(
            "    dispatch: {} emulated, {} fallbacks | slice histogram {:?} (Fig 7 right)",
            snap.emulated,
            snap.fallbacks(),
            snap.slice_histogram
        );
        println!();
    }
}
