//! The BLAS grading suite of §6 run against every GEMM implementation in
//! the repo: algorithm discovery (Tests 1–3) plus the Grade A/C criteria.
//!
//! Reproduces the paper's headline numerical claims:
//!   A1 — Test 2 cannot distinguish guardrailed ADP from floating point;
//!   A2 — ADP meets the Grade A componentwise criterion.
//!
//! ```sh
//! cargo run --release --offline --example grading_suite [n]
//! ```

use adp_dgemm::coordinator::heuristic::AlwaysEmulate;
use adp_dgemm::coordinator::{AdpConfig, AdpEngine};
use adp_dgemm::grading::{self, grade, generators};
use adp_dgemm::linalg::{gemm, strassen, Matrix};
use adp_dgemm::ozaki::{emulated_gemm, OzakiConfig};
use adp_dgemm::util::Rng;

fn main() {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(128);
    let seed = 11u64;

    let engine = AdpEngine::new(
        AdpConfig::fp64().with_heuristic(Box::new(AlwaysEmulate)).with_runtime(None),
    );

    println!("=== algorithm discovery (Tests 1-3), n={n} ===");
    let impls: Vec<(&str, Box<dyn FnMut(&Matrix, &Matrix) -> Matrix>)> = vec![
        ("native fp64", Box::new(|a: &Matrix, b: &Matrix| gemm(a, b))),
        ("strassen", Box::new(|a: &Matrix, b: &Matrix| strassen(a, b))),
        ("ozaki fixed-7 (no guardrails)", Box::new(|a: &Matrix, b: &Matrix| {
            emulated_gemm(a, b, &OzakiConfig::new(7))
        })),
        ("adp (guardrails + fallback)", Box::new(|a: &Matrix, b: &Matrix| engine.gemm(a, b).0)),
    ];
    for (name, mut f) in impls {
        // Strassen needs n > its 64-cutoff to recurse; use 4n for it.
        let nn = if name == "strassen" { n.max(256) } else { n };
        let class = grading::discover(nn, seed, &mut *f);
        println!("  {name:<32} -> {class:?}");
    }

    println!("\n=== Test 2 error sweep (the Fig 2 axis), n=64 ===");
    println!("  {:<6} {:>14} {:>14} {:>14}", "b", "native", "fixed-7", "adp");
    let mut rng = Rng::new(seed);
    for b in [0, 8, 16, 24, 32, 48, 64, 96] {
        let w = generators::test2_workload(64, b, &mut rng);
        let e_nat = grading::test2::relative_error(&w, &gemm(&w.a, &w.b));
        let e_fix =
            grading::test2::relative_error(&w, &emulated_gemm(&w.a, &w.b, &OzakiConfig::new(7)));
        let e_adp = grading::test2::relative_error(&w, &engine.gemm(&w.a, &w.b).0);
        println!("  {b:<6} {e_nat:>14.3e} {e_fix:>14.3e} {e_adp:>14.3e}");
    }

    println!("\n=== Grade A criterion (Aspect A2), uniform(0,1) ===");
    println!("  {:<6} {:>12} {:>12} {:>12}  (max componentwise err, eps units)", "n", "native", "adp", "strassen");
    for nn in [64usize, 128, 256] {
        let mut rng = Rng::new(seed + nn as u64);
        let (a, b) = generators::uniform_pair(nn, 0.0, 1.0, &mut rng);
        let rn = grade::measure(&a, &b, &gemm(&a, &b));
        let ra = grade::measure(&a, &b, &engine.gemm(&a, &b).0);
        let rs = grade::measure(&a, &b, &strassen(&a, &b));
        println!(
            "  {nn:<6} {:>12.2} {:>12.2} {:>12.2}   grade A: native {} adp {} strassen {}",
            rn.max_comp_eps,
            ra.max_comp_eps,
            rs.max_comp_eps,
            pass(grade::passes_grade_a(&rn, nn, 2.0)),
            pass(grade::passes_grade_a(&ra, nn, 2.0)),
            pass(grade::passes_grade_a(&rs, nn, 2.0)),
        );
    }
    let snap = engine.metrics.snapshot();
    println!(
        "\nadp dispatch over the whole suite: {} emulated, {} esc-fallbacks (both paths exercised)",
        snap.emulated, snap.fallback_esc
    );
}

fn pass(b: bool) -> &'static str {
    if b {
        "PASS"
    } else {
        "fail"
    }
}
