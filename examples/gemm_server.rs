//! End-to-end driver: the ADP GEMM *service* under a realistic mixed
//! request stream, with the AOT artifact path engaged.
//!
//! This is the repo's end-to-end validation (DESIGN.md): it loads the AOT
//! artifacts produced by `make artifacts`, starts the multi-worker
//! coordinator, replays a mixed workload (benign / wide-span / NaN / Inf /
//! tiny / ragged shapes), verifies every response against a double-double
//! reference, and reports latency percentiles, throughput, the dispatch
//! histogram and the guardrail-overhead share (§7.1's <10% claim, measured
//! on this substrate). Results are recorded in EXPERIMENTS.md §E2E.
//!
//! ```sh
//! make artifacts && cargo run --release --offline --example gemm_server
//! ```

use std::path::Path;
use std::time::Instant;

use adp_dgemm::coordinator::heuristic::AlwaysEmulate;
use adp_dgemm::coordinator::{GemmService, ServiceConfig};
use adp_dgemm::grading::generators::{self, SpecialKind};
use adp_dgemm::linalg::Matrix;
use adp_dgemm::runtime::RuntimeHandle;
use adp_dgemm::util::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Kind {
    Benign,
    WideSpan,
    Nan,
    Inf,
    ExtremeSpan,
    Ragged,
}

fn main() {
    let requests: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(200);
    let rt = RuntimeHandle::try_load(Path::new("artifacts"));
    match &rt {
        Some(r) => {
            println!("artifacts: {} entries", r.catalog().entries.len());
            // warm the hot artifacts so latency numbers are steady-state
            for &(kind, n, s) in &[
                (adp_dgemm::runtime::ArtifactKind::Gemm, 64usize, 7usize),
                (adp_dgemm::runtime::ArtifactKind::Dgemm, 64, 0),
            ] {
                let _ = r.warm(kind, n, s);
            }
        }
        None => println!("artifacts: none (native pipeline only) — run `make artifacts`"),
    }

    let cfg = ServiceConfig { workers: 4, ..Default::default() };
    let svc = GemmService::start(cfg, rt, || Box::new(AlwaysEmulate));

    let mut rng = Rng::new(0xE2E);
    let t0 = Instant::now();
    let mut pending = Vec::new();
    for i in 0..requests {
        let kind = match i % 10 {
            0 => Kind::WideSpan,
            3 => Kind::Nan,
            6 => Kind::Inf,
            7 => Kind::ExtremeSpan,
            8 => Kind::Ragged,
            _ => Kind::Benign,
        };
        let (a, b) = make_request(kind, &mut rng);
        pending.push((kind, a.clone(), b.clone(), svc.submit(a, b).expect("service running")));
    }

    let mut lat = Vec::new();
    let mut verified = 0usize;
    for (kind, a, b, rx) in pending {
        let resp = rx.recv().expect("service dropped reply").expect("request failed");
        lat.push(resp.total_s);
        // verify every finite response against the dd reference
        if kind != Kind::Nan && kind != Kind::Inf {
            let c_ref = a.matmul_dd(&b);
            let denom = a.abs().matmul_dd(&b.abs());
            for idx in 0..resp.c.data.len() {
                let d = denom.data[idx];
                if d > 0.0 {
                    let e = (resp.c.data[idx] - c_ref.data[idx]).abs() / d;
                    assert!(e < 200.0 * f64::EPSILON, "{kind:?}: err {e}");
                }
            }
            verified += 1;
        } else {
            assert!(resp.c.has_non_finite(), "{kind:?} must propagate specials");
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());

    let snap = svc.metrics.snapshot();
    println!("\n=== end-to-end report ({requests} requests, 4 workers) ===");
    println!(
        "throughput: {:.1} req/s | latency p50 {:.2} ms, p90 {:.2} ms, p99 {:.2} ms",
        requests as f64 / wall,
        lat[lat.len() / 2] * 1e3,
        lat[(lat.len() * 9) / 10] * 1e3,
        lat[(lat.len() * 99) / 100] * 1e3
    );
    println!(
        "dispatch: emulated {} | fallback nan {} inf {} esc {} heuristic {}",
        snap.emulated, snap.fallback_nan, snap.fallback_inf, snap.fallback_esc, snap.fallback_heuristic
    );
    println!("slice histogram: {:?}", snap.slice_histogram);
    println!(
        "guardrail share of total compute: {:.2}%  (paper §7.1 bound: <10%)",
        snap.guardrail_fraction() * 100.0
    );
    println!("accuracy: all {verified} finite responses verified against double-double reference");
    svc.shutdown();
}

fn make_request(kind: Kind, rng: &mut Rng) -> (Matrix, Matrix) {
    match kind {
        Kind::Benign => {
            let n = 64;
            generators::uniform_pair(n, -1.0, 1.0, rng)
        }
        Kind::WideSpan => {
            let n = 64;
            let (mut a, mut b) = generators::uniform_pair(n, 1.0, 2.0, rng);
            for l in 0..n {
                let e = (l as i32 - 32) / 3;
                for i in 0..n {
                    *a.at_mut(i, l) *= 2f64.powi(e);
                    *b.at_mut(l, i) *= 2f64.powi(-e);
                }
            }
            (a, b)
        }
        Kind::Nan => generators::with_special_values(48, SpecialKind::Nan, rng),
        Kind::Inf => generators::with_special_values(48, SpecialKind::PosInf, rng),
        Kind::ExtremeSpan => {
            let (mut a, mut b) = generators::uniform_pair(32, 1.0, 2.0, rng);
            *a.at_mut(0, 0) = 1e300;
            *b.at_mut(0, 0) = 1e-300;
            (a, b)
        }
        Kind::Ragged => {
            let m = 40 + rng.index(20);
            let k = 30 + rng.index(30);
            let n = 20 + rng.index(40);
            (
                Matrix::uniform(m, k, -1.0, 1.0, rng),
                Matrix::uniform(k, n, -1.0, 1.0, rng),
            )
        }
    }
}
