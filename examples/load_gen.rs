//! SLO-grade load generator: drive the sharded `GemmService` with
//! mixed-shape traffic on all three priority tiers at saturation, then
//! report per-tier p50/p99 queue + total latency, throughput, and
//! rejection rate — human-readable lines plus a machine-readable
//! `BENCH_service.json` (archived from CI, like `BENCH_ablation.json` /
//! `BENCH_hotpath.json`) so the service perf trajectory is recorded
//! across PRs.
//!
//! Three open-loop submitter threads run until the deadline, one per
//! tier, using the non-blocking APIs so backpressure shows up as
//! *counted rejections* instead of submitter stalls:
//!
//! * `high`   — interactive-sized requests via `submit_async` tickets;
//! * `normal` — medium requests via `try_submit`;
//! * `batch`  — shared-A groups via `submit_batch` (the one blocking
//!   path: bulk traffic is allowed to wait its turn).
//!
//! ```sh
//! cargo run --release --offline --example load_gen          # ~2 s run
//! LOADGEN_SECONDS=0.3 cargo run --release --example load_gen  # CI smoke
//! ```
//!
//! Env knobs: `LOADGEN_SECONDS` (default 2.0), `LOADGEN_WORKERS`
//! (default 4), `LOADGEN_SHARDS` (default 2), `LOADGEN_OUT` (default
//! `BENCH_service.json`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use adp_dgemm::coordinator::heuristic::AlwaysEmulate;
use adp_dgemm::coordinator::{GemmResult, GemmService, Priority, ServiceConfig};
use adp_dgemm::linalg::{gemm, Matrix};
use adp_dgemm::util::Rng;

fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Pre-generated operand pool: generation is O(n^2) against the GEMMs'
/// O(n^3), but keeping it off the submission loop makes the offered
/// load steadier.
fn pool(sizes: &[usize], per_size: usize, seed: u64) -> Vec<(Matrix, Matrix)> {
    let mut rng = Rng::new(seed);
    let mut pairs = Vec::new();
    for &n in sizes {
        for _ in 0..per_size {
            pairs.push((
                Matrix::uniform(n, n, -1.0, 1.0, &mut rng),
                Matrix::uniform(n, n, -1.0, 1.0, &mut rng),
            ));
        }
    }
    pairs
}

/// Drain-or-keep pass over pending replies; returns completions seen.
fn drain<T>(pending: &mut Vec<T>, mut poll: impl FnMut(&mut T) -> Option<GemmResult>) -> u64 {
    let mut done = 0;
    pending.retain_mut(|p| match poll(p) {
        Some(r) => {
            r.expect("load_gen submits only valid shapes");
            done += 1;
            false
        }
        None => true,
    });
    done
}

fn main() {
    let seconds = env_f64("LOADGEN_SECONDS", 2.0).max(0.05);
    let workers = env_usize("LOADGEN_WORKERS", 4);
    let shards = env_usize("LOADGEN_SHARDS", 2);
    let out_path = std::env::var("LOADGEN_OUT").unwrap_or_else(|| "BENCH_service.json".into());

    // Tight queues so saturation actually sheds load (the rejection-rate
    // column must measure something), coalescing on so the grouped
    // pipeline carries the bulk tier.
    let cfg = ServiceConfig {
        workers,
        shards,
        queue_depth: 64,
        tier_depths: [16, 32, 32],
        coalesce: true,
        coalesce_window: Duration::from_micros(200),
        ..Default::default()
    };
    let svc = Arc::new(GemmService::start(cfg, None, || Box::new(AlwaysEmulate)));

    // Sanity pin before opening the floodgates: the service result is
    // the real GEMM.
    {
        let mut rng = Rng::new(0x10AD);
        let a = Matrix::uniform(24, 24, -1.0, 1.0, &mut rng);
        let b = Matrix::uniform(24, 24, -1.0, 1.0, &mut rng);
        let resp = svc.gemm_blocking(a.clone(), b.clone()).expect("warmup request");
        assert!(resp.c.sub(&gemm(&a, &b)).max_abs() < 1e-12, "service result mismatch");
    }
    // Measure the load run only, not the warmup request.
    svc.metrics.reset();

    let completed = Arc::new([AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0)]);
    let deadline = Instant::now() + Duration::from_secs_f64(seconds);
    let t0 = Instant::now();

    // high tier: interactive-sized requests through submit_async tickets.
    let high = {
        let (svc, completed) = (svc.clone(), completed.clone());
        std::thread::spawn(move || {
            let ops = pool(&[16, 24, 32], 4, 1);
            let mut tickets = Vec::new();
            let mut i = 0usize;
            while Instant::now() < deadline {
                let (a, b) = ops[i % ops.len()].clone();
                i += 1;
                match svc.submit_async(a, b, Priority::High) {
                    Ok(t) => tickets.push(t),
                    Err(rej) => {
                        assert!(rej.error.is_retryable(), "unexpected: {}", rej.error);
                        std::thread::yield_now();
                    }
                }
                let done = drain(&mut tickets, |t| t.poll());
                completed[Priority::High.index()].fetch_add(done, Ordering::Relaxed);
            }
            let n = tickets.len() as u64;
            for t in tickets {
                t.wait().expect("load_gen submits only valid shapes");
            }
            completed[Priority::High.index()].fetch_add(n, Ordering::Relaxed);
        })
    };

    // normal tier: medium requests through try_submit receivers.
    let normal = {
        let (svc, completed) = (svc.clone(), completed.clone());
        std::thread::spawn(move || {
            let ops = pool(&[48, 64], 4, 2);
            let mut pending = Vec::new();
            let mut i = 0usize;
            while Instant::now() < deadline {
                let (a, b) = ops[i % ops.len()].clone();
                i += 1;
                match svc.try_submit(a, b) {
                    Ok(rx) => pending.push(rx),
                    Err(rej) => {
                        assert!(rej.error.is_retryable(), "unexpected: {}", rej.error);
                        std::thread::yield_now();
                    }
                }
                let done = drain(&mut pending, |rx| rx.try_recv().ok());
                completed[Priority::Normal.index()].fetch_add(done, Ordering::Relaxed);
            }
            let n = pending.len() as u64;
            for rx in pending {
                rx.recv().expect("reply").expect("load_gen submits only valid shapes");
            }
            completed[Priority::Normal.index()].fetch_add(n, Ordering::Relaxed);
        })
    };

    // batch tier: shared-A groups through submit_batch (blocking: bulk
    // traffic waits for queue space instead of shedding).
    let batch = {
        let (svc, completed) = (svc.clone(), completed.clone());
        std::thread::spawn(move || {
            let ops = pool(&[32, 64, 96], 2, 3);
            let mut pending = Vec::new();
            let mut i = 0usize;
            while Instant::now() < deadline {
                let (a, _) = ops[i % ops.len()].clone();
                let group: Vec<(Matrix, Matrix)> =
                    (0..4).map(|j| (a.clone(), ops[(i + j) % ops.len()].1.clone())).collect();
                i += 1;
                match svc.submit_batch(group) {
                    Ok(rxs) => pending.extend(rxs),
                    Err(e) => panic!("blocking batch submit failed: {e}"),
                }
                let done = drain(&mut pending, |rx| rx.try_recv().ok());
                completed[Priority::Batch.index()].fetch_add(done, Ordering::Relaxed);
            }
            let n = pending.len() as u64;
            for rx in pending {
                rx.recv().expect("reply").expect("load_gen submits only valid shapes");
            }
            completed[Priority::Batch.index()].fetch_add(n, Ordering::Relaxed);
        })
    };

    high.join().expect("high submitter");
    normal.join().expect("normal submitter");
    batch.join().expect("batch submitter");
    let wall = t0.elapsed().as_secs_f64();
    assert_eq!(svc.inflight(), 0, "drained load run must leave nothing inflight");

    let snap = svc.metrics.snapshot();
    let mut total_rps = 0.0;
    let mut tier_objs = Vec::new();
    println!("# service load: {wall:.2}s wall, {workers} workers / {shards} shard(s), coalesce on");
    for p in Priority::ALL {
        let t = &snap.tiers[p.index()];
        let done = completed[p.index()].load(Ordering::Relaxed);
        assert_eq!(done, t.completed, "tier {}: client and service counts agree", t.tier);
        let rps = t.completed as f64 / wall;
        total_rps += rps;
        println!(
            "tier {:<6} enq={} done={} rejected={} ({:.1}%) | {:.1} req/s | queue p50/p99 {:.2}/{:.2} ms | total p50/p99 {:.2}/{:.2} ms",
            t.tier,
            t.enqueued,
            t.completed,
            t.rejected,
            t.rejection_rate() * 100.0,
            rps,
            t.queue_p50_s * 1e3,
            t.queue_p99_s * 1e3,
            t.total_p50_s * 1e3,
            t.total_p99_s * 1e3
        );
        tier_objs.push(format!(
            "{{\"tier\":\"{}\",\"enqueued\":{},\"completed\":{},\"failed\":{},\"rejected\":{},\"rejection_rate\":{:.6},\"throughput_rps\":{:.3},\"queue_p50_s\":{:.9},\"queue_p99_s\":{:.9},\"total_p50_s\":{:.9},\"total_p99_s\":{:.9}}}",
            t.tier,
            t.enqueued,
            t.completed,
            t.failed,
            t.rejected,
            t.rejection_rate(),
            rps,
            t.queue_p50_s,
            t.queue_p99_s,
            t.total_p50_s,
            t.total_p99_s
        ));
    }
    println!(
        "total: {:.1} req/s | emulated {} | coalesced {} reqs in {} buckets",
        total_rps, snap.emulated, snap.coalesced_requests, snap.coalesced_batches
    );

    // Hand-rolled JSON (serde is unavailable offline), same shape family
    // as util::benchkit::JsonReport: context fields + one array.
    let mut json = String::from("{\n  \"bench\": \"service_load\"");
    for (k, v) in [
        ("seconds", format!("{wall:.3}")),
        ("workers", workers.to_string()),
        ("shards", shards.to_string()),
        ("coalesce", "true".to_string()),
        ("total_throughput_rps", format!("{total_rps:.3}")),
        ("requests", snap.requests.to_string()),
    ] {
        json.push_str(&format!(",\n  \"{k}\": \"{v}\""));
    }
    json.push_str(",\n  \"tiers\": [\n");
    for (i, obj) in tier_objs.iter().enumerate() {
        json.push_str("    ");
        json.push_str(obj);
        json.push_str(if i + 1 < tier_objs.len() { ",\n" } else { "\n" });
    }
    json.push_str("  ]\n}\n");
    std::fs::write(&out_path, json).expect("write BENCH_service.json");
    println!("wrote {out_path}");
    svc.shutdown();
}
