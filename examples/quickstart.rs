//! Quickstart: ADP-enabled DGEMM as a drop-in replacement.
//!
//! Demonstrates the whole §5 pipeline on three kinds of input — benign,
//! wide-exponent-span, and NaN-laced — plus the §3 unsigned-encoding
//! worked example of Fig 1. Run with:
//!
//! ```sh
//! make artifacts && cargo run --release --offline --example quickstart
//! ```
//!
//! (Works without artifacts too: ADP transparently uses the native
//! pipeline when no AOT artifact fits.)

use std::path::Path;

use adp_dgemm::coordinator::heuristic::AlwaysEmulate;
use adp_dgemm::coordinator::{AdpConfig, AdpEngine};
use adp_dgemm::grading::grade;
use adp_dgemm::linalg::Matrix;
use adp_dgemm::ozaki::slicing::fig1_remap;
use adp_dgemm::ozaki::SliceEncoding;
use adp_dgemm::runtime::RuntimeHandle;
use adp_dgemm::util::Rng;

fn main() {
    println!("=== Fig 1: unsigned slice encoding via two's complement ===");
    let (hi, lo) = fig1_remap(123, 200);
    println!("  123*256 + 200(u8)  ==  {hi}*256 + ({lo})(s8); bits of 200: {:#010b}", lo as u8);
    println!(
        "  slices for 53-bit FP64 fidelity: unsigned {} vs signed {}  (the 22% saving of §3)\n",
        SliceEncoding::Unsigned.slices_for_bits(53),
        SliceEncoding::Signed.slices_for_bits(53)
    );

    let rt = RuntimeHandle::try_load(Path::new("artifacts"));
    println!(
        "=== ADP engine ({} artifacts) ===",
        rt.as_ref().map(|r| r.catalog().entries.len()).unwrap_or(0)
    );
    let engine = AdpEngine::new(
        AdpConfig::fp64().with_heuristic(Box::new(AlwaysEmulate)).with_runtime(rt),
    );

    let n = 64;
    let mut rng = Rng::new(42);

    // 1. benign input: emulation at the ESC-chosen slice count
    let a = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
    let b = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
    run_one("benign uniform(-1,1)", &engine, &a, &b);

    // 2. wide exponent span arranged so big a's pair with small b's: more
    //    slices needed; ESC sizes them automatically
    let mut aw = Matrix::uniform(n, n, 1.0, 2.0, &mut rng);
    let mut bw = Matrix::uniform(n, n, 1.0, 2.0, &mut rng);
    for l in 0..n {
        let e = (l as i32 - 32) / 2;
        for i in 0..n {
            *aw.at_mut(i, l) *= 2f64.powi(e);
            *bw.at_mut(l, i) *= 2f64.powi(-e);
        }
    }
    run_one("wide exponent span", &engine, &aw, &bw);

    // 3. extreme span: beyond the slice budget, ADP falls back to FP64
    let mut ax = aw.clone();
    let mut bx = bw.clone();
    *ax.at_mut(0, 0) = 1e300;
    *bx.at_mut(0, 0) = 1e-300;
    run_one("extreme span (ESC fallback)", &engine, &ax, &bx);

    // 4. NaN input: safety fallback, NaN propagates with native semantics
    let mut an = a.clone();
    *an.at_mut(3, 4) = f64::NAN;
    let (cn, out) = engine.gemm(&an, &b);
    println!(
        "  {:<28} -> {:<22} (row 3 NaN propagated: {})",
        "NaN-laced input",
        out.decision.label(),
        cn.at(3, 0).is_nan()
    );

    let snap = engine.metrics.snapshot();
    println!(
        "\nmetrics: {} requests, {} emulated, {} fallbacks, guardrail share {:.2}%",
        snap.requests,
        snap.emulated,
        snap.fallbacks(),
        snap.guardrail_fraction() * 100.0
    );
}

fn run_one(label: &str, engine: &AdpEngine, a: &Matrix, b: &Matrix) {
    let (c, out) = engine.gemm(a, b);
    let rep = grade::measure(a, b, &c);
    println!(
        "  {:<28} -> {:<22} esc={:<4} slices={:<2} max err {:>8.2} eps (grade A: {})",
        label,
        out.decision.label(),
        out.esc,
        out.slices_required,
        rep.max_comp_eps,
        if grade::passes_grade_a(&rep, a.rows, 2.0) { "PASS" } else { "FAIL" }
    );
}
