//! SLO-grade service behavior, end to end: the sharded/async front end's
//! operational guarantees under real thread fleets.
//!
//! * **No convoy through the coalescing window** (regression for the
//!   old lock-held `recv_timeout` drain): while one shard's worker sits
//!   in a long micro-batching window, the *other* shard keeps serving at
//!   full speed.
//! * **No service path panics the submitter**: shape mismatches and
//!   engine panics surface as typed `GemmError`s on every submission API
//!   (blocking, ticket, callback), workers survive, and the inflight
//!   gauge drains to zero.
//! * **Latency accounting is exact**: every response reports
//!   `total_s == queue_s + proc_s` bit-for-bit, on the singleton and the
//!   grouped path, under concurrency.
//!
//! Each test runs under a watchdog so a deadlock regression fails fast
//! instead of hanging the suite.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use adp_dgemm::coordinator::heuristic::{AlwaysEmulate, HeuristicInput, SelectionHeuristic};
use adp_dgemm::coordinator::{GemmError, GemmService, Priority, ServiceConfig};
use adp_dgemm::linalg::Matrix;
use adp_dgemm::util::Rng;

/// Run `f` on a helper thread and fail if it does not finish in `limit`.
fn with_watchdog(limit: Duration, f: impl FnOnce() + Send + 'static) {
    let body = std::thread::spawn(f);
    let deadline = Instant::now() + limit;
    while !body.is_finished() {
        assert!(Instant::now() < deadline, "test exceeded the {limit:?} watchdog (deadlock?)");
        std::thread::sleep(Duration::from_millis(20));
    }
    if let Err(e) = body.join() {
        std::panic::resume_unwind(e);
    }
}

#[test]
fn other_shard_keeps_serving_during_a_coalescing_window() {
    // The convoy regression: the old dispatcher held the shared queue
    // mutex across its `coalesce_window` wait, so one coalescing worker
    // stalled every dequeue in the service. Sharded + condvar-timed
    // drains, a window on shard X must cost shard Y nothing.
    with_watchdog(Duration::from_secs(60), || {
        let window = Duration::from_millis(1500);
        let cfg = ServiceConfig {
            workers: 2, // one worker per shard
            shards: 2,
            use_artifacts: false,
            coalesce: true,
            coalesce_window: window,
            max_batch: 64, // never filled: the window runs its course
            ..Default::default()
        };
        let svc = GemmService::start(cfg, None, || Box::new(AlwaysEmulate));
        assert_eq!(svc.shard_count(), 2);
        // Find two small square shapes routed to different shards.
        let n_x = 8;
        let shard_x = svc.shard_for(n_x, n_x, n_x);
        let n_y = (9..40)
            .find(|&n| svc.shard_for(n, n, n) != shard_x)
            .expect("some shape must land on the other shard");
        let mut rng = Rng::new(710);
        let mk = |n: usize, rng: &mut Rng| {
            (Matrix::uniform(n, n, -1.0, 1.0, rng), Matrix::uniform(n, n, -1.0, 1.0, rng))
        };
        // Park shard X's worker in its coalescing window (a lone single
        // submission waits out the whole window for stragglers).
        let (a, b) = mk(n_x, &mut rng);
        let rx_x = svc.submit(a, b).expect("service running");
        std::thread::sleep(Duration::from_millis(50)); // let the window open
        // Shard Y must serve a stream of requests while X's window runs.
        // Explicit groups execute immediately (a `submit_batch` item ends
        // any window early), so each round trip measures shard Y's
        // responsiveness, not its own coalescing window.
        let t0 = Instant::now();
        for _ in 0..4 {
            let (a, b) = mk(n_y, &mut rng);
            let rxs = svc.submit_batch(vec![(a, b)]).expect("service running");
            for rx in rxs {
                let resp = rx.recv().expect("reply").expect("served");
                assert!(resp.outcome.decision.is_emulated());
            }
        }
        let y_elapsed = t0.elapsed();
        assert!(
            y_elapsed < window / 2,
            "shard Y took {y_elapsed:?} while shard X coalesced — the window convoyed the service"
        );
        // Shard X's request completes once its window closes.
        let resp = rx_x.recv().expect("reply").expect("served");
        assert!(resp.proc_s > 0.0);
        assert_eq!(svc.inflight(), 0);
        svc.shutdown();
    });
}

/// Panics inside the engine whenever m == 5 (heuristics run on the
/// workers, so this drives a worker-side engine panic on demand).
struct PanicOnFive;

impl SelectionHeuristic for PanicOnFive {
    fn emulate(&self, inp: &HeuristicInput) -> bool {
        assert!(inp.m != 5, "slo-suite heuristic bomb");
        true
    }
    fn name(&self) -> &'static str {
        "panic-on-five"
    }
}

#[test]
fn failure_modes_surface_as_typed_errors_on_every_api() {
    with_watchdog(Duration::from_secs(60), || {
        let cfg = ServiceConfig { workers: 2, use_artifacts: false, ..Default::default() };
        let svc = GemmService::start(cfg, None, || Box::new(PanicOnFive));
        // Blocking path: mismatch and panic, both typed.
        assert!(matches!(
            svc.gemm_blocking(Matrix::zeros(3, 4), Matrix::zeros(5, 3)),
            Err(GemmError::ShapeMismatch { m: 3, k_a: 4, k_b: 5, n: 3 })
        ));
        assert!(matches!(
            svc.gemm_blocking(Matrix::identity(5), Matrix::identity(5)),
            Err(GemmError::EnginePanic(_))
        ));
        // Ticket path.
        let t = svc
            .submit_async(Matrix::identity(5), Matrix::identity(5), Priority::High)
            .expect("admitted");
        assert!(matches!(t.wait(), Err(GemmError::EnginePanic(_))));
        // Callback path: invoked exactly once, with the typed error.
        let (tx, rx) = std::sync::mpsc::channel();
        svc.submit_callback(
            Matrix::zeros(2, 2),
            Matrix::zeros(3, 2),
            Priority::Batch,
            move |r| tx.send(r).unwrap(),
        )
        .expect("admitted");
        assert!(matches!(rx.recv().unwrap(), Err(GemmError::ShapeMismatch { .. })));
        // Grouped path: only the poisoned bucket fails.
        let rxs = svc
            .submit_batch(vec![
                (Matrix::identity(4), Matrix::identity(4)),
                (Matrix::identity(5), Matrix::identity(5)),
            ])
            .expect("service running");
        assert!(rxs[0].recv().unwrap().is_ok());
        assert!(matches!(rxs[1].recv().unwrap(), Err(GemmError::EnginePanic(_))));
        // The fleet survived all of it and still serves.
        let ok = svc.gemm_blocking(Matrix::identity(6), Matrix::identity(6)).expect("served");
        assert_eq!(ok.c.at(0, 0), 1.0);
        assert_eq!(svc.inflight(), 0, "failed requests must not leak inflight counts");
        let tiers = svc.metrics.snapshot().tiers;
        let failed: u64 = tiers.iter().map(|t| t.failed).sum();
        assert_eq!(failed, 5, "every typed error is accounted to its tier");
        svc.shutdown();
    });
}

#[test]
fn latency_components_stay_exact_under_concurrent_mixed_traffic() {
    with_watchdog(Duration::from_secs(120), || {
        let cfg = ServiceConfig {
            workers: 3,
            shards: 2,
            use_artifacts: false,
            coalesce: true,
            coalesce_window: Duration::from_micros(300),
            ..Default::default()
        };
        let svc = Arc::new(GemmService::start(cfg, None, || Box::new(AlwaysEmulate)));
        let checked = Arc::new(AtomicU64::new(0));
        let mut fleet = Vec::new();
        for t in 0..4u64 {
            let svc = svc.clone();
            let checked = checked.clone();
            fleet.push(std::thread::spawn(move || {
                let mut rng = Rng::new(0x510 + t);
                for i in 0..12usize {
                    let n = 6 + (i % 4) * 2;
                    let a = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
                    let b = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
                    let resp = if i % 3 == 0 {
                        let rxs = svc.submit_batch(vec![(a, b)]).expect("running");
                        rxs.into_iter().next().unwrap().recv().unwrap().expect("served")
                    } else {
                        svc.gemm_blocking(a, b).expect("served")
                    };
                    assert!(resp.queue_s >= 0.0 && resp.proc_s > 0.0);
                    assert_eq!(
                        resp.total_s.to_bits(),
                        (resp.queue_s + resp.proc_s).to_bits(),
                        "reported total_s must be the exact sum of its components"
                    );
                    checked.fetch_add(1, Ordering::SeqCst);
                }
            }));
        }
        for f in fleet {
            f.join().expect("submitter panicked");
        }
        assert_eq!(checked.load(Ordering::SeqCst), 48);
        assert_eq!(svc.inflight(), 0);
        // The per-tier histograms saw every completion.
        let tiers = svc.metrics.snapshot().tiers;
        let completed: u64 = tiers.iter().map(|t| t.completed).sum();
        assert_eq!(completed, 48);
        assert!(tiers[Priority::Normal.index()].total_p50_s > 0.0);
        assert!(tiers[Priority::Batch.index()].total_p50_s > 0.0);
        svc.shutdown();
    });
}

#[test]
fn async_tickets_and_callbacks_complete_a_mixed_stream() {
    with_watchdog(Duration::from_secs(60), || {
        let cfg = ServiceConfig {
            workers: 2,
            shards: 2,
            use_artifacts: false,
            ..Default::default()
        };
        let svc = GemmService::start(cfg, None, || Box::new(AlwaysEmulate));
        let done = Arc::new(AtomicU64::new(0));
        let mut rng = Rng::new(0xA57);
        let mut tickets = Vec::new();
        for i in 0..10usize {
            let n = 5 + i % 3;
            let a = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
            let b = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
            if i % 2 == 0 {
                tickets.push(
                    svc.submit_async(a, b, Priority::High).expect("admitted (queues are roomy)"),
                );
            } else {
                let done = done.clone();
                svc.submit_callback(a, b, Priority::Normal, move |r| {
                    r.expect("served");
                    done.fetch_add(1, Ordering::SeqCst);
                })
                .expect("admitted (queues are roomy)");
            }
        }
        for t in tickets {
            t.wait().expect("served");
        }
        while done.load(Ordering::SeqCst) < 5 {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(svc.inflight(), 0);
        let tiers = svc.metrics.snapshot().tiers;
        assert_eq!(tiers[Priority::High.index()].completed, 5);
        assert_eq!(tiers[Priority::Normal.index()].completed, 5);
        svc.shutdown();
    });
}

#[test]
fn stragglers_held_across_shutdown_resolve_and_never_hang() {
    // The shutdown contract for handles that outlive the service: every
    // admitted request is served during the drain (close-then-drain
    // queues), so tickets, receivers, and callbacks held across
    // `shutdown()` all resolve — Ok here, never a hang, never a `recv`
    // panic. Post-shutdown submissions fail typed on every API.
    with_watchdog(Duration::from_secs(60), || {
        let cfg = ServiceConfig { workers: 1, use_artifacts: false, ..Default::default() };
        let svc = GemmService::start(cfg, None, || Box::new(AlwaysEmulate));
        let mut rng = Rng::new(0x57A6);
        let mk = |n: usize, rng: &mut Rng| {
            (Matrix::uniform(n, n, -1.0, 1.0, rng), Matrix::uniform(n, n, -1.0, 1.0, rng))
        };
        // Queue stragglers on one worker, one per completion style.
        let (a, b) = mk(6, &mut rng);
        let t_wait = svc.submit_async(a, b, Priority::Normal).expect("admitted");
        let (a, b) = mk(8, &mut rng);
        let mut t_timeout = svc.submit_async(a, b, Priority::Normal).expect("admitted");
        let (a, b) = mk(10, &mut rng);
        let mut t_poll = svc.submit_async(a, b, Priority::Normal).expect("admitted");
        let (a, b) = mk(6, &mut rng);
        let rx = svc.submit(a, b).expect("admitted");
        let (cb_tx, cb_rx) = std::sync::mpsc::channel();
        let (a, b) = mk(8, &mut rng);
        svc.submit_callback(a, b, Priority::Batch, move |r| cb_tx.send(r).unwrap())
            .expect("admitted");
        // Shutdown with all five still (possibly) queued: drains and joins.
        svc.shutdown();
        // Every straggler style resolves without hanging.
        t_wait.wait().expect("drained and served");
        loop {
            // Exercises the timeout arm (None) when the reply raced ahead
            // of us it returns immediately; the watchdog bounds the loop.
            if let Some(r) = t_timeout.wait_timeout(Duration::from_millis(5)) {
                r.expect("drained and served");
                break;
            }
        }
        loop {
            if let Some(r) = t_poll.poll() {
                r.expect("drained and served");
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        rx.recv().expect("reply delivered").expect("drained and served");
        cb_rx.recv().expect("callback invoked").expect("drained and served");
        assert_eq!(svc.inflight(), 0);
        // Post-shutdown: typed rejection on every API, callbacks dropped
        // uninvoked (the Err return is the completion).
        let (a, b) = mk(6, &mut rng);
        assert!(svc.submit(a, b).is_err());
        let (a, b) = mk(6, &mut rng);
        assert!(svc.submit_async(a, b, Priority::High).is_err());
        let (a, b) = mk(6, &mut rng);
        assert!(matches!(svc.gemm_blocking(a, b), Err(GemmError::Rejected(_))));
        let (a, b) = mk(6, &mut rng);
        assert!(svc.submit_callback(a, b, Priority::Normal, |_| panic!("must not run")).is_err());
    });
}

#[test]
fn orderly_shutdown_flushes_learned_state_across_processes() {
    // Satellite for the shutdown-flush fix: a *separate process* running
    // `adp serve` with `ADP_COSTMODEL` set must leave a loadable catalog
    // behind after its orderly shutdown — previously the learned table
    // died with the process unless an unrelated save threshold happened
    // to trip. A second run then warm-loads it and flushes again.
    let dir = std::env::temp_dir();
    let cost = dir.join(format!("adp-slo-costmodel-{}.tsv", std::process::id()));
    let tune = dir.join(format!("adp-slo-tune-{}.tsv", std::process::id()));
    let _ = std::fs::remove_file(&cost);
    let _ = std::fs::remove_file(&tune);
    let run = |label: &str| {
        let out = std::process::Command::new(env!("CARGO_BIN_EXE_adp"))
            .args(["serve", "--requests", "8", "--n", "24", "--workers", "2"])
            .env("ADP_COSTMODEL", &cost)
            .env("ADP_TUNE_CATALOG", &tune)
            .output()
            .expect("spawn adp serve");
        assert!(
            out.status.success(),
            "{label} serve run failed:\n{}",
            String::from_utf8_lossy(&out.stderr)
        );
    };
    run("cold");
    let text = std::fs::read_to_string(&cost).expect("shutdown must flush the cost model");
    assert!(
        text.starts_with("# adp-dgemm cost-model catalog v1"),
        "flushed catalog must carry the versioned header, got: {:?}",
        text.lines().next()
    );
    run("warm");
    let text = std::fs::read_to_string(&cost).expect("warm run flushes too");
    assert!(text.starts_with("# adp-dgemm cost-model catalog v1"));
    assert!(
        !cost.with_extension("tsv.corrupt").exists(),
        "a clean catalog must never be quarantined on load"
    );
    let _ = std::fs::remove_file(&cost);
    let _ = std::fs::remove_file(&tune);
}
