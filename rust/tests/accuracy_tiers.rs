//! Integration suite for the dynamic accuracy tiers (the "dynamic
//! accuracy tiers" tentpole): per-tier error grading on the §6
//! discovery workloads, bitwise determinism of every tier across
//! backends and thread counts, bitwise identity of the guaranteed tier
//! with the seed semantics, mixed-tier grouped-batch isolation, and
//! cold-vs-warm decision stability of the online-learned cost model.

use std::sync::Arc;

use adp_dgemm::backend::{ParallelBackend, SerialBackend, WorkspacePool};
use adp_dgemm::coordinator::costmodel::MIN_SAMPLES;
use adp_dgemm::coordinator::heuristic::{AlwaysEmulate, EmulationChoice};
use adp_dgemm::coordinator::{AdpConfig, AdpEngine, GemmDecision};
use adp_dgemm::grading::grade::{measure, passes_grade_a};
use adp_dgemm::grading::{generators, test2, test3};
use adp_dgemm::linalg::Matrix;
use adp_dgemm::ozaki::{emulated_gemm, fused_gemm_on, AccuracyTier, OzakiConfig, ShapeBucket};
use adp_dgemm::util::Rng;
use adp_dgemm::{CostModel, LearnedHeuristic};

fn tier_engine(tier: AccuracyTier) -> AdpEngine {
    // AlwaysEmulate keeps the dispatch deterministic: every request runs
    // the tier's (possibly truncated) slice-pair schedule.
    AdpEngine::new(
        AdpConfig::fp64().with_heuristic(Box::new(AlwaysEmulate)).with_tier(tier),
    )
}

fn assert_bitwise(c1: &Matrix, c2: &Matrix, what: &str) {
    assert_eq!((c1.rows, c1.cols), (c2.rows, c2.cols), "{what}: shape");
    for (i, (x, y)) in c1.data.iter().zip(&c2.data).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: entry {i} ({x} vs {y})");
    }
}

/// Max componentwise relative error |C - AB| / (|A||B|), as a plain
/// ratio (not in eps units).
fn max_rel(a: &Matrix, b: &Matrix, c: &Matrix) -> f64 {
    measure(a, b, c).max_comp_eps * f64::EPSILON
}

#[test]
fn tier_bounds_hold_on_the_test1_staircase() {
    // Test 1's magnitude staircase (tiny first row of A / first column
    // of B) is the workload where componentwise error is hardest to
    // keep: the guaranteed tier must stay Grade A, and each fast tier
    // must hold its documented kept-bits bound (with generous slack for
    // the k-fold accumulation).
    let n = 64;
    let mut rng = Rng::new(900);
    let (a, b) = generators::tiny_corner_pair(n, 2f64.powi(-30), &mut rng);
    let mut errs = Vec::new();
    for tier in AccuracyTier::ALL {
        let eng = tier_engine(tier);
        let (c, out) = eng.gemm(&a, &b);
        assert!(out.decision.is_emulated(), "{tier:?}: {:?}", out.decision);
        let rep = measure(&a, &b, &c);
        match tier.kept_bits() {
            None => assert!(passes_grade_a(&rep, n, 64.0), "{tier:?}: {rep:?}"),
            Some(bits) => {
                let bound = 2f64.powi(-(bits - 12));
                let rel = rep.max_comp_eps * f64::EPSILON;
                assert!(rel < bound, "{tier:?}: rel {rel:e} vs bound {bound:e}");
            }
        }
        errs.push(rep.max_comp_eps);
    }
    // Error is monotone in the tier ordering: guaranteed <= fast <= fp32.
    assert!(errs[0] <= errs[1], "guaranteed {} vs fast {}", errs[0], errs[1]);
    assert!(errs[1] <= errs[2], "fast {} vs fp32 {}", errs[1], errs[2]);
}

#[test]
fn tier_bounds_hold_on_test2_and_test3_workloads() {
    // Test 2 (diagonal of the permuted-staircase product) and Test 3
    // (norm-wise on the same construction). The guaranteed tier holds
    // the paper's FP64 claim at every span; the fast tiers hold their
    // documented bounds on the well-conditioned (small-span) workload
    // they are specified for.
    let n = 48;
    {
        let eng = tier_engine(AccuracyTier::GuaranteedFp64);
        let mut m = |a: &Matrix, b: &Matrix| eng.gemm(a, b).0;
        for span in [8, 40] {
            let err = test2::run_at(n, span, 7, &mut m);
            assert!(err < 1e-12, "guaranteed test2 span {span}: {err}");
        }
        let err = test3::run_at(n, 8, 7, &mut m);
        assert!(err < 1e-12, "guaranteed test3: {err}");
    }
    let mut t2 = Vec::new();
    for (tier, bound) in
        [(AccuracyTier::Fp64FaithfulFast, 1e-4), (AccuracyTier::Fp32Grade, 1e-2)]
    {
        let eng = tier_engine(tier);
        let mut m = |a: &Matrix, b: &Matrix| eng.gemm(a, b).0;
        let err2 = test2::run_at(n, 4, 7, &mut m);
        assert!(err2 < bound, "{tier:?} test2: {err2} vs {bound}");
        let err3 = test3::run_at(n, 4, 7, &mut m);
        assert!(err3 < bound, "{tier:?} test3: {err3} vs {bound}");
        t2.push(err2);
        // The truncation genuinely skipped work (no silent escalation).
        let snap = eng.metrics.snapshot();
        assert!(snap.pairs_skipped > 0, "{tier:?}: {snap:?}");
        assert_eq!(snap.tier_escalations, 0, "{tier:?}: {snap:?}");
    }
    assert!(t2[0] <= t2[1], "fast {} must not exceed fp32 {}", t2[0], t2[1]);
}

#[test]
fn guaranteed_tier_bitwise_identical_across_backends_and_seed_path() {
    // The PR's compatibility criterion: the guaranteed tier is the
    // seed's bitwise semantics on every backend and thread count.
    let mut rng = Rng::new(901);
    let a = Matrix::uniform(48, 48, -2.0, 2.0, &mut rng);
    let b = Matrix::uniform(48, 48, -2.0, 2.0, &mut rng);
    let serial = tier_engine(AccuracyTier::GuaranteedFp64);
    let (c_ser, out) = serial.gemm(&a, &b);
    assert!(out.decision.is_emulated());
    for threads in [2usize, 4] {
        let eng = AdpEngine::new(
            AdpConfig::fp64()
                .with_heuristic(Box::new(AlwaysEmulate))
                .with_backend(Arc::new(ParallelBackend::new(threads).with_cutoff_ops(0)))
                .with_tier(AccuracyTier::GuaranteedFp64),
        );
        let (c_par, _) = eng.gemm(&a, &b);
        assert_bitwise(&c_ser, &c_par, &format!("guaranteed @ {threads} threads"));
    }
    // ...and identical to the pre-tier entry point at the same window.
    let s = out.decision.slices().unwrap();
    let c_seed = emulated_gemm(&a, &b, &OzakiConfig::new(s));
    assert_bitwise(&c_ser, &c_seed, "guaranteed vs seed semantics");
}

#[test]
fn every_tier_is_deterministic_across_backends() {
    // Truncated schedules keep the kept levels' weights and order, so
    // the fast tiers are just as deterministic as the full schedule:
    // serial and parallel fused runs must agree bitwise per tier.
    let par = ParallelBackend::new(3).with_cutoff_ops(0);
    let pool = WorkspacePool::new();
    let mut rng = Rng::new(902);
    let a = Matrix::uniform(70, 33, -3.0, 3.0, &mut rng);
    let b = Matrix::uniform(33, 65, -3.0, 3.0, &mut rng);
    for tier in AccuracyTier::ALL {
        let cfg = OzakiConfig::new(7).with_tier(tier);
        let c_ser = fused_gemm_on(&a, &b, &cfg, &SerialBackend, &pool);
        let c_par = fused_gemm_on(&a, &b, &cfg, &par, &pool);
        assert_bitwise(&c_ser, &c_par, &format!("{tier:?} serial vs parallel"));
    }
}

#[test]
fn mixed_tier_grouped_batches_isolate_members() {
    // Grouped rounds bucket by tier: a guaranteed member's bits never
    // change because a fast sibling shared the batch, and each tier's
    // grouped result equals its per-request result bitwise.
    let mut rng = Rng::new(903);
    let a = Matrix::uniform(40, 24, -2.0, 2.0, &mut rng);
    let b1 = Matrix::uniform(24, 40, -2.0, 2.0, &mut rng);
    let b2 = Matrix::uniform(24, 40, -2.0, 2.0, &mut rng);
    let probs: Vec<(&Matrix, &Matrix)> = vec![(&a, &b1), (&a, &b2)];

    let eng = tier_engine(AccuracyTier::GuaranteedFp64);
    let grouped_full = eng.gemm_grouped_tiered(&probs, AccuracyTier::GuaranteedFp64);
    let grouped_fast = eng.gemm_grouped_tiered(&probs, AccuracyTier::Fp64FaithfulFast);
    for (i, (pa, pb)) in probs.iter().enumerate() {
        let (c_full, _) = eng.gemm_tiered(pa, pb, AccuracyTier::GuaranteedFp64);
        assert_bitwise(&grouped_full[i].0, &c_full, &format!("guaranteed member {i}"));
        let (c_fast, _) = eng.gemm_tiered(pa, pb, AccuracyTier::Fp64FaithfulFast);
        assert_bitwise(&grouped_fast[i].0, &c_fast, &format!("fast member {i}"));
        // The tier lever is real: truncation changes bits (but stays
        // within the fast tier's documented bound)...
        let diffs = c_full
            .data
            .iter()
            .zip(&c_fast.data)
            .filter(|(x, y)| x.to_bits() != y.to_bits())
            .count();
        assert!(diffs > 0, "member {i}: fast tier should differ on generic inputs");
        assert!(max_rel(pa, pb, &c_fast) < 1e-6, "member {i} fast bound");
    }
}

#[test]
fn cold_cost_model_defers_to_fallback_and_warm_decisions_stabilize() {
    // The learned heuristic's contract, end to end through the engine:
    // while the table is cold decisions (and bits) are exactly the
    // fallback's, engine dispatches feed the table, and once warmed the
    // decision flips to the measured-cheapest family and stays there.
    let model = Arc::new(CostModel::in_memory());
    let eng = AdpEngine::new(
        AdpConfig::fp64()
            .with_cost_model(Arc::clone(&model))
            .with_heuristic(Box::new(LearnedHeuristic::new(
                Arc::clone(&model),
                Box::new(AlwaysEmulate),
            )))
            .with_tier(AccuracyTier::GuaranteedFp64),
    );
    let mut rng = Rng::new(904);
    let a = Matrix::uniform(32, 32, -1.0, 1.0, &mut rng);
    let b = Matrix::uniform(32, 32, -1.0, 1.0, &mut rng);
    let tier = AccuracyTier::GuaranteedFp64;
    let bucket = ShapeBucket::of(32, 32);

    // Cold: the fallback (AlwaysEmulate) decides, bitwise equal to a
    // plain-fallback engine.
    let (c_cold, out) = eng.gemm(&a, &b);
    assert!(out.decision.is_emulated(), "cold: {:?}", out.decision);
    let plain = tier_engine(tier);
    let (c_plain, _) = plain.gemm(&a, &b);
    assert_bitwise(&c_cold, &c_plain, "cold learned vs plain fallback");
    // The dispatch fed the table (slice pairs ran, so that arm observed).
    assert!(
        model.samples(bucket, EmulationChoice::SlicePair, tier) >= 1,
        "engine must feed the model"
    );

    // Warm both base arms with native far cheaper: the next decision is
    // the heuristic's native veto, and it stays stable across repeats
    // even while the engine keeps folding in real native timings.
    for _ in 0..MIN_SAMPLES {
        model.observe_ns_per_mac(bucket, EmulationChoice::Native, tier, 0.01);
        model.observe_ns_per_mac(bucket, EmulationChoice::SlicePair, tier, 1e6);
    }
    for trial in 0..4 {
        let (c, out) = eng.gemm(&a, &b);
        assert_eq!(
            out.decision,
            GemmDecision::FallbackHeuristic,
            "warm decision must be native (trial {trial})"
        );
        // Native dispatch: exactly the FP64 product, stable across trials.
        assert_bitwise(&c, &adp_dgemm::linalg::gemm(&a, &b), "native path (trial)");
    }
    assert!(
        model.samples(bucket, EmulationChoice::Native, tier) > MIN_SAMPLES,
        "warm dispatches keep observing the native arm"
    );
}
