//! Property suite for the tile-resident fused slice-pair engine: the
//! level-major serial pipeline is the retained oracle, and every other
//! schedule — fused serial, fused parallel (forced past the inline
//! cutoff), the grouped lockstep pipeline, and the ADP engine routing —
//! must reproduce it **bitwise** (`f64::to_bits`) across random shapes,
//! both slice encodings, and forced k-chunking. Also asserts the
//! workspace pool's zero-steady-state-allocation behavior end to end.

use std::sync::Arc;

use adp_dgemm::backend::{ComputeBackend, ParallelBackend, SerialBackend, WorkspacePool};
use adp_dgemm::coordinator::heuristic::AlwaysEmulate;
use adp_dgemm::linalg::Matrix;
use adp_dgemm::ozaki::{
    emulated_gemm_on, fused_gemm_on, gemm_grouped, tune, AccuracyTier, GroupedProblem,
    OzakiConfig, PairSchedule, SchemeKind, SliceCache, SliceEncoding, TileShape, FUSED_MC,
    FUSED_NC,
};
use adp_dgemm::util::{prop, Rng};
use adp_dgemm::{AdpConfig, AdpEngine};

fn assert_bitwise(c1: &Matrix, c2: &Matrix, what: &str) -> prop::PropResult {
    if (c1.rows, c1.cols) != (c2.rows, c2.cols) {
        return Err(format!("{what}: shape mismatch"));
    }
    for (x, y) in c1.data.iter().zip(&c2.data) {
        if x.to_bits() != y.to_bits() {
            return Err(format!("{what}: not bitwise identical ({x} vs {y})"));
        }
    }
    Ok(())
}

#[test]
fn prop_fused_engine_bitwise_identical_to_level_major_oracle() {
    // The tentpole acceptance property: random shapes (biased to straddle
    // the FUSED_MC/FUSED_NC tile boundaries), random slice counts, both
    // encodings, optional forced k-chunking — fused serial and fused
    // parallel must match the level-major serial oracle bit for bit.
    let par = ParallelBackend::new(4).with_cutoff_ops(0);
    let pool = WorkspacePool::new();
    prop::check("fused == level-major (bitwise)", 12, |rng| {
        let m = (if rng.f64() < 0.5 { rng.int(1, 24) } else { rng.int(60, 80) }) as usize;
        let n = (if rng.f64() < 0.5 { rng.int(1, 24) } else { rng.int(60, 80) }) as usize;
        let k = rng.int(1, 40) as usize;
        let s = rng.int(2, 8) as usize;
        let enc =
            if rng.f64() < 0.5 { SliceEncoding::Unsigned } else { SliceEncoding::Signed };
        let mut cfg = OzakiConfig::with_encoding(s, enc);
        if rng.f64() < 0.3 {
            // forced k-chunking: both drivers must chunk identically
            cfg = cfg.with_k_chunk(rng.int(1, k as i64).max(1) as usize);
        }
        let a = Matrix::uniform(m, k, -3.0, 3.0, rng);
        let b = Matrix::uniform(k, n, -3.0, 3.0, rng);
        let oracle = emulated_gemm_on(&a, &b, &cfg, &SerialBackend);
        let fused_ser = fused_gemm_on(&a, &b, &cfg, &SerialBackend, &pool);
        assert_bitwise(&oracle, &fused_ser, &format!("fused serial ({m},{k},{n}) s={s}"))?;
        let fused_par = fused_gemm_on(&a, &b, &cfg, &par, &pool);
        assert_bitwise(&oracle, &fused_par, &format!("fused parallel ({m},{k},{n}) s={s}"))
    });
    assert!(pool.stats().fused_tiles > 0, "the fused schedule must actually have run");
}

#[test]
fn fused_parallel_covers_multi_band_shapes() {
    // Deterministic shapes straddling the tile boundaries — including a
    // wide, flat output (m < FUSED_MC) whose parallel schedule must
    // shrink its band height to fan out — with cutoff forced to zero so
    // even these sizes run the work-stealing band queue.
    let par = ParallelBackend::new(3).with_cutoff_ops(0);
    let par_pool = WorkspacePool::new();
    let ser_pool = WorkspacePool::new();
    let mut rng = Rng::new(4100);
    // The tile-count accounting below assumes the FUSED_MC x FUSED_NC
    // grid, so pin the baseline geometry for the duration (the autotuner
    // may otherwise pick a different — bitwise identical — shape).
    tune::force_shape(Some(TileShape::BASELINE));
    let shapes = [
        (FUSED_MC + 1, 17, FUSED_NC - 1),
        (3 * FUSED_MC - 5, 8, FUSED_NC + 3),
        (16, 11, 2 * FUSED_NC + 9),
        (40, 9, 3 * FUSED_NC), // wide flat: band height < FUSED_MC
    ];
    for (m, k, n) in shapes {
        let a = Matrix::uniform(m, k, -2.0, 2.0, &mut rng);
        let b = Matrix::uniform(k, n, -2.0, 2.0, &mut rng);
        let cfg = OzakiConfig::new(6);
        let oracle = emulated_gemm_on(&a, &b, &cfg, &SerialBackend);
        let fused_ser = fused_gemm_on(&a, &b, &cfg, &SerialBackend, &ser_pool);
        assert_bitwise(&oracle, &fused_ser, &format!("serial multi-band ({m},{k},{n})")).unwrap();
        let fused_par = fused_gemm_on(&a, &b, &cfg, &par, &par_pool);
        assert_bitwise(&oracle, &fused_par, &format!("parallel multi-band ({m},{k},{n})")).unwrap();
    }
    // The serial engine's tile accounting is deterministic: the
    // FUSED_MC x FUSED_NC grid. The parallel engine may split shorter
    // bands (more, smaller tiles) but never fewer.
    let expect_tiles: u64 = shapes
        .iter()
        .map(|&(m, _, n)| (m.div_ceil(FUSED_MC) * n.div_ceil(FUSED_NC)) as u64)
        .sum();
    assert_eq!(ser_pool.stats().fused_tiles, expect_tiles, "serial tile grid accounting");
    assert!(
        par_pool.stats().fused_tiles >= expect_tiles,
        "parallel bands cover at least the serial grid"
    );
    tune::force_shape(None);
}

#[test]
fn prop_grouped_pipeline_matches_fused_oracle() {
    // gemm_grouped (the lockstep cross-problem schedule, pooled
    // workspaces, shared slice cache) against both the level-major and
    // fused per-request paths — everything must agree bitwise.
    let par = ParallelBackend::new(4).with_cutoff_ops(0);
    let cache = SliceCache::new(16);
    let pool = WorkspacePool::new();
    prop::check("grouped == fused == level-major", 8, |rng| {
        let nprobs = rng.int(1, 4) as usize;
        let k = rng.int(1, 24) as usize;
        let mut mats: Vec<(Matrix, Matrix, OzakiConfig)> = Vec::new();
        for _ in 0..nprobs {
            let m = rng.int(1, 70) as usize;
            let n = rng.int(1, 70) as usize;
            let enc =
                if rng.f64() < 0.5 { SliceEncoding::Unsigned } else { SliceEncoding::Signed };
            let cfg = OzakiConfig::with_encoding(rng.int(2, 7) as usize, enc);
            mats.push((
                Matrix::uniform(m, k, -3.0, 3.0, rng),
                Matrix::uniform(k, n, -3.0, 3.0, rng),
                cfg,
            ));
        }
        let probs: Vec<GroupedProblem<'_>> = mats
            .iter()
            .map(|(a, b, cfg)| GroupedProblem { a, b, cfg: *cfg, scheme: SchemeKind::SlicePair })
            .collect();
        // The oracle is backend-independent: compute it once per problem.
        let oracles: Vec<Matrix> =
            mats.iter().map(|(a, b, cfg)| emulated_gemm_on(a, b, cfg, &SerialBackend)).collect();
        for backend in [&SerialBackend as &dyn ComputeBackend, &par] {
            let (cs, _) = gemm_grouped(&probs, &cache, backend, &pool);
            for (((a, b, cfg), oracle), c) in mats.iter().zip(&oracles).zip(&cs) {
                assert_bitwise(c, oracle, &format!("grouped vs oracle on {}", backend.name()))?;
                let fused = fused_gemm_on(a, b, cfg, backend, &pool);
                assert_bitwise(c, &fused, &format!("grouped vs fused on {}", backend.name()))?;
            }
        }
        Ok(())
    });
}

#[test]
fn adp_engine_routes_through_fused_and_reuses_workspaces() {
    // The engine-level acceptance criterion: AdpEngine serves emulated
    // requests through the fused path (fused tiles appear in metrics),
    // results equal the level-major oracle bitwise, and repeat shapes
    // stop allocating scratch once the pool is warm.
    let pool = Arc::new(WorkspacePool::new());
    // Guaranteed tier pinned: the oracle below runs the full (untruncated)
    // schedule, so the engine must too, whatever ADP_TIER says.
    let eng = AdpEngine::new(
        AdpConfig::fp64()
            .with_heuristic(Box::new(AlwaysEmulate))
            .with_workspace_pool(pool.clone())
            .with_tier(AccuracyTier::GuaranteedFp64),
    );
    let mut rng = Rng::new(4200);
    let a = Matrix::uniform(40, 40, -1.0, 1.0, &mut rng);
    let b = Matrix::uniform(40, 40, -1.0, 1.0, &mut rng);
    let (c, out) = eng.gemm(&a, &b);
    assert!(out.decision.is_emulated(), "{:?}", out.decision);
    let cfg = OzakiConfig::new(out.decision.slices().unwrap());
    let oracle = emulated_gemm_on(&a, &b, &cfg, &SerialBackend);
    assert_bitwise(&c, &oracle, "engine vs level-major oracle").unwrap();
    let warm = eng.metrics.snapshot();
    assert!(warm.fused_tiles >= 1, "engine must route through the fused engine: {warm:?}");
    assert!(warm.workspace_checkouts >= 1);
    let fresh_warm = warm.workspace_fresh;
    for _ in 0..5 {
        let a = Matrix::uniform(40, 40, -1.0, 1.0, &mut rng);
        let b = Matrix::uniform(40, 40, -1.0, 1.0, &mut rng);
        let (_, out) = eng.gemm(&a, &b);
        assert!(out.decision.is_emulated());
    }
    let after = eng.metrics.snapshot();
    assert!(after.workspace_checkouts > warm.workspace_checkouts);
    assert!(after.fused_tiles > warm.fused_tiles);
    assert_eq!(
        after.workspace_fresh, fresh_warm,
        "repeat shapes on a warm pool must not allocate fresh workspaces"
    );
}

#[test]
fn shared_schedule_is_one_arc_per_config() {
    // The hoisted pair schedule: repeated GEMMs of one config share one
    // precomputed schedule instead of rebuilding per-level pair vectors.
    let s1 = PairSchedule::get(7, 8);
    let s2 = PairSchedule::for_config(&OzakiConfig::new(7));
    assert!(Arc::ptr_eq(&s1, &s2));
    assert_eq!(s1.pair_count(), 28);
    // Levels cover the triangular pair set exactly once, smallest weight
    // first.
    let mut total = 0;
    let mut last_w = i32::MIN;
    for (pairs, w) in s1.levels() {
        assert!(w > last_w, "weights must ascend");
        last_w = w;
        for &(t, u) in pairs {
            assert!(t + u <= 6, "Ozaki-I truncation: t+u <= s-1");
        }
        total += pairs.len();
    }
    assert_eq!(total, 28);
}
