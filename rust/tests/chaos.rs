//! Chaos suite: deterministic fault injection against the live service.
//!
//! Every scenario arms one (or a seeded mix) of the named
//! `util::faultinject` sites, drives real traffic through a supervised
//! [`GemmService`], and asserts the four self-healing invariants:
//!
//! 1. **No submitter panics or hangs** — every wait is bounded by a
//!    watchdog; a deadlock or dropped wakeup fails fast.
//! 2. **Exactly one reply per request** — each receiver yields one
//!    result (a response or a *typed* [`GemmError`]) and never a second.
//! 3. **Completed results are bitwise identical to a fault-free run** —
//!    faults may fail requests, they may not corrupt survivors.
//! 4. **Throughput recovers once the fault clears** — after `disarm`,
//!    fresh traffic completes normally (respawned workers, recovered
//!    locks, quarantined artifacts notwithstanding).
//!
//! The fault table is process-global, so scenarios serialize on [`pin`]
//! and disarm through a drop guard even when an assertion panics.
//! `ADP_FAULTS_SEED` (the CI chaos matrix knob) seeds the probabilistic
//! storm scenario; the deterministic scenarios are seed-independent.
//! The recovery-latency drill writes `BENCH_chaos.json` for CI to
//! archive next to the perf artifacts.

use std::sync::mpsc::Receiver;
use std::sync::{Mutex, MutexGuard};
use std::time::{Duration, Instant};

use adp_dgemm::coordinator::heuristic::AlwaysEmulate;
use adp_dgemm::coordinator::{GemmError, GemmResult, GemmService, Priority, ServiceConfig};
use adp_dgemm::linalg::Matrix;
use adp_dgemm::util::benchkit::{JsonReport, Stats};
use adp_dgemm::util::faultinject;
use adp_dgemm::util::Rng;

/// Serializes scenarios: arming is process-global state.
fn pin() -> MutexGuard<'static, ()> {
    static PIN: Mutex<()> = Mutex::new(());
    // A scenario that failed its assertions must not wedge the rest of
    // the suite: recover the guard instead of unwrapping the poison.
    PIN.lock().unwrap_or_else(|e| e.into_inner())
}

/// Disarms on drop, so a panicking assertion can't leak an armed fault
/// into the next scenario.
struct Disarm;

impl Drop for Disarm {
    fn drop(&mut self) {
        faultinject::disarm();
    }
}

/// Run `f` on a helper thread and fail if it does not finish in `limit`
/// (invariant 1: no submitter may hang).
fn with_watchdog(limit: Duration, f: impl FnOnce() + Send + 'static) {
    let body = std::thread::spawn(f);
    let deadline = Instant::now() + limit;
    while !body.is_finished() {
        assert!(Instant::now() < deadline, "chaos scenario exceeded the {limit:?} watchdog");
        std::thread::sleep(Duration::from_millis(20));
    }
    if let Err(e) = body.join() {
        std::panic::resume_unwind(e);
    }
}

/// Service shaped for chaos drills: fast supervisor sweeps so respawns
/// land within test time, artifacts off (pure in-process pipeline). The
/// accuracy tier stays at the config default so the suite exercises
/// whatever `ADP_TIER` the CI matrix leg exports — bitwise comparisons
/// hold because baseline and faulted runs share the environment.
fn chaos_cfg(workers: usize) -> ServiceConfig {
    ServiceConfig {
        workers,
        use_artifacts: false,
        supervisor_poll: Duration::from_millis(2),
        hang_threshold: Duration::from_millis(60),
        ..Default::default()
    }
}

fn start(cfg: ServiceConfig) -> GemmService {
    GemmService::start(cfg, None, || Box::new(AlwaysEmulate))
}

/// Deterministic mixed-shape workload (clean inputs: every request takes
/// the emulated path, so kernel/workspace fault sites are reached).
fn workload(seed: u64, n_reqs: usize) -> Vec<(Matrix, Matrix)> {
    let mut rng = Rng::new(seed);
    (0..n_reqs)
        .map(|i| {
            let n = 6 + (i % 4) * 2;
            (Matrix::uniform(n, n, -1.0, 1.0, &mut rng), Matrix::uniform(n, n, -1.0, 1.0, &mut rng))
        })
        .collect()
}

/// Reference results from a fault-free service (invariant 3's oracle).
/// Bitwise identity across worker counts / coalescing / sharding is
/// pinned by the service unit tests, so one baseline serves any config.
fn fault_free_baseline(pairs: &[(Matrix, Matrix)]) -> Vec<Matrix> {
    faultinject::disarm();
    let svc = start(chaos_cfg(2));
    let out = pairs
        .iter()
        .map(|(a, b)| svc.gemm_blocking(a.clone(), b.clone()).expect("fault-free run serves").c)
        .collect();
    svc.shutdown();
    out
}

/// Wait until the supervisor has counted `n` respawns. The supervisor
/// sweep runs every couple of milliseconds, so the counter can lag the
/// replies; the surrounding watchdog bounds this loop.
fn await_respawns(svc: &GemmService, n: u64) {
    while svc.metrics.snapshot().worker_respawns < n {
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// Invariant 2: exactly one reply, delivered within the watchdog.
fn recv_one(rx: &Receiver<GemmResult>, limit: Duration) -> GemmResult {
    let r = rx.recv_timeout(limit).expect("a reply must arrive (no silent loss, no hang)");
    assert!(rx.try_recv().is_err(), "a request must never receive a second reply");
    r
}

fn assert_bitwise(got: &Matrix, want: &Matrix) {
    assert_eq!(got.rows, want.rows);
    assert_eq!(got.cols, want.cols);
    for (x, y) in got.data.iter().zip(&want.data) {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "completed result diverged from the fault-free run"
        );
    }
}

/// Invariant 4: with faults disarmed, fresh traffic completes and
/// matches the fault-free oracle.
fn assert_recovers(svc: &GemmService, seed: u64) {
    faultinject::disarm();
    let fresh = workload(seed, 4);
    let oracle = fault_free_baseline(&fresh);
    for ((a, b), want) in fresh.into_iter().zip(&oracle) {
        let resp = svc.gemm_blocking(a, b).expect("service must serve after the fault clears");
        assert_bitwise(&resp.c, want);
    }
    assert_eq!(svc.inflight(), 0, "recovered service must not leak inflight counts");
}

const REPLY_WAIT: Duration = Duration::from_secs(30);

#[test]
fn worker_panic_storm_respawns_and_survivors_stay_bitwise() {
    with_watchdog(Duration::from_secs(120), || {
        let _p = pin();
        let _d = Disarm;
        let pairs = workload(0xC4A05_1, 12);
        let oracle = fault_free_baseline(&pairs);
        // Every 4th dequeue kills its worker outside the engine
        // catch_unwind — the hard death the supervisor exists for.
        faultinject::arm("worker.exec.panic=every:4").unwrap();
        let svc = start(chaos_cfg(2));
        let rxs: Vec<_> = pairs
            .iter()
            .map(|(a, b)| svc.submit(a.clone(), b.clone()).expect("queues are roomy"))
            .collect();
        let mut lost = 0usize;
        for (i, rx) in rxs.iter().enumerate() {
            match recv_one(rx, REPLY_WAIT) {
                Ok(resp) => assert_bitwise(&resp.c, &oracle[i]),
                Err(GemmError::ReplyLost) => lost += 1,
                Err(other) => panic!("unexpected error under worker panic: {other}"),
            }
        }
        // 12 dequeues, every:4 => exactly the 4th, 8th and 12th die.
        assert_eq!(lost, 3, "each worker death loses exactly its in-hand request");
        await_respawns(&svc, 3); // every death is detected and respawned
        assert_recovers(&svc, 0xC4A05_2);
        svc.shutdown();
    });
}

#[test]
fn hung_worker_is_superseded_and_every_reply_still_arrives() {
    with_watchdog(Duration::from_secs(120), || {
        let _p = pin();
        let _d = Disarm;
        let pairs = workload(0xC4A05_3, 4);
        let oracle = fault_free_baseline(&pairs);
        // First dequeue stalls 400ms against a 60ms hang threshold: the
        // supervisor must supersede, and the recovered worker must still
        // deliver its (valid) reply instead of double-draining.
        faultinject::arm("worker.hang=nth:1@400").unwrap();
        let svc = start(chaos_cfg(1));
        let rxs: Vec<_> = pairs
            .iter()
            .map(|(a, b)| svc.submit(a.clone(), b.clone()).expect("queues are roomy"))
            .collect();
        for (i, rx) in rxs.iter().enumerate() {
            let resp = recv_one(rx, REPLY_WAIT).expect("a hang delays, it must not fail");
            assert_bitwise(&resp.c, &oracle[i]);
        }
        await_respawns(&svc, 1); // the hang was detected and superseded
        assert_recovers(&svc, 0xC4A05_4);
        svc.shutdown();
    });
}

#[test]
fn dropped_reply_surfaces_as_reply_lost_never_silence() {
    with_watchdog(Duration::from_secs(60), || {
        let _p = pin();
        let _d = Disarm;
        let pairs = workload(0xC4A05_5, 5);
        let oracle = fault_free_baseline(&pairs);
        // The 2nd delivered reply is dropped before it reaches the
        // channel; the ReplySlot drop guard must convert the loss into a
        // typed error — a submitter may fail, it may never wait forever.
        faultinject::arm("reply.drop=nth:2").unwrap();
        let svc = start(chaos_cfg(1)); // single worker: FIFO reply order
        let rxs: Vec<_> = pairs
            .iter()
            .map(|(a, b)| svc.submit(a.clone(), b.clone()).expect("queues are roomy"))
            .collect();
        for (i, rx) in rxs.iter().enumerate() {
            match recv_one(rx, REPLY_WAIT) {
                Ok(resp) => assert_bitwise(&resp.c, &oracle[i]),
                Err(GemmError::ReplyLost) => {
                    assert_eq!(i, 1, "exactly the 2nd reply was armed to drop")
                }
                Err(other) => panic!("unexpected error under reply drop: {other}"),
            }
        }
        assert_recovers(&svc, 0xC4A05_6);
        svc.shutdown();
    });
}

#[test]
fn engine_faults_are_typed_errors_and_never_kill_workers() {
    with_watchdog(Duration::from_secs(60), || {
        let _p = pin();
        let _d = Disarm;
        let pairs = workload(0xC4A05_7, 9);
        let oracle = fault_free_baseline(&pairs);
        // Kernel-dispatch panics happen inside the engine catch_unwind:
        // the submitter gets EnginePanic, the worker never dies. A roomy
        // hang threshold keeps the `worker_respawns == 0` assertion
        // immune to scheduler stalls on loaded CI machines.
        faultinject::arm("kernel.dispatch.panic=every:3").unwrap();
        let mut cfg = chaos_cfg(2);
        cfg.hang_threshold = Duration::from_secs(30);
        let svc = start(cfg);
        let rxs: Vec<_> = pairs
            .iter()
            .map(|(a, b)| svc.submit(a.clone(), b.clone()).expect("queues are roomy"))
            .collect();
        let mut panicked = 0usize;
        for (i, rx) in rxs.iter().enumerate() {
            match recv_one(rx, REPLY_WAIT) {
                Ok(resp) => assert_bitwise(&resp.c, &oracle[i]),
                Err(GemmError::EnginePanic(msg)) => {
                    assert!(msg.contains("injected fault"), "payload preserved: {msg}");
                    panicked += 1;
                }
                Err(other) => panic!("unexpected error under dispatch panic: {other}"),
            }
        }
        assert_eq!(panicked, 3, "9 dispatches, every:3 => exactly 3 typed failures");
        assert_eq!(
            svc.metrics.snapshot().worker_respawns,
            0,
            "caught engine panics must not trip the supervisor"
        );
        // Same contract one layer down: a workspace-checkout panic is
        // also caught by the engine and typed, not a worker death.
        faultinject::arm("workspace.checkout.panic=nth:1").unwrap();
        let (a, b) = (Matrix::identity(8), Matrix::identity(8));
        assert!(matches!(
            svc.gemm_blocking(a.clone(), b.clone()),
            Err(GemmError::EnginePanic(_))
        ));
        assert!(svc.gemm_blocking(a, b).is_ok(), "the very next request is served");
        assert_recovers(&svc, 0xC4A05_8);
        svc.shutdown();
    });
}

#[test]
fn coalescing_drain_panic_loses_the_batch_not_the_service() {
    with_watchdog(Duration::from_secs(120), || {
        let _p = pin();
        let _d = Disarm;
        let mut cfg = chaos_cfg(1);
        cfg.coalesce = true;
        cfg.coalesce_window = Duration::from_millis(20);
        let pairs = workload(0xC4A05_9, 3);
        let oracle = fault_free_baseline(&pairs);
        // The first coalescing drain panics while the worker holds the
        // batch: its replies surface as ReplyLost through the drop
        // guards, the shard lock un-poisons via psync, the supervisor
        // respawns, and requests that missed the doomed batch complete.
        faultinject::arm("drain.coalesce.panic=nth:1").unwrap();
        let svc = start(cfg);
        let rxs: Vec<_> = pairs
            .iter()
            .map(|(a, b)| svc.submit(a.clone(), b.clone()).expect("queues are roomy"))
            .collect();
        let mut lost = 0usize;
        for (i, rx) in rxs.iter().enumerate() {
            match recv_one(rx, REPLY_WAIT) {
                Ok(resp) => assert_bitwise(&resp.c, &oracle[i]),
                Err(GemmError::ReplyLost) => lost += 1,
                Err(other) => panic!("unexpected error under drain panic: {other}"),
            }
        }
        assert!(lost >= 1, "the drained batch dies with its worker");
        await_respawns(&svc, 1);
        assert_recovers(&svc, 0xC4A05_A);
        svc.shutdown();
    });
}

#[test]
fn poisoned_metrics_lock_recovers_and_accounting_continues() {
    with_watchdog(Duration::from_secs(60), || {
        let _p = pin();
        let _d = Disarm;
        // The first outcome recording panics *while holding* the shared
        // metrics mutex. std's lock().unwrap() would now kill every
        // later metrics call — psync recovery must keep the service (and
        // its snapshot endpoint) alive.
        faultinject::arm("worker.lock.panic=nth:1").unwrap();
        let svc = start(chaos_cfg(1));
        let (a, b) = (Matrix::identity(8), Matrix::identity(8));
        match svc.gemm_blocking(a.clone(), b.clone()) {
            Err(GemmError::EnginePanic(msg)) => {
                assert!(msg.contains("metrics lock"), "payload preserved: {msg}")
            }
            other => panic!("expected a typed engine panic, got ok={}", other.is_ok()),
        }
        // The poisoned mutex is observable, recovered, and counted.
        let resp = svc.gemm_blocking(a, b).expect("served across the poisoned lock");
        assert_eq!(resp.c.at(0, 0), 1.0);
        let snap = svc.metrics.snapshot();
        assert!(snap.lock_recoveries >= 1, "poison recovery must be counted: {snap:?}");
        assert!(snap.requests >= 1, "accounting continues after the poison");
        assert_recovers(&svc, 0xC4A05_B);
        svc.shutdown();
    });
}

#[test]
fn corrupt_cost_model_is_quarantined_and_the_run_continues() {
    with_watchdog(Duration::from_secs(60), || {
        let _p = pin();
        let _d = Disarm;
        let dir = std::env::temp_dir();
        let path = dir.join(format!("adp-chaos-costmodel-{}.tsv", std::process::id()));
        let quarantined = path.with_extension("tsv.corrupt");
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(&quarantined);
        std::fs::write(&path, "# adp-dgemm cost-model catalog v1\n").expect("seed catalog");
        std::env::set_var("ADP_COSTMODEL", &path);
        // Load-time corruption: the catalog must be renamed aside — not
        // deleted (evidence), not left in place (next save collides) —
        // and the service must come up cold and healthy.
        faultinject::arm("costmodel.load.corrupt=always").unwrap();
        let svc = start(chaos_cfg(1));
        faultinject::disarm();
        assert!(!path.exists(), "corrupt catalog must be moved out of the load path");
        assert!(quarantined.exists(), "corrupt catalog must be preserved as .corrupt");
        assert!(svc.gemm_blocking(Matrix::identity(8), Matrix::identity(8)).is_ok());
        assert!(
            svc.metrics.snapshot().artifacts_quarantined >= 1,
            "quarantine must be visible in the service metrics"
        );
        // Orderly shutdown flushes the (now warm) model back to the
        // clean path — the quarantine freed it for exactly this.
        svc.shutdown();
        assert!(path.exists(), "shutdown must flush the learned model to the clean path");
        let text = std::fs::read_to_string(&path).expect("flushed catalog");
        assert!(
            text.starts_with("# adp-dgemm cost-model catalog v1"),
            "flushed catalog is well-formed"
        );
        std::env::remove_var("ADP_COSTMODEL");
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(&quarantined);
    });
}

#[test]
fn probabilistic_fault_storm_holds_all_invariants() {
    with_watchdog(Duration::from_secs(240), || {
        let _p = pin();
        let _d = Disarm;
        // The CI chaos matrix varies ADP_FAULTS_SEED: same invariants,
        // different deterministic fault interleavings per leg.
        let seed = std::env::var("ADP_FAULTS_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xC0FFEE);
        let pairs = workload(0xC4A05_C, 24);
        let oracle = fault_free_baseline(&pairs);
        faultinject::arm_seeded(
            "worker.exec.panic=prob:0.05,reply.drop=prob:0.05,kernel.dispatch.panic=prob:0.1",
            seed,
        )
        .unwrap();
        let svc = start(chaos_cfg(2));
        // Mixed scheduling tiers: most requests ride the Normal single
        // path, every 4th travels inside one Batch-tier group (grouped
        // dequeue, grouped replies — the storm must hold there too).
        let mut rxs: Vec<(usize, Receiver<GemmResult>)> = Vec::new();
        let mut group = Vec::new();
        for (i, (a, b)) in pairs.iter().enumerate() {
            if i % 4 == 3 {
                group.push((i, a.clone(), b.clone()));
            } else {
                rxs.push((i, svc.submit(a.clone(), b.clone()).expect("queues are roomy")));
            }
        }
        let batch_rxs = svc
            .submit_batch(group.iter().map(|(_, a, b)| (a.clone(), b.clone())).collect())
            .expect("queues are roomy");
        rxs.extend(group.iter().map(|(i, _, _)| *i).zip(batch_rxs));
        let mut completed = 0usize;
        for (i, rx) in &rxs {
            match recv_one(rx, REPLY_WAIT) {
                Ok(resp) => {
                    assert_bitwise(&resp.c, &oracle[*i]);
                    completed += 1;
                }
                Err(GemmError::ReplyLost) | Err(GemmError::EnginePanic(_)) => {}
                Err(other) => panic!("untyped failure escaped the storm: {other}"),
            }
        }
        assert!(completed >= 1, "a 5-10% fault storm must not fail everything");
        assert_recovers(&svc, 0xC4A05_D);
        svc.shutdown();
    });
}

#[test]
fn async_stragglers_of_a_dead_worker_resolve_to_reply_lost() {
    with_watchdog(Duration::from_secs(120), || {
        let _p = pin();
        let _d = Disarm;
        // Every dequeue kills the (sole) worker: each queued request is
        // served by a fresh respawn that dies on it in turn, so every
        // async completion style must resolve to the typed loss — a
        // ticket holder or callback waiter may never hang on a corpse.
        faultinject::arm("worker.exec.panic=always").unwrap();
        let svc = start(chaos_cfg(1));
        let (a, b) = (Matrix::identity(8), Matrix::identity(8));
        let t_wait =
            svc.submit_async(a.clone(), b.clone(), Priority::High).expect("admitted");
        let mut t_timeout =
            svc.submit_async(a.clone(), b.clone(), Priority::Normal).expect("admitted");
        let mut t_poll =
            svc.submit_async(a.clone(), b.clone(), Priority::Normal).expect("admitted");
        let (cb_tx, cb_rx) = std::sync::mpsc::channel();
        svc.submit_callback(a.clone(), b.clone(), Priority::Batch, move |r| {
            cb_tx.send(r).unwrap()
        })
        .expect("admitted");
        assert_eq!(t_wait.wait().err(), Some(GemmError::ReplyLost));
        loop {
            if let Some(r) = t_timeout.wait_timeout(Duration::from_millis(5)) {
                assert_eq!(r.err(), Some(GemmError::ReplyLost));
                break;
            }
        }
        loop {
            if let Some(r) = t_poll.poll() {
                assert_eq!(r.err(), Some(GemmError::ReplyLost));
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(
            cb_rx.recv_timeout(REPLY_WAIT).expect("callback invoked exactly once").err(),
            Some(GemmError::ReplyLost)
        );
        assert_eq!(svc.inflight(), 0, "dead workers must not leak inflight counts");
        assert_recovers(&svc, 0xC4A05_E);
        svc.shutdown();
    });
}

#[test]
fn bench_artifact_records_recovery_latency() {
    with_watchdog(Duration::from_secs(120), || {
        let _p = pin();
        let _d = Disarm;
        // Fault-free round trip: the baseline arm.
        faultinject::disarm();
        let svc = start(chaos_cfg(2));
        let (a, b) = (Matrix::identity(8), Matrix::identity(8));
        let mut clean = Vec::new();
        for _ in 0..5 {
            let t0 = Instant::now();
            svc.gemm_blocking(a.clone(), b.clone()).expect("served");
            clean.push(t0.elapsed().as_secs_f64());
        }
        // Respawn recovery: kill a worker, measure death-to-next-success.
        let mut recover = Vec::new();
        for _ in 0..3 {
            faultinject::arm("worker.exec.panic=nth:1").unwrap();
            let rx = svc.submit(a.clone(), b.clone()).expect("queues are roomy");
            assert_eq!(recv_one(&rx, REPLY_WAIT).err(), Some(GemmError::ReplyLost));
            let t0 = Instant::now();
            faultinject::disarm();
            svc.gemm_blocking(a.clone(), b.clone()).expect("served after respawn");
            recover.push(t0.elapsed().as_secs_f64());
        }
        await_respawns(&svc, 3);
        svc.shutdown();
        let stats = |mut t: Vec<f64>| {
            t.sort_by(|x, y| x.partial_cmp(y).unwrap());
            Stats {
                iters: t.len(),
                min_s: t[0],
                median_s: t[t.len() / 2],
                mean_s: t.iter().sum::<f64>() / t.len() as f64,
            }
        };
        let mut report = JsonReport::new();
        report.arm("fault_free_roundtrip", stats(clean), 1.0, &[]);
        report.arm("worker_respawn_recovery", stats(recover), 1.0, &[]);
        report
            .write("BENCH_chaos.json", "chaos", &[("workers", "2".to_string())])
            .expect("write BENCH_chaos.json");
    });
}
