//! Property/stress suite hardening the grouped batched GEMM pipeline:
//!
//! * ESC conservativeness on the grading-generator regimes (Test 1/2/3 of
//!   Demmel et al. §6): the coarse ESC — and hence the coarse slice
//!   count — never falls below the exact one, and ESC-sized emulation
//!   holds the FP64 grading tolerance on every regime.
//! * Service concurrency stress: many threads racing `submit` /
//!   `submit_batch` against `shutdown`, with a watchdog enforcing a
//!   bounded-time join — no lost replies, no leaked inflight counts, no
//!   deadlock.
//! * End-to-end bitwise identity of the coalesced service against the
//!   per-request engine.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use adp_dgemm::coordinator::heuristic::AlwaysEmulate;
use adp_dgemm::coordinator::{GemmService, ServiceConfig, SubmitError};
use adp_dgemm::esc::{coarse_esc_gemm, exact_esc_gemm};
use adp_dgemm::grading::generators::{test2_workload, tiny_corner_pair, uniform_pair};
use adp_dgemm::grading::grade::{measure, passes_grade_a};
use adp_dgemm::linalg::Matrix;
use adp_dgemm::ozaki::{emulated_gemm, OzakiConfig, SliceEncoding};
use adp_dgemm::util::Rng;

// ---------------------------------------------------------------------
// ESC conservativeness on grading-generator regimes (satellite: property)
// ---------------------------------------------------------------------

/// Shared regime check: coarse ESC >= exact ESC at every coarsening, the
/// induced slice counts are ordered the same way for both encodings, and
/// emulation sized from the deployment-default coarse ESC stays within
/// the FP64 grading tolerance (Grade A, componentwise).
fn check_esc_regime(a: &Matrix, b: &Matrix, what: &str) {
    let exact = exact_esc_gemm(a, b);
    for block in [1usize, 8, 64] {
        let coarse = coarse_esc_gemm(a, b, block);
        assert!(coarse >= exact, "{what} block={block}: coarse {coarse} < exact {exact}");
        for enc in [SliceEncoding::Unsigned, SliceEncoding::Signed] {
            let s_coarse = enc.slices_for_bits(53 + coarse + 1);
            let s_exact = enc.slices_for_bits(53 + exact + 1);
            assert!(
                s_coarse >= s_exact,
                "{what} block={block} {enc:?}: slices {s_coarse} < {s_exact}"
            );
        }
    }
    let esc = coarse_esc_gemm(a, b, 64);
    let cfg = OzakiConfig::for_bits(53 + esc + 1, SliceEncoding::Unsigned);
    let c = emulated_gemm(a, b, &cfg);
    let rep = measure(a, b, &c);
    // f(n) budget anchored at the inner dimension (the error unit of the
    // (k+4)*eps componentwise bound).
    assert!(
        passes_grade_a(&rep, a.cols.max(4), 4.0),
        "{what}: esc-sized emulation broke the grading tolerance: {rep:?} (esc {esc}, s {})",
        cfg.slices
    );
}

#[test]
fn esc_conservative_on_test1_regime() {
    // Test 1's magnitude staircase: a tiny leading row of A / column of B.
    let mut rng = Rng::new(801);
    for delta_exp in [-10i32, -30, -50] {
        let (a, b) = tiny_corner_pair(12, 2f64.powi(delta_exp), &mut rng);
        check_esc_regime(&a, &b, &format!("test1 delta=2^{delta_exp}"));
    }
}

#[test]
fn esc_conservative_on_test2_regime() {
    // Test 2's cyclic-shift diagonal scaling (the Fig 2 workload).
    let mut rng = Rng::new(802);
    for span_b in [4i32, 10, 20] {
        let w = test2_workload(16, span_b, &mut rng);
        check_esc_regime(&w.a, &w.b, &format!("test2 b={span_b}"));
    }
}

#[test]
fn esc_conservative_on_test3_regime() {
    // Test 3 reuses the Test 2 construction at escalating spans (judged
    // norm-wise there; here we still demand the componentwise guarantee
    // from ESC-sized emulation).
    let mut rng = Rng::new(803);
    for span_b in [8i32, 24] {
        let w = test2_workload(12, span_b, &mut rng);
        check_esc_regime(&w.a, &w.b, &format!("test3 b={span_b}"));
    }
    // and the uniform baseline regime
    let (a, b) = uniform_pair(16, -1.0, 1.0, &mut rng);
    check_esc_regime(&a, &b, "uniform");
}

// ---------------------------------------------------------------------
// Service concurrency stress (satellite: stress)
// ---------------------------------------------------------------------

/// The actual stress body; run under a watchdog by the #[test] wrappers.
/// Submitter threads race `submit`/`submit_batch` against a concurrent
/// `shutdown`. Invariants: every accepted request (Ok receiver) gets
/// exactly one reply, rejected submissions only ever see
/// `ServiceStopped`, and the inflight gauge drains to zero.
fn stress_body(coalesce: bool, seed: u64) {
    let cfg = ServiceConfig {
        workers: 3,
        queue_depth: 8, // small: exercises blocking-submit backpressure
        use_artifacts: false,
        coalesce,
        coalesce_window: Duration::from_micros(500),
        max_batch: 4,
        ..Default::default()
    };
    let svc = Arc::new(GemmService::start(cfg, None, || Box::new(AlwaysEmulate)));
    let accepted = Arc::new(AtomicU64::new(0));
    let replied = Arc::new(AtomicU64::new(0));
    let mut submitters = Vec::new();
    for t in 0..6u64 {
        let svc = svc.clone();
        let accepted = accepted.clone();
        let replied = replied.clone();
        submitters.push(std::thread::spawn(move || {
            let mut rng = Rng::new(seed ^ (t + 1));
            for i in 0..30usize {
                let n = 4 + i % 5;
                let a = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
                let b = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
                if i % 3 == 0 {
                    match svc.submit_batch(vec![(a.clone(), b.clone()), (a, b)]) {
                        Ok(rxs) => {
                            accepted.fetch_add(rxs.len() as u64, Ordering::SeqCst);
                            for rx in rxs {
                                rx.recv()
                                    .expect("accepted batch request lost its reply")
                                    .expect("valid accepted request must succeed");
                                replied.fetch_add(1, Ordering::SeqCst);
                            }
                        }
                        Err(SubmitError::ServiceStopped) => return,
                        Err(e) => panic!("unexpected submit_batch error: {e}"),
                    }
                } else {
                    match svc.submit(a, b) {
                        Ok(rx) => {
                            accepted.fetch_add(1, Ordering::SeqCst);
                            rx.recv()
                                .expect("accepted request lost its reply")
                                .expect("valid accepted request must succeed");
                            replied.fetch_add(1, Ordering::SeqCst);
                        }
                        Err(SubmitError::ServiceStopped) => return,
                        Err(e) => panic!("unexpected submit error: {e}"),
                    }
                }
            }
        }));
    }
    // Let traffic build, then race shutdown against live submitters.
    std::thread::sleep(Duration::from_millis(15));
    svc.shutdown();
    for s in submitters {
        s.join().expect("submitter panicked");
    }
    assert_eq!(
        accepted.load(Ordering::SeqCst),
        replied.load(Ordering::SeqCst),
        "every accepted request must get exactly one reply"
    );
    assert_eq!(svc.inflight(), 0, "inflight must drain to zero after shutdown");
    assert_eq!(
        svc.submit(Matrix::identity(2), Matrix::identity(2)).err(),
        Some(SubmitError::ServiceStopped),
        "post-shutdown submits must be rejected"
    );
    let snap = svc.metrics.snapshot();
    assert_eq!(snap.requests, replied.load(Ordering::SeqCst), "metrics count every served request");
}

/// Run `f` on a helper thread and fail the test if it does not finish
/// within `limit` (deadlock detector — a hung join would otherwise stall
/// the whole suite).
fn with_watchdog(limit: Duration, f: impl FnOnce() + Send + 'static) {
    let body = std::thread::spawn(f);
    let deadline = Instant::now() + limit;
    while !body.is_finished() {
        assert!(
            Instant::now() < deadline,
            "stress body exceeded the {limit:?} watchdog (deadlock?)"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
    if let Err(e) = body.join() {
        std::panic::resume_unwind(e);
    }
}

#[test]
fn stress_submit_races_shutdown_uncoalesced() {
    with_watchdog(Duration::from_secs(120), || stress_body(false, 0xA11CE));
}

#[test]
fn stress_submit_races_shutdown_coalesced() {
    with_watchdog(Duration::from_secs(120), || stress_body(true, 0xB0B5));
}

#[test]
fn stress_repeated_shutdown_is_idempotent_under_race() {
    with_watchdog(Duration::from_secs(60), || {
        let cfg = ServiceConfig { workers: 2, use_artifacts: false, ..Default::default() };
        let svc = Arc::new(GemmService::start(cfg, None, || Box::new(AlwaysEmulate)));
        let mut closers = Vec::new();
        for _ in 0..4 {
            let svc = svc.clone();
            closers.push(std::thread::spawn(move || svc.shutdown()));
        }
        for c in closers {
            c.join().expect("closer panicked");
        }
        assert_eq!(svc.inflight(), 0);
    });
}

// ---------------------------------------------------------------------
// End-to-end bitwise identity of the coalesced service
// ---------------------------------------------------------------------

#[test]
fn coalesced_service_bitwise_identical_to_per_request_engine() {
    use adp_dgemm::{AdpConfig, AdpEngine};
    let cfg = ServiceConfig {
        workers: 2,
        use_artifacts: false,
        coalesce: true,
        coalesce_window: Duration::from_millis(2),
        ..Default::default()
    };
    let svc = GemmService::start(cfg, None, || Box::new(AlwaysEmulate));
    let engine = AdpEngine::new(AdpConfig::fp64().with_heuristic(Box::new(AlwaysEmulate)));
    let mut rng = Rng::new(804);
    // [1, 2) entries: identical ESC across the group, so the shared A is
    // one cache key and the decomposition counters are deterministic.
    let a = Matrix::uniform(18, 18, 1.0, 2.0, &mut rng);
    let bs: Vec<Matrix> = (0..6).map(|_| Matrix::uniform(18, 18, 1.0, 2.0, &mut rng)).collect();
    let pairs: Vec<(Matrix, Matrix)> = bs.iter().map(|b| (a.clone(), b.clone())).collect();
    let rxs = svc.submit_batch(pairs).expect("service running");
    for (rx, b) in rxs.into_iter().zip(&bs) {
        let resp = rx.recv().expect("reply").expect("request served");
        assert!(resp.outcome.decision.is_emulated());
        let (c_ref, _) = engine.gemm(&a, b);
        for (x, y) in resp.c.data.iter().zip(&c_ref.data) {
            assert_eq!(x.to_bits(), y.to_bits(), "grouped service result differs from engine");
        }
    }
    let snap = svc.metrics.snapshot();
    assert_eq!(snap.slice_cache_misses, 7, "one A + six Bs decomposed");
    assert_eq!(snap.slice_cache_hits, 5, "A reused five times");
    svc.shutdown();
}
