//! Boundary-value and property oracle suite for the `ozaki::kernel`
//! microkernel layer: every kernel runnable on this machine (scalar
//! reference, AVX2 maddubs, AVX2 pmaddwd, AVX-512 pmaddwd, AVX-512 VNNI)
//! must reproduce the naive i64 digit dot product **exactly** — on digit
//! extremes sitting right at the i16 pairwise and i32 accumulator
//! bounds, on odd/tiny shapes that don't fill a register block (8-lane
//! AVX2 and 16-lane AVX-512 alike), on both encodings, and through the
//! fused engine end to end. Every `check_all_kernels` sweep iterates
//! `available_kernels()`, so the AVX-512 tier is covered at the same
//! boundary values on any host that can run it.

use adp_dgemm::backend::WorkspacePool;
use adp_dgemm::linalg::Matrix;
use adp_dgemm::ozaki::gemm::{fused_tile_gemm_serial_on, slice_pair_gemm_tile_on, K_CHUNK};
use adp_dgemm::ozaki::kernel::{self, KernelId, ScalarKernel, SliceKernel};
use adp_dgemm::ozaki::{slice_a, slice_b, PairSchedule, SliceEncoding, SlicedMatrix};
use adp_dgemm::util::{prop, Rng};

/// Naive i64 oracle straight off the slice tensors — independent of
/// every kernel, including the scalar one.
fn naive_pair(a: &SlicedMatrix, t: usize, b: &SlicedMatrix, u: usize) -> Vec<i64> {
    let (m, n, k) = (a.rows, b.rows, a.cols);
    let mut out = vec![0i64; m * n];
    for i in 0..m {
        let ar = a.slice_row(t, i);
        for j in 0..n {
            let br = b.slice_row(u, j);
            let mut acc = 0i64;
            for l in 0..k {
                acc += ar[l] as i64 * br[l] as i64;
            }
            out[i * n + j] = acc;
        }
    }
    out
}

/// Run one pair on `kern` via its own pack + compute path.
fn kernel_pair(
    kern: &dyn SliceKernel,
    a: &SlicedMatrix,
    t: usize,
    b: &SlicedMatrix,
    u: usize,
) -> Vec<i64> {
    let (m, n, k) = (a.rows, b.rows, a.cols);
    let mut apack = vec![0u8; kern.a_slice_bytes(m, k)];
    let mut bpack = vec![0u8; kern.b_slice_bytes(n, k)];
    kern.pack_a_slice(a, t, 0, m, &mut apack);
    kern.pack_b_slice(b, u, 0, n, &mut bpack);
    let mut out = vec![0i64; m * n];
    kern.pair_tile(&apack, &bpack, m, n, k, &mut out);
    out
}

/// A hand-built slice tensor with digits from `f(slice, row, col)`.
fn digits(
    s: usize,
    rows: usize,
    cols: usize,
    enc: SliceEncoding,
    f: impl Fn(usize, usize, usize) -> i8,
) -> SlicedMatrix {
    let mut data = vec![0i8; s * rows * cols];
    for t in 0..s {
        for i in 0..rows {
            for j in 0..cols {
                data[t * rows * cols + i * cols + j] = f(t, i, j);
            }
        }
    }
    SlicedMatrix { s, rows, cols, sigma: vec![0; rows], data, encoding: enc }
}

fn check_all_kernels(a: &SlicedMatrix, b: &SlicedMatrix, what: &str) {
    for kern in kernel::available_kernels() {
        for t in 0..a.s {
            for u in 0..b.s {
                let want = naive_pair(a, t, b, u);
                let got = kernel_pair(*kern, a, t, b, u);
                assert_eq!(got, want, "{what}: kernel {:?} t={t} u={u}", kern.id());
            }
        }
    }
}

#[test]
fn digit_extremes_exercise_the_i16_pairwise_bounds() {
    // The saturation-frontier cases of the maddubs proof: unsigned-
    // encoding extremes (leading ±64, sub-leading 127 / -128) paired so
    // adjacent products push the i16 intermediate to its limits —
    // including the exact i16::MIN case (-128 digit against -128 digit
    // on the negative plane: 2 * 128 * -128 = -32768).
    let enc = SliceEncoding::Unsigned;
    let k = 9; // odd: pairing groups of 2 and 4 both see a ragged tail
    let cases: [(&str, i8, i8); 6] = [
        ("max-pos x max-pos", 127, 127),
        ("min-neg x min-neg", -128, -128),
        ("min-neg x max-pos", -128, 127),
        ("leading-bound x min-neg", 64, -128),
        ("neg-leading x max-pos", -64, 127),
        ("mixed-ones", 1, -1),
    ];
    for (what, da, db) in cases {
        let a = digits(2, 2, k, enc, |t, i, j| {
            if t == 0 {
                64
            } else {
                da.wrapping_add((i + j) as i8 % 2)
            }
        });
        let b = digits(2, 3, k, enc, |t, _, j| {
            if t == 0 {
                -64
            } else if j % 2 == 0 {
                db
            } else {
                db.wrapping_neg()
            }
        });
        check_all_kernels(&a, &b, what);
    }
    // Exact i16::MIN on the negative plane: adjacent (-128, -128) A
    // digits against (-128, -128) B digits give a pair sum of
    // 2 * 128 * (-128) = -32768 — representable, must not clamp.
    let a = digits(1, 1, 8, enc, |_, _, _| -128);
    let b = digits(1, 1, 8, enc, |_, _, _| -128);
    check_all_kernels(&a, &b, "exact i16::MIN pair sum");
    // +32512 frontier: (-128, -128) against (127, 127) maximizes the
    // negative plane's positive pair sum (2 * 128 * 127).
    let b = digits(1, 1, 8, enc, |_, _, _| 127);
    check_all_kernels(&a, &b, "positive pairwise frontier 32512");
    // Alternating-sign worst case: successive pair sums swing between
    // +32512 and -32512, so a signed/unsigned operand mix-up or a wrong
    // saturation would surface here.
    let a = digits(1, 1, 8, enc, |_, _, j| if j % 4 < 2 { -128 } else { 127 });
    let b = digits(1, 1, 8, enc, |_, _, j| if j % 4 < 2 { 127 } else { -128 });
    check_all_kernels(&a, &b, "alternating-sign pairwise frontier");
}

#[test]
fn signed_encoding_extremes() {
    let enc = SliceEncoding::Signed;
    let a = digits(3, 3, 7, enc, |t, i, j| [127i8, -127, 64, -64, 1, 0][(t + i + j) % 6]);
    let b = digits(3, 4, 7, enc, |t, i, j| [-127i8, 127, -64, 63, -1, 0][(2 * t + i + 2 * j) % 6]);
    check_all_kernels(&a, &b, "signed extremes");
}

#[test]
fn tiny_and_odd_shapes_all_kernels() {
    // 1xKx1, single-row / single-column, and row/col counts that are not
    // multiples of the register blocks (2x4 scalar, 8-wide AVX2, 16-wide
    // AVX-512) — the n = 15/16/17 and 31/32/33 entries straddle the
    // 16-lane NR boundary of the AVX-512 tier on both sides.
    let mut rng = Rng::new(500);
    for (m, k, n) in [
        (1usize, 1usize, 1usize),
        (1, 17, 1),
        (1, 4, 9),
        (7, 3, 1),
        (3, 8, 5),
        (9, 31, 7),
        (2, 33, 15),
        (13, 40, 17),
        (5, 21, 16),
        (4, 10, 31),
        (6, 19, 32),
        (3, 12, 33),
    ] {
        let a = Matrix::uniform(m, k, -3.0, 3.0, &mut rng);
        let b = Matrix::uniform(k, n, -3.0, 3.0, &mut rng);
        for enc in [SliceEncoding::Unsigned, SliceEncoding::Signed] {
            for s in [2usize, 4] {
                let asl = slice_a(&a, s, enc);
                let bsl = slice_b(&b, s, enc);
                check_all_kernels(&asl, &bsl, &format!("({m},{k},{n}) {enc:?} s={s}"));
            }
        }
    }
}

#[test]
fn i32_accumulator_edge_at_full_k_chunk() {
    // k = K_CHUNK = 2^17 - 1 with worst-magnitude digits drives the
    // per-lane i32 accumulators to within 2^14 of overflow — the exact
    // frontier the kernel proofs (and the scalar K_CHUNK cap) rely on.
    let k = K_CHUNK;
    let enc = SliceEncoding::Unsigned;
    for (da, db) in [(-128i8, -128i8), (-128, 127), (127, 127), (127, -128)] {
        let a = digits(1, 1, k, enc, |_, _, _| da);
        let b = digits(1, 1, k, enc, |_, _, _| db);
        let want = (k as i64) * (da as i64) * (db as i64);
        for kern in kernel::available_kernels() {
            let got = kernel_pair(*kern, &a, 0, &b, 0);
            assert_eq!(got, vec![want], "kernel {:?} digits ({da},{db})", kern.id());
        }
    }
}

#[test]
fn sub_tile_ranges_match_the_dispatch_entry_point() {
    // The ranged entry point (`slice_pair_gemm_tile_on`) with nonzero
    // row0/col0 offsets, per kernel, against the naive oracle restricted
    // to the same window.
    let mut rng = Rng::new(501);
    let (m, k, n, s) = (11usize, 23usize, 10usize, 3usize);
    let a = Matrix::uniform(m, k, -2.0, 2.0, &mut rng);
    let b = Matrix::uniform(k, n, -2.0, 2.0, &mut rng);
    for enc in [SliceEncoding::Unsigned, SliceEncoding::Signed] {
        let asl = slice_a(&a, s, enc);
        let bsl = slice_b(&b, s, enc);
        let full = naive_pair(&asl, 1, &bsl, 2);
        for kern in kernel::available_kernels() {
            for (row0, rows, col0, cols) in
                [(0usize, 2usize, 0usize, 3usize), (3, 5, 2, 7), (9, 2, 8, 2), (0, 11, 0, 10)]
            {
                let mut out = vec![0i64; rows * cols];
                slice_pair_gemm_tile_on(*kern, &asl, 1, &bsl, 2, row0, rows, col0, cols, &mut out);
                for i in 0..rows {
                    for j in 0..cols {
                        assert_eq!(
                            out[i * cols + j],
                            full[(row0 + i) * n + col0 + j],
                            "{:?} {enc:?} window ({row0},{col0})+({rows},{cols}) at ({i},{j})",
                            kern.id()
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn fused_engine_is_bitwise_identical_across_kernels() {
    // End-to-end: the fused tile engine on every kernel must produce the
    // bit-identical FP64 result the scalar reference produces — shapes
    // straddling the FUSED tile boundaries, both encodings.
    let pool = WorkspacePool::new();
    let mut rng = Rng::new(502);
    for (m, k, n, s) in [(1usize, 1usize, 1usize, 2usize), (65, 20, 63, 5), (40, 9, 130, 7)] {
        let a = Matrix::uniform(m, k, -3.0, 3.0, &mut rng);
        let b = Matrix::uniform(k, n, -3.0, 3.0, &mut rng);
        for enc in [SliceEncoding::Unsigned, SliceEncoding::Signed] {
            let asl = slice_a(&a, s, enc);
            let bsl = slice_b(&b, s, enc);
            let schedule = PairSchedule::get(s, enc.radix_bits());
            let mut c_ref = Matrix::zeros(m, n);
            fused_tile_gemm_serial_on(&ScalarKernel, &asl, &bsl, &schedule, &pool, &mut c_ref);
            for kern in kernel::available_kernels() {
                let mut c = Matrix::zeros(m, n);
                fused_tile_gemm_serial_on(*kern, &asl, &bsl, &schedule, &pool, &mut c);
                for (x, y) in c.data.iter().zip(&c_ref.data) {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "fused {:?} vs scalar ({m},{k},{n}) {enc:?}: {x} vs {y}",
                        kern.id()
                    );
                }
            }
        }
    }
    let st = pool.stats();
    assert!(st.panel_packs > 0 && st.panel_reuses > 0, "fused runs must pack and reuse: {st:?}");
}

#[test]
fn prop_random_digit_tensors_match_across_kernels() {
    // Fully random digit tensors (not reachable by slicing — every i8
    // value in every slice) still must agree: the kernels' exactness
    // argument is digit-range independent for pmaddwd and range-checked
    // for maddubs via the pos/neg split.
    prop::check("kernels == naive on random digits", 24, |rng| {
        let m = rng.int(1, 12) as usize;
        let n = rng.int(1, 12) as usize;
        let k = rng.int(1, 70) as usize;
        let s = rng.int(1, 3) as usize;
        let enc =
            if rng.f64() < 0.5 { SliceEncoding::Unsigned } else { SliceEncoding::Signed };
        let mut a = digits(s, m, k, enc, |_, _, _| 0);
        let mut b = digits(s, n, k, enc, |_, _, _| 0);
        for d in a.data.iter_mut() {
            *d = rng.int(-128, 127) as i8;
        }
        for d in b.data.iter_mut() {
            *d = rng.int(-128, 127) as i8;
        }
        for kern in kernel::available_kernels() {
            for t in 0..s {
                for u in 0..s {
                    let want = naive_pair(&a, t, &b, u);
                    let got = kernel_pair(*kern, &a, t, &b, u);
                    if got != want {
                        return Err(format!(
                            "kernel {:?} ({m},{k},{n}) {enc:?} t={t} u={u}",
                            kern.id()
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn dispatch_honors_force_scalar_and_stays_in_the_available_set() {
    // Under `ADP_FORCE_SCALAR=1` (the CI fallback job) the dispatch must
    // pin the scalar kernel for both encodings; otherwise it must pick a
    // kernel this machine can actually run.
    let forced = matches!(
        std::env::var("ADP_FORCE_SCALAR").ok().as_deref(),
        Some("1") | Some("true") | Some("on")
    );
    for enc in [SliceEncoding::Unsigned, SliceEncoding::Signed] {
        let id = kernel::active_id(enc);
        if forced {
            assert_eq!(id, KernelId::Scalar, "ADP_FORCE_SCALAR must pin the scalar kernel");
        }
        assert!(
            kernel::available_kernels().iter().any(|k| k.id() == id),
            "dispatched {id:?} not runnable here"
        );
    }
}

#[test]
fn dispatch_honors_a_valid_adp_kernel_override() {
    // The CI kernel matrix runs the whole suite with ADP_KERNEL forced
    // per tier: when the override names a kernel this host can run, the
    // dispatch must select exactly it, for both encodings. (A missing or
    // unavailable override falls back to normal dispatch — covered by
    // the availability assert above.)
    let forced_scalar = matches!(
        std::env::var("ADP_FORCE_SCALAR").ok().as_deref(),
        Some("1") | Some("true") | Some("on")
    );
    let Some(want) = std::env::var("ADP_KERNEL").ok().and_then(|v| KernelId::parse(&v)) else {
        return;
    };
    if forced_scalar || kernel::kernel_by_id(want).is_none() {
        return; // force-scalar outranks the override; unavailable tiers fall back
    }
    for enc in [SliceEncoding::Unsigned, SliceEncoding::Signed] {
        assert_eq!(
            kernel::active_id(enc),
            want,
            "ADP_KERNEL={} must pin the dispatch for {enc:?}",
            want.label()
        );
    }
}
