//! Integration suite for the Ozaki-II/CRT scheme family.
//!
//! Mirrors the guarantees the slice-pair family already pins down:
//!
//! * FP64-level grading (Grade A, componentwise) on the Test 1/2/3
//!   generator regimes when the ESC-sized window fits the modulus basis;
//! * bitwise identity across backends, thread counts and forced
//!   k-chunking (the modulus loop is exact integer work, so scheduling
//!   cannot change a bit);
//! * scheme equivalence: on integer inputs both families compute the
//!   exact product, so CRT and slice pairs agree bitwise through two
//!   completely different reconstruction paths;
//! * the launch-count claim: one GEMM per modulus grows linearly in the
//!   window while slice pairs grow quadratically.

use adp_dgemm::backend::{ParallelBackend, SerialBackend, WorkspacePool};
use adp_dgemm::esc::coarse_esc_gemm;
use adp_dgemm::grading::generators::{test2_workload, tiny_corner_pair, uniform_pair};
use adp_dgemm::grading::grade::{measure, passes_grade_a};
use adp_dgemm::linalg::Matrix;
use adp_dgemm::ozaki::gemm::K_CHUNK;
use adp_dgemm::ozaki::{
    crt_gemm, crt_gemm_on, fused_gemm_on, CrtConfig, OzakiConfig, SliceEncoding,
};
use adp_dgemm::util::{prop, Rng};

// ---------------------------------------------------------------------
// FP64 grading on the generator regimes
// ---------------------------------------------------------------------

/// ESC-size the window exactly like the coordinator, run the CRT family,
/// and demand the componentwise Grade A tolerance — the same budget the
/// slice-pair regime suite uses (`grouped_pipeline.rs`).
fn check_crt_regime(a: &Matrix, b: &Matrix, what: &str) {
    let esc = coarse_esc_gemm(a, b, 64);
    let s_eq = SliceEncoding::Unsigned.slices_for_bits(53 + esc + 1);
    let Some(cfg) = CrtConfig::for_window(s_eq, a.cols) else {
        // Window exceeds the modulus basis: the coordinator runs slice
        // pairs for such requests (covered by the grouped_pipeline
        // regime suite), so there is nothing to grade here.
        return;
    };
    assert!(
        cfg.gemm_count() <= cfg.pair_gemm_count(),
        "{what}: CRT must never launch more than the pair schedule"
    );
    let c = crt_gemm(a, b, &cfg);
    let rep = measure(a, b, &c);
    assert!(
        passes_grade_a(&rep, a.cols.max(4), 4.0),
        "{what}: CRT emulation broke the grading tolerance: {rep:?} \
         (esc {esc}, s_eq {s_eq}, moduli {})",
        cfg.gemm_count()
    );
}

#[test]
fn crt_grade_a_on_test1_regime() {
    // Test 1's magnitude staircase: a tiny leading row of A / column of B.
    let mut rng = Rng::new(811);
    for delta_exp in [-10i32, -30, -50] {
        let (a, b) = tiny_corner_pair(12, 2f64.powi(delta_exp), &mut rng);
        check_crt_regime(&a, &b, &format!("test1 delta=2^{delta_exp}"));
    }
}

#[test]
fn crt_grade_a_on_test2_regime() {
    // Test 2's cyclic-shift diagonal scaling (the Fig 2 workload).
    let mut rng = Rng::new(812);
    for span_b in [4i32, 10, 20] {
        let w = test2_workload(16, span_b, &mut rng);
        check_crt_regime(&w.a, &w.b, &format!("test2 b={span_b}"));
    }
}

#[test]
fn crt_grade_a_on_test3_regime() {
    // Test 3 reuses the Test 2 construction at escalating spans, plus the
    // uniform baseline.
    let mut rng = Rng::new(813);
    for span_b in [8i32, 24] {
        let w = test2_workload(12, span_b, &mut rng);
        check_crt_regime(&w.a, &w.b, &format!("test3 b={span_b}"));
    }
    let (a, b) = uniform_pair(16, -1.0, 1.0, &mut rng);
    check_crt_regime(&a, &b, "uniform");
}

// ---------------------------------------------------------------------
// Bitwise identity across backends, thread counts and chunking
// ---------------------------------------------------------------------

#[test]
fn prop_crt_bitwise_identical_across_backends() {
    let pool = WorkspacePool::new();
    prop::check("crt serial == parallel (bitwise)", 12, |rng| {
        let m = rng.int(1, 40) as usize;
        let k = rng.int(1, 48) as usize;
        let n = rng.int(1, 40) as usize;
        let a = Matrix::uniform(m, k, -3.0, 3.0, rng);
        let b = Matrix::uniform(k, n, -3.0, 3.0, rng);
        let s_eq = rng.int(2, 9) as usize;
        let mut cfg = CrtConfig::for_window(s_eq, k).expect("small windows always fit");
        if rng.f64() < 0.4 {
            // forced chunking: the FP64 chunk summation order is fixed,
            // so bitwise identity must survive it too
            cfg = cfg.with_k_chunk(rng.int(1, k as i64) as usize);
        }
        let c_ref = crt_gemm_on(&a, &b, &cfg, &SerialBackend, &pool);
        for threads in [1usize, 2, 4] {
            let par = ParallelBackend::new(threads).with_cutoff_ops(0);
            let c = crt_gemm_on(&a, &b, &cfg, &par, &pool);
            for (x, y) in c.data.iter().zip(&c_ref.data) {
                if x.to_bits() != y.to_bits() {
                    return Err(format!("{threads} threads: {x} vs {y} (cfg {cfg:?})"));
                }
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Scheme equivalence on integer inputs
// ---------------------------------------------------------------------

#[test]
fn prop_crt_matches_slice_pairs_exactly_on_integer_inputs() {
    // On integer inputs the exact product is representable, both
    // families' accumulators are exact, and the shared descale pass is a
    // power-of-two multiply — so Garner reconstruction and compensated
    // pair recomposition must land on the *same bits*, and both on the
    // exact integer product.
    let pool = WorkspacePool::new();
    prop::check("crt == slice-pair == exact (integer inputs)", 12, |rng| {
        let m = rng.int(1, 24) as usize;
        let k = rng.int(1, 48) as usize;
        let n = rng.int(1, 24) as usize;
        let mut a = Matrix::uniform(m, k, -512.0, 512.0, rng);
        let mut b = Matrix::uniform(k, n, -512.0, 512.0, rng);
        for x in a.data.iter_mut().chain(b.data.iter_mut()) {
            *x = x.round();
        }
        let mut exact = Matrix::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                let mut acc: i64 = 0;
                for l in 0..k {
                    acc += (a.at(i, l) as i64) * (b.at(l, j) as i64);
                }
                *exact.at_mut(i, j) = acc as f64; // |acc| <= 48*2^18 << 2^53
            }
        }
        let s = rng.int(3, 8) as usize;
        let ccfg = CrtConfig::for_window(s, k).expect("small windows always fit");
        let c_crt = crt_gemm_on(&a, &b, &ccfg, &SerialBackend, &pool);
        let c_sp = fused_gemm_on(&a, &b, &OzakiConfig::new(s), &SerialBackend, &pool);
        for idx in 0..exact.data.len() {
            let (e, xc, xs) = (exact.data[idx], c_crt.data[idx], c_sp.data[idx]);
            if xc.to_bits() != e.to_bits() {
                return Err(format!("crt {xc} != exact {e} (s {s})"));
            }
            if xs.to_bits() != e.to_bits() {
                return Err(format!("slice-pair {xs} != exact {e} (s {s})"));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Launch-count claim: linear in the window vs quadratic
// ---------------------------------------------------------------------

#[test]
fn linear_launches_beat_quadratic_pairs() {
    // The paper-level claim behind the scheme family: at the deployment
    // chunk bound a 7-slice window costs 17 modular GEMMs against 28
    // slice pairs, and the gap only widens with the window.
    let cfg7 = CrtConfig::for_window(7, K_CHUNK).unwrap();
    assert_eq!(cfg7.gemm_count(), 17);
    assert_eq!(cfg7.pair_gemm_count(), 28);
    for s_eq in 5..=12 {
        let cfg = CrtConfig::for_window(s_eq, K_CHUNK).unwrap();
        assert!(
            cfg.gemm_count() < cfg.pair_gemm_count(),
            "s_eq={s_eq}: {} moduli vs {} pairs",
            cfg.gemm_count(),
            cfg.pair_gemm_count()
        );
    }
}
