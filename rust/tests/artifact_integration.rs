//! Integration: AOT artifacts (python/JAX/Pallas -> HLO text -> PJRT)
//! against the native Rust pipeline.
//!
//! These tests are the seam of the three-layer architecture: they prove
//! the L2 graph and the L3-native implementation compute the *same*
//! function. They skip (pass trivially with a note) when `artifacts/`
//! has not been built — run `make artifacts` first for full coverage.

use std::path::Path;
use std::sync::OnceLock;

use adp_dgemm::coordinator::heuristic::AlwaysEmulate;
use adp_dgemm::coordinator::{AdpConfig, AdpEngine};
use adp_dgemm::esc::coarse_esc_gemm;
use adp_dgemm::linalg::{gemm, Matrix};
use adp_dgemm::ozaki::{emulated_gemm, AccuracyTier, OzakiConfig};
use adp_dgemm::runtime::{ArtifactKind, RuntimeHandle};
use adp_dgemm::util::Rng;

fn runtime() -> Option<&'static RuntimeHandle> {
    static RT: OnceLock<Option<RuntimeHandle>> = OnceLock::new();
    RT.get_or_init(|| {
        let rt = RuntimeHandle::try_load(Path::new("artifacts"));
        if rt.is_none() {
            eprintln!("NOTE: artifacts/ missing — integration tests skipped (run `make artifacts`)");
        }
        rt
    })
    .as_ref()
}

#[test]
fn dgemm_artifact_matches_native_gemm() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(100);
    for n in rt.catalog().sizes(ArtifactKind::Dgemm) {
        let a = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
        let b = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
        let c_art = rt.dgemm(n, &a, &b).expect("dgemm artifact");
        let c_nat = gemm(&a, &b);
        // both are O(n^3) FP64; different summation orders => eps-level
        let denom = a.abs().matmul_dd(&b.abs());
        for i in 0..n {
            for j in 0..n {
                let e = (c_art.at(i, j) - c_nat.at(i, j)).abs() / denom.at(i, j);
                assert!(e < (n as f64) * f64::EPSILON, "n={n} ({i},{j}): {e}");
            }
        }
        break; // one size is enough for the slow path
    }
}

#[test]
fn ozaki_artifact_bitwise_matches_native_pipeline() {
    // The strongest cross-layer check in the repo: the L2 jax graph and
    // the native Rust pipeline implement the same deterministic function,
    // so results must be IDENTICAL bit for bit.
    let Some(rt) = runtime() else { return };
    let sizes = rt.catalog().sizes(ArtifactKind::Gemm);
    let n = *sizes.first().expect("at least one gemm artifact");
    let mut rng = Rng::new(101);
    for (trial, span) in [(0u64, 0i32), (1, 10), (2, 25)] {
        let a = Matrix::from_fn(n, n, |_, _| {
            rng.uniform(-2.0, 2.0) * 2f64.powi(rng.int(-span as i64, span as i64) as i32)
        });
        let b = Matrix::from_fn(n, n, |_, _| {
            rng.uniform(-2.0, 2.0) * 2f64.powi(rng.int(-span as i64, span as i64) as i32)
        });
        for s in rt.catalog().slice_counts(n) {
            let c_art = rt.emulated_gemm(n, s, &a, &b).expect("gemm artifact");
            let c_nat = emulated_gemm(&a, &b, &OzakiConfig::new(s));
            let mut diffs = 0;
            for (x, y) in c_art.data.iter().zip(&c_nat.data) {
                if x.to_bits() != y.to_bits() {
                    diffs += 1;
                }
            }
            assert_eq!(diffs, 0, "trial {trial} n={n} s={s}: {diffs} bitwise diffs");
        }
    }
}

#[test]
fn scan_artifact_matches_native_scan_and_esc() {
    let Some(rt) = runtime() else { return };
    let sizes = rt.catalog().sizes(ArtifactKind::Scan);
    let n = *sizes.first().expect("scan artifact");
    let mut rng = Rng::new(102);

    // clean input: flags clear, esc == native coarse esc (same block = 64)
    let a = Matrix::from_fn(n, n, |_, _| {
        rng.uniform(1.0, 2.0) * 2f64.powi(rng.int(-20, 20) as i32)
    });
    let b = Matrix::from_fn(n, n, |_, _| {
        rng.uniform(1.0, 2.0) * 2f64.powi(rng.int(-20, 20) as i32)
    });
    let res = rt.scan_esc(n, &a, &b).expect("scan artifact");
    assert!(!res.has_nan && !res.has_inf);
    let native = coarse_esc_gemm(&a, &b, 64);
    assert_eq!(res.esc, native, "artifact vs native coarsened ESC");
    assert_eq!(res.required_bits_fp64, 53 + native + 1);

    // NaN / Inf detection
    let mut a2 = a.clone();
    *a2.at_mut(1, 2) = f64::NAN;
    assert!(rt.scan_esc(n, &a2, &b).unwrap().has_nan);
    let mut b2 = b.clone();
    *b2.at_mut(0, 0) = f64::NEG_INFINITY;
    assert!(rt.scan_esc(n, &a, &b2).unwrap().has_inf);
}

#[test]
fn artifact_padding_crops_correctly() {
    let Some(rt) = runtime() else { return };
    let sizes = rt.catalog().sizes(ArtifactKind::Gemm);
    let n = *sizes.first().unwrap();
    let s = *rt.catalog().slice_counts(n).last().unwrap();
    let mut rng = Rng::new(103);
    // ragged shapes, padded into the square artifact
    let (m0, k0, n0) = (n - 3, n - 7, n / 2 + 1);
    let a = Matrix::uniform(m0, k0, -1.0, 1.0, &mut rng);
    let b = Matrix::uniform(k0, n0, -1.0, 1.0, &mut rng);
    let c = rt.emulated_gemm(n, s, &a, &b).expect("padded artifact gemm");
    assert_eq!((c.rows, c.cols), (m0, n0));
    let c_nat = emulated_gemm(&a.pad_to(n, n), &b.pad_to(n, n), &OzakiConfig::new(s));
    for i in 0..m0 {
        for j in 0..n0 {
            assert_eq!(c.at(i, j).to_bits(), c_nat.at(i, j).to_bits(), "({i},{j})");
        }
    }
}

#[test]
fn adp_engine_uses_artifacts_when_available() {
    let Some(rt) = runtime() else { return };
    // Guaranteed tier pinned: artifacts encode the full pair schedule, so
    // a fast-tier engine (e.g. under ADP_TIER=fast) would legitimately
    // bypass them — this test asserts the artifact dispatch itself.
    let engine = AdpEngine::new(
        AdpConfig::fp64()
            .with_heuristic(Box::new(AlwaysEmulate))
            .with_runtime(Some(rt.clone()))
            .with_tier(AccuracyTier::GuaranteedFp64),
    );
    let sizes = rt.catalog().sizes(ArtifactKind::Gemm);
    let n = sizes[0];
    let mut rng = Rng::new(104);
    let a = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
    let b = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
    let (c, out) = engine.gemm(&a, &b);
    assert!(
        matches!(out.decision, adp_dgemm::coordinator::GemmDecision::EmulatedArtifact { .. }),
        "{:?}",
        out.decision
    );
    let denom = a.abs().matmul_dd(&b.abs());
    let c_ref = a.matmul_dd(&b);
    for idx in 0..c.data.len() {
        let e = (c.data[idx] - c_ref.data[idx]).abs() / denom.data[idx];
        assert!(e < 64.0 * f64::EPSILON);
    }
}

#[test]
fn subnormal_inputs_steered_to_native_pipeline() {
    let Some(rt) = runtime() else { return };
    let engine = AdpEngine::new(
        AdpConfig::fp64()
            .with_heuristic(Box::new(AlwaysEmulate))
            .with_runtime(Some(rt.clone())),
    );
    let n = rt.catalog().sizes(ArtifactKind::Gemm)[0];
    let mut rng = Rng::new(105);
    let mut a = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
    let b = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
    *a.at_mut(0, 0) = f64::from_bits(12345); // deep subnormal
    let (_, out) = engine.gemm(&a, &b);
    // artifact substrate flushes subnormals (DAZ/FTZ): must dispatch native
    assert!(
        matches!(out.decision, adp_dgemm::coordinator::GemmDecision::EmulatedNative { .. }),
        "{:?}",
        out.decision
    );
}
