//! System integration across modules (no artifacts required): ADP engine +
//! QR + grading + service, exercising the paper's end-to-end claims.

use adp_dgemm::coordinator::heuristic::{AlwaysEmulate, HeuristicInput, SelectionHeuristic};
use adp_dgemm::coordinator::{AdpConfig, AdpEngine, GemmService, ServiceConfig};
use adp_dgemm::grading::{self, generators, AlgorithmClass};
use adp_dgemm::linalg::{blocked_qr, strassen, Matrix, NativeGemm};
use adp_dgemm::ozaki::{emulated_gemm, AccuracyTier, OzakiConfig};
use adp_dgemm::util::Rng;

fn emulating_engine() -> AdpEngine {
    // Pinned to the guaranteed tier: these tests assert the paper's
    // FP64-accuracy claims, which must hold regardless of any ADP_TIER
    // the test environment exports.
    AdpEngine::new(
        AdpConfig::fp64()
            .with_heuristic(Box::new(AlwaysEmulate))
            .with_runtime(None)
            .with_tier(AccuracyTier::GuaranteedFp64),
    )
}

#[test]
fn discovery_tree_classifies_all_four_quadrants() {
    // §6: the grading tests separate {O(n^3), Strassen} x {float, fixed}.
    let engine = emulating_engine();
    let mut adp = |a: &Matrix, b: &Matrix| engine.gemm(a, b).0;
    assert_eq!(grading::discover(96, 1, &mut adp), AlgorithmClass::FloatingPointO3);

    let mut fixed = |a: &Matrix, b: &Matrix| emulated_gemm(a, b, &OzakiConfig::new(7));
    assert_eq!(grading::discover(96, 1, &mut fixed), AlgorithmClass::FixedPointO3);

    let mut float_str = |a: &Matrix, b: &Matrix| strassen(a, b);
    assert_eq!(grading::discover(256, 1, &mut float_str), AlgorithmClass::FloatingPointStrassen);
}

#[test]
fn aspect_a1_guardrails_pass_test2() {
    // §6 A1: with guardrails + fallback, Test 2 cannot distinguish ADP
    // from a floating-point O(n^3) implementation.
    let engine = emulating_engine();
    for span in [8, 40, 96] {
        let mut m = |a: &Matrix, b: &Matrix| engine.gemm(a, b).0;
        let err = grading::test2::run_at(64, span, 5, &mut m);
        assert!(err < 1e-12, "span {span}: err {err}");
    }
    // sanity: some of those spans exceeded the 26-slice budget => fallback
    let snap = engine.metrics.snapshot();
    assert!(snap.fallback_esc > 0, "expected ESC fallbacks: {snap:?}");
    assert!(snap.emulated > 0, "expected emulated dispatches too");
}

#[test]
fn qr_with_adp_backend_matches_native_accuracy() {
    // §7.3 / Fig 7: trailing updates through ADP keep the factorization at
    // FP64-level residual, and the slice histogram is populated.
    let mut rng = Rng::new(200);
    let a = Matrix::uniform(96, 96, -1.0, 1.0, &mut rng);

    let (qr_nat, _) = blocked_qr(&a, 24, &mut NativeGemm);
    let mut engine = emulating_engine();
    let (qr_adp, stats) = blocked_qr(&a, 24, &mut engine);

    let r_nat = qr_nat.residual(&a);
    let r_adp = qr_adp.residual(&a);
    assert!(r_adp < 4.0 * r_nat.max(1e-15), "adp {r_adp} vs native {r_nat}");
    assert!(stats.gemm_calls >= 6);
    let snap = engine.metrics.snapshot();
    assert_eq!(snap.requests as usize, stats.gemm_calls);
    assert!(!snap.slice_histogram.is_empty());
}

#[test]
fn service_survives_adversarial_stream() {
    // End-to-end: mixed benign/adversarial stream through the service;
    // every response correct, metrics consistent, no deadlock.
    let cfg = ServiceConfig {
        workers: 3,
        use_artifacts: false,
        default_tier: AccuracyTier::GuaranteedFp64, // asserts 100-eps accuracy below
        ..Default::default()
    };
    let svc = GemmService::start(cfg, None, || Box::new(AlwaysEmulate));
    let mut rng = Rng::new(201);
    let mut pending = Vec::new();
    for i in 0..30 {
        let n = 8 + rng.index(24);
        let (mut a, b) = generators::uniform_pair(n, -2.0, 2.0, &mut rng);
        if i % 7 == 3 {
            *a.at_mut(0, 0) = f64::NAN;
        }
        let expect_finite = i % 7 != 3;
        let (ac, bc) = (a.clone(), b.clone());
        let rx = svc.submit(a, b).expect("service running");
        pending.push((ac, bc, expect_finite, rx));
    }
    for (a, b, expect_finite, rx) in pending {
        let resp = rx.recv().unwrap().expect("request served");
        assert_eq!((resp.c.rows, resp.c.cols), (a.rows, b.cols));
        if expect_finite {
            assert!(!resp.c.has_non_finite());
            let denom = a.abs().matmul_dd(&b.abs());
            let c_ref = a.matmul_dd(&b);
            for idx in 0..resp.c.data.len() {
                let d = denom.data[idx];
                if d > 0.0 {
                    let e = (resp.c.data[idx] - c_ref.data[idx]).abs() / d;
                    assert!(e < 100.0 * f64::EPSILON, "err {e}");
                }
            }
        } else {
            assert!(resp.c.has_non_finite());
        }
    }
    let snap = svc.metrics.snapshot();
    assert_eq!(snap.requests, 30);
    assert!(snap.guardrail_fraction() < 0.9);
    svc.shutdown();
}

#[test]
fn adp_never_worse_than_fp64_accuracy_on_test2_sweep() {
    // The paper's headline guarantee, end to end: for every span, ADP's
    // componentwise error stays within a small factor of native FP64's.
    let engine = emulating_engine();
    let mut rng = Rng::new(202);
    for span in [0, 16, 48, 80] {
        let w = generators::test2_workload(48, span, &mut rng);
        let (c, _) = engine.gemm(&w.a, &w.b);
        let e_adp = grading::test2::relative_error(&w, &c);
        let c_nat = adp_dgemm::linalg::gemm(&w.a, &w.b);
        let e_nat = grading::test2::relative_error(&w, &c_nat);
        assert!(
            e_adp <= 8.0 * e_nat.max(1e-15),
            "span {span}: adp {e_adp} vs native {e_nat}"
        );
    }
}

#[test]
fn heuristic_decisions_consistent_with_cost_model() {
    // The platform heuristic must agree with the model's profitability.
    use adp_dgemm::perfmodel::{GB200, RTX_PRO_6000};
    for p in [GB200, RTX_PRO_6000] {
        let h = adp_dgemm::coordinator::heuristic::PlatformHeuristic { platform: p };
        for n in [64usize, 512, 2048, 8192] {
            let inp = HeuristicInput::single(n, n, n, 7);
            assert_eq!(h.emulate(&inp), p.emulation_profitable(n, n, n, 7), "{} n={n}", p.name);
        }
    }
}
