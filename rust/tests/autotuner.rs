//! Autotuner acceptance suite: (a) the geometry-invariance property —
//! every tile shape the autotuner can ever pick (the whole `CANDIDATES`
//! grid) is **bitwise identical** to the fixed 64×64 baseline across
//! backends, thread counts, and encodings, which is the argument that
//! makes runtime tile tuning safe at all; (b) the persistence loop — a
//! probe writes a `runtime::tuning` catalog entry that a later lookup
//! (and a later process, via the same file) consumes instead of
//! re-probing.
//!
//! Both tests mutate process-wide tuner state (the `force_shape` pin and
//! the `ADP_TUNE_CATALOG` path, which is latched in a `OnceLock` on first
//! use), so each stays on its own state: the property test only ever
//! runs *pinned* (never touching the catalog path), and the persistence
//! test sets the env var before the first catalog access in this test
//! binary.

use adp_dgemm::backend::{ComputeBackend, ParallelBackend, SerialBackend, WorkspacePool};
use adp_dgemm::linalg::Matrix;
use adp_dgemm::ozaki::{
    emulated_gemm_on, fused_gemm_on, tune, KernelId, OzakiConfig, ShapeBucket, SliceEncoding,
    TileShape,
};
use adp_dgemm::runtime::tuning;
use adp_dgemm::util::Rng;

fn assert_bitwise(c1: &Matrix, c2: &Matrix, what: &str) {
    assert_eq!((c1.rows, c1.cols), (c2.rows, c2.cols), "{what}: shape mismatch");
    for (x, y) in c1.data.iter().zip(&c2.data) {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: not bitwise identical ({x} vs {y})");
    }
}

#[test]
fn every_candidate_tile_shape_is_bitwise_identical_across_backends() {
    // The property behind the whole autotuner: geometry is a pure
    // performance knob. Reference = level-major serial (the retained
    // oracle, tile-shape-free); every candidate shape must reproduce it
    // bitwise on the serial fused engine and on parallel engines at
    // several thread counts with the inline cutoff forced off.
    let par2 = ParallelBackend::new(2).with_cutoff_ops(0);
    let par4 = ParallelBackend::new(4).with_cutoff_ops(0);
    let backends: [(&str, &dyn ComputeBackend); 3] =
        [("serial", &SerialBackend), ("par2", &par2), ("par4", &par4)];
    let pool = WorkspacePool::new();
    let mut rng = Rng::new(7100);
    // Shapes chosen to straddle tile boundaries of *different* candidates:
    // multi-band, multi-column-strip, flat-wide, and tall-narrow outputs.
    let shapes = [(65, 20, 130), (100, 15, 70), (33, 9, 97), (130, 12, 31)];
    for (m, k, n) in shapes {
        for enc in [SliceEncoding::Unsigned, SliceEncoding::Signed] {
            let a = Matrix::uniform(m, k, -3.0, 3.0, &mut rng);
            let b = Matrix::uniform(k, n, -3.0, 3.0, &mut rng);
            let cfg = OzakiConfig::with_encoding(3, enc);
            let oracle = emulated_gemm_on(&a, &b, &cfg, &SerialBackend);
            for &shape in tune::CANDIDATES.iter() {
                tune::force_shape(Some(shape));
                for (name, backend) in backends {
                    let c = fused_gemm_on(&a, &b, &cfg, backend, &pool);
                    assert_bitwise(
                        &c,
                        &oracle,
                        &format!("tile {} on {name} ({m},{k},{n}) {enc:?}", shape.label()),
                    );
                }
            }
        }
    }
    tune::force_shape(None);
    assert!(pool.stats().fused_tiles > 0, "the fused schedule must actually have run");
}

#[test]
fn probe_persists_a_catalog_entry_that_a_reload_consumes() {
    // End-to-end persistence loop on a private catalog file: first
    // resolve probes (source=probed), second resolves from the in-process
    // cache (source=cached), and the file on disk is a valid
    // runtime::tuning catalog a *fresh* process would load instead of
    // probing — asserted here by parsing it back and checking the winner.
    let dir = std::env::temp_dir().join(format!("adp_autotuner_it_{}", std::process::id()));
    let path = dir.join("tile_tuning.txt");
    std::fs::create_dir_all(&dir).expect("temp catalog dir");
    // Latch the catalog path before anything in this test binary touches
    // the tuner's persistence layer (the path is read once per process).
    std::env::set_var("ADP_TUNE_CATALOG", &path);

    let (shape, cached) = tune::tune_probe(KernelId::Scalar, ShapeBucket::Large);
    assert!(!cached, "first resolve must probe, not hit a cache");
    assert!(tune::CANDIDATES.contains(&shape), "{shape:?} not in the candidate grid");
    let (again, cached) = tune::tune_probe(KernelId::Scalar, ShapeBucket::Large);
    assert_eq!(again, shape, "cached winner must be stable");
    assert!(cached, "second resolve must come from the cache");

    // The probe must have persisted a catalog a future process can load.
    let entries = tuning::load(&path).expect("probe persists a parseable catalog");
    let entry = entries
        .iter()
        .find(|e| e.kernel == KernelId::Scalar.label() && e.bucket == ShapeBucket::Large.label())
        .expect("catalog holds the probed (kernel, bucket) entry");
    assert_eq!((entry.mc, entry.nc), (shape.mc, shape.nc), "persisted shape mismatch");
    assert!(
        entry.pair_ns.is_finite() && entry.pair_ns > 0.0,
        "probe must persist its measured ns/MAC: {entry:?}"
    );
    // Round-trip sanity: what we persisted is exactly what a reload
    // parses (the same loader ozaki::tune uses at startup).
    let reparsed = tuning::parse(&tuning::serialize(&entries)).unwrap();
    assert_eq!(reparsed, entries);
    // The tuned shape also parses back through the ADP_TILE/label format.
    assert_eq!(TileShape::parse(&shape.label()), Some(shape));

    let _ = std::fs::remove_dir_all(&dir);
}
