//! Ablation benches for the design choices DESIGN.md calls out:
//!
//!   (a) unsigned vs signed slice encoding (§3): slice count, pair-GEMM
//!       count, measured time and accuracy at equal target bits;
//!   (b) ESC coarsening block size (§4): estimate tightness vs cost;
//!   (c) fused tile engine vs the level-major reference schedule (same
//!       bits out, one output pass instead of s level sweeps);
//!   (d) grouped pipeline slice-cache amortization (the --coalesce path);
//!   (f) scheme families at a matched window: native FP64 vs Ozaki-I
//!       slice pairs vs Ozaki-II/CRT — launches, time, accuracy.
//!   (g) accuracy tiers (§tiers): per-tier pair-truncated schedules —
//!       pair count, time, and measured componentwise error.
//!
//! Section (f) also emits `BENCH_ablation.json` (machine-readable arms)
//! next to the working directory so CI can archive the comparison;
//! `perf_hotpath` emits the per-tier twin `BENCH_tiers.json`.

use adp_dgemm::backend::{SerialBackend, WorkspacePool};
use adp_dgemm::esc::{coarse_esc_gemm, exact_esc_gemm};
use adp_dgemm::grading::grade::measure;
use adp_dgemm::linalg::Matrix;
use adp_dgemm::ozaki::gemm::fused_tile_gemm_serial_on;
use adp_dgemm::ozaki::kernel;
use adp_dgemm::ozaki::{
    crt_gemm_on, emulated_gemm, fused_gemm_on, gemm_grouped, slice_a, slice_b, AccuracyTier,
    CrtConfig, GroupedProblem, OzakiConfig, PairSchedule, SchemeKind, SliceCache, SliceEncoding,
};
use adp_dgemm::util::{benchkit, Rng};

fn main() {
    let n = 256;
    let mut rng = Rng::new(404);
    let a = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
    let b = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);

    println!("# (a) encoding ablation at equal target bits (n={n})");
    println!(
        "{:>10} {:>8} {:>8} {:>8} {:>12} {:>12}",
        "target", "enc", "slices", "pairs", "time_ms", "maxerr_eps"
    );
    for target in [30, 53, 70] {
        for enc in [SliceEncoding::Unsigned, SliceEncoding::Signed] {
            let cfg = OzakiConfig::for_bits(target, enc);
            let st = benchkit::bench(1, 3, || emulated_gemm(&a, &b, &cfg));
            let rep = measure(&a, &b, &emulated_gemm(&a, &b, &cfg));
            println!(
                "{:>10} {:>8} {:>8} {:>8} {:>12.1} {:>12.3}",
                target,
                match enc {
                    SliceEncoding::Unsigned => "u8",
                    SliceEncoding::Signed => "s8",
                },
                cfg.slices,
                cfg.pair_count(),
                st.median_s * 1e3,
                rep.max_comp_eps
            );
        }
    }
    println!("# u8 encoding: fewer slices => ~22% fewer pair GEMMs at 53-bit target (§3)");

    println!("\n# (b) ESC coarsening block ablation (wide-span workload, n={n}, k-span 2^±25)");
    let mut aw = Matrix::uniform(n, n, 1.0, 2.0, &mut rng);
    let mut bw = Matrix::uniform(n, n, 1.0, 2.0, &mut rng);
    for l in 0..n {
        let e = (l as i32 - (n as i32) / 2) / 5;
        for i in 0..n {
            *aw.at_mut(i, l) *= 2f64.powi(e);
            *bw.at_mut(l, i) *= 2f64.powi(-e);
        }
    }
    let exact = exact_esc_gemm(&aw, &bw);
    println!("{:>8} {:>8} {:>10} {:>12}", "block", "esc", "overest", "time_ms");
    for block in [1usize, 4, 16, 64, 256] {
        let st = benchkit::bench(1, 3, || coarse_esc_gemm(&aw, &bw, block));
        let esc = coarse_esc_gemm(&aw, &bw, block);
        println!(
            "{block:>8} {esc:>8} {:>10} {:>12.2}",
            esc - exact,
            st.median_s * 1e3
        );
    }
    println!("# exact ESC = {exact}; smaller blocks tighten the estimate at higher scan cost");
    println!("# (b=64 is the default: cost ~1/64 of a GEMM pass, overestimate within one slice)");

    println!("\n# (c) fused tile engine vs level-major reference (n={n}, s=7, serial)");
    let cfg7 = OzakiConfig::new(7);
    let wpool = WorkspacePool::new();
    let st_lvl = benchkit::bench(1, 3, || emulated_gemm(&a, &b, &cfg7));
    let st_fus = benchkit::bench(1, 3, || fused_gemm_on(&a, &b, &cfg7, &SerialBackend, &wpool));
    {
        let c_lvl = emulated_gemm(&a, &b, &cfg7);
        let c_fus = fused_gemm_on(&a, &b, &cfg7, &SerialBackend, &wpool);
        let identical = c_lvl.data.iter().zip(&c_fus.data).all(|(x, y)| x.to_bits() == y.to_bits());
        let ws = wpool.stats();
        println!(
            "level-major {:.1} ms vs fused {:.1} ms ({:.2}x); bitwise identical: {identical}; {} tiles, {} fresh allocs over {} checkouts",
            st_lvl.median_s * 1e3,
            st_fus.median_s * 1e3,
            st_lvl.median_s / st_fus.median_s,
            ws.fused_tiles,
            ws.fresh_allocs,
            ws.checkouts
        );
    }
    println!("# one pass over the output (tile-resident pairs) instead of s matrix-wide level sweeps");

    println!("\n# (d) grouped-pipeline (--coalesce) ablation: 8 requests sharing one A (n={n}, s=7)");
    let group = 8usize;
    let bs: Vec<Matrix> =
        (0..group).map(|_| Matrix::uniform(n, n, -1.0, 1.0, &mut rng)).collect();
    let st_seq = benchkit::bench(1, 3, || {
        for b in &bs {
            std::hint::black_box(emulated_gemm(&a, b, &cfg7));
        }
    });
    // Cold cache each iteration: measures amortization *within* one group
    // (a warm service cache only improves on this).
    let st_grp = benchkit::bench(1, 3, || {
        let cache = SliceCache::new(2 * group + 2);
        let probs: Vec<GroupedProblem<'_>> = bs
            .iter()
            .map(|b| GroupedProblem { a: &a, b, cfg: cfg7, scheme: SchemeKind::SlicePair })
            .collect();
        std::hint::black_box(gemm_grouped(&probs, &cache, &SerialBackend, &wpool))
    });
    let cache = SliceCache::new(2 * group + 2);
    let probs: Vec<GroupedProblem<'_>> = bs
        .iter()
        .map(|b| GroupedProblem { a: &a, b, cfg: cfg7, scheme: SchemeKind::SlicePair })
        .collect();
    let (_, gstats) = gemm_grouped(&probs, &cache, &SerialBackend, &wpool);
    println!(
        "per-request {:.1} ms vs grouped {:.1} ms ({:.2}x); decompositions {} vs {} (hits {})",
        st_seq.median_s * 1e3,
        st_grp.median_s * 1e3,
        st_seq.median_s / st_grp.median_s,
        2 * group,
        gstats.slice_cache_misses,
        gstats.slice_cache_hits
    );
    println!("# shared A sliced once per group: the §5.4 queue amortizes decomposition");

    println!("\n# (e) int8 microkernel ablation: fused engine per kernel (n={n}, s=7, serial)");
    let asl = slice_a(&a, 7, SliceEncoding::Unsigned);
    let bsl = slice_b(&b, 7, SliceEncoding::Unsigned);
    let schedule = PairSchedule::get(7, SliceEncoding::Unsigned.radix_bits());
    let mut c_ref = Matrix::zeros(n, n);
    fused_tile_gemm_serial_on(&kernel::ScalarKernel, &asl, &bsl, &schedule, &wpool, &mut c_ref);
    let mut scalar_ms = 0.0;
    println!("{:>20} {:>12} {:>12} {:>10}", "kernel", "time_ms", "vs scalar", "bitwise");
    for kern in kernel::available_kernels() {
        let st = benchkit::bench(1, 3, || {
            let mut c = Matrix::zeros(n, n);
            fused_tile_gemm_serial_on(*kern, &asl, &bsl, &schedule, &wpool, &mut c);
            c
        });
        let mut c = Matrix::zeros(n, n);
        fused_tile_gemm_serial_on(*kern, &asl, &bsl, &schedule, &wpool, &mut c);
        let identical =
            c.data.iter().zip(&c_ref.data).all(|(x, y)| x.to_bits() == y.to_bits());
        let ms = st.median_s * 1e3;
        if kern.id() == kernel::KernelId::Scalar {
            scalar_ms = ms;
        }
        println!(
            "{:>20} {:>12.1} {:>12} {:>10}",
            kern.id().label(),
            ms,
            if scalar_ms > 0.0 { format!("{:.2}x", scalar_ms / ms) } else { "-".into() },
            identical
        );
    }
    let ws = wpool.stats();
    println!(
        "# dispatched: {} | packed panels: {} packs, {} pair reuses (reuse = s(s+1)/2 - 1 per tile)",
        kernel::active_id(SliceEncoding::Unsigned).label(),
        ws.panel_packs,
        ws.panel_reuses
    );
    println!("# ADP_FORCE_SCALAR=1 pins the scalar reference; RUSTFLAGS=-Ctarget-cpu=native helps the packers");

    println!("\n# (f) scheme families at a matched 7-slice window (n={n}, serial)");
    let ccfg = CrtConfig::for_window(7, n).expect("7-slice window fits the modulus basis");
    let native = || adp_dgemm::linalg::gemm::gemm(&a, &b);
    let spair = || fused_gemm_on(&a, &b, &cfg7, &SerialBackend, &wpool);
    let crt = || crt_gemm_on(&a, &b, &ccfg, &SerialBackend, &wpool);
    let mut arms: Vec<(&str, usize, f64, f64)> = Vec::new();
    {
        let st = benchkit::bench(1, 3, native);
        arms.push(("native-fp64", 1, st.median_s * 1e3, measure(&a, &b, &native()).max_comp_eps));
        let st = benchkit::bench(1, 3, spair);
        let eps = measure(&a, &b, &spair()).max_comp_eps;
        arms.push(("slice-pair", cfg7.pair_count(), st.median_s * 1e3, eps));
        let st = benchkit::bench(1, 3, crt);
        let eps = measure(&a, &b, &crt()).max_comp_eps;
        arms.push(("crt", ccfg.gemm_count(), st.median_s * 1e3, eps));
    }
    println!("{:>12} {:>8} {:>12} {:>12}", "scheme", "gemms", "time_ms", "maxerr_eps");
    for (name, gemms, ms, eps) in &arms {
        println!("{name:>12} {gemms:>8} {ms:>12.1} {eps:>12.3}");
    }
    println!(
        "# CRT runs {} integer GEMMs vs {} slice pairs for the same 54-bit window (linear vs quadratic)",
        ccfg.gemm_count(),
        cfg7.pair_count()
    );

    println!("\n# (g) accuracy tiers: pair-truncated schedules (n={n}, s=7, serial fused)");
    println!(
        "{:>12} {:>8} {:>8} {:>12} {:>12} {:>14}",
        "tier", "pairs", "skipped", "time_ms", "vs full", "maxerr_eps"
    );
    let mut guaranteed_ms = f64::NAN;
    for tier in AccuracyTier::ALL {
        let tcfg = OzakiConfig::new(7).with_tier(tier);
        let st = benchkit::bench(1, 3, || fused_gemm_on(&a, &b, &tcfg, &SerialBackend, &wpool));
        let ms = st.median_s * 1e3;
        if tier == AccuracyTier::GuaranteedFp64 {
            guaranteed_ms = ms;
        }
        let eps = measure(&a, &b, &fused_gemm_on(&a, &b, &tcfg, &SerialBackend, &wpool))
            .max_comp_eps;
        println!(
            "{:>12} {:>8} {:>8} {:>12.1} {:>12} {:>14.3}",
            tier.label(),
            tcfg.pair_count(),
            tcfg.skipped_pair_count(),
            ms,
            format!("{:.2}x", guaranteed_ms / ms),
            eps
        );
    }
    println!("# fast tiers keep the largest-weight pair levels only: quadratically fewer GEMMs");

    // Machine-readable copy for CI artifacts. The repo is dependency-free,
    // so the JSON is assembled by hand.
    let mut json = String::from("{\n");
    json.push_str(&format!("  \"n\": {n},\n  \"window_slices\": 7,\n  \"arms\": [\n"));
    for (i, (name, gemms, ms, eps)) in arms.iter().enumerate() {
        let sep = if i + 1 < arms.len() { "," } else { "" };
        json.push_str(&format!(
            "    {{\"scheme\": \"{name}\", \"integer_gemms\": {gemms}, \
             \"time_ms\": {ms:.3}, \"maxerr_eps\": {eps:.3}}}{sep}\n"
        ));
    }
    json.push_str("  ]\n}\n");
    std::fs::write("BENCH_ablation.json", &json).expect("write BENCH_ablation.json");
    println!("# wrote BENCH_ablation.json");
}
