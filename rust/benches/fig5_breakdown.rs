//! Fig 5 regenerator: breakdown of emulated-DGEMM run time when ADP is
//! forced to 55-bit-class precision (7 slices in our unsigned encoding),
//! the worst case for ADP's relative overhead (§7.1).
//!
//! Two sections:
//!   (a) *measured* on this CPU substrate — slicing / INT8 pair GEMMs /
//!       recomposition from the native pipeline's instrumentation, plus
//!       the ADP guardrail time (scan + coarsened ESC + heuristic);
//!   (b) *modeled* for the paper's GPU platforms via `perfmodel`
//!       (DESIGN.md §Substitutions).
//!
//! Claim under test: ADP share < 10% of total run time in both views.

use adp_dgemm::backend::{ComputeBackend, ParallelBackend, SerialBackend};
use adp_dgemm::coordinator::scan::scan_pair;
use adp_dgemm::esc::coarse_esc_gemm;
use adp_dgemm::linalg::Matrix;
use adp_dgemm::ozaki::{emulated_gemm_with_breakdown_on, OzakiConfig};
use adp_dgemm::perfmodel::{GB200, RTX_PRO_6000};
use adp_dgemm::util::benchkit;
use adp_dgemm::util::Rng;

const S55: usize = 7; // the paper's 55-bit setting (see DESIGN.md)

fn main() {
    let full = std::env::var("FULL").is_ok();
    let sizes: Vec<usize> = if full { vec![128, 256, 512, 1024] } else { vec![128, 256, 512] };
    let parallel = ParallelBackend::new(0);

    // Backend ablation arms: the ADP guardrail share shrinks further once
    // the pair GEMMs go wide, so the serial view is the conservative one.
    for (arm, backend) in
        [("serial", &SerialBackend as &dyn ComputeBackend), ("parallel", &parallel)]
    {
        println!(
            "# Fig 5(a): measured CPU-substrate breakdown at s={S55} (forced), {arm} backend ({} threads)",
            backend.threads()
        );
        println!(
            "{:>6} {:>10} {:>10} {:>10} {:>10} {:>10} {:>8}",
            "n", "adp_ms", "slice_ms", "gemm_ms", "recomp_ms", "total_ms", "adp_%"
        );
        for &n in &sizes {
            let mut rng = Rng::new(55);
            let a = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
            let b = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);

            // guardrail pass (scan + coarse ESC), timed separately
            let g = benchkit::bench(1, 3, || {
                let f = scan_pair(&a, &b);
                let esc = coarse_esc_gemm(&a, &b, 64);
                (f, esc)
            });

            let cfg = OzakiConfig::new(S55);
            let mut bd_acc = (0.0, 0.0, 0.0);
            let iters = 3;
            for _ in 0..iters {
                let (_, bd) = emulated_gemm_with_breakdown_on(&a, &b, &cfg, backend);
                bd_acc.0 += bd.slice_s / iters as f64;
                bd_acc.1 += bd.gemm_s / iters as f64;
                bd_acc.2 += bd.recompose_s / iters as f64;
            }
            let adp = g.median_s;
            let total = adp + bd_acc.0 + bd_acc.1 + bd_acc.2;
            println!(
                "{n:>6} {:>10.3} {:>10.3} {:>10.3} {:>10.3} {:>10.3} {:>8.2}",
                adp * 1e3,
                bd_acc.0 * 1e3,
                bd_acc.1 * 1e3,
                bd_acc.2 * 1e3,
                total * 1e3,
                100.0 * adp / total
            );
        }
    }

    println!("\n# Fig 5(b): modeled GPU breakdown at s={S55} (forced), percentages of total");
    println!(
        "{:>24} {:>6} {:>9} {:>9} {:>9} {:>9} {:>10}",
        "platform", "n", "adp_%", "slice_%", "gemm_%", "recomp_%", "total_ms"
    );
    for p in [GB200, RTX_PRO_6000] {
        for n in [1024usize, 2048, 4096, 8192] {
            let bd = p.emulated_breakdown(n, n, n, S55, true);
            let t = bd.total();
            println!(
                "{:>24} {n:>6} {:>9.2} {:>9.2} {:>9.2} {:>9.2} {:>10.3}",
                p.name,
                100.0 * bd.scan_esc_s / t,
                100.0 * bd.slice_s / t,
                100.0 * bd.int_gemm_s / t,
                100.0 * bd.recompose_s / t,
                t * 1e3
            );
            // The paper's <10% claim holds at benchmark sizes; below the
            // crossover the fixed pre-pass dominates — which is exactly
            // why the §5.3 heuristic sends small problems to native FP64.
            if n >= 2048 {
                assert!(bd.adp_overhead_fraction() < 0.10, "ADP overhead must stay <10%");
            }
        }
    }
    println!("# paper claim reproduced: ADP (scan+ESC+heuristic) < 10% of run time at n >= 2048");
}
