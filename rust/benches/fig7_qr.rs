//! Fig 7 regenerator: application-level ADP in blocked Householder QR
//! (the cusolverDnGeqrf analogue), trailing updates through emulated GEMM.
//!
//! Left panel: end-to-end speedup relative to native FP64 for (i) fixed
//! 55-bit emulation, no ADP (ceiling) and (ii) ADP dynamic — projected for
//! the RTX Pro 6000 via the cost model applied to the *actual* GEMM call
//! trace of the factorization (shape + chosen slice count per call), with
//! the measured factorization residual. Right panel: the distribution of
//! slice counts ADP chose across all GEMMs.
//!
//! Expected shape: ADP speedup up to ~3.7x, slightly below the fixed
//! ceiling; residuals at FP64 level for ADP at every size while fixed
//! 55-bit drifts; histogram concentrated at 8-9 slices.

use adp_dgemm::coordinator::heuristic::{HeuristicInput, SelectionHeuristic};
use adp_dgemm::coordinator::{AdpConfig, AdpEngine};
use adp_dgemm::linalg::{blocked_qr, GemmBackend, Matrix, NativeGemm};
use adp_dgemm::ozaki::{emulated_gemm, OzakiConfig};
use adp_dgemm::perfmodel::{Platform, RTX_PRO_6000};
use adp_dgemm::util::Rng;

const S55: usize = 7;

/// Records the GEMM call trace so the GPU model can price the whole
/// factorization per backend.
struct Traced<B> {
    inner: B,
    calls: Vec<(usize, usize, usize, Option<usize>)>, // m,k,n,slices
}

impl GemmBackend for Traced<NativeGemm> {
    fn gemm(&mut self, a: &Matrix, b: &Matrix) -> Matrix {
        self.calls.push((a.rows, a.cols, b.cols, None));
        self.inner.gemm(a, b)
    }
}

struct Fixed55;
impl GemmBackend for Traced<Fixed55> {
    fn gemm(&mut self, a: &Matrix, b: &Matrix) -> Matrix {
        self.calls.push((a.rows, a.cols, b.cols, Some(S55)));
        emulated_gemm(a, b, &OzakiConfig::new(S55))
    }
}

struct AdpTrace {
    engine: AdpEngine,
    calls: Vec<(usize, usize, usize, Option<usize>)>,
}

impl GemmBackend for AdpTrace {
    fn gemm(&mut self, a: &Matrix, b: &Matrix) -> Matrix {
        let (c, out) = self.engine.gemm(a, b);
        self.calls.push((a.rows, a.cols, b.cols, out.decision.slices()));
        c
    }
}

/// A "GPU deployment" heuristic: emulate when the platform model says so.
struct RtxHeuristic;
impl SelectionHeuristic for RtxHeuristic {
    fn emulate(&self, inp: &HeuristicInput) -> bool {
        RTX_PRO_6000.emulation_profitable(inp.m, inp.k, inp.n, inp.slices)
    }
    fn name(&self) -> &'static str {
        "rtx-model"
    }
}

fn price(p: &Platform, calls: &[(usize, usize, usize, Option<usize>)]) -> f64 {
    calls
        .iter()
        .map(|&(m, k, n, s)| match s {
            None => p.dgemm_time(m, k, n),
            Some(s) => p.emulated_time(m, k, n, s, true),
        })
        .sum()
}

fn main() {
    let full = std::env::var("FULL").is_ok();
    // trailing updates only become GPU-profitable (on the RTX model) once
    // the trailing matrix is ~1k wide — same effect as the paper's Fig 7,
    // where small problems fall back to native.
    let sizes: Vec<usize> = if full { vec![512, 1024, 2048] } else { vec![256, 512, 1024] };
    let panel = 64;
    let p = RTX_PRO_6000;

    println!("# Fig 7 (left): QR end-to-end speedup vs native FP64 (RTX Pro 6000 model)");
    println!(
        "{:>6} {:>12} {:>12} {:>14} {:>14} {:>14}",
        "n", "fixed55_x", "adp_x", "resid_native", "resid_fixed", "resid_adp"
    );
    let mut histo_total: Vec<(usize, u64)> = vec![];
    for &n in &sizes {
        let mut rng = Rng::new(777 + n as u64);
        let a = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);

        let mut nat = Traced { inner: NativeGemm, calls: vec![] };
        let (qr_n, _) = blocked_qr(&a, panel, &mut nat);

        let mut fix = Traced { inner: Fixed55, calls: vec![] };
        let (qr_f, _) = blocked_qr(&a, panel, &mut fix);

        let mut adp = AdpTrace {
            engine: AdpEngine::new(
                AdpConfig::fp64().with_heuristic(Box::new(RtxHeuristic)).with_runtime(None),
            ),
            calls: vec![],
        };
        let (qr_a, _) = blocked_qr(&a, panel, &mut adp);

        // price the *whole* trailing-update stream on the GPU model; the
        // panel factorization is identical across backends and excluded,
        // matching the paper's "trailing updates redirected" setup.
        let t_nat = price(&p, &nat.calls);
        let t_fix = price(&p, &fix.calls);
        let t_adp = price(&p, &adp.calls);
        println!(
            "{n:>6} {:>12.2} {:>12.2} {:>14.3e} {:>14.3e} {:>14.3e}",
            t_nat / t_fix,
            t_nat / t_adp,
            qr_n.residual(&a),
            qr_f.residual(&a),
            qr_a.residual(&a)
        );
        for (s, c) in adp.engine.metrics.snapshot().slice_histogram {
            match histo_total.iter_mut().find(|(hs, _)| *hs == s) {
                Some((_, hc)) => *hc += c,
                None => histo_total.push((s, c)),
            }
        }
    }
    histo_total.sort();
    println!("\n# Fig 7 (right): ADP slice-count distribution across all trailing GEMMs");
    let total: u64 = histo_total.iter().map(|(_, c)| c).sum::<u64>().max(1);
    for (s, c) in &histo_total {
        println!(
            "  slices {:>2}: {:>4} calls ({:>5.1}%)  {}",
            s,
            c,
            100.0 * *c as f64 / total as f64,
            "#".repeat((40 * c / total) as usize)
        );
    }
    let fallbacks: u64 = 0; // heuristic fallbacks appear as None-slice calls
    let native_calls = histo_total.is_empty();
    println!("# small problems fall back to native (heuristic): tracked as fp64-priced calls");
    let _ = (fallbacks, native_calls);
}
