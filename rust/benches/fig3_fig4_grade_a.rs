//! Fig 3 + Fig 4 regenerator: maximum and average componentwise relative
//! error for uniform(0,1) square matrices, five seeds per size, comparing
//! emulated DGEMM (ADP config, <=200 mantissa bits, no fallback expected),
//! native FP64 GEMM, and floating-point Strassen.
//!
//! Paper shape: emulated stays below the Grade A linear slope with
//! ~sqrt(n) average growth (Fig 4); Strassen's componentwise error grows
//! markedly faster (exceeds the Grade A slope); native FP64 is in between.
//! Default sizes 64..512; FULL=1 adds 1024 (paper goes to 4096).

use adp_dgemm::coordinator::heuristic::AlwaysEmulate;
use adp_dgemm::coordinator::{AdpConfig, AdpEngine};
use adp_dgemm::grading::grade::{growth_exponent, measure};
use adp_dgemm::linalg::{gemm, strassen, Matrix};
use adp_dgemm::util::Rng;

fn main() {
    let full = std::env::var("FULL").is_ok();
    let mut sizes = vec![64usize, 128, 256, 512];
    if full {
        sizes.push(1024);
    }
    let seeds = [1u64, 2, 3, 4, 5];

    let engine = AdpEngine::new(
        AdpConfig::fp64().with_heuristic(Box::new(AlwaysEmulate)).with_runtime(None),
    );

    println!("# Fig 3 (max) + Fig 4 (avg) componentwise relative error, eps units");
    println!(
        "{:>6} {:>10} {:>10} {:>10}   {:>10} {:>10} {:>10}",
        "n", "emu_max", "nat_max", "str_max", "emu_avg", "nat_avg", "str_avg"
    );
    let (mut emu_max, mut nat_max, mut str_max) = (vec![], vec![], vec![]);
    let (mut emu_avg, mut nat_avg, mut str_avg) = (vec![], vec![], vec![]);
    for &n in &sizes {
        let (mut em, mut nm, mut sm) = (0.0f64, 0.0f64, 0.0f64);
        let (mut ea, mut na, mut sa) = (0.0f64, 0.0f64, 0.0f64);
        for &seed in &seeds {
            let mut rng = Rng::new(seed * 1000 + n as u64);
            let a = Matrix::uniform(n, n, 0.0, 1.0, &mut rng);
            let b = Matrix::uniform(n, n, 0.0, 1.0, &mut rng);
            let (c_emu, out) = engine.gemm(&a, &b);
            assert!(out.decision.is_emulated(), "fig3 must never fall back: {:?}", out.decision);
            let re = measure(&a, &b, &c_emu);
            let rn = measure(&a, &b, &gemm(&a, &b));
            let rs = measure(&a, &b, &strassen(&a, &b));
            em = em.max(re.max_comp_eps);
            nm = nm.max(rn.max_comp_eps);
            sm = sm.max(rs.max_comp_eps);
            ea += re.avg_comp_eps / seeds.len() as f64;
            na += rn.avg_comp_eps / seeds.len() as f64;
            sa += rs.avg_comp_eps / seeds.len() as f64;
        }
        println!(
            "{n:>6} {em:>10.3} {nm:>10.3} {sm:>10.3}   {ea:>10.4} {na:>10.4} {sa:>10.4}"
        );
        emu_max.push(em);
        nat_max.push(nm);
        str_max.push(sm);
        emu_avg.push(ea);
        nat_avg.push(na);
        str_avg.push(sa);
    }
    println!("# growth exponents (err ~ n^p):");
    println!(
        "#   max: emulated p={:.2}, native p={:.2}, strassen p={:.2}  (grade A needs p <= ~1; strassen largest)",
        growth_exponent(&sizes, &emu_max),
        growth_exponent(&sizes, &nat_max),
        growth_exponent(&sizes, &str_max)
    );
    println!(
        "#   avg: emulated p={:.2} (theory: 0.5), native p={:.2}, strassen p={:.2}",
        growth_exponent(&sizes, &emu_avg),
        growth_exponent(&sizes, &nat_avg),
        growth_exponent(&sizes, &str_avg)
    );
}
