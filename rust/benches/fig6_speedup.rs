//! Fig 6 regenerator: end-to-end emulated-DGEMM speedup over native FP64
//! DGEMM on GB200 (top) and RTX Pro 6000 Blackwell (bottom), at the 55-bit
//! setting, without ADP (left: performance ceiling, no safety) and with
//! ADP forced to 55 bits (right: guardrails on).
//!
//! The curves come from the calibrated `perfmodel` (no GPU in this
//! environment; DESIGN.md §Substitutions). A measured-CPU column is
//! included for transparency: on a CPU there is no 8-bit tensor-core
//! advantage, so emulation is *slower* than native here — the model is
//! what carries the paper's platform claims, the CPU numbers validate the
//! op-mix accounting feeding it.
//!
//! Expected shape: speedups grow with n and saturate near 2.3x (GB200) /
//! 13.2x (RTX Pro 6000); ADP costs only a few percent of the ceiling.

use adp_dgemm::linalg::{gemm, Matrix};
use adp_dgemm::ozaki::{emulated_gemm, OzakiConfig};
use adp_dgemm::perfmodel::{GB200, RTX_PRO_6000};
use adp_dgemm::util::{benchkit, Rng};

const S55: usize = 7;

fn main() {
    let full = std::env::var("FULL").is_ok();

    println!("# Fig 6: modeled speedup vs native DGEMM at 55-bit setting");
    println!(
        "{:>24} {:>6} {:>12} {:>12} {:>10}",
        "platform", "n", "no_adp_x", "with_adp_x", "adp_cost_%"
    );
    for p in [GB200, RTX_PRO_6000] {
        for n in [256usize, 512, 1024, 2048, 4096, 8192, 16384] {
            let ceiling = p.speedup(n, S55, false);
            let with = p.speedup(n, S55, true);
            println!(
                "{:>24} {n:>6} {ceiling:>12.2} {with:>12.2} {:>10.2}",
                p.name,
                100.0 * (1.0 - with / ceiling)
            );
        }
        let peak = p.speedup(16384, S55, true);
        println!("#   {} peak (ADP on): {peak:.2}x", p.name);
    }

    println!("\n# measured CPU substrate (sanity: op-mix accounting, not a GPU claim)");
    println!("{:>6} {:>12} {:>12} {:>10}", "n", "fp64_ms", "emul_ms", "ratio");
    let sizes: Vec<usize> = if full { vec![128, 256, 512] } else { vec![128, 256] };
    for n in sizes {
        let mut rng = Rng::new(66);
        let a = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
        let b = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
        let t_nat = benchkit::bench(1, 3, || gemm(&a, &b));
        let cfg = OzakiConfig::new(S55);
        let t_emu = benchkit::bench(1, 3, || emulated_gemm(&a, &b, &cfg));
        println!(
            "{n:>6} {:>12.2} {:>12.2} {:>10.2}",
            t_nat.median_s * 1e3,
            t_emu.median_s * 1e3,
            t_nat.median_s / t_emu.median_s
        );
    }
}
