//! Hot-path micro-benchmarks feeding EXPERIMENTS.md §Perf: per-layer
//! throughput of every stage of the emulated-DGEMM pipeline plus the
//! native baseline and the AOT artifact path.
//!
//!   slice_pair_gemm  — i8 x i8 -> i32 MACs/s (the Tensor-Core stand-in)
//!   slice_a          — FP64 -> INT8 decomposition bandwidth
//!   fp64 gemm        — the baseline FLOP/s (denominator of every speedup)
//!   recompose        — level accumulation + descaling bandwidth
//!   coarse ESC       — guardrail pass throughput
//!   serial/parallel  — backend ablation of the emulated + FP64 hot paths
//!   accuracy tiers   — pair-truncated schedules (emits BENCH_tiers.json)
//!   artifact gemm    — PJRT end-to-end (when artifacts/ exists)

use std::path::Path;

use adp_dgemm::backend::{ComputeBackend, ParallelBackend, SerialBackend, WorkspacePool};
use adp_dgemm::esc::coarse_esc_gemm;
use adp_dgemm::linalg::{gemm, Matrix};
use adp_dgemm::ozaki::gemm::slice_pair_gemm_tile_on;
use adp_dgemm::ozaki::kernel::{self, ScalarKernel};
use adp_dgemm::ozaki::{
    emulated_gemm_on, emulated_gemm_with_breakdown, fused_gemm_on, gemm_grouped, slice_a,
    slice_b, slice_pair_gemm, tune, AccuracyTier, GroupedProblem, OzakiConfig, SchemeKind,
    SliceCache, SliceEncoding,
};
use adp_dgemm::coordinator::scan::{scan_matrix, scan_pair};
use adp_dgemm::runtime::RuntimeHandle;
use adp_dgemm::util::{benchkit, faultinject, Rng};

fn main() {
    let n = std::env::var("N").ok().and_then(|s| s.parse().ok()).unwrap_or(512usize);
    let s = 7usize;
    let mut rng = Rng::new(99);
    let a = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
    let b = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);

    println!("# perf_hotpath n={n} s={s} (stage benches single-thread; backend ablation below)");

    // Machine-readable twin of the report lines: per-arm ns/flop (or
    // ns/MAC for the integer-kernel arms), written to BENCH_hotpath.json
    // at the end so CI can archive and diff the numbers.
    let mut json = benchkit::JsonReport::new();
    let flops = 2.0 * (n * n * n) as f64;
    let macs = (n * n * n) as f64;

    // --- L3 native fp64 GEMM baseline -------------------------------
    let st_fp64 = benchkit::bench_budget(1.0, || gemm(&a, &b));
    benchkit::report(
        "fp64_gemm",
        st_fp64,
        &[("GFLOP/s", format!("{:.2}", st_fp64.per_sec(2.0 * (n * n * n) as f64) / 1e9))],
    );
    json.arm("fp64_gemm", st_fp64, flops, &[("unit", "flop".to_string())]);

    // --- slicing ------------------------------------------------------
    let st = benchkit::bench_budget(1.0, || slice_a(&a, s, SliceEncoding::Unsigned));
    benchkit::report(
        "slice_a(s=7)",
        st,
        &[
            ("Melem/s", format!("{:.1}", st.per_sec((n * n) as f64) / 1e6)),
            ("GB/s out", format!("{:.2}", st.per_sec((n * n * s) as f64) / 1e9)),
        ],
    );

    // --- i8 pair GEMM --------------------------------------------------
    let asl = slice_a(&a, s, SliceEncoding::Unsigned);
    let bsl = slice_b(&b, s, SliceEncoding::Unsigned);
    let mut out = vec![0i64; n * n];
    let st = benchkit::bench_budget(1.5, || {
        out.fill(0);
        slice_pair_gemm(&asl, 0, &bsl, 0, &mut out);
    });
    benchkit::report(
        "slice_pair_gemm",
        st,
        &[("GMAC/s", format!("{:.2}", st.per_sec((n * n * n) as f64) / 1e9))],
    );

    // --- int8 microkernel ablation: scalar vs dispatched SIMD ----------
    // (a) single pair per kernel (pack cost included — the standalone
    //     entry-point cost model); (b) the fused-style sweep: pack once,
    //     run all s(s+1)/2 pairs off the packed panels (amortized).
    println!(
        "# kernel dispatch: unsigned -> {}, signed -> {} (ADP_FORCE_SCALAR=1 pins scalar)",
        kernel::active_id(SliceEncoding::Unsigned).label(),
        kernel::active_id(SliceEncoding::Signed).label()
    );
    for kern in kernel::available_kernels() {
        let st = benchkit::bench_budget(1.0, || {
            out.fill(0);
            slice_pair_gemm_tile_on(*kern, &asl, 1, &bsl, 0, 0, n, 0, n, &mut out);
        });
        benchkit::report(
            &format!("pair_gemm[{}]", kern.id().label()),
            st,
            &[("GMAC/s", format!("{:.2}", st.per_sec((n * n * n) as f64) / 1e9))],
        );
        json.arm(
            &format!("pair_gemm[{}]", kern.id().label()),
            st,
            macs,
            &[("unit", "mac".to_string()), ("kernel", kern.id().label().to_string())],
        );
    }
    {
        // packed vs unpacked pair sweep: all pairs of the s=7 schedule.
        let pairs: Vec<(usize, usize)> =
            (0..s).flat_map(|t| (0..s - t).map(move |u| (t, u))).collect();
        let npairs = pairs.len();
        let st_unp = benchkit::bench_budget(1.5, || {
            out.fill(0);
            for &(t, u) in &pairs {
                slice_pair_gemm_tile_on(&ScalarKernel, &asl, t, &bsl, u, 0, n, 0, n, &mut out);
            }
        });
        benchkit::report(
            "pair_sweep[scalar unpacked]",
            st_unp,
            &[("GMAC/s", format!("{:.2}", st_unp.per_sec((npairs * n * n * n) as f64) / 1e9))],
        );
        json.arm(
            "pair_sweep[scalar unpacked]",
            st_unp,
            (npairs * n * n * n) as f64,
            &[("unit", "mac".to_string()), ("kernel", "scalar".to_string())],
        );
        for kern in kernel::available_kernels() {
            let mut apack = vec![0u8; s * kern.a_slice_bytes(n, n)];
            let mut bpack = vec![0u8; s * kern.b_slice_bytes(n, n)];
            let (ab, bb) = (kern.a_slice_bytes(n, n), kern.b_slice_bytes(n, n));
            let st = benchkit::bench_budget(1.5, || {
                out.fill(0);
                for t in 0..s {
                    kern.pack_a_slice(&asl, t, 0, n, &mut apack[t * ab..(t + 1) * ab]);
                    kern.pack_b_slice(&bsl, t, 0, n, &mut bpack[t * bb..(t + 1) * bb]);
                }
                for &(t, u) in &pairs {
                    let ap = &apack[t * ab..(t + 1) * ab];
                    let bp = &bpack[u * bb..(u + 1) * bb];
                    kern.pair_tile(ap, bp, n, n, n, &mut out);
                }
            });
            benchkit::report(
                &format!("pair_sweep[{} packed]", kern.id().label()),
                st,
                &[
                    ("GMAC/s", format!("{:.2}", st.per_sec((npairs * n * n * n) as f64) / 1e9)),
                    ("vs scalar unpacked", format!("{:.2}x", st_unp.median_s / st.median_s)),
                ],
            );
            json.arm(
                &format!("pair_sweep[{} packed]", kern.id().label()),
                st,
                (npairs * n * n * n) as f64,
                &[("unit", "mac".to_string()), ("kernel", kern.id().label().to_string())],
            );
        }
    }

    // --- full emulated pipeline with breakdown -------------------------
    let cfg = OzakiConfig::new(s);
    let (_, bd) = emulated_gemm_with_breakdown(&a, &b, &cfg);
    println!(
        "emulated_gemm(s=7): slice {:.1} ms, pair-gemms {:.1} ms ({} pairs, {:.2} GMAC/s), recompose {:.1} ms",
        bd.slice_s * 1e3,
        bd.gemm_s * 1e3,
        bd.pairs,
        (bd.pairs * n * n * n) as f64 / bd.gemm_s / 1e9,
        bd.recompose_s * 1e3
    );

    // --- backend ablation: serial vs parallel ---------------------------
    let parallel = ParallelBackend::new(0);
    let threads = parallel.threads();
    let st_ser = benchkit::bench_budget(2.0, || emulated_gemm_on(&a, &b, &cfg, &SerialBackend));
    benchkit::report("emulated_gemm(serial)", st_ser, &[]);
    json.arm(
        "emulated_gemm(serial)",
        st_ser,
        flops,
        &[("unit", "flop".to_string()), ("engine", "level-major".to_string())],
    );
    let st_par = benchkit::bench_budget(2.0, || emulated_gemm_on(&a, &b, &cfg, &parallel));
    benchkit::report("emulated_gemm(parallel)", st_par, &[("threads", threads.to_string())]);
    json.arm(
        "emulated_gemm(parallel)",
        st_par,
        flops,
        &[
            ("unit", "flop".to_string()),
            ("engine", "level-major".to_string()),
            ("threads", threads.to_string()),
        ],
    );
    println!(
        "emulated_gemm backend speedup: {:.2}x over serial (n={n}, s={s}, {threads} threads)",
        st_ser.median_s / st_par.median_s
    );

    // --- fused tile engine vs level-major, both backends ----------------
    let wpool = WorkspacePool::new();
    let dispatched = kernel::active_id(SliceEncoding::Unsigned);
    let st_fser = benchkit::bench_budget(2.0, || fused_gemm_on(&a, &b, &cfg, &SerialBackend, &wpool));
    benchkit::report(
        "fused_gemm(serial)",
        st_fser,
        &[("vs level-major", format!("{:.2}x", st_ser.median_s / st_fser.median_s))],
    );
    json.arm(
        "fused_gemm(serial)",
        st_fser,
        flops,
        &[
            ("unit", "flop".to_string()),
            ("engine", "fused".to_string()),
            ("kernel", dispatched.label().to_string()),
            ("tile", tune::tile_shape_for(dispatched, n, n).label()),
        ],
    );
    let st_fus_par = benchkit::bench_budget(2.0, || fused_gemm_on(&a, &b, &cfg, &parallel, &wpool));
    benchkit::report(
        "fused_gemm(parallel)",
        st_fus_par,
        &[
            ("threads", threads.to_string()),
            ("vs level-major", format!("{:.2}x", st_par.median_s / st_fus_par.median_s)),
        ],
    );
    json.arm(
        "fused_gemm(parallel)",
        st_fus_par,
        flops,
        &[
            ("unit", "flop".to_string()),
            ("engine", "fused".to_string()),
            ("kernel", dispatched.label().to_string()),
            ("tile", tune::tile_shape_for(dispatched, n, n).label()),
            ("threads", threads.to_string()),
        ],
    );
    let ws = wpool.stats();
    println!(
        "fused engine: {} tiles, {} checkouts, {} fresh allocations (steady state reuses)",
        ws.fused_tiles, ws.checkouts, ws.fresh_allocs
    );

    // --- accuracy tiers: pair-truncated schedules -----------------------
    // One arm per tier on the serial fused engine; the fast tiers drop the
    // lowest-weight pair levels, so time should fall roughly with the pair
    // count. Written to BENCH_tiers.json so CI archives per-tier ns/flop.
    {
        let mut tjson = benchkit::JsonReport::new();
        let mut guaranteed_s = f64::NAN;
        for tier in AccuracyTier::ALL {
            let cfg_t = OzakiConfig::new(s).with_tier(tier);
            let st =
                benchkit::bench_budget(1.0, || fused_gemm_on(&a, &b, &cfg_t, &SerialBackend, &wpool));
            if tier == AccuracyTier::GuaranteedFp64 {
                guaranteed_s = st.median_s;
            }
            let extra = [
                ("unit", "flop".to_string()),
                ("engine", "fused".to_string()),
                ("tier", tier.label().to_string()),
                ("pairs", cfg_t.pair_count().to_string()),
                ("pairs_skipped", cfg_t.skipped_pair_count().to_string()),
                ("vs guaranteed", format!("{:.2}x", guaranteed_s / st.median_s)),
            ];
            benchkit::report(&format!("fused_tier[{}]", tier.label()), st, &extra);
            tjson.arm(&format!("fused_tier[{}]", tier.label()), st, flops, &extra);
        }
        let tctx = [
            ("n", n.to_string()),
            ("s", s.to_string()),
            ("kernel", dispatched.label().to_string()),
        ];
        match tjson.write("BENCH_tiers.json", "perf_hotpath_tiers", &tctx) {
            Ok(()) => println!("# wrote BENCH_tiers.json ({} arms)", tjson.len()),
            Err(e) => eprintln!("# BENCH_tiers.json not written: {e}"),
        }
    }

    // --- tile-geometry ablation: every candidate shape, tuned marked ----
    // The autotuner's acceptance bar lives here: the `tuned=true` arm
    // must not be slower than the `64x64` baseline arm (same serial
    // fused engine, same dispatched kernel, only the geometry pinned).
    {
        let tuned = tune::tile_shape_for(dispatched, n, n);
        let spool = WorkspacePool::new();
        let mut baseline_s = f64::NAN;
        for shape in tune::CANDIDATES {
            tune::force_shape(Some(shape));
            let st =
                benchkit::bench_budget(1.0, || fused_gemm_on(&a, &b, &cfg, &SerialBackend, &spool));
            if shape == tune::TileShape::BASELINE {
                baseline_s = st.median_s;
            }
            let extra = [
                ("unit", "flop".to_string()),
                ("engine", "fused".to_string()),
                ("kernel", dispatched.label().to_string()),
                ("tile", shape.label()),
                ("tuned", (shape == tuned).to_string()),
                ("vs baseline", format!("{:.2}x", baseline_s / st.median_s)),
            ];
            benchkit::report(&format!("fused_tile[{}]", shape.label()), st, &extra);
            json.arm(&format!("fused_tile[{}]", shape.label()), st, flops, &extra);
        }
        tune::force_shape(None);
        println!("# autotuned tile for {} at n={n}: {}", dispatched.label(), tuned.label());
    }
    let st_fpar = benchkit::bench_budget(1.0, || parallel.fp64_gemm(&a, &b));
    benchkit::report(
        "fp64_gemm(parallel)",
        st_fpar,
        &[
            ("threads", threads.to_string()),
            // against the fp64_gemm baseline measured at the top
            ("speedup", format!("{:.2}x", st_fp64.median_s / st_fpar.median_s)),
            ("GFLOP/s", format!("{:.2}", st_fpar.per_sec(2.0 * (n * n * n) as f64) / 1e9)),
        ],
    );

    // --- grouped pipeline: slice-cache amortization ---------------------
    {
        let group = 8usize;
        let bs: Vec<Matrix> =
            (0..group).map(|_| Matrix::uniform(n, n, -1.0, 1.0, &mut rng)).collect();
        let st_seq = benchkit::bench_budget(2.0, || {
            for b in &bs {
                std::hint::black_box(emulated_gemm_on(&a, b, &cfg, &SerialBackend));
            }
        });
        benchkit::report("emulated_group(per-request)", st_seq, &[("reqs", group.to_string())]);
        let gpool = WorkspacePool::new();
        let st_grp = benchkit::bench_budget(2.0, || {
            // cold cache per iteration: amortization within the group only
            let cache = SliceCache::new(2 * group + 2);
            let probs: Vec<GroupedProblem<'_>> = bs
                .iter()
                .map(|b| GroupedProblem { a: &a, b, cfg, scheme: SchemeKind::SlicePair })
                .collect();
            std::hint::black_box(gemm_grouped(&probs, &cache, &SerialBackend, &gpool))
        });
        benchkit::report(
            "emulated_group(grouped)",
            st_grp,
            &[
                ("reqs", group.to_string()),
                ("speedup", format!("{:.2}x", st_seq.median_s / st_grp.median_s)),
            ],
        );
    }

    // --- guardrails -----------------------------------------------------
    let st = benchkit::bench_budget(0.5, || coarse_esc_gemm(&a, &b, 64));
    benchkit::report(
        "coarse_esc(b=64)",
        st,
        &[("Mdot/s", format!("{:.1}", st.per_sec((n * n) as f64) / 1e6))],
    );

    // --- safety scan: clean sweep vs adversarial early exit --------------
    {
        let elems = (n * n) as f64;
        let st_clean = benchkit::bench_budget(0.5, || scan_matrix(&a));
        benchkit::report(
            "scan_clean",
            st_clean,
            &[("Melem/s", format!("{:.1}", st_clean.per_sec(elems) / 1e6))],
        );
        json.arm("scan_clean", st_clean, elems, &[("unit", "elem".to_string())]);
        // NaN/Inf/subnormal in the first elements: the scan saturates
        // immediately, so the verdict is O(1) instead of a full O(n^2)
        // sweep — the worst adversarial input becomes the cheapest.
        let mut adv = a.clone();
        adv.data[0] = f64::NAN;
        adv.data[1] = f64::INFINITY;
        adv.data[2] = f64::from_bits(1);
        let st_adv = benchkit::bench_budget(0.5, || scan_matrix(&adv));
        benchkit::report(
            "scan_adversarial",
            st_adv,
            &[("vs clean", format!("{:.0}x", st_clean.median_s / st_adv.median_s))],
        );
        json.arm("scan_adversarial", st_adv, elems, &[("unit", "elem".to_string())]);
        // A NaN in A forces the fallback regardless of B, so the pair
        // scan skips B's O(k*n) sweep entirely.
        let st_pair = benchkit::bench_budget(0.5, || scan_pair(&adv, &b));
        benchkit::report(
            "scan_pair[nan-in-a]",
            st_pair,
            &[("vs clean matrix", format!("{:.0}x", st_clean.median_s / st_pair.median_s))],
        );
        json.arm("scan_pair[nan-in-a]", st_pair, 2.0 * elems, &[("unit", "elem".to_string())]);
    }

    // --- disarmed fault sites: hot-path cost is one relaxed load ---------
    {
        let checks = 4096u32;
        let st = benchkit::bench_budget(0.25, || {
            let mut hits = 0u32;
            for _ in 0..checks {
                hits += u32::from(faultinject::fires(faultinject::site::WORKER_HANG));
            }
            assert_eq!(hits, 0, "faults must stay disarmed in benches");
        });
        benchkit::report(
            "faultinject_disarmed",
            st,
            &[("ns/site-check", format!("{:.2}", st.median_s * 1e9 / checks as f64))],
        );
        json.arm("faultinject_disarmed", st, checks as f64, &[("unit", "check".to_string())]);
    }

    // --- artifact path ---------------------------------------------------
    if let Some(rt) = RuntimeHandle::try_load(Path::new("artifacts")) {
        if let Some(na) = rt.catalog().fitting_size(64, 64, 64) {
            let slices = rt.catalog().slice_count_at_least(na, 7).unwrap_or(7);
            let mut rng = Rng::new(7);
            let aa = Matrix::uniform(na, na, -1.0, 1.0, &mut rng);
            let bb = Matrix::uniform(na, na, -1.0, 1.0, &mut rng);
            let _ = rt.emulated_gemm(na, slices, &aa, &bb); // compile warmup
            let st = benchkit::bench(1, 5, || rt.emulated_gemm(na, slices, &aa, &bb).unwrap());
            benchkit::report(
                "artifact_gemm",
                st,
                &[("n", na.to_string()), ("slices", slices.to_string())],
            );
            let _ = rt.dgemm(na, &aa, &bb);
            let st = benchkit::bench(1, 5, || rt.dgemm(na, &aa, &bb).unwrap());
            benchkit::report("artifact_dgemm", st, &[("n", na.to_string())]);
        }
    } else {
        println!("artifact path: skipped (run `make artifacts`)");
    }

    // --- machine-readable artifact ---------------------------------------
    let ctx = [
        ("n", n.to_string()),
        ("s", s.to_string()),
        ("threads", threads.to_string()),
        ("dispatched_kernel", kernel::active_id(SliceEncoding::Unsigned).label().to_string()),
    ];
    match json.write("BENCH_hotpath.json", "perf_hotpath", &ctx) {
        Ok(()) => println!("# wrote BENCH_hotpath.json ({} arms)", json.len()),
        Err(e) => eprintln!("# BENCH_hotpath.json not written: {e}"),
    }
}
