//! Fig 2 regenerator: ADP-enabled DGEMM on Test 2 for increasing exponent
//! range b, at several configured mantissa-bit counts, with and without
//! guardrails + automatic fallback to native FP64.
//!
//! Paper setup: n = 1024, mantissa bits {26, 31, 37, 43, 49, 55}. Our
//! unsigned encoding yields 8s-2 effective bits, so the configured counts
//! map to slice counts s in {4..8} (labels show the effective bits; see
//! DESIGN.md on the 55-bit <-> 7-slice accounting). Default n = 256 keeps
//! the double-double reference fast; FULL=1 runs the paper's n = 1024.
//!
//! Expected shape (paper): solid (no-fallback) lines peel off to large
//! error once b exceeds each config's window; dashed (guardrails) lines
//! stay at floating-point-level error for all b.

use adp_dgemm::esc::coarse_esc_gemm;
use adp_dgemm::grading::generators::test2_workload;
use adp_dgemm::grading::test2::relative_error;
use adp_dgemm::linalg::gemm;
use adp_dgemm::ozaki::{emulated_gemm, OzakiConfig, SliceEncoding};
use adp_dgemm::util::Rng;

fn main() {
    let full = std::env::var("FULL").is_ok();
    let n = if full { 1024 } else { 256 };
    let slice_cfgs = [4usize, 5, 6, 7, 8];
    let bs: Vec<i32> = vec![0, 4, 8, 12, 16, 20, 24, 28, 32, 40, 48, 64, 96];

    println!("# Fig 2: Test 2 relative error vs exponent-range b (n={n})");
    print!("{:>4} {:>10}", "b", "esc");
    for &s in &slice_cfgs {
        print!(" {:>11}", format!("s{}({}b)", s, SliceEncoding::Unsigned.effective_bits(s)));
        print!(" {:>11}", format!("s{}+grd", s));
    }
    println!(" {:>11}", "native");

    let mut rng = Rng::new(2024);
    for &b in &bs {
        let w = test2_workload(n, b, &mut rng);
        let esc = coarse_esc_gemm(&w.a, &w.b, 64);
        let required_bits = 53 + esc + 1;
        print!("{b:>4} {esc:>10}");
        for &s in &slice_cfgs {
            // solid line: fixed slices, no guardrails
            let e_solid = relative_error(&w, &emulated_gemm(&w.a, &w.b, &OzakiConfig::new(s)));
            // dashed line: guardrails — fall back to native FP64 when the
            // ESC-required bits exceed the configured window (§5.3)
            let window = SliceEncoding::Unsigned.effective_bits(s);
            let e_dash = if required_bits > window {
                relative_error(&w, &gemm(&w.a, &w.b))
            } else {
                e_solid
            };
            print!(" {e_solid:>11.3e} {e_dash:>11.3e}");
        }
        let e_nat = relative_error(&w, &gemm(&w.a, &w.b));
        println!(" {e_nat:>11.3e}");
    }
    println!("# guardrailed variants must track the native column at every b (Aspect A1)");
}
