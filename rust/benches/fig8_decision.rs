//! Fig 8 regenerator: the ADP GEMM decision flowchart, exercised by a
//! mixed request stream and reported as a dispatch-outcome table.
//!
//! Each workload class must land on exactly the flowchart edge the paper
//! draws: NaN/Inf -> fallback; ESC too large -> fallback; unprofitable
//! (tiny) -> fallback; everything else -> emulation at the ESC-sized
//! slice count.

use adp_dgemm::coordinator::heuristic::{HeuristicInput, SelectionHeuristic};
use adp_dgemm::coordinator::{AdpConfig, AdpEngine, GemmDecision};
use adp_dgemm::grading::generators::{self, SpecialKind};
use adp_dgemm::perfmodel::RTX_PRO_6000;
use adp_dgemm::util::Rng;

struct RtxHeuristic;
impl SelectionHeuristic for RtxHeuristic {
    fn emulate(&self, inp: &HeuristicInput) -> bool {
        RTX_PRO_6000.emulation_profitable(inp.m, inp.k, inp.n, inp.slices)
    }
    fn name(&self) -> &'static str {
        "rtx6000-model"
    }
}

fn main() {
    let engine = AdpEngine::new(
        AdpConfig::fp64().with_heuristic(Box::new(RtxHeuristic)).with_runtime(None),
    );
    let mut rng = Rng::new(88);

    println!("# Fig 8: decision outcomes by workload class (RTX Pro 6000 heuristic)");
    println!("{:<34} {:>6} -> {:<22} {:>5} {:>7}", "workload", "n", "decision", "esc", "slices");

    let mut run = |label: &str, a: adp_dgemm::linalg::Matrix, b: adp_dgemm::linalg::Matrix| {
        let n = a.rows;
        let (_, out) = engine.gemm(&a, &b);
        println!(
            "{label:<34} {n:>6} -> {:<22} {:>5} {:>7}",
            out.decision.label(),
            out.esc,
            out.slices_required
        );
        out.decision
    };

    // 1. benign large: emulate
    let (a, b) = generators::uniform_pair(96, -1.0, 1.0, &mut rng);
    // pretend-large for the GB200 heuristic: scale by logical shape (the
    // heuristic sees the true shape; 96 is "tiny" for a GB200 -> fallback)
    let d = run("benign, GPU-small (96)", a, b);
    assert_eq!(d, GemmDecision::FallbackHeuristic);

    let (a, b) = generators::uniform_pair(512, -1.0, 1.0, &mut rng);
    let d = run("benign, GPU-large (512)", a, b);
    assert!(d.is_emulated(), "512 must be profitable on the RTX profile: {d:?}");

    // 2. NaN
    let (a, b) = generators::with_special_values(96, SpecialKind::Nan, &mut rng);
    assert_eq!(run("NaN-laced", a, b), GemmDecision::FallbackNan);

    // 3. Inf (both signs)
    let (a, b) = generators::with_special_values(96, SpecialKind::PosInf, &mut rng);
    assert_eq!(run("+Inf-laced", a, b), GemmDecision::FallbackInf);
    let (a, b) = generators::with_special_values(96, SpecialKind::NegInf, &mut rng);
    assert_eq!(run("-Inf-laced", a, b), GemmDecision::FallbackInf);

    // 4. negative zero: NOT special — treated as zero (§5.1)
    let (a, b) = generators::with_special_values(96, SpecialKind::NegZero, &mut rng);
    let d = run("-0.0-laced (not special)", a, b);
    assert_ne!(d, GemmDecision::FallbackNan);
    assert_ne!(d, GemmDecision::FallbackInf);

    // 5. extreme exponent span: ESC fallback
    let (mut a, mut b) = generators::uniform_pair(96, 1.0, 2.0, &mut rng);
    *a.at_mut(0, 0) = 1e300;
    *b.at_mut(0, 0) = 1e-300;
    let d = run("extreme span (1e300 x 1e-300)", a, b);
    assert!(matches!(d, GemmDecision::FallbackEsc { .. }));

    // 6. moderate span: emulation with a larger slice count
    let (mut a, mut b) = generators::uniform_pair(512, 1.0, 2.0, &mut rng);
    for l in 0..512 {
        let e = (l as i32 - 256) / 16;
        for i in 0..512 {
            *a.at_mut(i, l) *= 2f64.powi(e);
            *b.at_mut(l, i) *= 2f64.powi(-e);
        }
    }
    run("moderate span (ESC sizes slices)", a, b);

    let snap = engine.metrics.snapshot();
    println!(
        "\nsummary: {} requests | emulated {} | nan {} inf {} esc {} heuristic {}",
        snap.requests,
        snap.emulated,
        snap.fallback_nan,
        snap.fallback_inf,
        snap.fallback_esc,
        snap.fallback_heuristic
    );
    println!("# every edge of the Fig 8 flowchart exercised and asserted");
}
