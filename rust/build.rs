//! Toolchain probe for the AVX-512 kernel tier.
//!
//! The AVX-512 intrinsics (`_mm512_dpbusd_epi32`, `_mm512_madd_epi16`,
//! the 512-bit loads/stores) stabilized in Rust 1.89. The crate supports
//! older toolchains, so `ozaki::kernel::avx512` is compiled only when the
//! building rustc is new enough, signalled through the custom
//! `adp_avx512` cfg. On toolchains that understand `--check-cfg`
//! (>= 1.80) the cfg is also declared, keeping
//! `clippy -D warnings` (`unexpected_cfgs`) green whether or not the
//! module is compiled in.

use std::env;
use std::process::Command;

/// `(major, minor)` of the rustc driving this build, or `None` when the
/// version string is unparseable (pre-release channels still match the
/// leading `major.minor` digits).
fn rustc_version() -> Option<(u32, u32)> {
    let rustc = env::var("RUSTC").unwrap_or_else(|_| "rustc".to_string());
    let out = Command::new(rustc).arg("--version").output().ok()?;
    let text = String::from_utf8(out.stdout).ok()?;
    // "rustc 1.89.0 (abc 2025-07-01)" -> ["rustc", "1.89.0", ...]
    let ver = text.split_whitespace().nth(1)?;
    let mut parts = ver.split(['.', '-']);
    let major = parts.next()?.parse().ok()?;
    let minor = parts.next()?.parse().ok()?;
    Some((major, minor))
}

fn main() {
    println!("cargo:rerun-if-changed=build.rs");
    let ver = rustc_version();
    if ver.is_some_and(|(maj, min)| (maj, min) >= (1, 80)) {
        println!("cargo:rustc-check-cfg=cfg(adp_avx512)");
    }
    if ver.is_some_and(|(maj, min)| (maj, min) >= (1, 89)) {
        println!("cargo:rustc-cfg=adp_avx512");
    }
}
