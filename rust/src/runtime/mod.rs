//! PJRT execution layer: loads the AOT-compiled HLO-text artifacts produced
//! by `python/compile/aot.py` and executes them from the Rust hot path.
//!
//! The `xla` crate's client types are `Rc`-based (not `Send`), so a single
//! dedicated **runtime thread** owns the PJRT CPU client and all compiled
//! executables; the rest of the system talks to it through a cloneable
//! [`RuntimeHandle`] over channels. This mirrors the paper's GPU-resident
//! design: one device context, no per-request host/device renegotiation.

pub mod catalog;
pub mod handle;
pub mod quarantine;
pub mod tuning;

pub use catalog::{ArtifactKind, Catalog, CatalogEntry};
pub use handle::{RuntimeHandle, ScanResult};
pub use tuning::TuningEntry;
