//! Corrupt-artifact quarantine.
//!
//! Persisted catalogs (tile-tuning, cost-model) are *accelerants*, not
//! correctness inputs: a run without them is merely cold. A corrupt or
//! unreadable catalog therefore must not crash the run — but silently
//! ignoring it (the old `let Ok(..) else return` behavior) is worse: the
//! file stays corrupt forever, every future process re-reads the garbage,
//! and nobody learns it happened.
//!
//! [`quarantine_file`] implements the middle path: rename the bad file to
//! `<path>.corrupt` so the next run starts clean (and the evidence is
//! preserved for inspection), warn once per path per process, and count
//! the event so service metrics can surface it.

use std::collections::HashSet;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

use crate::util::sync as psync;

static TOTAL: AtomicU64 = AtomicU64::new(0);

fn warned() -> &'static Mutex<HashSet<PathBuf>> {
    static WARNED: OnceLock<Mutex<HashSet<PathBuf>>> = OnceLock::new();
    WARNED.get_or_init(|| Mutex::new(HashSet::new()))
}

/// Process-wide count of quarantined artifacts (service metrics gauge).
pub fn total() -> u64 {
    TOTAL.load(Ordering::Relaxed)
}

/// Quarantine a corrupt persisted artifact: rename it to `<path>.corrupt`
/// (best effort — an unreadable path may also be un-renamable), warn once
/// per path, and count the event. Returns the quarantine path when the
/// rename succeeded. `what` names the artifact kind for the warning
/// (e.g. `"tile-tuning catalog"`); `err` is the parse/io error.
pub fn quarantine_file(path: &Path, what: &str, err: &str) -> Option<PathBuf> {
    TOTAL.fetch_add(1, Ordering::Relaxed);
    let mut q = path.as_os_str().to_os_string();
    q.push(".corrupt");
    let q = PathBuf::from(q);
    let renamed = std::fs::rename(path, &q).is_ok();
    if psync::lock(warned()).insert(path.to_path_buf()) {
        if renamed {
            eprintln!(
                "[adp] corrupt {what} at {}: {err}; quarantined to {} and continuing on defaults",
                path.display(),
                q.display()
            );
        } else {
            eprintln!(
                "[adp] corrupt {what} at {}: {err}; could not quarantine, continuing on defaults",
                path.display()
            );
        }
    }
    renamed.then_some(q)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renames_and_counts() {
        let dir = std::env::temp_dir().join(format!("adp_quarantine_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("catalog.txt");
        std::fs::write(&path, "garbage").unwrap();
        let before = total();
        let q = quarantine_file(&path, "test catalog", "not a catalog").expect("renamed");
        assert!(!path.exists(), "original must be moved aside");
        assert_eq!(std::fs::read_to_string(&q).unwrap(), "garbage", "evidence preserved");
        assert_eq!(total(), before + 1);
        // A missing file still counts (the caller saw *something* wrong)
        // but cannot be renamed.
        assert_eq!(quarantine_file(&path, "test catalog", "io error"), None);
        assert_eq!(total(), before + 2);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
