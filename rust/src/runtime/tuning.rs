//! Persisted tile-tuning catalog — the `ArtifactKind::TileTuning` file.
//!
//! Stores the fused-engine autotuner's probed winners so warm processes
//! and future runs skip the first-use microbenchmark
//! (`ozaki::tune::tile_shape_for`). Hand-rolled text format, one entry
//! per line (serde is unavailable offline, same as the manifest):
//!
//! ```text
//! # adp-dgemm tile-tuning catalog v1
//! # kernel bucket mc nc pair_ns
//! avx512-vnni medium 64 128 0.0312
//! ```
//!
//! `kernel` is a `KernelId` label, `bucket` a `ShapeBucket` label, `mc`/
//! `nc` the winning tile dims, `pair_ns` the measured ns per integer MAC
//! (0 when unknown). Unknown kernels or buckets are the *reader's*
//! concern — `ozaki::tune` skips entries it cannot resolve, so a catalog
//! written by a newer binary (or another machine) degrades to a partial
//! cache instead of an error. This module only enforces the line shape.
//!
//! Saves are atomic (write to `<path>.tmp`, then rename) so a crashed or
//! raced writer can never leave a half-written catalog behind.

use std::path::Path;

use crate::util::faultinject;

/// One persisted tuning decision.
#[derive(Clone, Debug, PartialEq)]
pub struct TuningEntry {
    /// `KernelId::label()` of the kernel this entry tunes.
    pub kernel: String,
    /// `ShapeBucket::label()` of the output-shape class.
    pub bucket: String,
    /// Winning tile height.
    pub mc: usize,
    /// Winning tile width.
    pub nc: usize,
    /// Measured ns per integer MAC of the winner (0 = unknown).
    pub pair_ns: f64,
}

/// Parse a catalog text. Blank lines and `#` comments are skipped;
/// malformed lines are errors (a corrupted catalog should be noticed by
/// the caller and rebuilt, not half-trusted).
pub fn parse(text: &str) -> Result<Vec<TuningEntry>, String> {
    let mut entries = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut it = line.split_whitespace();
        let (Some(kernel), Some(bucket), Some(mc), Some(nc), Some(pair_ns)) =
            (it.next(), it.next(), it.next(), it.next(), it.next())
        else {
            return Err(format!("tuning catalog line {} malformed: '{line}'", lineno + 1));
        };
        if it.next().is_some() {
            return Err(format!("tuning catalog line {} has trailing fields: '{line}'", lineno + 1));
        }
        let mc: usize =
            mc.parse().map_err(|_| format!("line {}: bad mc '{mc}'", lineno + 1))?;
        let nc: usize =
            nc.parse().map_err(|_| format!("line {}: bad nc '{nc}'", lineno + 1))?;
        let pair_ns: f64 =
            pair_ns.parse().map_err(|_| format!("line {}: bad pair_ns '{pair_ns}'", lineno + 1))?;
        if mc == 0 || nc == 0 || !pair_ns.is_finite() || pair_ns < 0.0 {
            return Err(format!("tuning catalog line {} out of range: '{line}'", lineno + 1));
        }
        entries.push(TuningEntry {
            kernel: kernel.to_string(),
            bucket: bucket.to_string(),
            mc,
            nc,
            pair_ns,
        });
    }
    Ok(entries)
}

/// Serialize entries in the format [`parse`] reads.
pub fn serialize(entries: &[TuningEntry]) -> String {
    let mut out =
        String::from("# adp-dgemm tile-tuning catalog v1\n# kernel bucket mc nc pair_ns\n");
    for e in entries {
        out.push_str(&format!("{} {} {} {} {:.6}\n", e.kernel, e.bucket, e.mc, e.nc, e.pair_ns));
    }
    out
}

/// Load a catalog file.
pub fn load(path: &Path) -> Result<Vec<TuningEntry>, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("reading {}: {e}", path.display()))?;
    parse(&text)
}

/// Save a catalog atomically: write `<path>.tmp`, then rename over the
/// destination, so readers never observe a torn file.
pub fn save(path: &Path, entries: &[TuningEntry]) -> Result<(), String> {
    let mut text = serialize(entries);
    if faultinject::fires(faultinject::site::TUNE_SAVE_TORN) {
        // Simulate a torn write slipping past the tmp+rename protocol:
        // half the bytes (plus a line parse() must reject) land at the
        // final path directly, exactly what a crashed non-atomic writer
        // leaves behind. The next load quarantines it.
        text.truncate(text.len() / 2);
        text.push_str("\ntorn\n");
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                let _ = std::fs::create_dir_all(dir);
            }
        }
        return std::fs::write(path, text)
            .map_err(|e| format!("writing {}: {e}", path.display()));
    }
    let mut tmp = path.as_os_str().to_os_string();
    tmp.push(".tmp");
    let tmp = std::path::PathBuf::from(tmp);
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).map_err(|e| format!("creating {}: {e}", dir.display()))?;
        }
    }
    std::fs::write(&tmp, text).map_err(|e| format!("writing {}: {e}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .map_err(|e| format!("renaming {} -> {}: {e}", tmp.display(), path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_serialize_round_trips() {
        let entries = vec![
            TuningEntry {
                kernel: "avx512-vnni".into(),
                bucket: "medium".into(),
                mc: 64,
                nc: 128,
                pair_ns: 0.031_25,
            },
            TuningEntry {
                kernel: "scalar".into(),
                bucket: "large".into(),
                mc: 96,
                nc: 96,
                pair_ns: 0.0,
            },
        ];
        let text = serialize(&entries);
        assert_eq!(parse(&text).unwrap(), entries);
    }

    #[test]
    fn parse_skips_comments_and_blanks() {
        let got = parse("# header\n\n  \nscalar medium 64 64 1.5\n").unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!((got[0].mc, got[0].nc), (64, 64));
    }

    #[test]
    fn rejects_malformed_lines() {
        for bad in [
            "scalar medium 64",                // too few fields
            "scalar medium 64 64 1.0 extra",   // too many fields
            "scalar medium zero 64 1.0",       // non-numeric mc
            "scalar medium 0 64 1.0",          // degenerate tile
            "scalar medium 64 64 nope",        // non-numeric pair_ns
            "scalar medium 64 64 -1.0",        // negative cost
        ] {
            assert!(parse(bad).is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn save_and_load_round_trip_through_disk() {
        let dir = std::env::temp_dir().join(format!("adp_tune_test_{}", std::process::id()));
        let path = dir.join("tile_tuning.txt");
        let entries = vec![TuningEntry {
            kernel: "avx2-maddubs".into(),
            bucket: "large".into(),
            mc: 128,
            nc: 64,
            pair_ns: 0.25,
        }];
        save(&path, &entries).unwrap();
        assert_eq!(load(&path).unwrap(), entries);
        // Overwrite must be atomic-rename clean, not append.
        save(&path, &entries[..0].to_vec()).unwrap();
        assert!(load(&path).unwrap().is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
