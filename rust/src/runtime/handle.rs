//! The runtime thread and its cloneable handle.
//!
//! One thread owns `PjRtClient::cpu()` and a cache of compiled executables
//! (HLO text -> `HloModuleProto::from_text_file` -> compile, cached on
//! first use). Requests arrive over an mpsc channel; every request carries
//! its own reply channel. Artifact execution is synchronous on the runtime
//! thread — matching one GPU stream — while callers overlap freely.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use super::catalog::{ArtifactKind, Catalog};
use crate::linalg::Matrix;

/// Result of the fused scan+ESC artifact (i32[4] on the wire).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ScanResult {
    pub has_nan: bool,
    pub has_inf: bool,
    pub esc: i32,
    pub required_bits_fp64: i32,
}

enum Request {
    /// Execute a 2-input f64[n,n] -> f64[n,n] artifact.
    Gemm { kind: ArtifactKind, n: usize, slices: usize, a: Vec<f64>, b: Vec<f64>, reply: Sender<Result<Vec<f64>>> },
    /// Execute the scan artifact: f64[n,n] x2 -> i32[4].
    Scan { n: usize, a: Vec<f64>, b: Vec<f64>, reply: Sender<Result<ScanResult>> },
    /// Compile (warm) an artifact without executing it.
    Warm { kind: ArtifactKind, n: usize, slices: usize, reply: Sender<Result<()>> },
    Shutdown,
}

/// Cloneable handle to the runtime thread.
#[derive(Clone)]
pub struct RuntimeHandle {
    tx: Sender<Request>,
    catalog: Arc<Catalog>,
}

impl RuntimeHandle {
    /// Load the catalog at `dir` and spawn the runtime thread.
    pub fn load(dir: &Path) -> Result<RuntimeHandle> {
        let catalog = Arc::new(Catalog::load(dir)?);
        let (tx, rx) = channel::<Request>();
        let cat = catalog.clone();
        std::thread::Builder::new()
            .name("pjrt-runtime".into())
            .spawn(move || runtime_main(cat, rx))
            .context("spawning runtime thread")?;
        Ok(RuntimeHandle { tx, catalog })
    }

    /// Try to load; `None` when no artifacts have been built (callers then
    /// use the native Rust paths).
    pub fn try_load(dir: &Path) -> Option<RuntimeHandle> {
        RuntimeHandle::load(dir).ok()
    }

    pub fn catalog(&self) -> &Catalog {
        &self.catalog
    }

    /// Execute the emulated-GEMM artifact `(n, slices)`. Operands may be
    /// any shape <= n; they are zero-padded (exact for GEMM) and the result
    /// is cropped back.
    pub fn emulated_gemm(&self, n: usize, slices: usize, a: &Matrix, b: &Matrix) -> Result<Matrix> {
        self.run_square(ArtifactKind::Gemm, n, slices, a, b)
    }

    /// Execute the native-FP64 DGEMM artifact of size `n`.
    pub fn dgemm(&self, n: usize, a: &Matrix, b: &Matrix) -> Result<Matrix> {
        self.run_square(ArtifactKind::Dgemm, n, 0, a, b)
    }

    /// Execute the fused scan+ESC artifact of size `n`.
    pub fn scan_esc(&self, n: usize, a: &Matrix, b: &Matrix) -> Result<ScanResult> {
        assert_eq!(a.cols, b.rows);
        let (ap, bp) = (a.pad_to(n, n), b.pad_to(n, n));
        let (rtx, rrx) = channel();
        self.tx
            .send(Request::Scan { n, a: ap.data, b: bp.data, reply: rtx })
            .map_err(|_| anyhow!("runtime thread gone"))?;
        rrx.recv().context("runtime reply")?
    }

    /// Pre-compile an artifact so first-request latency is predictable.
    pub fn warm(&self, kind: ArtifactKind, n: usize, slices: usize) -> Result<()> {
        let (rtx, rrx) = channel();
        self.tx
            .send(Request::Warm { kind, n, slices, reply: rtx })
            .map_err(|_| anyhow!("runtime thread gone"))?;
        rrx.recv().context("runtime reply")?
    }

    fn run_square(
        &self,
        kind: ArtifactKind,
        n: usize,
        slices: usize,
        a: &Matrix,
        b: &Matrix,
    ) -> Result<Matrix> {
        assert_eq!(a.cols, b.rows);
        let (m0, n0) = (a.rows, b.cols);
        let (ap, bp) = (a.pad_to(n, n), b.pad_to(n, n));
        let (rtx, rrx) = channel();
        self.tx
            .send(Request::Gemm { kind, n, slices, a: ap.data, b: bp.data, reply: rtx })
            .map_err(|_| anyhow!("runtime thread gone"))?;
        let data = rrx.recv().context("runtime reply")??;
        let full = Matrix::from_rows(n, n, data);
        Ok(if (m0, n0) == (n, n) { full } else { full.block(0, 0, m0, n0) })
    }

    /// Ask the runtime thread to exit (used by tests; dropping all handles
    /// also shuts it down).
    pub fn shutdown(&self) {
        let _ = self.tx.send(Request::Shutdown);
    }
}

fn runtime_main(catalog: Arc<Catalog>, rx: Receiver<Request>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            // Answer every request with the construction error.
            let msg = format!("PJRT CPU client failed: {e:?}");
            for req in rx {
                match req {
                    Request::Gemm { reply, .. } => {
                        let _ = reply.send(Err(anyhow!(msg.clone())));
                    }
                    Request::Scan { reply, .. } => {
                        let _ = reply.send(Err(anyhow!(msg.clone())));
                    }
                    Request::Warm { reply, .. } => {
                        let _ = reply.send(Err(anyhow!(msg.clone())));
                    }
                    Request::Shutdown => break,
                }
            }
            return;
        }
    };
    let mut cache: HashMap<PathBuf, xla::PjRtLoadedExecutable> = HashMap::new();

    let compile = |cache: &mut HashMap<PathBuf, xla::PjRtLoadedExecutable>,
                   client: &xla::PjRtClient,
                   kind: ArtifactKind,
                   n: usize,
                   slices: usize|
     -> Result<()> {
        let entry = catalog
            .find(kind, n, slices)
            .ok_or_else(|| anyhow!("no artifact for {kind:?} n={n} s={slices}"))?;
        if cache.contains_key(&entry.path) {
            return Ok(());
        }
        let proto = xla::HloModuleProto::from_text_file(
            entry.path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", entry.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", entry.path.display()))?;
        cache.insert(entry.path.clone(), exe);
        Ok(())
    };

    for req in rx {
        match req {
            Request::Shutdown => break,
            Request::Warm { kind, n, slices, reply } => {
                let _ = reply.send(compile(&mut cache, &client, kind, n, slices));
            }
            Request::Gemm { kind, n, slices, a, b, reply } => {
                let r = (|| -> Result<Vec<f64>> {
                    compile(&mut cache, &client, kind, n, slices)?;
                    let entry = catalog.find(kind, n, slices).unwrap();
                    let exe = cache.get(&entry.path).unwrap();
                    let la = literal_f64(&a, n)?;
                    let lb = literal_f64(&b, n)?;
                    let out = exe
                        .execute::<xla::Literal>(&[la, lb])
                        .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
                        .to_literal_sync()
                        .map_err(|e| anyhow!("to_literal: {e:?}"))?;
                    // aot.py lowers with return_tuple=True: unwrap 1-tuple.
                    let out = out.to_tuple1().map_err(|e| anyhow!("tuple: {e:?}"))?;
                    out.to_vec::<f64>().map_err(|e| anyhow!("to_vec: {e:?}"))
                })();
                let _ = reply.send(r);
            }
            Request::Scan { n, a, b, reply } => {
                let r = (|| -> Result<ScanResult> {
                    compile(&mut cache, &client, ArtifactKind::Scan, n, 0)?;
                    let entry = catalog.find(ArtifactKind::Scan, n, 0).unwrap();
                    let exe = cache.get(&entry.path).unwrap();
                    let la = literal_f64(&a, n)?;
                    let lb = literal_f64(&b, n)?;
                    let out = exe
                        .execute::<xla::Literal>(&[la, lb])
                        .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
                        .to_literal_sync()
                        .map_err(|e| anyhow!("to_literal: {e:?}"))?;
                    let out = out.to_tuple1().map_err(|e| anyhow!("tuple: {e:?}"))?;
                    let v = out.to_vec::<i32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
                    if v.len() != 4 {
                        bail!("scan artifact returned {} words, expected 4", v.len());
                    }
                    Ok(ScanResult {
                        has_nan: v[0] != 0,
                        has_inf: v[1] != 0,
                        esc: v[2],
                        required_bits_fp64: v[3],
                    })
                })();
                let _ = reply.send(r);
            }
        }
    }
}

fn literal_f64(data: &[f64], n: usize) -> Result<xla::Literal> {
    assert_eq!(data.len(), n * n);
    xla::Literal::vec1(data)
        .reshape(&[n as i64, n as i64])
        .map_err(|e| anyhow!("literal reshape: {e:?}"))
}
