//! Artifact catalog: parses `artifacts/manifest.txt` (written by
//! `python/compile/aot.py`) and answers shape/slice availability queries.
//!
//! Manifest format, one artifact per line: `kind n slices filename`, with
//! `slices = 0` for the non-GEMM kinds. Hand-rolled (serde is unavailable
//! offline) and deliberately trivial.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ArtifactKind {
    /// Emulated Ozaki-I GEMM at a fixed slice count.
    Gemm,
    /// Fused NaN/Inf scan + coarsened ESC (returns i32[4]).
    Scan,
    /// Native FP64 GEMM (fallback target).
    Dgemm,
    /// Persisted tile-tuning catalog of the fused-engine autotuner
    /// (`runtime::tuning` text format; `n`/`slices` are 0).
    TileTuning,
}

impl ArtifactKind {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "gemm" => ArtifactKind::Gemm,
            "scan" => ArtifactKind::Scan,
            "dgemm" => ArtifactKind::Dgemm,
            "tiletune" => ArtifactKind::TileTuning,
            other => bail!("unknown artifact kind '{other}'"),
        })
    }
}

#[derive(Clone, Debug)]
pub struct CatalogEntry {
    pub kind: ArtifactKind,
    pub n: usize,
    pub slices: usize,
    pub path: PathBuf,
}

/// Parsed artifact manifest.
#[derive(Clone, Debug, Default)]
pub struct Catalog {
    pub entries: Vec<CatalogEntry>,
}

impl Catalog {
    pub fn load(dir: &Path) -> Result<Catalog> {
        let manifest = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("reading {}", manifest.display()))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Catalog> {
        let mut entries = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut it = line.split_whitespace();
            let (Some(kind), Some(n), Some(slices), Some(file)) =
                (it.next(), it.next(), it.next(), it.next())
            else {
                bail!("manifest line {} malformed: '{line}'", lineno + 1);
            };
            entries.push(CatalogEntry {
                kind: ArtifactKind::parse(kind)?,
                n: n.parse().context("n field")?,
                slices: slices.parse().context("slices field")?,
                path: dir.join(file),
            });
        }
        Ok(Catalog { entries })
    }

    pub fn find(&self, kind: ArtifactKind, n: usize, slices: usize) -> Option<&CatalogEntry> {
        self.entries
            .iter()
            .find(|e| e.kind == kind && e.n == n && e.slices == slices)
    }

    /// Registered square sizes for `kind`, ascending.
    pub fn sizes(&self, kind: ArtifactKind) -> Vec<usize> {
        let set: BTreeSet<usize> =
            self.entries.iter().filter(|e| e.kind == kind).map(|e| e.n).collect();
        set.into_iter().collect()
    }

    /// Smallest registered GEMM size that fits an (m, k, n) problem, if any.
    pub fn fitting_size(&self, m: usize, k: usize, n: usize) -> Option<usize> {
        let need = m.max(k).max(n);
        self.sizes(ArtifactKind::Gemm).into_iter().find(|&s| s >= need)
    }

    /// Slice counts registered for GEMM size `n`, ascending.
    pub fn slice_counts(&self, n: usize) -> Vec<usize> {
        let set: BTreeSet<usize> = self
            .entries
            .iter()
            .filter(|e| e.kind == ArtifactKind::Gemm && e.n == n)
            .map(|e| e.slices)
            .collect();
        set.into_iter().collect()
    }

    /// Smallest registered slice count >= `want` at size `n`.
    pub fn slice_count_at_least(&self, n: usize, want: usize) -> Option<usize> {
        self.slice_counts(n).into_iter().find(|&s| s >= want)
    }

    /// Path of the persisted tile-tuning catalog, when the manifest
    /// registers one (`tiletune 0 0 <file>`). The autotuner loads winners
    /// from — and persists new probes to — this file.
    pub fn tuning_path(&self) -> Option<PathBuf> {
        self.entries.iter().find(|e| e.kind == ArtifactKind::TileTuning).map(|e| e.path.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# comment
dgemm 64 0 dgemm_n64.hlo.txt
scan 64 0 scan_esc_n64.hlo.txt
gemm 64 3 ozaki_gemm_n64_s3.hlo.txt
gemm 64 7 ozaki_gemm_n64_s7.hlo.txt
gemm 128 7 ozaki_gemm_n128_s7.hlo.txt
tiletune 0 0 tile_tuning.txt
";

    #[test]
    fn parses_sample() {
        let c = Catalog::parse(SAMPLE, Path::new("/art")).unwrap();
        assert_eq!(c.entries.len(), 6);
        assert_eq!(c.sizes(ArtifactKind::Gemm), vec![64, 128]);
        assert_eq!(c.slice_counts(64), vec![3, 7]);
        assert!(c.find(ArtifactKind::Scan, 64, 0).is_some());
        assert_eq!(
            c.find(ArtifactKind::Gemm, 64, 7).unwrap().path,
            Path::new("/art/ozaki_gemm_n64_s7.hlo.txt")
        );
        assert_eq!(c.tuning_path().unwrap(), Path::new("/art/tile_tuning.txt"));
    }

    #[test]
    fn tuning_path_absent_when_unregistered() {
        let c = Catalog::parse("gemm 64 7 g.hlo.txt", Path::new("/a")).unwrap();
        assert!(c.tuning_path().is_none());
    }

    #[test]
    fn fitting_size_rounds_up() {
        let c = Catalog::parse(SAMPLE, Path::new("/a")).unwrap();
        assert_eq!(c.fitting_size(60, 64, 10), Some(64));
        assert_eq!(c.fitting_size(65, 2, 2), Some(128));
        assert_eq!(c.fitting_size(200, 2, 2), None);
    }

    #[test]
    fn slice_count_at_least_picks_next() {
        let c = Catalog::parse(SAMPLE, Path::new("/a")).unwrap();
        assert_eq!(c.slice_count_at_least(64, 5), Some(7));
        assert_eq!(c.slice_count_at_least(64, 8), None);
        assert_eq!(c.slice_count_at_least(128, 7), Some(7));
    }

    #[test]
    fn rejects_malformed() {
        assert!(Catalog::parse("gemm 64", Path::new("/a")).is_err());
        assert!(Catalog::parse("wat 64 0 f", Path::new("/a")).is_err());
    }
}
