//! Input safety scan (§5.1): detect NaN/Inf before any O(n^3) work.
//!
//! The native-path equivalent of the scan half of the fused scan+ESC
//! artifact. Negative zeros need no rewrite pass: slicing already treats
//! -0.0 as 0.0 (its digits are all zero), matching the paper's "negative
//! zeros in the input are simply treated as a zero".

use crate::linalg::Matrix;

#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ScanFlags {
    pub has_nan: bool,
    pub has_inf: bool,
    /// Subnormals are handled exactly by the native pipeline but flushed to
    /// zero by the XLA-CPU artifact path (DAZ/FTZ); ADP uses this flag to
    /// steer such inputs away from artifacts (see DESIGN.md).
    pub has_subnormal: bool,
}

impl ScanFlags {
    pub fn clean(&self) -> bool {
        !self.has_nan && !self.has_inf
    }
}

/// Scan one operand. Exits early once every flag is set — there is
/// nothing left to learn from the remaining elements, and adversarial
/// inputs (a NaN in row 0 of a huge matrix) shouldn't pay a full O(m·k)
/// sweep for a verdict that was decided immediately. Clean elements pay
/// nothing for the check: it sits inside the (cold) flag-setting
/// branches. Flag-identical to the full sweep by construction.
pub fn scan_matrix(m: &Matrix) -> ScanFlags {
    let mut f = ScanFlags::default();
    for &x in &m.data {
        // classify via bit pattern (exp field all-ones / all-zeros)
        let bits = x.to_bits();
        let exp = (bits >> 52) & 0x7FF;
        let mant = bits & ((1u64 << 52) - 1);
        if exp == 0x7FF {
            if mant == 0 {
                f.has_inf = true;
            } else {
                f.has_nan = true;
            }
            if f.has_nan && f.has_inf && f.has_subnormal {
                return f; // saturated
            }
        } else if exp == 0 && mant != 0 {
            f.has_subnormal = true;
            if f.has_nan && f.has_inf {
                return f; // saturated (has_subnormal just set)
            }
        }
    }
    f
}

/// Scan both operands of a GEMM. When `a` contains a NaN the NaN
/// fallback is already forced — every consumer checks `has_nan` before
/// `has_inf`, and `has_subnormal` only steers dispatch on *clean*
/// inputs — so `b`'s O(k·n) scan is skipped entirely. In that case the
/// returned flags are decision-identical rather than the exact union
/// (`b`'s inf/subnormal bits are not collected); in every other case
/// the union is exact.
pub fn scan_pair(a: &Matrix, b: &Matrix) -> ScanFlags {
    let fa = scan_matrix(a);
    if fa.has_nan {
        return fa;
    }
    let fb = scan_matrix(b);
    ScanFlags {
        has_nan: fa.has_nan || fb.has_nan,
        has_inf: fa.has_inf || fb.has_inf,
        has_subnormal: fa.has_subnormal || fb.has_subnormal,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_matrix() {
        let m = Matrix::from_rows(2, 2, vec![1.0, -0.0, f64::MAX, f64::MIN_POSITIVE]);
        assert!(scan_matrix(&m).clean());
    }

    #[test]
    fn detects_nan_inf_separately() {
        let m = Matrix::from_rows(1, 2, vec![f64::NAN, 1.0]);
        assert_eq!(scan_matrix(&m), ScanFlags { has_nan: true, ..Default::default() });
        let m = Matrix::from_rows(1, 2, vec![f64::NEG_INFINITY, 1.0]);
        assert_eq!(scan_matrix(&m), ScanFlags { has_inf: true, ..Default::default() });
    }

    #[test]
    fn pair_merges_flags() {
        let a = Matrix::from_rows(1, 1, vec![f64::NAN]);
        let b = Matrix::from_rows(1, 1, vec![f64::INFINITY]);
        let f = scan_pair(&a, &b);
        assert!(f.has_nan && f.has_inf && !f.clean());
    }

    #[test]
    fn subnormals_are_clean_but_flagged() {
        let m = Matrix::from_rows(1, 1, vec![f64::from_bits(1)]);
        let f = scan_matrix(&m);
        assert!(f.clean());
        assert!(f.has_subnormal);
        let n = Matrix::from_rows(1, 1, vec![f64::MIN_POSITIVE]);
        assert!(!scan_matrix(&n).has_subnormal);
    }

    /// Reference sweep with no early exit, for flag-identity pinning.
    fn naive_scan(m: &Matrix) -> ScanFlags {
        let mut f = ScanFlags::default();
        for &x in &m.data {
            let bits = x.to_bits();
            let exp = (bits >> 52) & 0x7FF;
            let mant = bits & ((1u64 << 52) - 1);
            if exp == 0x7FF {
                if mant == 0 {
                    f.has_inf = true;
                } else {
                    f.has_nan = true;
                }
            } else if exp == 0 && mant != 0 {
                f.has_subnormal = true;
            }
        }
        f
    }

    #[test]
    fn early_exit_is_flag_identical_on_adversarial_inputs() {
        let sub = f64::from_bits(1);
        let cases: Vec<Vec<f64>> = vec![
            vec![1.0; 64],                                         // all clean
            vec![f64::NAN; 64],                                    // all NaN, never saturates
            [vec![f64::NAN, f64::INFINITY, sub], vec![0.5; 61]].concat(), // saturates at 3
            [vec![0.5; 61], vec![f64::NAN, f64::INFINITY, sub]].concat(), // saturates at end
            [vec![f64::NAN, f64::NAN], vec![1.0; 62]].concat(),    // repeats, no saturation
            [vec![sub; 4], vec![f64::NEG_INFINITY], vec![2.0; 59]].concat(),
            [vec![f64::INFINITY, sub, f64::NAN], vec![f64::MAX; 61]].concat(),
            vec![-0.0, f64::MIN_POSITIVE, f64::MAX, f64::MIN],     // clean edge values
        ];
        for data in cases {
            let n = data.len();
            let m = Matrix::from_rows(1, n, data);
            assert_eq!(scan_matrix(&m), naive_scan(&m), "early exit changed flags: {m:?}");
        }
    }

    #[test]
    fn pair_skips_b_only_under_a_nan_and_stays_decision_identical() {
        let nan = Matrix::from_rows(1, 2, vec![f64::NAN, 1.0]);
        let inf = Matrix::from_rows(1, 2, vec![f64::INFINITY, 1.0]);
        let sub = Matrix::from_rows(1, 2, vec![f64::from_bits(1), 1.0]);
        let clean = Matrix::from_rows(1, 2, vec![1.0, 2.0]);
        // A-NaN short circuit: has_nan dominates every consumer, so the
        // decision (FallbackNan) is identical even though B is unscanned.
        let f = scan_pair(&nan, &inf);
        assert!(f.has_nan && !f.clean());
        // Without a NaN in A, the union stays exact — including B's NaN,
        // inf and subnormal contributions.
        let f = scan_pair(&inf, &sub);
        assert!(!f.has_nan && f.has_inf && f.has_subnormal);
        let f = scan_pair(&clean, &nan);
        assert!(f.has_nan);
        let f = scan_pair(&sub, &clean);
        assert!(f.clean() && f.has_subnormal);
    }
}
