//! Input safety scan (§5.1): detect NaN/Inf before any O(n^3) work.
//!
//! The native-path equivalent of the scan half of the fused scan+ESC
//! artifact. Negative zeros need no rewrite pass: slicing already treats
//! -0.0 as 0.0 (its digits are all zero), matching the paper's "negative
//! zeros in the input are simply treated as a zero".

use crate::linalg::Matrix;

#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ScanFlags {
    pub has_nan: bool,
    pub has_inf: bool,
    /// Subnormals are handled exactly by the native pipeline but flushed to
    /// zero by the XLA-CPU artifact path (DAZ/FTZ); ADP uses this flag to
    /// steer such inputs away from artifacts (see DESIGN.md).
    pub has_subnormal: bool,
}

impl ScanFlags {
    pub fn clean(&self) -> bool {
        !self.has_nan && !self.has_inf
    }
}

/// Scan one operand.
pub fn scan_matrix(m: &Matrix) -> ScanFlags {
    let mut f = ScanFlags::default();
    for &x in &m.data {
        // classify via bit pattern (exp field all-ones / all-zeros)
        let bits = x.to_bits();
        let exp = (bits >> 52) & 0x7FF;
        let mant = bits & ((1u64 << 52) - 1);
        if exp == 0x7FF {
            if mant == 0 {
                f.has_inf = true;
            } else {
                f.has_nan = true;
            }
        } else if exp == 0 && mant != 0 {
            f.has_subnormal = true;
        }
    }
    f
}

/// Scan both operands of a GEMM.
pub fn scan_pair(a: &Matrix, b: &Matrix) -> ScanFlags {
    let fa = scan_matrix(a);
    let fb = scan_matrix(b);
    ScanFlags {
        has_nan: fa.has_nan || fb.has_nan,
        has_inf: fa.has_inf || fb.has_inf,
        has_subnormal: fa.has_subnormal || fb.has_subnormal,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_matrix() {
        let m = Matrix::from_rows(2, 2, vec![1.0, -0.0, f64::MAX, f64::MIN_POSITIVE]);
        assert!(scan_matrix(&m).clean());
    }

    #[test]
    fn detects_nan_inf_separately() {
        let m = Matrix::from_rows(1, 2, vec![f64::NAN, 1.0]);
        assert_eq!(scan_matrix(&m), ScanFlags { has_nan: true, ..Default::default() });
        let m = Matrix::from_rows(1, 2, vec![f64::NEG_INFINITY, 1.0]);
        assert_eq!(scan_matrix(&m), ScanFlags { has_inf: true, ..Default::default() });
    }

    #[test]
    fn pair_merges_flags() {
        let a = Matrix::from_rows(1, 1, vec![f64::NAN]);
        let b = Matrix::from_rows(1, 1, vec![f64::INFINITY]);
        let f = scan_pair(&a, &b);
        assert!(f.has_nan && f.has_inf && !f.clean());
    }

    #[test]
    fn subnormals_are_clean_but_flagged() {
        let m = Matrix::from_rows(1, 1, vec![f64::from_bits(1)]);
        let f = scan_matrix(&m);
        assert!(f.clean());
        assert!(f.has_subnormal);
        let n = Matrix::from_rows(1, 1, vec![f64::MIN_POSITIVE]);
        assert!(!scan_matrix(&n).has_subnormal);
    }
}
