//! Dispatch metrics: outcome histogram, slice-count histogram (Fig 7
//! right), guardrail-vs-exec time split (Fig 5 / §7.1's <10% claim).

use std::collections::BTreeMap;
use std::sync::Mutex;

use super::adp::{AdpOutcome, GemmDecision};

#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Default, Clone)]
struct Inner {
    requests: u64,
    emulated: u64,
    fallback_nan: u64,
    fallback_inf: u64,
    fallback_esc: u64,
    fallback_heuristic: u64,
    slice_histogram: BTreeMap<usize, u64>,
    guardrail_s: f64,
    exec_s: f64,
}

/// Immutable snapshot of the counters.
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub emulated: u64,
    pub fallback_nan: u64,
    pub fallback_inf: u64,
    pub fallback_esc: u64,
    pub fallback_heuristic: u64,
    pub slice_histogram: Vec<(usize, u64)>,
    pub guardrail_s: f64,
    pub exec_s: f64,
}

impl MetricsSnapshot {
    /// Guardrail share of total time — the §7.1 "<10% overhead" metric.
    pub fn guardrail_fraction(&self) -> f64 {
        let total = self.guardrail_s + self.exec_s;
        if total == 0.0 {
            0.0
        } else {
            self.guardrail_s / total
        }
    }

    pub fn fallbacks(&self) -> u64 {
        self.fallback_nan + self.fallback_inf + self.fallback_esc + self.fallback_heuristic
    }
}

impl Metrics {
    pub fn record(&self, out: &AdpOutcome) {
        let mut g = self.inner.lock().unwrap();
        g.requests += 1;
        match out.decision {
            GemmDecision::EmulatedArtifact { slices, .. }
            | GemmDecision::EmulatedNative { slices } => {
                g.emulated += 1;
                *g.slice_histogram.entry(slices).or_insert(0) += 1;
            }
            GemmDecision::FallbackNan => g.fallback_nan += 1,
            GemmDecision::FallbackInf => g.fallback_inf += 1,
            GemmDecision::FallbackEsc { .. } => g.fallback_esc += 1,
            GemmDecision::FallbackHeuristic => g.fallback_heuristic += 1,
        }
        g.guardrail_s += out.guardrail_s;
        g.exec_s += out.exec_s;
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.inner.lock().unwrap().clone();
        MetricsSnapshot {
            requests: g.requests,
            emulated: g.emulated,
            fallback_nan: g.fallback_nan,
            fallback_inf: g.fallback_inf,
            fallback_esc: g.fallback_esc,
            fallback_heuristic: g.fallback_heuristic,
            slice_histogram: g.slice_histogram.into_iter().collect(),
            guardrail_s: g.guardrail_s,
            exec_s: g.exec_s,
        }
    }

    pub fn reset(&self) {
        *self.inner.lock().unwrap() = Inner::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(decision: GemmDecision) -> AdpOutcome {
        AdpOutcome { decision, esc: 1, slices_required: 7, guardrail_s: 0.1, exec_s: 0.9 }
    }

    #[test]
    fn histogram_and_fractions() {
        let m = Metrics::default();
        m.record(&outcome(GemmDecision::EmulatedNative { slices: 7 }));
        m.record(&outcome(GemmDecision::EmulatedNative { slices: 7 }));
        m.record(&outcome(GemmDecision::EmulatedArtifact { n: 64, slices: 9 }));
        m.record(&outcome(GemmDecision::FallbackNan));
        let s = m.snapshot();
        assert_eq!(s.requests, 4);
        assert_eq!(s.emulated, 3);
        assert_eq!(s.fallbacks(), 1);
        assert_eq!(s.slice_histogram, vec![(7, 2), (9, 1)]);
        assert!((s.guardrail_fraction() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn reset_clears() {
        let m = Metrics::default();
        m.record(&outcome(GemmDecision::FallbackEsc { esc: 99 }));
        m.reset();
        assert_eq!(m.snapshot().requests, 0);
    }
}
