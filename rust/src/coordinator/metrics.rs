//! Dispatch metrics: outcome histogram, slice-count histogram (Fig 7
//! right), guardrail-vs-exec time split (Fig 5 / §7.1's <10% claim),
//! plus per-[`Priority`]-tier service accounting (admissions, typed
//! failures, retryable rejections, and queue/total latency quantiles
//! from lock-cheap log2 histograms).

use std::collections::BTreeMap;
use std::sync::Mutex;

use super::adp::{AdpOutcome, GemmDecision};
use super::service::Priority;
use crate::backend::WorkspaceStats;
use crate::ozaki::AccuracyTier;
use crate::runtime::quarantine;
use crate::util::faultinject;
use crate::util::sync as psync;

/// Number of [`Priority`] tiers ([`Priority::ALL`]'s length).
pub const TIER_COUNT: usize = 3;

/// Number of [`AccuracyTier`]s ([`AccuracyTier::ALL`]'s length) — a
/// *request accuracy* axis, orthogonal to the [`Priority`] service tiers
/// above.
pub const ACCURACY_TIER_COUNT: usize = 3;

/// log2-microsecond latency histogram: bucket 0 holds sub-microsecond
/// samples, bucket `i` covers `[2^(i-1), 2^i)` us — 47 doublings reach
/// ~2.2 years, so saturation is theoretical. Fixed-size and allocation-
/// free: recording a latency under the metrics lock is two increments.
#[derive(Clone)]
struct LatencyHistogram {
    buckets: [u64; 48],
    count: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram { buckets: [0; 48], count: 0 }
    }
}

impl LatencyHistogram {
    fn record(&mut self, seconds: f64) {
        let us = (seconds.max(0.0) * 1e6) as u64;
        let bucket = if us == 0 { 0 } else { (64 - us.leading_zeros() as usize).min(47) };
        self.buckets[bucket] += 1;
        self.count += 1;
    }

    /// Quantile estimate (`q` in [0, 1]) as seconds: the geometric
    /// midpoint `2^(i-1)·sqrt(2)` us of the bucket holding the q-th
    /// sample. 0.0 with no samples.
    fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                let mid_us = if i == 0 {
                    0.5
                } else {
                    (1u64 << (i - 1)) as f64 * std::f64::consts::SQRT_2
                };
                return mid_us * 1e-6;
            }
        }
        0.0
    }
}

/// Mutable per-tier counters under the metrics lock.
#[derive(Default, Clone)]
struct TierInner {
    enqueued: u64,
    completed: u64,
    failed: u64,
    rejected: u64,
    shed: u64,
    queue: LatencyHistogram,
    total: LatencyHistogram,
}

/// Per-[`Priority`]-tier service accounting, reported inside
/// [`MetricsSnapshot::tiers`] (indexed by [`Priority::index`]).
#[derive(Clone, Debug, Default)]
pub struct TierSnapshot {
    /// Tier label ([`Priority::label`]); `""` on a default snapshot.
    pub tier: &'static str,
    /// Requests admitted past admission control into a shard queue.
    pub enqueued: u64,
    /// Requests that completed with a successful response.
    pub completed: u64,
    /// Requests that completed with a typed error (shape mismatch,
    /// engine panic) after admission.
    pub failed: u64,
    /// Retryable admission rejections (`QueueFull`/`TierFull`) on the
    /// non-blocking submission paths. Shutdown rejections are not
    /// load-shedding and are not counted here.
    pub rejected: u64,
    /// Admitted requests shed at dequeue because their server-side
    /// deadline had already expired (each failed with
    /// `GemmError::DeadlineExceeded` instead of executing stale work).
    pub shed: u64,
    /// Median submission-to-execution-start latency, seconds.
    pub queue_p50_s: f64,
    /// p99 submission-to-execution-start latency, seconds.
    pub queue_p99_s: f64,
    /// Median end-to-end latency, seconds.
    pub total_p50_s: f64,
    /// p99 end-to-end latency, seconds.
    pub total_p99_s: f64,
}

impl TierSnapshot {
    /// Fraction of admission attempts shed by backpressure.
    pub fn rejection_rate(&self) -> f64 {
        let attempts = self.enqueued + self.rejected;
        if attempts == 0 {
            0.0
        } else {
            self.rejected as f64 / attempts as f64
        }
    }
}

#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Default, Clone)]
struct Inner {
    requests: u64,
    emulated: u64,
    emulated_crt: u64,
    fallback_nan: u64,
    fallback_inf: u64,
    fallback_esc: u64,
    fallback_heuristic: u64,
    slice_histogram: BTreeMap<usize, u64>,
    guardrail_s: f64,
    exec_s: f64,
    slice_cache_hits: u64,
    slice_cache_misses: u64,
    esc_cache_hits: u64,
    esc_cache_misses: u64,
    coalesced_batches: u64,
    coalesced_requests: u64,
    workspace_checkouts: u64,
    workspace_fresh: u64,
    fused_tiles: u64,
    panel_packs: u64,
    panel_reuses: u64,
    kernel: &'static str,
    tile_mc: usize,
    tile_nc: usize,
    tiers: [TierInner; TIER_COUNT],
    tier_requests: [u64; ACCURACY_TIER_COUNT],
    pairs_executed: u64,
    pairs_skipped: u64,
    tier_escalations: u64,
    worker_respawns: u64,
}

/// Immutable snapshot of the counters.
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub emulated: u64,
    /// Emulated requests served by the Ozaki-II/CRT scheme family (a
    /// subset of `emulated`; the remainder ran slice pairs).
    pub emulated_crt: u64,
    pub fallback_nan: u64,
    pub fallback_inf: u64,
    pub fallback_esc: u64,
    pub fallback_heuristic: u64,
    pub slice_histogram: Vec<(usize, u64)>,
    pub guardrail_s: f64,
    pub exec_s: f64,
    /// Operand decompositions *reused* from the grouped-pipeline slice
    /// cache (each hit is one `slice_a`/`slice_b` pass not paid).
    pub slice_cache_hits: u64,
    /// Operand decompositions actually performed by the grouped pipeline.
    pub slice_cache_misses: u64,
    /// Coarse-ESC reductions skipped by the plan cache.
    pub esc_cache_hits: u64,
    /// Coarse-ESC reductions performed through the plan cache.
    pub esc_cache_misses: u64,
    /// Shape-bucketed groups executed by the coalescing dispatcher.
    pub coalesced_batches: u64,
    /// Requests served inside those groups.
    pub coalesced_requests: u64,
    /// Workspace-pool checkouts (fused engine + grouped pipeline scratch).
    /// Pool lifetime total, refreshed per request — like the other
    /// workspace gauges below it tracks the shared pool, not this
    /// `Metrics` instance, so [`Metrics::reset`] does not rewind it (the
    /// next sync restores the pool total); measure windows as deltas
    /// between snapshots.
    pub workspace_checkouts: u64,
    /// Checkouts that had to allocate or grow a buffer. A warm service
    /// serving repeat shapes keeps this flat — the zero-hot-path-
    /// allocation criterion, asserted by a counter test.
    pub workspace_fresh: u64,
    /// Output tiles executed by the fused tile engine.
    pub fused_tiles: u64,
    /// Operand panel builds by the fused engine's packing layer (pool
    /// lifetime gauge, like the workspace gauges above).
    pub panel_packs: u64,
    /// Slice-pair kernel calls served from already-packed panels
    /// (`s(s+1)/2 - 1` per fused tile): the packed-panel amortization
    /// criterion, asserted by a counter test.
    pub panel_reuses: u64,
    /// Label of the slice-pair kernel that **actually executed** the last
    /// dispatch (`""` until one ran) — e.g. `"avx512-vnni"`, or
    /// `"scalar"` under `ADP_FORCE_SCALAR=1`. Read from the workspace
    /// pool's dispatch gauge, which every tile-engine driver (serial,
    /// parallel, CRT planes, grouped rounds) stamps at dispatch time, so
    /// it reflects what ran on every path — not what a planner chose.
    pub kernel: &'static str,
    /// Tile height of the last fused dispatch — the (possibly autotuned)
    /// geometry that actually ran. 0 until a tile-engine dispatch, or
    /// when the last dispatch was level-major (no tile geometry).
    pub tile_mc: usize,
    /// Tile width of the last fused dispatch (0 = see `tile_mc`).
    pub tile_nc: usize,
    /// Per-priority-tier service accounting (admissions, completions,
    /// typed failures, rejections, latency quantiles), indexed by
    /// [`Priority::index`].
    pub tiers: [TierSnapshot; TIER_COUNT],
    /// Requests dispatched per **accuracy** tier, indexed by
    /// [`AccuracyTier::index`] (orthogonal to the `tiers` priority axis).
    pub tier_requests: [u64; ACCURACY_TIER_COUNT],
    /// Slice-pair GEMMs the dispatched schedules actually ran (kept
    /// pairs only; native and CRT requests contribute 0).
    pub pairs_executed: u64,
    /// Pair GEMMs skipped by tier truncation relative to the full
    /// `s(s+1)/2` schedules — the fast tiers' compute saving, pinned by
    /// a counter test.
    pub pairs_skipped: u64,
    /// Fast-tier requests the engine escalated to the full schedule
    /// because ESC left no truncation room (the tier's bound could not
    /// be met any cheaper) — never a silent accuracy loss.
    pub tier_escalations: u64,
    /// Total deadline sheds across all priority tiers (sum of the
    /// per-tier `shed` fields — the `shed_expired` service counter).
    pub shed_expired: u64,
    /// Shard workers the supervisor replaced after a death or hang.
    pub worker_respawns: u64,
    /// Corrupt persisted artifacts quarantined to `<path>.corrupt`
    /// (process-wide gauge from [`crate::runtime::quarantine`]).
    pub artifacts_quarantined: u64,
    /// Poisoned-mutex recoveries (process-wide gauge from
    /// [`crate::util::sync`]): each is a panic that did *not* cascade.
    pub lock_recoveries: u64,
}

impl MetricsSnapshot {
    /// Guardrail share of total time — the §7.1 "<10% overhead" metric.
    pub fn guardrail_fraction(&self) -> f64 {
        let total = self.guardrail_s + self.exec_s;
        if total == 0.0 {
            0.0
        } else {
            self.guardrail_s / total
        }
    }

    pub fn fallbacks(&self) -> u64 {
        self.fallback_nan + self.fallback_inf + self.fallback_esc + self.fallback_heuristic
    }
}

impl Metrics {
    pub fn record(&self, out: &AdpOutcome) {
        let mut g = psync::lock(&self.inner);
        if faultinject::fires(faultinject::site::WORKER_LOCK_PANIC) {
            // Deliberately unwinds while `g` is held: the chaos suite's
            // poisoned-Metrics scenario. Every other accessor recovers
            // via `psync::lock`, so the service keeps serving.
            panic!("injected fault: panic while holding the metrics lock");
        }
        g.requests += 1;
        match out.decision {
            GemmDecision::EmulatedArtifact { slices, .. }
            | GemmDecision::EmulatedNative { slices } => {
                g.emulated += 1;
                *g.slice_histogram.entry(slices).or_insert(0) += 1;
            }
            GemmDecision::EmulatedCrt { slices, .. } => {
                g.emulated += 1;
                g.emulated_crt += 1;
                *g.slice_histogram.entry(slices).or_insert(0) += 1;
            }
            GemmDecision::FallbackNan => g.fallback_nan += 1,
            GemmDecision::FallbackInf => g.fallback_inf += 1,
            GemmDecision::FallbackEsc { .. } => g.fallback_esc += 1,
            GemmDecision::FallbackHeuristic => g.fallback_heuristic += 1,
        }
        g.guardrail_s += out.guardrail_s;
        g.exec_s += out.exec_s;
    }

    /// Fold one grouped-pipeline slicing report into the counters.
    pub fn record_group(&self, stats: &crate::ozaki::GroupStats) {
        let mut g = psync::lock(&self.inner);
        g.slice_cache_hits += stats.slice_cache_hits;
        g.slice_cache_misses += stats.slice_cache_misses;
    }

    /// Record one plan-cache consultation.
    pub fn record_esc_cache(&self, hit: bool) {
        let mut g = psync::lock(&self.inner);
        if hit {
            g.esc_cache_hits += 1;
        } else {
            g.esc_cache_misses += 1;
        }
    }

    /// Record one request's accuracy-tier accounting: which tier it ran
    /// at, how many slice-pair GEMMs its schedule executed and skipped
    /// (both 0 for native/CRT dispatches), and whether a fast tier had
    /// to escalate to the full schedule.
    pub fn record_tier(
        &self,
        tier: AccuracyTier,
        pairs_executed: u64,
        pairs_skipped: u64,
        escalated: bool,
    ) {
        let mut g = psync::lock(&self.inner);
        g.tier_requests[tier.index()] += 1;
        g.pairs_executed += pairs_executed;
        g.pairs_skipped += pairs_skipped;
        if escalated {
            g.tier_escalations += 1;
        }
    }

    /// Record one coalesced shape bucket of `n` requests.
    pub fn record_coalesced_batch(&self, n: u64) {
        let mut g = psync::lock(&self.inner);
        g.coalesced_batches += 1;
        g.coalesced_requests += n;
    }

    /// `n` requests admitted into a shard queue at `tier`.
    pub fn record_enqueued(&self, tier: Priority, n: u64) {
        psync::lock(&self.inner).tiers[tier.index()].enqueued += n;
    }

    /// `n` requests shed by admission control at `tier` (retryable
    /// `QueueFull`/`TierFull` verdicts on the non-blocking paths).
    pub fn record_rejected(&self, tier: Priority, n: u64) {
        psync::lock(&self.inner).tiers[tier.index()].rejected += n;
    }

    /// `n` admitted requests shed at dequeue with an expired server-side
    /// deadline (each answered `GemmError::DeadlineExceeded`).
    pub fn record_shed(&self, tier: Priority, n: u64) {
        psync::lock(&self.inner).tiers[tier.index()].shed += n;
    }

    /// The supervisor replaced a dead or hung shard worker.
    pub fn record_respawn(&self) {
        psync::lock(&self.inner).worker_respawns += 1;
    }

    /// One request completed successfully with the given latency split.
    pub fn record_latency(&self, tier: Priority, queue_s: f64, total_s: f64) {
        let mut g = psync::lock(&self.inner);
        let t = &mut g.tiers[tier.index()];
        t.completed += 1;
        t.queue.record(queue_s);
        t.total.record(total_s);
    }

    /// One admitted request completed with a typed error (shape
    /// mismatch, engine panic).
    pub fn record_failure(&self, tier: Priority) {
        psync::lock(&self.inner).tiers[tier.index()].failed += 1;
    }

    /// Refresh the workspace gauges from a pool's lifetime totals. The
    /// pool is shared service-wide, so totals (not per-request deltas)
    /// are the meaningful series; `max` keeps the gauges monotone even
    /// when racing workers sync out of order.
    pub fn sync_workspace(&self, stats: WorkspaceStats) {
        let mut g = psync::lock(&self.inner);
        g.workspace_checkouts = g.workspace_checkouts.max(stats.checkouts);
        g.workspace_fresh = g.workspace_fresh.max(stats.fresh_allocs);
        g.fused_tiles = g.fused_tiles.max(stats.fused_tiles);
        g.panel_packs = g.panel_packs.max(stats.panel_packs);
        g.panel_reuses = g.panel_reuses.max(stats.panel_reuses);
        // The pool's dispatch gauge is stamped by the driver that ran
        // (fused serial/parallel, CRT planes, grouped rounds), so this
        // reports the executed kernel and tile geometry on every path —
        // not merely the engine's planned choice.
        if !stats.kernel.is_empty() {
            g.kernel = stats.kernel;
            g.tile_mc = stats.tile_mc;
            g.tile_nc = stats.tile_nc;
        }
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = psync::lock(&self.inner).clone();
        MetricsSnapshot {
            requests: g.requests,
            emulated: g.emulated,
            emulated_crt: g.emulated_crt,
            fallback_nan: g.fallback_nan,
            fallback_inf: g.fallback_inf,
            fallback_esc: g.fallback_esc,
            fallback_heuristic: g.fallback_heuristic,
            slice_histogram: g.slice_histogram.into_iter().collect(),
            guardrail_s: g.guardrail_s,
            exec_s: g.exec_s,
            slice_cache_hits: g.slice_cache_hits,
            slice_cache_misses: g.slice_cache_misses,
            esc_cache_hits: g.esc_cache_hits,
            esc_cache_misses: g.esc_cache_misses,
            coalesced_batches: g.coalesced_batches,
            coalesced_requests: g.coalesced_requests,
            workspace_checkouts: g.workspace_checkouts,
            workspace_fresh: g.workspace_fresh,
            fused_tiles: g.fused_tiles,
            panel_packs: g.panel_packs,
            panel_reuses: g.panel_reuses,
            kernel: g.kernel,
            tile_mc: g.tile_mc,
            tile_nc: g.tile_nc,
            tiers: {
                let mut tiers: [TierSnapshot; TIER_COUNT] = Default::default();
                for p in Priority::ALL {
                    let t = &g.tiers[p.index()];
                    tiers[p.index()] = TierSnapshot {
                        tier: p.label(),
                        enqueued: t.enqueued,
                        completed: t.completed,
                        failed: t.failed,
                        rejected: t.rejected,
                        shed: t.shed,
                        queue_p50_s: t.queue.quantile(0.50),
                        queue_p99_s: t.queue.quantile(0.99),
                        total_p50_s: t.total.quantile(0.50),
                        total_p99_s: t.total.quantile(0.99),
                    };
                }
                tiers
            },
            tier_requests: g.tier_requests,
            pairs_executed: g.pairs_executed,
            pairs_skipped: g.pairs_skipped,
            tier_escalations: g.tier_escalations,
            shed_expired: g.tiers.iter().map(|t| t.shed).sum(),
            worker_respawns: g.worker_respawns,
            artifacts_quarantined: quarantine::total(),
            lock_recoveries: psync::recovered_total(),
        }
    }

    /// Zero every counter. The workspace gauges (`workspace_checkouts`,
    /// `workspace_fresh`, `fused_tiles`) mirror the *shared pool's*
    /// lifetime totals, so the first post-reset sync restores them —
    /// treat them as gauges and difference snapshots for window math.
    pub fn reset(&self) {
        *psync::lock(&self.inner) = Inner::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(decision: GemmDecision) -> AdpOutcome {
        AdpOutcome { decision, esc: 1, slices_required: 7, guardrail_s: 0.1, exec_s: 0.9 }
    }

    #[test]
    fn histogram_and_fractions() {
        let m = Metrics::default();
        m.record(&outcome(GemmDecision::EmulatedNative { slices: 7 }));
        m.record(&outcome(GemmDecision::EmulatedNative { slices: 7 }));
        m.record(&outcome(GemmDecision::EmulatedArtifact { n: 64, slices: 9 }));
        m.record(&outcome(GemmDecision::EmulatedCrt { slices: 9, moduli: 17 }));
        m.record(&outcome(GemmDecision::FallbackNan));
        let s = m.snapshot();
        assert_eq!(s.requests, 5);
        assert_eq!(s.emulated, 4);
        assert_eq!(s.emulated_crt, 1, "CRT requests counted inside `emulated`");
        assert_eq!(s.fallbacks(), 1);
        assert_eq!(s.slice_histogram, vec![(7, 2), (9, 2)]);
        assert!((s.guardrail_fraction() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn cache_and_coalesce_counters() {
        let m = Metrics::default();
        m.record_group(&crate::ozaki::GroupStats {
            slice_cache_hits: 3,
            slice_cache_misses: 5,
            chunked_bypass: 0,
            crt_routed: 0,
        });
        m.record_esc_cache(true);
        m.record_esc_cache(false);
        m.record_coalesced_batch(4);
        let s = m.snapshot();
        assert_eq!((s.slice_cache_hits, s.slice_cache_misses), (3, 5));
        assert_eq!((s.esc_cache_hits, s.esc_cache_misses), (1, 1));
        assert_eq!((s.coalesced_batches, s.coalesced_requests), (1, 4));
    }

    #[test]
    fn workspace_gauges_track_pool_totals_monotonically() {
        use crate::backend::WorkspaceStats;
        let m = Metrics::default();
        m.sync_workspace(WorkspaceStats {
            checkouts: 4,
            fresh_allocs: 2,
            fused_tiles: 9,
            panel_packs: 18,
            panel_reuses: 243,
            ..Default::default()
        });
        // A stale (smaller) sync from a racing worker must not regress.
        m.sync_workspace(WorkspaceStats {
            checkouts: 3,
            fresh_allocs: 1,
            fused_tiles: 5,
            panel_packs: 10,
            panel_reuses: 100,
            ..Default::default()
        });
        let s = m.snapshot();
        assert_eq!((s.workspace_checkouts, s.workspace_fresh, s.fused_tiles), (4, 2, 9));
        assert_eq!((s.panel_packs, s.panel_reuses), (18, 243));
        m.sync_workspace(WorkspaceStats {
            checkouts: 10,
            fresh_allocs: 2,
            fused_tiles: 20,
            panel_packs: 40,
            panel_reuses: 540,
            ..Default::default()
        });
        let s = m.snapshot();
        assert_eq!((s.workspace_checkouts, s.workspace_fresh, s.fused_tiles), (10, 2, 20));
        assert_eq!((s.panel_packs, s.panel_reuses), (40, 540));
    }

    #[test]
    fn kernel_gauge_reports_the_executed_dispatch() {
        let m = Metrics::default();
        assert_eq!(m.snapshot().kernel, "", "no kernel before the first emulated request");
        // A sync with no dispatch stamped must not disturb the gauge.
        m.sync_workspace(WorkspaceStats { checkouts: 1, ..Default::default() });
        assert_eq!(m.snapshot().kernel, "");
        // A fused dispatch carries kernel + tuned tile geometry.
        m.sync_workspace(WorkspaceStats {
            kernel: "avx512-vnni",
            tile_mc: 64,
            tile_nc: 128,
            ..Default::default()
        });
        let s = m.snapshot();
        assert_eq!((s.kernel, s.tile_mc, s.tile_nc), ("avx512-vnni", 64, 128));
        // A level-major dispatch reports the kernel with no geometry.
        m.sync_workspace(WorkspaceStats { kernel: "scalar", ..Default::default() });
        let s = m.snapshot();
        assert_eq!((s.kernel, s.tile_mc, s.tile_nc), ("scalar", 0, 0));
        m.reset();
        assert_eq!(m.snapshot().kernel, "");
    }

    #[test]
    fn tier_counters_and_quantiles() {
        let m = Metrics::default();
        m.record_enqueued(Priority::High, 3);
        m.record_rejected(Priority::High, 1);
        // Two fast requests and one slow one: p50 lands in the fast
        // buckets, p99 in the slow one.
        m.record_latency(Priority::High, 10e-6, 100e-6);
        m.record_latency(Priority::High, 12e-6, 110e-6);
        m.record_latency(Priority::High, 5e-3, 80e-3);
        m.record_failure(Priority::High);
        m.record_enqueued(Priority::Batch, 7);
        let s = m.snapshot();
        let high = &s.tiers[Priority::High.index()];
        assert_eq!(high.tier, "high");
        assert_eq!((high.enqueued, high.completed, high.failed, high.rejected), (3, 3, 1, 1));
        assert!((high.rejection_rate() - 0.25).abs() < 1e-12);
        // p50 ~= 11 us (log2 bucket midpoints): well under 1 ms.
        assert!(high.total_p50_s > 10e-6 && high.total_p50_s < 1e-3, "{}", high.total_p50_s);
        // p99 lands in the slow request's bucket: tens of milliseconds.
        assert!(high.total_p99_s > 10e-3 && high.total_p99_s < 1.0, "{}", high.total_p99_s);
        assert!(high.queue_p50_s < high.total_p50_s);
        assert_eq!(s.tiers[Priority::Batch.index()].enqueued, 7);
        assert_eq!(s.tiers[Priority::Normal.index()].tier, "normal");
        assert_eq!(s.tiers[Priority::Normal.index()].completed, 0);
        assert_eq!(s.tiers[Priority::Normal.index()].rejection_rate(), 0.0, "0/0 is 0");
        m.reset();
        assert_eq!(m.snapshot().tiers[Priority::High.index()].completed, 0);
    }

    #[test]
    fn latency_histogram_quantile_edges() {
        let mut h = LatencyHistogram::default();
        assert_eq!(h.quantile(0.5), 0.0, "empty histogram reports 0");
        h.record(0.0); // sub-microsecond bucket
        assert!(h.quantile(0.5) > 0.0 && h.quantile(0.5) < 1e-6);
        let mut h = LatencyHistogram::default();
        for _ in 0..99 {
            h.record(1e-6);
        }
        h.record(1.0);
        assert!(h.quantile(0.5) < 1e-5);
        assert!(h.quantile(0.99) < 1e-5, "99th of 100 is still the fast bucket");
        assert!(h.quantile(1.0) > 0.5, "max lands in the 1 s bucket");
        // Monotone in q.
        assert!(h.quantile(0.5) <= h.quantile(0.99));
    }

    #[test]
    fn accuracy_tier_counters() {
        let m = Metrics::default();
        // A guaranteed request runs its full 28-pair schedule.
        m.record_tier(AccuracyTier::GuaranteedFp64, 28, 0, false);
        // A fast request runs the 10 kept pairs and skips 18.
        m.record_tier(AccuracyTier::Fp64FaithfulFast, 10, 18, false);
        // A fast request at a tiny window escalates: full schedule, no
        // skips, escalation counted.
        m.record_tier(AccuracyTier::Fp64FaithfulFast, 6, 0, true);
        // A native fallback at the fp32 tier executes no pairs at all.
        m.record_tier(AccuracyTier::Fp32Grade, 0, 0, false);
        let s = m.snapshot();
        assert_eq!(s.tier_requests, [1, 2, 1]);
        assert_eq!(s.pairs_executed, 44);
        assert_eq!(s.pairs_skipped, 18);
        assert_eq!(s.tier_escalations, 1);
        // Orthogonal to the priority axis: no service tier was touched.
        assert_eq!(s.tiers[Priority::Normal.index()].enqueued, 0);
        m.reset();
        assert_eq!(m.snapshot().tier_requests, [0, 0, 0]);
        assert_eq!(m.snapshot().tier_escalations, 0);
    }

    #[test]
    fn reset_clears() {
        let m = Metrics::default();
        m.record(&outcome(GemmDecision::FallbackEsc { esc: 99 }));
        m.reset();
        assert_eq!(m.snapshot().requests, 0);
    }
}
