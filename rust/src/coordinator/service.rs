//! Batched GEMM service: the deployment shape of ADP.
//!
//! A bounded request queue feeds N worker threads, each running an
//! [`AdpEngine`] against shared [`Metrics`] and (optionally) the shared
//! PJRT runtime handle. This is the "cuBLAS behind a production queue"
//! integration the paper targets (§5.4/§8.2), adapted to std threads
//! (tokio is unavailable offline; the request path is CPU-bound anyway).
//!
//! All workers share **one** compute backend (and therefore one thread
//! pool, see `backend::pool`): a lone request can fan its slice pairs and
//! tiles across the whole machine, while a saturated queue degrades each
//! worker to inline execution instead of oversubscribing cores with
//! N workers × T oblivious threads.
//!
//! ## Coalescing dispatcher
//!
//! With [`ServiceConfig::coalesce`] enabled (or via [`GemmService::submit_batch`],
//! which always groups), workers batch requests before execution: a worker
//! that dequeues a request keeps draining the queue for a small
//! micro-batching window (`coalesce_window`, up to `max_batch` requests),
//! buckets what it collected by (m, k, n) shape, and runs each bucket
//! through [`AdpEngine::gemm_grouped`] — one fused backend schedule per
//! bucket, with operand decompositions shared through the service-wide
//! [`SliceCache`] and ESC reductions through the [`EscPlanCache`].
//! Grouped results are bitwise identical to the per-request path.

use std::collections::HashMap;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use super::adp::{AdpConfig, AdpEngine, AdpOutcome};
use super::heuristic::SelectionHeuristic;
use super::metrics::Metrics;
use super::plan::EscPlanCache;
use crate::backend::{BackendSpec, WorkspacePool};
use crate::linalg::Matrix;
use crate::ozaki::batched::SliceCache;
use crate::ozaki::SliceEncoding;
use crate::runtime::RuntimeHandle;

/// One GEMM request.
pub struct GemmRequest {
    pub a: Matrix,
    pub b: Matrix,
    reply: Sender<GemmResponse>,
    submitted: Instant,
}

/// Completed response with queueing/processing latency.
pub struct GemmResponse {
    pub c: Matrix,
    pub outcome: AdpOutcome,
    pub queue_s: f64,
    pub total_s: f64,
}

/// What travels through the bounded queue: a single request, or an
/// explicit group from [`GemmService::submit_batch`] (always coalesced,
/// regardless of the `coalesce` flag).
enum QueueItem {
    One(GemmRequest),
    Batch(Vec<GemmRequest>),
}

/// Why a submission was rejected.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The service was shut down (or every worker died); the request
    /// queue is closed. Permanent — retrying cannot succeed.
    ServiceStopped,
    /// The bounded queue is full right now. Transient backpressure:
    /// retry later, shed load, or use the blocking [`GemmService::submit`].
    /// Only [`GemmService::try_submit`] reports this.
    QueueFull,
}

impl SubmitError {
    /// Whether a later retry can succeed.
    pub fn is_retryable(&self) -> bool {
        matches!(self, SubmitError::QueueFull)
    }
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::ServiceStopped => write!(f, "gemm service stopped"),
            SubmitError::QueueFull => write!(f, "gemm service queue full"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// A rejected non-blocking submission: the error plus the operands, handed
/// back so the caller can retry without cloning up front.
#[derive(Debug)]
pub struct RejectedSubmit {
    pub error: SubmitError,
    pub a: Matrix,
    pub b: Matrix,
}

/// Service configuration. The heuristic/encoding mirror [`AdpConfig`];
/// each worker constructs its own engine from a factory closure because
/// `SelectionHeuristic` boxes are not `Clone`.
pub struct ServiceConfig {
    pub workers: usize,
    pub queue_depth: usize,
    pub target_mantissa: i32,
    pub max_slices: usize,
    pub encoding: SliceEncoding,
    pub esc_block: usize,
    pub use_artifacts: bool,
    /// Compute backend shared by all workers (one pool for the whole
    /// service). Bitwise identical across variants; default is the
    /// machine-sized parallel backend.
    pub backend: BackendSpec,
    /// Coalesce individually-submitted requests: a worker drains the
    /// queue for `coalesce_window` (up to `max_batch` requests), buckets
    /// by shape and executes each bucket as one grouped schedule.
    /// `submit_batch` groups are coalesced regardless of this flag.
    pub coalesce: bool,
    /// Micro-batching window a worker waits to fill a batch.
    pub coalesce_window: Duration,
    /// Max requests coalesced into one group.
    pub max_batch: usize,
    /// Resident decompositions in the service-wide [`SliceCache`].
    pub slice_cache_entries: usize,
    /// Resident plans in the service-wide [`EscPlanCache`].
    pub plan_cache_entries: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8),
            queue_depth: 256,
            target_mantissa: 53,
            max_slices: 26,
            encoding: SliceEncoding::Unsigned,
            esc_block: crate::esc::coarse::DEFAULT_BLOCK,
            use_artifacts: true,
            backend: BackendSpec::auto(),
            coalesce: false,
            coalesce_window: Duration::from_micros(200),
            max_batch: 16,
            slice_cache_entries: 32,
            plan_cache_entries: 64,
        }
    }
}

/// Handle to the running service; submission and shutdown are
/// thread-safe through `&self`, so the handle can be shared (e.g. in an
/// `Arc`) between submitters and a controller racing them.
pub struct GemmService {
    tx: Mutex<Option<SyncSender<QueueItem>>>,
    pub metrics: Arc<Metrics>,
    inflight: Arc<AtomicU64>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl GemmService {
    /// Start the service. `heuristic_factory` is invoked once per worker.
    pub fn start(
        cfg: ServiceConfig,
        runtime: Option<RuntimeHandle>,
        heuristic_factory: impl Fn() -> Box<dyn SelectionHeuristic>,
    ) -> GemmService {
        let metrics = Arc::new(Metrics::default());
        let (tx, rx) = mpsc::sync_channel::<QueueItem>(cfg.queue_depth);
        let rx = Arc::new(Mutex::new(rx));
        let inflight = Arc::new(AtomicU64::new(0));
        // One backend (=> one thread pool), one cache pair and one
        // workspace pool shared by every worker: the whole service
        // amortizes together, and steady-state traffic recycles the same
        // scratch buffers instead of allocating per request.
        let backend = cfg.backend.build();
        let plan_cache = Arc::new(EscPlanCache::new(cfg.plan_cache_entries));
        let slice_cache = Arc::new(SliceCache::new(cfg.slice_cache_entries));
        let workspace_pool = Arc::new(WorkspacePool::new());
        let mut workers = Vec::new();
        for wid in 0..cfg.workers.max(1) {
            let rx = rx.clone();
            let metrics = metrics.clone();
            let inflight = inflight.clone();
            let engine_cfg = AdpConfig {
                target_mantissa: cfg.target_mantissa,
                max_slices: cfg.max_slices,
                encoding: cfg.encoding,
                esc_block: cfg.esc_block,
                heuristic: heuristic_factory(),
                runtime: runtime.clone(),
                use_artifacts: cfg.use_artifacts,
                backend: backend.clone(),
                plan_cache: Some(plan_cache.clone()),
                slice_cache: Some(slice_cache.clone()),
                workspace_pool: workspace_pool.clone(),
            };
            let knobs = CoalesceKnobs {
                coalesce: cfg.coalesce,
                window: cfg.coalesce_window,
                max_batch: cfg.max_batch.max(1),
            };
            workers.push(
                std::thread::Builder::new()
                    .name(format!("adp-worker-{wid}"))
                    .spawn(move || worker_main(rx, engine_cfg, metrics, inflight, knobs))
                    .expect("spawn worker"),
            );
        }
        GemmService {
            tx: Mutex::new(Some(tx)),
            metrics,
            inflight,
            workers: Mutex::new(workers),
        }
    }

    /// Clone the live sender, or fail if the service was shut down.
    fn sender(&self) -> Result<SyncSender<QueueItem>, SubmitError> {
        self.tx.lock().unwrap().clone().ok_or(SubmitError::ServiceStopped)
    }

    /// Submit a request; returns the receiver for its response, or
    /// [`SubmitError::ServiceStopped`] when the queue is closed.
    /// Blocks when the queue is full (backpressure).
    pub fn submit(&self, a: Matrix, b: Matrix) -> Result<Receiver<GemmResponse>, SubmitError> {
        let tx = self.sender()?;
        let (rtx, rrx) = channel();
        self.inflight.fetch_add(1, Ordering::SeqCst);
        match tx.send(QueueItem::One(GemmRequest {
            a,
            b,
            reply: rtx,
            submitted: Instant::now(),
        })) {
            Ok(()) => Ok(rrx),
            Err(_) => {
                self.inflight.fetch_sub(1, Ordering::SeqCst);
                Err(SubmitError::ServiceStopped)
            }
        }
    }

    /// Non-blocking submit. A full queue is reported as the *retryable*
    /// [`SubmitError::QueueFull`] with the operands handed back, instead
    /// of blocking the caller or conflating backpressure with shutdown.
    pub fn try_submit(
        &self,
        a: Matrix,
        b: Matrix,
    ) -> Result<Receiver<GemmResponse>, RejectedSubmit> {
        let tx = match self.sender() {
            Ok(tx) => tx,
            Err(error) => return Err(RejectedSubmit { error, a, b }),
        };
        let (rtx, rrx) = channel();
        self.inflight.fetch_add(1, Ordering::SeqCst);
        let item = QueueItem::One(GemmRequest { a, b, reply: rtx, submitted: Instant::now() });
        match tx.try_send(item) {
            Ok(()) => Ok(rrx),
            Err(e) => {
                self.inflight.fetch_sub(1, Ordering::SeqCst);
                let (error, item) = match e {
                    TrySendError::Full(item) => (SubmitError::QueueFull, item),
                    TrySendError::Disconnected(item) => (SubmitError::ServiceStopped, item),
                };
                let QueueItem::One(req) = item else { unreachable!("sent a One") };
                Err(RejectedSubmit { error, a: req.a, b: req.b })
            }
        }
    }

    /// Submit a group of requests that should be executed together: the
    /// group travels the queue as one item and is shape-bucketed and run
    /// through the grouped pipeline by a single worker, sharing operand
    /// decompositions via the service slice cache. Blocks when the queue
    /// is full. Receivers are returned in submission order.
    pub fn submit_batch(
        &self,
        pairs: Vec<(Matrix, Matrix)>,
    ) -> Result<Vec<Receiver<GemmResponse>>, SubmitError> {
        if pairs.is_empty() {
            return Ok(Vec::new());
        }
        let tx = self.sender()?;
        let n = pairs.len() as u64;
        let submitted = Instant::now();
        let mut reqs = Vec::with_capacity(pairs.len());
        let mut rxs = Vec::with_capacity(pairs.len());
        for (a, b) in pairs {
            let (rtx, rrx) = channel();
            reqs.push(GemmRequest { a, b, reply: rtx, submitted });
            rxs.push(rrx);
        }
        self.inflight.fetch_add(n, Ordering::SeqCst);
        match tx.send(QueueItem::Batch(reqs)) {
            Ok(()) => Ok(rxs),
            Err(_) => {
                self.inflight.fetch_sub(n, Ordering::SeqCst);
                Err(SubmitError::ServiceStopped)
            }
        }
    }

    /// Convenience: submit and wait.
    pub fn gemm_blocking(&self, a: Matrix, b: Matrix) -> GemmResponse {
        self.submit(a, b).expect("service stopped").recv().expect("worker died")
    }

    pub fn inflight(&self) -> u64 {
        self.inflight.load(Ordering::SeqCst)
    }

    /// Stop accepting work, drain the queue and join the workers.
    /// Idempotent, and safe to race against concurrent `submit*` calls:
    /// a submission either lands before the close (and is served) or
    /// gets [`SubmitError::ServiceStopped`].
    pub fn shutdown(&self) {
        // Closing the queue: drop our sender; in-flight `submit` calls
        // holding a clone finish their send, then the channel disconnects
        // and workers drain what remains before exiting.
        self.tx.lock().unwrap().take();
        let workers: Vec<_> = {
            let mut g = self.workers.lock().unwrap();
            g.drain(..).collect()
        };
        for w in workers {
            let _ = w.join();
        }
    }
}

/// Decrements the inflight counter on drop, so a request that panics its
/// worker still leaves the counter accurate (it is no longer in flight —
/// it is dead).
struct InflightGuard<'a>(&'a AtomicU64);

impl Drop for InflightGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

#[derive(Clone, Copy)]
struct CoalesceKnobs {
    coalesce: bool,
    window: Duration,
    max_batch: usize,
}

fn worker_main(
    rx: Arc<Mutex<Receiver<QueueItem>>>,
    cfg: AdpConfig,
    metrics: Arc<Metrics>,
    inflight: Arc<AtomicU64>,
    knobs: CoalesceKnobs,
) {
    let engine = AdpEngine::with_metrics(cfg, metrics.clone());
    loop {
        // Hold the lock only while dequeuing so workers pull concurrently.
        let item = match rx.lock().unwrap().recv() {
            Ok(r) => r,
            Err(_) => break, // service dropped
        };
        match item {
            QueueItem::Batch(reqs) => process_group(&engine, reqs, &metrics, &inflight),
            QueueItem::One(req) => {
                if !knobs.coalesce {
                    process_single(&engine, req, &inflight);
                    continue;
                }
                // Micro-batching: keep draining for the window. Holding
                // the queue lock here is deliberate — this worker is the
                // coalescer for the window; an empty drain just means it
                // processes its one request.
                let mut batch = vec![req];
                let deadline = Instant::now() + knobs.window;
                {
                    let g = rx.lock().unwrap();
                    while batch.len() < knobs.max_batch {
                        let now = Instant::now();
                        if now >= deadline {
                            break;
                        }
                        match g.recv_timeout(deadline - now) {
                            Ok(QueueItem::One(r)) => batch.push(r),
                            Ok(QueueItem::Batch(rs)) => {
                                batch.extend(rs);
                                break;
                            }
                            Err(_) => break, // timeout or disconnect
                        }
                    }
                }
                if batch.len() == 1 {
                    process_single(&engine, batch.pop().expect("len checked"), &inflight);
                } else {
                    process_group(&engine, batch, &metrics, &inflight);
                }
            }
        }
    }
}

fn process_single(engine: &AdpEngine, req: GemmRequest, inflight: &AtomicU64) {
    let queue_s = req.submitted.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let (c, outcome) = {
        // Scope the guard so the decrement lands before the reply is
        // sent (a caller seeing its response must see inflight drop),
        // while a panic in the engine still decrements during unwind.
        let _guard = InflightGuard(inflight);
        engine.gemm(&req.a, &req.b)
    };
    let total_s = queue_s + t0.elapsed().as_secs_f64();
    let _ = req.reply.send(GemmResponse { c, outcome, queue_s, total_s });
}

fn process_group(
    engine: &AdpEngine,
    reqs: Vec<GemmRequest>,
    metrics: &Metrics,
    inflight: &AtomicU64,
) {
    // Shape-mismatched requests cannot enter a grouped schedule; drop
    // their reply senders (the caller's recv fails, mirroring the
    // per-request poison behavior) without killing the worker or the
    // rest of the group.
    let (valid, invalid): (Vec<GemmRequest>, Vec<GemmRequest>) =
        reqs.into_iter().partition(|r| r.a.cols == r.b.rows);
    for req in invalid {
        let _guard = InflightGuard(inflight);
        drop(req);
    }
    if valid.is_empty() {
        return;
    }
    // Bucket by shape: plan-cache keys repeat within a bucket and the
    // grouped schedule stays load-balanced.
    let mut buckets: HashMap<(usize, usize, usize), Vec<GemmRequest>> = HashMap::new();
    for req in valid {
        buckets.entry((req.a.rows, req.a.cols, req.b.cols)).or_default().push(req);
    }
    // Deterministic bucket order (HashMap iteration order is arbitrary).
    let mut buckets: Vec<_> = buckets.into_values().collect();
    buckets.sort_by_key(|reqs| (reqs[0].a.rows, reqs[0].a.cols, reqs[0].b.cols));
    for bucket in buckets {
        metrics.record_coalesced_batch(bucket.len() as u64);
        // One guard per request, held across the grouped call: a panic
        // inside the engine unwinds through them, so the bucket cannot
        // leak inflight counts (mirrors process_single's guard scope).
        let mut guards: Vec<InflightGuard<'_>> =
            bucket.iter().map(|_| InflightGuard(inflight)).collect();
        let t0 = Instant::now();
        let probs: Vec<(&Matrix, &Matrix)> = bucket.iter().map(|r| (&r.a, &r.b)).collect();
        let results = engine.gemm_grouped(&probs);
        let proc_s = t0.elapsed().as_secs_f64();
        for (req, (c, outcome)) in bucket.iter().zip(results) {
            drop(guards.pop()); // decrement lands before the reply is sent
            let queue_s = req.submitted.elapsed().as_secs_f64() - proc_s;
            let total_s = queue_s + proc_s;
            let _ = req.reply.send(GemmResponse { c, outcome, queue_s: queue_s.max(0.0), total_s });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::heuristic::{AlwaysEmulate, HeuristicInput};
    use crate::linalg::gemm;
    use crate::util::{prop, Rng};
    use std::sync::atomic::AtomicBool;
    use std::sync::Condvar;

    fn small_service(workers: usize) -> GemmService {
        let cfg = ServiceConfig { workers, use_artifacts: false, ..Default::default() };
        GemmService::start(cfg, None, || Box::new(AlwaysEmulate))
    }

    #[test]
    fn serves_correct_results() {
        let svc = small_service(2);
        let mut rng = Rng::new(90);
        let a = Matrix::uniform(16, 16, -1.0, 1.0, &mut rng);
        let b = Matrix::uniform(16, 16, -1.0, 1.0, &mut rng);
        let resp = svc.gemm_blocking(a.clone(), b.clone());
        let err = resp.c.sub(&gemm(&a, &b)).max_abs();
        assert!(err < 1e-12, "err={err}");
        assert!(resp.outcome.decision.is_emulated());
        svc.shutdown();
    }

    #[test]
    fn parallel_requests_all_complete() {
        let svc = small_service(4);
        let mut rng = Rng::new(91);
        let mut pending = Vec::new();
        let mut expects = Vec::new();
        for _ in 0..24 {
            let n = 4 + rng.index(12);
            let a = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
            let b = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
            expects.push(gemm(&a, &b));
            pending.push(svc.submit(a, b).expect("service running"));
        }
        for (rx, expect) in pending.into_iter().zip(expects) {
            let resp = rx.recv().unwrap();
            assert!(resp.c.sub(&expect).max_abs() < 1e-12);
        }
        assert_eq!(svc.metrics.snapshot().requests, 24);
        assert_eq!(svc.inflight(), 0);
        svc.shutdown();
    }

    #[test]
    fn serial_and_parallel_service_agree_bitwise() {
        // The backend choice is invisible in the results — the whole
        // service stack must be bitwise deterministic either way.
        let mk = |backend| {
            let cfg =
                ServiceConfig { workers: 2, use_artifacts: false, backend, ..Default::default() };
            GemmService::start(cfg, None, || Box::new(AlwaysEmulate))
        };
        let svc_ser = mk(BackendSpec::Serial);
        let svc_par = mk(BackendSpec::Parallel { threads: 4 });
        let mut rng = Rng::new(93);
        let a = Matrix::uniform(24, 24, -1.0, 1.0, &mut rng);
        let b = Matrix::uniform(24, 24, -1.0, 1.0, &mut rng);
        let c_ser = svc_ser.gemm_blocking(a.clone(), b.clone()).c;
        let c_par = svc_par.gemm_blocking(a, b).c;
        for (x, y) in c_ser.data.iter().zip(&c_par.data) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        svc_ser.shutdown();
        svc_par.shutdown();
    }

    #[test]
    fn warm_service_serves_repeat_shapes_with_zero_fresh_workspaces() {
        // Acceptance criterion of the workspace satellite: once warm, a
        // service sees repeat shapes without a single fresh scratch
        // allocation — checkouts and fused tiles keep climbing, the
        // fresh-allocation gauge stays flat.
        let svc = small_service(2);
        let mut rng = Rng::new(99);
        let mk = |rng: &mut Rng| {
            (Matrix::uniform(16, 16, -1.0, 1.0, rng), Matrix::uniform(16, 16, -1.0, 1.0, rng))
        };
        for _ in 0..4 {
            let (a, b) = mk(&mut rng);
            let resp = svc.gemm_blocking(a, b);
            assert!(resp.outcome.decision.is_emulated());
        }
        let warm = svc.metrics.snapshot();
        assert!(warm.workspace_checkouts >= 4, "one checkout per fused request: {warm:?}");
        assert!(warm.fused_tiles >= 4, "each 16x16 request runs one fused tile: {warm:?}");
        assert!(warm.workspace_fresh >= 1, "cold pool must have allocated once");
        for _ in 0..6 {
            let (a, b) = mk(&mut rng);
            svc.gemm_blocking(a, b);
        }
        let after = svc.metrics.snapshot();
        assert!(after.workspace_checkouts >= warm.workspace_checkouts + 6);
        assert!(after.fused_tiles >= warm.fused_tiles + 6);
        assert_eq!(
            after.workspace_fresh, warm.workspace_fresh,
            "warm service must serve repeat shapes with zero fresh workspace allocations"
        );
        svc.shutdown();
    }

    #[test]
    fn submit_reports_stopped_service() {
        // Poison pill: a shape-mismatched request panics the only worker;
        // once it is gone the queue closes and submit must return Err
        // instead of panicking the caller.
        let svc = small_service(1);
        let bad = svc.submit(Matrix::zeros(2, 3), Matrix::zeros(4, 2)).expect("queue open");
        assert!(bad.recv().is_err(), "poisoned request must get no reply");
        // The panicked request is no longer in flight (guard decrements
        // during unwind); only later race-window submissions may linger.
        assert_eq!(svc.inflight(), 0, "dead request must not leak the inflight counter");
        let mut stopped = false;
        for _ in 0..400 {
            match svc.submit(Matrix::identity(2), Matrix::identity(2)) {
                Err(SubmitError::ServiceStopped) => {
                    stopped = true;
                    break;
                }
                Err(e) => panic!("unexpected submit error {e}"),
                Ok(_) => std::thread::sleep(std::time::Duration::from_millis(5)),
            }
        }
        assert!(stopped, "submit must fail once the last worker is gone");
    }

    #[test]
    fn shutdown_then_submit_reports_stopped() {
        let svc = small_service(2);
        svc.shutdown();
        assert_eq!(
            svc.submit(Matrix::identity(2), Matrix::identity(2)).err(),
            Some(SubmitError::ServiceStopped)
        );
        let rej = svc.try_submit(Matrix::identity(2), Matrix::identity(2)).unwrap_err();
        assert_eq!(rej.error, SubmitError::ServiceStopped);
        assert!(!rej.error.is_retryable());
        assert_eq!((rej.a.rows, rej.b.rows), (2, 2), "operands returned for inspection");
        assert_eq!(svc.submit_batch(vec![]).unwrap().len(), 0, "empty batch is trivially ok");
        assert_eq!(
            svc.submit_batch(vec![(Matrix::identity(2), Matrix::identity(2))]).err(),
            Some(SubmitError::ServiceStopped)
        );
        svc.shutdown(); // idempotent
        assert_eq!(svc.inflight(), 0);
    }

    /// Heuristic that parks its worker until the gate opens — makes the
    /// queue-full condition deterministic.
    struct GatedHeuristic {
        entered: Arc<AtomicBool>,
        gate: Arc<(Mutex<bool>, Condvar)>,
    }

    impl SelectionHeuristic for GatedHeuristic {
        fn emulate(&self, _: &HeuristicInput) -> bool {
            self.entered.store(true, Ordering::SeqCst);
            let (m, cv) = &*self.gate;
            let mut open = m.lock().unwrap();
            while !*open {
                open = cv.wait(open).unwrap();
            }
            true
        }
        fn name(&self) -> &'static str {
            "gated"
        }
    }

    #[test]
    fn try_submit_reports_queue_full_and_recovers() {
        let entered = Arc::new(AtomicBool::new(false));
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let cfg = ServiceConfig {
            workers: 1,
            queue_depth: 1,
            use_artifacts: false,
            ..Default::default()
        };
        let svc = {
            let (entered, gate) = (entered.clone(), gate.clone());
            GemmService::start(cfg, None, move || {
                Box::new(GatedHeuristic { entered: entered.clone(), gate: gate.clone() })
            })
        };
        let mk = || (Matrix::identity(4), Matrix::identity(4));
        // First request: picked up by the worker, parked in the heuristic.
        let (a, b) = mk();
        let rx1 = svc.submit(a, b).expect("queue open");
        while !entered.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(1));
        }
        // Second request: fills the queue slot.
        let (a, b) = mk();
        let rx2 = svc.submit(a, b).expect("queue open");
        // Third: the queue is full — retryable backpressure, not fatal.
        let (a, b) = mk();
        let rej = svc.try_submit(a, b).unwrap_err();
        assert_eq!(rej.error, SubmitError::QueueFull);
        assert!(rej.error.is_retryable());
        // Open the gate; the backlog drains and the retry succeeds.
        {
            let (m, cv) = &*gate;
            *m.lock().unwrap() = true;
            cv.notify_all();
        }
        assert!(rx1.recv().is_ok());
        assert!(rx2.recv().is_ok());
        let rx3 = svc
            .try_submit(rej.a, rej.b)
            .map_err(|r| r.error)
            .expect("retry after drain succeeds");
        assert!(rx3.recv().is_ok());
        assert_eq!(svc.inflight(), 0);
        svc.shutdown();
    }

    #[test]
    fn submit_batch_amortizes_shared_operand() {
        // Acceptance criterion: N same-A requests through submit_batch
        // perform exactly 1 decomposition of A (and N of B), bitwise
        // identical to the per-request path.
        let n_reqs = 5;
        let svc = small_service(2);
        let mut rng = Rng::new(94);
        // Entries in [1, 2): every request's ESC (and hence slice count)
        // is identical, so the shared A maps to exactly one cache key.
        let a = Matrix::uniform(16, 16, 1.0, 2.0, &mut rng);
        let bs: Vec<Matrix> =
            (0..n_reqs).map(|_| Matrix::uniform(16, 16, 1.0, 2.0, &mut rng)).collect();
        let pairs: Vec<(Matrix, Matrix)> =
            bs.iter().map(|b| (a.clone(), b.clone())).collect();
        let rxs = svc.submit_batch(pairs).expect("service running");
        let grouped: Vec<Matrix> = rxs.into_iter().map(|rx| rx.recv().unwrap().c).collect();
        let snap = svc.metrics.snapshot();
        assert_eq!(snap.slice_cache_misses, n_reqs as u64 + 1, "A once + N Bs");
        assert_eq!(snap.slice_cache_hits, n_reqs as u64 - 1, "A reused N-1 times");
        assert_eq!(snap.coalesced_batches, 1);
        assert_eq!(snap.coalesced_requests, n_reqs as u64);
        assert_eq!(snap.requests, n_reqs as u64);
        assert_eq!(svc.inflight(), 0);
        // Bitwise identity against the per-request service path.
        let svc_ref = small_service(1);
        for (b, c) in bs.iter().zip(&grouped) {
            let c_ref = svc_ref.gemm_blocking(a.clone(), b.clone()).c;
            for (x, y) in c.data.iter().zip(&c_ref.data) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        svc_ref.shutdown();
        svc.shutdown();
    }

    #[test]
    fn submit_batch_mixed_shapes_bucketed() {
        let svc = small_service(2);
        let mut rng = Rng::new(95);
        let mut pairs = Vec::new();
        let mut expects = Vec::new();
        for i in 0..6 {
            let n = if i % 2 == 0 { 8 } else { 12 };
            let a = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
            let b = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
            expects.push(gemm(&a, &b));
            pairs.push((a, b));
        }
        let rxs = svc.submit_batch(pairs).expect("service running");
        for (rx, expect) in rxs.into_iter().zip(expects) {
            let resp = rx.recv().unwrap();
            assert!(resp.c.sub(&expect).max_abs() < 1e-12);
            assert!(resp.outcome.decision.is_emulated());
        }
        let snap = svc.metrics.snapshot();
        assert_eq!(snap.coalesced_batches, 2, "two shape buckets");
        assert_eq!(snap.coalesced_requests, 6);
        assert_eq!(svc.inflight(), 0);
        svc.shutdown();
    }

    #[test]
    fn batched_shape_mismatch_drops_reply_not_worker() {
        let svc = small_service(1);
        let mut rng = Rng::new(96);
        let a = Matrix::uniform(6, 6, -1.0, 1.0, &mut rng);
        let b = Matrix::uniform(6, 6, -1.0, 1.0, &mut rng);
        let rxs = svc
            .submit_batch(vec![
                (a.clone(), b.clone()),
                (Matrix::zeros(2, 3), Matrix::zeros(4, 2)), // mismatched
                (a.clone(), b.clone()),
            ])
            .expect("service running");
        assert!(rxs[0].recv().is_ok());
        assert!(rxs[1].recv().is_err(), "mismatched request gets no reply");
        assert!(rxs[2].recv().is_ok());
        assert_eq!(svc.inflight(), 0);
        // The worker survived: new submissions still work.
        assert!(svc.submit(a, b).is_ok());
        svc.shutdown();
    }

    #[test]
    fn coalesced_service_agrees_bitwise_with_uncoalesced() {
        let mk = |coalesce| {
            let cfg = ServiceConfig {
                workers: 2,
                use_artifacts: false,
                coalesce,
                coalesce_window: Duration::from_millis(5),
                ..Default::default()
            };
            GemmService::start(cfg, None, || Box::new(AlwaysEmulate))
        };
        let svc_c = mk(true);
        let svc_u = mk(false);
        let mut rng = Rng::new(97);
        let a = Matrix::uniform(20, 20, -1.0, 1.0, &mut rng);
        let bs: Vec<Matrix> =
            (0..8).map(|_| Matrix::uniform(20, 20, -1.0, 1.0, &mut rng)).collect();
        let pend_c: Vec<_> =
            bs.iter().map(|b| svc_c.submit(a.clone(), b.clone()).unwrap()).collect();
        let pend_u: Vec<_> =
            bs.iter().map(|b| svc_u.submit(a.clone(), b.clone()).unwrap()).collect();
        for (rc, ru) in pend_c.into_iter().zip(pend_u) {
            let (cc, cu) = (rc.recv().unwrap().c, ru.recv().unwrap().c);
            for (x, y) in cc.data.iter().zip(&cu.data) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        assert_eq!(svc_c.metrics.snapshot().requests, 8);
        svc_c.shutdown();
        svc_u.shutdown();
    }

    #[test]
    fn prop_request_response_bijection() {
        // Every response matches *its own* request (no cross-wiring),
        // verified by tagging requests with distinguishable scalings —
        // through both the singleton and the batched submission paths.
        let svc = small_service(3);
        prop::check("service bijection", 8, |rng| {
            let mut pending = Vec::new();
            let mut batch = Vec::new();
            for tag in 1..=6u32 {
                let scale = tag as f64;
                let a = Matrix::from_fn(4, 4, |i, j| {
                    scale * ((i * 4 + j) as f64 + 1.0) + rng.f64() * 0.0
                });
                let b = Matrix::identity(4);
                if tag % 2 == 0 {
                    batch.push((scale, a, b));
                } else {
                    let rx = svc.submit(a, b).expect("service running");
                    pending.push((scale, rx));
                }
            }
            let scales: Vec<f64> = batch.iter().map(|(s, _, _)| *s).collect();
            let pairs: Vec<(Matrix, Matrix)> =
                batch.into_iter().map(|(_, a, b)| (a, b)).collect();
            let rxs = svc.submit_batch(pairs).expect("service running");
            pending.extend(scales.into_iter().zip(rxs));
            for (scale, rx) in pending {
                let resp = rx.recv().unwrap();
                if (resp.c.at(0, 0) - scale).abs() > 1e-12 {
                    return Err(format!("response mismatch: {} vs {scale}", resp.c.at(0, 0)));
                }
            }
            Ok(())
        });
        svc.shutdown();
    }

    #[test]
    fn mixed_workload_outcome_accounting() {
        let svc = small_service(2);
        let mut rng = Rng::new(92);
        let mut pending = Vec::new();
        for i in 0..12 {
            let mut a = Matrix::uniform(8, 8, 1.0, 2.0, &mut rng);
            let mut b = Matrix::uniform(8, 8, 1.0, 2.0, &mut rng);
            if i % 4 == 1 {
                *a.at_mut(0, 0) = f64::NAN;
            }
            if i % 4 == 2 {
                *a.at_mut(0, 0) = f64::INFINITY;
            }
            if i % 4 == 3 {
                // huge-x-pairs-with-tiny-y: ESC beyond the slice budget
                *a.at_mut(0, 0) = 1e300;
                *b.at_mut(0, 0) = 1e-300;
            }
            pending.push(svc.submit(a, b).expect("service running"));
        }
        for rx in pending {
            rx.recv().unwrap();
        }
        let s = svc.metrics.snapshot();
        assert_eq!(s.requests, 12);
        assert_eq!(s.fallback_nan, 3);
        assert_eq!(s.fallback_inf, 3);
        assert_eq!(s.fallback_esc, 3);
        assert_eq!(s.emulated, 3);
        svc.shutdown();
    }

    #[test]
    fn mixed_workload_accounting_through_submit_batch() {
        // The grouped path must preserve the per-request guardrail
        // accounting exactly.
        let svc = small_service(2);
        let mut rng = Rng::new(98);
        let mut pairs = Vec::new();
        for i in 0..8 {
            let mut a = Matrix::uniform(8, 8, 1.0, 2.0, &mut rng);
            let b = Matrix::uniform(8, 8, 1.0, 2.0, &mut rng);
            if i % 4 == 1 {
                *a.at_mut(0, 0) = f64::NAN;
            }
            pairs.push((a, b));
        }
        let rxs = svc.submit_batch(pairs).expect("service running");
        for rx in rxs {
            rx.recv().unwrap();
        }
        let s = svc.metrics.snapshot();
        assert_eq!(s.requests, 8);
        assert_eq!(s.fallback_nan, 2);
        assert_eq!(s.emulated, 6);
        assert_eq!(svc.inflight(), 0);
        svc.shutdown();
    }
}
