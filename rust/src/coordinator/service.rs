//! Batched GEMM service: the deployment shape of ADP.
//!
//! A bounded request queue feeds N worker threads, each running an
//! [`AdpEngine`] against shared [`Metrics`] and (optionally) the shared
//! PJRT runtime handle. This is the "cuBLAS behind a production queue"
//! integration the paper targets (§5.4/§8.2), adapted to std threads
//! (tokio is unavailable offline; the request path is CPU-bound anyway).
//!
//! All workers share **one** compute backend (and therefore one thread
//! pool, see `backend::pool`): a lone request can fan its slice pairs and
//! tiles across the whole machine, while a saturated queue degrades each
//! worker to inline execution instead of oversubscribing cores with
//! N workers × T oblivious threads.

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, SyncSender};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use super::adp::{AdpConfig, AdpEngine, AdpOutcome};
use super::heuristic::SelectionHeuristic;
use super::metrics::Metrics;
use crate::backend::BackendSpec;
use crate::linalg::Matrix;
use crate::ozaki::SliceEncoding;
use crate::runtime::RuntimeHandle;

/// One GEMM request.
pub struct GemmRequest {
    pub a: Matrix,
    pub b: Matrix,
    reply: Sender<GemmResponse>,
    submitted: Instant,
}

/// Completed response with queueing/processing latency.
pub struct GemmResponse {
    pub c: Matrix,
    pub outcome: AdpOutcome,
    pub queue_s: f64,
    pub total_s: f64,
}

/// Why a submission was rejected.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The service was shut down (or every worker died); the request
    /// queue is closed and the matrices were dropped.
    ServiceStopped,
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::ServiceStopped => write!(f, "gemm service stopped"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Service configuration. The heuristic/encoding mirror [`AdpConfig`];
/// each worker constructs its own engine from a factory closure because
/// `SelectionHeuristic` boxes are not `Clone`.
pub struct ServiceConfig {
    pub workers: usize,
    pub queue_depth: usize,
    pub target_mantissa: i32,
    pub max_slices: usize,
    pub encoding: SliceEncoding,
    pub esc_block: usize,
    pub use_artifacts: bool,
    /// Compute backend shared by all workers (one pool for the whole
    /// service). Bitwise identical across variants; default is the
    /// machine-sized parallel backend.
    pub backend: BackendSpec,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8),
            queue_depth: 256,
            target_mantissa: 53,
            max_slices: 26,
            encoding: SliceEncoding::Unsigned,
            esc_block: crate::esc::coarse::DEFAULT_BLOCK,
            use_artifacts: true,
            backend: BackendSpec::auto(),
        }
    }
}

/// Handle to the running service; cloneable, submission is thread-safe.
pub struct GemmService {
    tx: SyncSender<GemmRequest>,
    pub metrics: Arc<Metrics>,
    inflight: Arc<AtomicU64>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl GemmService {
    /// Start the service. `heuristic_factory` is invoked once per worker.
    pub fn start(
        cfg: ServiceConfig,
        runtime: Option<RuntimeHandle>,
        heuristic_factory: impl Fn() -> Box<dyn SelectionHeuristic>,
    ) -> GemmService {
        let metrics = Arc::new(Metrics::default());
        let (tx, rx) = mpsc::sync_channel::<GemmRequest>(cfg.queue_depth);
        let rx = Arc::new(Mutex::new(rx));
        let inflight = Arc::new(AtomicU64::new(0));
        // One backend (=> one thread pool) shared by every worker.
        let backend = cfg.backend.build();
        let mut workers = Vec::new();
        for wid in 0..cfg.workers.max(1) {
            let rx = rx.clone();
            let metrics = metrics.clone();
            let inflight = inflight.clone();
            let engine_cfg = AdpConfig {
                target_mantissa: cfg.target_mantissa,
                max_slices: cfg.max_slices,
                encoding: cfg.encoding,
                esc_block: cfg.esc_block,
                heuristic: heuristic_factory(),
                runtime: runtime.clone(),
                use_artifacts: cfg.use_artifacts,
                backend: backend.clone(),
            };
            workers.push(
                std::thread::Builder::new()
                    .name(format!("adp-worker-{wid}"))
                    .spawn(move || worker_main(rx, engine_cfg, metrics, inflight))
                    .expect("spawn worker"),
            );
        }
        GemmService { tx, metrics, inflight, workers }
    }

    /// Submit a request; returns the receiver for its response, or
    /// [`SubmitError::ServiceStopped`] when the queue is closed.
    /// Blocks when the queue is full (backpressure).
    pub fn submit(&self, a: Matrix, b: Matrix) -> Result<Receiver<GemmResponse>, SubmitError> {
        let (rtx, rrx) = channel();
        self.inflight.fetch_add(1, Ordering::SeqCst);
        match self.tx.send(GemmRequest { a, b, reply: rtx, submitted: Instant::now() }) {
            Ok(()) => Ok(rrx),
            Err(_) => {
                self.inflight.fetch_sub(1, Ordering::SeqCst);
                Err(SubmitError::ServiceStopped)
            }
        }
    }

    /// Convenience: submit and wait.
    pub fn gemm_blocking(&self, a: Matrix, b: Matrix) -> GemmResponse {
        self.submit(a, b).expect("service stopped").recv().expect("worker died")
    }

    pub fn inflight(&self) -> u64 {
        self.inflight.load(Ordering::SeqCst)
    }

    /// Stop accepting work and join the workers.
    pub fn shutdown(self) {
        drop(self.tx);
        for w in self.workers {
            let _ = w.join();
        }
    }
}

/// Decrements the inflight counter on drop, so a request that panics its
/// worker still leaves the counter accurate (it is no longer in flight —
/// it is dead).
struct InflightGuard<'a>(&'a AtomicU64);

impl Drop for InflightGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

fn worker_main(
    rx: Arc<Mutex<Receiver<GemmRequest>>>,
    cfg: AdpConfig,
    metrics: Arc<Metrics>,
    inflight: Arc<AtomicU64>,
) {
    let engine = AdpEngine::with_metrics(cfg, metrics);
    loop {
        // Hold the lock only while dequeuing so workers pull concurrently.
        let req = match rx.lock().unwrap().recv() {
            Ok(r) => r,
            Err(_) => break, // service dropped
        };
        let queue_s = req.submitted.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let (c, outcome) = {
            // Scope the guard so the decrement lands before the reply is
            // sent (a caller seeing its response must see inflight drop),
            // while a panic in the engine still decrements during unwind.
            let _guard = InflightGuard(&inflight);
            engine.gemm(&req.a, &req.b)
        };
        let total_s = queue_s + t0.elapsed().as_secs_f64();
        let _ = req.reply.send(GemmResponse { c, outcome, queue_s, total_s });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::heuristic::AlwaysEmulate;
    use crate::linalg::gemm;
    use crate::util::{prop, Rng};

    fn small_service(workers: usize) -> GemmService {
        let cfg = ServiceConfig { workers, use_artifacts: false, ..Default::default() };
        GemmService::start(cfg, None, || Box::new(AlwaysEmulate))
    }

    #[test]
    fn serves_correct_results() {
        let svc = small_service(2);
        let mut rng = Rng::new(90);
        let a = Matrix::uniform(16, 16, -1.0, 1.0, &mut rng);
        let b = Matrix::uniform(16, 16, -1.0, 1.0, &mut rng);
        let resp = svc.gemm_blocking(a.clone(), b.clone());
        let err = resp.c.sub(&gemm(&a, &b)).max_abs();
        assert!(err < 1e-12, "err={err}");
        assert!(resp.outcome.decision.is_emulated());
        svc.shutdown();
    }

    #[test]
    fn parallel_requests_all_complete() {
        let svc = small_service(4);
        let mut rng = Rng::new(91);
        let mut pending = Vec::new();
        let mut expects = Vec::new();
        for _ in 0..24 {
            let n = 4 + rng.index(12);
            let a = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
            let b = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
            expects.push(gemm(&a, &b));
            pending.push(svc.submit(a, b).expect("service running"));
        }
        for (rx, expect) in pending.into_iter().zip(expects) {
            let resp = rx.recv().unwrap();
            assert!(resp.c.sub(&expect).max_abs() < 1e-12);
        }
        assert_eq!(svc.metrics.snapshot().requests, 24);
        assert_eq!(svc.inflight(), 0);
        svc.shutdown();
    }

    #[test]
    fn serial_and_parallel_service_agree_bitwise() {
        // The backend choice is invisible in the results — the whole
        // service stack must be bitwise deterministic either way.
        let mk = |backend| {
            let cfg =
                ServiceConfig { workers: 2, use_artifacts: false, backend, ..Default::default() };
            GemmService::start(cfg, None, || Box::new(AlwaysEmulate))
        };
        let svc_ser = mk(BackendSpec::Serial);
        let svc_par = mk(BackendSpec::Parallel { threads: 4 });
        let mut rng = Rng::new(93);
        let a = Matrix::uniform(24, 24, -1.0, 1.0, &mut rng);
        let b = Matrix::uniform(24, 24, -1.0, 1.0, &mut rng);
        let c_ser = svc_ser.gemm_blocking(a.clone(), b.clone()).c;
        let c_par = svc_par.gemm_blocking(a, b).c;
        for (x, y) in c_ser.data.iter().zip(&c_par.data) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        svc_ser.shutdown();
        svc_par.shutdown();
    }

    #[test]
    fn submit_reports_stopped_service() {
        // Poison pill: a shape-mismatched request panics the only worker;
        // once it is gone the queue closes and submit must return Err
        // instead of panicking the caller.
        let svc = small_service(1);
        let bad = svc.submit(Matrix::zeros(2, 3), Matrix::zeros(4, 2)).expect("queue open");
        assert!(bad.recv().is_err(), "poisoned request must get no reply");
        // The panicked request is no longer in flight (guard decrements
        // during unwind); only later race-window submissions may linger.
        assert_eq!(svc.inflight(), 0, "dead request must not leak the inflight counter");
        let mut stopped = false;
        for _ in 0..400 {
            match svc.submit(Matrix::identity(2), Matrix::identity(2)) {
                Err(SubmitError::ServiceStopped) => {
                    stopped = true;
                    break;
                }
                Ok(_) => std::thread::sleep(std::time::Duration::from_millis(5)),
            }
        }
        assert!(stopped, "submit must fail once the last worker is gone");
    }

    #[test]
    fn prop_request_response_bijection() {
        // Every response matches *its own* request (no cross-wiring),
        // verified by tagging requests with distinguishable scalings.
        let svc = small_service(3);
        prop::check("service bijection", 8, |rng| {
            let mut pending = Vec::new();
            for tag in 1..=6u32 {
                let scale = tag as f64;
                let a = Matrix::from_fn(4, 4, |i, j| {
                    scale * ((i * 4 + j) as f64 + 1.0) + rng.f64() * 0.0
                });
                let b = Matrix::identity(4);
                let rx = svc.submit(a, b).expect("service running");
                pending.push((scale, rx));
            }
            for (scale, rx) in pending {
                let resp = rx.recv().unwrap();
                if (resp.c.at(0, 0) - scale).abs() > 1e-12 {
                    return Err(format!("response mismatch: {} vs {scale}", resp.c.at(0, 0)));
                }
            }
            Ok(())
        });
        svc.shutdown();
    }

    #[test]
    fn mixed_workload_outcome_accounting() {
        let svc = small_service(2);
        let mut rng = Rng::new(92);
        let mut pending = Vec::new();
        for i in 0..12 {
            let mut a = Matrix::uniform(8, 8, 1.0, 2.0, &mut rng);
            let mut b = Matrix::uniform(8, 8, 1.0, 2.0, &mut rng);
            if i % 4 == 1 {
                *a.at_mut(0, 0) = f64::NAN;
            }
            if i % 4 == 2 {
                *a.at_mut(0, 0) = f64::INFINITY;
            }
            if i % 4 == 3 {
                // huge-x-pairs-with-tiny-y: ESC beyond the slice budget
                *a.at_mut(0, 0) = 1e300;
                *b.at_mut(0, 0) = 1e-300;
            }
            pending.push(svc.submit(a, b).expect("service running"));
        }
        for rx in pending {
            rx.recv().unwrap();
        }
        let s = svc.metrics.snapshot();
        assert_eq!(s.requests, 12);
        assert_eq!(s.fallback_nan, 3);
        assert_eq!(s.fallback_inf, 3);
        assert_eq!(s.fallback_esc, 3);
        assert_eq!(s.emulated, 3);
        svc.shutdown();
    }
}
