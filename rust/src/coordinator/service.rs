//! Sharded batched GEMM service: the deployment shape of ADP.
//!
//! N **shard queues** feed per-shard worker pools, each shard running one
//! shared [`AdpEngine`] against service-wide [`Metrics`] and (optionally)
//! the shared PJRT runtime handle. This is the "cuBLAS behind a
//! production queue" integration the paper targets (§5.4/§8.2), adapted
//! to std threads (tokio is unavailable offline; the request path is
//! CPU-bound anyway).
//!
//! ## Sharding
//!
//! Requests are routed to a shard by a hash of their (m, k, n) shape
//! bucket, so repeat shapes land on the same shard and its slice-/plan-
//! cache locality survives the split (the caches themselves stay
//! service-wide — a shard is a *scheduling* domain, not a cache domain).
//! Each shard owns a slice of the compute budget
//! ([`BackendSpec::shard_slice`]): one worker-pool slice per shard, so a
//! saturated shard degrades itself instead of convoying its neighbors.
//!
//! ## Priority tiers and admission control
//!
//! Every submission carries a [`Priority`] (`High`/`Normal`/`Batch`).
//! Workers always drain higher tiers first, and each tier has its own
//! per-shard queue-depth cap ([`ServiceConfig::tier_depths`]) under the
//! shard-total cap ([`ServiceConfig::queue_depth`]): bulk `Batch` traffic
//! cannot starve interactive `High` admissions. Non-blocking submission
//! reports a full tier as the retryable [`SubmitError::TierFull`] and a
//! full shard as [`SubmitError::QueueFull`]; the blocking paths wait for
//! space. Per-tier latency/outcome accounting lands in
//! [`Metrics::snapshot`]'s `tiers`.
//!
//! ## Accuracy tiers
//!
//! Orthogonally to the scheduling priority, every request carries an
//! [`AccuracyTier`] — the accuracy/speed trade-off the engine runs it
//! at. The plain `submit*` paths use [`ServiceConfig::default_tier`]
//! (seeded from `ADP_TIER`); the `*_tiered` variants set it per
//! request. The tier is part of the coalescing bucket key, so a
//! mixed-tier group splits into one grouped schedule per (shape, tier)
//! and `GuaranteedFp64` members keep their bitwise guarantee regardless
//! of what they were batched with.
//!
//! ## Async submission
//!
//! [`GemmService::submit_async`] returns a pollable [`GemmTicket`];
//! [`GemmService::submit_callback`] invokes a completion callback from
//! the worker instead. Neither blocks the submitter.
//!
//! ## Error semantics
//!
//! No service path panics the submitting thread. Workers pre-validate
//! shapes and wrap the engine in `catch_unwind`, so a shape-mismatched
//! request or a panicking engine produces a typed [`GemmError`] response
//! on the reply channel — the worker survives and keeps serving. A reply
//! sender is *never* dropped silently: [`ReplySlot`]'s drop guard turns
//! any lost reply into [`GemmError::ReplyLost`].
//!
//! ## Coalescing dispatcher
//!
//! With [`ServiceConfig::coalesce`] enabled (or via
//! [`GemmService::submit_batch`], which always groups), workers batch
//! requests before execution: a worker that dequeues a request keeps
//! draining its shard for a micro-batching window (`coalesce_window`, up
//! to `max_batch` requests), buckets what it collected by (m, k, n,
//! accuracy-tier), and runs each bucket through the grouped engine — one
//! fused backend schedule per bucket, with operand decompositions shared
//! through the service-wide [`SliceCache`] and ESC reductions through
//! the [`EscPlanCache`]. The window wait is a condvar timed wait that
//! **releases the shard lock**, so sibling workers (and submitters) keep
//! moving while one worker coalesces — the window can no longer convoy
//! the shard, let alone the service. Grouped results are bitwise
//! identical to the per-request path.

use std::collections::{HashMap, VecDeque};
use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::adp::{AdpConfig, AdpEngine, AdpOutcome};
use super::costmodel::CostModel;
use super::heuristic::SelectionHeuristic;
use super::metrics::Metrics;
use super::plan::EscPlanCache;
use crate::backend::{BackendSpec, WorkspacePool};
use crate::linalg::Matrix;
use crate::ozaki::batched::SliceCache;
use crate::ozaki::{AccuracyTier, SliceEncoding};
use crate::runtime::RuntimeHandle;
use crate::util::faultinject;
use crate::util::sync as psync;
use crate::util::Rng;

/// Admission-control priority tier of a submission.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Priority {
    /// Interactive / latency-sensitive: drained first, smallest backlog.
    High,
    /// Default tier for `submit`/`try_submit`.
    Normal,
    /// Bulk / throughput traffic (`submit_batch` groups land here):
    /// drained last, so it can never starve the tiers above it.
    Batch,
}

impl Priority {
    pub const ALL: [Priority; 3] = [Priority::High, Priority::Normal, Priority::Batch];

    /// Dense index (drain order: 0 drains first).
    pub fn index(self) -> usize {
        match self {
            Priority::High => 0,
            Priority::Normal => 1,
            Priority::Batch => 2,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Priority::High => "high",
            Priority::Normal => "normal",
            Priority::Batch => "batch",
        }
    }

    /// Parse `"high"` / `"normal"` / `"batch"` (CLI flags, load gens).
    pub fn parse(s: &str) -> Option<Priority> {
        match s {
            "high" => Some(Priority::High),
            "normal" => Some(Priority::Normal),
            "batch" => Some(Priority::Batch),
            _ => None,
        }
    }
}

/// One GEMM request.
pub struct GemmRequest {
    pub a: Matrix,
    pub b: Matrix,
    reply: ReplySlot,
    submitted: Instant,
    tier: Priority,
    /// Accuracy/speed trade-off of *this* request (orthogonal to the
    /// scheduling `tier`): threaded into the engine per request, and
    /// part of the coalescing bucket key so mixed-tier groups stay
    /// isolated.
    accuracy: AccuracyTier,
    /// Per-request deadline override; `None` falls back to
    /// [`ServiceConfig::default_deadline`]. Measured from submission and
    /// enforced at *dequeue*: a request that expires while queued is shed
    /// with [`GemmError::DeadlineExceeded`] instead of burning a worker
    /// on an answer nobody is waiting for.
    deadline: Option<Duration>,
}

/// Completed response with queueing/processing latency. The reported
/// components are exact by construction: `total_s` is stored as the sum
/// `queue_s + proc_s` (grouped requests report the whole bucket's wall
/// time as `proc_s` — the bucket completes as one schedule, so that *is*
/// the time the request spent in processing).
pub struct GemmResponse {
    pub c: Matrix,
    pub outcome: AdpOutcome,
    /// Submission-to-execution-start latency, seconds.
    pub queue_s: f64,
    /// Execution latency (for grouped requests: the bucket's), seconds.
    pub proc_s: f64,
    /// End-to-end latency; always exactly `queue_s + proc_s`.
    pub total_s: f64,
}

/// Why a request failed after it was admitted. Delivered *on the reply
/// channel* — the submitting thread never panics, the worker survives.
#[derive(Clone, Debug, PartialEq)]
pub enum GemmError {
    /// `a.cols != b.rows`; rejected by the worker's pre-validation.
    ShapeMismatch { m: usize, k_a: usize, k_b: usize, n: usize },
    /// The engine panicked on this request (payload message preserved).
    /// The worker caught the unwind and keeps serving.
    EnginePanic(String),
    /// The reply slot was dropped without a response — the terminal
    /// "never silently lost" guarantee (e.g. a worker died mid-request).
    ReplyLost,
    /// The request's deadline expired while it sat in the queue; the
    /// worker shed it at dequeue without executing anything.
    DeadlineExceeded,
    /// Submission-time rejection folded into [`GemmService::gemm_blocking`].
    Rejected(SubmitError),
}

impl fmt::Display for GemmError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            GemmError::ShapeMismatch { m, k_a, k_b, n } => {
                write!(f, "gemm shape mismatch: ({m}x{k_a}) x ({k_b}x{n})")
            }
            GemmError::EnginePanic(msg) => write!(f, "gemm engine panicked: {msg}"),
            GemmError::ReplyLost => write!(f, "gemm reply lost (worker died)"),
            GemmError::DeadlineExceeded => {
                write!(f, "gemm request deadline expired while queued")
            }
            GemmError::Rejected(e) => write!(f, "gemm submission rejected: {e}"),
        }
    }
}

impl std::error::Error for GemmError {}

/// What a reply channel carries: the response, or a typed failure.
pub type GemmResult = Result<GemmResponse, GemmError>;

/// Completion route of a request: a channel the submitter polls/awaits,
/// or a callback invoked from the worker thread.
enum Completion {
    Channel(Sender<GemmResult>),
    Callback(Box<dyn FnOnce(GemmResult) + Send>),
}

/// Reply sender with a drop guard: if the slot is dropped before a
/// response was sent (worker death, future refactoring bugs), the
/// submitter receives [`GemmError::ReplyLost`] instead of a hang or a
/// `recv` panic. `disarm` is for requests that were never admitted (the
/// rejection itself is the signal).
struct ReplySlot(Option<Completion>);

impl ReplySlot {
    fn channel() -> (ReplySlot, Receiver<GemmResult>) {
        let (tx, rx) = channel();
        (ReplySlot(Some(Completion::Channel(tx))), rx)
    }

    fn callback(f: impl FnOnce(GemmResult) + Send + 'static) -> ReplySlot {
        ReplySlot(Some(Completion::Callback(Box::new(f))))
    }

    fn send(&mut self, result: GemmResult) {
        // Injected reply loss: return *without* consuming the completion,
        // so the drop guard below still fires and the submitter receives
        // `ReplyLost` — the exactly-one-reply guarantee holds even while
        // replies are being "dropped". (Never swallow the drop guard's
        // own `ReplyLost` send, or the reply really would vanish.)
        if self.0.is_some()
            && !matches!(result, Err(GemmError::ReplyLost))
            && faultinject::fires(faultinject::site::REPLY_DROP)
        {
            return;
        }
        match self.0.take() {
            Some(Completion::Channel(tx)) => {
                let _ = tx.send(result); // receiver gone: caller lost interest
            }
            Some(Completion::Callback(f)) => f(result),
            None => {}
        }
    }

    fn disarm(&mut self) {
        self.0 = None;
    }
}

impl Drop for ReplySlot {
    fn drop(&mut self) {
        self.send(Err(GemmError::ReplyLost));
    }
}

/// Pollable completion handle returned by [`GemmService::submit_async`].
pub struct GemmTicket {
    rx: Receiver<GemmResult>,
}

impl GemmTicket {
    /// Non-blocking poll: `None` while the request is still in flight.
    pub fn poll(&mut self) -> Option<GemmResult> {
        match self.rx.try_recv() {
            Ok(r) => Some(r),
            Err(TryRecvError::Empty) => None,
            Err(TryRecvError::Disconnected) => Some(Err(GemmError::ReplyLost)),
        }
    }

    /// Block until the result arrives. Never panics: a vanished worker
    /// surfaces as [`GemmError::ReplyLost`].
    pub fn wait(self) -> GemmResult {
        self.rx.recv().unwrap_or(Err(GemmError::ReplyLost))
    }

    /// Block with a deadline; `None` on timeout (ticket stays usable).
    pub fn wait_timeout(&mut self, d: Duration) -> Option<GemmResult> {
        match self.rx.recv_timeout(d) {
            Ok(r) => Some(r),
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => None,
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                Some(Err(GemmError::ReplyLost))
            }
        }
    }
}

/// What travels through a shard queue: a single request, or an explicit
/// group from [`GemmService::submit_batch`] (always coalesced, regardless
/// of the `coalesce` flag).
enum QueueItem {
    One(GemmRequest),
    Batch(Vec<GemmRequest>),
}

impl QueueItem {
    /// Requests inside (admission control counts requests, not items).
    fn len(&self) -> usize {
        match self {
            QueueItem::One(_) => 1,
            QueueItem::Batch(v) => v.len(),
        }
    }
}

/// Why a submission was rejected.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The service was shut down; the request queues are closed.
    /// Permanent — retrying cannot succeed.
    ServiceStopped,
    /// The target shard is at its total queue-depth cap right now.
    /// Transient backpressure: retry later, shed load, or use the
    /// blocking [`GemmService::submit`]. Only the non-blocking paths
    /// report this.
    QueueFull,
    /// The submission's priority tier is at its per-shard depth cap
    /// (other tiers may still have room). Transient, like `QueueFull`.
    TierFull,
}

impl SubmitError {
    /// Whether a later retry can succeed.
    pub fn is_retryable(&self) -> bool {
        matches!(self, SubmitError::QueueFull | SubmitError::TierFull)
    }
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::ServiceStopped => write!(f, "gemm service stopped"),
            SubmitError::QueueFull => write!(f, "gemm service queue full"),
            SubmitError::TierFull => write!(f, "gemm service priority tier full"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// A rejected non-blocking submission: the error plus the operands, handed
/// back so the caller can retry without cloning up front.
#[derive(Debug)]
pub struct RejectedSubmit {
    pub error: SubmitError,
    pub a: Matrix,
    pub b: Matrix,
}

/// Service configuration. The heuristic/encoding mirror [`AdpConfig`];
/// each shard constructs its engine from a factory closure because
/// `SelectionHeuristic` boxes are not `Clone`.
pub struct ServiceConfig {
    /// Total worker threads across all shards (each shard gets at least
    /// one; the remainder is distributed round-robin).
    pub workers: usize,
    /// Per-shard total queued-request cap (admission control).
    pub queue_depth: usize,
    pub target_mantissa: i32,
    pub max_slices: usize,
    pub encoding: SliceEncoding,
    pub esc_block: usize,
    pub use_artifacts: bool,
    /// [`AccuracyTier`] applied to submissions that don't carry one (the
    /// plain `submit`/`try_submit`/`submit_async`/`submit_callback`/
    /// `submit_batch` paths). Seeded from `ADP_TIER`; per-request
    /// `*_tiered` submissions override it.
    pub default_tier: AccuracyTier,
    /// Compute budget of the whole service; each shard builds its own
    /// pool from a [`BackendSpec::shard_slice`] of this. Bitwise
    /// identical across variants; default is the machine-sized parallel
    /// backend.
    pub backend: BackendSpec,
    /// Shard count. Requests route by shape-bucket hash; `1` preserves
    /// the single-queue behavior (and its deterministic cache counters).
    pub shards: usize,
    /// Per-shard queued-request cap of each [`Priority`] tier, indexed by
    /// [`Priority::index`]. A tier whose backlog is empty always admits
    /// one submission (so an oversized batch can make progress); caps
    /// bind from the second queued request on.
    pub tier_depths: [usize; 3],
    /// Coalesce individually-submitted requests: a worker drains its
    /// shard for `coalesce_window` (up to `max_batch` requests), buckets
    /// by shape and executes each bucket as one grouped schedule.
    /// `submit_batch` groups are coalesced regardless of this flag.
    pub coalesce: bool,
    /// Micro-batching window a worker waits to fill a batch.
    pub coalesce_window: Duration,
    /// Max requests coalesced into one group.
    pub max_batch: usize,
    /// Resident decompositions in the service-wide [`SliceCache`].
    pub slice_cache_entries: usize,
    /// Resident plans in the service-wide [`EscPlanCache`].
    pub plan_cache_entries: usize,
    /// Deadline applied to requests that don't carry their own (see
    /// [`GemmService::submit_deadline`]). Enforced at dequeue: expired
    /// requests are shed with [`GemmError::DeadlineExceeded`] and counted
    /// in the `shed_expired` metric. `None` disables shedding.
    pub default_deadline: Option<Duration>,
    /// Run the shard supervisor: a watchdog thread that detects dead
    /// workers (panicked outside the engine `catch_unwind`) and hung
    /// workers (busy past `hang_threshold`), respawns a replacement
    /// against the still-warm shared engine/caches, and counts the event
    /// in `worker_respawns`.
    pub supervise: bool,
    /// Supervisor sweep interval.
    pub supervisor_poll: Duration,
    /// How long a worker may stay busy on one dequeued item before the
    /// supervisor declares it hung and respawns a replacement. The old
    /// worker is *superseded*, not killed: if it recovers it finishes its
    /// current request (the reply stays valid) and exits. Size this above
    /// the largest legitimate single-request latency.
    pub hang_threshold: Duration,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8),
            queue_depth: 256,
            target_mantissa: 53,
            max_slices: 26,
            encoding: SliceEncoding::Unsigned,
            esc_block: crate::esc::coarse::DEFAULT_BLOCK,
            use_artifacts: true,
            default_tier: AccuracyTier::env_default(),
            backend: BackendSpec::auto(),
            shards: 1,
            // High/Normal bound only by the shard total; bulk Batch
            // traffic can fill at most half a shard.
            tier_depths: [256, 256, 128],
            coalesce: false,
            coalesce_window: Duration::from_micros(200),
            max_batch: 16,
            slice_cache_entries: 32,
            plan_cache_entries: 64,
            default_deadline: None,
            supervise: true,
            supervisor_poll: Duration::from_millis(20),
            hang_threshold: Duration::from_secs(5),
        }
    }
}

/// Per-shard queue state under the shard mutex: one FIFO per priority
/// tier plus queued-request depth counts.
struct ShardState {
    queues: [VecDeque<QueueItem>; 3],
    depth: [usize; 3],
    closed: bool,
}

/// A shard's bounded multi-tier queue. One `Condvar` serves both "item
/// available" (workers) and "space available" (blocking submitters) —
/// every transition notifies, correctness comes from re-checking under
/// the lock. Crucially, **no path holds the mutex across a timed wait**:
/// the coalescing drain waits on the condvar, which releases the lock.
struct ShardQueue {
    state: Mutex<ShardState>,
    cv: Condvar,
    tier_depths: [usize; 3],
    total_depth: usize,
}

impl ShardQueue {
    fn new(total_depth: usize, tier_depths: [usize; 3]) -> ShardQueue {
        ShardQueue {
            state: Mutex::new(ShardState {
                queues: [VecDeque::new(), VecDeque::new(), VecDeque::new()],
                depth: [0; 3],
                closed: false,
            }),
            cv: Condvar::new(),
            tier_depths,
            total_depth: total_depth.max(1),
        }
    }

    /// Admission check for `n` more queued requests in `tier`. An empty
    /// tier (or empty shard) always admits one item — oversized batches
    /// must be able to make progress — so caps bind from the second
    /// queued request on. Tier verdicts are more specific than shard
    /// verdicts, so `TierFull` is reported first.
    fn admissible(&self, g: &ShardState, tier: usize, n: usize) -> Result<(), SubmitError> {
        if g.depth[tier] > 0 && g.depth[tier] + n > self.tier_depths[tier].max(1) {
            return Err(SubmitError::TierFull);
        }
        let total: usize = g.depth.iter().sum();
        if total > 0 && total + n > self.total_depth {
            return Err(SubmitError::QueueFull);
        }
        Ok(())
    }

    /// Enqueue under admission control. `block` waits for space (woken by
    /// dequeues); non-blocking failure hands the item back for operand
    /// recovery.
    fn push(
        &self,
        item: QueueItem,
        tier: Priority,
        block: bool,
    ) -> Result<(), (SubmitError, QueueItem)> {
        let n = item.len();
        let t = tier.index();
        let mut g = psync::lock(&self.state);
        loop {
            if g.closed {
                return Err((SubmitError::ServiceStopped, item));
            }
            match self.admissible(&g, t, n) {
                Ok(()) => {
                    g.depth[t] += n;
                    g.queues[t].push_back(item);
                    self.cv.notify_all();
                    return Ok(());
                }
                Err(e) if !block => return Err((e, item)),
                Err(_) => g = psync::wait(&self.cv, g),
            }
        }
    }

    /// Highest-priority available item, if any (caller holds the lock).
    fn take_next(g: &mut ShardState) -> Option<QueueItem> {
        for t in 0..3 {
            if let Some(item) = g.queues[t].pop_front() {
                g.depth[t] -= item.len();
                return Some(item);
            }
        }
        None
    }

    /// Blocking dequeue; `None` once the queue is closed *and* drained
    /// (shutdown serves everything that was admitted).
    fn pop(&self) -> Option<QueueItem> {
        let mut g = psync::lock(&self.state);
        loop {
            if let Some(item) = Self::take_next(&mut g) {
                drop(g);
                self.cv.notify_all(); // space freed: wake blocked submitters
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = psync::wait(&self.cv, g);
        }
    }

    /// Coalescing drain: extend `batch` up to `max` requests, waiting out
    /// `deadline` for stragglers. The waits are condvar timed waits — the
    /// shard lock is **released** while waiting, so sibling workers keep
    /// dequeuing and submitters keep enqueuing during the window (the
    /// old implementation held the receiver mutex here and convoyed every
    /// other worker). An explicit `submit_batch` group ends the window
    /// early, mirroring the pre-shard dispatcher: the group asked for
    /// grouped execution *now*.
    fn drain_into(&self, batch: &mut Vec<GemmRequest>, max: usize, deadline: Instant) {
        let mut g = psync::lock(&self.state);
        loop {
            let mut took = false;
            let mut batch_item = false;
            while batch.len() < max {
                match Self::take_next(&mut g) {
                    Some(QueueItem::One(r)) => {
                        batch.push(r);
                        took = true;
                    }
                    Some(QueueItem::Batch(rs)) => {
                        batch.extend(rs);
                        took = true;
                        batch_item = true;
                        break;
                    }
                    None => break,
                }
            }
            if took {
                // Space freed: wake blocked submitters before (possibly)
                // waiting out the rest of the window.
                self.cv.notify_all();
            }
            if batch.len() >= max || batch_item || g.closed {
                return;
            }
            let now = Instant::now();
            if now >= deadline {
                return;
            }
            let (g2, _) = psync::wait_timeout(&self.cv, g, deadline - now);
            g = g2;
        }
    }

    fn close(&self) {
        let mut g = psync::lock(&self.state);
        g.closed = true;
        drop(g);
        self.cv.notify_all();
    }
}

/// FNV-1a over the shape bucket: repeat shapes go to the same shard, so
/// per-shard locality of the (service-wide) caches survives sharding.
fn shape_shard(m: usize, k: usize, n: usize, shards: usize) -> usize {
    if shards <= 1 {
        return 0;
    }
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for v in [m as u64, k as u64, n as u64] {
        h ^= v;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    (h % shards as u64) as usize
}

/// Milliseconds on a process-local monotonic clock. `0` is reserved as
/// the heartbeat's "idle" sentinel, so the clock starts at 1.
fn monotonic_ms() -> u64 {
    use std::sync::OnceLock;
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    let epoch = *EPOCH.get_or_init(Instant::now);
    (epoch.elapsed().as_millis() as u64).max(1)
}

/// Everything a worker thread needs — kept per slot so the supervisor can
/// respawn a replacement against the *same* still-warm engine and shard
/// queue (caches, cost model and workspace pool ride along inside the
/// engine `Arc`s).
#[derive(Clone)]
struct WorkerCtx {
    queue: Arc<ShardQueue>,
    engine: Arc<AdpEngine>,
    metrics: Arc<Metrics>,
    inflight: Arc<AtomicU64>,
    knobs: CoalesceKnobs,
    default_deadline: Option<Duration>,
}

/// One supervised worker: its join handle, its heartbeat (0 = idle in
/// `pop`, otherwise the `monotonic_ms` stamp of when it went busy — so an
/// idle worker blocked on the condvar can never look hung), and the
/// supersede flag a replaced worker checks to retire itself.
struct WorkerSlot {
    handle: std::thread::JoinHandle<()>,
    beat: Arc<AtomicU64>,
    superseded: Arc<AtomicBool>,
    ctx: WorkerCtx,
    base_name: String,
    respawns: usize,
}

/// Worker slots plus the handles of superseded workers that may still be
/// running (joined at shutdown).
struct WorkerTable {
    slots: Vec<WorkerSlot>,
    retired: Vec<std::thread::JoinHandle<()>>,
}

fn spawn_worker(ctx: WorkerCtx, base_name: String, respawns: usize) -> WorkerSlot {
    let beat = Arc::new(AtomicU64::new(0));
    let superseded = Arc::new(AtomicBool::new(false));
    let name =
        if respawns == 0 { base_name.clone() } else { format!("{base_name}-r{respawns}") };
    let handle = {
        let (ctx, beat, superseded) = (ctx.clone(), beat.clone(), superseded.clone());
        std::thread::Builder::new()
            .name(name)
            .spawn(move || worker_main(ctx, beat, superseded))
            .expect("spawn worker")
    };
    WorkerSlot { handle, beat, superseded, ctx, base_name, respawns }
}

/// Supervisor loop: sweep the worker table every `poll`, respawn any
/// worker that died (its in-flight replies already surfaced as
/// [`GemmError::ReplyLost`] through the reply drop guards) or has been
/// busy on one item longer than `hang`. Replacements attach to the same
/// shard queue and shared engine, so warm caches survive the respawn.
fn supervisor_main(
    table: Arc<Mutex<WorkerTable>>,
    stop: Arc<AtomicBool>,
    metrics: Arc<Metrics>,
    poll: Duration,
    hang: Duration,
) {
    let hang_ms = (hang.as_millis() as u64).max(1);
    while !stop.load(Ordering::SeqCst) {
        std::thread::sleep(poll);
        if stop.load(Ordering::SeqCst) {
            return;
        }
        let mut g = psync::lock(&table);
        for i in 0..g.slots.len() {
            let dead = g.slots[i].handle.is_finished();
            let hung = {
                let b = g.slots[i].beat.load(Ordering::SeqCst);
                b != 0 && monotonic_ms().saturating_sub(b) > hang_ms
            };
            if !(dead || hung) {
                continue;
            }
            let respawns = g.slots[i].respawns + 1;
            let fresh =
                spawn_worker(g.slots[i].ctx.clone(), g.slots[i].base_name.clone(), respawns);
            let old = std::mem::replace(&mut g.slots[i], fresh);
            // A hung worker that later recovers finishes its current
            // request (the reply stays valid) and retires; a dead one
            // joins immediately at shutdown.
            old.superseded.store(true, Ordering::SeqCst);
            g.retired.push(old.handle);
            metrics.record_respawn();
        }
    }
}

/// Handle to the running service; submission and shutdown are
/// thread-safe through `&self`, so the handle can be shared (e.g. in an
/// `Arc`) between submitters and a controller racing them.
pub struct GemmService {
    shards: Vec<Arc<ShardQueue>>,
    pub metrics: Arc<Metrics>,
    inflight: Arc<AtomicU64>,
    workers: Arc<Mutex<WorkerTable>>,
    supervisor: Mutex<Option<std::thread::JoinHandle<()>>>,
    supervisor_stop: Arc<AtomicBool>,
    cost_model: Arc<CostModel>,
    default_tier: AccuracyTier,
}

impl GemmService {
    /// Start the service. `heuristic_factory` is invoked once per shard
    /// (the shard's workers share one engine through an `Arc`, which is
    /// why [`SelectionHeuristic`] is `Sync`).
    pub fn start(
        cfg: ServiceConfig,
        runtime: Option<RuntimeHandle>,
        heuristic_factory: impl Fn() -> Box<dyn SelectionHeuristic>,
    ) -> GemmService {
        let metrics = Arc::new(Metrics::default());
        let inflight = Arc::new(AtomicU64::new(0));
        let nshards = cfg.shards.max(1);
        let workers_total = cfg.workers.max(1);
        // Caches and the workspace pool stay service-wide: the whole
        // deployment amortizes together, and steady-state traffic
        // recycles the same scratch buffers instead of allocating per
        // request. Only the *scheduling* (queues + backend pools) shards.
        let plan_cache = Arc::new(EscPlanCache::new(cfg.plan_cache_entries));
        let slice_cache = Arc::new(SliceCache::new(cfg.slice_cache_entries));
        let workspace_pool = Arc::new(WorkspacePool::new());
        // The learned cost model is service-wide too: every shard's
        // measured timings feed one table, so a shape bucket warms from
        // the whole deployment's traffic, not one shard's slice of it.
        let cost_model = Arc::new(CostModel::from_env());
        let knobs = CoalesceKnobs {
            coalesce: cfg.coalesce,
            window: cfg.coalesce_window,
            max_batch: cfg.max_batch.max(1),
        };
        let mut shards = Vec::with_capacity(nshards);
        let mut workers = Vec::new();
        for sid in 0..nshards {
            let queue = Arc::new(ShardQueue::new(cfg.queue_depth, cfg.tier_depths));
            // One engine per shard, shared by the shard's workers; one
            // backend pool slice per shard, so shards cannot convoy each
            // other through a common thread pool.
            let engine_cfg = AdpConfig {
                target_mantissa: cfg.target_mantissa,
                max_slices: cfg.max_slices,
                encoding: cfg.encoding,
                esc_block: cfg.esc_block,
                heuristic: heuristic_factory(),
                runtime: runtime.clone(),
                use_artifacts: cfg.use_artifacts,
                tier: cfg.default_tier,
                cost_model: cost_model.clone(),
                backend: cfg.backend.shard_slice(nshards).build(),
                plan_cache: Some(plan_cache.clone()),
                slice_cache: Some(slice_cache.clone()),
                workspace_pool: workspace_pool.clone(),
            };
            let engine = Arc::new(AdpEngine::with_metrics(engine_cfg, metrics.clone()));
            let base = workers_total / nshards;
            let shard_workers = (base + usize::from(sid < workers_total % nshards)).max(1);
            for wid in 0..shard_workers {
                let ctx = WorkerCtx {
                    queue: queue.clone(),
                    engine: engine.clone(),
                    metrics: metrics.clone(),
                    inflight: inflight.clone(),
                    knobs,
                    default_deadline: cfg.default_deadline,
                };
                workers.push(spawn_worker(ctx, format!("adp-s{sid}-w{wid}"), 0));
            }
            shards.push(queue);
        }
        let workers = Arc::new(Mutex::new(WorkerTable { slots: workers, retired: Vec::new() }));
        let supervisor_stop = Arc::new(AtomicBool::new(false));
        let supervisor = if cfg.supervise {
            let (table, stop, metrics) = (workers.clone(), supervisor_stop.clone(), metrics.clone());
            let (poll, hang) = (cfg.supervisor_poll.max(Duration::from_millis(1)), cfg.hang_threshold);
            Some(
                std::thread::Builder::new()
                    .name("adp-supervisor".to_string())
                    .spawn(move || supervisor_main(table, stop, metrics, poll, hang))
                    .expect("spawn supervisor"),
            )
        } else {
            None
        };
        GemmService {
            shards,
            metrics,
            inflight,
            workers,
            supervisor: Mutex::new(supervisor),
            supervisor_stop,
            cost_model,
            default_tier: cfg.default_tier,
        }
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Which shard serves shape `(m, k, n)` (i.e. `a: m x k`, `b: k x n`).
    /// Exposed so load generators and tests can steer traffic per shard.
    pub fn shard_for(&self, m: usize, k: usize, n: usize) -> usize {
        shape_shard(m, k, n, self.shards.len())
    }

    /// Route + enqueue one request. On rejection the request is handed
    /// back (with its reply slot still armed) for operand recovery.
    fn enqueue_one(
        &self,
        a: Matrix,
        b: Matrix,
        tier: Priority,
        accuracy: AccuracyTier,
        deadline: Option<Duration>,
        reply: ReplySlot,
        block: bool,
    ) -> Result<(), (SubmitError, GemmRequest)> {
        let shard = &self.shards[shape_shard(a.rows, a.cols, b.cols, self.shards.len())];
        self.inflight.fetch_add(1, Ordering::SeqCst);
        let req =
            GemmRequest { a, b, reply, submitted: Instant::now(), tier, accuracy, deadline };
        match shard.push(QueueItem::One(req), tier, block) {
            Ok(()) => {
                self.metrics.record_enqueued(tier, 1);
                Ok(())
            }
            Err((error, QueueItem::One(req))) => {
                self.inflight.fetch_sub(1, Ordering::SeqCst);
                if error.is_retryable() {
                    self.metrics.record_rejected(tier, 1);
                }
                Err((error, req))
            }
            Err(_) => unreachable!("pushed a One"),
        }
    }

    /// Submit a Normal-tier request; returns the receiver for its
    /// [`GemmResult`], or [`SubmitError::ServiceStopped`] when the queues
    /// are closed. Blocks while the shard is full (backpressure).
    pub fn submit(&self, a: Matrix, b: Matrix) -> Result<Receiver<GemmResult>, SubmitError> {
        self.submit_tiered(a, b, self.default_tier)
    }

    /// [`GemmService::submit`] with an explicit per-request
    /// [`AccuracyTier`] (the plain path uses the service default).
    pub fn submit_tiered(
        &self,
        a: Matrix,
        b: Matrix,
        accuracy: AccuracyTier,
    ) -> Result<Receiver<GemmResult>, SubmitError> {
        let (reply, rx) = ReplySlot::channel();
        match self.enqueue_one(a, b, Priority::Normal, accuracy, None, reply, true) {
            Ok(()) => Ok(rx),
            Err((error, mut req)) => {
                req.reply.disarm(); // the Err return is the signal
                Err(error)
            }
        }
    }

    /// Non-blocking Normal-tier submit. A full shard/tier is reported as
    /// the *retryable* [`SubmitError::QueueFull`]/[`SubmitError::TierFull`]
    /// with the operands handed back, instead of blocking the caller or
    /// conflating backpressure with shutdown.
    pub fn try_submit(&self, a: Matrix, b: Matrix) -> Result<Receiver<GemmResult>, RejectedSubmit> {
        self.try_submit_tiered(a, b, self.default_tier)
    }

    /// [`GemmService::try_submit`] with an explicit per-request
    /// [`AccuracyTier`].
    pub fn try_submit_tiered(
        &self,
        a: Matrix,
        b: Matrix,
        accuracy: AccuracyTier,
    ) -> Result<Receiver<GemmResult>, RejectedSubmit> {
        let (reply, rx) = ReplySlot::channel();
        match self.enqueue_one(a, b, Priority::Normal, accuracy, None, reply, false) {
            Ok(()) => Ok(rx),
            Err((error, mut req)) => {
                req.reply.disarm();
                let GemmRequest { a, b, .. } = req;
                Err(RejectedSubmit { error, a, b })
            }
        }
    }

    /// Non-blocking async submit at an explicit [`Priority`]: returns a
    /// pollable [`GemmTicket`] — the submitter never blocks, neither on
    /// admission (full ⇒ retryable rejection with operands back) nor on
    /// completion (poll, or `wait` when it chooses to).
    pub fn submit_async(
        &self,
        a: Matrix,
        b: Matrix,
        priority: Priority,
    ) -> Result<GemmTicket, RejectedSubmit> {
        self.submit_async_tiered(a, b, priority, self.default_tier)
    }

    /// [`GemmService::submit_async`] with an explicit per-request
    /// [`AccuracyTier`].
    pub fn submit_async_tiered(
        &self,
        a: Matrix,
        b: Matrix,
        priority: Priority,
        accuracy: AccuracyTier,
    ) -> Result<GemmTicket, RejectedSubmit> {
        let (reply, rx) = ReplySlot::channel();
        match self.enqueue_one(a, b, priority, accuracy, None, reply, false) {
            Ok(()) => Ok(GemmTicket { rx }),
            Err((error, mut req)) => {
                req.reply.disarm();
                let GemmRequest { a, b, .. } = req;
                Err(RejectedSubmit { error, a, b })
            }
        }
    }

    /// [`GemmService::submit_async`] with a per-request deadline override
    /// (takes precedence over [`ServiceConfig::default_deadline`]). The
    /// deadline is measured from submission and enforced at dequeue: if
    /// it expires while the request is queued, the reply is
    /// [`GemmError::DeadlineExceeded`] and no compute is spent.
    pub fn submit_deadline(
        &self,
        a: Matrix,
        b: Matrix,
        priority: Priority,
        deadline: Duration,
    ) -> Result<GemmTicket, RejectedSubmit> {
        let (reply, rx) = ReplySlot::channel();
        match self.enqueue_one(a, b, priority, self.default_tier, Some(deadline), reply, false) {
            Ok(()) => Ok(GemmTicket { rx }),
            Err((error, mut req)) => {
                req.reply.disarm();
                let GemmRequest { a, b, .. } = req;
                Err(RejectedSubmit { error, a, b })
            }
        }
    }

    /// Non-blocking submit with a completion callback invoked from the
    /// worker thread (keep it cheap — it runs on the service's time). On
    /// rejection the callback is dropped uninvoked: the `Err` return *is*
    /// the completion. Once admitted, the callback is guaranteed exactly
    /// one invocation — a response, a typed [`GemmError`], or
    /// [`GemmError::ReplyLost`] if the worker dies.
    pub fn submit_callback(
        &self,
        a: Matrix,
        b: Matrix,
        priority: Priority,
        on_done: impl FnOnce(GemmResult) + Send + 'static,
    ) -> Result<(), RejectedSubmit> {
        self.submit_callback_tiered(a, b, priority, self.default_tier, on_done)
    }

    /// [`GemmService::submit_callback`] with an explicit per-request
    /// [`AccuracyTier`].
    pub fn submit_callback_tiered(
        &self,
        a: Matrix,
        b: Matrix,
        priority: Priority,
        accuracy: AccuracyTier,
        on_done: impl FnOnce(GemmResult) + Send + 'static,
    ) -> Result<(), RejectedSubmit> {
        let reply = ReplySlot::callback(on_done);
        match self.enqueue_one(a, b, priority, accuracy, None, reply, false) {
            Ok(()) => Ok(()),
            Err((error, mut req)) => {
                req.reply.disarm();
                let GemmRequest { a, b, .. } = req;
                Err(RejectedSubmit { error, a, b })
            }
        }
    }

    /// Submit a group of requests that should be executed together: the
    /// group travels one shard queue as one Batch-tier item and is
    /// shape-bucketed and run through the grouped pipeline by a single
    /// worker, sharing operand decompositions via the service slice
    /// cache. The whole group routes by its first problem's shape —
    /// groups share operands by construction, so keeping them on one
    /// shard preserves cache locality. Blocks while the shard is full.
    /// Receivers are returned in submission order.
    pub fn submit_batch(
        &self,
        pairs: Vec<(Matrix, Matrix)>,
    ) -> Result<Vec<Receiver<GemmResult>>, SubmitError> {
        let tier = self.default_tier;
        self.submit_batch_tiered(pairs.into_iter().map(|(a, b)| (a, b, tier)).collect())
    }

    /// [`GemmService::submit_batch`] with an explicit [`AccuracyTier`]
    /// per member. Mixed tiers are fine: the accuracy tier is part of
    /// the coalescing bucket key, so a group splits into one grouped
    /// schedule per (shape, tier) — a fast sibling can never perturb a
    /// guaranteed member's bits.
    pub fn submit_batch_tiered(
        &self,
        pairs: Vec<(Matrix, Matrix, AccuracyTier)>,
    ) -> Result<Vec<Receiver<GemmResult>>, SubmitError> {
        if pairs.is_empty() {
            return Ok(Vec::new());
        }
        let shard_idx = {
            let (a, b, _) = &pairs[0];
            shape_shard(a.rows, a.cols, b.cols, self.shards.len())
        };
        let n = pairs.len() as u64;
        let submitted = Instant::now();
        let mut reqs = Vec::with_capacity(pairs.len());
        let mut rxs = Vec::with_capacity(pairs.len());
        for (a, b, accuracy) in pairs {
            let (reply, rx) = ReplySlot::channel();
            reqs.push(GemmRequest {
                a,
                b,
                reply,
                submitted,
                tier: Priority::Batch,
                accuracy,
                deadline: None,
            });
            rxs.push(rx);
        }
        self.inflight.fetch_add(n, Ordering::SeqCst);
        match self.shards[shard_idx].push(QueueItem::Batch(reqs), Priority::Batch, true) {
            Ok(()) => {
                self.metrics.record_enqueued(Priority::Batch, n);
                Ok(rxs)
            }
            Err((error, item)) => {
                self.inflight.fetch_sub(n, Ordering::SeqCst);
                if error.is_retryable() {
                    self.metrics.record_rejected(Priority::Batch, n);
                }
                if let QueueItem::Batch(reqs) = item {
                    for mut req in reqs {
                        req.reply.disarm(); // no ReplyLost into rxs we drop
                    }
                }
                Err(error)
            }
        }
    }

    /// Non-blocking submit with bounded exponential backoff over the
    /// *retryable* rejections ([`SubmitError::QueueFull`] /
    /// [`SubmitError::TierFull`]). Sleeps between attempts grow
    /// geometrically from [`RetryPolicy::base_backoff`], cap at
    /// [`RetryPolicy::max_backoff`], and carry deterministic seeded
    /// jitter (full-jitter in the upper half of the window) so a
    /// thundering herd of retriers decorrelates. Permanent rejections
    /// ([`SubmitError::ServiceStopped`]) and exhausted budgets hand the
    /// operands back unchanged.
    pub fn submit_with_retry(
        &self,
        a: Matrix,
        b: Matrix,
        priority: Priority,
        policy: &RetryPolicy,
    ) -> Result<GemmTicket, RejectedSubmit> {
        let mut rng = Rng::new(policy.seed);
        let (mut a, mut b) = (a, b);
        let mut attempt = 0usize;
        loop {
            match self.submit_async(a, b, priority) {
                Ok(t) => return Ok(t),
                Err(rej) if rej.error.is_retryable() && attempt + 1 < policy.max_attempts.max(1) => {
                    attempt += 1;
                    let shift = (attempt - 1).min(16) as u32;
                    let exp = policy.base_backoff.saturating_mul(1u32 << shift);
                    let cap = exp.min(policy.max_backoff).max(Duration::from_nanos(1));
                    let nanos = cap.as_nanos() as u64;
                    let jittered = nanos / 2 + rng.next_u64() % (nanos / 2 + 1);
                    std::thread::sleep(Duration::from_nanos(jittered));
                    a = rej.a;
                    b = rej.b;
                }
                Err(rej) => return Err(rej),
            }
        }
    }

    /// Convenience: submit and wait. Every failure mode — shutdown,
    /// shape mismatch, engine panic, worker death — comes back as a
    /// typed `Err`; this can no longer panic the submitting thread.
    pub fn gemm_blocking(&self, a: Matrix, b: Matrix) -> GemmResult {
        match self.submit(a, b) {
            Ok(rx) => rx.recv().unwrap_or(Err(GemmError::ReplyLost)),
            Err(e) => Err(GemmError::Rejected(e)),
        }
    }

    pub fn inflight(&self) -> u64 {
        self.inflight.load(Ordering::SeqCst)
    }

    /// Stop accepting work, drain the queues and join the workers.
    /// Idempotent, and safe to race against concurrent `submit*` calls:
    /// a submission either lands before the close (and is served) or
    /// gets [`SubmitError::ServiceStopped`]. The supervisor stops first
    /// (so drained-and-exiting workers aren't mistaken for dead ones),
    /// and learned state — the cost model and the tile-tuning catalog —
    /// is flushed to its artifacts so a warm model survives an orderly
    /// shutdown.
    pub fn shutdown(&self) {
        self.supervisor_stop.store(true, Ordering::SeqCst);
        if let Some(h) = psync::lock(&self.supervisor).take() {
            let _ = h.join();
        }
        for s in &self.shards {
            s.close();
        }
        let (slots, retired) = {
            let mut g = psync::lock(&self.workers);
            (std::mem::take(&mut g.slots), std::mem::take(&mut g.retired))
        };
        for s in slots {
            let _ = s.handle.join();
        }
        for h in retired {
            let _ = h.join();
        }
        self.cost_model.save_if_dirty();
        crate::ozaki::tune::flush();
    }
}

/// Backoff schedule for [`GemmService::submit_with_retry`].
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Total submission attempts (the first try included); at least 1.
    pub max_attempts: usize,
    /// Sleep before the first retry; doubles each attempt after.
    pub base_backoff: Duration,
    /// Ceiling on any single backoff sleep.
    pub max_backoff: Duration,
    /// Seed of the deterministic jitter stream ([`Rng`]), so retry
    /// timing is reproducible under test.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 6,
            base_backoff: Duration::from_micros(200),
            max_backoff: Duration::from_millis(20),
            seed: 0x5eed_ba11,
        }
    }
}

/// Decrements the inflight counter on drop, so a request whose engine
/// call panics still leaves the counter accurate during unwind.
struct InflightGuard<'a>(&'a AtomicU64);

impl Drop for InflightGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

#[derive(Clone, Copy)]
struct CoalesceKnobs {
    coalesce: bool,
    window: Duration,
    max_batch: usize,
}

/// Best-effort panic payload message (worker-side; the payload itself
/// cannot cross the reply channel, only a `String` rendering).
fn panic_msg(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "engine panicked".to_string()
    }
}

fn worker_main(ctx: WorkerCtx, beat: Arc<AtomicU64>, superseded: Arc<AtomicBool>) {
    let WorkerCtx { queue, engine, metrics, inflight, knobs, default_deadline } = ctx;
    loop {
        beat.store(0, Ordering::SeqCst); // idle: blocked in pop
        let item = match queue.pop() {
            Some(item) => item,
            None => break, // closed and drained
        };
        beat.store(monotonic_ms(), Ordering::SeqCst);
        if faultinject::fires(faultinject::site::WORKER_PANIC) {
            // A worker killed mid-request: decrement inflight for the
            // items in hand (their InflightGuards never get built), then
            // unwind — the reply drop guards turn every dropped reply
            // into `ReplyLost`, and the supervisor respawns the slot.
            inflight.fetch_sub(item.len() as u64, Ordering::SeqCst);
            panic!("injected fault: worker killed mid-request");
        }
        faultinject::hang(faultinject::site::WORKER_HANG);
        match item {
            QueueItem::Batch(reqs) => {
                process_group(&engine, reqs, &metrics, &inflight, default_deadline)
            }
            QueueItem::One(req) => {
                if !knobs.coalesce {
                    process_single(&engine, req, &metrics, &inflight, default_deadline);
                } else {
                    let mut batch = vec![req];
                    queue.drain_into(&mut batch, knobs.max_batch, Instant::now() + knobs.window);
                    if faultinject::fires(faultinject::site::DRAIN_COALESCE) {
                        inflight.fetch_sub(batch.len() as u64, Ordering::SeqCst);
                        panic!("injected fault: coalescing drain panicked");
                    }
                    if batch.len() == 1 {
                        let req = batch.pop().expect("len checked");
                        process_single(&engine, req, &metrics, &inflight, default_deadline);
                    } else {
                        process_group(&engine, batch, &metrics, &inflight, default_deadline);
                    }
                }
            }
        }
        if superseded.load(Ordering::SeqCst) {
            // The supervisor replaced this worker while it looked hung;
            // its current request was still answered (above), but it must
            // not keep draining alongside its replacement.
            break;
        }
    }
    beat.store(0, Ordering::SeqCst);
}

/// Whether `req` expired in the queue; sheds it (typed reply + metric)
/// when so. Called at dequeue, before any compute is spent.
fn shed_if_expired(
    req: &mut GemmRequest,
    metrics: &Metrics,
    inflight: &AtomicU64,
    default_deadline: Option<Duration>,
) -> bool {
    let Some(d) = req.deadline.or(default_deadline) else { return false };
    if req.submitted.elapsed() <= d {
        return false;
    }
    {
        let _guard = InflightGuard(inflight);
    }
    metrics.record_shed(req.tier, 1);
    req.reply.send(Err(GemmError::DeadlineExceeded));
    true
}

fn process_single(
    engine: &AdpEngine,
    mut req: GemmRequest,
    metrics: &Metrics,
    inflight: &AtomicU64,
    default_deadline: Option<Duration>,
) {
    if shed_if_expired(&mut req, metrics, inflight, default_deadline) {
        return;
    }
    // Pre-validate: an invalid shape is a per-request error response,
    // never a worker-killing assert.
    if req.a.cols != req.b.rows {
        {
            let _guard = InflightGuard(inflight);
        }
        metrics.record_failure(req.tier);
        let err = GemmError::ShapeMismatch {
            m: req.a.rows,
            k_a: req.a.cols,
            k_b: req.b.rows,
            n: req.b.cols,
        };
        req.reply.send(Err(err));
        return;
    }
    let t0 = Instant::now();
    let queue_s = t0.saturating_duration_since(req.submitted).as_secs_f64();
    let outcome = {
        // Scope the guard so the decrement lands before the reply is
        // sent (a caller seeing its response must see inflight drop),
        // while a panic in the engine still decrements during unwind.
        // The engine holds no locks where user-influenced code runs
        // (guardrails, heuristic, kernels), so catching the unwind
        // cannot strand a poisoned mutex.
        let _guard = InflightGuard(inflight);
        catch_unwind(AssertUnwindSafe(|| engine.gemm_tiered(&req.a, &req.b, req.accuracy)))
    };
    match outcome {
        Ok((c, outcome)) => {
            let proc_s = t0.elapsed().as_secs_f64();
            let total_s = queue_s + proc_s;
            metrics.record_latency(req.tier, queue_s, total_s);
            req.reply.send(Ok(GemmResponse { c, outcome, queue_s, proc_s, total_s }));
        }
        Err(payload) => {
            metrics.record_failure(req.tier);
            req.reply.send(Err(GemmError::EnginePanic(panic_msg(payload.as_ref()))));
        }
    }
}

fn process_group(
    engine: &AdpEngine,
    reqs: Vec<GemmRequest>,
    metrics: &Metrics,
    inflight: &AtomicU64,
    default_deadline: Option<Duration>,
) {
    // Deadline shedding first: an expired member leaves the group before
    // bucketing, so no schedule is built around work nobody wants.
    let mut reqs: Vec<GemmRequest> = reqs
        .into_iter()
        .filter_map(|mut r| {
            (!shed_if_expired(&mut r, metrics, inflight, default_deadline)).then_some(r)
        })
        .collect();
    if reqs.is_empty() {
        return;
    }
    // Shape-mismatched requests cannot enter a grouped schedule; they
    // get an explicit typed error response — a reply sender is never
    // dropped silently — without killing the worker or the rest of the
    // group.
    let (valid, invalid): (Vec<GemmRequest>, Vec<GemmRequest>) =
        reqs.drain(..).partition(|r| r.a.cols == r.b.rows);
    for mut req in invalid {
        {
            let _guard = InflightGuard(inflight);
        }
        metrics.record_failure(req.tier);
        let err = GemmError::ShapeMismatch {
            m: req.a.rows,
            k_a: req.a.cols,
            k_b: req.b.rows,
            n: req.b.cols,
        };
        req.reply.send(Err(err));
    }
    if valid.is_empty() {
        return;
    }
    // Bucket by (shape, accuracy tier): plan-cache keys repeat within a
    // bucket, the grouped schedule stays load-balanced, and mixed-tier
    // groups run as separate schedules — a fast member can never change
    // a guaranteed member's truncation depth (or its bits).
    let mut buckets: HashMap<(usize, usize, usize, AccuracyTier), Vec<GemmRequest>> =
        HashMap::new();
    for req in valid {
        buckets
            .entry((req.a.rows, req.a.cols, req.b.cols, req.accuracy))
            .or_default()
            .push(req);
    }
    // Deterministic bucket order (HashMap iteration order is arbitrary).
    let mut buckets: Vec<_> = buckets.into_values().collect();
    buckets.sort_by_key(|reqs| (reqs[0].a.rows, reqs[0].a.cols, reqs[0].b.cols, reqs[0].accuracy));
    for bucket in buckets {
        let accuracy = bucket[0].accuracy;
        metrics.record_coalesced_batch(bucket.len() as u64);
        let t0 = Instant::now();
        let results = {
            // One guard per request, held across the grouped call: a
            // panic inside the engine unwinds through them, so the
            // bucket cannot leak inflight counts — and the decrements
            // land before any reply is sent either way (guards drop when
            // this block exits, replies go out below).
            let _guards: Vec<InflightGuard<'_>> =
                bucket.iter().map(|_| InflightGuard(inflight)).collect();
            let probs: Vec<(&Matrix, &Matrix)> = bucket.iter().map(|r| (&r.a, &r.b)).collect();
            catch_unwind(AssertUnwindSafe(|| engine.gemm_grouped_tiered(&probs, accuracy)))
        };
        let proc_s = t0.elapsed().as_secs_f64();
        match results {
            Ok(results) => {
                for (mut req, (c, outcome)) in bucket.into_iter().zip(results) {
                    // The bucket completes as one schedule, so every
                    // member's processing latency is the bucket wall
                    // time; queueing is everything before execution
                    // began. `total_s` is the exact sum of the two
                    // reported components (the old path mixed a clamped
                    // and an unclamped queue_s, so totals disagreed
                    // with their parts).
                    let queue_s = t0.saturating_duration_since(req.submitted).as_secs_f64();
                    let total_s = queue_s + proc_s;
                    metrics.record_latency(req.tier, queue_s, total_s);
                    req.reply.send(Ok(GemmResponse { c, outcome, queue_s, proc_s, total_s }));
                }
            }
            Err(payload) => {
                let msg = panic_msg(payload.as_ref());
                for mut req in bucket {
                    metrics.record_failure(req.tier);
                    req.reply.send(Err(GemmError::EnginePanic(msg.clone())));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::heuristic::{AlwaysEmulate, HeuristicInput};
    use crate::linalg::gemm;
    use crate::util::{prop, Rng};
    use std::sync::atomic::AtomicBool;

    fn small_service(workers: usize) -> GemmService {
        // Pin the guaranteed tier: these tests assert FP64-grade accuracy
        // and exact cache/latency accounting, which must hold regardless
        // of any ADP_TIER the test environment exports.
        let cfg = ServiceConfig {
            workers,
            use_artifacts: false,
            default_tier: AccuracyTier::GuaranteedFp64,
            ..Default::default()
        };
        GemmService::start(cfg, None, || Box::new(AlwaysEmulate))
    }

    #[test]
    fn serves_correct_results() {
        let svc = small_service(2);
        let mut rng = Rng::new(90);
        let a = Matrix::uniform(16, 16, -1.0, 1.0, &mut rng);
        let b = Matrix::uniform(16, 16, -1.0, 1.0, &mut rng);
        let resp = svc.gemm_blocking(a.clone(), b.clone()).expect("request served");
        let err = resp.c.sub(&gemm(&a, &b)).max_abs();
        assert!(err < 1e-12, "err={err}");
        assert!(resp.outcome.decision.is_emulated());
        svc.shutdown();
    }

    #[test]
    fn parallel_requests_all_complete() {
        let svc = small_service(4);
        let mut rng = Rng::new(91);
        let mut pending = Vec::new();
        let mut expects = Vec::new();
        for _ in 0..24 {
            let n = 4 + rng.index(12);
            let a = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
            let b = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
            expects.push(gemm(&a, &b));
            pending.push(svc.submit(a, b).expect("service running"));
        }
        for (rx, expect) in pending.into_iter().zip(expects) {
            let resp = rx.recv().unwrap().expect("request served");
            assert!(resp.c.sub(&expect).max_abs() < 1e-12);
        }
        assert_eq!(svc.metrics.snapshot().requests, 24);
        assert_eq!(svc.inflight(), 0);
        svc.shutdown();
    }

    #[test]
    fn serial_and_parallel_service_agree_bitwise() {
        // The backend choice is invisible in the results — the whole
        // service stack must be bitwise deterministic either way.
        let mk = |backend| {
            let cfg =
                ServiceConfig { workers: 2, use_artifacts: false, backend, ..Default::default() };
            GemmService::start(cfg, None, || Box::new(AlwaysEmulate))
        };
        let svc_ser = mk(BackendSpec::Serial);
        let svc_par = mk(BackendSpec::Parallel { threads: 4 });
        let mut rng = Rng::new(93);
        let a = Matrix::uniform(24, 24, -1.0, 1.0, &mut rng);
        let b = Matrix::uniform(24, 24, -1.0, 1.0, &mut rng);
        let c_ser = svc_ser.gemm_blocking(a.clone(), b.clone()).expect("served").c;
        let c_par = svc_par.gemm_blocking(a, b).expect("served").c;
        for (x, y) in c_ser.data.iter().zip(&c_par.data) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        svc_ser.shutdown();
        svc_par.shutdown();
    }

    #[test]
    fn sharded_service_agrees_bitwise_with_single_queue() {
        // Sharding is a scheduling decision only: N shards with sliced
        // pools produce bit-identical results to the single queue.
        let mk = |shards| {
            let cfg = ServiceConfig {
                workers: 4,
                shards,
                use_artifacts: false,
                ..Default::default()
            };
            GemmService::start(cfg, None, || Box::new(AlwaysEmulate))
        };
        let svc_1 = mk(1);
        let svc_4 = mk(4);
        assert_eq!(svc_1.shard_count(), 1);
        assert_eq!(svc_4.shard_count(), 4);
        let mut rng = Rng::new(101);
        for i in 0..8 {
            let n = 8 + 4 * (i % 3);
            let a = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
            let b = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
            let c1 = svc_1.gemm_blocking(a.clone(), b.clone()).expect("served").c;
            let c4 = svc_4.gemm_blocking(a, b).expect("served").c;
            for (x, y) in c1.data.iter().zip(&c4.data) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        assert_eq!(svc_4.metrics.snapshot().requests, 8);
        assert_eq!(svc_4.inflight(), 0);
        svc_1.shutdown();
        svc_4.shutdown();
    }

    #[test]
    fn shard_routing_is_stable_and_in_range() {
        let svc = GemmService::start(
            ServiceConfig { workers: 3, shards: 3, use_artifacts: false, ..Default::default() },
            None,
            || Box::new(AlwaysEmulate),
        );
        for (m, k, n) in [(8, 8, 8), (16, 8, 4), (64, 64, 64), (1, 1000, 1)] {
            let s = svc.shard_for(m, k, n);
            assert!(s < 3);
            assert_eq!(s, svc.shard_for(m, k, n), "routing must be deterministic");
        }
        // The hash actually spreads: 64 distinct shapes cannot all land
        // on one of three shards.
        let hit: std::collections::HashSet<usize> =
            (1..=64).map(|n| svc.shard_for(n, n, n)).collect();
        assert!(hit.len() > 1, "shape hash must use more than one shard");
        svc.shutdown();
    }

    #[test]
    fn warm_service_serves_repeat_shapes_with_zero_fresh_workspaces() {
        // Acceptance criterion of the workspace satellite: once warm, a
        // service sees repeat shapes without a single fresh scratch
        // allocation — checkouts and fused tiles keep climbing, the
        // fresh-allocation gauge stays flat.
        let svc = small_service(2);
        let mut rng = Rng::new(99);
        let mk = |rng: &mut Rng| {
            (Matrix::uniform(16, 16, -1.0, 1.0, rng), Matrix::uniform(16, 16, -1.0, 1.0, rng))
        };
        for _ in 0..4 {
            let (a, b) = mk(&mut rng);
            let resp = svc.gemm_blocking(a, b).expect("request served");
            assert!(resp.outcome.decision.is_emulated());
        }
        let warm = svc.metrics.snapshot();
        assert!(warm.workspace_checkouts >= 4, "one checkout per fused request: {warm:?}");
        assert!(warm.fused_tiles >= 4, "each 16x16 request runs one fused tile: {warm:?}");
        assert!(warm.workspace_fresh >= 1, "cold pool must have allocated once");
        for _ in 0..6 {
            let (a, b) = mk(&mut rng);
            svc.gemm_blocking(a, b).expect("request served");
        }
        let after = svc.metrics.snapshot();
        assert!(after.workspace_checkouts >= warm.workspace_checkouts + 6);
        assert!(after.fused_tiles >= warm.fused_tiles + 6);
        assert_eq!(
            after.workspace_fresh, warm.workspace_fresh,
            "warm service must serve repeat shapes with zero fresh workspace allocations"
        );
        svc.shutdown();
    }

    #[test]
    fn shape_mismatch_is_a_typed_error_and_the_worker_survives() {
        // The old behavior let a mismatched request assert inside the
        // engine, killing the worker and eventually the service; now the
        // submitter gets a typed error and the worker keeps serving.
        let svc = small_service(1);
        let resp = svc.gemm_blocking(Matrix::zeros(2, 3), Matrix::zeros(4, 2));
        assert_eq!(
            resp.err(),
            Some(GemmError::ShapeMismatch { m: 2, k_a: 3, k_b: 4, n: 2 })
        );
        assert_eq!(svc.inflight(), 0, "failed request must not leak the inflight counter");
        // Same worker, next request: served normally.
        let ok = svc.gemm_blocking(Matrix::identity(4), Matrix::identity(4)).expect("served");
        assert_eq!(ok.c.at(0, 0), 1.0);
        let tiers = svc.metrics.snapshot().tiers;
        assert_eq!(tiers[Priority::Normal.index()].failed, 1);
        assert_eq!(tiers[Priority::Normal.index()].completed, 1);
        svc.shutdown();
    }

    /// Heuristic that panics on 5x5 problems (and only those) — drives
    /// an engine panic from inside a worker deterministically.
    struct PanicOnFive;

    impl SelectionHeuristic for PanicOnFive {
        fn emulate(&self, inp: &HeuristicInput) -> bool {
            assert!(inp.m != 5, "panic-on-five heuristic tripped");
            true
        }
        fn name(&self) -> &'static str {
            "panic-on-five"
        }
    }

    #[test]
    fn engine_panic_is_a_typed_error_and_the_worker_survives() {
        let cfg = ServiceConfig { workers: 1, use_artifacts: false, ..Default::default() };
        let svc = GemmService::start(cfg, None, || Box::new(PanicOnFive));
        let resp = svc.gemm_blocking(Matrix::identity(5), Matrix::identity(5));
        match resp {
            Err(GemmError::EnginePanic(msg)) => {
                assert!(msg.contains("panic-on-five"), "payload preserved: {msg}")
            }
            other => panic!("expected EnginePanic, got {:?}", other.err()),
        }
        assert_eq!(svc.inflight(), 0, "panicked request must not leak the inflight counter");
        // The same (sole) worker keeps serving.
        let ok = svc.gemm_blocking(Matrix::identity(4), Matrix::identity(4)).expect("served");
        assert_eq!(ok.c.at(1, 1), 1.0);
        assert_eq!(svc.metrics.snapshot().tiers[Priority::Normal.index()].failed, 1);
        svc.shutdown();
    }

    #[test]
    fn engine_panic_in_grouped_path_fails_the_bucket_not_the_group() {
        // A panicking bucket produces typed errors for its members; the
        // other shape buckets of the same group still complete.
        let cfg = ServiceConfig { workers: 1, use_artifacts: false, ..Default::default() };
        let svc = GemmService::start(cfg, None, || Box::new(PanicOnFive));
        let rxs = svc
            .submit_batch(vec![
                (Matrix::identity(4), Matrix::identity(4)),
                (Matrix::identity(5), Matrix::identity(5)), // panics its bucket
                (Matrix::identity(4), Matrix::identity(4)),
            ])
            .expect("service running");
        assert!(rxs[0].recv().unwrap().is_ok());
        assert!(matches!(rxs[1].recv().unwrap(), Err(GemmError::EnginePanic(_))));
        assert!(rxs[2].recv().unwrap().is_ok());
        assert_eq!(svc.inflight(), 0);
        svc.shutdown();
    }

    #[test]
    fn shutdown_then_submit_reports_stopped() {
        let svc = small_service(2);
        svc.shutdown();
        assert_eq!(
            svc.submit(Matrix::identity(2), Matrix::identity(2)).err(),
            Some(SubmitError::ServiceStopped)
        );
        let rej = svc.try_submit(Matrix::identity(2), Matrix::identity(2)).unwrap_err();
        assert_eq!(rej.error, SubmitError::ServiceStopped);
        assert!(!rej.error.is_retryable());
        assert_eq!((rej.a.rows, rej.b.rows), (2, 2), "operands returned for inspection");
        assert_eq!(svc.submit_batch(vec![]).unwrap().len(), 0, "empty batch is trivially ok");
        assert_eq!(
            svc.submit_batch(vec![(Matrix::identity(2), Matrix::identity(2))]).err(),
            Some(SubmitError::ServiceStopped)
        );
        let rej = svc.submit_async(Matrix::identity(2), Matrix::identity(2), Priority::High);
        assert_eq!(rej.unwrap_err().error, SubmitError::ServiceStopped);
        // gemm_blocking folds the rejection instead of panicking.
        assert_eq!(
            svc.gemm_blocking(Matrix::identity(2), Matrix::identity(2)).err(),
            Some(GemmError::Rejected(SubmitError::ServiceStopped))
        );
        svc.shutdown(); // idempotent
        assert_eq!(svc.inflight(), 0);
    }

    /// Heuristic that parks its worker until the gate opens — makes
    /// queue-depth conditions deterministic.
    struct GatedHeuristic {
        entered: Arc<AtomicBool>,
        gate: Arc<(Mutex<bool>, Condvar)>,
    }

    impl SelectionHeuristic for GatedHeuristic {
        fn emulate(&self, _: &HeuristicInput) -> bool {
            self.entered.store(true, Ordering::SeqCst);
            let (m, cv) = &*self.gate;
            let mut open = psync::lock(m);
            while !*open {
                open = psync::wait(cv, open);
            }
            true
        }
        fn name(&self) -> &'static str {
            "gated"
        }
    }

    type Gate = Arc<(Mutex<bool>, Condvar)>;

    fn gated_service(cfg: ServiceConfig) -> (GemmService, Arc<AtomicBool>, Gate) {
        let entered = Arc::new(AtomicBool::new(false));
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let svc = {
            let (entered, gate) = (entered.clone(), gate.clone());
            GemmService::start(cfg, None, move || {
                Box::new(GatedHeuristic { entered: entered.clone(), gate: gate.clone() })
            })
        };
        (svc, entered, gate)
    }

    fn open_gate(gate: &Arc<(Mutex<bool>, Condvar)>) {
        let (m, cv) = &**gate;
        *psync::lock(m) = true;
        cv.notify_all();
    }

    #[test]
    fn try_submit_reports_queue_full_and_recovers() {
        let cfg = ServiceConfig {
            workers: 1,
            queue_depth: 1,
            use_artifacts: false,
            ..Default::default()
        };
        let (svc, entered, gate) = gated_service(cfg);
        let mk = || (Matrix::identity(4), Matrix::identity(4));
        // First request: picked up by the worker, parked in the heuristic.
        let (a, b) = mk();
        let rx1 = svc.submit(a, b).expect("queue open");
        while !entered.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(1));
        }
        // Second request: fills the shard's only queue slot.
        let (a, b) = mk();
        let rx2 = svc.submit(a, b).expect("queue open");
        // Third: the shard is full — retryable backpressure, not fatal.
        let (a, b) = mk();
        let rej = svc.try_submit(a, b).unwrap_err();
        assert_eq!(rej.error, SubmitError::QueueFull);
        assert!(rej.error.is_retryable());
        // Open the gate; the backlog drains and the retry succeeds.
        open_gate(&gate);
        assert!(rx1.recv().unwrap().is_ok());
        assert!(rx2.recv().unwrap().is_ok());
        let rx3 = svc
            .try_submit(rej.a, rej.b)
            .map_err(|r| r.error)
            .expect("retry after drain succeeds");
        assert!(rx3.recv().unwrap().is_ok());
        assert_eq!(svc.inflight(), 0);
        svc.shutdown();
    }

    #[test]
    fn tier_caps_reject_independently_and_retryably() {
        // tier cap 1 on High and Normal, roomy shard total: the *tier*
        // verdict fires while other tiers still admit.
        let cfg = ServiceConfig {
            workers: 1,
            queue_depth: 16,
            tier_depths: [1, 1, 16],
            use_artifacts: false,
            ..Default::default()
        };
        let (svc, entered, gate) = gated_service(cfg);
        let mk = || (Matrix::identity(4), Matrix::identity(4));
        // Park the worker on a first (Normal) request.
        let (a, b) = mk();
        let rx0 = svc.submit(a, b).expect("queue open");
        while !entered.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(1));
        }
        // One queued High admits (empty tier), a second hits the cap.
        let (a, b) = mk();
        let mut t1 = svc.submit_async(a, b, Priority::High).expect("first high admits");
        let (a, b) = mk();
        let rej = svc.submit_async(a, b, Priority::High).unwrap_err();
        assert_eq!(rej.error, SubmitError::TierFull);
        assert!(rej.error.is_retryable());
        // Normal still admits its own first queued request...
        let (a, b) = mk();
        let rx2 = svc.submit(a, b).expect("normal tier independent of high");
        // ...and then hits its own cap, while Batch remains open.
        let (a, b) = mk();
        assert_eq!(svc.try_submit(a, b).unwrap_err().error, SubmitError::TierFull);
        let rxb = svc.submit_batch(vec![mk()]).expect("batch tier still open");
        // Tier rejections are visible per tier in the metrics.
        let tiers = svc.metrics.snapshot().tiers;
        assert_eq!(tiers[Priority::High.index()].rejected, 1);
        assert_eq!(tiers[Priority::Normal.index()].rejected, 1);
        assert_eq!(tiers[Priority::Batch.index()].rejected, 0);
        open_gate(&gate);
        assert!(rx0.recv().unwrap().is_ok());
        assert!(t1.wait().is_ok());
        assert!(rx2.recv().unwrap().is_ok());
        assert!(rxb[0].recv().unwrap().is_ok());
        assert_eq!(svc.inflight(), 0);
        svc.shutdown();
    }

    #[test]
    fn high_tier_drains_before_batch_tier() {
        let cfg = ServiceConfig {
            workers: 1,
            use_artifacts: false,
            ..Default::default()
        };
        let (svc, entered, gate) = gated_service(cfg);
        // Park the worker, then queue Batch *before* High.
        let rx0 = svc.submit(Matrix::identity(4), Matrix::identity(4)).expect("open");
        while !entered.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(1));
        }
        let order = Arc::new(Mutex::new(Vec::new()));
        let (o1, o2) = (order.clone(), order.clone());
        svc.submit_callback(Matrix::identity(6), Matrix::identity(6), Priority::Batch, move |r| {
            assert!(r.is_ok());
            psync::lock(&o1).push("batch");
        })
        .expect("admitted");
        svc.submit_callback(Matrix::identity(8), Matrix::identity(8), Priority::High, move |r| {
            assert!(r.is_ok());
            psync::lock(&o2).push("high");
        })
        .expect("admitted");
        open_gate(&gate);
        // Wait for the queue to drain through the sole worker.
        while svc.inflight() != 0 {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(
            *psync::lock(&order),
            vec!["high", "batch"],
            "High must be dequeued before Batch even when enqueued later"
        );
        assert!(rx0.recv().unwrap().is_ok());
        svc.shutdown();
    }

    #[test]
    fn ticket_polls_to_completion_and_callback_fires() {
        let svc = small_service(2);
        let mut t = svc
            .submit_async(Matrix::identity(6), Matrix::identity(6), Priority::High)
            .expect("admitted");
        let resp = loop {
            match t.poll() {
                Some(r) => break r.expect("served"),
                None => std::thread::sleep(Duration::from_millis(1)),
            }
        };
        assert_eq!(resp.c.at(2, 2), 1.0);
        assert_eq!(resp.total_s.to_bits(), (resp.queue_s + resp.proc_s).to_bits());
        let (done_tx, done_rx) = channel();
        svc.submit_callback(Matrix::identity(3), Matrix::identity(3), Priority::Normal, move |r| {
            done_tx.send(r.map(|resp| resp.c.at(0, 0))).unwrap();
        })
        .expect("admitted");
        assert_eq!(done_rx.recv().unwrap().expect("served"), 1.0);
        assert_eq!(svc.inflight(), 0);
        svc.shutdown();
    }

    #[test]
    fn latency_components_sum_exactly_on_both_paths() {
        // The grouped-latency satellite's pin: total_s == queue_s +
        // proc_s bit-for-bit, on the single path and the grouped path.
        let svc = small_service(2);
        let mut rng = Rng::new(103);
        let mk = |rng: &mut Rng| {
            (Matrix::uniform(12, 12, -1.0, 1.0, rng), Matrix::uniform(12, 12, -1.0, 1.0, rng))
        };
        for _ in 0..3 {
            let (a, b) = mk(&mut rng);
            let r = svc.gemm_blocking(a, b).expect("served");
            assert!(r.queue_s >= 0.0 && r.proc_s > 0.0);
            assert_eq!(r.total_s.to_bits(), (r.queue_s + r.proc_s).to_bits());
        }
        let pairs: Vec<_> = (0..5).map(|_| mk(&mut rng)).collect();
        let rxs = svc.submit_batch(pairs).expect("service running");
        let resps: Vec<GemmResponse> =
            rxs.into_iter().map(|rx| rx.recv().unwrap().expect("served")).collect();
        for r in &resps {
            assert!(r.queue_s >= 0.0 && r.proc_s > 0.0);
            assert_eq!(
                r.total_s.to_bits(),
                (r.queue_s + r.proc_s).to_bits(),
                "reported total must equal the sum of its reported components"
            );
        }
        // Same shape bucket => every member reports the same bucket wall
        // time as proc_s.
        for r in &resps[1..] {
            assert_eq!(r.proc_s.to_bits(), resps[0].proc_s.to_bits());
        }
        svc.shutdown();
    }

    #[test]
    fn per_tier_latency_metrics_populate() {
        let svc = small_service(2);
        for _ in 0..4 {
            svc.gemm_blocking(Matrix::identity(8), Matrix::identity(8)).expect("served");
        }
        let t = svc
            .submit_async(Matrix::identity(8), Matrix::identity(8), Priority::High)
            .expect("admitted");
        t.wait().expect("served");
        let tiers = svc.metrics.snapshot().tiers;
        let normal = &tiers[Priority::Normal.index()];
        assert_eq!(normal.tier, "normal");
        assert_eq!(normal.enqueued, 4);
        assert_eq!(normal.completed, 4);
        assert_eq!(normal.failed, 0);
        assert!(normal.total_p50_s > 0.0, "p50 must be measured: {normal:?}");
        assert!(normal.total_p99_s >= normal.total_p50_s);
        assert!(normal.queue_p50_s <= normal.total_p50_s);
        let high = &tiers[Priority::High.index()];
        assert_eq!((high.enqueued, high.completed), (1, 1));
        assert_eq!(tiers[Priority::Batch.index()].completed, 0);
        svc.shutdown();
    }

    #[test]
    fn submit_batch_amortizes_shared_operand() {
        // Acceptance criterion: N same-A requests through submit_batch
        // perform exactly 1 decomposition of A (and N of B), bitwise
        // identical to the per-request path.
        let n_reqs = 5;
        let svc = small_service(2);
        let mut rng = Rng::new(94);
        // Entries in [1, 2): every request's ESC (and hence slice count)
        // is identical, so the shared A maps to exactly one cache key.
        let a = Matrix::uniform(16, 16, 1.0, 2.0, &mut rng);
        let bs: Vec<Matrix> =
            (0..n_reqs).map(|_| Matrix::uniform(16, 16, 1.0, 2.0, &mut rng)).collect();
        let pairs: Vec<(Matrix, Matrix)> =
            bs.iter().map(|b| (a.clone(), b.clone())).collect();
        let rxs = svc.submit_batch(pairs).expect("service running");
        let grouped: Vec<Matrix> =
            rxs.into_iter().map(|rx| rx.recv().unwrap().expect("served").c).collect();
        let snap = svc.metrics.snapshot();
        assert_eq!(snap.slice_cache_misses, n_reqs as u64 + 1, "A once + N Bs");
        assert_eq!(snap.slice_cache_hits, n_reqs as u64 - 1, "A reused N-1 times");
        assert_eq!(snap.coalesced_batches, 1);
        assert_eq!(snap.coalesced_requests, n_reqs as u64);
        assert_eq!(snap.requests, n_reqs as u64);
        assert_eq!(svc.inflight(), 0);
        // Bitwise identity against the per-request service path.
        let svc_ref = small_service(1);
        for (b, c) in bs.iter().zip(&grouped) {
            let c_ref = svc_ref.gemm_blocking(a.clone(), b.clone()).expect("served").c;
            for (x, y) in c.data.iter().zip(&c_ref.data) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        svc_ref.shutdown();
        svc.shutdown();
    }

    #[test]
    fn submit_batch_mixed_shapes_bucketed() {
        let svc = small_service(2);
        let mut rng = Rng::new(95);
        let mut pairs = Vec::new();
        let mut expects = Vec::new();
        for i in 0..6 {
            let n = if i % 2 == 0 { 8 } else { 12 };
            let a = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
            let b = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
            expects.push(gemm(&a, &b));
            pairs.push((a, b));
        }
        let rxs = svc.submit_batch(pairs).expect("service running");
        for (rx, expect) in rxs.into_iter().zip(expects) {
            let resp = rx.recv().unwrap().expect("served");
            assert!(resp.c.sub(&expect).max_abs() < 1e-12);
            assert!(resp.outcome.decision.is_emulated());
        }
        let snap = svc.metrics.snapshot();
        assert_eq!(snap.coalesced_batches, 2, "two shape buckets");
        assert_eq!(snap.coalesced_requests, 6);
        assert_eq!(svc.inflight(), 0);
        svc.shutdown();
    }

    #[test]
    fn batched_shape_mismatch_is_typed_error_not_dead_worker() {
        let svc = small_service(1);
        let mut rng = Rng::new(96);
        let a = Matrix::uniform(6, 6, -1.0, 1.0, &mut rng);
        let b = Matrix::uniform(6, 6, -1.0, 1.0, &mut rng);
        let rxs = svc
            .submit_batch(vec![
                (a.clone(), b.clone()),
                (Matrix::zeros(2, 3), Matrix::zeros(4, 2)), // mismatched
                (a.clone(), b.clone()),
            ])
            .expect("service running");
        assert!(rxs[0].recv().unwrap().is_ok());
        assert_eq!(
            rxs[1].recv().unwrap().err(),
            Some(GemmError::ShapeMismatch { m: 2, k_a: 3, k_b: 4, n: 2 }),
            "mismatched request gets a typed error, not a dropped reply"
        );
        assert!(rxs[2].recv().unwrap().is_ok());
        assert_eq!(svc.inflight(), 0);
        // The worker survived: new submissions still work.
        assert!(svc.submit(a, b).is_ok());
        svc.shutdown();
    }

    #[test]
    fn coalesced_service_agrees_bitwise_with_uncoalesced() {
        let mk = |coalesce| {
            let cfg = ServiceConfig {
                workers: 2,
                use_artifacts: false,
                coalesce,
                coalesce_window: Duration::from_millis(5),
                ..Default::default()
            };
            GemmService::start(cfg, None, || Box::new(AlwaysEmulate))
        };
        let svc_c = mk(true);
        let svc_u = mk(false);
        let mut rng = Rng::new(97);
        let a = Matrix::uniform(20, 20, -1.0, 1.0, &mut rng);
        let bs: Vec<Matrix> =
            (0..8).map(|_| Matrix::uniform(20, 20, -1.0, 1.0, &mut rng)).collect();
        let pend_c: Vec<_> =
            bs.iter().map(|b| svc_c.submit(a.clone(), b.clone()).unwrap()).collect();
        let pend_u: Vec<_> =
            bs.iter().map(|b| svc_u.submit(a.clone(), b.clone()).unwrap()).collect();
        for (rc, ru) in pend_c.into_iter().zip(pend_u) {
            let cc = rc.recv().unwrap().expect("served").c;
            let cu = ru.recv().unwrap().expect("served").c;
            for (x, y) in cc.data.iter().zip(&cu.data) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        assert_eq!(svc_c.metrics.snapshot().requests, 8);
        svc_c.shutdown();
        svc_u.shutdown();
    }

    #[test]
    fn prop_request_response_bijection() {
        // Every response matches *its own* request (no cross-wiring),
        // verified by tagging requests with distinguishable scalings —
        // through both the singleton and the batched submission paths.
        let svc = small_service(3);
        prop::check("service bijection", 8, |rng| {
            let mut pending = Vec::new();
            let mut batch = Vec::new();
            for tag in 1..=6u32 {
                let scale = tag as f64;
                let a = Matrix::from_fn(4, 4, |i, j| {
                    scale * ((i * 4 + j) as f64 + 1.0) + rng.f64() * 0.0
                });
                let b = Matrix::identity(4);
                if tag % 2 == 0 {
                    batch.push((scale, a, b));
                } else {
                    let rx = svc.submit(a, b).expect("service running");
                    pending.push((scale, rx));
                }
            }
            let scales: Vec<f64> = batch.iter().map(|(s, _, _)| *s).collect();
            let pairs: Vec<(Matrix, Matrix)> =
                batch.into_iter().map(|(_, a, b)| (a, b)).collect();
            let rxs = svc.submit_batch(pairs).expect("service running");
            pending.extend(scales.into_iter().zip(rxs));
            for (scale, rx) in pending {
                let resp = rx.recv().unwrap().expect("served");
                if (resp.c.at(0, 0) - scale).abs() > 1e-12 {
                    return Err(format!("response mismatch: {} vs {scale}", resp.c.at(0, 0)));
                }
            }
            Ok(())
        });
        svc.shutdown();
    }

    #[test]
    fn mixed_workload_outcome_accounting() {
        let svc = small_service(2);
        let mut rng = Rng::new(92);
        let mut pending = Vec::new();
        for i in 0..12 {
            let mut a = Matrix::uniform(8, 8, 1.0, 2.0, &mut rng);
            let mut b = Matrix::uniform(8, 8, 1.0, 2.0, &mut rng);
            if i % 4 == 1 {
                *a.at_mut(0, 0) = f64::NAN;
            }
            if i % 4 == 2 {
                *a.at_mut(0, 0) = f64::INFINITY;
            }
            if i % 4 == 3 {
                // huge-x-pairs-with-tiny-y: ESC beyond the slice budget
                *a.at_mut(0, 0) = 1e300;
                *b.at_mut(0, 0) = 1e-300;
            }
            pending.push(svc.submit(a, b).expect("service running"));
        }
        for rx in pending {
            rx.recv().unwrap().expect("served");
        }
        let s = svc.metrics.snapshot();
        assert_eq!(s.requests, 12);
        assert_eq!(s.fallback_nan, 3);
        assert_eq!(s.fallback_inf, 3);
        assert_eq!(s.fallback_esc, 3);
        assert_eq!(s.emulated, 3);
        svc.shutdown();
    }

    #[test]
    fn mixed_workload_accounting_through_submit_batch() {
        // The grouped path must preserve the per-request guardrail
        // accounting exactly.
        let svc = small_service(2);
        let mut rng = Rng::new(98);
        let mut pairs = Vec::new();
        for i in 0..8 {
            let mut a = Matrix::uniform(8, 8, 1.0, 2.0, &mut rng);
            let b = Matrix::uniform(8, 8, 1.0, 2.0, &mut rng);
            if i % 4 == 1 {
                *a.at_mut(0, 0) = f64::NAN;
            }
            pairs.push((a, b));
        }
        let rxs = svc.submit_batch(pairs).expect("service running");
        for rx in rxs {
            rx.recv().unwrap().expect("served");
        }
        let s = svc.metrics.snapshot();
        assert_eq!(s.requests, 8);
        assert_eq!(s.fallback_nan, 2);
        assert_eq!(s.emulated, 6);
        assert_eq!(svc.inflight(), 0);
        svc.shutdown();
    }

    #[test]
    fn per_request_accuracy_tiers_flow_through_the_service() {
        let svc = small_service(2);
        let mut rng = Rng::new(104);
        let a = Matrix::uniform(24, 24, 1.0, 2.0, &mut rng);
        let b = Matrix::uniform(24, 24, 1.0, 2.0, &mut rng);
        let c_full = svc
            .submit_tiered(a.clone(), b.clone(), AccuracyTier::GuaranteedFp64)
            .expect("service running")
            .recv()
            .unwrap()
            .expect("served")
            .c;
        let c_fast = svc
            .submit_tiered(a.clone(), b.clone(), AccuracyTier::Fp64FaithfulFast)
            .expect("service running")
            .recv()
            .unwrap()
            .expect("served")
            .c;
        let reference = gemm(&a, &b);
        let full_err = c_full.sub(&reference).max_abs();
        let fast_err = c_fast.sub(&reference).max_abs();
        assert!(full_err < 1e-12, "guaranteed tier: full_err={full_err}");
        assert!(fast_err < 1e-4, "fast tier must stay near-FP64: fast_err={fast_err}");
        let snap = svc.metrics.snapshot();
        assert_eq!(snap.tier_requests[AccuracyTier::GuaranteedFp64.index()], 1);
        assert_eq!(snap.tier_requests[AccuracyTier::Fp64FaithfulFast.index()], 1);
        assert!(snap.pairs_skipped > 0, "the fast request must skip pairs: {snap:?}");
        svc.shutdown();
    }

    #[test]
    fn mixed_tier_batches_bucket_separately_and_guaranteed_stays_bitwise() {
        let svc = small_service(2);
        let mut rng = Rng::new(105);
        let a = Matrix::uniform(16, 16, 1.0, 2.0, &mut rng);
        let b = Matrix::uniform(16, 16, 1.0, 2.0, &mut rng);
        let rxs = svc
            .submit_batch_tiered(vec![
                (a.clone(), b.clone(), AccuracyTier::GuaranteedFp64),
                (a.clone(), b.clone(), AccuracyTier::Fp64FaithfulFast),
                (a.clone(), b.clone(), AccuracyTier::GuaranteedFp64),
            ])
            .expect("service running");
        let got: Vec<Matrix> =
            rxs.into_iter().map(|rx| rx.recv().unwrap().expect("served").c).collect();
        // Same shape, two tiers: the coalescer must split the group into
        // two buckets — the accuracy tier is part of the bucket key.
        let snap = svc.metrics.snapshot();
        assert_eq!(snap.coalesced_batches, 2, "one bucket per (shape, tier): {snap:?}");
        assert_eq!(snap.coalesced_requests, 3);
        // The guaranteed members match the per-request guaranteed path
        // bitwise, untouched by the fast sibling they were batched with.
        let c_ref = svc
            .submit_tiered(a, b, AccuracyTier::GuaranteedFp64)
            .expect("service running")
            .recv()
            .unwrap()
            .expect("served")
            .c;
        for idx in [0usize, 2] {
            for (x, y) in got[idx].data.iter().zip(&c_ref.data) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        svc.shutdown();
    }

    #[test]
    fn expired_requests_are_shed_with_a_typed_error() {
        let cfg = ServiceConfig {
            workers: 1,
            use_artifacts: false,
            default_deadline: Some(Duration::from_millis(25)),
            ..Default::default()
        };
        let (svc, entered, gate) = gated_service(cfg);
        // r1 dequeues fresh (inside its deadline) and parks in the
        // engine: shedding is a *dequeue* decision, in-flight work is
        // never aborted.
        let rx1 = svc.submit(Matrix::identity(4), Matrix::identity(4)).expect("open");
        while !entered.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(1));
        }
        // r2 queues behind the parked worker and expires there.
        let rx2 = svc.submit(Matrix::identity(4), Matrix::identity(4)).expect("open");
        std::thread::sleep(Duration::from_millis(60));
        open_gate(&gate);
        assert!(rx1.recv().unwrap().is_ok(), "in-flight request is not shed");
        assert_eq!(rx2.recv().unwrap().err(), Some(GemmError::DeadlineExceeded));
        let snap = svc.metrics.snapshot();
        assert_eq!(snap.shed_expired, 1);
        assert_eq!(snap.tiers[Priority::Normal.index()].shed, 1);
        // Shedding isn't sticky: a fresh request completes normally.
        assert!(svc.gemm_blocking(Matrix::identity(4), Matrix::identity(4)).is_ok());
        assert_eq!(svc.inflight(), 0, "shed requests must not leak the inflight counter");
        svc.shutdown();
    }

    #[test]
    fn per_request_deadline_overrides_the_config_default() {
        // No service-wide deadline: only the request that carries its own
        // is shed.
        let cfg = ServiceConfig { workers: 1, use_artifacts: false, ..Default::default() };
        let (svc, entered, gate) = gated_service(cfg);
        let rx1 = svc.submit(Matrix::identity(4), Matrix::identity(4)).expect("open");
        while !entered.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(1));
        }
        let t = svc
            .submit_deadline(
                Matrix::identity(4),
                Matrix::identity(4),
                Priority::High,
                Duration::from_millis(5),
            )
            .expect("admitted");
        let rx3 = svc.submit(Matrix::identity(4), Matrix::identity(4)).expect("open");
        std::thread::sleep(Duration::from_millis(30));
        open_gate(&gate);
        assert!(rx1.recv().unwrap().is_ok());
        assert_eq!(t.wait().err(), Some(GemmError::DeadlineExceeded));
        assert!(rx3.recv().unwrap().is_ok(), "requests without a deadline are never shed");
        let snap = svc.metrics.snapshot();
        assert_eq!(snap.shed_expired, 1);
        assert_eq!(snap.tiers[Priority::High.index()].shed, 1);
        svc.shutdown();
    }

    #[test]
    fn submit_with_retry_exhausts_then_succeeds_after_drain() {
        let cfg = ServiceConfig {
            workers: 1,
            queue_depth: 1,
            use_artifacts: false,
            ..Default::default()
        };
        let (svc, entered, gate) = gated_service(cfg);
        let rx1 = svc.submit(Matrix::identity(4), Matrix::identity(4)).expect("open");
        while !entered.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(1));
        }
        let rx2 = svc.submit(Matrix::identity(4), Matrix::identity(4)).expect("open");
        // Against a queue that stays full, a bounded budget exhausts and
        // hands the operands back with the retryable verdict.
        let tight = RetryPolicy {
            max_attempts: 3,
            base_backoff: Duration::from_micros(50),
            max_backoff: Duration::from_micros(200),
            seed: 7,
        };
        let rej = svc
            .submit_with_retry(Matrix::identity(4), Matrix::identity(4), Priority::Normal, &tight)
            .unwrap_err();
        assert!(rej.error.is_retryable());
        // Once the backlog drains, the backoff loop wins.
        open_gate(&gate);
        let roomy = RetryPolicy {
            max_attempts: 500,
            base_backoff: Duration::from_micros(200),
            max_backoff: Duration::from_millis(2),
            seed: 8,
        };
        let t = svc
            .submit_with_retry(rej.a, rej.b, Priority::Normal, &roomy)
            .expect("admitted after drain");
        assert!(t.wait().is_ok());
        assert!(rx1.recv().unwrap().is_ok());
        assert!(rx2.recv().unwrap().is_ok());
        svc.shutdown();
        // Permanent rejections short-circuit the backoff loop.
        let rej = svc
            .submit_with_retry(
                Matrix::identity(2),
                Matrix::identity(2),
                Priority::Normal,
                &RetryPolicy::default(),
            )
            .unwrap_err();
        assert_eq!(rej.error, SubmitError::ServiceStopped);
    }

    /// Heuristic that parks only its *first* caller — so a respawned
    /// replacement worker sails through while the original stays hung.
    struct ParkFirstHeuristic {
        parked: Arc<AtomicBool>,
        gate: Gate,
    }

    impl SelectionHeuristic for ParkFirstHeuristic {
        fn emulate(&self, _: &HeuristicInput) -> bool {
            if !self.parked.swap(true, Ordering::SeqCst) {
                let (m, cv) = &*self.gate;
                let mut open = psync::lock(m);
                while !*open {
                    open = psync::wait(cv, open);
                }
            }
            true
        }
        fn name(&self) -> &'static str {
            "park-first"
        }
    }

    #[test]
    fn supervisor_respawns_a_hung_worker_and_the_shard_keeps_serving() {
        let cfg = ServiceConfig {
            workers: 1,
            use_artifacts: false,
            default_tier: AccuracyTier::GuaranteedFp64,
            supervisor_poll: Duration::from_millis(2),
            hang_threshold: Duration::from_millis(40),
            ..Default::default()
        };
        let parked = Arc::new(AtomicBool::new(false));
        let gate: Gate = Arc::new((Mutex::new(false), Condvar::new()));
        let svc = {
            let (parked, gate) = (parked.clone(), gate.clone());
            GemmService::start(cfg, None, move || {
                Box::new(ParkFirstHeuristic { parked: parked.clone(), gate: gate.clone() })
            })
        };
        // r1 parks the shard's only worker inside the engine — to the
        // supervisor this is indistinguishable from a hang.
        let rx1 = svc.submit(Matrix::identity(4), Matrix::identity(4)).expect("open");
        while !parked.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(1));
        }
        // r2 queues behind the hang; only a respawned replacement can
        // serve it while the original stays parked.
        let rx2 = svc.submit(Matrix::identity(6), Matrix::identity(6)).expect("open");
        let r2 = rx2
            .recv_timeout(Duration::from_secs(10))
            .expect("replacement worker must pick up the backlog")
            .expect("served");
        assert_eq!(r2.c.at(5, 5), 1.0);
        assert!(svc.metrics.snapshot().worker_respawns >= 1, "respawn must be counted");
        // The hung worker recovers: its request still gets its one valid
        // reply, then the superseded worker retires instead of
        // double-draining alongside its replacement.
        open_gate(&gate);
        assert!(rx1.recv().unwrap().is_ok());
        assert!(svc.gemm_blocking(Matrix::identity(3), Matrix::identity(3)).is_ok());
        assert_eq!(svc.inflight(), 0);
        svc.shutdown();
    }
}
