//! ADP — Automatic Dynamic Precision (§5 of the paper).
//!
//! The coordinator is the paper's *system* contribution: a runtime that
//! makes emulated DGEMM safe and deployable with no user intervention.
//! Per request it runs the Fig 8 decision pipeline:
//!
//! ```text
//! scan A,B ──NaN/Inf──► native FP64 fallback
//!    │
//! coarsened ESC ──too many bits──► native FP64 fallback
//!    │
//! heuristic (cost model) ──not profitable──► native FP64 fallback
//!    │
//! emulated GEMM @ ESC-sized slice count
//!    (AOT artifact when the shape is registered, native pipeline otherwise)
//! ```
//!
//! * [`scan`] — NaN/Inf safety scan (§5.1).
//! * [`heuristic`] — emulate-vs-native selection (§5.3), batch- and
//!   accuracy-tier-aware (truncated schedules are priced at the pair
//!   count they actually run).
//! * [`costmodel`] — the online-learned ns/MAC table (EWMA per shape
//!   bucket × family × accuracy tier, fed from measured request
//!   timings, persisted via `ADP_COSTMODEL`) and [`LearnedHeuristic`],
//!   which layers it over any fallback policy.
//! * [`adp`] — the decision engine (§5.4) and its outcome record, with a
//!   grouped entry point feeding the slice-cached batched pipeline.
//! * [`plan`] — the ESC plan cache: skips redundant coarse-ESC reductions
//!   for repeat (shape, exponent-summary) keys, guarantee-preserving.
//! * [`service`] — sharded multi-worker batched GEMM service (the
//!   "cuBLAS behind a queue" deployment shape; std threads — tokio
//!   unavailable offline): shape-hash shard routing, priority-tier
//!   admission control, non-blocking `submit_async`/`submit_callback`,
//!   shape-bucketed request coalescing and `submit_batch` — with typed
//!   error responses (no service path panics the submitter).
//! * [`metrics`] — dispatch/outcome/latency accounting (Fig 7/8 inputs)
//!   plus slice-/plan-cache, coalescing, and per-tier service counters.

pub mod adp;
pub mod costmodel;
pub mod heuristic;
pub mod metrics;
pub mod plan;
pub mod scan;
pub mod service;

pub use adp::{AdpConfig, AdpEngine, AdpOutcome, GemmDecision};
pub use costmodel::{CostModel, LearnedHeuristic};
pub use metrics::{Metrics, MetricsSnapshot, TierSnapshot};
pub use plan::EscPlanCache;
pub use service::{
    GemmError, GemmResponse, GemmResult, GemmService, GemmTicket, Priority, RejectedSubmit,
    RetryPolicy, ServiceConfig, SubmitError,
};
