//! Online-learned emulation cost model (ROADMAP "dynamic accuracy
//! tiers" tentpole): an EWMA ns/MAC table keyed by
//! `(shape bucket, execution family, accuracy tier)`, fed by the
//! timings [`crate::coordinator::AdpEngine`] already measures on every
//! request it dispatches.
//!
//! The static [`crate::perfmodel::Platform`] coefficients and the
//! one-shot [`super::heuristic::CpuCalibration`] price an *idealized*
//! substrate; the learned table prices the substrate the process is
//! actually running on, per tier (truncated schedules have genuinely
//! different measured throughput per arm). [`LearnedHeuristic`] layers
//! the table over any fallback [`SelectionHeuristic`]: while a cell is
//! cold (fewer than [`MIN_SAMPLES`] observations) decisions come from
//! the fallback unchanged, so a fresh process behaves exactly like the
//! pre-learned coordinator until enough evidence accumulates.
//!
//! Persistence mirrors the tile autotuner's catalog: a small text file
//! with one `bucket arm tier ns_per_mac samples` line per warmed cell,
//! written atomically (tmp + rename). The `ADP_COSTMODEL` knob selects
//! the file (`ADP_COSTMODEL=<path>`), disables learning entirely
//! (`ADP_COSTMODEL=off`), or — when unset — keeps the model in-memory
//! only, which keeps test runs hermetic.

use std::path::PathBuf;
use std::sync::Arc;
use std::sync::Mutex;

use super::heuristic::{EmulationChoice, HeuristicInput, SelectionHeuristic};
use crate::ozaki::{AccuracyTier, ShapeBucket};
use crate::runtime::quarantine;
use crate::util::faultinject;
use crate::util::sync as psync;

/// Observations a cell needs before its prediction participates in
/// decisions. Below this the heuristic defers to its fallback — which
/// also bounds how much a few noisy early timings can sway routing.
pub const MIN_SAMPLES: u64 = 8;

/// EWMA smoothing factor: each new observation moves the cell a quarter
/// of the way to the measured value (recent behavior dominates after
/// ~a dozen requests without thrashing on one outlier).
const ALPHA: f64 = 0.25;

/// Persist at most every this many observations (plus on drop) so a
/// busy service does not pay a write per request.
const SAVE_EVERY: u64 = 32;

const CATALOG_HEADER: &str = "# adp-dgemm cost-model catalog v1";

const BUCKETS: usize = 3;
const CHOICES: usize = 3;
const TIERS: usize = 3;

fn bucket_index(b: ShapeBucket) -> usize {
    ShapeBucket::ALL.iter().position(|x| *x == b).unwrap_or(0)
}

fn choice_index(c: EmulationChoice) -> usize {
    match c {
        EmulationChoice::Native => 0,
        EmulationChoice::SlicePair => 1,
        EmulationChoice::Crt => 2,
    }
}

const CHOICE_ORDER: [EmulationChoice; CHOICES] =
    [EmulationChoice::Native, EmulationChoice::SlicePair, EmulationChoice::Crt];

fn parse_choice(s: &str) -> Option<EmulationChoice> {
    CHOICE_ORDER.into_iter().find(|c| c.label() == s)
}

/// One EWMA cell: smoothed ns per logical MAC (`m*k*n` multiply-adds of
/// the *request*, regardless of how many physical pair/residue GEMMs
/// the family ran — the family's multiplier is thus baked into the
/// cell, which is exactly why the tier belongs in the key).
#[derive(Clone, Copy, Debug, PartialEq)]
struct Cell {
    ns_per_mac: f64,
    samples: u64,
}

struct Inner {
    cells: [[[Option<Cell>; TIERS]; CHOICES]; BUCKETS],
    /// Observations since the last save (persistence cadence).
    unsaved: u64,
    dirty: bool,
}

/// The learned table plus its persistence policy. Share one instance
/// per engine (or across engines) through an `Arc`; all methods take
/// `&self`.
pub struct CostModel {
    inner: Mutex<Inner>,
    path: Option<PathBuf>,
    enabled: bool,
}

impl CostModel {
    fn empty(path: Option<PathBuf>, enabled: bool) -> CostModel {
        CostModel {
            inner: Mutex::new(Inner {
                cells: [[[None; TIERS]; CHOICES]; BUCKETS],
                unsaved: 0,
                dirty: false,
            }),
            path,
            enabled,
        }
    }

    /// In-memory model: learns within this process, never touches disk.
    pub fn in_memory() -> CostModel {
        CostModel::empty(None, true)
    }

    /// Inert model: `observe` is a no-op and `predict` always `None`
    /// (every decision stays with the fallback heuristic).
    pub fn disabled() -> CostModel {
        CostModel::empty(None, false)
    }

    /// Model persisted at `path` (loaded now if the file exists, saved
    /// atomically every [`SAVE_EVERY`] observations and on drop).
    pub fn with_path(path: PathBuf) -> CostModel {
        let model = CostModel::empty(Some(path), true);
        model.load();
        model
    }

    /// Honor the `ADP_COSTMODEL` knob: `off`/`0`/`false` disables
    /// learning, a path persists the catalog there, unset keeps the
    /// model in-memory for this process only.
    pub fn from_env() -> CostModel {
        match std::env::var("ADP_COSTMODEL").ok().as_deref() {
            Some("off") | Some("0") | Some("false") => CostModel::disabled(),
            Some(p) if !p.trim().is_empty() => CostModel::with_path(PathBuf::from(p)),
            _ => CostModel::in_memory(),
        }
    }

    /// Whether learning is active (the `off` knob reports `false`).
    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Fold one measured request into the table. `seconds` is the
    /// execution time of the dispatched family for an `m x k x n`
    /// problem; the cell stores it normalized to ns per logical MAC.
    pub fn observe(
        &self,
        m: usize,
        k: usize,
        n: usize,
        choice: EmulationChoice,
        tier: AccuracyTier,
        seconds: f64,
    ) {
        let macs = m as f64 * k as f64 * n as f64;
        if macs <= 0.0 {
            return;
        }
        self.observe_ns_per_mac(ShapeBucket::of(m, n), choice, tier, seconds * 1e9 / macs);
    }

    /// [`CostModel::observe`] with a pre-normalized ns/MAC figure
    /// (tests and calibration replays).
    pub fn observe_ns_per_mac(
        &self,
        bucket: ShapeBucket,
        choice: EmulationChoice,
        tier: AccuracyTier,
        ns_per_mac: f64,
    ) {
        if !self.enabled || !ns_per_mac.is_finite() || ns_per_mac <= 0.0 {
            return;
        }
        let should_save = {
            let mut inner = psync::lock(&self.inner);
            let cell = &mut inner.cells[bucket_index(bucket)][choice_index(choice)][tier.index()];
            *cell = Some(match *cell {
                None => Cell { ns_per_mac, samples: 1 },
                Some(c) => Cell {
                    ns_per_mac: c.ns_per_mac + ALPHA * (ns_per_mac - c.ns_per_mac),
                    samples: c.samples.saturating_add(1),
                },
            });
            inner.dirty = true;
            inner.unsaved += 1;
            if self.path.is_some() && inner.unsaved >= SAVE_EVERY {
                inner.unsaved = 0;
                true
            } else {
                false
            }
        };
        if should_save {
            self.save();
        }
    }

    /// Smoothed ns/MAC for a warmed cell; `None` while cold (fewer than
    /// [`MIN_SAMPLES`] observations) so callers fall back instead of
    /// trusting noise.
    pub fn predict(
        &self,
        bucket: ShapeBucket,
        choice: EmulationChoice,
        tier: AccuracyTier,
    ) -> Option<f64> {
        if !self.enabled {
            return None;
        }
        let inner = psync::lock(&self.inner);
        inner.cells[bucket_index(bucket)][choice_index(choice)][tier.index()]
            .filter(|c| c.samples >= MIN_SAMPLES)
            .map(|c| c.ns_per_mac)
    }

    /// Raw sample count of a cell (0 when empty) — the counters the
    /// warm/cold tests pin.
    pub fn samples(&self, bucket: ShapeBucket, choice: EmulationChoice, tier: AccuracyTier) -> u64 {
        let inner = psync::lock(&self.inner);
        inner.cells[bucket_index(bucket)][choice_index(choice)][tier.index()]
            .map_or(0, |c| c.samples)
    }

    fn serialize(&self) -> String {
        let inner = psync::lock(&self.inner);
        let mut out = String::new();
        out.push_str(CATALOG_HEADER);
        out.push('\n');
        out.push_str("# bucket arm tier ns_per_mac samples\n");
        for (bi, bucket) in ShapeBucket::ALL.iter().enumerate() {
            for (ci, choice) in CHOICE_ORDER.iter().enumerate() {
                for tier in AccuracyTier::ALL {
                    if let Some(c) = inner.cells[bi][ci][tier.index()] {
                        out.push_str(&format!(
                            "{} {} {} {:.6} {}\n",
                            bucket.label(),
                            choice.label(),
                            tier.label(),
                            c.ns_per_mac,
                            c.samples
                        ));
                    }
                }
            }
        }
        out
    }

    /// Merge a serialized catalog into the table (bad lines are skipped
    /// — same tolerance as the tile autotuner's parser: a stale or
    /// hand-edited catalog degrades to "cold", never to a crash).
    fn absorb(&self, text: &str) {
        let mut inner = psync::lock(&self.inner);
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let fields: Vec<&str> = line.split_whitespace().collect();
            if fields.len() != 5 {
                continue;
            }
            let (Some(bucket), Some(choice), Some(tier)) = (
                ShapeBucket::parse(fields[0]),
                parse_choice(fields[1]),
                AccuracyTier::parse(fields[2]),
            ) else {
                continue;
            };
            let (Ok(ns), Ok(samples)) = (fields[3].parse::<f64>(), fields[4].parse::<u64>())
            else {
                continue;
            };
            if !ns.is_finite() || ns <= 0.0 {
                continue;
            }
            inner.cells[bucket_index(bucket)][choice_index(choice)][tier.index()] =
                Some(Cell { ns_per_mac: ns, samples });
        }
    }

    /// Load the persisted catalog. Individual bad *lines* degrade to
    /// cold cells ([`CostModel::absorb`] tolerance), but a file that is
    /// not a cost-model catalog at all — wrong or missing header, or an
    /// unreadable existing file — is quarantined (renamed to
    /// `<path>.corrupt`, warned once, counted) so the run continues on a
    /// cold model and the next save starts from a clean path.
    fn load(&self) {
        let Some(path) = &self.path else { return };
        if !path.exists() {
            return; // cold start, nothing to load or quarantine
        }
        match std::fs::read_to_string(path) {
            Ok(text) => {
                let corrupt_injected = faultinject::fires(faultinject::site::COSTMODEL_LOAD_CORRUPT);
                if !text.starts_with(CATALOG_HEADER) || corrupt_injected {
                    let why = if corrupt_injected { "injected corruption" } else { "missing catalog header" };
                    quarantine::quarantine_file(path, "cost-model catalog", why);
                    return;
                }
                self.absorb(&text);
            }
            Err(e) => {
                quarantine::quarantine_file(path, "cost-model catalog", &e.to_string());
            }
        }
    }

    /// Persist the table atomically (tmp + rename, the same idiom as
    /// the runtime tuning catalog). No-op without a configured path.
    pub fn save(&self) {
        let Some(path) = &self.path else { return };
        let mut text = self.serialize();
        if faultinject::fires(faultinject::site::COSTMODEL_SAVE_TORN) {
            // Simulate a torn write slipping past tmp+rename: a header-less
            // half of the catalog lands at the final path directly. The
            // next load quarantines it.
            text = text.split_off(text.len() / 2);
            let _ = std::fs::write(path, text);
            psync::lock(&self.inner).dirty = false;
            return;
        }
        let tmp = path.with_extension("tmp");
        if std::fs::write(&tmp, text).is_ok() {
            let _ = std::fs::rename(&tmp, path);
        }
        psync::lock(&self.inner).dirty = false;
    }

    /// Persist only when observations arrived since the last save — the
    /// orderly-shutdown flush ([`crate::coordinator::GemmService::shutdown`]
    /// and `adp serve` exit). No-op without a configured path.
    pub fn save_if_dirty(&self) {
        if self.path.is_some() && psync::lock(&self.inner).dirty {
            self.save();
        }
    }
}

impl Drop for CostModel {
    fn drop(&mut self) {
        self.save_if_dirty();
    }
}

/// [`SelectionHeuristic`] backed by the learned table. A decision uses
/// the table only when both the native and slice-pair cells for the
/// request's `(bucket, tier)` are warm; the CRT arm additionally joins
/// the comparison when the input advertises a basis *and* its cell is
/// warm. Everything else defers to the wrapped fallback — cold behavior
/// is bitwise-identical to running the fallback alone.
pub struct LearnedHeuristic {
    model: Arc<CostModel>,
    fallback: Box<dyn SelectionHeuristic>,
}

impl LearnedHeuristic {
    pub fn new(model: Arc<CostModel>, fallback: Box<dyn SelectionHeuristic>) -> LearnedHeuristic {
        LearnedHeuristic { model, fallback }
    }

    pub fn model(&self) -> &Arc<CostModel> {
        &self.model
    }
}

impl SelectionHeuristic for LearnedHeuristic {
    fn emulate(&self, inp: &HeuristicInput) -> bool {
        self.choose(inp).is_emulated()
    }

    fn choose(&self, inp: &HeuristicInput) -> EmulationChoice {
        let bucket = ShapeBucket::of(inp.m, inp.n);
        let nat = self.model.predict(bucket, EmulationChoice::Native, inp.tier);
        let sp = self.model.predict(bucket, EmulationChoice::SlicePair, inp.tier);
        // All cells share the same logical-MAC denominator, so ns/MAC
        // comparisons are time comparisons.
        match (nat, sp) {
            (Some(t_nat), Some(t_sp)) => {
                let t_crt = inp
                    .crt_moduli
                    .and_then(|_| self.model.predict(bucket, EmulationChoice::Crt, inp.tier));
                match t_crt {
                    Some(tc) if tc < t_sp && tc < t_nat => EmulationChoice::Crt,
                    _ if t_sp < t_nat => EmulationChoice::SlicePair,
                    _ => EmulationChoice::Native,
                }
            }
            _ => self.fallback.choose(inp),
        }
    }

    fn name(&self) -> &'static str {
        "learned"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::heuristic::AlwaysEmulate;

    fn warm(model: &CostModel, choice: EmulationChoice, tier: AccuracyTier, ns: f64) {
        for _ in 0..MIN_SAMPLES {
            model.observe_ns_per_mac(ShapeBucket::Medium, choice, tier, ns);
        }
    }

    #[test]
    fn cells_stay_cold_until_min_samples() {
        let m = CostModel::in_memory();
        let (b, c, t) =
            (ShapeBucket::Medium, EmulationChoice::Native, AccuracyTier::GuaranteedFp64);
        for i in 0..MIN_SAMPLES - 1 {
            m.observe_ns_per_mac(b, c, t, 2.0);
            assert_eq!(m.samples(b, c, t), i + 1);
            assert_eq!(m.predict(b, c, t), None, "cold after {} samples", i + 1);
        }
        m.observe_ns_per_mac(b, c, t, 2.0);
        let v = m.predict(b, c, t).expect("warm at MIN_SAMPLES");
        assert!((v - 2.0).abs() < 1e-12, "constant stream converges exactly: {v}");
        // Cells are independent across every key axis.
        assert_eq!(m.predict(b, c, AccuracyTier::Fp64FaithfulFast), None);
        assert_eq!(m.predict(b, EmulationChoice::SlicePair, t), None);
        assert_eq!(m.predict(ShapeBucket::Large, c, t), None);
    }

    #[test]
    fn ewma_tracks_drift_and_rejects_garbage() {
        let m = CostModel::in_memory();
        let (b, c, t) =
            (ShapeBucket::Small, EmulationChoice::SlicePair, AccuracyTier::Fp32Grade);
        warm(&m, c, t, 1.0);
        for _ in 0..64 {
            m.observe_ns_per_mac(b, c, t, 3.0);
        }
        let v = m.predict(b, c, t).unwrap();
        assert!((v - 3.0).abs() < 0.01, "EWMA converged to the drifted rate: {v}");
        // Non-finite and non-positive observations are dropped, not folded.
        m.observe_ns_per_mac(b, c, t, f64::NAN);
        m.observe_ns_per_mac(b, c, t, -1.0);
        m.observe_ns_per_mac(b, c, t, 0.0);
        assert!((m.predict(b, c, t).unwrap() - v).abs() < 1e-12);
    }

    #[test]
    fn observe_normalizes_to_ns_per_mac_and_buckets_shape() {
        let m = CostModel::in_memory();
        let t = AccuracyTier::GuaranteedFp64;
        // 128^3 MACs in 2.097152 ms = exactly 1 ns/MAC, Medium bucket.
        for _ in 0..MIN_SAMPLES {
            m.observe(128, 128, 128, EmulationChoice::Native, t, 128.0 * 128.0 * 128.0 * 1e-9);
        }
        let v = m.predict(ShapeBucket::Medium, EmulationChoice::Native, t).unwrap();
        assert!((v - 1.0).abs() < 1e-9, "{v}");
        assert_eq!(m.predict(ShapeBucket::Small, EmulationChoice::Native, t), None);
    }

    #[test]
    fn catalog_round_trips_and_skips_bad_lines() {
        let m = CostModel::in_memory();
        warm(&m, EmulationChoice::Native, AccuracyTier::GuaranteedFp64, 0.5);
        warm(&m, EmulationChoice::SlicePair, AccuracyTier::Fp64FaithfulFast, 0.125);
        let text = m.serialize();
        assert!(text.starts_with(CATALOG_HEADER));

        let m2 = CostModel::in_memory();
        m2.absorb(&text);
        for (c, t, want) in [
            (EmulationChoice::Native, AccuracyTier::GuaranteedFp64, 0.5),
            (EmulationChoice::SlicePair, AccuracyTier::Fp64FaithfulFast, 0.125),
        ] {
            let got = m2.predict(ShapeBucket::Medium, c, t).unwrap();
            assert!((got - want).abs() < 1e-5, "{c:?}/{t:?}: {got} vs {want}");
            assert_eq!(m2.samples(ShapeBucket::Medium, c, t), MIN_SAMPLES);
        }

        // Malformed lines (wrong arity, unknown labels, bad numbers,
        // non-positive rates) are skipped without poisoning good ones.
        let m3 = CostModel::in_memory();
        m3.absorb(
            "# header\n\
             medium native guaranteed 0.5 8\n\
             medium native guaranteed 0.5\n\
             medium native guaranteed 0.5 8 extra\n\
             huge native guaranteed 0.5 8\n\
             medium warp guaranteed 0.5 8\n\
             medium native turbo 0.5 8\n\
             medium crt fast nan 8\n\
             medium crt fast -1.0 8\n\
             medium crt fast 0.5 eight\n",
        );
        assert_eq!(
            m3.predict(ShapeBucket::Medium, EmulationChoice::Native, AccuracyTier::GuaranteedFp64),
            Some(0.5)
        );
        assert_eq!(
            m3.samples(ShapeBucket::Medium, EmulationChoice::Crt, AccuracyTier::Fp64FaithfulFast),
            0
        );
    }

    #[test]
    fn save_and_reload_through_a_file() {
        let path = std::env::temp_dir()
            .join(format!("adp-costmodel-test-{}.txt", std::process::id()));
        let _ = std::fs::remove_file(&path);
        {
            let m = CostModel::with_path(path.clone());
            warm(&m, EmulationChoice::Crt, AccuracyTier::Fp32Grade, 0.25);
            m.save();
        }
        let m2 = CostModel::with_path(path.clone());
        assert_eq!(
            m2.predict(ShapeBucket::Medium, EmulationChoice::Crt, AccuracyTier::Fp32Grade),
            Some(0.25)
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn disabled_model_never_learns() {
        let m = CostModel::disabled();
        assert!(!m.is_enabled());
        warm(&m, EmulationChoice::Native, AccuracyTier::GuaranteedFp64, 1.0);
        assert_eq!(
            m.predict(ShapeBucket::Medium, EmulationChoice::Native, AccuracyTier::GuaranteedFp64),
            None
        );
    }

    #[test]
    fn learned_heuristic_cold_falls_back_warm_overrides() {
        let model = Arc::new(CostModel::in_memory());
        let h = LearnedHeuristic::new(Arc::clone(&model), Box::new(AlwaysEmulate));
        assert_eq!(h.name(), "learned");
        let inp = HeuristicInput::single(128, 128, 128, 7); // Medium bucket
        let tier = AccuracyTier::GuaranteedFp64;

        // Cold: the fallback decides (AlwaysEmulate => slice pairs).
        assert_eq!(h.choose(&inp), EmulationChoice::SlicePair);
        assert!(h.emulate(&inp));

        // Only one warm arm is still "cold" for decision purposes.
        warm(&model, EmulationChoice::Native, tier, 1.0);
        assert_eq!(h.choose(&inp), EmulationChoice::SlicePair, "needs both base arms");

        // Warm native+slice-pair with native cheaper: overrides fallback.
        warm(&model, EmulationChoice::SlicePair, tier, 4.0);
        assert_eq!(h.choose(&inp), EmulationChoice::Native);
        assert!(!h.emulate(&inp));

        // A warm, cheapest CRT cell joins only when a basis is advertised.
        warm(&model, EmulationChoice::Crt, tier, 0.5);
        assert_eq!(h.choose(&inp.with_crt(None)), EmulationChoice::Native);
        assert_eq!(h.choose(&inp.with_crt(Some(17))), EmulationChoice::Crt);

        // Tiers have independent tables: the fast tier is still cold.
        assert_eq!(
            h.choose(&inp.with_tier(AccuracyTier::Fp64FaithfulFast)),
            EmulationChoice::SlicePair,
            "cold tier defers to the fallback"
        );
    }
}
