//! The ADP decision engine (§5, Fig 8).
//!
//! `AdpEngine::gemm` is the drop-in DGEMM entry point: it guarantees an
//! FP64-grade result for every input by construction — either through
//! ESC-sized emulation or through fallback to native FP64 — and records
//! which path was taken and why.

use std::sync::Arc;
use std::time::Instant;

use super::costmodel::{CostModel, LearnedHeuristic};
use super::heuristic::{EmulationChoice, HeuristicInput, SelectionHeuristic};
use super::metrics::Metrics;
use super::plan::EscPlanCache;
use super::scan::scan_pair;
use crate::backend::{BackendSpec, ComputeBackend, WorkspacePool};
use crate::esc::coarse::{coarse_esc_gemm, DEFAULT_BLOCK};
use crate::linalg::Matrix;
use crate::ozaki::batched::{gemm_grouped, GroupedProblem, SliceCache};
use crate::ozaki::{
    fused_gemm_on, AccuracyTier, CrtConfig, CrtScheme, DecompositionScheme, OzakiConfig,
    SchemeKind, SliceEncoding,
};
use crate::runtime::{ArtifactKind, RuntimeHandle};
use crate::util::faultinject;

/// Why ADP dispatched the way it did (Fig 8 / Fig 7-right inputs).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum GemmDecision {
    /// Emulated via an AOT artifact (registered square size).
    EmulatedArtifact { n: usize, slices: usize },
    /// Emulated via the native Rust pipeline (unregistered shape).
    EmulatedNative { slices: usize },
    /// Emulated via the Ozaki-II/CRT scheme family: `moduli` integer
    /// GEMMs at the window an `slices`-slice configuration would use.
    EmulatedCrt { slices: usize, moduli: usize },
    /// NaN detected in the inputs (§5.1).
    FallbackNan,
    /// Inf detected in the inputs (§5.1).
    FallbackInf,
    /// ESC demanded more bits than `max_slices` can provide (§5.3).
    FallbackEsc { esc: i32 },
    /// The heuristic judged emulation unprofitable (§5.3).
    FallbackHeuristic,
}

impl GemmDecision {
    pub fn is_emulated(&self) -> bool {
        matches!(
            self,
            GemmDecision::EmulatedArtifact { .. }
                | GemmDecision::EmulatedNative { .. }
                | GemmDecision::EmulatedCrt { .. }
        )
    }

    pub fn slices(&self) -> Option<usize> {
        match *self {
            GemmDecision::EmulatedArtifact { slices, .. }
            | GemmDecision::EmulatedNative { slices }
            | GemmDecision::EmulatedCrt { slices, .. } => Some(slices),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            GemmDecision::EmulatedArtifact { .. } => "emulated-artifact",
            GemmDecision::EmulatedNative { .. } => "emulated-native",
            GemmDecision::EmulatedCrt { .. } => "emulated-crt",
            GemmDecision::FallbackNan => "fallback-nan",
            GemmDecision::FallbackInf => "fallback-inf",
            GemmDecision::FallbackEsc { .. } => "fallback-esc",
            GemmDecision::FallbackHeuristic => "fallback-heuristic",
        }
    }
}

/// Per-request outcome record.
#[derive(Clone, Copy, Debug)]
pub struct AdpOutcome {
    pub decision: GemmDecision,
    /// Coarsened ESC of the inputs (0 when the scan already fell back).
    pub esc: i32,
    /// ESC-derived slice requirement (before catalog rounding).
    pub slices_required: usize,
    /// Guardrail time (scan + ESC + decision), seconds — Fig 5's ADP share.
    pub guardrail_s: f64,
    /// Execution time of the chosen path, seconds.
    pub exec_s: f64,
}

/// Engine configuration.
pub struct AdpConfig {
    /// Target mantissa bits (53 = FP64).
    pub target_mantissa: i32,
    /// Hard cap on slices; ESC requirements beyond this fall back (§5.3).
    pub max_slices: usize,
    pub encoding: SliceEncoding,
    /// ESC coarsening block along k.
    pub esc_block: usize,
    /// Emulate-vs-native policy.
    pub heuristic: Box<dyn SelectionHeuristic>,
    /// AOT artifact runtime; `None` => always use the native pipeline.
    pub runtime: Option<RuntimeHandle>,
    /// Prefer artifacts when the shape is registered.
    pub use_artifacts: bool,
    /// Compute substrate for both the emulated slice-pair schedule and the
    /// native FP64 fallback. All backends are bitwise identical, so this
    /// only changes how much hardware a request uses. Share one `Arc`
    /// across engines to share its thread pool.
    pub backend: Arc<dyn ComputeBackend>,
    /// ESC plan cache: skips the O(m·n·nb) coarse-ESC reduction when the
    /// (shape, exponent-summary) key repeats. `None` => always reduce.
    /// Share one `Arc` across engines so a whole service learns together.
    pub plan_cache: Option<Arc<EscPlanCache>>,
    /// Sliced-operand cache for [`AdpEngine::gemm_grouped`]. `None` =>
    /// each grouped call amortizes only within itself (private cache).
    pub slice_cache: Option<Arc<SliceCache>>,
    /// Scratch pool for the fused tile engine and the grouped pipeline:
    /// per-thread tile accumulators and hi/lo buffers, checked out per
    /// request. Share one `Arc` across engines (the service does) so the
    /// whole deployment reaches zero steady-state scratch allocation.
    pub workspace_pool: Arc<WorkspacePool>,
    /// Default accuracy tier for [`AdpEngine::gemm`] /
    /// [`AdpEngine::gemm_grouped`]; per-request overrides go through the
    /// `*_tiered` entry points. Seeded from the `ADP_TIER` environment
    /// override by [`AdpConfig::fp64`].
    pub tier: AccuracyTier,
    /// Online-learned ns/MAC table, fed by every request this engine
    /// dispatches (all three families, all tiers) and consulted by
    /// [`LearnedHeuristic`] when it is the configured policy. Share one
    /// `Arc` across engines so a whole service learns together.
    pub cost_model: Arc<CostModel>,
}

impl AdpConfig {
    /// Defaults matching the paper: FP64 target, 200-bit ceiling (~26
    /// slices, the Fig 3 configuration), unsigned encoding.
    pub fn fp64() -> AdpConfig {
        // The default policy layers the learned cost model over the
        // seed's AlwaysEmulate: while the table is cold every decision
        // is exactly the fallback's, so a fresh engine behaves like the
        // pre-learned coordinator until real measurements accumulate.
        let cost_model = Arc::new(CostModel::from_env());
        AdpConfig {
            target_mantissa: 53,
            max_slices: 26,
            encoding: SliceEncoding::Unsigned,
            esc_block: DEFAULT_BLOCK,
            heuristic: Box::new(LearnedHeuristic::new(
                Arc::clone(&cost_model),
                Box::new(super::heuristic::AlwaysEmulate),
            )),
            runtime: None,
            use_artifacts: true,
            backend: BackendSpec::Serial.build(),
            plan_cache: None,
            slice_cache: None,
            workspace_pool: Arc::new(WorkspacePool::new()),
            tier: AccuracyTier::env_default(),
            cost_model,
        }
    }

    pub fn with_heuristic(mut self, h: Box<dyn SelectionHeuristic>) -> AdpConfig {
        self.heuristic = h;
        self
    }

    pub fn with_backend(mut self, backend: Arc<dyn ComputeBackend>) -> AdpConfig {
        self.backend = backend;
        self
    }

    pub fn with_runtime(mut self, rt: Option<RuntimeHandle>) -> AdpConfig {
        self.runtime = rt;
        self
    }

    pub fn with_max_slices(mut self, s: usize) -> AdpConfig {
        self.max_slices = s;
        self
    }

    pub fn with_plan_cache(mut self, cache: Arc<EscPlanCache>) -> AdpConfig {
        self.plan_cache = Some(cache);
        self
    }

    pub fn with_slice_cache(mut self, cache: Arc<SliceCache>) -> AdpConfig {
        self.slice_cache = Some(cache);
        self
    }

    pub fn with_workspace_pool(mut self, pool: Arc<WorkspacePool>) -> AdpConfig {
        self.workspace_pool = pool;
        self
    }

    /// Override the engine's default accuracy tier (requests without an
    /// explicit per-request tier run here).
    pub fn with_tier(mut self, tier: AccuracyTier) -> AdpConfig {
        self.tier = tier;
        self
    }

    /// Share a learned cost model (observations flow into it; pair it
    /// with a [`LearnedHeuristic`] over the same `Arc` to also consult
    /// it for decisions).
    pub fn with_cost_model(mut self, model: Arc<CostModel>) -> AdpConfig {
        self.cost_model = model;
        self
    }
}

/// The ADP engine. Cheap to construct, and `Send + Sync` (every method
/// takes `&self`; shared state lives behind `Arc`s and the heuristic is
/// `Sync`): the sharded service shares one engine per shard across that
/// shard's workers through an `Arc`, so the shard's plan/slice caches,
/// workspace pool, and backend pool slice are one coherent unit.
pub struct AdpEngine {
    pub cfg: AdpConfig,
    pub metrics: Arc<Metrics>,
}

impl AdpEngine {
    pub fn new(cfg: AdpConfig) -> AdpEngine {
        AdpEngine { cfg, metrics: Arc::new(Metrics::default()) }
    }

    pub fn with_metrics(cfg: AdpConfig, metrics: Arc<Metrics>) -> AdpEngine {
        AdpEngine { cfg, metrics }
    }

    /// The guaranteed-accuracy GEMM entry point, at the engine's
    /// configured default tier.
    pub fn gemm(&self, a: &Matrix, b: &Matrix) -> (Matrix, AdpOutcome) {
        self.gemm_tiered(a, b, self.cfg.tier)
    }

    /// [`AdpEngine::gemm`] with a per-request accuracy tier. At
    /// [`AccuracyTier::GuaranteedFp64`] this is the seed's bitwise
    /// semantics; the fast tiers run the tier-truncated pair schedule
    /// (and a correspondingly smaller CRT basis) — unless ESC already
    /// sized the window at or below the tier's kept bits, in which case
    /// the full schedule runs and the escalation is counted (no silent
    /// accuracy loss from truncating an already-minimal schedule).
    pub fn gemm_tiered(
        &self,
        a: &Matrix,
        b: &Matrix,
        tier: AccuracyTier,
    ) -> (Matrix, AdpOutcome) {
        assert_eq!(a.cols, b.rows, "gemm shape mismatch");
        let shape = (a.rows, a.cols, b.cols);
        let t0 = Instant::now();

        // ---- Guardrail 1: safety scan (§5.1) -------------------------
        let flags = scan_pair(a, b);
        if !flags.clean() {
            let decision =
                if flags.has_nan { GemmDecision::FallbackNan } else { GemmDecision::FallbackInf };
            let guardrail_s = t0.elapsed().as_secs_f64();
            let (c, exec_s) = self.native(a, b);
            return self.finish(c, decision, 0, 0, guardrail_s, exec_s, tier, shape, (0, 0), false);
        }

        // ---- Guardrail 2: coarsened ESC (§5.2) -----------------------
        let esc = self.coarse_esc(a, b);
        let bits = self.cfg.target_mantissa + esc + 1;
        let slices = self.cfg.encoding.slices_for_bits(bits);
        if slices > self.cfg.max_slices {
            let guardrail_s = t0.elapsed().as_secs_f64();
            let (c, exec_s) = self.native(a, b);
            return self.finish(
                c,
                GemmDecision::FallbackEsc { esc },
                esc,
                slices,
                guardrail_s,
                exec_s,
                tier,
                shape,
                (0, 0),
                false,
            );
        }

        // The tier-aware schedule config: pair truncation depth and the
        // CRT-side window reduction both derive from it. When ESC left
        // no room to truncate (depth 0 at a fast tier) the dispatch
        // below runs the full schedule and reports an escalation.
        let ozcfg = OzakiConfig::with_encoding(slices, self.cfg.encoding).with_tier(tier);
        let escalated = tier != AccuracyTier::GuaranteedFp64 && ozcfg.truncation_depth() == 0;

        // ---- Guardrail 3: profitability heuristic (§5.3) -------------
        // Both scheme families are sized from the same coarse ESC: slice
        // pairs at `slices` (tier-truncated pair count), CRT at the
        // tier-capped unsigned-equivalent window when the modulus basis
        // covers it. The heuristic picks the cheapest of native /
        // slice-pair / CRT (boolean policies keep their pre-CRT
        // slice-pair behavior via the default `choose`).
        let crt_cfg = CrtConfig::for_window(ozcfg.crt_window(), a.cols);
        let hin = HeuristicInput::single(a.rows, a.cols, b.cols, slices)
            .with_pairs(ozcfg.pair_count())
            .with_tier(tier)
            .with_crt(crt_cfg.map(|c| c.gemm_count()));
        let choice = self.cfg.heuristic.choose(&hin);
        if choice == EmulationChoice::Native {
            let guardrail_s = t0.elapsed().as_secs_f64();
            let (c, exec_s) = self.native(a, b);
            return self.finish(
                c,
                GemmDecision::FallbackHeuristic,
                esc,
                slices,
                guardrail_s,
                exec_s,
                tier,
                shape,
                (0, 0),
                false,
            );
        }
        let guardrail_s = t0.elapsed().as_secs_f64();
        let pairs = (ozcfg.pair_count() as u64, ozcfg.skipped_pair_count() as u64);

        // ---- Dispatch emulation (§5.4) -------------------------------
        // CRT dispatch always runs the native pipeline (AOT artifacts
        // are compiled for the slice-pair schedule only); exception
        // fallbacks above are scheme-independent and already handled.
        // An injected dispatch panic unwinds from *inside* the engine:
        // the service worker's catch_unwind turns it into a typed
        // `EnginePanic` reply and the worker survives.
        if faultinject::fires(faultinject::site::KERNEL_DISPATCH) {
            panic!("injected fault: kernel dispatch panicked");
        }
        let te = Instant::now();
        if let (EmulationChoice::Crt, Some(ccfg)) = (choice, crt_cfg) {
            let c = CrtScheme::new(ccfg).gemm_on(
                a,
                b,
                self.cfg.backend.as_ref(),
                self.cfg.workspace_pool.as_ref(),
            );
            let exec_s = te.elapsed().as_secs_f64();
            let d = GemmDecision::EmulatedCrt { slices: ccfg.s_eq, moduli: ccfg.gemm_count() };
            // CRT runs modulus GEMMs, not slice pairs: the pair counters
            // stay at 0; the tier's saving shows up as the smaller basis.
            return self.finish(c, d, esc, slices, guardrail_s, exec_s, tier, shape, (0, 0), escalated);
        }
        // Subnormal inputs are exact on the native pipeline but flushed by
        // the XLA-CPU artifact substrate (DAZ/FTZ): steer them native.
        // Artifacts encode the *full* triangular schedule, so they only
        // serve requests whose tier keeps the full schedule anyway
        // (guaranteed, or a fast tier that escalated to depth 0).
        if self.cfg.use_artifacts && !flags.has_subnormal && ozcfg.truncation_depth() == 0 {
            if let Some(rt) = &self.cfg.runtime {
                if let Some(nreg) = rt.catalog().fitting_size(a.rows, a.cols, b.cols) {
                    if let Some(sreg) = rt.catalog().slice_count_at_least(nreg, slices) {
                        if let Ok(c) = rt.emulated_gemm(nreg, sreg, a, b) {
                            let exec_s = te.elapsed().as_secs_f64();
                            let d = GemmDecision::EmulatedArtifact { n: nreg, slices: sreg };
                            let apairs = (sreg * (sreg + 1) / 2) as u64;
                            return self.finish(
                                c,
                                d,
                                esc,
                                slices,
                                guardrail_s,
                                exec_s,
                                tier,
                                shape,
                                (apairs, 0),
                                escalated,
                            );
                        }
                        // artifact failure => continue to native pipeline
                    }
                }
            }
        }
        // Native emulation runs the fused tile engine (bitwise identical
        // to the level-major reference; scratch from the shared pool).
        let c = fused_gemm_on(
            a,
            b,
            &ozcfg,
            self.cfg.backend.as_ref(),
            self.cfg.workspace_pool.as_ref(),
        );
        let exec_s = te.elapsed().as_secs_f64();
        self.finish(
            c,
            GemmDecision::EmulatedNative { slices },
            esc,
            slices,
            guardrail_s,
            exec_s,
            tier,
            shape,
            pairs,
            escalated,
        )
    }

    /// Coarse ESC through the plan cache when configured (recording the
    /// hit/miss), the direct reduction otherwise. Identical values either
    /// way — the cache only reuses reductions whose exponent summary
    /// matches exactly.
    fn coarse_esc(&self, a: &Matrix, b: &Matrix) -> i32 {
        match &self.cfg.plan_cache {
            Some(pc) => {
                let (esc, hit) = pc.esc_gemm(a, b, self.cfg.esc_block);
                self.metrics.record_esc_cache(hit);
                esc
            }
            None => coarse_esc_gemm(a, b, self.cfg.esc_block),
        }
    }

    /// Grouped entry point of the coalescing dispatcher: run the Fig 8
    /// guardrails per problem (the exception-handling fallbacks are fully
    /// preserved), then execute every emulatable problem through the
    /// slice-cached grouped pipeline as **one** backend schedule
    /// ([`crate::ozaki::batched::gemm_grouped`]).
    ///
    /// Results are returned in input order. Emulated results are bitwise
    /// identical to calling [`AdpEngine::gemm`] per problem on the native
    /// pipeline; the AOT-artifact dispatch is intentionally not used here
    /// (grouped schedules target the native pipeline). `exec_s` of each
    /// grouped outcome is the group's wall time split evenly — the group
    /// runs as one schedule, so no finer attribution exists.
    ///
    /// The profitability heuristic sees `batch` = how many group members
    /// actually share the problem's operands (1 when nothing is shared),
    /// so a batch-aware cost model can only flip emulate-vs-native where
    /// slice-cache amortization is real; with such a model the *decision*
    /// may legitimately differ from the standalone path — the emulated
    /// numerics never do.
    pub fn gemm_grouped(&self, problems: &[(&Matrix, &Matrix)]) -> Vec<(Matrix, AdpOutcome)> {
        self.gemm_grouped_tiered(problems, self.cfg.tier)
    }

    /// [`AdpEngine::gemm_grouped`] with an explicit accuracy tier for
    /// the whole group. Mixed-tier batches are dispatched as separate
    /// groups by the service (the tier is part of its bucket key), but
    /// the shared slice cache still amortizes across them — slicing is
    /// tier-independent, only the schedule depth differs.
    pub fn gemm_grouped_tiered(
        &self,
        problems: &[(&Matrix, &Matrix)],
        tier: AccuracyTier,
    ) -> Vec<(Matrix, AdpOutcome)> {
        struct Pending {
            idx: usize,
            slices: usize,
            esc: i32,
            guardrail_s: f64,
            /// `Some` when the heuristic routed this problem to the CRT
            /// family (the config records the window + modulus count).
            crt: Option<CrtConfig>,
        }
        let mut results: Vec<Option<(Matrix, AdpOutcome)>> =
            (0..problems.len()).map(|_| None).collect();
        let mut pending: Vec<Pending> = Vec::new();
        // How many group members actually share each operand (by shape +
        // content fingerprint): the heuristic's amortization factor must
        // reflect real slice-cache sharing, not the raw bucket size —
        // distinct-operand requests get batch = 1 and are judged exactly
        // like standalone requests.
        let mut multiplicity: std::collections::HashMap<(usize, usize, u64, u64), usize> =
            std::collections::HashMap::new();
        let fps: Vec<[(usize, usize, u64, u64); 2]> = problems
            .iter()
            .map(|&(a, b)| {
                let fa = a.fingerprint();
                let fb = b.fingerprint();
                [(a.rows, a.cols, fa.0, fa.1), (b.rows, b.cols, fb.0, fb.1)]
            })
            .collect();
        for fp in &fps {
            *multiplicity.entry(fp[0]).or_insert(0) += 1;
            *multiplicity.entry(fp[1]).or_insert(0) += 1;
        }
        for (idx, &(a, b)) in problems.iter().enumerate() {
            assert_eq!(a.cols, b.rows, "gemm shape mismatch");
            let shape = (a.rows, a.cols, b.cols);
            let t0 = Instant::now();
            let flags = scan_pair(a, b);
            if !flags.clean() {
                let decision = if flags.has_nan {
                    GemmDecision::FallbackNan
                } else {
                    GemmDecision::FallbackInf
                };
                let guardrail_s = t0.elapsed().as_secs_f64();
                let (c, exec_s) = self.native(a, b);
                results[idx] = Some(self.finish(
                    c,
                    decision,
                    0,
                    0,
                    guardrail_s,
                    exec_s,
                    tier,
                    shape,
                    (0, 0),
                    false,
                ));
                continue;
            }
            let esc = self.coarse_esc(a, b);
            let bits = self.cfg.target_mantissa + esc + 1;
            let slices = self.cfg.encoding.slices_for_bits(bits);
            if slices > self.cfg.max_slices {
                let guardrail_s = t0.elapsed().as_secs_f64();
                let (c, exec_s) = self.native(a, b);
                results[idx] = Some(self.finish(
                    c,
                    GemmDecision::FallbackEsc { esc },
                    esc,
                    slices,
                    guardrail_s,
                    exec_s,
                    tier,
                    shape,
                    (0, 0),
                    false,
                ));
                continue;
            }
            let batch = multiplicity[&fps[idx][0]].max(multiplicity[&fps[idx][1]]);
            // Same tier-aware derivation as the standalone path: the
            // grouped pipeline must take the same decision and build the
            // same configs so results stay bitwise interchangeable.
            let ozcfg = OzakiConfig::with_encoding(slices, self.cfg.encoding).with_tier(tier);
            let crt_cfg = CrtConfig::for_window(ozcfg.crt_window(), a.cols);
            let hin = HeuristicInput {
                m: a.rows,
                k: a.cols,
                n: b.cols,
                slices,
                pairs: ozcfg.pair_count(),
                batch,
                crt_moduli: crt_cfg.map(|c| c.gemm_count()),
                tier,
            };
            let choice = self.cfg.heuristic.choose(&hin);
            if choice == EmulationChoice::Native {
                let guardrail_s = t0.elapsed().as_secs_f64();
                let (c, exec_s) = self.native(a, b);
                results[idx] = Some(self.finish(
                    c,
                    GemmDecision::FallbackHeuristic,
                    esc,
                    slices,
                    guardrail_s,
                    exec_s,
                    tier,
                    shape,
                    (0, 0),
                    false,
                ));
                continue;
            }
            let guardrail_s = t0.elapsed().as_secs_f64();
            let crt = if choice == EmulationChoice::Crt { crt_cfg } else { None };
            pending.push(Pending { idx, slices, esc, guardrail_s, crt });
        }

        if !pending.is_empty() {
            let te = Instant::now();
            let private;
            let cache: &SliceCache = match &self.cfg.slice_cache {
                Some(c) => c.as_ref(),
                None => {
                    private = SliceCache::default();
                    &private
                }
            };
            let probs: Vec<GroupedProblem<'_>> = pending
                .iter()
                .map(|p| GroupedProblem {
                    a: problems[p.idx].0,
                    b: problems[p.idx].1,
                    cfg: OzakiConfig::with_encoding(p.slices, self.cfg.encoding).with_tier(tier),
                    scheme: if p.crt.is_some() { SchemeKind::Crt } else { SchemeKind::SlicePair },
                })
                .collect();
            let (cs, gstats) =
                gemm_grouped(&probs, cache, self.cfg.backend.as_ref(), self.cfg.workspace_pool.as_ref());
            self.metrics.record_group(&gstats);
            let exec_each = te.elapsed().as_secs_f64() / pending.len() as f64;
            for (p, c) in pending.into_iter().zip(cs) {
                let ozcfg =
                    OzakiConfig::with_encoding(p.slices, self.cfg.encoding).with_tier(tier);
                let escalated =
                    tier != AccuracyTier::GuaranteedFp64 && ozcfg.truncation_depth() == 0;
                let shape =
                    (problems[p.idx].0.rows, problems[p.idx].0.cols, problems[p.idx].1.cols);
                let (decision, pairs) = match p.crt {
                    Some(ccfg) => (
                        GemmDecision::EmulatedCrt {
                            slices: ccfg.s_eq,
                            moduli: ccfg.gemm_count(),
                        },
                        (0, 0),
                    ),
                    None => (
                        GemmDecision::EmulatedNative { slices: p.slices },
                        (ozcfg.pair_count() as u64, ozcfg.skipped_pair_count() as u64),
                    ),
                };
                results[p.idx] = Some(self.finish(
                    c,
                    decision,
                    p.esc,
                    p.slices,
                    p.guardrail_s,
                    exec_each,
                    tier,
                    shape,
                    pairs,
                    escalated,
                ));
            }
        }
        results.into_iter().map(|r| r.expect("every problem resolved")).collect()
    }

    /// Native FP64 fallback: prefer the DGEMM artifact if registered
    /// (keeps the whole request on the "device"), else the Rust GEMM.
    fn native(&self, a: &Matrix, b: &Matrix) -> (Matrix, f64) {
        let t = Instant::now();
        if self.cfg.use_artifacts {
            if let Some(rt) = &self.cfg.runtime {
                if let Some(n) = rt.catalog().fitting_size(a.rows, a.cols, b.cols) {
                    if rt.catalog().find(ArtifactKind::Dgemm, n, 0).is_some() {
                        if let Ok(c) = rt.dgemm(n, a, b) {
                            return (c, t.elapsed().as_secs_f64());
                        }
                    }
                }
            }
        }
        let c = self.cfg.backend.fp64_gemm(a, b);
        (c, t.elapsed().as_secs_f64())
    }

    #[allow(clippy::too_many_arguments)] // internal seam; every site is a tail call
    fn finish(
        &self,
        c: Matrix,
        decision: GemmDecision,
        esc: i32,
        slices_required: usize,
        guardrail_s: f64,
        exec_s: f64,
        tier: AccuracyTier,
        shape: (usize, usize, usize),
        pairs: (u64, u64),
        escalated: bool,
    ) -> (Matrix, AdpOutcome) {
        let outcome = AdpOutcome { decision, esc, slices_required, guardrail_s, exec_s };
        self.metrics.record(&outcome);
        self.metrics.record_tier(tier, pairs.0, pairs.1, escalated);
        // Feed the learned cost model with what actually ran: the
        // dispatched family's wall time, normalized per logical MAC and
        // keyed by shape bucket + family + tier. Fallback paths observe
        // the native arm — guardrail fallbacks are real native timings,
        // which is exactly the evidence the three-way comparison needs.
        let arm = match decision {
            GemmDecision::EmulatedArtifact { .. } | GemmDecision::EmulatedNative { .. } => {
                EmulationChoice::SlicePair
            }
            GemmDecision::EmulatedCrt { .. } => EmulationChoice::Crt,
            _ => EmulationChoice::Native,
        };
        self.cfg.cost_model.observe(shape.0, shape.1, shape.2, arm, tier, exec_s);
        // Refresh the workspace-pool gauges (pool lifetime totals) so
        // snapshots expose checkout/fresh-allocation/fused-tile counts,
        // the packed-panel amortization counters, and the dispatch gauge
        // — the kernel and tile geometry the drivers actually executed
        // (every tile-engine path stamps it, including grouped rounds
        // and the CRT planes; artifact dispatch and FP64 fallbacks never
        // touch the kernel layer and leave it unchanged).
        self.metrics.sync_workspace(self.cfg.workspace_pool.stats());
        (c, outcome)
    }
}

/// ADP as a QR trailing-update backend (Fig 7's integration).
impl crate::linalg::qr::GemmBackend for AdpEngine {
    fn gemm(&mut self, a: &Matrix, b: &Matrix) -> Matrix {
        AdpEngine::gemm(self, a, b).0
    }
    fn name(&self) -> &'static str {
        "adp"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::heuristic::{AlwaysEmulate, ForceCrt, NeverEmulate};
    use crate::linalg::gemm as native_gemm;
    use crate::util::Rng;

    /// Guaranteed-tier engine: these tests pin full-schedule facts
    /// (pair counts, CRT windows, bitwise references), so they must not
    /// float with the `ADP_TIER` environment default.
    fn engine() -> AdpEngine {
        AdpEngine::new(
            AdpConfig::fp64()
                .with_heuristic(Box::new(AlwaysEmulate))
                .with_tier(AccuracyTier::GuaranteedFp64),
        )
    }

    #[test]
    fn parallel_backend_engine_is_bitwise_identical() {
        // Both ADP paths (emulated + native fallback) must be backend
        // agnostic down to the last bit.
        let mut rng = Rng::new(87);
        let a = Matrix::uniform(48, 48, -1.0, 1.0, &mut rng);
        let b = Matrix::uniform(48, 48, -1.0, 1.0, &mut rng);
        let par = AdpEngine::new(
            AdpConfig::fp64()
                .with_heuristic(Box::new(AlwaysEmulate))
                .with_tier(AccuracyTier::GuaranteedFp64)
                .with_backend(BackendSpec::Parallel { threads: 4 }.build()),
        );
        let (c_ser, o_ser) = engine().gemm(&a, &b);
        let (c_par, o_par) = par.gemm(&a, &b);
        assert_eq!(o_ser.decision, o_par.decision);
        for (x, y) in c_ser.data.iter().zip(&c_par.data) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        // native fallback path
        let nat_ser = AdpEngine::new(
            AdpConfig::fp64()
                .with_heuristic(Box::new(NeverEmulate))
                .with_tier(AccuracyTier::GuaranteedFp64),
        );
        let nat_par = AdpEngine::new(
            AdpConfig::fp64()
                .with_heuristic(Box::new(NeverEmulate))
                .with_tier(AccuracyTier::GuaranteedFp64)
                .with_backend(BackendSpec::Parallel { threads: 4 }.build()),
        );
        let (c_ser, _) = nat_ser.gemm(&a, &b);
        let (c_par, _) = nat_par.gemm(&a, &b);
        for (x, y) in c_ser.data.iter().zip(&c_par.data) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn benign_inputs_emulate() {
        let mut rng = Rng::new(80);
        let a = Matrix::uniform(24, 24, -1.0, 1.0, &mut rng);
        let b = Matrix::uniform(24, 24, -1.0, 1.0, &mut rng);
        let (c, out) = engine().gemm(&a, &b);
        assert!(out.decision.is_emulated(), "{:?}", out.decision);
        let c_ref = a.matmul_dd(&b);
        let denom = a.abs().matmul_dd(&b.abs());
        for i in 0..24 {
            for j in 0..24 {
                let e = (c.at(i, j) - c_ref.at(i, j)).abs() / denom.at(i, j);
                assert!(e < 64.0 * f64::EPSILON, "({i},{j}) err {e}");
            }
        }
    }

    #[test]
    fn nan_falls_back_and_propagates() {
        let mut rng = Rng::new(81);
        let mut a = Matrix::uniform(8, 8, -1.0, 1.0, &mut rng);
        let b = Matrix::uniform(8, 8, -1.0, 1.0, &mut rng);
        *a.at_mut(3, 4) = f64::NAN;
        let (c, out) = engine().gemm(&a, &b);
        assert_eq!(out.decision, GemmDecision::FallbackNan);
        // native semantics: NaN propagates through row 3
        assert!(c.at(3, 0).is_nan());
        assert!(!c.at(0, 0).is_nan());
    }

    #[test]
    fn inf_falls_back() {
        let mut rng = Rng::new(82);
        let mut a = Matrix::uniform(8, 8, -1.0, 1.0, &mut rng);
        let b = Matrix::uniform(8, 8, -1.0, 1.0, &mut rng);
        *a.at_mut(0, 0) = f64::INFINITY;
        let (c, out) = engine().gemm(&a, &b);
        assert_eq!(out.decision, GemmDecision::FallbackInf);
        assert!(c.at(0, 0).is_infinite() || c.at(0, 0).is_nan());
    }

    #[test]
    fn extreme_span_falls_back_to_fp64() {
        // Exceeds the 26-slice (200-bit) budget: ESC fallback. The huge
        // A-entry must pair with a tiny B-entry so x_p + y_q >> z_r.
        let mut rng = Rng::new(83);
        let mut a = Matrix::uniform(8, 8, 1.0, 2.0, &mut rng);
        let mut b = Matrix::uniform(8, 8, 1.0, 2.0, &mut rng);
        *a.at_mut(0, 0) = 1e300;
        *b.at_mut(0, 0) = 1e-300;
        let (c, out) = engine().gemm(&a, &b);
        assert!(matches!(out.decision, GemmDecision::FallbackEsc { .. }), "{:?}", out.decision);
        // result still correct (native)
        let r = native_gemm(&a, &b);
        assert_eq!(c.sub(&r).max_abs(), 0.0);
    }

    #[test]
    fn heuristic_veto_respected() {
        let mut rng = Rng::new(84);
        let a = Matrix::uniform(16, 16, -1.0, 1.0, &mut rng);
        let b = Matrix::uniform(16, 16, -1.0, 1.0, &mut rng);
        let eng = AdpEngine::new(AdpConfig::fp64().with_heuristic(Box::new(NeverEmulate)));
        let (_, out) = eng.gemm(&a, &b);
        assert_eq!(out.decision, GemmDecision::FallbackHeuristic);
    }

    #[test]
    fn fast_tier_executes_exactly_the_truncated_pair_count() {
        // The satellite counter test: a fast-tier request runs exactly
        // `pair_count()` slice-pair GEMMs and skips the rest — pinned
        // through the pairs_executed/pairs_skipped counters — and on an
        // FP64-sized window that is no more than half the full schedule
        // (the PR's headline saving).
        let eng = engine();
        let mut rng = Rng::new(93);
        let a = Matrix::uniform(24, 24, 1.0, 2.0, &mut rng);
        let b = Matrix::uniform(24, 24, 1.0, 2.0, &mut rng);
        let (c, out) = eng.gemm_tiered(&a, &b, AccuracyTier::Fp64FaithfulFast);
        assert!(matches!(out.decision, GemmDecision::EmulatedNative { .. }), "{:?}", out.decision);
        let s = out.decision.slices().unwrap();
        let cfg = OzakiConfig::new(s).with_tier(AccuracyTier::Fp64FaithfulFast);
        assert!(cfg.truncation_depth() > 0, "FP64-sized window must truncate (s = {s})");
        assert!(
            cfg.pair_count() * 2 <= cfg.full_pair_count(),
            "fast tier must run at most half the pair GEMMs: {}/{}",
            cfg.pair_count(),
            cfg.full_pair_count()
        );
        let snap = eng.metrics.snapshot();
        assert_eq!(snap.tier_requests, [0, 1, 0]);
        assert_eq!(snap.pairs_executed, cfg.pair_count() as u64);
        assert_eq!(snap.pairs_skipped, cfg.skipped_pair_count() as u64);
        assert_eq!(snap.tier_escalations, 0);
        // The kept ~30 bits hold on benign inputs (documented tier bound,
        // with slack for the k-fold accumulation).
        let c_ref = a.matmul_dd(&b);
        let denom = a.abs().matmul_dd(&b.abs());
        for idx in 0..c.data.len() {
            let e = (c.data[idx] - c_ref.data[idx]).abs() / denom.data[idx];
            assert!(e < 1e-6, "err {e}");
        }
    }

    #[test]
    fn tiny_windows_escalate_to_the_full_schedule() {
        // When ESC sizes the window at or below the tier's kept bits,
        // truncation cannot meet the tier's bound any cheaper: the full
        // schedule runs and the escalation counter increments.
        let mut cfg = AdpConfig::fp64()
            .with_heuristic(Box::new(AlwaysEmulate))
            .with_tier(AccuracyTier::GuaranteedFp64);
        cfg.target_mantissa = 8; // far below the fast tier's 30 kept bits
        let eng = AdpEngine::new(cfg);
        let mut rng = Rng::new(94);
        let a = Matrix::uniform(8, 8, 1.0, 2.0, &mut rng);
        let b = Matrix::uniform(8, 8, 1.0, 2.0, &mut rng);
        let (_, out) = eng.gemm_tiered(&a, &b, AccuracyTier::Fp64FaithfulFast);
        let s = out.decision.slices().expect("emulated");
        assert_eq!(
            OzakiConfig::new(s).with_tier(AccuracyTier::Fp64FaithfulFast).truncation_depth(),
            0,
            "window already minimal at s = {s}"
        );
        let snap = eng.metrics.snapshot();
        assert_eq!(snap.tier_escalations, 1, "ESC-rejected truncation must escalate");
        assert_eq!(snap.pairs_skipped, 0, "escalated request ran the full schedule");
        assert_eq!(snap.pairs_executed, (s * (s + 1) / 2) as u64);
        assert_eq!(snap.tier_requests, [0, 1, 0]);
    }

    #[test]
    fn guaranteed_tier_is_bitwise_identical_across_entry_points() {
        // gemm() at the guaranteed default and an explicit guaranteed
        // gemm_tiered() are the same code path bit for bit; the fast
        // tier genuinely changes the result on generic inputs.
        let mut rng = Rng::new(95);
        let a = Matrix::uniform(32, 32, -1.0, 1.0, &mut rng);
        let b = Matrix::uniform(32, 32, -1.0, 1.0, &mut rng);
        let (c0, o0) = engine().gemm(&a, &b);
        let (c1, o1) = engine().gemm_tiered(&a, &b, AccuracyTier::GuaranteedFp64);
        assert_eq!(o0.decision, o1.decision);
        for (x, y) in c0.data.iter().zip(&c1.data) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        let (c2, o2) = engine().gemm_tiered(&a, &b, AccuracyTier::Fp64FaithfulFast);
        assert!(o2.decision.is_emulated());
        assert!(
            c2.data.iter().zip(&c0.data).any(|(x, y)| x.to_bits() != y.to_bits()),
            "truncated schedule must differ on wide-mantissa inputs"
        );
    }

    #[test]
    fn esc_sizes_slices_on_spanned_input() {
        let mut rng = Rng::new(85);
        let mut a = Matrix::uniform(16, 16, 1.0, 2.0, &mut rng);
        let b = Matrix::uniform(16, 16, 1.0, 2.0, &mut rng);
        for j in 0..16 {
            for i in 0..16 {
                *a.at_mut(i, j) *= 2f64.powi((j as i32 - 8) * 4);
            }
        }
        let (c, out) = engine().gemm(&a, &b);
        assert!(out.decision.is_emulated());
        assert!(out.slices_required > 7, "slices {}", out.slices_required);
        let c_ref = a.matmul_dd(&b);
        let denom = a.abs().matmul_dd(&b.abs());
        for idx in 0..c.data.len() {
            let e = (c.data[idx] - c_ref.data[idx]).abs() / denom.data[idx];
            assert!(e < 64.0 * f64::EPSILON, "err {e}");
        }
    }

    #[test]
    fn grouped_matches_per_request_bitwise_and_counts_caches() {
        let mut rng = Rng::new(88);
        let eng = AdpEngine::new(
            AdpConfig::fp64()
                .with_heuristic(Box::new(AlwaysEmulate))
                .with_tier(AccuracyTier::GuaranteedFp64)
                .with_plan_cache(Arc::new(EscPlanCache::default()))
                .with_slice_cache(Arc::new(SliceCache::default())),
        );
        // [1, 2) entries: every problem's ESC (hence slice count) is the
        // same, so the shared A is exactly one slice-cache key.
        let a = Matrix::uniform(20, 20, 1.0, 2.0, &mut rng);
        let bs: Vec<Matrix> =
            (0..3).map(|_| Matrix::uniform(20, 20, 1.0, 2.0, &mut rng)).collect();
        let probs: Vec<(&Matrix, &Matrix)> = bs.iter().map(|b| (&a, b)).collect();
        let grouped = eng.gemm_grouped(&probs);
        let reference = engine();
        for ((c, out), b) in grouped.iter().zip(&bs) {
            assert!(out.decision.is_emulated(), "{:?}", out.decision);
            let (cr, _) = reference.gemm(&a, b);
            for (x, y) in c.data.iter().zip(&cr.data) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        // Shared A decomposed once: 4 misses (A + 3 Bs), 2 hits (A reuse).
        let snap = eng.metrics.snapshot();
        assert_eq!(snap.slice_cache_misses, 4);
        assert_eq!(snap.slice_cache_hits, 2);
        // All [1,2) operands share one exponent summary per shape, so the
        // plan cache converges after the very first reduction.
        assert_eq!(snap.esc_cache_misses, 1);
        assert_eq!(snap.esc_cache_hits, 2);
        // Replay: everything hits (plan cache and slice cache).
        eng.gemm_grouped(&probs);
        let snap = eng.metrics.snapshot();
        assert_eq!(snap.slice_cache_misses, 4, "replay must not re-decompose");
        assert_eq!(snap.slice_cache_hits, 8);
        assert_eq!(snap.esc_cache_misses, 1);
        assert_eq!(snap.esc_cache_hits, 5);
    }

    #[test]
    fn grouped_preserves_guardrail_fallbacks() {
        // A NaN problem and an over-span problem inside a group must fall
        // back individually while their neighbors still emulate.
        let mut rng = Rng::new(89);
        let eng = engine();
        let good_a = Matrix::uniform(8, 8, 1.0, 2.0, &mut rng);
        let good_b = Matrix::uniform(8, 8, 1.0, 2.0, &mut rng);
        let mut nan_a = good_a.clone();
        *nan_a.at_mut(0, 0) = f64::NAN;
        let mut span_a = good_a.clone();
        let mut span_b = good_b.clone();
        *span_a.at_mut(0, 0) = 1e300;
        *span_b.at_mut(0, 0) = 1e-300;
        let probs: Vec<(&Matrix, &Matrix)> =
            vec![(&good_a, &good_b), (&nan_a, &good_b), (&span_a, &span_b)];
        let rs = eng.gemm_grouped(&probs);
        assert!(rs[0].1.decision.is_emulated());
        assert_eq!(rs[1].1.decision, GemmDecision::FallbackNan);
        assert!(rs[1].0.at(0, 0).is_nan());
        assert!(matches!(rs[2].1.decision, GemmDecision::FallbackEsc { .. }));
        // Fallback results equal the per-request engine's exactly.
        let (c_nan, _) = engine().gemm(&nan_a, &good_b);
        for (x, y) in rs[1].0.data.iter().zip(&c_nan.data) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn plan_cache_speeds_repeat_shapes_on_single_requests() {
        let mut rng = Rng::new(90);
        let eng = AdpEngine::new(
            AdpConfig::fp64()
                .with_heuristic(Box::new(AlwaysEmulate))
                .with_tier(AccuracyTier::GuaranteedFp64)
                .with_plan_cache(Arc::new(EscPlanCache::default())),
        );
        let a = Matrix::uniform(12, 12, 1.0, 2.0, &mut rng);
        let b = Matrix::uniform(12, 12, 1.0, 2.0, &mut rng);
        let (c1, o1) = eng.gemm(&a, &b);
        let (c2, o2) = eng.gemm(&a, &b);
        assert_eq!(o1.esc, o2.esc);
        for (x, y) in c1.data.iter().zip(&c2.data) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        let snap = eng.metrics.snapshot();
        assert_eq!(snap.esc_cache_misses, 1);
        assert_eq!(snap.esc_cache_hits, 1);
    }

    #[test]
    fn warm_fused_run_reports_kernel_id_and_panel_reuse() {
        // Satellite counter test: a warm fused-engine run must report
        // the dispatched kernel id and a packed-panel reuse count of at
        // least s(s+1)/2 - 1 per executed tile (panels packed once per
        // tile, reused by every remaining slice pair).
        let pool = Arc::new(WorkspacePool::new());
        let eng = AdpEngine::new(
            AdpConfig::fp64()
                .with_heuristic(Box::new(AlwaysEmulate))
                .with_tier(AccuracyTier::GuaranteedFp64)
                .with_workspace_pool(pool.clone()),
        );
        let mut rng = Rng::new(91);
        let a = Matrix::uniform(40, 40, -1.0, 1.0, &mut rng);
        let b = Matrix::uniform(40, 40, -1.0, 1.0, &mut rng);
        let (_, first) = eng.gemm(&a, &b); // cold: sizes the pool
        let (_, out) = eng.gemm(&a, &b); // warm run under test
        assert!(first.decision.is_emulated() && out.decision.is_emulated());
        let s = out.decision.slices().expect("emulated");
        let pairs = (s * (s + 1) / 2) as u64;
        let snap = eng.metrics.snapshot();
        assert_eq!(
            snap.kernel,
            crate::ozaki::kernel::active_id(SliceEncoding::Unsigned).label(),
            "metrics must report the dispatched kernel id"
        );
        assert!(
            snap.tile_mc > 0 && snap.tile_nc > 0,
            "fused dispatch must report its tile geometry: {snap:?}"
        );
        assert!(snap.fused_tiles >= 2, "both requests run the fused engine: {snap:?}");
        // One B pack per tile plus at least one A-band pack per run.
        assert!(snap.panel_packs > snap.fused_tiles, "A band + B panel packs: {snap:?}");
        assert!(
            snap.panel_reuses >= snap.fused_tiles * (pairs - 1),
            "panels must be reused across all {pairs} slice pairs of each tile: {snap:?}"
        );
        // The pool totals agree with the metrics gauges.
        let ws = pool.stats();
        assert_eq!(ws.panel_reuses, snap.panel_reuses);
        assert_eq!(ws.panel_packs, snap.panel_packs);
    }

    #[test]
    fn force_crt_routes_the_crt_family_end_to_end() {
        let eng = AdpEngine::new(
            AdpConfig::fp64()
                .with_heuristic(Box::new(ForceCrt))
                .with_tier(AccuracyTier::GuaranteedFp64),
        );
        let mut rng = Rng::new(92);
        let a = Matrix::uniform(24, 24, -1.0, 1.0, &mut rng);
        let b = Matrix::uniform(24, 24, -1.0, 1.0, &mut rng);
        let (c, out) = eng.gemm(&a, &b);
        assert!(
            matches!(out.decision, GemmDecision::EmulatedCrt { .. }),
            "{:?}",
            out.decision
        );
        if let GemmDecision::EmulatedCrt { slices, moduli } = out.decision {
            assert_eq!(slices, out.slices_required, "CRT window == ESC-sized slice count");
            assert!(moduli > 0 && moduli < slices * (slices + 1) / 2);
        }
        let c_ref = a.matmul_dd(&b);
        let denom = a.abs().matmul_dd(&b.abs());
        for idx in 0..c.data.len() {
            let e = (c.data[idx] - c_ref.data[idx]).abs() / denom.data[idx];
            assert!(e < 64.0 * f64::EPSILON, "err {e}");
        }
        // The grouped path takes the same decision and produces the same
        // bits (cached residue planes + the same modulus tile engine).
        let grouped = eng.gemm_grouped(&[(&a, &b)]);
        assert!(matches!(grouped[0].1.decision, GemmDecision::EmulatedCrt { .. }));
        for (x, y) in grouped[0].0.data.iter().zip(&c.data) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        let snap = eng.metrics.snapshot();
        assert_eq!(snap.emulated_crt, 2, "standalone + grouped CRT requests");
        assert_eq!(snap.emulated, 2);
        // NaN guardrails stay scheme-independent under ForceCrt.
        let mut nan_a = a.clone();
        *nan_a.at_mut(0, 0) = f64::NAN;
        let (_, o) = eng.gemm(&nan_a, &b);
        assert_eq!(o.decision, GemmDecision::FallbackNan);
    }

    #[test]
    fn metrics_accumulate() {
        let eng = engine();
        let mut rng = Rng::new(86);
        for _ in 0..5 {
            let a = Matrix::uniform(8, 8, -1.0, 1.0, &mut rng);
            let b = Matrix::uniform(8, 8, -1.0, 1.0, &mut rng);
            eng.gemm(&a, &b);
        }
        let snap = eng.metrics.snapshot();
        assert_eq!(snap.requests, 5);
        assert_eq!(snap.emulated, 5);
    }
}
