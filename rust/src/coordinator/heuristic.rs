//! Heuristic run-time selection (§5.3): decide emulate-vs-native — and,
//! with the Ozaki-II extension, *which* decomposition family — from the
//! ESC-derived window and problem shape.
//!
//! Two heuristic sources:
//!
//! * [`PlatformHeuristic`] — the GPU cost model of `crate::perfmodel`
//!   (what a deployment on GB200 / RTX Pro 6000 would decide);
//! * [`CpuCalibration`] — measured constants of *this* substrate (what is
//!   actually faster here), auto-calibrated on first use so the
//!   end-to-end examples never regress below native on this machine.
//!
//! Both implement [`SelectionHeuristic::choose`], the three-way
//! native / slice-pair / CRT comparison; the boolean
//! [`SelectionHeuristic::emulate`] is its pre-CRT projection and keeps
//! every existing policy working unchanged.

use crate::ozaki::{AccuracyTier, CrtConfig};
use crate::perfmodel::Platform;

/// Decision inputs the ADP engine feeds the heuristic.
#[derive(Clone, Copy, Debug)]
pub struct HeuristicInput {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub slices: usize,
    /// Pair GEMMs the schedule will actually run: `s(s+1)/2` at the
    /// guaranteed tier, fewer under tier truncation. Cost models must
    /// price what executes, not the full triangle.
    pub pairs: usize,
    /// Requests amortizing the same operand decompositions (1 for a
    /// standalone GEMM). The coalescing dispatcher reports its shape
    /// bucket size here so cost models can spread the slicing cost.
    pub batch: usize,
    /// Modulus count of the CRT family for the same window, when the
    /// basis covers it (`CrtConfig::for_window` returned `Some`);
    /// `None` disables the CRT arm. Linear counterpart of `slices`'
    /// quadratic `s(s+1)/2` pair-GEMM count.
    pub crt_moduli: Option<usize>,
    /// Accuracy tier of the request — the learned cost model keys its
    /// ns/MAC table on it (truncated schedules have different measured
    /// throughput per arm).
    pub tier: AccuracyTier,
}

impl HeuristicInput {
    /// Standalone (unbatched) request at the guaranteed tier. The CRT
    /// arm is advertised whenever the modulus basis covers the unsigned
    /// window equivalent to `slices` — callers no longer need
    /// `.with_crt(..)` to let cost models consider all three families
    /// (pass `.with_crt(None)` to explicitly disable the arm).
    pub fn single(m: usize, k: usize, n: usize, slices: usize) -> HeuristicInput {
        let crt_moduli = CrtConfig::for_window(slices, k).map(|c| c.gemm_count());
        HeuristicInput {
            m,
            k,
            n,
            slices,
            pairs: slices * (slices + 1) / 2,
            batch: 1,
            crt_moduli,
            tier: AccuracyTier::GuaranteedFp64,
        }
    }

    /// Advertise the CRT family (its modulus count) to the cost models.
    pub fn with_crt(mut self, moduli: Option<usize>) -> HeuristicInput {
        self.crt_moduli = moduli;
        self
    }

    /// Override the pair-GEMM count (tier-truncated schedules).
    pub fn with_pairs(mut self, pairs: usize) -> HeuristicInput {
        self.pairs = pairs;
        self
    }

    /// Tag the request's accuracy tier.
    pub fn with_tier(mut self, tier: AccuracyTier) -> HeuristicInput {
        self.tier = tier;
        self
    }
}

/// Which execution family the heuristic picked.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EmulationChoice {
    Native,
    SlicePair,
    Crt,
}

impl EmulationChoice {
    pub fn label(self) -> &'static str {
        match self {
            EmulationChoice::Native => "native",
            EmulationChoice::SlicePair => "slice-pair",
            EmulationChoice::Crt => "crt",
        }
    }

    pub fn is_emulated(self) -> bool {
        !matches!(self, EmulationChoice::Native)
    }
}

/// `Send + Sync`: the sharded [`crate::coordinator::GemmService`] shares
/// one engine (and therefore one heuristic) across a shard's workers
/// through an `Arc`. Heuristics are consulted concurrently, so interior
/// state needs its own synchronization (all shipped policies are plain
/// data).
pub trait SelectionHeuristic: Send + Sync {
    /// true => dispatch emulation; false => native FP64.
    fn emulate(&self, inp: &HeuristicInput) -> bool;

    /// Scheme-aware refinement of [`SelectionHeuristic::emulate`]: pick
    /// the cheapest of native FP64, slice-pair and (when `inp`
    /// advertises one) CRT emulation. The default preserves pre-CRT
    /// behavior — `emulate()` maps to slice pairs — so boolean policies
    /// need no changes.
    fn choose(&self, inp: &HeuristicInput) -> EmulationChoice {
        if self.emulate(inp) {
            EmulationChoice::SlicePair
        } else {
            EmulationChoice::Native
        }
    }

    fn name(&self) -> &'static str;
}

/// Cost-model heuristic for a GPU platform profile.
pub struct PlatformHeuristic {
    pub platform: Platform,
}

impl SelectionHeuristic for PlatformHeuristic {
    fn emulate(&self, inp: &HeuristicInput) -> bool {
        self.platform.emulation_profitable(inp.m, inp.k, inp.n, inp.slices)
    }

    fn choose(&self, inp: &HeuristicInput) -> EmulationChoice {
        let t_nat = self.platform.dgemm_time(inp.m, inp.k, inp.n);
        let t_sp = self
            .platform
            .emulated_breakdown_pairs(inp.m, inp.k, inp.n, inp.slices, inp.pairs, true)
            .total();
        let t_crt = inp
            .crt_moduli
            .map(|nm| self.platform.crt_emulated_time(inp.m, inp.k, inp.n, nm, true));
        match t_crt {
            Some(tc) if tc < t_sp && tc < t_nat => EmulationChoice::Crt,
            _ if t_sp < t_nat => EmulationChoice::SlicePair,
            _ => EmulationChoice::Native,
        }
    }

    fn name(&self) -> &'static str {
        "platform-model"
    }
}

/// Floor for per-element measured constants: coarse or quantized timers
/// can report zero (or denormal garbage) for cheap phases, which would
/// make every downstream cost comparison degenerate.
const MIN_NS: f64 = 1e-3;
/// Floor for the fixed decision overhead (1 us — below any real scan).
const MIN_FIXED_NS: f64 = 1_000.0;

/// Conservative `crt_ns` stand-in when the modulus basis cannot cover
/// the calibration window: priced so high that [`CpuCalibration::choose`]
/// never picks the CRT arm, instead of the old `.expect(...)` aborting
/// calibration — and with it the first request of whichever service
/// worker triggered it.
pub const FALLBACK_CRT_NS: f64 = 1e9;

/// Guard one measured constant against zero/denormal/NaN timings.
fn sane(x: f64, floor: f64) -> f64 {
    if x.is_finite() && x >= floor {
        x
    } else {
        floor
    }
}

/// Measured-constant heuristic for the CPU substrate: emulation costs
/// ~`pair_cost * s(s+1)/2 + slice_cost * s` per element-op vs `fp64_cost`
/// for native; the CRT family costs `pair_cost * nm` GEMMs plus an
/// `nm`-residue extraction/reconstruction term. Constants come from a
/// one-shot micro-calibration.
pub struct CpuCalibration {
    /// ns per element-op (2 flops) of the native FP64 GEMM.
    pub fp64_ns: f64,
    /// ns per element-op of one INT8 slice-pair GEMM. The CRT scheme's
    /// per-modulus GEMMs run the same microkernels, so this constant is
    /// shared by both families.
    pub pair_ns: f64,
    /// ns per element of slicing, per slice.
    pub slice_ns: f64,
    /// ns per element per modulus of the CRT scheme's residue extraction
    /// and Garner reconstruction (everything its GEMMs don't explain).
    pub crt_ns: f64,
    /// Fixed decision overhead, ns (measured: the coarse-ESC pre-pass).
    pub fixed_ns: f64,
}

impl CpuCalibration {
    /// Measure the constants on this machine (one-time, ~100 ms).
    pub fn measure() -> CpuCalibration {
        use crate::esc::coarse::{coarse_esc_gemm, DEFAULT_BLOCK};
        use crate::linalg::{gemm, Matrix};
        use crate::ozaki::{crt_gemm, emulated_gemm_with_breakdown, CrtConfig, OzakiConfig};
        use crate::util::Rng;
        let n = 96;
        let mut rng = Rng::new(0xCA11B);
        let a = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
        let b = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
        let ops = (n * n * n) as f64;

        // Warmup pass: fault in the matrices, spin the core out of idle
        // states and prime the caches, so the timed loops below measure
        // steady-state throughput. Without it the first run's one-time
        // costs landed entirely in fp64_ns and skewed every decision
        // toward emulation.
        std::hint::black_box(gemm(&a, &b));

        let t0 = std::time::Instant::now();
        for _ in 0..3 {
            std::hint::black_box(gemm(&a, &b));
        }
        let fp64_ns = sane(t0.elapsed().as_secs_f64() * 1e9 / (3.0 * ops), MIN_NS);

        let cfg = OzakiConfig::new(7);
        let (_, bd) = emulated_gemm_with_breakdown(&a, &b, &cfg);
        let mut pair_ns = sane(bd.gemm_s * 1e9 / (cfg.pair_count() as f64 * ops), MIN_NS);
        // The emulated run above dispatched the tile autotuner, whose
        // probe times the dispatched kernel's fused path at the tuned
        // geometry (same ns-per-MAC unit). Prefer that figure when it
        // exists: the decision layer then prices the kernel and tile
        // shape that will actually run, not this one 96^3 sample.
        if let Some(t) =
            crate::ozaki::tune::measured_pair_ns(crate::ozaki::kernel::active_id(cfg.encoding))
        {
            pair_ns = sane(t, MIN_NS);
        }
        let slice_ns = sane(bd.slice_s * 1e9 / (7.0 * 2.0 * (n * n) as f64), MIN_NS);

        // CRT arm: time the whole CRT GEMM at the same window and
        // attribute what its per-modulus GEMMs (same microkernels, so
        // pair_ns applies) don't explain to the per-element-per-modulus
        // extraction + reconstruction constant. If the basis cannot
        // cover the calibration window, degrade to a conservative
        // constant (the CRT arm is simply never chosen) instead of
        // panicking the calibration.
        let crt_ns = match CrtConfig::for_window(7, n) {
            Some(crt_cfg) => {
                let nm = crt_cfg.gemm_count() as f64;
                let t1 = std::time::Instant::now();
                std::hint::black_box(crt_gemm(&a, &b, &crt_cfg));
                let crt_total = t1.elapsed().as_secs_f64() * 1e9;
                let crt_elems = nm * (3 * n * n) as f64; // A + B planes + output recon
                sane((crt_total - pair_ns * nm * ops) / crt_elems, MIN_NS)
            }
            None => FALLBACK_CRT_NS,
        };

        // The fixed overhead is the decision pre-pass itself — measure
        // the coarse-ESC reduction instead of hard-coding a guess (the
        // old 20 us constant was an order of magnitude off on some
        // substrates, mis-pricing every small GEMM).
        let reps = 8;
        let t2 = std::time::Instant::now();
        for _ in 0..reps {
            std::hint::black_box(coarse_esc_gemm(&a, &b, DEFAULT_BLOCK));
        }
        let fixed_ns = sane(t2.elapsed().as_secs_f64() * 1e9 / reps as f64, MIN_FIXED_NS);

        CpuCalibration { fp64_ns, pair_ns, slice_ns, crt_ns, fixed_ns }
    }

    fn t_native(&self, inp: &HeuristicInput) -> f64 {
        self.fp64_ns * inp.m as f64 * inp.k as f64 * inp.n as f64
    }

    fn t_slice_pair(&self, inp: &HeuristicInput) -> f64 {
        let ops = inp.m as f64 * inp.k as f64 * inp.n as f64;
        let elems = (inp.m * inp.k + inp.k * inp.n) as f64;
        let s = inp.slices as f64;
        // Tier-truncated schedules run fewer than s(s+1)/2 pair GEMMs;
        // price what the request will actually execute.
        let pairs = inp.pairs as f64;
        // Slicing amortizes across a coalesced bucket (the slice cache
        // decomposes a shared operand once); the pair GEMMs do not.
        let amort = inp.batch.max(1) as f64;
        self.pair_ns * pairs * ops + self.slice_ns * s * elems / amort + self.fixed_ns
    }

    /// CRT cost at `inp`'s window, when the basis covers it: `nm` GEMMs
    /// on the same microkernels, residue extraction amortizable like
    /// slicing, Garner reconstruction on the output (never amortizable).
    fn t_crt(&self, inp: &HeuristicInput) -> Option<f64> {
        inp.crt_moduli.map(|nm| {
            let ops = inp.m as f64 * inp.k as f64 * inp.n as f64;
            let elems = (inp.m * inp.k + inp.k * inp.n) as f64;
            let mn = (inp.m * inp.n) as f64;
            let amort = inp.batch.max(1) as f64;
            let nm = nm as f64;
            self.pair_ns * nm * ops + self.crt_ns * nm * (elems / amort + mn) + self.fixed_ns
        })
    }
}

impl SelectionHeuristic for CpuCalibration {
    fn emulate(&self, inp: &HeuristicInput) -> bool {
        self.t_slice_pair(inp) < self.t_native(inp)
    }

    fn choose(&self, inp: &HeuristicInput) -> EmulationChoice {
        let t_nat = self.t_native(inp);
        let t_sp = self.t_slice_pair(inp);
        match self.t_crt(inp) {
            Some(tc) if tc < t_sp && tc < t_nat => EmulationChoice::Crt,
            _ if t_sp < t_nat => EmulationChoice::SlicePair,
            _ => EmulationChoice::Native,
        }
    }

    fn name(&self) -> &'static str {
        "cpu-calibrated"
    }
}

/// Fixed policies, mostly for tests and ablations.
pub struct AlwaysEmulate;
impl SelectionHeuristic for AlwaysEmulate {
    fn emulate(&self, _: &HeuristicInput) -> bool {
        true
    }
    fn name(&self) -> &'static str {
        "always-emulate"
    }
}

pub struct NeverEmulate;
impl SelectionHeuristic for NeverEmulate {
    fn emulate(&self, _: &HeuristicInput) -> bool {
        false
    }
    fn name(&self) -> &'static str {
        "never-emulate"
    }
}

/// Test/ablation policy: always the CRT family when the window admits
/// one, slice pairs otherwise (never native).
pub struct ForceCrt;
impl SelectionHeuristic for ForceCrt {
    fn emulate(&self, _: &HeuristicInput) -> bool {
        true
    }
    fn choose(&self, inp: &HeuristicInput) -> EmulationChoice {
        if inp.crt_moduli.is_some() {
            EmulationChoice::Crt
        } else {
            EmulationChoice::SlicePair
        }
    }
    fn name(&self) -> &'static str {
        "force-crt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::{GB200, RTX_PRO_6000};

    #[test]
    fn platform_heuristic_matches_model() {
        let h = PlatformHeuristic { platform: GB200 };
        assert!(!h.emulate(&HeuristicInput::single(64, 64, 64, 7)));
        assert!(h.emulate(&HeuristicInput::single(8192, 8192, 8192, 7)));
    }

    #[test]
    fn rtx_emulates_much_earlier() {
        let g = PlatformHeuristic { platform: GB200 };
        let r = PlatformHeuristic { platform: RTX_PRO_6000 };
        let mid = HeuristicInput::single(1024, 1024, 1024, 7);
        assert!(r.emulate(&mid));
        // GB200's strong FP64 makes mid sizes marginal there.
        let _ = g.emulate(&mid); // decision platform-dependent; just exercise
    }

    #[test]
    fn huge_slice_counts_disable_emulation() {
        let h = PlatformHeuristic { platform: RTX_PRO_6000 };
        // ~64 slices => 2080 pair GEMMs: never profitable.
        assert!(!h.emulate(&HeuristicInput::single(4096, 4096, 4096, 64)));
    }

    #[test]
    fn platform_choose_prefers_linear_crt() {
        // Large GEMM on RTX: both families beat native; CRT's 17
        // launches beat the 28 slice pairs for the same window.
        let r = PlatformHeuristic { platform: RTX_PRO_6000 };
        let big = HeuristicInput::single(4096, 4096, 4096, 7).with_crt(Some(17));
        assert_eq!(r.choose(&big), EmulationChoice::Crt);
        // Without a CRT arm the same problem stays on slice pairs.
        assert_eq!(
            r.choose(&HeuristicInput::single(4096, 4096, 4096, 7).with_crt(None)),
            EmulationChoice::SlicePair
        );
        // Tiny GEMM on GB200: launch overheads dominate both families.
        let g = PlatformHeuristic { platform: GB200 };
        let tiny = HeuristicInput::single(128, 128, 128, 7).with_crt(Some(17));
        assert_eq!(g.choose(&tiny), EmulationChoice::Native);
    }

    #[test]
    fn batch_amortization_only_helps() {
        // A synthetic slicing-dominated cost model: batching amortizes the
        // slicing term, so emulation can only become *more* attractive.
        let c = CpuCalibration {
            fp64_ns: 1.0,
            pair_ns: 0.001,
            slice_ns: 50.0,
            crt_ns: 0.0,
            fixed_ns: 0.0,
        };
        let single = HeuristicInput::single(64, 64, 64, 7);
        let batched = HeuristicInput { batch: 64, ..single };
        assert!(!c.emulate(&single), "slicing-dominated single request stays native");
        assert!(c.emulate(&batched), "amortized bucket flips to emulation");
    }

    #[test]
    fn choose_picks_the_cheapest_family() {
        // GEMM-dominated model: 28 pairs cost 0.84 ops, 17 moduli 0.51,
        // native 1.0 — CRT wins exactly when it is advertised.
        let c = CpuCalibration {
            fp64_ns: 1.0,
            pair_ns: 0.03,
            slice_ns: 0.0,
            crt_ns: 0.0,
            fixed_ns: 0.0,
        };
        let sp_only = HeuristicInput::single(256, 256, 256, 7).with_crt(None);
        assert_eq!(c.choose(&sp_only), EmulationChoice::SlicePair);
        assert_eq!(c.choose(&sp_only.with_crt(Some(17))), EmulationChoice::Crt);
        // A reconstruction-heavy substrate flips back to slice pairs.
        let heavy = CpuCalibration {
            fp64_ns: 1.0,
            pair_ns: 0.03,
            slice_ns: 0.0,
            crt_ns: 1e6,
            fixed_ns: 0.0,
        };
        assert_eq!(heavy.choose(&sp_only.with_crt(Some(17))), EmulationChoice::SlicePair);
        // When neither family beats native, CRT availability is moot.
        let slow = CpuCalibration {
            fp64_ns: 1.0,
            pair_ns: 1.0,
            slice_ns: 0.0,
            crt_ns: 0.0,
            fixed_ns: 0.0,
        };
        assert_eq!(slow.choose(&sp_only.with_crt(Some(17))), EmulationChoice::Native);
    }

    #[test]
    fn default_choose_mirrors_emulate() {
        // Boolean policies keep working untouched: choose() maps their
        // verdict onto slice-pair/native even when a CRT arm is offered.
        let crt = HeuristicInput::single(64, 64, 64, 7).with_crt(Some(17));
        assert_eq!(AlwaysEmulate.choose(&crt), EmulationChoice::SlicePair);
        assert_eq!(NeverEmulate.choose(&crt), EmulationChoice::Native);
        assert!(EmulationChoice::SlicePair.is_emulated());
        assert!(!EmulationChoice::Native.is_emulated());
        assert_eq!(EmulationChoice::Crt.label(), "crt");
    }

    #[test]
    fn force_crt_policy() {
        let h = ForceCrt;
        let inp = HeuristicInput::single(64, 64, 64, 7).with_crt(None);
        assert!(h.emulate(&inp));
        assert_eq!(h.choose(&inp), EmulationChoice::SlicePair, "no basis => slice pairs");
        assert_eq!(h.choose(&inp.with_crt(Some(17))), EmulationChoice::Crt);
        assert_eq!(h.name(), "force-crt");
    }

    #[test]
    fn single_advertises_all_three_arms() {
        // The satellite fix: `single()` used to hardcode `crt_moduli:
        // None`, so every call site that forgot `.with_crt(..)` silently
        // collapsed the three-way decision to two arms. It now derives
        // the modulus count from the window itself.
        let inp = HeuristicInput::single(256, 256, 256, 7);
        assert_eq!(
            inp.crt_moduli,
            CrtConfig::for_window(7, 256).map(|c| c.gemm_count()),
            "CRT arm must mirror the basis for the same window"
        );
        assert!(inp.crt_moduli.is_some(), "the shipped basis covers the FP64 window");
        assert_eq!(inp.pairs, 28, "guaranteed tier defaults to the full triangle");
        assert_eq!(inp.tier, AccuracyTier::GuaranteedFp64);

        // Three-way decision surface of a GEMM-dominated model on that
        // one input: cheap CRT wins; pricing CRT out falls back to slice
        // pairs; pricing the pair GEMMs out too falls back to native.
        let mut c = CpuCalibration {
            fp64_ns: 1.0,
            pair_ns: 0.03,
            slice_ns: 0.0,
            crt_ns: 0.0,
            fixed_ns: 0.0,
        };
        assert_eq!(c.choose(&inp), EmulationChoice::Crt);
        c.crt_ns = 1e6;
        assert_eq!(c.choose(&inp), EmulationChoice::SlicePair);
        c.pair_ns = 1.0;
        assert_eq!(c.choose(&inp), EmulationChoice::Native);
    }

    #[test]
    fn truncated_pairs_flip_the_slice_pair_arm() {
        // 28 full pairs at 0.04x native each cost 1.12x native — stay
        // native. The fast tier's 10 kept pairs cost 0.4x — emulate.
        // Both cost models must price `pairs`, not s(s+1)/2.
        let c = CpuCalibration {
            fp64_ns: 1.0,
            pair_ns: 0.04,
            slice_ns: 0.0,
            crt_ns: FALLBACK_CRT_NS,
            fixed_ns: 0.0,
        };
        let full = HeuristicInput::single(256, 256, 256, 7).with_crt(None);
        assert_eq!(c.choose(&full), EmulationChoice::Native);
        let fast = full.with_pairs(10).with_tier(AccuracyTier::Fp64FaithfulFast);
        assert_eq!(c.choose(&fast), EmulationChoice::SlicePair);

        // The platform model scales its int-GEMM phase the same way.
        let p = PlatformHeuristic { platform: GB200 };
        let n = 2048;
        let marginal = HeuristicInput::single(n, n, n, 26).with_crt(None);
        let truncated = marginal.with_pairs(10);
        let t_full = p
            .platform
            .emulated_breakdown_pairs(n, n, n, 26, marginal.pairs, true)
            .total();
        let t_trunc =
            p.platform.emulated_breakdown_pairs(n, n, n, 26, 10, true).total();
        assert!(t_trunc < t_full);
        // And the choice honors it: if the full schedule loses to native
        // the truncated one can only do better or equal.
        if p.choose(&marginal) == EmulationChoice::SlicePair {
            assert_eq!(p.choose(&truncated), EmulationChoice::SlicePair);
        }
    }

    #[test]
    fn fallback_crt_constant_disables_the_crt_arm() {
        // The calibration's no-basis degradation path: a calibration
        // carrying FALLBACK_CRT_NS still works, it just never routes to
        // the CRT family — even when the input advertises one.
        let c = CpuCalibration {
            fp64_ns: 1.0,
            pair_ns: 0.03,
            slice_ns: 0.0,
            crt_ns: FALLBACK_CRT_NS,
            fixed_ns: 0.0,
        };
        let inp = HeuristicInput::single(256, 256, 256, 7).with_crt(Some(17));
        assert_eq!(c.choose(&inp), EmulationChoice::SlicePair, "CRT arm priced out");
        assert!(c.emulate(&inp), "the boolean projection is unaffected");
    }

    #[test]
    fn cpu_calibration_sane() {
        let c = CpuCalibration::measure();
        assert!(c.fp64_ns > 0.0 && c.pair_ns > 0.0 && c.slice_ns > 0.0 && c.crt_ns > 0.0);
        assert!(c.fp64_ns.is_finite() && c.crt_ns.is_finite());
        // Measured, not the old hard-coded 20 us guess — but still
        // floored against degenerate timer readings.
        assert!(c.fixed_ns >= MIN_FIXED_NS && c.fixed_ns.is_finite());
        // On a CPU substrate a 28-pair emulation is never faster than one
        // native FP64 GEMM — the calibrated heuristic must say "native".
        assert!(!c.emulate(&HeuristicInput::single(512, 512, 512, 7)));
        // The three-way choice at that size never picks slice pairs
        // (native beats them, per the assert above); whether CRT's 17
        // GEMMs beat native here is genuinely substrate-dependent, so
        // only the slice-pair exclusion is pinned.
        let choice = c.choose(&HeuristicInput::single(512, 512, 512, 7).with_crt(Some(17)));
        assert_ne!(choice, EmulationChoice::SlicePair);
    }
}
