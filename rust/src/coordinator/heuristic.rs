//! Heuristic run-time selection (§5.3): decide emulate-vs-native from the
//! ESC-derived slice count and problem shape.
//!
//! Two heuristic sources:
//!
//! * [`PlatformHeuristic`] — the GPU cost model of `crate::perfmodel`
//!   (what a deployment on GB200 / RTX Pro 6000 would decide);
//! * [`CpuCalibration`] — measured constants of *this* substrate (what is
//!   actually faster here), auto-calibrated on first use so the
//!   end-to-end examples never regress below native on this machine.

use crate::perfmodel::Platform;

/// Decision inputs the ADP engine feeds the heuristic.
#[derive(Clone, Copy, Debug)]
pub struct HeuristicInput {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub slices: usize,
    /// Requests amortizing the same operand decompositions (1 for a
    /// standalone GEMM). The coalescing dispatcher reports its shape
    /// bucket size here so cost models can spread the slicing cost.
    pub batch: usize,
}

impl HeuristicInput {
    /// Standalone (unbatched) request.
    pub fn single(m: usize, k: usize, n: usize, slices: usize) -> HeuristicInput {
        HeuristicInput { m, k, n, slices, batch: 1 }
    }
}

pub trait SelectionHeuristic: Send {
    /// true => dispatch emulation; false => native FP64.
    fn emulate(&self, inp: &HeuristicInput) -> bool;
    fn name(&self) -> &'static str;
}

/// Cost-model heuristic for a GPU platform profile.
pub struct PlatformHeuristic {
    pub platform: Platform,
}

impl SelectionHeuristic for PlatformHeuristic {
    fn emulate(&self, inp: &HeuristicInput) -> bool {
        self.platform.emulation_profitable(inp.m, inp.k, inp.n, inp.slices)
    }
    fn name(&self) -> &'static str {
        "platform-model"
    }
}

/// Measured-constant heuristic for the CPU substrate: emulation costs
/// ~`pair_cost * s(s+1)/2 + slice_cost * s` per element-op vs `fp64_cost`
/// for native. Constants come from a one-shot micro-calibration.
pub struct CpuCalibration {
    /// ns per element-op (2 flops) of the native FP64 GEMM.
    pub fp64_ns: f64,
    /// ns per element-op of one INT8 slice-pair GEMM.
    pub pair_ns: f64,
    /// ns per element of slicing, per slice.
    pub slice_ns: f64,
    /// Fixed decision overhead, ns.
    pub fixed_ns: f64,
}

impl CpuCalibration {
    /// Measure the constants on this machine (one-time, ~100 ms).
    pub fn measure() -> CpuCalibration {
        use crate::linalg::{gemm, Matrix};
        use crate::ozaki::{emulated_gemm_with_breakdown, OzakiConfig};
        use crate::util::Rng;
        let n = 96;
        let mut rng = Rng::new(0xCA11B);
        let a = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
        let b = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
        let ops = (n * n * n) as f64;

        let t0 = std::time::Instant::now();
        for _ in 0..3 {
            std::hint::black_box(gemm(&a, &b));
        }
        let fp64_ns = t0.elapsed().as_secs_f64() * 1e9 / (3.0 * ops);

        let cfg = OzakiConfig::new(7);
        let (_, bd) = emulated_gemm_with_breakdown(&a, &b, &cfg);
        let pair_ns = bd.gemm_s * 1e9 / (cfg.pair_count() as f64 * ops);
        let slice_ns = bd.slice_s * 1e9 / (7.0 * 2.0 * (n * n) as f64);
        CpuCalibration { fp64_ns, pair_ns, slice_ns, fixed_ns: 20_000.0 }
    }
}

impl SelectionHeuristic for CpuCalibration {
    fn emulate(&self, inp: &HeuristicInput) -> bool {
        let ops = inp.m as f64 * inp.k as f64 * inp.n as f64;
        let elems = (inp.m * inp.k + inp.k * inp.n) as f64;
        let s = inp.slices as f64;
        let pairs = s * (s + 1.0) / 2.0;
        // Slicing amortizes across a coalesced bucket (the slice cache
        // decomposes a shared operand once); the pair GEMMs do not.
        let amort = inp.batch.max(1) as f64;
        let t_emu = self.pair_ns * pairs * ops + self.slice_ns * s * elems / amort + self.fixed_ns;
        let t_nat = self.fp64_ns * ops;
        t_emu < t_nat
    }
    fn name(&self) -> &'static str {
        "cpu-calibrated"
    }
}

/// Fixed policies, mostly for tests and ablations.
pub struct AlwaysEmulate;
impl SelectionHeuristic for AlwaysEmulate {
    fn emulate(&self, _: &HeuristicInput) -> bool {
        true
    }
    fn name(&self) -> &'static str {
        "always-emulate"
    }
}

pub struct NeverEmulate;
impl SelectionHeuristic for NeverEmulate {
    fn emulate(&self, _: &HeuristicInput) -> bool {
        false
    }
    fn name(&self) -> &'static str {
        "never-emulate"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::{GB200, RTX_PRO_6000};

    #[test]
    fn platform_heuristic_matches_model() {
        let h = PlatformHeuristic { platform: GB200 };
        assert!(!h.emulate(&HeuristicInput::single(64, 64, 64, 7)));
        assert!(h.emulate(&HeuristicInput::single(8192, 8192, 8192, 7)));
    }

    #[test]
    fn rtx_emulates_much_earlier() {
        let g = PlatformHeuristic { platform: GB200 };
        let r = PlatformHeuristic { platform: RTX_PRO_6000 };
        let mid = HeuristicInput::single(1024, 1024, 1024, 7);
        assert!(r.emulate(&mid));
        // GB200's strong FP64 makes mid sizes marginal there.
        let _ = g.emulate(&mid); // decision platform-dependent; just exercise
    }

    #[test]
    fn huge_slice_counts_disable_emulation() {
        let h = PlatformHeuristic { platform: RTX_PRO_6000 };
        // ~64 slices => 2080 pair GEMMs: never profitable.
        assert!(!h.emulate(&HeuristicInput::single(4096, 4096, 4096, 64)));
    }

    #[test]
    fn batch_amortization_only_helps() {
        // A synthetic slicing-dominated cost model: batching amortizes the
        // slicing term, so emulation can only become *more* attractive.
        let c = CpuCalibration { fp64_ns: 1.0, pair_ns: 0.001, slice_ns: 50.0, fixed_ns: 0.0 };
        let single = HeuristicInput::single(64, 64, 64, 7);
        let batched = HeuristicInput { batch: 64, ..single };
        assert!(!c.emulate(&single), "slicing-dominated single request stays native");
        assert!(c.emulate(&batched), "amortized bucket flips to emulation");
    }

    #[test]
    fn cpu_calibration_sane() {
        let c = CpuCalibration::measure();
        assert!(c.fp64_ns > 0.0 && c.pair_ns > 0.0 && c.slice_ns > 0.0);
        // On a CPU substrate a 28-pair emulation is never faster than one
        // native FP64 GEMM — the calibrated heuristic must say "native".
        assert!(!c.emulate(&HeuristicInput::single(512, 512, 512, 7)));
    }
}
