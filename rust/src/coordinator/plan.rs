//! ADP plan cache (§5.2 amortized): skip redundant coarse-ESC reductions
//! for repeat shapes without weakening the accuracy guarantee.
//!
//! The coarse ESC of §4 has two phases with very different costs: building
//! the per-row block exponent tables is **linear** in the operand sizes
//! (O(mk + kn)), while the max-plus reduction over all (i, j) dots is
//! O(m·n·nb). A service stream that keeps seeing the same shapes (and, per
//! the batched-GEMM motivation, often the *same operands*) re-pays the
//! expensive reduction for identical inputs.
//!
//! [`EscPlanCache`] keys a finished ESC by **(shape, coarsening block,
//! exponent-span summary)** where the summary is the full pair of coarse
//! block-exponent tables. The coarse ESC is a pure function of exactly
//! those tables, so a key match reuses an ESC that is *identical* — not
//! merely conservative — to what a fresh reduction would produce. The
//! paper's "coarse never underestimates" safety proof is therefore
//! untouched, and the NaN/Inf exception scan is never skipped (it runs
//! before the cache is consulted, see [`super::adp::AdpEngine::gemm`]).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::esc::coarse::{coarse_esc_from, CoarseExponents};
use crate::linalg::Matrix;
use crate::util::sync as psync;

/// Cache key: shape + coarsening block + both operands' coarse exponent
/// tables. Exact equality only — no lossy hashing of the tables — so a
/// hit can never smuggle in another input's (possibly smaller) ESC.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
struct PlanKey {
    m: usize,
    k: usize,
    n: usize,
    block: usize,
    a_bmax: Vec<i32>,
    a_bmin: Vec<i32>,
    b_bmax: Vec<i32>,
    b_bmin: Vec<i32>,
}

struct Inner {
    /// value = (esc, last-used stamp).
    map: HashMap<PlanKey, (i32, u64)>,
    tick: u64,
}

/// Bounded ESC plan cache; thread-safe, share per service via `Arc`.
pub struct EscPlanCache {
    inner: Mutex<Inner>,
    capacity: usize,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl EscPlanCache {
    pub fn new(capacity: usize) -> EscPlanCache {
        EscPlanCache {
            inner: Mutex::new(Inner { map: HashMap::new(), tick: 0 }),
            capacity: capacity.max(1),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Coarse ESC of `A * B` at coarsening `block`, reusing a cached
    /// reduction when the exponent summary matches exactly. Returns
    /// (esc, was_hit). Always bit-for-bit equal to
    /// [`crate::esc::coarse_esc_gemm`] on the same inputs.
    pub fn esc_gemm(&self, a: &Matrix, b: &Matrix, block: usize) -> (i32, bool) {
        assert_eq!(a.cols, b.rows, "gemm shape mismatch");
        let ca = CoarseExponents::of_rows(a, block);
        let cb = CoarseExponents::of_cols(b, block);
        let key = PlanKey {
            m: a.rows,
            k: a.cols,
            n: b.cols,
            block,
            a_bmax: ca.bmax.clone(),
            a_bmin: ca.bmin.clone(),
            b_bmax: cb.bmax.clone(),
            b_bmin: cb.bmin.clone(),
        };
        {
            let mut g = psync::lock(&self.inner);
            g.tick += 1;
            let tick = g.tick;
            if let Some(entry) = g.map.get_mut(&key) {
                entry.1 = tick;
                let esc = entry.0;
                drop(g);
                self.hits.fetch_add(1, Ordering::Relaxed);
                return (esc, true);
            }
        }
        // Miss: the expensive O(m*n*nb) max-plus reduction.
        let esc = coarse_esc_from(&ca, &cb);
        let mut g = psync::lock(&self.inner);
        if g.map.len() >= self.capacity && !g.map.contains_key(&key) {
            // Evict the least-recently-used entry (capacity is small; the
            // linear scan is noise next to the reduction just paid).
            if let Some(victim) = g
                .map
                .iter()
                .min_by_key(|(_, &(_, stamp))| stamp)
                .map(|(k, _)| k.clone())
            {
                g.map.remove(&victim);
            }
        }
        g.tick += 1;
        let tick = g.tick;
        g.map.insert(key, (esc, tick));
        drop(g);
        self.misses.fetch_add(1, Ordering::Relaxed);
        (esc, false)
    }

    /// Lifetime (hits, misses).
    pub fn stats(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    /// Resident plans.
    pub fn len(&self) -> usize {
        psync::lock(&self.inner).map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Default for EscPlanCache {
    fn default() -> EscPlanCache {
        EscPlanCache::new(64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::esc::coarse_esc_gemm;
    use crate::util::{prop, Rng};

    #[test]
    fn repeat_inputs_hit_and_agree() {
        let mut rng = Rng::new(720);
        let cache = EscPlanCache::new(8);
        let a = Matrix::uniform(9, 40, -2.0, 2.0, &mut rng);
        let b = Matrix::uniform(40, 7, -2.0, 2.0, &mut rng);
        let (e1, h1) = cache.esc_gemm(&a, &b, 16);
        let (e2, h2) = cache.esc_gemm(&a, &b, 16);
        assert!(!h1 && h2);
        assert_eq!(e1, e2);
        assert_eq!(e1, coarse_esc_gemm(&a, &b, 16));
        // A different block size is a different plan.
        let (_, h3) = cache.esc_gemm(&a, &b, 8);
        assert!(!h3);
        assert_eq!(cache.stats(), (1, 2));
    }

    #[test]
    fn mantissa_changes_hit_exponent_changes_miss() {
        // Same exponent structure => same summary => hit, and the reused
        // ESC is exactly what a fresh reduction would compute (ESC is a
        // function of exponents only). Changed exponents => miss.
        let mut rng = Rng::new(721);
        let cache = EscPlanCache::new(8);
        // entries in [1, 2): frexp exponent 1 everywhere
        let a = Matrix::uniform(6, 24, 1.0, 2.0, &mut rng);
        let b = Matrix::uniform(24, 6, 1.0, 2.0, &mut rng);
        let (e1, _) = cache.esc_gemm(&a, &b, 8);
        let a2 = Matrix::uniform(6, 24, 1.0, 2.0, &mut rng); // new mantissas
        let (e2, hit) = cache.esc_gemm(&a2, &b, 8);
        assert!(hit, "identical exponent summary must hit");
        assert_eq!(e2, coarse_esc_gemm(&a2, &b, 8), "reused ESC must equal fresh ESC");
        assert_eq!(e1, e2);
        let mut a3 = a.clone();
        *a3.at_mut(0, 0) = 4.0; // exponent 3 at one entry
        let (_, hit3) = cache.esc_gemm(&a3, &b, 8);
        assert!(!hit3, "changed exponent structure must miss");
    }

    #[test]
    fn eviction_keeps_capacity_bounded() {
        let mut rng = Rng::new(722);
        let cache = EscPlanCache::new(2);
        for i in 0..5 {
            let a = Matrix::uniform(3 + i, 10, -1.0, 1.0, &mut rng);
            let b = Matrix::uniform(10, 3, -1.0, 1.0, &mut rng);
            cache.esc_gemm(&a, &b, 4);
        }
        assert_eq!(cache.len(), 2);
        assert!(!cache.is_empty());
    }

    #[test]
    fn prop_cache_transparent() {
        // Hit or miss, the cached path must be indistinguishable from
        // calling coarse_esc_gemm directly.
        let cache = EscPlanCache::new(4);
        prop::check("plan cache == direct coarse ESC", 40, |rng| {
            let m = rng.int(1, 8) as usize;
            let k = rng.int(1, 40) as usize;
            let n = rng.int(1, 8) as usize;
            let span = rng.int(0, 40) as i32;
            let a = Matrix::from_fn(m, k, |_, _| {
                rng.uniform(1.0, 2.0) * 2f64.powi(rng.int(-span as i64, span as i64) as i32)
            });
            let b = Matrix::from_fn(k, n, |_, _| {
                rng.uniform(1.0, 2.0) * 2f64.powi(rng.int(-span as i64, span as i64) as i32)
            });
            let block = rng.int(1, 16) as usize;
            let (esc, _) = cache.esc_gemm(&a, &b, block);
            prop::assert_that(
                esc == coarse_esc_gemm(&a, &b, block),
                format!("cached {esc} != direct"),
            )
        });
    }
}
