//! Minimal property-testing driver (proptest is unavailable offline).
//!
//! `check` runs a property over `cases` seeded inputs; on failure it reports
//! the failing case index and seed so the case can be replayed exactly:
//!
//! ```no_run
//! use adp_dgemm::util::{prop, Rng};
//! prop::check("sum is commutative", 64, |rng| {
//!     let (a, b) = (rng.f64(), rng.f64());
//!     prop::assert_close(a + b, b + a, 0.0, "a+b == b+a")
//! });
//! ```

use super::rng::Rng;

/// Result of a single property evaluation.
pub type PropResult = Result<(), String>;

/// Run `prop` over `cases` derived RNG streams; panic with replay info on
/// the first failure. The base seed can be overridden with `ADP_PROP_SEED`
/// to replay a reported failure.
pub fn check(name: &str, cases: u64, mut prop: impl FnMut(&mut Rng) -> PropResult) {
    let base = std::env::var("ADP_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xADB0_0C0DEu64);
    for case in 0..cases {
        let seed = base ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed at case {case}/{cases} \
                 (replay: ADP_PROP_SEED={base}, case seed {seed}):\n  {msg}"
            );
        }
    }
}

/// Assert `|a - b| <= tol * max(1, |a|, |b|)`, reporting values on failure.
pub fn assert_close(a: f64, b: f64, tol: f64, what: &str) -> PropResult {
    let scale = 1f64.max(a.abs()).max(b.abs());
    if (a - b).abs() <= tol * scale || (a.is_nan() && b.is_nan()) {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (tol {tol}, scale {scale})"))
    }
}

/// Assert a boolean condition with a message.
pub fn assert_that(cond: bool, what: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(what.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivially() {
        check("trivial", 16, |rng| {
            let x = rng.f64();
            assert_that((0.0..1.0).contains(&x), format!("x={x} in [0,1)"))
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn check_reports_failure() {
        check("always fails", 4, |_| Err("nope".into()));
    }

    #[test]
    fn close_handles_scales() {
        assert!(assert_close(1e300, 1e300 * (1.0 + 1e-12), 1e-11, "big").is_ok());
        assert!(assert_close(1.0, 1.1, 1e-3, "off").is_err());
    }
}
