//! Deterministic fault-injection harness.
//!
//! Production components fail in ways unit tests rarely exercise: a
//! worker panics mid-request or while holding a shared lock, a persisted
//! catalog arrives corrupt, a write is torn halfway, a thread hangs. This
//! module gives every such failure a *named site* that the code under
//! test consults (`fires(site)`); the chaos suite (`rust/tests/chaos.rs`)
//! and the CI fault matrix arm sites deterministically and assert the
//! service self-heals.
//!
//! Disarmed cost: `fires()` is a single relaxed atomic load plus a
//! predictable branch — no allocation, no lock, no site lookup — so the
//! hot path pays nothing when no fault is armed (verified by the scan /
//! dispatch arms of `BENCH_hotpath.json` running with the harness
//! compiled in but disarmed).
//!
//! Arming:
//! - env: `ADP_FAULTS="site=trigger[@arg],site=trigger[@arg]"`, read once
//!   on first use; `ADP_FAULTS_SEED` seeds the `prob:` trigger streams.
//! - programmatic: [`arm`]/[`arm_seeded`]/[`disarm`] for in-process tests.
//!
//! Triggers: `always`, `never`, `nth:K` (fire on the K-th hit only,
//! 1-based), `first:K` (hits 1..=K), `every:K`, `prob:P` (seeded,
//! deterministic per site). The optional `@arg` integer is site-specific
//! (e.g. hang duration in milliseconds, torn-write byte count).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Duration;

use super::rng::Rng;
use super::sync as psync;

/// Canonical injection-site names, one per failure mode threaded through
/// the stack. Keep in sync with the README failure-modes table.
pub mod site {
    /// Worker thread panics mid-request, outside the engine
    /// `catch_unwind` (unwinds `worker_main`; supervisor must respawn).
    pub const WORKER_PANIC: &str = "worker.exec.panic";
    /// Worker panics while holding the shared `Metrics` lock
    /// (poisons it; every later metrics call must recover).
    pub const WORKER_LOCK_PANIC: &str = "worker.lock.panic";
    /// Worker hangs (sleeps `@arg` ms, default 1000) before serving.
    pub const WORKER_HANG: &str = "worker.hang";
    /// Success reply is dropped before delivery; the `ReplySlot` drop
    /// guard must still deliver a typed error (never silence).
    pub const REPLY_DROP: &str = "reply.drop";
    /// Panic inside `WorkspacePool::checkout` (caught by the engine
    /// `catch_unwind`, surfaces as `GemmError::EnginePanic`).
    pub const WORKSPACE_CHECKOUT: &str = "workspace.checkout.panic";
    /// Panic at kernel dispatch inside the engine.
    pub const KERNEL_DISPATCH: &str = "kernel.dispatch.panic";
    /// Treat the persisted cost model as corrupt at load.
    pub const COSTMODEL_LOAD_CORRUPT: &str = "costmodel.load.corrupt";
    /// Tear the cost-model save: persist only the first `@arg` bytes.
    pub const COSTMODEL_SAVE_TORN: &str = "costmodel.save.torn";
    /// Treat the tile-tuning catalog as corrupt at load.
    pub const TUNE_LOAD_CORRUPT: &str = "tune.load.corrupt";
    /// Tear the tuning-catalog save: persist only the first `@arg` bytes.
    pub const TUNE_SAVE_TORN: &str = "tune.save.torn";
    /// Panic inside the coalescing drain while holding the shard lock
    /// (poisons `ShardState`; queue ops must recover).
    pub const DRAIN_COALESCE: &str = "drain.coalesce.panic";
}

/// All sites, for spec validation and the README/CI cross-check.
pub const ALL_SITES: &[&str] = &[
    site::WORKER_PANIC,
    site::WORKER_LOCK_PANIC,
    site::WORKER_HANG,
    site::REPLY_DROP,
    site::WORKSPACE_CHECKOUT,
    site::KERNEL_DISPATCH,
    site::COSTMODEL_LOAD_CORRUPT,
    site::COSTMODEL_SAVE_TORN,
    site::TUNE_LOAD_CORRUPT,
    site::TUNE_SAVE_TORN,
    site::DRAIN_COALESCE,
];

#[derive(Clone, Copy, Debug, PartialEq)]
enum Trigger {
    Always,
    Never,
    Nth(u64),
    First(u64),
    Every(u64),
    Prob(f64),
}

#[derive(Debug)]
struct SiteState {
    trigger: Trigger,
    arg: Option<u64>,
    hits: u64,
    fired: u64,
    rng: Rng,
}

impl SiteState {
    fn decide(&mut self) -> bool {
        self.hits += 1;
        let fire = match self.trigger {
            Trigger::Always => true,
            Trigger::Never => false,
            Trigger::Nth(k) => self.hits == k,
            Trigger::First(k) => self.hits <= k,
            Trigger::Every(k) => k > 0 && self.hits % k == 0,
            Trigger::Prob(p) => self.rng.f64() < p,
        };
        if fire {
            self.fired += 1;
        }
        fire
    }
}

/// 0 = env not yet consulted, 1 = disarmed, 2 = armed.
static MODE: AtomicU8 = AtomicU8::new(0);

fn table() -> &'static Mutex<HashMap<&'static str, SiteState>> {
    static TABLE: OnceLock<Mutex<HashMap<&'static str, SiteState>>> = OnceLock::new();
    TABLE.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Leak-free interning is unnecessary: sites are `&'static str`
/// constants; specs referencing unknown sites are rejected at parse.
fn canonical(site: &str) -> Option<&'static str> {
    ALL_SITES.iter().copied().find(|s| *s == site)
}

fn parse_trigger(s: &str) -> Result<Trigger, String> {
    if let Some(rest) = s.strip_prefix("nth:") {
        return rest
            .parse()
            .map(Trigger::Nth)
            .map_err(|e| format!("bad nth count {rest:?}: {e}"));
    }
    if let Some(rest) = s.strip_prefix("first:") {
        return rest
            .parse()
            .map(Trigger::First)
            .map_err(|e| format!("bad first count {rest:?}: {e}"));
    }
    if let Some(rest) = s.strip_prefix("every:") {
        return rest
            .parse()
            .map(Trigger::Every)
            .map_err(|e| format!("bad every count {rest:?}: {e}"));
    }
    if let Some(rest) = s.strip_prefix("prob:") {
        let p: f64 = rest
            .parse()
            .map_err(|e| format!("bad probability {rest:?}: {e}"))?;
        if !(0.0..=1.0).contains(&p) {
            return Err(format!("probability {p} outside [0,1]"));
        }
        return Ok(Trigger::Prob(p));
    }
    match s {
        "always" => Ok(Trigger::Always),
        "never" => Ok(Trigger::Never),
        other => Err(format!("unknown trigger {other:?}")),
    }
}

fn parse_spec(spec: &str, seed: u64) -> Result<HashMap<&'static str, SiteState>, String> {
    let mut map = HashMap::new();
    for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
        let (name, rhs) = entry
            .split_once('=')
            .ok_or_else(|| format!("fault entry {entry:?} missing '='"))?;
        let name = canonical(name.trim())
            .ok_or_else(|| format!("unknown fault site {:?}", name.trim()))?;
        let (trig_s, arg) = match rhs.split_once('@') {
            Some((t, a)) => {
                let arg: u64 = a
                    .trim()
                    .parse()
                    .map_err(|e| format!("bad @arg {a:?} for {name}: {e}"))?;
                (t.trim(), Some(arg))
            }
            None => (rhs.trim(), None),
        };
        let trigger = parse_trigger(trig_s).map_err(|e| format!("{name}: {e}"))?;
        // Per-site deterministic stream: fork the spec seed by the FNV-1a
        // hash of the site name so sites are independent but reproducible.
        let mut h: u64 = 0xcbf29ce484222325;
        for b in name.bytes() {
            h = (h ^ b as u64).wrapping_mul(0x100000001b3);
        }
        map.insert(
            name,
            SiteState {
                trigger,
                arg,
                hits: 0,
                fired: 0,
                rng: Rng::new(seed ^ h),
            },
        );
    }
    Ok(map)
}

#[cold]
fn init_from_env() -> bool {
    let armed = match std::env::var("ADP_FAULTS") {
        Ok(spec) if !spec.trim().is_empty() => {
            let seed = std::env::var("ADP_FAULTS_SEED")
                .ok()
                .and_then(|s| s.parse().ok())
                .unwrap_or(0);
            match parse_spec(&spec, seed) {
                Ok(map) => {
                    let armed = !map.is_empty();
                    *psync::lock(table()) = map;
                    armed
                }
                Err(e) => {
                    eprintln!("[adp] ADP_FAULTS ignored: {e}");
                    false
                }
            }
        }
        _ => false,
    };
    MODE.store(if armed { 2 } else { 1 }, Ordering::Release);
    armed
}

#[inline(always)]
fn armed_now() -> bool {
    match MODE.load(Ordering::Relaxed) {
        1 => false,
        2 => true,
        _ => init_from_env(),
    }
}

/// Does the named site fire on this hit? Counts the hit when armed.
/// Disarmed, this is one relaxed load + branch — the no-op fast path.
#[inline]
pub fn fires(site: &'static str) -> bool {
    if !armed_now() {
        return false;
    }
    fires_slow(site)
}

#[cold]
fn fires_slow(site: &'static str) -> bool {
    let mut t = psync::lock(table());
    match t.get_mut(site) {
        Some(s) => s.decide(),
        None => false,
    }
}

/// Site-specific `@arg` of an armed entry (e.g. hang ms, torn-byte count).
pub fn arg(site: &'static str) -> Option<u64> {
    if !armed_now() {
        return None;
    }
    psync::lock(table()).get(site).and_then(|s| s.arg)
}

/// Hits recorded at a site since arming (0 when disarmed/unknown).
pub fn hits(site: &'static str) -> u64 {
    if !armed_now() {
        return 0;
    }
    psync::lock(table()).get(site).map_or(0, |s| s.hits)
}

/// Fires recorded at a site since arming.
pub fn fired(site: &'static str) -> u64 {
    if !armed_now() {
        return 0;
    }
    psync::lock(table()).get(site).map_or(0, |s| s.fired)
}

/// Arm programmatically from a spec string (same grammar as `ADP_FAULTS`),
/// replacing any previous arming. Seeded with 0; see [`arm_seeded`].
pub fn arm(spec: &str) -> Result<(), String> {
    arm_seeded(spec, 0)
}

/// Arm with an explicit seed for `prob:` triggers.
pub fn arm_seeded(spec: &str, seed: u64) -> Result<(), String> {
    let map = parse_spec(spec, seed)?;
    let armed = !map.is_empty();
    *psync::lock(table()) = map;
    MODE.store(if armed { 2 } else { 1 }, Ordering::Release);
    Ok(())
}

/// Disarm every site. The fast path returns to constant-false.
pub fn disarm() {
    psync::lock(table()).clear();
    MODE.store(1, Ordering::Release);
}

/// True if any site is armed (env or programmatic).
pub fn armed() -> bool {
    armed_now()
}

/// Convenience for hang sites: when the site fires on this hit, sleep
/// its `@arg` milliseconds (default 1000), in short slices so disarming
/// shortens the stall. Sites that don't fire (or aren't armed) cost the
/// usual `fires` fast path and nothing else.
pub fn hang(site: &'static str) {
    if !fires(site) {
        return;
    }
    let total = Duration::from_millis(arg(site).unwrap_or(1000));
    let start = std::time::Instant::now();
    while start.elapsed() < total {
        if !armed_now() {
            return;
        }
        std::thread::sleep(Duration::from_millis(10).min(total));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // These tests mutate the global arming table; the `#[serial]`-style
    // guard below keeps them from interleaving with each other.
    fn guard() -> std::sync::MutexGuard<'static, ()> {
        static G: Mutex<()> = Mutex::new(());
        psync::lock(&G)
    }

    #[test]
    fn disarmed_never_fires() {
        let _g = guard();
        disarm();
        for _ in 0..100 {
            assert!(!fires(site::WORKER_PANIC));
        }
        assert_eq!(hits(site::WORKER_PANIC), 0);
    }

    #[test]
    fn nth_fires_exactly_once() {
        let _g = guard();
        arm("worker.exec.panic=nth:3").unwrap();
        let fired: Vec<bool> = (0..6).map(|_| fires(site::WORKER_PANIC)).collect();
        assert_eq!(fired, vec![false, false, true, false, false, false]);
        assert_eq!(hits(site::WORKER_PANIC), 6);
        assert_eq!(super::fired(site::WORKER_PANIC), 1);
        disarm();
    }

    #[test]
    fn first_and_every_and_arg() {
        let _g = guard();
        arm("worker.hang=first:2@250,drain.coalesce.panic=every:2").unwrap();
        assert!(fires(site::WORKER_HANG));
        assert!(fires(site::WORKER_HANG));
        assert!(!fires(site::WORKER_HANG));
        assert_eq!(arg(site::WORKER_HANG), Some(250));
        assert_eq!(
            (0..4).map(|_| fires(site::DRAIN_COALESCE)).collect::<Vec<_>>(),
            vec![false, true, false, true]
        );
        disarm();
    }

    #[test]
    fn prob_is_deterministic_per_seed() {
        let _g = guard();
        arm_seeded("kernel.dispatch.panic=prob:0.5", 42).unwrap();
        let a: Vec<bool> = (0..32).map(|_| fires(site::KERNEL_DISPATCH)).collect();
        arm_seeded("kernel.dispatch.panic=prob:0.5", 42).unwrap();
        let b: Vec<bool> = (0..32).map(|_| fires(site::KERNEL_DISPATCH)).collect();
        assert_eq!(a, b);
        assert!(a.iter().any(|&f| f) && a.iter().any(|&f| !f));
        disarm();
    }

    #[test]
    fn bad_specs_rejected() {
        let _g = guard();
        assert!(arm("nonsense.site=always").is_err());
        assert!(arm("worker.exec.panic=maybe").is_err());
        assert!(arm("worker.exec.panic=prob:1.5").is_err());
        assert!(arm("worker.exec.panic").is_err());
        // A failed arm leaves the harness disarmed.
        disarm();
        assert!(!fires(site::WORKER_PANIC));
    }
}
