//! Deterministic, seedable RNG (SplitMix64 + xoshiro256**).
//!
//! The `rand` crate is unavailable offline; every workload generator,
//! property test and bench in this repo derives from this RNG so that all
//! reported numbers are reproducible from a printed seed.

/// xoshiro256** with SplitMix64 seeding.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 to spread a small seed over the full state.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [lo, hi] (inclusive).
    #[inline]
    pub fn int(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + (self.next_u64() % span) as i64
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.index(i + 1));
        }
    }

    /// Fork a derived, independent stream (for parallel generators).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::new(7);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn int_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let v = r.int(-5, 5);
            assert!((-5..=5).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let (mut s1, mut s2) = (0.0, 0.0);
        let n = 20_000;
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
