//! FP64 bit-level helpers shared by slicing (§3) and ESC (§4).
//!
//! Mirrors `python/compile/ozaki.py::frexp_exponent`; the two are
//! cross-validated by the artifact-vs-native integration tests.

/// Exponent assigned to zero entries: far below any real FP64 exponent so a
/// zero can never win a max and always loses a min (the conservative
/// direction for the coarsened ESC — see DESIGN.md).
pub const ZERO_EXP: i32 = -(1 << 24);

/// Exponent `e` with `|x| < 2^e` (frexp convention: `x = m * 2^e`,
/// `0.5 <= |m| < 1`). Handles subnormals exactly; returns [`ZERO_EXP`] for
/// zero. NaN/Inf never reach this function on the ADP path (the safety scan
/// falls back first); for completeness they report the maximum exponent.
#[inline]
pub fn frexp_exponent(x: f64) -> i32 {
    if x == 0.0 {
        return ZERO_EXP;
    }
    let bits = x.to_bits();
    let raw = ((bits >> 52) & 0x7FF) as i32;
    if raw != 0 {
        raw - 1022 // normal: |x| in [2^(raw-1023), 2^(raw-1022))
    } else {
        // subnormal: |x| = mant * 2^-1074, highest set bit h => e = h+1-1074
        let mant = bits & ((1u64 << 52) - 1);
        (63 - mant.leading_zeros() as i32) + 1 - 1074
    }
}

/// `2^e` as f64, exact for any `e` in the finite-result range, including
/// subnormal results (`e >= -1074`). Panics outside `[-1074, 1023]`.
#[inline]
pub fn exp2i(e: i32) -> f64 {
    assert!((-1074..=1023).contains(&e), "exp2i out of range: {e}");
    if e >= -1022 {
        f64::from_bits(((e + 1023) as u64) << 52)
    } else {
        // subnormal power of two
        f64::from_bits(1u64 << (e + 1074))
    }
}

/// Scale `x * 2^e`, correct for any `e` (overflow -> ±Inf, underflow -> 0,
/// single final rounding when the result is subnormal). `2^e` may be far
/// outside the f64 range; scaling proceeds in exact power-of-two steps that
/// keep intermediates normal until the final multiply.
#[inline]
pub fn ldexp(mut x: f64, mut e: i32) -> f64 {
    while e > 1023 {
        x *= exp2i(1023);
        e -= 1023;
        if !x.is_finite() || x == 0.0 {
            return x;
        }
    }
    while e < -1022 {
        x *= exp2i(-1022);
        e += 1022;
        if x == 0.0 || !x.is_finite() {
            return x;
        }
    }
    x * exp2i(e)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frexp_matches_std() {
        for &x in &[1.0, 0.5, 0.75, 1.5, 2.0, 3.0, 1e300, 1e-300, -7.25] {
            let e = frexp_exponent(x);
            let m = x / exp2i(e.clamp(-1074, 1023));
            assert!((0.5..1.0).contains(&m.abs()), "x={x} e={e} m={m}");
        }
    }

    #[test]
    fn frexp_zero_sentinel() {
        assert_eq!(frexp_exponent(0.0), ZERO_EXP);
        assert_eq!(frexp_exponent(-0.0), ZERO_EXP);
    }

    #[test]
    fn frexp_subnormals() {
        let min_sub = f64::from_bits(1); // 2^-1074
        assert_eq!(frexp_exponent(min_sub), -1073);
        assert_eq!(frexp_exponent(f64::MIN_POSITIVE), -1021);
        assert_eq!(frexp_exponent(f64::MIN_POSITIVE / 2.0), -1022);
    }

    #[test]
    fn frexp_extremes() {
        assert_eq!(frexp_exponent(f64::MAX), 1024);
        assert_eq!(frexp_exponent(1.0), 1);
        assert_eq!(frexp_exponent(0.99), 0);
    }

    #[test]
    fn exp2i_exact() {
        assert_eq!(exp2i(0), 1.0);
        assert_eq!(exp2i(-1074), f64::from_bits(1));
        assert_eq!(exp2i(1023), 2f64.powi(1023));
        assert_eq!(exp2i(-1022), f64::MIN_POSITIVE);
    }

    #[test]
    fn ldexp_wide_range() {
        assert_eq!(ldexp(1.5, 10), 1536.0);
        assert_eq!(ldexp(1.0, -1074), f64::from_bits(1));
        assert_eq!(ldexp(f64::from_bits(1), 1074), 1.0);
        assert!(ldexp(1.0, 2000).is_infinite()); // overflow -> inf
        assert_eq!(ldexp(1.0, -2000), 0.0); // underflow -> 0
        assert_eq!(ldexp(f64::from_bits(1), 2147), 2f64.powi(1073));
        assert_eq!(ldexp(2f64.powi(1023), -2097), f64::from_bits(1));
    }
}
