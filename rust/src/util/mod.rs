//! Small in-tree utilities replacing crates unavailable in the offline
//! environment (see DESIGN.md §Substitutions).

pub mod benchkit;
pub mod bits;
pub mod faultinject;
pub mod prop;
pub mod rng;
pub mod sync;

pub use bits::{frexp_exponent, ZERO_EXP};
pub use rng::Rng;
