//! Tiny wall-clock bench harness (criterion is unavailable offline).
//!
//! Used by the `rust/benches/*.rs` figure regenerators and the perf pass.
//! Reports min/median/mean over timed iterations after a warmup, in a
//! stable single-line format the EXPERIMENTS.md tables are built from.

use std::time::Instant;

#[derive(Clone, Copy, Debug)]
pub struct Stats {
    pub iters: usize,
    pub min_s: f64,
    pub median_s: f64,
    pub mean_s: f64,
}

impl Stats {
    /// Throughput in "units" (e.g. flops) per second based on median time.
    pub fn per_sec(&self, units: f64) -> f64 {
        units / self.median_s
    }
}

/// Time `f` with `warmup` untimed runs then `iters` timed runs.
pub fn bench<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Stats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(iters.max(1));
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    Stats {
        iters: times.len(),
        min_s: times[0],
        median_s: times[times.len() / 2],
        mean_s: mean,
    }
}

/// Time `f` adaptively: enough iterations to spend ~`budget_s` seconds.
pub fn bench_budget<T>(budget_s: f64, mut f: impl FnMut() -> T) -> Stats {
    let t0 = Instant::now();
    std::hint::black_box(f());
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((budget_s / once) as usize).clamp(3, 1000);
    bench(1, iters, f)
}

/// Print one result row: `name, median_ms, min_ms, label=value ...`.
pub fn report(name: &str, stats: Stats, extra: &[(&str, String)]) {
    let mut line = format!(
        "{name}: median {:.3} ms, min {:.3} ms, mean {:.3} ms ({} iters)",
        stats.median_s * 1e3,
        stats.min_s * 1e3,
        stats.mean_s * 1e3,
        stats.iters
    );
    for (k, v) in extra {
        line.push_str(&format!(", {k}={v}"));
    }
    println!("{line}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let s = bench(1, 5, || (0..1000).sum::<u64>());
        assert_eq!(s.iters, 5);
        assert!(s.min_s <= s.median_s && s.median_s <= s.mean_s * 5.0);
        assert!(s.min_s >= 0.0);
    }

    #[test]
    fn budget_clamps_iters() {
        let s = bench_budget(0.001, || std::thread::sleep(std::time::Duration::from_millis(1)));
        assert!(s.iters >= 3);
    }
}
