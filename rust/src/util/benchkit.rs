//! Tiny wall-clock bench harness (criterion is unavailable offline).
//!
//! Used by the `rust/benches/*.rs` figure regenerators and the perf pass.
//! Reports min/median/mean over timed iterations after a warmup, in a
//! stable single-line format the EXPERIMENTS.md tables are built from.

use std::time::Instant;

#[derive(Clone, Copy, Debug)]
pub struct Stats {
    pub iters: usize,
    pub min_s: f64,
    pub median_s: f64,
    pub mean_s: f64,
}

impl Stats {
    /// Throughput in "units" (e.g. flops) per second based on median time.
    pub fn per_sec(&self, units: f64) -> f64 {
        units / self.median_s
    }
}

/// Time `f` with `warmup` untimed runs then `iters` timed runs.
pub fn bench<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Stats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(iters.max(1));
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    Stats {
        iters: times.len(),
        min_s: times[0],
        median_s: times[times.len() / 2],
        mean_s: mean,
    }
}

/// Time `f` adaptively: enough iterations to spend ~`budget_s` seconds.
pub fn bench_budget<T>(budget_s: f64, mut f: impl FnMut() -> T) -> Stats {
    let t0 = Instant::now();
    std::hint::black_box(f());
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((budget_s / once) as usize).clamp(3, 1000);
    bench(1, iters, f)
}

/// Machine-readable arm collector for the `BENCH_*.json` artifacts CI
/// archives next to the human-readable report lines. Hand-rolled JSON
/// (serde is unavailable offline, like everything else here): a flat
/// `arms` array of objects with the timing stats, an `ns_per_unit`
/// normalization (e.g. ns/flop or ns/MAC), and free-form string context
/// (kernel id, tile shape, thread count).
#[derive(Default)]
pub struct JsonReport {
    arms: Vec<String>,
}

impl JsonReport {
    pub fn new() -> JsonReport {
        JsonReport::default()
    }

    /// Record one arm. `units` is the work one iteration performs (flops,
    /// MACs, elements) — `ns_per_unit` is derived from the median time.
    pub fn arm(&mut self, name: &str, stats: Stats, units: f64, extra: &[(&str, String)]) {
        let mut obj = format!(
            "{{\"name\":\"{}\",\"median_s\":{:.9},\"min_s\":{:.9},\"mean_s\":{:.9},\"iters\":{},\"ns_per_unit\":{:.6}",
            name,
            stats.median_s,
            stats.min_s,
            stats.mean_s,
            stats.iters,
            stats.median_s * 1e9 / units.max(1.0)
        );
        for (k, v) in extra {
            obj.push_str(&format!(",\"{k}\":\"{v}\""));
        }
        obj.push('}');
        self.arms.push(obj);
    }

    /// Arms recorded so far.
    pub fn len(&self) -> usize {
        self.arms.len()
    }

    pub fn is_empty(&self) -> bool {
        self.arms.is_empty()
    }

    /// Serialize `{"bench":..., <context...>, "arms":[...]}`.
    pub fn to_json(&self, bench: &str, context: &[(&str, String)]) -> String {
        let mut out = format!("{{\n  \"bench\": \"{bench}\"");
        for (k, v) in context {
            out.push_str(&format!(",\n  \"{k}\": \"{v}\""));
        }
        out.push_str(",\n  \"arms\": [\n");
        for (i, arm) in self.arms.iter().enumerate() {
            out.push_str("    ");
            out.push_str(arm);
            out.push_str(if i + 1 < self.arms.len() { ",\n" } else { "\n" });
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Write the report to `path` (best effort is the *caller's* call —
    /// this propagates IO errors).
    pub fn write(
        &self,
        path: &str,
        bench: &str,
        context: &[(&str, String)],
    ) -> std::io::Result<()> {
        std::fs::write(path, self.to_json(bench, context))
    }
}

/// Print one result row: `name, median_ms, min_ms, label=value ...`.
pub fn report(name: &str, stats: Stats, extra: &[(&str, String)]) {
    let mut line = format!(
        "{name}: median {:.3} ms, min {:.3} ms, mean {:.3} ms ({} iters)",
        stats.median_s * 1e3,
        stats.min_s * 1e3,
        stats.mean_s * 1e3,
        stats.iters
    );
    for (k, v) in extra {
        line.push_str(&format!(", {k}={v}"));
    }
    println!("{line}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let s = bench(1, 5, || (0..1000).sum::<u64>());
        assert_eq!(s.iters, 5);
        assert!(s.min_s <= s.median_s && s.median_s <= s.mean_s * 5.0);
        assert!(s.min_s >= 0.0);
    }

    #[test]
    fn json_report_shape() {
        let mut j = JsonReport::new();
        let st = Stats { iters: 3, min_s: 1e-3, median_s: 2e-3, mean_s: 2e-3 };
        j.arm("fused[scalar]", st, 1e6, &[("kernel", "scalar".to_string())]);
        j.arm("fused[vnni]", st, 1e6, &[]);
        let json = j.to_json("perf_hotpath", &[("n", "512".to_string())]);
        assert!(json.contains("\"bench\": \"perf_hotpath\""));
        assert!(json.contains("\"n\": \"512\""));
        assert!(json.contains("\"name\":\"fused[scalar]\""));
        assert!(json.contains("\"kernel\":\"scalar\""));
        // ns_per_unit = 2e-3 s * 1e9 / 1e6 units = 2 ns/unit.
        assert!(json.contains("\"ns_per_unit\":2.000000"));
        // Exactly one trailing-comma-free arm list: valid JSON by hand.
        assert_eq!(json.matches("},\n").count(), 1);
        assert_eq!(json.matches("\"arms\"").count(), 1);
    }

    #[test]
    fn budget_clamps_iters() {
        let s = bench_budget(0.001, || std::thread::sleep(std::time::Duration::from_millis(1)));
        assert!(s.iters >= 3);
    }
}
