//! Poison-recovering lock helpers.
//!
//! Every piece of shared mutable state in this repo is either a counter
//! bundle (`Metrics`, `CostModel` EWMA cells) or a cache (`EscPlanCache`,
//! `SliceCache`, `WorkspacePool`, tuning catalogs). Both are safe to keep
//! using after a panic unwound while the lock was held: counters may be
//! off by the one in-flight update, caches may hold a half-inserted entry
//! that is either valid or will simply be overwritten. What is *not*
//! acceptable is the std default, where one panic poisons the mutex and
//! every later `lock().unwrap()` propagates the panic — turning a single
//! worker fault into whole-service death (the failure mode the chaos
//! suite injects deliberately).
//!
//! `lock`/`wait`/`wait_timeout` therefore recover the guard from a
//! `PoisonError` instead of unwrapping, and count each recovery so the
//! event is observable rather than silent. Call sites must not leave
//! multi-step invariants broken across a panic; the repo's shared state
//! keeps its invariants per-field, which is why blanket recovery is sound
//! here.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard, WaitTimeoutResult};
use std::time::Duration;

/// Total poisoned-lock recoveries since process start (all mutexes).
static RECOVERED: AtomicU64 = AtomicU64::new(0);

#[cold]
fn note_recovery() {
    RECOVERED.fetch_add(1, Ordering::Relaxed);
}

/// Process-wide count of poisoned-mutex recoveries.
pub fn recovered_total() -> u64 {
    RECOVERED.load(Ordering::Relaxed)
}

/// Lock `m`, recovering the guard if a previous holder panicked.
#[inline]
pub fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => {
            note_recovery();
            poisoned.into_inner()
        }
    }
}

/// `Condvar::wait` that recovers a poisoned guard.
#[inline]
pub fn wait<'a, T>(cv: &Condvar, g: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    match cv.wait(g) {
        Ok(g) => g,
        Err(poisoned) => {
            note_recovery();
            poisoned.into_inner()
        }
    }
}

/// `Condvar::wait_timeout` that recovers a poisoned guard.
#[inline]
pub fn wait_timeout<'a, T>(
    cv: &Condvar,
    g: MutexGuard<'a, T>,
    dur: Duration,
) -> (MutexGuard<'a, T>, WaitTimeoutResult) {
    match cv.wait_timeout(g, dur) {
        Ok(pair) => pair,
        Err(poisoned) => {
            note_recovery();
            poisoned.into_inner()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn recovers_after_panic_while_held() {
        let m = Arc::new(Mutex::new(7usize));
        let m2 = Arc::clone(&m);
        let before = recovered_total();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.is_poisoned());
        // std's unwrap would propagate the panic here; we recover.
        {
            let mut g = lock(&m);
            assert_eq!(*g, 7);
            *g = 8;
        }
        assert_eq!(*lock(&m), 8);
        assert!(recovered_total() > before);
    }

    #[test]
    fn wait_timeout_recovers() {
        let pair = Arc::new((Mutex::new(0u32), Condvar::new()));
        let p2 = Arc::clone(&pair);
        let _ = std::thread::spawn(move || {
            let _g = p2.0.lock().unwrap();
            panic!("poison under cv");
        })
        .join();
        let g = lock(&pair.0);
        let (g, timed_out) = wait_timeout(&pair.1, g, Duration::from_millis(1));
        assert!(timed_out.timed_out());
        drop(g);
    }
}
