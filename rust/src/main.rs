//! `adp` — CLI for the ADP-DGEMM reproduction.
//!
//! Subcommands:
//!   info                         artifact catalog + platform profiles
//!   gemm   --n N [..]            one ADP GEMM, decision + accuracy report
//!   serve  --requests R [..]     batched service demo (latency/throughput)
//!   grade  --impl I --n N        grading-test verdict for implementation I
//!   qr     --n N [..]            ADP-backed blocked QR demo
//!   kernels                      slice-pair kernel tiers on this host
//!   tune-probe [--kernel K ..]   resolve the tile autotuner (probe/cache)
//!
//! `gemm`, `serve` and `qr` accept `--compute serial|parallel|parallel:N`
//! to pick the compute backend (default: machine-sized parallel; results
//! are bitwise identical either way). `gemm` and `serve` accept
//! `--tier guaranteed|fast|fp32` to pick the accuracy tier (default:
//! the `ADP_TIER` env var, else guaranteed); `ADP_COSTMODEL=<path>`
//! persists the learned ns/MAC cost model across runs. `serve`
//! additionally accepts
//! `--shards S` to split the queue into S shape-routed shards (each with
//! its own worker-pool slice), `--coalesce true` to enable the grouped
//! pipeline (micro-batching window + shape buckets + slice cache),
//! `--batch B` to size the shared-A request groups it submits (default
//! 8) and `--deadline-ms D` to shed requests whose queue wait exceeds D
//! milliseconds (0 = never shed, the default). For sustained
//! mixed-shape saturation with per-tier SLO reporting see
//! `examples/load_gen.rs` (`BENCH_service.json`).
//!
//! Fault injection (chaos drills): `ADP_FAULTS=site=trigger[@arg],...`
//! arms deterministic faults at named sites (`ADP_FAULTS_SEED` seeds the
//! probabilistic triggers); see `util::faultinject` for the grammar and
//! the site list. Disarmed (the default), every site is a single
//! relaxed atomic load. `serve` prints the self-healing counters
//! (shed/respawns/quarantines/lock recoveries) after each run.
//!
//! Argument parsing is hand-rolled (`--key value` pairs); clap is
//! unavailable in the offline environment.

use std::collections::HashMap;
use std::path::Path;

use adp_dgemm::backend::BackendSpec;
use adp_dgemm::coordinator::heuristic::{AlwaysEmulate, CpuCalibration};
use adp_dgemm::coordinator::{AdpConfig, AdpEngine, GemmService, ServiceConfig};
use adp_dgemm::grading::{self, generators};
use adp_dgemm::linalg::{blocked_qr, gemm, strassen, Matrix, NativeGemm};
use adp_dgemm::ozaki::{
    emulated_gemm, kernel, tune, AccuracyTier, KernelId, OzakiConfig, ShapeBucket, SliceEncoding,
};
use adp_dgemm::perfmodel::{GB200, RTX_PRO_6000};
use adp_dgemm::runtime::RuntimeHandle;
use adp_dgemm::util::Rng;

struct Args {
    kv: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut kv = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            if let Some(key) = argv[i].strip_prefix("--") {
                let val = argv.get(i + 1).cloned().unwrap_or_else(|| "true".into());
                kv.insert(key.to_string(), val);
                i += 2;
            } else {
                i += 1;
            }
        }
        Args { kv }
    }

    fn usize(&self, key: &str, default: usize) -> usize {
        self.kv.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn u64(&self, key: &str, default: u64) -> u64 {
        self.kv.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.kv.get(key).map(|s| s.as_str()).unwrap_or(default)
    }
}

fn compute_spec(args: &Args) -> BackendSpec {
    let s = args.str("compute", "parallel");
    BackendSpec::parse(s).unwrap_or_else(|| {
        eprintln!("note: unknown --compute '{s}' — using the serial backend");
        BackendSpec::Serial
    })
}

fn accuracy_tier(args: &Args) -> AccuracyTier {
    match args.kv.get("tier") {
        Some(s) => AccuracyTier::parse(s).unwrap_or_else(|| {
            eprintln!("note: unknown --tier '{s}' (want guaranteed|fast|fp32) — using guaranteed");
            AccuracyTier::GuaranteedFp64
        }),
        None => AccuracyTier::env_default(),
    }
}

fn runtime(args: &Args) -> Option<RuntimeHandle> {
    let dir = args.str("artifacts", "artifacts").to_string();
    let rt = RuntimeHandle::try_load(Path::new(&dir));
    if rt.is_none() {
        eprintln!("note: no artifacts at '{dir}' — using native pipelines (run `make artifacts`)");
    }
    rt
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let args = Args::parse(&argv[argv.len().min(1)..]);
    match cmd {
        "info" => cmd_info(&args),
        "gemm" => cmd_gemm(&args),
        "serve" => cmd_serve(&args),
        "grade" => cmd_grade(&args),
        "qr" => cmd_qr(&args),
        "kernels" => cmd_kernels(),
        "tune-probe" => cmd_tune_probe(&args),
        _ => {
            println!(
                "usage: adp <info|gemm|serve|grade|qr|kernels|tune-probe> [--key value ...]\n\
                 see rust/src/main.rs header for options"
            );
        }
    }
}

fn cmd_info(args: &Args) {
    println!("ADP-DGEMM reproduction — platform profiles:");
    for p in [GB200, RTX_PRO_6000] {
        println!(
            "  {:<28} fp64 {:>6.2} TF (eff {:.2})  int8 {:>6.0} TOPS (eff {:.2})  bw {:>5.0} GB/s",
            p.name, p.fp64_tflops, p.fp64_eff, p.int8_tops, p.int8_eff, p.mem_bw_gbs
        );
    }
    match runtime(args) {
        Some(rt) => {
            let cat = rt.catalog();
            println!("artifacts ({} entries):", cat.entries.len());
            for e in &cat.entries {
                println!("  {:?} n={} slices={} {}", e.kind, e.n, e.slices, e.path.display());
            }
        }
        None => println!("artifacts: none"),
    }
}

fn cmd_gemm(args: &Args) {
    let n = args.usize("n", 64);
    let seed = args.u64("seed", 1);
    let span = args.usize("span", 0) as i32;
    let mut rng = Rng::new(seed);
    let (a, b) = if span > 0 {
        let w = generators::test2_workload(n, span, &mut rng);
        (w.a, w.b)
    } else {
        generators::uniform_pair(n, -1.0, 1.0, &mut rng)
    };
    let tier = accuracy_tier(args);
    let engine = AdpEngine::new(
        AdpConfig::fp64()
            .with_heuristic(Box::new(AlwaysEmulate))
            .with_runtime(runtime(args))
            .with_backend(compute_spec(args).build())
            .with_tier(tier),
    );
    let (c, out) = engine.gemm(&a, &b);
    let rep = grading::grade::measure(&a, &b, &c);
    let snap = engine.metrics.snapshot();
    println!(
        "n={n} span={span} tier={}: decision={} esc={} slices={} guardrail={:.3}ms exec={:.3}ms pairs={}+{} skipped (escalations {})",
        tier.label(),
        out.decision.label(),
        out.esc,
        out.slices_required,
        out.guardrail_s * 1e3,
        out.exec_s * 1e3,
        snap.pairs_executed,
        snap.pairs_skipped,
        snap.tier_escalations
    );
    println!(
        "accuracy: max {:.2} eps, avg {:.3} eps (grade A at slope 2: {})",
        rep.max_comp_eps,
        rep.avg_comp_eps,
        if grading::grade::passes_grade_a(&rep, n, 2.0) { "PASS" } else { "FAIL" }
    );
}

fn cmd_serve(args: &Args) {
    let requests = args.usize("requests", 64);
    let n = args.usize("n", 64);
    let workers = args.usize("workers", 4);
    let seed = args.u64("seed", 7);
    let coalesce = args.str("coalesce", "false") == "true";
    let batch = args.usize("batch", 8).max(1);
    let shards = args.usize("shards", 1).max(1);
    let deadline_ms = args.usize("deadline-ms", 0);
    let rt = runtime(args);
    let tier = accuracy_tier(args);
    let cfg = ServiceConfig {
        workers,
        shards,
        backend: compute_spec(args),
        coalesce,
        default_tier: tier,
        default_deadline: (deadline_ms > 0)
            .then(|| std::time::Duration::from_millis(deadline_ms as u64)),
        ..Default::default()
    };
    let svc = GemmService::start(cfg, rt, || Box::new(AlwaysEmulate));
    let mut rng = Rng::new(seed);
    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    if coalesce {
        // Grouped submission: each group shares one A, so the slice cache
        // decomposes it once per group (watch the hit counters below).
        let mut i = 0;
        while i < requests {
            let g = batch.min(requests - i);
            let a = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
            let mut pairs = Vec::with_capacity(g);
            for j in 0..g {
                let mut a = a.clone();
                if (i + j) % 16 == 5 {
                    *a.at_mut(0, 0) = f64::NAN; // exercise the guardrails
                }
                pairs.push((a, Matrix::uniform(n, n, -1.0, 1.0, &mut rng)));
            }
            pending.extend(svc.submit_batch(pairs).expect("service running"));
            i += g;
        }
    } else {
        for i in 0..requests {
            let (mut a, b) = generators::uniform_pair(n, -1.0, 1.0, &mut rng);
            if i % 16 == 5 {
                *a.at_mut(0, 0) = f64::NAN; // exercise the guardrails
            }
            pending.push(svc.submit(a, b).expect("service running"));
        }
    }
    let mut lat = Vec::new();
    let mut shed = 0u64;
    for rx in pending {
        match rx.recv().expect("service dropped reply") {
            Ok(resp) => lat.push(resp.total_s),
            Err(adp_dgemm::coordinator::service::GemmError::DeadlineExceeded) => shed += 1,
            Err(e) => panic!("request failed: {e}"),
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let snap = svc.metrics.snapshot();
    if lat.is_empty() {
        println!("{requests} reqs x n={n}: every request shed at its deadline ({shed} shed)");
        svc.shutdown();
        return;
    }
    println!(
        "{requests} reqs x n={n}, {workers} workers / {shards} shard(s), tier {}{}: {:.2} req/s, p50 {:.2} ms, p99 {:.2} ms",
        tier.label(),
        if coalesce { " [coalesced]" } else { "" },
        requests as f64 / wall,
        lat[lat.len() / 2] * 1e3,
        lat[(lat.len() * 99) / 100] * 1e3
    );
    for t in &snap.tiers {
        if t.enqueued + t.rejected == 0 {
            continue;
        }
        println!(
            "tier {:<6} enq={} done={} failed={} rejected={} ({:.1}%) | queue p50/p99 {:.2}/{:.2} ms, total p50/p99 {:.2}/{:.2} ms",
            t.tier,
            t.enqueued,
            t.completed,
            t.failed,
            t.rejected,
            t.rejection_rate() * 100.0,
            t.queue_p50_s * 1e3,
            t.queue_p99_s * 1e3,
            t.total_p50_s * 1e3,
            t.total_p99_s * 1e3
        );
    }
    println!(
        "outcomes: emulated={} nan={} inf={} esc={} heuristic={} | guardrail {:.2}%",
        snap.emulated,
        snap.fallback_nan,
        snap.fallback_inf,
        snap.fallback_esc,
        snap.fallback_heuristic,
        snap.guardrail_fraction() * 100.0
    );
    println!(
        "accuracy tiers: requests {:?} | pairs executed/skipped {}/{} | escalations {}",
        snap.tier_requests, snap.pairs_executed, snap.pairs_skipped, snap.tier_escalations
    );
    println!(
        "caches: slice hits/misses {}/{} esc hits/misses {}/{} | {} reqs in {} buckets",
        snap.slice_cache_hits,
        snap.slice_cache_misses,
        snap.esc_cache_hits,
        snap.esc_cache_misses,
        snap.coalesced_requests,
        snap.coalesced_batches
    );
    println!(
        "fused engine: {} tiles on kernel '{}' at tile {} ({} panel packs, {} pair reuses) | workspaces: {} checkouts, {} fresh allocations",
        snap.fused_tiles,
        if snap.kernel.is_empty() { "n/a" } else { snap.kernel },
        if snap.tile_mc == 0 {
            "n/a".to_string()
        } else {
            format!("{}x{}", snap.tile_mc, snap.tile_nc)
        },
        snap.panel_packs,
        snap.panel_reuses,
        snap.workspace_checkouts,
        snap.workspace_fresh
    );
    println!(
        "self-healing: shed_expired={} worker_respawns={} artifacts_quarantined={} lock_recoveries={}",
        snap.shed_expired, snap.worker_respawns, snap.artifacts_quarantined, snap.lock_recoveries
    );
    // shutdown() flushes the learned cost model and tile-tuning catalog,
    // so a warm model survives an orderly exit (ADP_COSTMODEL /
    // ADP_TUNE_CATALOG).
    svc.shutdown();
}

fn cmd_kernels() {
    // One line per tier, machine-greppable (CI uses this to decide which
    // ADP_KERNEL values the host can actually run):
    //   kernel <label> available|unavailable [active]
    let active = kernel::active_id(SliceEncoding::Unsigned);
    for id in KernelId::ALL {
        println!(
            "kernel {} {}{}",
            id.label(),
            if kernel::kernel_by_id(id).is_some() { "available" } else { "unavailable" },
            if id == active { " active" } else { "" }
        );
    }
}

fn cmd_tune_probe(args: &Args) {
    // Force-resolve the autotuner for one (kernel, bucket) and report
    // where the entry came from. With ADP_TUNE_CATALOG set, a first run
    // prints `source=probed` and a second process prints `source=cached`
    // — the CI persistence check.
    let kern = match args.kv.get("kernel") {
        Some(s) => match KernelId::parse(s) {
            Some(k) => k,
            None => {
                eprintln!("unknown --kernel '{s}' (see `adp kernels`)");
                std::process::exit(2);
            }
        },
        None => kernel::active_id(SliceEncoding::Unsigned),
    };
    let bucket = match ShapeBucket::parse(args.str("bucket", "medium")) {
        Some(b) => b,
        None => {
            eprintln!("unknown --bucket (want small|medium|large)");
            std::process::exit(2);
        }
    };
    if kernel::kernel_by_id(kern).is_none() {
        println!("tune-probe kernel={} unavailable on this host", kern.label());
        return;
    }
    let (shape, cached) = tune::tune_probe(kern, bucket);
    let pair_ns = tune::measured_pair_ns(kern).unwrap_or(0.0);
    println!(
        "tune-probe kernel={} bucket={} tile={} source={} pair_ns={:.6}",
        kern.label(),
        bucket.label(),
        shape.label(),
        if cached { "cached" } else { "probed" },
        pair_ns
    );
}

fn cmd_grade(args: &Args) {
    let n = args.usize("n", 128);
    let seed = args.u64("seed", 3);
    let which = args.str("impl", "adp").to_string();
    let rt = runtime(args);
    let engine = AdpEngine::new(
        AdpConfig::fp64().with_heuristic(Box::new(AlwaysEmulate)).with_runtime(rt),
    );
    let mut mult: Box<dyn FnMut(&Matrix, &Matrix) -> Matrix> = match which.as_str() {
        "native" => Box::new(|a, b| gemm(a, b)),
        "strassen" => Box::new(|a, b| strassen(a, b)),
        s if s.starts_with("fixed:") => {
            let slices: usize = s[6..].parse().expect("fixed:<slices>");
            Box::new(move |a, b| emulated_gemm(a, b, &OzakiConfig::new(slices)))
        }
        _ => Box::new(move |a, b| engine.gemm(a, b).0),
    };
    let class = grading::discover(n, seed, &mut *mult);
    println!("impl '{which}' at n={n}: classified as {class:?}");
}

fn cmd_qr(args: &Args) {
    let n = args.usize("n", 256);
    let panel = args.usize("panel", 32);
    let seed = args.u64("seed", 5);
    let backend = args.str("backend", "adp").to_string();
    let mut rng = Rng::new(seed);
    let a = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
    let t0 = std::time::Instant::now();
    let (qr, stats) = match backend.as_str() {
        "native" => blocked_qr(&a, panel, &mut NativeGemm),
        _ => {
            let mut engine = AdpEngine::new(
                AdpConfig::fp64()
                    .with_heuristic(Box::new(CpuCalibration::measure()))
                    .with_runtime(runtime(args))
                    .with_backend(compute_spec(args).build()),
            );
            let r = blocked_qr(&a, panel, &mut engine);
            let snap = engine.metrics.snapshot();
            println!(
                "adp backend: {} gemms, emulated {}, fallbacks {}, slice histogram {:?}",
                snap.requests,
                snap.emulated,
                snap.fallbacks(),
                snap.slice_histogram
            );
            r
        }
    };
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "qr n={n} panel={panel} backend={backend}: {:.1} ms, residual {:.3e}, {} trailing gemms ({:.2} GF routed)",
        dt * 1e3,
        qr.residual(&a),
        stats.gemm_calls,
        stats.gemm_flops / 1e9
    );
}
