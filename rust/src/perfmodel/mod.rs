//! GPU Tensor-Core cost model — the performance substrate substitution.
//!
//! The paper's performance results (Figs 5–7) are measured on NVIDIA GB200
//! and RTX Pro 6000 Blackwell Server Edition GPUs, which this environment
//! does not have. Following DESIGN.md §Substitutions, the benches combine
//! (a) *measured* CPU-substrate numbers for the algorithmic op mix with
//! (b) this analytical throughput model parameterized by the two platforms'
//! published peak rates, to reproduce the *shape* of the paper's results:
//! who wins, by what factor, where the crossovers fall, and the <10% ADP
//! overhead bound. The model is deliberately simple and fully documented so
//! every projected number in EXPERIMENTS.md can be traced to a formula.

/// A GPU platform profile (peak rates with achievable-efficiency factors).
#[derive(Clone, Copy, Debug)]
pub struct Platform {
    pub name: &'static str,
    /// Peak FP64 (tensor-core) throughput, TFLOP/s.
    pub fp64_tflops: f64,
    /// Peak INT8 tensor-core throughput, TOP/s (dense).
    pub int8_tops: f64,
    /// HBM bandwidth, GB/s.
    pub mem_bw_gbs: f64,
    /// Fraction of FP64 peak a tuned DGEMM achieves at large n.
    pub fp64_eff: f64,
    /// Fraction of INT8 peak the slice GEMMs achieve at large n.
    pub int8_eff: f64,
    /// Fixed per-launch overhead of the ADP pre-pass kernels, microseconds
    /// (scan + ESC + heuristic; §5: "negligible decision overhead").
    pub adp_fixed_us: f64,
}

/// NVIDIA GB200 (Blackwell, datacenter): strong native FP64 tensor cores
/// (1:112 INT8:FP64 op ratio) — emulation wins modestly (paper: up to 2.3x).
pub const GB200: Platform = Platform {
    name: "GB200",
    fp64_tflops: 40.0,
    int8_tops: 4500.0,
    mem_bw_gbs: 8000.0,
    fp64_eff: 0.85,
    // Calibrated so the 55-bit large-n speedup lands at the paper's 2.3x
    // (see EXPERIMENTS.md §Fig6 for the calibration trace).
    int8_eff: 0.52,
    adp_fixed_us: 8.0,
};

/// RTX Pro 6000 Blackwell Server Edition (workstation-class): FP64 is
/// 1:64 of FP32 (~2 TFLOP/s) while INT8 tensor cores are huge — emulation
/// wins big (paper: up to 13.2x).
pub const RTX_PRO_6000: Platform = Platform {
    name: "RTX Pro 6000 Blackwell",
    fp64_tflops: 1.95,
    int8_tops: 1800.0,
    mem_bw_gbs: 1790.0,
    fp64_eff: 0.80,
    // Calibrated to the paper's 13.2x 55-bit ceiling (EXPERIMENTS.md §Fig6).
    int8_eff: 0.34,
    adp_fixed_us: 8.0,
};

/// Per-phase time breakdown of one emulated GEMM (seconds) — Fig 5's bars.
#[derive(Clone, Copy, Debug, Default)]
pub struct ModelBreakdown {
    pub scan_esc_s: f64,
    pub slice_s: f64,
    pub int_gemm_s: f64,
    pub recompose_s: f64,
}

impl ModelBreakdown {
    pub fn total(&self) -> f64 {
        self.scan_esc_s + self.slice_s + self.int_gemm_s + self.recompose_s
    }

    pub fn adp_overhead_fraction(&self) -> f64 {
        self.scan_esc_s / self.total()
    }
}

impl Platform {
    /// Time for a tuned native FP64 GEMM (the cuBLAS DGEMM baseline).
    pub fn dgemm_time(&self, m: usize, k: usize, n: usize) -> f64 {
        let flops = 2.0 * m as f64 * k as f64 * n as f64;
        let compute = flops / (self.fp64_tflops * 1e12 * self.fp64_eff);
        let bytes = 8.0 * (m * k + k * n + m * n) as f64;
        compute.max(bytes / (self.mem_bw_gbs * 1e9)) + 3e-6
    }

    /// Emulated DGEMM time with `slices` slices at the full triangular
    /// schedule, including or excluding the ADP guardrail pre-pass.
    pub fn emulated_breakdown(
        &self,
        m: usize,
        k: usize,
        n: usize,
        slices: usize,
        with_adp: bool,
    ) -> ModelBreakdown {
        self.emulated_breakdown_pairs(m, k, n, slices, slices * (slices + 1) / 2, with_adp)
    }

    /// [`Platform::emulated_breakdown`] with an explicit pair-GEMM count —
    /// the tier-truncated schedules run fewer than `s(s+1)/2` pairs, and
    /// the projected int-GEMM phase must scale with what actually runs.
    pub fn emulated_breakdown_pairs(
        &self,
        m: usize,
        k: usize,
        n: usize,
        slices: usize,
        pair_count: usize,
        with_adp: bool,
    ) -> ModelBreakdown {
        let (mf, kf, nf) = (m as f64, k as f64, n as f64);
        let bw = self.mem_bw_gbs * 1e9;

        // ADP pre-pass (§5): one extra read of A and B for the fused
        // NaN/Inf scan + block min/max, a max-plus "GEMM" coarsened by
        // b=64 on DPX-class integer units (modeled at INT8 rate / 8), and
        // a fixed launch cost. Runs once regardless of slice count.
        let scan_esc_s = if with_adp {
            let scan_bytes = 8.0 * (mf * kf + kf * nf);
            let maxplus_ops = mf * nf * (kf / 64.0) * 2.0;
            scan_bytes / bw
                + maxplus_ops / (self.int8_tops * 1e12 / 8.0)
                + self.adp_fixed_us * 1e-6
        } else {
            0.0
        };

        // Per-phase kernel-launch overhead (same 3 us the DGEMM baseline
        // carries): slicing, the batched pair GEMMs, and recomposition.
        const LAUNCH: f64 = 3e-6;

        // Slicing: read each operand once, write s INT8 slice tensors
        // (bandwidth-bound; the conversion ALU work hides under the loads).
        let slice_bytes = (8.0 + slices as f64) * (mf * kf + kf * nf);
        let slice_s = slice_bytes / bw + LAUNCH;

        // The schedule's INT8 pair-GEMMs: s(s+1)/2 under full Ozaki-I
        // triangular truncation, fewer under tier truncation.
        let pairs = pair_count as f64;
        let int_ops = 2.0 * mf * kf * nf * pairs;
        let int_gemm_s = int_ops / (self.int8_tops * 1e12 * self.int8_eff) + LAUNCH;

        // Recomposition: s weight levels of i32->f64 scaled accumulation
        // over the m*n output (bandwidth-bound).
        let recompose_bytes = (4.0 * pairs.min(slices as f64 * 2.0) + 8.0) * mf * nf;
        let recompose_s = recompose_bytes / bw + LAUNCH;

        ModelBreakdown { scan_esc_s, slice_s, int_gemm_s, recompose_s }
    }

    pub fn emulated_time(&self, m: usize, k: usize, n: usize, slices: usize, with_adp: bool) -> f64 {
        self.emulated_breakdown(m, k, n, slices, with_adp).total()
    }

    /// Emulated DGEMM time for the Ozaki-II/CRT family: one INT8 GEMM per
    /// modulus (`moduli` launches — linear in the window, against the
    /// slice-pair scheme's quadratic `s(s+1)/2`), paid for by a heavier
    /// per-element reconstruction (Garner over all `moduli` residue
    /// planes) and `moduli` residue planes per operand instead of `s`
    /// slices.
    pub fn crt_breakdown(
        &self,
        m: usize,
        k: usize,
        n: usize,
        moduli: usize,
        with_adp: bool,
    ) -> ModelBreakdown {
        let (mf, kf, nf) = (m as f64, k as f64, n as f64);
        let bw = self.mem_bw_gbs * 1e9;
        let nmf = moduli as f64;

        // The ADP pre-pass is scheme-independent: same scan, same coarse
        // ESC reduction, same fixed decision cost.
        let scan_esc_s = if with_adp {
            let scan_bytes = 8.0 * (mf * kf + kf * nf);
            let maxplus_ops = mf * nf * (kf / 64.0) * 2.0;
            scan_bytes / bw
                + maxplus_ops / (self.int8_tops * 1e12 / 8.0)
                + self.adp_fixed_us * 1e-6
        } else {
            0.0
        };

        const LAUNCH: f64 = 3e-6;

        // Residue extraction: read each operand once, write one INT8
        // residue plane per modulus (bandwidth-bound, like slicing).
        let slice_bytes = (8.0 + nmf) * (mf * kf + kf * nf);
        let slice_s = slice_bytes / bw + LAUNCH;

        // One INT8 GEMM per modulus — the linear launch count.
        let int_ops = 2.0 * mf * kf * nf * nmf;
        let int_gemm_s = int_ops / (self.int8_tops * 1e12 * self.int8_eff) + LAUNCH;

        // CRT reconstruction: fold `moduli` i32 residue planes through
        // Garner into one FP64 output (bandwidth-bound).
        let recompose_bytes = (4.0 * nmf + 8.0) * mf * nf;
        let recompose_s = recompose_bytes / bw + LAUNCH;

        ModelBreakdown { scan_esc_s, slice_s, int_gemm_s, recompose_s }
    }

    pub fn crt_emulated_time(
        &self,
        m: usize,
        k: usize,
        n: usize,
        moduli: usize,
        with_adp: bool,
    ) -> f64 {
        self.crt_breakdown(m, k, n, moduli, with_adp).total()
    }

    /// Speedup of emulation over native FP64 (Fig 6's y-axis).
    pub fn speedup(&self, n: usize, slices: usize, with_adp: bool) -> f64 {
        self.dgemm_time(n, n, n) / self.emulated_time(n, n, n, slices, with_adp)
    }

    /// The ADP heuristic's decision input (§5.3): emulate iff the modeled
    /// emulated time (including guardrails) beats native FP64.
    pub fn emulation_profitable(&self, m: usize, k: usize, n: usize, slices: usize) -> bool {
        self.emulated_time(m, k, n, slices, true) < self.dgemm_time(m, k, n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 55-bit setting of the paper = 7 slices in its encoding; our unsigned
    /// encoding reaches 54 bits at s=7 (see DESIGN.md).
    const S55: usize = 7;

    #[test]
    fn paper_headline_speedups() {
        // Fig 6: up to ~2.3x on GB200, ~13.2x on RTX Pro 6000 at 55 bits.
        let g = GB200.speedup(8192, S55, false);
        assert!((1.8..3.0).contains(&g), "GB200 speedup {g}");
        let r = RTX_PRO_6000.speedup(8192, S55, false);
        assert!((10.0..16.0).contains(&r), "RTX speedup {r}");
    }

    #[test]
    fn adp_overhead_below_ten_percent() {
        // §7.1: even forced to 55 bits, ADP adds < 10% for large GEMMs.
        for p in [GB200, RTX_PRO_6000] {
            for n in [2048usize, 4096, 8192] {
                let with = p.emulated_time(n, n, n, S55, true);
                let without = p.emulated_time(n, n, n, S55, false);
                let overhead = (with - without) / with;
                assert!(overhead < 0.10, "{} n={n}: overhead {overhead}", p.name);
            }
        }
    }

    #[test]
    fn small_sizes_fall_back() {
        // Fig 7: "for very small problem sizes ADP recognizes that the
        // overhead of emulation outweighs its benefits".
        assert!(!GB200.emulation_profitable(128, 128, 128, S55));
        assert!(GB200.emulation_profitable(8192, 8192, 8192, S55));
        assert!(RTX_PRO_6000.emulation_profitable(2048, 2048, 2048, S55));
    }

    #[test]
    fn more_slices_cost_more() {
        let t7 = GB200.emulated_time(4096, 4096, 4096, 7, true);
        let t9 = GB200.emulated_time(4096, 4096, 4096, 9, true);
        let t14 = GB200.emulated_time(4096, 4096, 4096, 14, true);
        assert!(t7 < t9 && t9 < t14);
    }

    #[test]
    fn unsigned_vs_signed_compute_saving() {
        // §3: 7 slices instead of 8 => 28 vs 36 pair GEMMs (~22% less).
        let t7 = GB200.emulated_time(8192, 8192, 8192, 7, false);
        let t8 = GB200.emulated_time(8192, 8192, 8192, 8, false);
        let saving = 1.0 - t7 / t8;
        assert!((0.15..0.26).contains(&saving), "saving {saving}");
    }

    #[test]
    fn truncated_pairs_project_proportionally_cheaper() {
        // The fast tier at s=7 runs 10 of 28 pairs; the projected
        // int-GEMM phase must shrink by exactly that ratio while the
        // bandwidth-bound phases stay put.
        let full = GB200.emulated_breakdown(4096, 4096, 4096, S55, false);
        let trunc = GB200.emulated_breakdown_pairs(4096, 4096, 4096, S55, 10, false);
        let ratio = trunc.int_gemm_s / full.int_gemm_s;
        assert!((ratio - 10.0 / 28.0).abs() < 0.05, "ratio {ratio}");
        assert_eq!(trunc.slice_s.to_bits(), full.slice_s.to_bits());
        assert!(trunc.total() < full.total());
    }

    #[test]
    fn crt_linear_launches_beat_pairs_at_matched_window() {
        // Same 54-bit window: 17 modulus GEMMs vs 28 slice-pair GEMMs.
        // Compute-bound at large n, the CRT arm must be strictly cheaper
        // on both platforms; its reconstruction is heavier, so the gap
        // stays below the raw 28/17 launch ratio.
        for p in [GB200, RTX_PRO_6000] {
            let sp = p.emulated_time(4096, 4096, 4096, S55, false);
            let crt = p.crt_emulated_time(4096, 4096, 4096, 17, false);
            assert!(crt < sp, "{}: crt {crt} vs slice-pair {sp}", p.name);
            assert!(sp / crt < 28.0 / 17.0, "{}: ratio {}", p.name, sp / crt);
        }
    }

    #[test]
    fn crossover_exists() {
        // Fig 6 shape: speedup grows with n, crossing 1.0 somewhere
        // between tiny and large sizes on GB200.
        let small = GB200.speedup(256, S55, true);
        let large = GB200.speedup(8192, S55, true);
        assert!(small < 1.0, "small {small}");
        assert!(large > 1.5, "large {large}");
    }
}
