//! Exact (uncoarsened) ESC — the O(mnk) oracle of §4.

use crate::linalg::Matrix;
use crate::util::bits::{frexp_exponent, ZERO_EXP};

/// Exact ESC of a single dot product. Returns 0 when the product has no
/// overlapping nonzero terms (the emulated result is exactly zero).
pub fn exact_esc_dot(x: &[f64], y: &[f64]) -> i32 {
    debug_assert_eq!(x.len(), y.len());
    let mut xp = ZERO_EXP; // exp(x_p)
    let mut yq = ZERO_EXP; // exp(y_q)
    let mut zr = i64::MIN; // exp(z_r) = max_i exp(x_i) + exp(y_i)
    for (&a, &b) in x.iter().zip(y) {
        let ea = frexp_exponent(a);
        let eb = frexp_exponent(b);
        xp = xp.max(ea);
        yq = yq.max(eb);
        if ea != ZERO_EXP && eb != ZERO_EXP {
            zr = zr.max(ea as i64 + eb as i64);
        }
    }
    if zr == i64::MIN || xp == ZERO_EXP || yq == ZERO_EXP {
        return 0; // all products vanish
    }
    // +1: mantissa products are < 4, may raise the exponent by one (§4).
    ((xp as i64 + yq as i64 - zr) + 1) as i32
}

/// Exact ESC of a GEMM: max over the m*n dot products.
pub fn exact_esc_gemm(a: &Matrix, b: &Matrix) -> i32 {
    assert_eq!(a.cols, b.rows);
    let bt = b.transpose();
    let mut esc = 0;
    for i in 0..a.rows {
        for j in 0..bt.rows {
            esc = esc.max(exact_esc_dot(a.row(i), bt.row(j)));
        }
    }
    esc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn uniform_same_exponent_is_one() {
        // All entries in [1,2): every Hadamard exponent equals xp+yq, so
        // ESC = 0 + 1 (mantissa margin).
        let x = vec![1.5, 1.25, 1.75];
        let y = vec![1.0, 1.5, 1.9];
        assert_eq!(exact_esc_dot(&x, &y), 1);
    }

    #[test]
    fn balanced_spans_cancel() {
        // x scaled up by 2^t exactly where y is scaled down: z uniform.
        let x = vec![2f64.powi(20), 1.0];
        let y = vec![2f64.powi(-20), 1.0];
        // xp = 21, yq = 1, zr = max(21-19, 1) = 2 -> 21+1-2+1... careful:
        // exp(2^20)=21 (frexp), exp(2^-20)=-19, exp(1.0)=1.
        // zr = max(21 + -19, 1 + 1) = 2; ESC = 21 + 1 - 2 + 1 = 21.
        assert_eq!(exact_esc_dot(&x, &y), 21);
    }

    #[test]
    fn zeros_are_excluded() {
        let x = vec![0.0, 1.0];
        let y = vec![1e300, 1.0];
        // the 1e300 pairs with a zero: only the 1*1 product survives.
        // xp = 1, yq = exp(1e300) = 997, zr = 1+1 = 2; ESC = 1+997-2+1.
        assert_eq!(exact_esc_dot(&x, &y), 997);
    }

    #[test]
    fn all_zero_returns_zero() {
        assert_eq!(exact_esc_dot(&[0.0, 0.0], &[1.0, 2.0]), 0);
        assert_eq!(exact_esc_dot(&[], &[]), 0);
    }

    #[test]
    fn gemm_takes_worst_dot() {
        let mut rng = Rng::new(40);
        let mut a = Matrix::uniform(4, 8, 1.0, 2.0, &mut rng);
        let b = Matrix::uniform(8, 4, 1.0, 2.0, &mut rng);
        assert_eq!(exact_esc_gemm(&a, &b), 1);
        // A big A-entry alone does NOT raise ESC: its own products raise
        // z_r along with x_p (the window tracks the row max).
        *a.at_mut(2, 3) = 2f64.powi(40);
        assert_eq!(exact_esc_gemm(&a, &b), 1);
        // ESC grows when the big x pairs with a small y: shrink B's row 3
        // so the 2^40 contribution cancels in z-space while x_p stays big.
        let mut b2 = b.clone();
        for j in 0..4 {
            *b2.at_mut(3, j) *= 2f64.powi(-40);
        }
        let esc = exact_esc_gemm(&a, &b2);
        assert!((40..=42).contains(&esc), "esc={esc}");
    }

    #[test]
    fn esc_is_shift_invariant() {
        // Scaling a whole row of A by 2^t leaves its ESC unchanged.
        let mut rng = Rng::new(41);
        let a = Matrix::uniform(3, 10, -4.0, 4.0, &mut rng);
        let b = Matrix::uniform(10, 3, -4.0, 4.0, &mut rng);
        let base = exact_esc_gemm(&a, &b);
        let mut a2 = a.clone();
        for j in 0..10 {
            *a2.at_mut(1, j) *= 2f64.powi(25);
        }
        assert_eq!(exact_esc_gemm(&a2, &b), base);
    }
}
