//! ESC — Exponent Span Capacity estimation (§4 of the paper).
//!
//! The ESC of a dot product `x . y` is `exp(x_p) + exp(y_q) - exp(z_r) + 1`
//! where `x_p`, `y_q` are the max-exponent entries of x and y, and
//! `z_r` the max-exponent Hadamard product (`exp(z_r) = max_i exp(x_i) +
//! exp(y_i)`); the `+1` covers the mantissa-product margin (mantissa
//! products are < 4). For a GEMM it is the max over all m*n dot products.
//!
//! ESC is the number of *extra* mantissa bits the fixed-point window must
//! reserve beyond the target precision so that the maximal contribution is
//! captured with full fidelity: `required_bits = target_mantissa + ESC + 1`.
//!
//! [`exact_esc_gemm`] is the O(mnk) oracle; [`coarse_esc_gemm`] is the
//! blocked estimator the runtime uses (O(mnk/b)), proven here (and tested)
//! never to *under*-estimate the exact ESC.

pub mod coarse;
pub mod exact;

pub use coarse::{coarse_esc_gemm, CoarseExponents};
pub use exact::{exact_esc_dot, exact_esc_gemm};

/// Outcome of an ESC estimation pass.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EscReport {
    /// The (estimated) exponent span capacity in bits.
    pub esc: i32,
    /// Bits required for 53-bit (FP64) target mantissa: 53 + esc + 1.
    pub required_bits_fp64: i32,
}

impl EscReport {
    pub fn new(esc: i32) -> Self {
        EscReport { esc, required_bits_fp64: 53 + esc + 1 }
    }

    /// Bits required for an arbitrary target mantissa width.
    pub fn required_bits(&self, target_mantissa: i32) -> i32 {
        target_mantissa + self.esc + 1
    }
}
