//! Coarsened ESC (§4): block min/max exponents + max-plus reduction.
//!
//! The k dimension is split into blocks of length `b`; each block is
//! represented by its max and min exponent. `exp(z_r)` is then estimated as
//! `max_i max( Max(xb_i)+Min(yb_i), Min(xb_i)+Max(yb_i) )`, which the
//! paper proves can only *under*-estimate the exact `exp(z_r)` — hence the
//! coarsened ESC can only be larger (safe). Zero entries carry the
//! [`ZERO_EXP`] sentinel: they lose every max and win every min, which
//! pushes the estimate further down — still safe, merely conservative.
//!
//! This mirrors `python/compile/model.py::scan_esc` + the `escmax` Pallas
//! kernel (the DPX/CUTLASS analogue); cross-validated in integration tests.

use crate::linalg::Matrix;
use crate::util::bits::{frexp_exponent, ZERO_EXP};

/// Default coarsening block (matches python/compile/model.py::ESC_BLOCK).
pub const DEFAULT_BLOCK: usize = 64;

/// Per-row block min/max exponents of one operand (A rows or B columns).
#[derive(Clone, Debug)]
pub struct CoarseExponents {
    pub rows: usize,
    pub nblocks: usize,
    /// The coarsening block the tables were built with (blocks of the k
    /// dimension; the last block may be shorter).
    pub block: usize,
    pub bmax: Vec<i32>, // rows x nblocks
    pub bmin: Vec<i32>,
    pub row_max: Vec<i32>, // exp(x_p) per row
}

impl CoarseExponents {
    /// Coarsen the rows of `a`.
    pub fn of_rows(a: &Matrix, block: usize) -> CoarseExponents {
        Self::of_source(a.rows, a.cols, block, |i, l| a.row(i)[l])
    }

    /// Coarsen the columns of `b` through a strided view — exponent tables
    /// identical to `of_rows(&b.transpose(), block)` without materializing
    /// the O(k·n) transpose temporary (test-pinned).
    pub fn of_cols(b: &Matrix, block: usize) -> CoarseExponents {
        Self::of_source(b.cols, b.rows, block, |j, l| b.data[l * b.cols + j])
    }

    fn of_source(
        m: usize,
        k: usize,
        block: usize,
        at: impl Fn(usize, usize) -> f64,
    ) -> CoarseExponents {
        let nb = k.div_ceil(block);
        let mut bmax = vec![ZERO_EXP; m * nb];
        let mut bmin = vec![i32::MAX; m * nb];
        let mut row_max = vec![ZERO_EXP; m];
        for i in 0..m {
            for bi in 0..nb {
                let lo = bi * block;
                let hi = (lo + block).min(k);
                let (mut mx, mut mn) = (ZERO_EXP, i32::MAX);
                for l in lo..hi {
                    let e = frexp_exponent(at(i, l));
                    mx = mx.max(e);
                    mn = mn.min(e);
                }
                bmax[i * nb + bi] = mx;
                bmin[i * nb + bi] = mn;
                row_max[i] = row_max[i].max(mx);
            }
        }
        CoarseExponents { rows: m, nblocks: nb, block, bmax, bmin, row_max }
    }

    /// Collapse the block tables to a single whole-k block. Equivalent to
    /// coarsening with `block >= k`, so the no-underestimate guarantee is
    /// preserved — merely the loosest member of the refinement family.
    fn collapse(&self) -> CoarseExponents {
        let m = self.rows;
        let nb = self.nblocks;
        let mut bmax = vec![ZERO_EXP; m];
        let mut bmin = vec![i32::MAX; m];
        for i in 0..m {
            for bi in 0..nb {
                // ZERO_EXP block maxes (all-zero blocks) lose the max and
                // i32::MAX mins (empty blocks can't occur: nb covers k)
                // lose the min, matching a direct whole-row scan.
                bmax[i] = bmax[i].max(self.bmax[i * nb + bi]);
                bmin[i] = bmin[i].min(self.bmin[i * nb + bi]);
            }
        }
        CoarseExponents {
            rows: m,
            nblocks: 1,
            block: usize::MAX,
            bmax,
            bmin,
            row_max: self.row_max.clone(),
        }
    }
}

/// Coarsened ESC of C = A * B with coarsening block `block`.
pub fn coarse_esc_gemm(a: &Matrix, b: &Matrix, block: usize) -> i32 {
    assert_eq!(a.cols, b.rows);
    let ca = CoarseExponents::of_rows(a, block);
    let cb = CoarseExponents::of_cols(b, block);
    coarse_esc_from(&ca, &cb)
}

/// ESC from precomputed coarse exponents (the runtime path: A's coarse form
/// can be reused across many B's, e.g. the QR trailing updates).
///
/// The fast path requires both operands coarsened with the same block
/// grid. On a mismatch (e.g. cached tables built under different
/// coarsening blocks meeting at a shared call site) this no longer
/// panics: both tables are collapsed to the whole-k block — a checked
/// recompute that stays on the conservative side of the §4 guarantee
/// at the cost of a looser estimate.
pub fn coarse_esc_from(ca: &CoarseExponents, cb: &CoarseExponents) -> i32 {
    if ca.nblocks != cb.nblocks || (ca.nblocks > 1 && ca.block != cb.block) {
        return coarse_esc_tables(&ca.collapse(), &cb.collapse());
    }
    coarse_esc_tables(ca, cb)
}

fn coarse_esc_tables(ca: &CoarseExponents, cb: &CoarseExponents) -> i32 {
    debug_assert_eq!(ca.nblocks, cb.nblocks);
    let nb = ca.nblocks;
    let mut esc = 0i32;
    for i in 0..ca.rows {
        let am = &ca.bmax[i * nb..(i + 1) * nb];
        let an = &ca.bmin[i * nb..(i + 1) * nb];
        for j in 0..cb.rows {
            let bm = &cb.bmax[j * nb..(j + 1) * nb];
            let bn = &cb.bmin[j * nb..(j + 1) * nb];
            // max-plus row: estimate exp(z_r) from below
            let mut zest = i64::MIN;
            for l in 0..nb {
                if am[l] == ZERO_EXP || bm[l] == ZERO_EXP {
                    continue; // block all-zero on one side: no products
                }
                let c1 = am[l] as i64 + bn[l] as i64;
                let c2 = an[l] as i64 + bm[l] as i64;
                zest = zest.max(c1.max(c2));
            }
            let (rm, cm) = (ca.row_max[i], cb.row_max[j]);
            if zest == i64::MIN || rm == ZERO_EXP || cm == ZERO_EXP {
                continue; // dead dot product: exactly zero under emulation
            }
            let e = (rm as i64 + cm as i64 - zest + 1) as i32;
            esc = esc.max(e);
        }
    }
    esc.max(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::esc::exact::exact_esc_gemm;
    use crate::util::{prop, Rng};

    fn rand_spanned(rng: &mut Rng, m: usize, k: usize, span: i32) -> Matrix {
        Matrix::from_fn(m, k, |_, _| {
            let e = rng.int(-span as i64, span as i64) as i32;
            rng.uniform(1.0, 2.0) * 2f64.powi(e) * if rng.f64() < 0.5 { -1.0 } else { 1.0 }
        })
    }

    #[test]
    fn coarse_never_below_exact() {
        let mut rng = Rng::new(50);
        for trial in 0..30 {
            let (m, k, n) = (6, 48, 5);
            let a = rand_spanned(&mut rng, m, k, 30);
            let b = rand_spanned(&mut rng, k, n, 30);
            let exact = exact_esc_gemm(&a, &b);
            for block in [1, 4, 16, 48] {
                let coarse = coarse_esc_gemm(&a, &b, block);
                assert!(coarse >= exact, "trial {trial} block {block}: {coarse} < {exact}");
            }
        }
    }

    #[test]
    fn block_one_equals_exact() {
        // With b = 1, Max(xb)=Min(xb) per block: the estimate is exact.
        let mut rng = Rng::new(51);
        let a = rand_spanned(&mut rng, 5, 20, 25);
        let b = rand_spanned(&mut rng, 20, 4, 25);
        assert_eq!(coarse_esc_gemm(&a, &b, 1), exact_esc_gemm(&a, &b));
    }

    #[test]
    fn refinement_monotone_on_average() {
        // Smaller blocks can only tighten (or keep) the per-dot estimate.
        let mut rng = Rng::new(52);
        let a = rand_spanned(&mut rng, 8, 64, 20);
        let b = rand_spanned(&mut rng, 64, 8, 20);
        let e64 = coarse_esc_gemm(&a, &b, 64);
        let e16 = coarse_esc_gemm(&a, &b, 16);
        let e1 = coarse_esc_gemm(&a, &b, 1);
        assert!(e1 <= e16 && e16 <= e64, "{e1} <= {e16} <= {e64}");
    }

    #[test]
    fn zeros_are_conservative_not_unsafe() {
        let mut rng = Rng::new(53);
        let mut a = rand_spanned(&mut rng, 4, 32, 10);
        let b = rand_spanned(&mut rng, 32, 4, 10);
        for j in 0..32 {
            if j % 3 == 0 {
                *a.at_mut(2, j) = 0.0;
            }
        }
        let exact = exact_esc_gemm(&a, &b);
        let coarse = coarse_esc_gemm(&a, &b, 8);
        assert!(coarse >= exact);
    }

    #[test]
    fn all_zero_operand() {
        let a = Matrix::zeros(3, 16);
        let mut rng = Rng::new(54);
        let b = rand_spanned(&mut rng, 16, 3, 10);
        assert_eq!(coarse_esc_gemm(&a, &b, 4), 0);
        assert_eq!(exact_esc_gemm(&a, &b), 0);
    }

    #[test]
    fn of_cols_matches_transposed_of_rows() {
        // Satellite pin: the strided column coarsening must produce tables
        // (and hence ESC values) bit-identical to coarsening the
        // materialized transpose, for every block size and shape, zeros
        // included.
        let mut rng = Rng::new(57);
        for (k, n) in [(1usize, 1usize), (7, 3), (48, 5), (65, 9)] {
            let mut b = rand_spanned(&mut rng, k, n, 30);
            for v in b.data.iter_mut() {
                if rng.f64() < 0.2 {
                    *v = 0.0;
                }
            }
            let bt = b.transpose();
            for block in [1usize, 4, 16, 64, 100] {
                let via_cols = CoarseExponents::of_cols(&b, block);
                let via_rows = CoarseExponents::of_rows(&bt, block);
                assert_eq!(via_cols.rows, via_rows.rows);
                assert_eq!(via_cols.nblocks, via_rows.nblocks);
                assert_eq!(via_cols.bmax, via_rows.bmax, "k={k} n={n} block={block}");
                assert_eq!(via_cols.bmin, via_rows.bmin, "k={k} n={n} block={block}");
                assert_eq!(via_cols.row_max, via_rows.row_max, "k={k} n={n} block={block}");
            }
        }
    }

    #[test]
    fn mismatched_blocks_recompute_conservatively() {
        // Satellite regression: coarse_esc_from used to assert_eq! (and
        // kill the service) when tables built under different coarsening
        // blocks met. Now it collapses to the whole-k block — still never
        // below the exact ESC, and never below the matched-block estimate
        // it degrades from.
        let mut rng = Rng::new(58);
        let a = rand_spanned(&mut rng, 6, 80, 25);
        let b = rand_spanned(&mut rng, 80, 6, 25);
        let exact = exact_esc_gemm(&a, &b);
        for (ba, bb) in [(8usize, 16usize), (16, 8), (40, 50), (1, 80)] {
            let ca = CoarseExponents::of_rows(&a, ba);
            let cb = CoarseExponents::of_cols(&b, bb);
            let esc = coarse_esc_from(&ca, &cb);
            assert!(esc >= exact, "blocks ({ba},{bb}): esc {esc} < exact {exact}");
            // the collapse is exactly the whole-k coarsening
            assert_eq!(esc, coarse_esc_gemm(&a, &b, 80), "blocks ({ba},{bb})");
        }
        // same-grid tables still take the fast (uncollapsed) path
        let ca = CoarseExponents::of_rows(&a, 8);
        let cb = CoarseExponents::of_cols(&b, 8);
        assert_eq!(coarse_esc_from(&ca, &cb), coarse_esc_gemm(&a, &b, 8));
    }

    #[test]
    fn prop_coarse_safety() {
        // The paper's §4 safety proof, property-tested across shapes,
        // spans, zero densities and block sizes.
        prop::check("coarse ESC >= exact ESC", 60, |rng| {
            let m = rng.int(1, 10) as usize;
            let k = rng.int(1, 70) as usize;
            let n = rng.int(1, 10) as usize;
            let span = rng.int(0, 60) as i32;
            let zero_frac = rng.f64() * 0.4;
            let mut a = rand_spanned(rng, m, k, span);
            let mut b = rand_spanned(rng, k, n, span);
            for v in a.data.iter_mut() {
                if rng.f64() < zero_frac {
                    *v = 0.0;
                }
            }
            for v in b.data.iter_mut() {
                if rng.f64() < zero_frac {
                    *v = 0.0;
                }
            }
            let exact = exact_esc_gemm(&a, &b);
            let block = rng.int(1, 32) as usize;
            let coarse = coarse_esc_gemm(&a, &b, block);
            prop::assert_that(
                coarse >= exact,
                format!("block {block}: coarse {coarse} < exact {exact}"),
            )
        });
    }

    #[test]
    fn prop_slices_from_esc_recover_accuracy() {
        // End-to-end safety: sizing slices from the coarse ESC always
        // recovers FP64-class accuracy, even on adversarial spans.
        use crate::ozaki::{emulated_gemm, OzakiConfig, SliceEncoding};
        prop::check("ESC-sized slices give FP64 accuracy", 10, |rng| {
            let (m, k, n) = (6, 24, 6);
            let span = rng.int(0, 40) as i32;
            let a = rand_spanned(rng, m, k, span);
            let b = rand_spanned(rng, k, n, span);
            let esc = coarse_esc_gemm(&a, &b, 8);
            let bits = 53 + esc + 1;
            let cfg = OzakiConfig::for_bits(bits, SliceEncoding::Unsigned);
            let c = emulated_gemm(&a, &b, &cfg);
            let c_ref = a.matmul_dd(&b);
            let denom = a.abs().matmul_dd(&b.abs());
            for i in 0..m {
                for j in 0..n {
                    let d = denom.at(i, j);
                    if d == 0.0 {
                        continue;
                    }
                    let e = (c.at(i, j) - c_ref.at(i, j)).abs() / d;
                    if e > (k as f64 + 4.0) * f64::EPSILON {
                        return Err(format!("({i},{j}): err {e}, esc {esc}, s {}", cfg.slices));
                    }
                }
            }
            Ok(())
        });
    }
}
