//! Grouped batched emulation: slice-operand caching + one fused schedule.
//!
//! The service path of the paper (§5.4/§8.2) sees *streams* of GEMMs, and
//! in practice many of them share an operand (the same A against many
//! partners — QR trailing updates, attention-style batches) or at least a
//! shape. The decomposition/recomposition stages dominate once the integer
//! GEMM is fast (Uchino & Ozaki 2024), so re-slicing a shared operand per
//! request throws away the cheapest available throughput win (Mukunoki
//! 2025 amortizes exactly these stages across batched multiplies).
//!
//! Two pieces implement that amortization here:
//!
//! * [`SliceCache`] — a ref-counted cache of finished decompositions,
//!   keyed by (role, slice count, encoding, shape, content fingerprint).
//!   Entries are `Arc<SlicedMatrix>`: eviction drops the cache's
//!   reference while in-flight GEMMs keep theirs. Initialization is
//!   exactly-once per resident key (a per-entry `OnceLock`), so N
//!   concurrent requests sharing an operand cost one decomposition.
//! * [`gemm_grouped`] — runs a group of problems through the level
//!   pipeline in lockstep rounds: round `r` executes weight level
//!   `q = s-1-r` of every problem that still has one, handing *all* of
//!   the round's level batches to the backend as one schedule
//!   ([`ComputeBackend::slice_pair_gemm_batches`]). Per problem the level
//!   order, the i64 accumulations and the compensated recomposition are
//!   exactly those of [`super::gemm::emulated_gemm_on`], so the grouped
//!   result is **bitwise identical** to the per-request path — the
//!   serial/parallel identity property extends to groups. The round
//!   batches execute on the runtime-dispatched
//!   [`ozaki::kernel`](super::kernel) microkernels (via
//!   `slice_pair_gemm_tile`), so grouped traffic gets the SIMD path —
//!   and, being exact integer work, stays bitwise identical under it.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use super::crt::{crt_gemm_on, CrtBasis, CrtConfig};
use super::recompose::{add_level_into, recompose_slices};
use super::schedule::PairSchedule;
use super::scheme::SchemeKind;
use super::slicing::{crt_slice_a, crt_slice_b, slice_a, slice_b, SlicedMatrix};
use super::{OzakiConfig, SliceEncoding};
use crate::backend::{ComputeBackend, SliceBatch, WorkspaceGuard, WorkspacePool};
use crate::linalg::Matrix;
use crate::util::sync as psync;

/// Which operand role a cached decomposition was built for. A-slicing
/// stores row-major A, B-slicing stores B transposed — the two are not
/// interchangeable even for the same underlying matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OperandRole {
    A,
    B,
}

/// Identity of one cached decomposition. Slice-pair digit planes and CRT
/// residue planes are never interchangeable, so the key carries the
/// scheme family (and for CRT the basis length — a wider basis means
/// more residue planes for the same `s_eq` window).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
struct SliceKey {
    role: OperandRole,
    scheme: SchemeKind,
    slices: usize,
    encoding: SliceEncoding,
    /// CRT basis length; 0 for slice-pair entries.
    moduli: usize,
    rows: usize,
    cols: usize,
    fingerprint: (u64, u64),
}

/// One cache entry: exactly-once initialization so concurrent callers
/// sharing an operand never decompose it twice (losers block briefly on
/// the winner instead).
struct CacheCell(OnceLock<Arc<SlicedMatrix>>);

struct CacheInner {
    map: HashMap<SliceKey, Arc<CacheCell>>,
    /// LRU order, most recently used last.
    order: Vec<SliceKey>,
}

/// Ref-counted sliced-operand cache (see module docs). Thread-safe;
/// share one per service via `Arc`.
pub struct SliceCache {
    inner: Mutex<CacheInner>,
    capacity: usize,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl SliceCache {
    /// `capacity` is the max number of *resident* decompositions (>= 1);
    /// in-flight users of evicted entries keep them alive via `Arc`.
    pub fn new(capacity: usize) -> SliceCache {
        SliceCache {
            inner: Mutex::new(CacheInner { map: HashMap::new(), order: Vec::new() }),
            capacity: capacity.max(1),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Acquire (or insert) the cell for `key`, applying the LRU policy.
    /// Returns the cell and whether it was already resident.
    fn cell_for(&self, key: SliceKey) -> (Arc<CacheCell>, bool) {
        let mut g = psync::lock(&self.inner);
        if let Some(c) = g.map.get(&key) {
            let c = c.clone();
            // LRU bump: move to the back of the order list.
            if let Some(pos) = g.order.iter().position(|k| k == &key) {
                let k = g.order.remove(pos);
                g.order.push(k);
            }
            (c, true)
        } else {
            let c = Arc::new(CacheCell(OnceLock::new()));
            g.map.insert(key.clone(), c.clone());
            g.order.push(key);
            while g.map.len() > self.capacity {
                let victim = g.order.remove(0);
                g.map.remove(&victim);
            }
            (c, false)
        }
    }

    fn count(&self, hit: bool) {
        if hit {
            self.hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.misses.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Fetch (or compute, exactly once per resident key) the slice-pair
    /// decomposition of `m` in `role` under `cfg`. Returns the shared
    /// decomposition and whether this call was a cache hit (i.e. did
    /// *not* decompose).
    pub fn get_or_slice(
        &self,
        role: OperandRole,
        m: &Matrix,
        cfg: &OzakiConfig,
    ) -> (Arc<SlicedMatrix>, bool) {
        let key = SliceKey {
            role,
            scheme: SchemeKind::SlicePair,
            slices: cfg.slices,
            encoding: cfg.encoding,
            moduli: 0,
            rows: m.rows,
            cols: m.cols,
            fingerprint: m.fingerprint(),
        };
        let (cell, hit) = self.cell_for(key);
        // Decompose outside the cache lock; OnceLock serializes per entry.
        let sl = cell
            .0
            .get_or_init(|| {
                Arc::new(match role {
                    OperandRole::A => slice_a(m, cfg.slices, cfg.encoding),
                    OperandRole::B => slice_b(m, cfg.slices, cfg.encoding),
                })
            })
            .clone();
        self.count(hit);
        (sl, hit)
    }

    /// CRT twin of [`SliceCache::get_or_slice`]: fetch (or compute,
    /// exactly once) the residue-plane decomposition of `m` under `cfg`.
    /// CRT planes always ride the unsigned 8-bit window, so the key's
    /// encoding is fixed and the basis length disambiguates.
    pub fn get_or_slice_crt(
        &self,
        role: OperandRole,
        m: &Matrix,
        cfg: &CrtConfig,
    ) -> (Arc<SlicedMatrix>, bool) {
        let key = SliceKey {
            role,
            scheme: SchemeKind::Crt,
            slices: cfg.s_eq,
            encoding: SliceEncoding::Unsigned,
            moduli: cfg.moduli,
            rows: m.rows,
            cols: m.cols,
            fingerprint: m.fingerprint(),
        };
        let (cell, hit) = self.cell_for(key);
        let sl = cell
            .0
            .get_or_init(|| {
                let basis = CrtBasis::for_config(cfg);
                Arc::new(match role {
                    OperandRole::A => crt_slice_a(m, cfg.s_eq, &basis),
                    OperandRole::B => crt_slice_b(m, cfg.s_eq, &basis),
                })
            })
            .clone();
        self.count(hit);
        (sl, hit)
    }

    /// Lifetime (hits, misses). Misses count decompositions performed.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    /// Resident entries.
    pub fn len(&self) -> usize {
        psync::lock(&self.inner).map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every resident entry (in-flight `Arc`s stay valid).
    pub fn clear(&self) {
        let mut g = psync::lock(&self.inner);
        g.map.clear();
        g.order.clear();
    }
}

impl Default for SliceCache {
    /// Default sized for a service worker set: a few dozen resident
    /// operands (each up to s * m * k bytes).
    fn default() -> SliceCache {
        SliceCache::new(32)
    }
}

/// One problem of a grouped GEMM. `cfg` may differ per problem (ESC sizes
/// slices per request even inside one shape bucket), and so may the
/// scheme family the coordinator picked for it.
pub struct GroupedProblem<'a> {
    pub a: &'a Matrix,
    pub b: &'a Matrix,
    pub cfg: OzakiConfig,
    /// Family to run this problem under. [`SchemeKind::Crt`] problems
    /// use `cfg` only for its window (`cfg.slices`/`cfg.encoding` fix
    /// the equivalent CRT config) and `cfg.k_chunk`; if the window does
    /// not fit the modulus basis they fall back to slice pairs.
    pub scheme: SchemeKind,
}

/// Slicing-amortization accounting of one [`gemm_grouped`] call.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct GroupStats {
    /// Cache hits: operand decompositions *reused* instead of recomputed.
    pub slice_cache_hits: u64,
    /// Cache misses: decompositions actually performed by this call.
    pub slice_cache_misses: u64,
    /// Problems routed through the chunked large-k per-request path
    /// (per-chunk decompositions are not cacheable across requests).
    pub chunked_bypass: u64,
    /// Problems executed by the Ozaki-II/CRT family (cached residues or
    /// chunked bypass; the rest ran slice-pair rounds).
    pub crt_routed: u64,
}

/// In-flight state of one problem between lockstep rounds. The level
/// buffer and compensated hi/lo accumulator live in a pooled workspace
/// checked out for the duration of the call, so a warm pool makes the
/// whole group allocation-free apart from the result matrices.
struct Active<'p> {
    idx: usize,
    asl: Arc<SlicedMatrix>,
    bsl: Arc<SlicedMatrix>,
    /// Kept levels of this problem's (possibly tier-truncated) schedule.
    levels: usize,
    schedule: Arc<PairSchedule>,
    ws: WorkspaceGuard<'p>,
    m: usize,
    n: usize,
}

/// Grouped batched emulated DGEMM (see module docs). Results are bitwise
/// identical to calling [`super::gemm::emulated_gemm_on`] (equivalently,
/// the fused engine [`super::gemm::fused_gemm_on`]) per problem with the
/// same configs, for any backend, cache or workspace-pool state.
pub fn gemm_grouped(
    problems: &[GroupedProblem<'_>],
    cache: &SliceCache,
    backend: &dyn ComputeBackend,
    workspaces: &WorkspacePool,
) -> (Vec<Matrix>, GroupStats) {
    let mut stats = GroupStats::default();
    let mut out: Vec<Option<Matrix>> = (0..problems.len()).map(|_| None).collect();
    let mut active: Vec<Active<'_>> = Vec::new();

    for (idx, p) in problems.iter().enumerate() {
        assert_eq!(p.a.cols, p.b.rows, "gemm shape mismatch");
        let (m, k, n) = (p.a.rows, p.a.cols, p.b.cols);
        if m == 0 || k == 0 || n == 0 {
            out[idx] = Some(Matrix::zeros(m, n));
            continue;
        }
        if p.scheme == SchemeKind::Crt {
            // CRT problems don't join the lockstep level rounds — the
            // family has no per-level structure to interleave (one GEMM
            // per modulus, folded independently). They still amortize
            // the expensive stage: residue decompositions go through
            // the same cache, and the modulus loop runs on the
            // backend's parallel tile engine. The config derivation
            // mirrors the coordinator's standalone path (same window =>
            // same basis), so results stay bitwise identical to
            // `crt_gemm_on` per problem.
            let s_eq = p.cfg.crt_window();
            if let Some(ccfg) =
                CrtConfig::for_window(s_eq, k).map(|c| c.with_k_chunk(p.cfg.k_chunk()))
            {
                stats.crt_routed += 1;
                if k > ccfg.k_chunk() {
                    out[idx] = Some(crt_gemm_on(p.a, p.b, &ccfg, backend, workspaces));
                    stats.chunked_bypass += 1;
                } else {
                    let (asl, hit_a) = cache.get_or_slice_crt(OperandRole::A, p.a, &ccfg);
                    let (bsl, hit_b) = cache.get_or_slice_crt(OperandRole::B, p.b, &ccfg);
                    stats.slice_cache_hits += hit_a as u64 + hit_b as u64;
                    stats.slice_cache_misses += (!hit_a) as u64 + (!hit_b) as u64;
                    let basis = CrtBasis::for_config(&ccfg);
                    let mut c = Matrix::zeros(m, n);
                    backend.crt_tile_gemm(asl.as_ref(), bsl.as_ref(), &basis, workspaces, &mut c);
                    out[idx] = Some(c);
                }
                continue;
            }
            // Window exceeds the modulus basis: run the problem as
            // slice pairs below (same accuracy, more launches).
        }
        if k > p.cfg.k_chunk() {
            // Rare large-k path: bitwise identical to the per-request
            // pipeline by construction (it *is* the per-request fused
            // pipeline, which matches the level-major reference).
            out[idx] = Some(super::gemm::fused_gemm_on(p.a, p.b, &p.cfg, backend, workspaces));
            stats.chunked_bypass += 1;
            continue;
        }
        let (asl, hit_a) = cache.get_or_slice(OperandRole::A, p.a, &p.cfg);
        let (bsl, hit_b) = cache.get_or_slice(OperandRole::B, p.b, &p.cfg);
        stats.slice_cache_hits += hit_a as u64 + hit_b as u64;
        stats.slice_cache_misses += (!hit_a) as u64 + (!hit_b) as u64;
        let mut ws = workspaces.checkout(m * n);
        ws.hi[..m * n].fill(0.0);
        ws.lo[..m * n].fill(0.0);
        let schedule = PairSchedule::for_config(&p.cfg);
        active.push(Active { idx, asl, bsl, levels: schedule.level_count(), schedule, ws, m, n });
    }

    // The round batches run level-major on the runtime-dispatched
    // microkernel — stamp the dispatch gauge so metrics report grouped
    // traffic's executed kernel too (no tile geometry on this path).
    if let Some(act) = active.first() {
        workspaces.record_dispatch(super::kernel::active_id(act.asl.encoding), None);
    }

    // Lockstep rounds: round r runs weight level q = s-1-depth-r of
    // every problem that still has one, as ONE backend schedule (tier-
    // truncated problems simply have fewer levels and drop out of the
    // rounds early). Levels feed each problem's compensated accumulator
    // strictly in the per-request order (schedule order); the i64 level
    // products are exact, so the cross-problem schedule cannot change a
    // bit.
    let rounds = active.iter().map(|a| a.levels).max().unwrap_or(0);
    for r in 0..rounds {
        let mut batches: Vec<SliceBatch<'_>> = Vec::new();
        for act in active.iter_mut() {
            if r < act.levels {
                let e = act.m * act.n;
                let ws = &mut *act.ws;
                ws.pbuf[..e].fill(0);
                batches.push(SliceBatch {
                    a: act.asl.as_ref(),
                    b: act.bsl.as_ref(),
                    pairs: act.schedule.level(r).0,
                    out: &mut ws.pbuf[..e],
                });
            }
        }
        backend.slice_pair_gemm_batches(&mut batches);
        drop(batches);
        for act in active.iter_mut() {
            if r < act.levels {
                let e = act.m * act.n;
                let (_, w) = act.schedule.level(r);
                let ws = &mut *act.ws;
                add_level_into(&mut ws.hi[..e], &mut ws.lo[..e], &ws.pbuf[..e], w);
            }
        }
    }

    for mut act in active {
        let e = act.m * act.n;
        let (m, n) = (act.m, act.n);
        let ws = &mut *act.ws;
        let c = recompose_slices(
            &mut ws.hi[..e],
            &mut ws.lo[..e],
            &act.asl.sigma,
            &act.bsl.sigma,
            m,
            n,
        );
        out[act.idx] = Some(c);
    }
    (out.into_iter().map(|c| c.expect("every problem produced")).collect(), stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{ParallelBackend, SerialBackend};
    use crate::ozaki::emulated_gemm_on;
    use crate::util::{prop, Rng};

    fn assert_bitwise(c1: &Matrix, c2: &Matrix, what: &str) {
        assert_eq!((c1.rows, c1.cols), (c2.rows, c2.cols), "{what}: shape");
        for (x, y) in c1.data.iter().zip(&c2.data) {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: {x} vs {y}");
        }
    }

    #[test]
    fn shared_a_decomposed_once() {
        let mut rng = Rng::new(700);
        let a = Matrix::uniform(12, 20, -2.0, 2.0, &mut rng);
        let bs: Vec<Matrix> = (0..4).map(|_| Matrix::uniform(20, 9, -2.0, 2.0, &mut rng)).collect();
        let cfg = OzakiConfig::new(7);
        let probs: Vec<GroupedProblem<'_>> =
            bs.iter()
                .map(|b| GroupedProblem { a: &a, b, cfg, scheme: SchemeKind::SlicePair })
                .collect();
        let cache = SliceCache::new(32);
        let pool = WorkspacePool::new();
        let (cs, st) = gemm_grouped(&probs, &cache, &SerialBackend, &pool);
        // A: 1 miss + 3 hits; B: 4 distinct misses.
        assert_eq!(st.slice_cache_misses, 5, "{st:?}");
        assert_eq!(st.slice_cache_hits, 3, "{st:?}");
        for (c, b) in cs.iter().zip(&bs) {
            assert_bitwise(c, &emulated_gemm_on(&a, b, &cfg, &SerialBackend), "shared-A group");
        }
        // Replaying the same group is all hits, and the warm workspace
        // pool serves it without a single fresh allocation.
        let fresh_after_first = pool.stats().fresh_allocs;
        let (_, st2) = gemm_grouped(&probs, &cache, &SerialBackend, &pool);
        assert_eq!(st2.slice_cache_misses, 0);
        assert_eq!(st2.slice_cache_hits, 8);
        let ws = pool.stats();
        assert_eq!(ws.fresh_allocs, fresh_after_first, "warm pool must not allocate");
        assert_eq!(ws.checkouts, 8, "one workspace checkout per problem per call");
    }

    #[test]
    fn cache_keys_distinguish_role_config_and_content() {
        let mut rng = Rng::new(701);
        let sq = Matrix::uniform(10, 10, -1.0, 1.0, &mut rng);
        let cache = SliceCache::new(32);
        let c7 = OzakiConfig::new(7);
        // Same matrix as A and as B: two decompositions (B is transposed).
        assert!(!cache.get_or_slice(OperandRole::A, &sq, &c7).1);
        assert!(!cache.get_or_slice(OperandRole::B, &sq, &c7).1);
        // Same role, different slice count / encoding: new entries.
        assert!(!cache.get_or_slice(OperandRole::A, &sq, &OzakiConfig::new(5)).1);
        assert!(!cache
            .get_or_slice(OperandRole::A, &sq, &OzakiConfig::with_encoding(7, SliceEncoding::Signed))
            .1);
        // Content change (a single flipped sign bit): new entry.
        let mut sq2 = sq.clone();
        let flipped = -sq2.at(0, 0);
        *sq2.at_mut(0, 0) = flipped;
        assert!(!cache.get_or_slice(OperandRole::A, &sq2, &c7).1);
        // Replays all hit.
        assert!(cache.get_or_slice(OperandRole::A, &sq, &c7).1);
        assert_eq!(cache.stats(), (1, 5));
    }

    #[test]
    fn lru_eviction_bounds_residency() {
        let mut rng = Rng::new(702);
        let cache = SliceCache::new(2);
        let cfg = OzakiConfig::new(4);
        let ms: Vec<Matrix> = (0..3).map(|_| Matrix::uniform(6, 6, -1.0, 1.0, &mut rng)).collect();
        for m in &ms {
            cache.get_or_slice(OperandRole::A, m, &cfg);
        }
        assert_eq!(cache.len(), 2);
        // ms[0] was evicted (LRU): re-fetch is a miss; ms[2] still hits.
        assert!(cache.get_or_slice(OperandRole::A, &ms[2], &cfg).1);
        assert!(!cache.get_or_slice(OperandRole::A, &ms[0], &cfg).1);
        cache.clear();
        assert!(cache.is_empty());
    }

    #[test]
    fn empty_and_degenerate_problems() {
        let cache = SliceCache::default();
        let cfg = OzakiConfig::new(7);
        let a = Matrix::zeros(0, 3);
        let b = Matrix::zeros(3, 2);
        let a2 = Matrix::zeros(2, 0);
        let b2 = Matrix::zeros(0, 2);
        let probs = vec![
            GroupedProblem { a: &a, b: &b, cfg, scheme: SchemeKind::SlicePair },
            GroupedProblem { a: &a2, b: &b2, cfg, scheme: SchemeKind::Crt },
        ];
        let pool = WorkspacePool::new();
        let (cs, st) = gemm_grouped(&probs, &cache, &SerialBackend, &pool);
        assert_eq!((cs[0].rows, cs[0].cols), (0, 2));
        assert_eq!((cs[1].rows, cs[1].cols), (2, 2));
        assert!(cs[1].data.iter().all(|&x| x == 0.0));
        assert_eq!(st.slice_cache_misses, 0, "degenerate problems skip the cache");
        assert_eq!(pool.stats().checkouts, 0, "degenerate problems skip the pool");
        assert_eq!(gemm_grouped(&[], &cache, &SerialBackend, &pool).0.len(), 0);
    }

    #[test]
    fn crt_grouped_amortizes_and_matches_the_standalone_path() {
        let mut rng = Rng::new(703);
        let a = Matrix::uniform(10, 18, -2.0, 2.0, &mut rng);
        let bs: Vec<Matrix> =
            (0..3).map(|_| Matrix::uniform(18, 8, -2.0, 2.0, &mut rng)).collect();
        let cfg = OzakiConfig::new(7);
        let probs: Vec<GroupedProblem<'_>> = bs
            .iter()
            .map(|b| GroupedProblem { a: &a, b, cfg, scheme: SchemeKind::Crt })
            .collect();
        let cache = SliceCache::new(32);
        let pool = WorkspacePool::new();
        let (cs, st) = gemm_grouped(&probs, &cache, &SerialBackend, &pool);
        // A's residues: 1 miss + 2 hits; B residues: 3 distinct misses.
        assert_eq!(st.slice_cache_misses, 4, "{st:?}");
        assert_eq!(st.slice_cache_hits, 2, "{st:?}");
        assert_eq!(st.crt_routed, 3, "{st:?}");
        assert_eq!(st.chunked_bypass, 0, "{st:?}");
        let ccfg = CrtConfig::for_window(7, a.cols).unwrap();
        for (c, b) in cs.iter().zip(&bs) {
            assert_bitwise(c, &crate::ozaki::crt_gemm(&a, b, &ccfg), "grouped CRT");
        }
        // CRT and slice-pair entries of the same operand don't collide:
        // re-running the group as slice pairs misses on every operand.
        let probs_sp: Vec<GroupedProblem<'_>> = bs
            .iter()
            .map(|b| GroupedProblem { a: &a, b, cfg, scheme: SchemeKind::SlicePair })
            .collect();
        let (cs_sp, st_sp) = gemm_grouped(&probs_sp, &cache, &SerialBackend, &pool);
        assert_eq!(st_sp.slice_cache_misses, 4, "{st_sp:?}");
        assert_eq!(st_sp.crt_routed, 0, "{st_sp:?}");
        for (c, b) in cs_sp.iter().zip(&bs) {
            assert_bitwise(c, &emulated_gemm_on(&a, b, &cfg, &SerialBackend), "sp after crt");
        }
    }

    #[test]
    fn mixed_tier_groups_stay_isolated() {
        // Problems at different accuracy tiers share one group (and the
        // tier-independent slice cache) without contaminating each
        // other: every result is bitwise the per-request result at its
        // own tier.
        use crate::ozaki::AccuracyTier;
        let mut rng = Rng::new(704);
        let a = Matrix::uniform(9, 14, -2.0, 2.0, &mut rng);
        let b = Matrix::uniform(14, 7, -2.0, 2.0, &mut rng);
        let cfgs = [
            OzakiConfig::new(7),
            OzakiConfig::new(7).with_tier(AccuracyTier::Fp64FaithfulFast),
            OzakiConfig::new(7).with_tier(AccuracyTier::Fp32Grade),
        ];
        let probs: Vec<GroupedProblem<'_>> = cfgs
            .iter()
            .map(|cfg| GroupedProblem { a: &a, b: &b, cfg: *cfg, scheme: SchemeKind::SlicePair })
            .collect();
        let cache = SliceCache::new(8);
        let pool = WorkspacePool::new();
        let (cs, st) = gemm_grouped(&probs, &cache, &SerialBackend, &pool);
        // Slicing is tier-independent: one A + one B decomposition
        // serves all three tiers.
        assert_eq!(st.slice_cache_misses, 2, "{st:?}");
        assert_eq!(st.slice_cache_hits, 4, "{st:?}");
        for (cfg, c) in cfgs.iter().zip(&cs) {
            assert_bitwise(c, &emulated_gemm_on(&a, &b, cfg, &SerialBackend), "mixed-tier group");
        }
        // And the tiers really differ: truncation must change low bits.
        assert!(cs[0].data.iter().zip(&cs[1].data).any(|(x, y)| x.to_bits() != y.to_bits()));
    }

    #[test]
    fn prop_grouped_bitwise_identical_to_sequential() {
        // The tentpole property: gemm_grouped (cache hits included, serial
        // AND parallel backends, mixed configs per group) is bitwise
        // identical to the per-request pipeline.
        let par = ParallelBackend::new(4).with_cutoff_ops(0);
        let cache = SliceCache::new(16); // small: exercises eviction across cases
        let pool = WorkspacePool::new();
        prop::check("grouped == sequential (bitwise)", 10, |rng| {
            let nprobs = rng.int(1, 6) as usize;
            let shared_a = rng.f64() < 0.5;
            let k = rng.int(1, 40) as usize;
            let a0 = Matrix::uniform(rng.int(1, 16) as usize, k, -3.0, 3.0, rng);
            let mut mats: Vec<(Matrix, Matrix, OzakiConfig)> = Vec::new();
            for _ in 0..nprobs {
                let a = if shared_a {
                    a0.clone()
                } else {
                    Matrix::uniform(rng.int(1, 16) as usize, k, -3.0, 3.0, rng)
                };
                let b = Matrix::uniform(k, rng.int(1, 16) as usize, -3.0, 3.0, rng);
                let enc = if rng.f64() < 0.5 { SliceEncoding::Unsigned } else { SliceEncoding::Signed };
                let mut cfg = OzakiConfig::with_encoding(rng.int(2, 8) as usize, enc);
                if rng.f64() < 0.3 {
                    // chunked-k config: forces the per-request bypass
                    cfg = cfg.with_k_chunk(rng.int(1, k as i64).max(1) as usize);
                }
                mats.push((a, b, cfg));
            }
            let probs: Vec<GroupedProblem<'_>> =
                mats.iter()
                    .map(|(a, b, cfg)| GroupedProblem {
                        a,
                        b,
                        cfg: *cfg,
                        scheme: SchemeKind::SlicePair,
                    })
                    .collect();
            for backend in [&SerialBackend as &dyn ComputeBackend, &par] {
                let (cs, _) = gemm_grouped(&probs, &cache, backend, &pool);
                for ((a, b, cfg), c) in mats.iter().zip(&cs) {
                    let c_ref = emulated_gemm_on(a, b, cfg, backend);
                    for (x, y) in c.data.iter().zip(&c_ref.data) {
                        if x.to_bits() != y.to_bits() {
                            return Err(format!(
                                "grouped != sequential on {}: {x} vs {y}",
                                backend.name()
                            ));
                        }
                    }
                }
            }
            Ok(())
        });
    }
}
