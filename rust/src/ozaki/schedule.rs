//! The precomputed slice-pair schedule of one Ozaki-I configuration.
//!
//! Both emulated-GEMM drivers walk the same triangular pair set: every
//! `(t, u)` with `t + u <= s - 1`, grouped by weight level `q = t + u`
//! and accumulated **smallest weight first** (`q = s-1` down to `0`) into
//! the compensated accumulator. The level-major reference used to rebuild
//! each level's `Vec<(t, u)>` on the fly — `s` heap allocations per GEMM,
//! per request. [`PairSchedule`] hoists that: the pairs are laid out once
//! in a flat arena with per-level ranges and weight exponents, and a
//! process-wide cache ([`PairSchedule::get`]) shares one `Arc` per
//! `(slices, radix_bits)` configuration, so steady-state requests touch
//! no allocator at all. The schedule is shared verbatim by the
//! level-major reference path, the fused tile engine, and the grouped
//! lockstep pipeline — one source of truth for pair order and weights.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use super::OzakiConfig;

/// One weight level: a range into the flat pair arena plus its exponent.
struct Level {
    start: usize,
    end: usize,
    weight: i32,
}

/// Immutable pair schedule of an `(s, radix_bits)` configuration (see
/// module docs). Levels are stored in accumulation order: index `r`
/// holds level `q = s - 1 - r`, so iterating `0..s` feeds the
/// compensated accumulator smallest weight first — exactly the
/// level-major reference order.
pub struct PairSchedule {
    s: usize,
    rb: i32,
    pairs: Vec<(usize, usize)>,
    levels: Vec<Level>,
}

static SCHEDULE_CACHE: OnceLock<Mutex<HashMap<(usize, i32), Arc<PairSchedule>>>> = OnceLock::new();

impl PairSchedule {
    /// Build the schedule for `s` slices at `rb` radix bits.
    pub fn new(s: usize, rb: i32) -> PairSchedule {
        assert!(s >= 1, "slice count must be >= 1");
        let mut pairs = Vec::with_capacity(s * (s + 1) / 2);
        let mut levels = Vec::with_capacity(s);
        for q in (0..s).rev() {
            let start = pairs.len();
            pairs.extend((0..=q).map(|t| (t, q - t)));
            let weight = 2 * rb * (s as i32 - 1) - rb * q as i32;
            levels.push(Level { start, end: pairs.len(), weight });
        }
        PairSchedule { s, rb, pairs, levels }
    }

    /// The process-wide shared schedule for `(s, rb)`; computed once per
    /// configuration (the key space is tiny: `s <= max_slices`, `rb` in
    /// {7, 8}), then served allocation-free.
    pub fn get(s: usize, rb: i32) -> Arc<PairSchedule> {
        let cache = SCHEDULE_CACHE.get_or_init(|| Mutex::new(HashMap::new()));
        let mut g = cache.lock().unwrap();
        g.entry((s, rb)).or_insert_with(|| Arc::new(PairSchedule::new(s, rb))).clone()
    }

    /// Shared schedule of an [`OzakiConfig`].
    pub fn for_config(cfg: &OzakiConfig) -> Arc<PairSchedule> {
        PairSchedule::get(cfg.slices, cfg.encoding.radix_bits())
    }

    /// Slice count `s` (also the number of levels).
    pub fn slices(&self) -> usize {
        self.s
    }

    pub fn radix_bits(&self) -> i32 {
        self.rb
    }

    /// Total `(t, u)` pairs: `s(s+1)/2`.
    pub fn pair_count(&self) -> usize {
        self.pairs.len()
    }

    /// Level `r` in accumulation order (`r = 0` is `q = s-1`, the
    /// smallest weight): its pairs and weight exponent.
    pub fn level(&self, r: usize) -> (&[(usize, usize)], i32) {
        let l = &self.levels[r];
        (&self.pairs[l.start..l.end], l.weight)
    }

    /// All levels in accumulation order.
    pub fn levels(&self) -> impl Iterator<Item = (&[(usize, usize)], i32)> + '_ {
        self.levels.iter().map(move |l| (&self.pairs[l.start..l.end], l.weight))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_the_reference_enumeration() {
        // The level-major reference: q = s-1 down to 0, pairs (t, q-t) for
        // t = 0..=q, weight 2*rb*(s-1) - rb*q.
        for (s, rb) in [(1usize, 8i32), (4, 8), (7, 8), (8, 7)] {
            let sched = PairSchedule::new(s, rb);
            assert_eq!(sched.slices(), s);
            assert_eq!(sched.radix_bits(), rb);
            assert_eq!(sched.pair_count(), s * (s + 1) / 2);
            let mut seen = 0;
            for (r, (pairs, w)) in sched.levels().enumerate() {
                let q = s - 1 - r;
                let expect: Vec<(usize, usize)> = (0..=q).map(|t| (t, q - t)).collect();
                assert_eq!(pairs, expect.as_slice(), "s={s} rb={rb} q={q}");
                assert_eq!(w, 2 * rb * (s as i32 - 1) - rb * q as i32);
                assert_eq!(sched.level(r).0, expect.as_slice());
                assert_eq!(sched.level(r).1, w);
                seen += pairs.len();
            }
            assert_eq!(seen, sched.pair_count(), "levels partition the pair set");
        }
    }

    #[test]
    fn weights_increase_along_accumulation_order() {
        // Smallest-weight-first is what keeps the compensated sum's
        // per-element order identical to python/compile/ozaki.py.
        let sched = PairSchedule::new(7, 8);
        let ws: Vec<i32> = sched.levels().map(|(_, w)| w).collect();
        for pair in ws.windows(2) {
            assert!(pair[0] < pair[1], "weights must ascend: {ws:?}");
        }
    }

    #[test]
    fn global_cache_shares_one_arc_per_config() {
        let a = PairSchedule::get(5, 8);
        let b = PairSchedule::get(5, 8);
        assert!(Arc::ptr_eq(&a, &b), "same config must share one schedule");
        let c = PairSchedule::get(5, 7);
        assert!(!Arc::ptr_eq(&a, &c), "different radix is a different schedule");
        let d = PairSchedule::for_config(&OzakiConfig::new(5));
        assert!(Arc::ptr_eq(&a, &d), "for_config resolves through the same cache");
    }
}
