//! The precomputed slice-pair schedule of one Ozaki-I configuration.
//!
//! Both emulated-GEMM drivers walk the same triangular pair set: every
//! `(t, u)` with `t + u <= s - 1`, grouped by weight level `q = t + u`
//! and accumulated **smallest weight first** (`q = s-1` down to `0`) into
//! the compensated accumulator. The level-major reference used to rebuild
//! each level's `Vec<(t, u)>` on the fly — `s` heap allocations per GEMM,
//! per request. [`PairSchedule`] hoists that: the pairs are laid out once
//! in a flat arena with per-level ranges and weight exponents, and a
//! process-wide cache ([`PairSchedule::get`]) shares one `Arc` per
//! `(slices, radix_bits)` configuration, so steady-state requests touch
//! no allocator at all. The schedule is shared verbatim by the
//! level-major reference path, the fused tile engine, and the grouped
//! lockstep pipeline — one source of truth for pair order and weights.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use super::OzakiConfig;
use crate::util::sync as psync;

/// One weight level: a range into the flat pair arena plus its exponent.
struct Level {
    start: usize,
    end: usize,
    weight: i32,
}

/// Immutable pair schedule of an `(s, radix_bits)` configuration (see
/// module docs). Levels are stored in accumulation order: index `r`
/// holds level `q = s - 1 - r`, so iterating `0..s` feeds the
/// compensated accumulator smallest weight first — exactly the
/// level-major reference order.
pub struct PairSchedule {
    s: usize,
    rb: i32,
    depth: usize,
    pairs: Vec<(usize, usize)>,
    levels: Vec<Level>,
}

static SCHEDULE_CACHE: OnceLock<Mutex<HashMap<(usize, i32, usize), Arc<PairSchedule>>>> =
    OnceLock::new();

impl PairSchedule {
    /// Build the full triangular schedule for `s` slices at `rb` radix
    /// bits (truncation depth 0).
    pub fn new(s: usize, rb: i32) -> PairSchedule {
        PairSchedule::new_truncated(s, rb, 0)
    }

    /// Build the schedule for `s` slices at `rb` radix bits with the
    /// `depth` smallest-weight levels dropped: fast-mode truncation skips
    /// every pair `(t, u)` with `t + u >= s - depth` (arXiv 2409.13313).
    /// The kept levels retain exactly the weights and pair order of the
    /// full schedule, so the compensated accumulation of what remains is
    /// bitwise identical to the full path's prefix; `depth = 0` is the
    /// full Ozaki-I triangular schedule.
    pub fn new_truncated(s: usize, rb: i32, depth: usize) -> PairSchedule {
        assert!(s >= 1, "slice count must be >= 1");
        assert!(depth < s, "truncation must keep at least one level");
        let keep = s - depth;
        let mut pairs = Vec::with_capacity(keep * (keep + 1) / 2);
        let mut levels = Vec::with_capacity(keep);
        for q in (0..keep).rev() {
            let start = pairs.len();
            pairs.extend((0..=q).map(|t| (t, q - t)));
            let weight = 2 * rb * (s as i32 - 1) - rb * q as i32;
            levels.push(Level { start, end: pairs.len(), weight });
        }
        PairSchedule { s, rb, depth, pairs, levels }
    }

    /// The process-wide shared full schedule for `(s, rb)`; computed once
    /// per configuration (the key space is tiny: `s <= max_slices`, `rb`
    /// in {7, 8}), then served allocation-free.
    pub fn get(s: usize, rb: i32) -> Arc<PairSchedule> {
        PairSchedule::get_truncated(s, rb, 0)
    }

    /// The process-wide shared schedule for `(s, rb)` truncated by
    /// `depth` levels; `depth = 0` resolves to the same `Arc` as
    /// [`PairSchedule::get`], so guaranteed-tier traffic keeps sharing
    /// today's entries.
    pub fn get_truncated(s: usize, rb: i32, depth: usize) -> Arc<PairSchedule> {
        let cache = SCHEDULE_CACHE.get_or_init(|| Mutex::new(HashMap::new()));
        let mut g = psync::lock(cache);
        g.entry((s, rb, depth))
            .or_insert_with(|| Arc::new(PairSchedule::new_truncated(s, rb, depth)))
            .clone()
    }

    /// Shared schedule of an [`OzakiConfig`], honoring its accuracy
    /// tier's truncation depth.
    pub fn for_config(cfg: &OzakiConfig) -> Arc<PairSchedule> {
        PairSchedule::get_truncated(
            cfg.slices,
            cfg.encoding.radix_bits(),
            cfg.truncation_depth(),
        )
    }

    /// Slice count `s` of the decomposition this schedule walks (the
    /// number of levels only when untruncated; see
    /// [`PairSchedule::level_count`]).
    pub fn slices(&self) -> usize {
        self.s
    }

    /// How many smallest-weight levels were dropped (0 = full schedule).
    pub fn truncation_depth(&self) -> usize {
        self.depth
    }

    /// Number of kept levels: `s - depth`.
    pub fn level_count(&self) -> usize {
        self.levels.len()
    }

    /// Pairs the full (untruncated) schedule would run: `s(s+1)/2`.
    pub fn full_pair_count(&self) -> usize {
        self.s * (self.s + 1) / 2
    }

    /// Pairs skipped by truncation relative to the full schedule.
    pub fn skipped_pair_count(&self) -> usize {
        self.full_pair_count() - self.pairs.len()
    }

    pub fn radix_bits(&self) -> i32 {
        self.rb
    }

    /// Kept `(t, u)` pairs: `(s-depth)(s-depth+1)/2` (`s(s+1)/2` when
    /// untruncated).
    pub fn pair_count(&self) -> usize {
        self.pairs.len()
    }

    /// Level `r` in accumulation order (`r = 0` is `q = s-1-depth`, the
    /// smallest kept weight): its pairs and weight exponent.
    pub fn level(&self, r: usize) -> (&[(usize, usize)], i32) {
        let l = &self.levels[r];
        (&self.pairs[l.start..l.end], l.weight)
    }

    /// All levels in accumulation order.
    pub fn levels(&self) -> impl Iterator<Item = (&[(usize, usize)], i32)> + '_ {
        self.levels.iter().map(move |l| (&self.pairs[l.start..l.end], l.weight))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_the_reference_enumeration() {
        // The level-major reference: q = s-1 down to 0, pairs (t, q-t) for
        // t = 0..=q, weight 2*rb*(s-1) - rb*q.
        for (s, rb) in [(1usize, 8i32), (4, 8), (7, 8), (8, 7)] {
            let sched = PairSchedule::new(s, rb);
            assert_eq!(sched.slices(), s);
            assert_eq!(sched.radix_bits(), rb);
            assert_eq!(sched.pair_count(), s * (s + 1) / 2);
            let mut seen = 0;
            for (r, (pairs, w)) in sched.levels().enumerate() {
                let q = s - 1 - r;
                let expect: Vec<(usize, usize)> = (0..=q).map(|t| (t, q - t)).collect();
                assert_eq!(pairs, expect.as_slice(), "s={s} rb={rb} q={q}");
                assert_eq!(w, 2 * rb * (s as i32 - 1) - rb * q as i32);
                assert_eq!(sched.level(r).0, expect.as_slice());
                assert_eq!(sched.level(r).1, w);
                seen += pairs.len();
            }
            assert_eq!(seen, sched.pair_count(), "levels partition the pair set");
        }
    }

    #[test]
    fn weights_increase_along_accumulation_order() {
        // Smallest-weight-first is what keeps the compensated sum's
        // per-element order identical to python/compile/ozaki.py.
        let sched = PairSchedule::new(7, 8);
        let ws: Vec<i32> = sched.levels().map(|(_, w)| w).collect();
        for pair in ws.windows(2) {
            assert!(pair[0] < pair[1], "weights must ascend: {ws:?}");
        }
    }

    #[test]
    fn global_cache_shares_one_arc_per_config() {
        let a = PairSchedule::get(5, 8);
        let b = PairSchedule::get(5, 8);
        assert!(Arc::ptr_eq(&a, &b), "same config must share one schedule");
        let c = PairSchedule::get(5, 7);
        assert!(!Arc::ptr_eq(&a, &c), "different radix is a different schedule");
        let d = PairSchedule::for_config(&OzakiConfig::new(5));
        assert!(Arc::ptr_eq(&a, &d), "for_config resolves through the same cache");
    }

    #[test]
    fn truncated_schedule_is_the_full_schedules_weighted_tail() {
        // Dropping `depth` levels removes exactly the first `depth`
        // stored (smallest-weight) levels; every kept level must match
        // the full schedule's corresponding level bit for bit.
        for (s, rb) in [(4usize, 8i32), (7, 8), (8, 7)] {
            let full = PairSchedule::new(s, rb);
            for depth in 0..s {
                let t = PairSchedule::new_truncated(s, rb, depth);
                assert_eq!(t.slices(), s);
                assert_eq!(t.truncation_depth(), depth);
                assert_eq!(t.level_count(), s - depth);
                let keep = s - depth;
                assert_eq!(t.pair_count(), keep * (keep + 1) / 2);
                assert_eq!(t.full_pair_count(), s * (s + 1) / 2);
                assert_eq!(t.skipped_pair_count(), t.full_pair_count() - t.pair_count());
                for r in 0..t.level_count() {
                    // kept level r of the truncated schedule is level
                    // depth + r of the full one
                    let (tp, tw) = t.level(r);
                    let (fp, fw) = full.level(depth + r);
                    assert_eq!(tp, fp, "s={s} depth={depth} r={r}");
                    assert_eq!(tw, fw, "s={s} depth={depth} r={r}");
                }
                // no kept pair references a slice index beyond s-1-depth
                for (r, (pairs, _)) in t.levels().enumerate() {
                    for &(a, b) in pairs {
                        assert!(a + b <= s - 1 - depth, "r={r} pair=({a},{b})");
                    }
                }
            }
        }
    }

    #[test]
    fn truncation_depth_zero_shares_the_untruncated_arc() {
        let a = PairSchedule::get(6, 8);
        let b = PairSchedule::get_truncated(6, 8, 0);
        assert!(Arc::ptr_eq(&a, &b), "depth 0 must resolve to the full schedule's entry");
        let c = PairSchedule::get_truncated(6, 8, 2);
        assert!(!Arc::ptr_eq(&a, &c), "each depth is its own cache entry");
        let d = PairSchedule::get_truncated(6, 8, 2);
        assert!(Arc::ptr_eq(&c, &d), "same depth shares one schedule");
    }
}
