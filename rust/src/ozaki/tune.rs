//! One-shot runtime autotuner for the fused-engine tile geometry.
//!
//! The fixed `FUSED_MC × FUSED_NC = 64×64` tile was chosen for one
//! microarchitecture; the right shape depends on which microkernel is
//! dispatched (register block width: 8 i32 lanes for AVX2, 16 for
//! AVX-512) and on the output shape (a 64-row band of a 4096-wide output
//! streams very different panel traffic than a square 128×128 problem).
//! Because **every** tile shape is bitwise identical by the fused-engine
//! argument (exact integer pair products + per-element level/descale
//! order), the geometry is a pure performance knob — which makes it safe
//! to pick at runtime, the ADP philosophy applied to the CPU substrate.
//!
//! Mechanics:
//!
//! * [`TileShape`] `{mc, nc}` — the output-tile geometry threaded through
//!   `fused_tile_gemm_serial*`, `ParallelBackend::{fused,crt}_tile_gemm`
//!   and the CRT serial driver. The k extent is **not** tunable: the
//!   `K_CHUNK` cap is a correctness bound (i32 exactness) and changing
//!   k-chunking changes the f64 chunk-sum sequence, which would break
//!   bitwise identity.
//! * [`tile_shape_for`] — per `(kernel, shape bucket)` lookup: first use
//!   microbenchmarks the small [`CANDIDATES`] grid on synthetic digit
//!   tensors (deterministic LCG digits, zero sigmas) and caches the
//!   winner process-wide. The baseline 64×64 shape is in the grid, so
//!   the tuned choice is never slower than the fixed geometry (up to
//!   probe noise on the probe itself).
//! * Persistence — when a tuning catalog path is configured
//!   (`ADP_TUNE_CATALOG=<file>`, or a `tiletune` entry in the
//!   `artifacts/` manifest via [`runtime::Catalog`]), probed winners are
//!   written through `runtime::tuning` and reloaded on the next process
//!   start, so warm services and future runs skip the probe entirely.
//! * Knobs — `ADP_TUNE=off` pins the 64×64 baseline with zero probing;
//!   `ADP_TILE=<mc>x<nc>` pins an explicit shape (A/B perf runs);
//!   [`force_shape`] is the in-process test hook. All three are safe
//!   precisely because shapes cannot change results.
//!
//! The probe also yields the winning kernel's measured ns-per-MAC
//! ([`measured_pair_ns`]), which `CpuCalibration` feeds into the
//! native-vs-emulate heuristic — the decision layer prices the kernel
//! that will actually run, not a scalar-era constant.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use super::gemm::{fused_tile_gemm_serial_shaped, FUSED_MC, FUSED_NC};
use super::kernel::{self, KernelId, SliceKernel};
use super::schedule::PairSchedule;
use super::slicing::SlicedMatrix;
use super::SliceEncoding;
use crate::backend::WorkspacePool;
use crate::linalg::Matrix;
use crate::runtime::quarantine;
use crate::runtime::tuning::{self, TuningEntry};
use crate::util::faultinject;
use crate::util::sync as psync;

/// Output-tile geometry of the fused engine (rows × cols of one tile).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TileShape {
    pub mc: usize,
    pub nc: usize,
}

impl TileShape {
    /// The fixed pre-autotuner geometry — always in the candidate grid,
    /// and the shape every tuned choice is benchmarked against.
    pub const BASELINE: TileShape = TileShape { mc: FUSED_MC, nc: FUSED_NC };

    /// Workspace elements one tile needs (i64 + hi + lo scratch each).
    pub fn elems(self) -> usize {
        self.mc * self.nc
    }

    /// `"<mc>x<nc>"` — the `ADP_TILE` / catalog / metrics format.
    pub fn label(self) -> String {
        format!("{}x{}", self.mc, self.nc)
    }

    /// Inverse of [`TileShape::label`]; rejects degenerate or absurd
    /// dims (a 0-wide tile would loop forever, a huge one defeats the
    /// cache-residency point of the fused engine).
    pub fn parse(s: &str) -> Option<TileShape> {
        let (mc, nc) = s.split_once('x')?;
        let (mc, nc) = (mc.parse().ok()?, nc.parse().ok()?);
        if !(1..=4096).contains(&mc) || !(1..=4096).contains(&nc) {
            return None;
        }
        Some(TileShape { mc, nc })
    }
}

/// Output-shape size class of one fused GEMM — the autotuner's second
/// cache key alongside the kernel. Coarse on purpose: per-exact-shape
/// keys would re-probe constantly and overfit probe noise.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ShapeBucket {
    /// `max(m, n) <= 64`: at most one baseline tile — nothing to tune.
    Small,
    /// `max(m, n) <= 256`.
    Medium,
    /// `max(m, n) > 256`.
    Large,
}

impl ShapeBucket {
    pub const ALL: [ShapeBucket; 3] = [ShapeBucket::Small, ShapeBucket::Medium, ShapeBucket::Large];

    pub fn of(m: usize, n: usize) -> ShapeBucket {
        match m.max(n) {
            0..=64 => ShapeBucket::Small,
            65..=256 => ShapeBucket::Medium,
            _ => ShapeBucket::Large,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            ShapeBucket::Small => "small",
            ShapeBucket::Medium => "medium",
            ShapeBucket::Large => "large",
        }
    }

    pub fn parse(s: &str) -> Option<ShapeBucket> {
        ShapeBucket::ALL.into_iter().find(|b| b.label() == s)
    }

    /// Representative probe problem `(m, n, k, s)` for this bucket —
    /// small enough that even the scalar kernel probes in tens of
    /// milliseconds, large enough to exercise multi-band/multi-tile
    /// traffic for every candidate.
    fn probe_dims(self) -> (usize, usize, usize, usize) {
        match self {
            ShapeBucket::Small => (64, 64, 48, 2),
            ShapeBucket::Medium => (160, 160, 48, 2),
            ShapeBucket::Large => (288, 288, 48, 2),
        }
    }
}

/// The candidate grid. Small by design (first-use probe cost is
/// 2 runs × grid per (kernel, bucket)); the baseline is element 0 so
/// ties and degenerate probes fall back to the fixed geometry.
pub const CANDIDATES: [TileShape; 6] = [
    TileShape::BASELINE,
    TileShape { mc: 32, nc: 64 },
    TileShape { mc: 48, nc: 96 },
    TileShape { mc: 64, nc: 128 },
    TileShape { mc: 96, nc: 96 },
    TileShape { mc: 128, nc: 64 },
];

struct TuneState {
    /// Winner per (kernel, bucket) — probed, loaded, or both.
    shapes: HashMap<(KernelId, ShapeBucket), TileShape>,
    /// Keys that came from the persisted catalog (vs a live probe).
    from_catalog: HashMap<(KernelId, ShapeBucket), bool>,
    /// Measured ns per integer MAC of the winning shape, per kernel
    /// (the freshest bucket wins; they agree to probe noise).
    pair_ns: HashMap<KernelId, f64>,
    loaded: bool,
}

fn state() -> &'static Mutex<TuneState> {
    static STATE: OnceLock<Mutex<TuneState>> = OnceLock::new();
    STATE.get_or_init(|| {
        Mutex::new(TuneState {
            shapes: HashMap::new(),
            from_catalog: HashMap::new(),
            pair_ns: HashMap::new(),
            loaded: false,
        })
    })
}

/// In-process shape pin for tests and benches (takes precedence over
/// everything but `ADP_FORCE`-style env pins are below it — the hook is
/// for code that just proved all shapes identical). Pass `None` to
/// restore normal dispatch. Safe under races: every shape is bitwise
/// identical, so a concurrently-running GEMM picking either value is
/// still correct.
pub fn force_shape(shape: Option<TileShape>) {
    *psync::lock(forced()) = shape;
}

fn forced() -> &'static Mutex<Option<TileShape>> {
    static FORCED: OnceLock<Mutex<Option<TileShape>>> = OnceLock::new();
    FORCED.get_or_init(|| Mutex::new(None))
}

/// `ADP_TILE=<mc>x<nc>` pins one shape process-wide (cached; a malformed
/// value warns once and is ignored).
fn env_tile() -> Option<TileShape> {
    static TILE: OnceLock<Option<TileShape>> = OnceLock::new();
    *TILE.get_or_init(|| {
        let raw = std::env::var("ADP_TILE").ok()?;
        let parsed = TileShape::parse(&raw);
        if parsed.is_none() {
            eprintln!("ADP_TILE={raw}: expected <mc>x<nc> (e.g. 64x128); ignoring");
        }
        parsed
    })
}

/// `ADP_TUNE=off` (or `0`/`false`) disables probing entirely — the fixed
/// baseline geometry everywhere, zero startup cost.
fn tune_off() -> bool {
    static OFF: OnceLock<bool> = OnceLock::new();
    *OFF.get_or_init(|| {
        matches!(std::env::var("ADP_TUNE").ok().as_deref(), Some("off") | Some("0") | Some("false"))
    })
}

/// Where the persisted tuning catalog lives, if anywhere:
/// `ADP_TUNE_CATALOG=<file>` first, else the `tiletune` entry of the
/// `artifacts/` manifest ([`ArtifactKind::TileTuning`]). `None` disables
/// persistence (probing still works, per process).
fn catalog_path() -> Option<&'static PathBuf> {
    static PATH: OnceLock<Option<PathBuf>> = OnceLock::new();
    PATH.get_or_init(|| {
        if let Ok(p) = std::env::var("ADP_TUNE_CATALOG") {
            if !p.is_empty() {
                return Some(PathBuf::from(p));
            }
        }
        crate::runtime::Catalog::load(std::path::Path::new("artifacts"))
            .ok()
            .and_then(|c| c.tuning_path())
    })
    .as_ref()
}

/// Load the persisted catalog into `st` (once per process; unknown
/// kernels/buckets and malformed shapes are skipped, not errors — the
/// catalog may come from another machine or an older binary). A catalog
/// that fails to parse at all is quarantined (renamed to `<path>.corrupt`,
/// warned once, counted) instead of silently dropped: the run continues
/// on probe defaults and the next process starts from a clean slate.
fn ensure_loaded(st: &mut TuneState) {
    if st.loaded {
        return;
    }
    st.loaded = true;
    let Some(path) = catalog_path() else { return };
    if !path.exists() {
        return; // cold start, nothing to load or quarantine
    }
    let entries = match tuning::load(path) {
        Ok(entries) if !faultinject::fires(faultinject::site::TUNE_LOAD_CORRUPT) => entries,
        Ok(_) => {
            quarantine::quarantine_file(path, "tile-tuning catalog", "injected corruption");
            return;
        }
        Err(e) => {
            quarantine::quarantine_file(path, "tile-tuning catalog", &e);
            return;
        }
    };
    for e in entries {
        let (Some(kern), Some(bucket)) = (KernelId::parse(&e.kernel), ShapeBucket::parse(&e.bucket))
        else {
            continue;
        };
        let shape = TileShape { mc: e.mc, nc: e.nc };
        if !CANDIDATES.contains(&shape) {
            continue; // stale grid: re-probe rather than trust it
        }
        st.shapes.insert((kern, bucket), shape);
        st.from_catalog.insert((kern, bucket), true);
        if e.pair_ns > 0.0 {
            st.pair_ns.entry(kern).or_insert(e.pair_ns);
        }
    }
}

/// Persist every cached winner (best effort: persistence failing must
/// never fail a GEMM).
fn persist(st: &TuneState) {
    let Some(path) = catalog_path() else { return };
    let mut entries: Vec<TuningEntry> = st
        .shapes
        .iter()
        .map(|(&(kern, bucket), &shape)| TuningEntry {
            kernel: kern.label().to_string(),
            bucket: bucket.label().to_string(),
            mc: shape.mc,
            nc: shape.nc,
            pair_ns: st.pair_ns.get(&kern).copied().unwrap_or(0.0),
        })
        .collect();
    entries.sort_by(|a, b| (&a.kernel, &a.bucket).cmp(&(&b.kernel, &b.bucket)));
    let _ = tuning::save(path, &entries);
}

/// Deterministic synthetic slice tensor for probing: LCG digits over the
/// full i8 range, zero sigmas (descaling cost is shape-independent
/// anyway). Unsigned encoding — the probe kernel is fixed explicitly, so
/// the encoding only labels the tensor.
fn probe_operand(s: usize, rows: usize, k: usize, seed: u64) -> SlicedMatrix {
    let mut data = vec![0i8; s * rows * k];
    let mut x = seed;
    for d in data.iter_mut() {
        x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        *d = (x >> 56) as i8;
    }
    SlicedMatrix { s, rows, cols: k, sigma: vec![0; rows], data, encoding: SliceEncoding::Unsigned }
}

/// Microbenchmark the candidate grid for `(kern, bucket)`: 1 warmup + 1
/// timed run per candidate on the bucket's representative problem,
/// minimum time wins. Returns the winner and its ns per integer MAC.
fn probe_bucket(kern: &'static dyn SliceKernel, bucket: ShapeBucket) -> (TileShape, f64) {
    let (m, n, k, s) = bucket.probe_dims();
    let asl = probe_operand(s, m, k, 0x9e37_79b9_7f4a_7c15);
    let bsl = probe_operand(s, n, k, 0xd1b5_4a32_d192_ed03);
    let schedule = PairSchedule::get(s, SliceEncoding::Unsigned.radix_bits());
    let pool = WorkspacePool::new();
    let macs = (schedule.pair_count() * m * n * k) as f64;
    let mut c = Matrix::zeros(m, n);
    let mut best = (TileShape::BASELINE, f64::INFINITY);
    for &shape in CANDIDATES.iter() {
        fused_tile_gemm_serial_shaped(kern, &asl, &bsl, &schedule, &pool, shape, &mut c);
        let t0 = Instant::now();
        fused_tile_gemm_serial_shaped(kern, &asl, &bsl, &schedule, &pool, shape, &mut c);
        let dt = t0.elapsed().as_secs_f64();
        if dt < best.1 {
            best = (shape, dt);
        }
    }
    (best.0, best.1 * 1e9 / macs)
}

/// The tile geometry to run `kern` with for an `m x n` output — the seam
/// every fused/CRT driver calls. Precedence: [`force_shape`] pin →
/// `ADP_TILE` env pin → `ADP_TUNE=off` baseline → small-problem baseline
/// → cached/persisted winner → live probe (cached + persisted).
pub fn tile_shape_for(kern: KernelId, m: usize, n: usize) -> TileShape {
    if let Some(shape) = *psync::lock(forced()) {
        return shape;
    }
    if let Some(shape) = env_tile() {
        return shape;
    }
    if tune_off() {
        return TileShape::BASELINE;
    }
    let bucket = ShapeBucket::of(m, n);
    if bucket == ShapeBucket::Small {
        return TileShape::BASELINE;
    }
    let Some(kernel) = kernel::kernel_by_id(kern) else {
        return TileShape::BASELINE;
    };
    let mut st = psync::lock(state());
    ensure_loaded(&mut st);
    if let Some(&shape) = st.shapes.get(&(kern, bucket)) {
        return shape;
    }
    // First use for this (kernel, bucket): probe under the lock so
    // concurrent callers block on one probe instead of racing duplicates.
    let (shape, pair_ns) = probe_bucket(kernel, bucket);
    st.shapes.insert((kern, bucket), shape);
    st.from_catalog.insert((kern, bucket), false);
    st.pair_ns.insert(kern, pair_ns);
    persist(&st);
    shape
}

/// Measured ns per integer MAC of `kern`'s tuned fused path, from the
/// most recent probe (or the persisted catalog). `None` until something
/// probed this kernel — callers keep their own fallback measurement.
pub fn measured_pair_ns(kern: KernelId) -> Option<f64> {
    let mut st = psync::lock(state());
    ensure_loaded(&mut st);
    st.pair_ns.get(&kern).copied()
}

/// Persist the cached winners now (orderly-shutdown flush). Today every
/// probe already persists eagerly, so this is cheap; it exists so
/// `GemmService::shutdown` / `adp serve` exit can guarantee the catalog
/// is on disk even if a future change batches the incidental saves.
/// No-op when nothing was probed or persistence is disabled.
pub fn flush() {
    let st = psync::lock(state());
    if st.loaded && !st.shapes.is_empty() {
        persist(&st);
    }
}

/// Force-resolve the tuning entry for `(kern, bucket)`, reporting where
/// it came from: `(shape, true)` when the persisted catalog (or an
/// earlier call) already had it, `(shape, false)` when this call probed.
/// The `adp tune-probe` subcommand and the CI persistence check drive
/// this.
pub fn tune_probe(kern: KernelId, bucket: ShapeBucket) -> (TileShape, bool) {
    let Some(kernel) = kernel::kernel_by_id(kern) else {
        return (TileShape::BASELINE, false);
    };
    let mut st = psync::lock(state());
    ensure_loaded(&mut st);
    if let Some(&shape) = st.shapes.get(&(kern, bucket)) {
        return (shape, true);
    }
    let (shape, pair_ns) = probe_bucket(kernel, bucket);
    st.shapes.insert((kern, bucket), shape);
    st.from_catalog.insert((kern, bucket), false);
    st.pair_ns.insert(kern, pair_ns);
    persist(&st);
    (shape, false)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Serializes the tests that read or write the process-wide
    /// [`force_shape`] pin — concurrent test threads would otherwise
    /// observe each other's pins.
    fn pin_lock() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: Mutex<()> = Mutex::new(());
        LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn shape_label_parse_round_trips() {
        for shape in CANDIDATES {
            assert_eq!(TileShape::parse(&shape.label()), Some(shape));
        }
        assert_eq!(TileShape::parse("64x128"), Some(TileShape { mc: 64, nc: 128 }));
        for bad in ["", "64", "x", "0x64", "64x0", "64x9999", "axb", "64x64x64"] {
            assert_eq!(TileShape::parse(bad), None, "{bad:?} must not parse");
        }
    }

    #[test]
    fn buckets_classify_and_round_trip() {
        assert_eq!(ShapeBucket::of(1, 1), ShapeBucket::Small);
        assert_eq!(ShapeBucket::of(64, 64), ShapeBucket::Small);
        assert_eq!(ShapeBucket::of(65, 1), ShapeBucket::Medium);
        assert_eq!(ShapeBucket::of(1, 256), ShapeBucket::Medium);
        assert_eq!(ShapeBucket::of(257, 8), ShapeBucket::Large);
        for b in ShapeBucket::ALL {
            assert_eq!(ShapeBucket::parse(b.label()), Some(b));
        }
        assert_eq!(ShapeBucket::parse("galactic"), None);
    }

    #[test]
    fn grid_contains_the_baseline_first() {
        assert_eq!(CANDIDATES[0], TileShape::BASELINE);
        assert_eq!(TileShape::BASELINE.elems(), FUSED_MC * FUSED_NC);
    }

    #[test]
    fn small_problems_pin_the_baseline_without_probing() {
        // Must not probe (Small is at most one baseline tile); also the
        // cheapest smoke test that the dispatch path works at all.
        let _g = pin_lock();
        assert_eq!(tile_shape_for(KernelId::Scalar, 8, 8), TileShape::BASELINE);
        assert_eq!(tile_shape_for(KernelId::Scalar, 64, 64), TileShape::BASELINE);
    }

    #[test]
    fn forced_shape_wins_and_restores() {
        let _g = pin_lock();
        let pin = TileShape { mc: 32, nc: 64 };
        force_shape(Some(pin));
        assert_eq!(tile_shape_for(KernelId::Scalar, 500, 500), pin);
        force_shape(None);
        assert_eq!(tile_shape_for(KernelId::Scalar, 8, 8), TileShape::BASELINE);
    }

    #[test]
    fn probe_returns_a_candidate_and_records_pair_ns() {
        let _g = pin_lock();
        let shape = tile_shape_for(KernelId::Scalar, 100, 100);
        assert!(CANDIDATES.contains(&shape), "{shape:?} not in the grid");
        // Second lookup is a cache hit returning the same winner.
        assert_eq!(tile_shape_for(KernelId::Scalar, 100, 100), shape);
        let (again, cached) = tune_probe(KernelId::Scalar, ShapeBucket::Medium);
        assert_eq!(again, shape);
        assert!(cached, "tune_probe must see the cached entry");
        let ns = measured_pair_ns(KernelId::Scalar).expect("probe records pair ns");
        assert!(ns.is_finite() && ns > 0.0, "pair ns {ns}");
    }
}
