//! Ozaki-I decomposition on INT8 slices — the paper's core algorithm.
//!
//! * [`slicing`] — FP64 → INT8 slice tensors, in both the paper's
//!   **unsigned encoding** (§3: leading signed slice, full 8-bit sub-leading
//!   slices via the two's-complement remap) and the naive **signed
//!   encoding** (the ablation baseline: one redundant sign bit per slice).
//! * [`gemm`] — exact INT8×INT8→INT32 slice-pair GEMM and the two
//!   emulated-DGEMM drivers with Ozaki-I triangular truncation: the
//!   level-major reference (the property-test oracle) and the tile-major
//!   **fused tile engine** (the hot path — cache-resident tiles, pooled
//!   workspaces, one parallel region, bitwise identical).
//! * [`kernel`] — the runtime-dispatched slice-pair **microkernels**
//!   (the CPU tensor-core analog): a packed-panel [`SliceKernel`] seam
//!   with the scalar reference and AVX2 `maddubs`/`pmaddwd`
//!   implementations, all exact-integer and therefore bitwise
//!   interchangeable; `ADP_FORCE_SCALAR=1` pins the reference.
//! * [`tune`] — the one-shot runtime **tile-geometry autotuner**: a
//!   per-(kernel, shape-bucket) [`TileShape`] picked by microbenchmark on
//!   first use, cached process-wide and persisted through the runtime
//!   catalog; safe because every geometry is bitwise identical.
//! * [`schedule`] — the precomputed per-level slice-pair schedule shared
//!   by both drivers and the grouped pipeline.
//! * [`recompose`] — scaled recombination of slice products back to FP64.
//! * [`crt`] — the Ozaki-II/CRT scheme family: per-modulus residue GEMMs
//!   on the same microkernels (one launch per modulus — linear, not
//!   quadratic) with balanced-Garner CRT reconstruction.
//! * [`scheme`] — the [`DecompositionScheme`] seam the coordinator uses
//!   to pick slice-pair vs CRT per request.
//!
//! This native-Rust pipeline mirrors `python/compile/ozaki.py` formula for
//! formula; the integration tests assert **bitwise identical** results
//! between the two, which is what lets ADP treat AOT artifacts and the
//! native path as interchangeable dispatch targets.

pub mod batched;
pub mod crt;
pub mod gemm;
pub mod kernel;
pub mod recompose;
pub mod schedule;
pub mod scheme;
pub mod slicing;
pub mod tune;

pub use batched::{gemm_grouped, GroupStats, GroupedProblem, OperandRole, SliceCache};
pub use crt::{crt_gemm, crt_gemm_on, CrtBasis, CrtConfig, CRT_MODULI};
pub use gemm::{
    emulated_gemm, emulated_gemm_on, emulated_gemm_with_breakdown,
    emulated_gemm_with_breakdown_on, fused_gemm, fused_gemm_on, slice_pair_gemm,
    slice_pair_gemm_rows, slice_pair_gemm_tile, EmulationBreakdown, FUSED_MC, FUSED_NC,
};
pub use kernel::{KernelId, SliceKernel};
pub use schedule::PairSchedule;
pub use tune::{tile_shape_for, ShapeBucket, TileShape};
pub use scheme::{CrtScheme, DecompositionScheme, SchemeKind, SlicePairScheme};
pub use slicing::{crt_slice_a, crt_slice_b, slice_a, slice_b, SlicedMatrix};

/// Which slice encoding to use (§3 of the paper).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SliceEncoding {
    /// Leading slice signed; sub-leading slices use the full 8-bit range via
    /// the two's-complement redistribution. 8s-2 effective mantissa bits.
    Unsigned,
    /// Every slice stores a sign bit (the naive baseline). 7s-1 effective
    /// mantissa bits — one more slice needed for FP64 fidelity.
    Signed,
}

impl SliceEncoding {
    /// Base-2 log of the digit radix (bits consumed per sub-leading slice).
    #[inline]
    pub fn radix_bits(self) -> i32 {
        match self {
            SliceEncoding::Unsigned => 8,
            SliceEncoding::Signed => 7,
        }
    }

    /// Effective mantissa bits captured by `s` slices.
    #[inline]
    pub fn effective_bits(self, s: usize) -> i32 {
        match self {
            SliceEncoding::Unsigned => 8 * s as i32 - 2, // sign + headroom
            SliceEncoding::Signed => 7 * s as i32 - 1,   // sign per slice
        }
    }

    /// Minimum slice count covering `bits` mantissa bits.
    #[inline]
    pub fn slices_for_bits(self, bits: i32) -> usize {
        let s = match self {
            SliceEncoding::Unsigned => (bits + 2 + 7) / 8,
            SliceEncoding::Signed => (bits + 1 + 6) / 7,
        };
        s.max(1) as usize
    }
}

/// User-selectable accuracy/speed trade-off (ROADMAP "dynamic accuracy
/// tiers"). A tier maps to a pair-truncation depth in [`PairSchedule`]:
/// the fast tiers drop the smallest-weight levels of the triangular
/// schedule (pairs `(t, u)` with `t + u >= s - depth`, the fast-mode
/// lever of arXiv 2409.13313), trading guaranteed mantissa bits for
/// quadratically fewer pair GEMMs. [`AccuracyTier::GuaranteedFp64`]
/// never truncates and stays bitwise identical to the seed semantics.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum AccuracyTier {
    /// Full triangular schedule; ESC-guaranteed FP64 accuracy (Grade A).
    GuaranteedFp64,
    /// Keep the cross terms covering ~30 mantissa bits; FP64-faithful on
    /// well-conditioned inputs at roughly a third of the pair GEMMs.
    Fp64FaithfulFast,
    /// Keep ~22 mantissa bits — error comparable to an FP32-arithmetic
    /// GEMM — at the steepest truncation.
    Fp32Grade,
}

impl Default for AccuracyTier {
    fn default() -> Self {
        AccuracyTier::GuaranteedFp64
    }
}

impl AccuracyTier {
    pub const ALL: [AccuracyTier; 3] =
        [AccuracyTier::GuaranteedFp64, AccuracyTier::Fp64FaithfulFast, AccuracyTier::Fp32Grade];

    /// Dense index for per-tier counter arrays.
    #[inline]
    pub fn index(self) -> usize {
        match self {
            AccuracyTier::GuaranteedFp64 => 0,
            AccuracyTier::Fp64FaithfulFast => 1,
            AccuracyTier::Fp32Grade => 2,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            AccuracyTier::GuaranteedFp64 => "guaranteed",
            AccuracyTier::Fp64FaithfulFast => "fast",
            AccuracyTier::Fp32Grade => "fp32",
        }
    }

    pub fn parse(s: &str) -> Option<AccuracyTier> {
        match s.trim().to_ascii_lowercase().as_str() {
            "guaranteed" | "guaranteed-fp64" | "fp64" | "full" => {
                Some(AccuracyTier::GuaranteedFp64)
            }
            "fast" | "fp64-fast" | "faithful" => Some(AccuracyTier::Fp64FaithfulFast),
            "fp32" | "fp32-grade" => Some(AccuracyTier::Fp32Grade),
            _ => None,
        }
    }

    /// Mantissa bits the kept cross terms must still cover, or `None`
    /// for the full (never-truncated) schedule. These are the tiers'
    /// documented error levels; the grading suite enforces them.
    #[inline]
    pub fn kept_bits(self) -> Option<i32> {
        match self {
            AccuracyTier::GuaranteedFp64 => None,
            AccuracyTier::Fp64FaithfulFast => Some(30),
            AccuracyTier::Fp32Grade => Some(22),
        }
    }

    /// Pair-truncation depth for a decomposition of `s` slices: drop
    /// levels until the kept cross terms still cover
    /// [`AccuracyTier::kept_bits`]. Returns 0 (no truncation) for the
    /// guaranteed tier, and 0 whenever `s` is already at or below the
    /// tier's kept slice count — the case the coordinator reports as a
    /// tier escalation (the full schedule is the only way to meet the
    /// tier's bound, so nothing can be skipped).
    pub fn truncation_depth(self, s: usize, encoding: SliceEncoding) -> usize {
        match self.kept_bits() {
            None => 0,
            Some(bits) => s.saturating_sub(encoding.slices_for_bits(bits)),
        }
    }

    /// Session default: the `ADP_TIER` environment override if set and
    /// valid, else [`AccuracyTier::GuaranteedFp64`]. Read once per
    /// process (the coordinator consults this; the raw `ozaki` layer
    /// never does, so explicitly-configured decompositions stay
    /// deterministic under any environment).
    pub fn env_default() -> AccuracyTier {
        static CACHE: std::sync::OnceLock<AccuracyTier> = std::sync::OnceLock::new();
        *CACHE.get_or_init(|| {
            std::env::var("ADP_TIER")
                .ok()
                .and_then(|v| AccuracyTier::parse(&v))
                .unwrap_or(AccuracyTier::GuaranteedFp64)
        })
    }
}

/// Configuration of the emulated GEMM.
#[derive(Clone, Copy, Debug)]
pub struct OzakiConfig {
    pub slices: usize,
    pub encoding: SliceEncoding,
    /// Largest k per exact accumulation pass. Defaults to the i32
    /// exactness cap [`gemm::K_CHUNK`] and is clamped to it; tests inject
    /// smaller values to exercise the chunked large-k path at small k.
    pub k_chunk: usize,
    /// Accuracy tier → pair-truncation depth of the schedule both
    /// drivers walk. Defaults to the guaranteed (full-schedule) tier.
    pub tier: AccuracyTier,
}

impl OzakiConfig {
    pub fn new(slices: usize) -> Self {
        OzakiConfig {
            slices,
            encoding: SliceEncoding::Unsigned,
            k_chunk: gemm::K_CHUNK,
            tier: AccuracyTier::GuaranteedFp64,
        }
    }

    pub fn with_encoding(slices: usize, encoding: SliceEncoding) -> Self {
        OzakiConfig { encoding, ..OzakiConfig::new(slices) }
    }

    /// Config reaching at least `bits` effective mantissa bits.
    pub fn for_bits(bits: i32, encoding: SliceEncoding) -> Self {
        OzakiConfig::with_encoding(encoding.slices_for_bits(bits), encoding)
    }

    /// Override the accumulation chunk size (clamped to `[1, K_CHUNK]`).
    pub fn with_k_chunk(mut self, k_chunk: usize) -> Self {
        self.k_chunk = k_chunk;
        self
    }

    /// Override the accuracy tier.
    pub fn with_tier(mut self, tier: AccuracyTier) -> Self {
        self.tier = tier;
        self
    }

    /// Effective chunk size: never beyond the i32 exactness cap.
    pub fn k_chunk(&self) -> usize {
        self.k_chunk.clamp(1, gemm::K_CHUNK)
    }

    /// Pair-truncation depth the tier induces at this slice count.
    pub fn truncation_depth(&self) -> usize {
        self.tier.truncation_depth(self.slices, self.encoding)
    }

    /// Slice-pair GEMMs executed under Ozaki-I triangular truncation at
    /// this config's tier (kept pairs only).
    pub fn pair_count(&self) -> usize {
        let keep = self.slices - self.truncation_depth();
        keep * (keep + 1) / 2
    }

    /// Pairs the guaranteed (full) schedule would execute: `s(s+1)/2`.
    pub fn full_pair_count(&self) -> usize {
        self.slices * (self.slices + 1) / 2
    }

    /// Pair GEMMs the tier skips relative to the full schedule.
    pub fn skipped_pair_count(&self) -> usize {
        self.full_pair_count() - self.pair_count()
    }

    /// Equivalent Ozaki-II/CRT window (`s_eq`): the unsigned 8-bit slice
    /// count covering this config's effective bits, capped at the tier's
    /// kept bits — the CRT-side analogue of pair truncation (a smaller
    /// window selects a smaller modulus basis, i.e. fewer residue GEMMs).
    pub fn crt_window(&self) -> usize {
        let mut bits = self.encoding.effective_bits(self.slices);
        if let Some(kept) = self.tier.kept_bits() {
            bits = bits.min(kept);
        }
        SliceEncoding::Unsigned.slices_for_bits(bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fp64_needs_7_unsigned_8_signed() {
        // The paper's §3 claim: 53-bit fidelity in 7 slices instead of 8.
        assert_eq!(SliceEncoding::Unsigned.slices_for_bits(53), 7);
        assert_eq!(SliceEncoding::Signed.slices_for_bits(53), 8);
    }

    #[test]
    fn effective_bits_monotone() {
        // (equal at s = 1: one slice is one signed digit either way)
        for s in 2..20 {
            assert!(SliceEncoding::Unsigned.effective_bits(s) > SliceEncoding::Signed.effective_bits(s));
            let b = SliceEncoding::Unsigned.effective_bits(s);
            assert_eq!(SliceEncoding::Unsigned.slices_for_bits(b), s);
        }
    }

    #[test]
    fn pair_count_quadratic() {
        assert_eq!(OzakiConfig::new(7).pair_count(), 28);
        assert_eq!(OzakiConfig::new(8).pair_count(), 36);
        // the 22% compute reduction claim of §3: 28/36 ~ 0.78
        assert!((28.0f64 / 36.0 - 0.78).abs() < 0.01);
    }

    #[test]
    fn tier_truncation_depths_at_fp64_slicing() {
        // At the canonical s=7 unsigned decomposition the fast tier keeps
        // slices_for_bits(30) = 4 levels (10 of 28 pairs — well under
        // half) and the fp32 tier keeps 3 (6 of 28).
        let full = OzakiConfig::new(7);
        assert_eq!(full.truncation_depth(), 0);
        assert_eq!(full.skipped_pair_count(), 0);

        let fast = OzakiConfig::new(7).with_tier(AccuracyTier::Fp64FaithfulFast);
        assert_eq!(fast.truncation_depth(), 3);
        assert_eq!(fast.pair_count(), 10);
        assert_eq!(fast.skipped_pair_count(), 18);
        assert!(fast.pair_count() * 2 <= full.pair_count());

        let fp32 = OzakiConfig::new(7).with_tier(AccuracyTier::Fp32Grade);
        assert_eq!(fp32.truncation_depth(), 4);
        assert_eq!(fp32.pair_count(), 6);

        // Small decompositions already meet the tier bound with the full
        // schedule: depth saturates to 0 (the escalation case).
        let tiny = OzakiConfig::new(3).with_tier(AccuracyTier::Fp64FaithfulFast);
        assert_eq!(tiny.truncation_depth(), 0);
        assert_eq!(tiny.pair_count(), tiny.full_pair_count());
    }

    #[test]
    fn tier_labels_round_trip() {
        for t in AccuracyTier::ALL {
            assert_eq!(AccuracyTier::parse(t.label()), Some(t));
        }
        assert_eq!(AccuracyTier::parse("FAST"), Some(AccuracyTier::Fp64FaithfulFast));
        assert_eq!(AccuracyTier::parse("guaranteed-fp64"), Some(AccuracyTier::GuaranteedFp64));
        assert_eq!(AccuracyTier::parse("fp32-grade"), Some(AccuracyTier::Fp32Grade));
        assert_eq!(AccuracyTier::parse("bogus"), None);
        assert_eq!(AccuracyTier::default(), AccuracyTier::GuaranteedFp64);
        assert_eq!(
            AccuracyTier::ALL.map(AccuracyTier::index),
            [0, 1, 2],
            "indices must be dense for counter arrays"
        );
    }
}
