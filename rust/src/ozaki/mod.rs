//! Ozaki-I decomposition on INT8 slices — the paper's core algorithm.
//!
//! * [`slicing`] — FP64 → INT8 slice tensors, in both the paper's
//!   **unsigned encoding** (§3: leading signed slice, full 8-bit sub-leading
//!   slices via the two's-complement remap) and the naive **signed
//!   encoding** (the ablation baseline: one redundant sign bit per slice).
//! * [`gemm`] — exact INT8×INT8→INT32 slice-pair GEMM and the two
//!   emulated-DGEMM drivers with Ozaki-I triangular truncation: the
//!   level-major reference (the property-test oracle) and the tile-major
//!   **fused tile engine** (the hot path — cache-resident tiles, pooled
//!   workspaces, one parallel region, bitwise identical).
//! * [`kernel`] — the runtime-dispatched slice-pair **microkernels**
//!   (the CPU tensor-core analog): a packed-panel [`SliceKernel`] seam
//!   with the scalar reference and AVX2 `maddubs`/`pmaddwd`
//!   implementations, all exact-integer and therefore bitwise
//!   interchangeable; `ADP_FORCE_SCALAR=1` pins the reference.
//! * [`tune`] — the one-shot runtime **tile-geometry autotuner**: a
//!   per-(kernel, shape-bucket) [`TileShape`] picked by microbenchmark on
//!   first use, cached process-wide and persisted through the runtime
//!   catalog; safe because every geometry is bitwise identical.
//! * [`schedule`] — the precomputed per-level slice-pair schedule shared
//!   by both drivers and the grouped pipeline.
//! * [`recompose`] — scaled recombination of slice products back to FP64.
//! * [`crt`] — the Ozaki-II/CRT scheme family: per-modulus residue GEMMs
//!   on the same microkernels (one launch per modulus — linear, not
//!   quadratic) with balanced-Garner CRT reconstruction.
//! * [`scheme`] — the [`DecompositionScheme`] seam the coordinator uses
//!   to pick slice-pair vs CRT per request.
//!
//! This native-Rust pipeline mirrors `python/compile/ozaki.py` formula for
//! formula; the integration tests assert **bitwise identical** results
//! between the two, which is what lets ADP treat AOT artifacts and the
//! native path as interchangeable dispatch targets.

pub mod batched;
pub mod crt;
pub mod gemm;
pub mod kernel;
pub mod recompose;
pub mod schedule;
pub mod scheme;
pub mod slicing;
pub mod tune;

pub use batched::{gemm_grouped, GroupStats, GroupedProblem, OperandRole, SliceCache};
pub use crt::{crt_gemm, crt_gemm_on, CrtBasis, CrtConfig, CRT_MODULI};
pub use gemm::{
    emulated_gemm, emulated_gemm_on, emulated_gemm_with_breakdown,
    emulated_gemm_with_breakdown_on, fused_gemm, fused_gemm_on, slice_pair_gemm,
    slice_pair_gemm_rows, slice_pair_gemm_tile, EmulationBreakdown, FUSED_MC, FUSED_NC,
};
pub use kernel::{KernelId, SliceKernel};
pub use schedule::PairSchedule;
pub use tune::{tile_shape_for, ShapeBucket, TileShape};
pub use scheme::{CrtScheme, DecompositionScheme, SchemeKind, SlicePairScheme};
pub use slicing::{crt_slice_a, crt_slice_b, slice_a, slice_b, SlicedMatrix};

/// Which slice encoding to use (§3 of the paper).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SliceEncoding {
    /// Leading slice signed; sub-leading slices use the full 8-bit range via
    /// the two's-complement redistribution. 8s-2 effective mantissa bits.
    Unsigned,
    /// Every slice stores a sign bit (the naive baseline). 7s-1 effective
    /// mantissa bits — one more slice needed for FP64 fidelity.
    Signed,
}

impl SliceEncoding {
    /// Base-2 log of the digit radix (bits consumed per sub-leading slice).
    #[inline]
    pub fn radix_bits(self) -> i32 {
        match self {
            SliceEncoding::Unsigned => 8,
            SliceEncoding::Signed => 7,
        }
    }

    /// Effective mantissa bits captured by `s` slices.
    #[inline]
    pub fn effective_bits(self, s: usize) -> i32 {
        match self {
            SliceEncoding::Unsigned => 8 * s as i32 - 2, // sign + headroom
            SliceEncoding::Signed => 7 * s as i32 - 1,   // sign per slice
        }
    }

    /// Minimum slice count covering `bits` mantissa bits.
    #[inline]
    pub fn slices_for_bits(self, bits: i32) -> usize {
        let s = match self {
            SliceEncoding::Unsigned => (bits + 2 + 7) / 8,
            SliceEncoding::Signed => (bits + 1 + 6) / 7,
        };
        s.max(1) as usize
    }
}

/// Configuration of the emulated GEMM.
#[derive(Clone, Copy, Debug)]
pub struct OzakiConfig {
    pub slices: usize,
    pub encoding: SliceEncoding,
    /// Largest k per exact accumulation pass. Defaults to the i32
    /// exactness cap [`gemm::K_CHUNK`] and is clamped to it; tests inject
    /// smaller values to exercise the chunked large-k path at small k.
    pub k_chunk: usize,
}

impl OzakiConfig {
    pub fn new(slices: usize) -> Self {
        OzakiConfig { slices, encoding: SliceEncoding::Unsigned, k_chunk: gemm::K_CHUNK }
    }

    pub fn with_encoding(slices: usize, encoding: SliceEncoding) -> Self {
        OzakiConfig { slices, encoding, k_chunk: gemm::K_CHUNK }
    }

    /// Config reaching at least `bits` effective mantissa bits.
    pub fn for_bits(bits: i32, encoding: SliceEncoding) -> Self {
        OzakiConfig { slices: encoding.slices_for_bits(bits), encoding, k_chunk: gemm::K_CHUNK }
    }

    /// Override the accumulation chunk size (clamped to `[1, K_CHUNK]`).
    pub fn with_k_chunk(mut self, k_chunk: usize) -> Self {
        self.k_chunk = k_chunk;
        self
    }

    /// Effective chunk size: never beyond the i32 exactness cap.
    pub fn k_chunk(&self) -> usize {
        self.k_chunk.clamp(1, gemm::K_CHUNK)
    }

    /// Slice-pair GEMMs executed under Ozaki-I triangular truncation.
    pub fn pair_count(&self) -> usize {
        self.slices * (self.slices + 1) / 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fp64_needs_7_unsigned_8_signed() {
        // The paper's §3 claim: 53-bit fidelity in 7 slices instead of 8.
        assert_eq!(SliceEncoding::Unsigned.slices_for_bits(53), 7);
        assert_eq!(SliceEncoding::Signed.slices_for_bits(53), 8);
    }

    #[test]
    fn effective_bits_monotone() {
        // (equal at s = 1: one slice is one signed digit either way)
        for s in 2..20 {
            assert!(SliceEncoding::Unsigned.effective_bits(s) > SliceEncoding::Signed.effective_bits(s));
            let b = SliceEncoding::Unsigned.effective_bits(s);
            assert_eq!(SliceEncoding::Unsigned.slices_for_bits(b), s);
        }
    }

    #[test]
    fn pair_count_quadratic() {
        assert_eq!(OzakiConfig::new(7).pair_count(), 28);
        assert_eq!(OzakiConfig::new(8).pair_count(), 36);
        // the 22% compute reduction claim of §3: 28/36 ~ 0.78
        assert!((28.0f64 / 36.0 - 0.78).abs() < 0.01);
    }
}
