//! Exact INT8 slice-pair GEMM and the full emulated-DGEMM pipeline.
//!
//! The slice-pair GEMM is the Tensor-Core workload of the paper: INT8
//! inputs, INT32 accumulation, exact integer arithmetic. Ozaki-I runs
//! `s(s+1)/2` of these (pairs with `t + u <= s-1`), which is where the
//! quadratic-in-slices compute cost comes from (§4) and why the unsigned
//! encoding's slice reduction translates into a 22% compute saving (§3).
//!
//! The per-level pair schedule is dispatched through a
//! [`ComputeBackend`](crate::backend::ComputeBackend): the serial backend
//! runs the pairs in order, the parallel backend splits the level's output
//! rows across a thread pool. Both are bitwise identical — every i64
//! accumulation here is exact, so the schedule cannot change a single bit.

use super::recompose::{recompose, LevelAccumulator};
use super::slicing::{slice_a, slice_b, SlicedMatrix};
use super::OzakiConfig;
use crate::backend::{ComputeBackend, SerialBackend};
use crate::linalg::Matrix;

/// Largest k processed in one i32 accumulation pass: |digit| <= 128 so each
/// product is <= 2^14, and (2^17 - 1) summands reach at most
/// 2^31 - 2^14 < i32::MAX. (A full 2^17 could hit exactly 2^31 when every
/// product is (-128)*(-128) — one past i32::MAX.)
pub const K_CHUNK: usize = (1 << 17) - 1;

/// P[i,j] += sum_l a_t[i,l] * b_u[j,l] — exact integer GEMM of slice `t` of
/// A against slice `u` of B (B slices are stored transposed), over all of
/// A's rows. See [`slice_pair_gemm_rows`] for the row-ranged kernel.
pub fn slice_pair_gemm(a: &SlicedMatrix, t: usize, b: &SlicedMatrix, u: usize, out: &mut [i64]) {
    assert_eq!(out.len(), a.rows * b.rows);
    slice_pair_gemm_rows(a, t, b, u, 0, a.rows, out);
}

/// Rows `[row0, row0 + rows)` of the slice-pair GEMM, accumulating into
/// `out`, the row-major `rows x n` sub-buffer for exactly that row range.
/// The inner accumulation is i32 (exact for k <= K_CHUNK); `out` aggregates
/// in i64 so multiple pairs of the same weight level can share a buffer
/// safely. Disjoint row ranges may run concurrently: integer arithmetic
/// makes any row partition bitwise identical to the full-matrix call.
#[allow(clippy::too_many_arguments)]
pub fn slice_pair_gemm_rows(
    a: &SlicedMatrix,
    t: usize,
    b: &SlicedMatrix,
    u: usize,
    row0: usize,
    rows: usize,
    out: &mut [i64],
) {
    let (k, n) = (a.cols, b.rows);
    assert_eq!(a.cols, b.cols, "inner dimension mismatch");
    assert!(row0 + rows <= a.rows, "row range out of bounds");
    assert_eq!(out.len(), rows * n);
    assert!(k <= K_CHUNK, "k chunking is handled by emulated_gemm");
    let at = a.slice(t);
    let bu = b.slice(u);
    // Row-major x row-major(transposed) dot kernel, 2x4 register blocked
    // (8 independent i32 accumulator chains for the auto-vectorizer).
    let mut i = 0;
    while i + 2 <= rows {
        let a0 = &at[(row0 + i) * k..(row0 + i + 1) * k];
        let a1 = &at[(row0 + i + 1) * k..(row0 + i + 2) * k];
        let mut j = 0;
        while j + 4 <= n {
            let b0 = &bu[j * k..(j + 1) * k];
            let b1 = &bu[(j + 1) * k..(j + 2) * k];
            let b2 = &bu[(j + 2) * k..(j + 3) * k];
            let b3 = &bu[(j + 3) * k..(j + 4) * k];
            let mut c0 = [0i32; 4];
            let mut c1 = [0i32; 4];
            for l in 0..k {
                let (x0, x1) = (a0[l] as i32, a1[l] as i32);
                let y = [b0[l] as i32, b1[l] as i32, b2[l] as i32, b3[l] as i32];
                for r in 0..4 {
                    c0[r] += x0 * y[r];
                    c1[r] += x1 * y[r];
                }
            }
            for r in 0..4 {
                out[i * n + j + r] += c0[r] as i64;
                out[(i + 1) * n + j + r] += c1[r] as i64;
            }
            j += 4;
        }
        while j < n {
            let b0 = &bu[j * k..(j + 1) * k];
            let (mut c00, mut c10) = (0i32, 0i32);
            for l in 0..k {
                c00 += a0[l] as i32 * b0[l] as i32;
                c10 += a1[l] as i32 * b0[l] as i32;
            }
            out[i * n + j] += c00 as i64;
            out[(i + 1) * n + j] += c10 as i64;
            j += 1;
        }
        i += 2;
    }
    if i < rows {
        let a0 = &at[(row0 + i) * k..(row0 + i + 1) * k];
        for j in 0..n {
            let b0 = &bu[j * k..(j + 1) * k];
            let mut c = 0i32;
            for l in 0..k {
                c += a0[l] as i32 * b0[l] as i32;
            }
            out[i * n + j] += c as i64;
        }
    }
}

/// Timing breakdown of one emulated GEMM (feeds the Fig 5 harness).
#[derive(Clone, Copy, Debug, Default)]
pub struct EmulationBreakdown {
    pub slice_s: f64,
    pub gemm_s: f64,
    pub recompose_s: f64,
    pub pairs: usize,
}

/// Full Ozaki-I emulated DGEMM: C ~= A * B with `cfg.slices` INT8 slices,
/// on the serial reference backend.
pub fn emulated_gemm(a: &Matrix, b: &Matrix, cfg: &OzakiConfig) -> Matrix {
    emulated_gemm_on(a, b, cfg, &SerialBackend)
}

/// As [`emulated_gemm`], dispatching the slice-pair schedule through the
/// given compute backend. Results are bitwise identical across backends.
pub fn emulated_gemm_on(
    a: &Matrix,
    b: &Matrix,
    cfg: &OzakiConfig,
    backend: &dyn ComputeBackend,
) -> Matrix {
    emulated_gemm_with_breakdown_on(a, b, cfg, backend).0
}

/// As [`emulated_gemm`], also returning the per-phase timing breakdown.
pub fn emulated_gemm_with_breakdown(
    a: &Matrix,
    b: &Matrix,
    cfg: &OzakiConfig,
) -> (Matrix, EmulationBreakdown) {
    emulated_gemm_with_breakdown_on(a, b, cfg, &SerialBackend)
}

/// Backend-dispatched emulation with the per-phase timing breakdown.
pub fn emulated_gemm_with_breakdown_on(
    a: &Matrix,
    b: &Matrix,
    cfg: &OzakiConfig,
    backend: &dyn ComputeBackend,
) -> (Matrix, EmulationBreakdown) {
    assert_eq!(a.cols, b.rows, "gemm shape mismatch");
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut bd = EmulationBreakdown { pairs: cfg.pair_count(), ..Default::default() };
    if k == 0 || m == 0 || n == 0 {
        return (Matrix::zeros(m, n), bd);
    }
    let kchunk = cfg.k_chunk();
    if k <= kchunk {
        return emulated_gemm_chunk(a, b, cfg, backend);
    }
    // Rare large-k path: exact i32 accumulation caps each pass at the
    // chunk size; chunk results are summed in f64 (same rounding class as
    // one pass).
    let mut c = Matrix::zeros(m, n);
    let mut k0 = 0;
    while k0 < k {
        let kc = kchunk.min(k - k0);
        let (cc, cbd) =
            emulated_gemm_chunk(&a.block(0, k0, m, kc), &b.block(k0, 0, kc, n), cfg, backend);
        c.add_assign(&cc);
        bd.slice_s += cbd.slice_s;
        bd.gemm_s += cbd.gemm_s;
        bd.recompose_s += cbd.recompose_s;
        k0 += kc;
    }
    (c, bd)
}

fn emulated_gemm_chunk(
    a: &Matrix,
    b: &Matrix,
    cfg: &OzakiConfig,
    backend: &dyn ComputeBackend,
) -> (Matrix, EmulationBreakdown) {
    let s = cfg.slices;
    let (m, n) = (a.rows, b.cols);
    let mut bd = EmulationBreakdown { pairs: cfg.pair_count(), ..Default::default() };

    let ts = std::time::Instant::now();
    let asl = slice_a(a, s, cfg.encoding);
    let bsl = slice_b(b, s, cfg.encoding);
    bd.slice_s = ts.elapsed().as_secs_f64();

    let tg = std::time::Instant::now();
    let rb = cfg.encoding.radix_bits();
    let mut acc = LevelAccumulator::new(m * n);
    let mut pbuf = vec![0i64; m * n];
    // Group pairs by weight level q = t+u; accumulate levels smallest
    // weight first (matches python/compile/ozaki.py::recompose exactly).
    // Each level is one backend batch — the backend may run its pairs in
    // any schedule (exact integer arithmetic), but levels feed the
    // compensated accumulator strictly in this order.
    for q in (0..s).rev() {
        pbuf.fill(0);
        let pairs: Vec<(usize, usize)> = (0..=q).map(|t| (t, q - t)).collect();
        backend.slice_pair_gemm_batch(&asl, &bsl, &pairs, &mut pbuf);
        let w = 2 * rb * (s as i32 - 1) - rb * q as i32;
        acc.add_level(&pbuf, w);
    }
    bd.gemm_s = tg.elapsed().as_secs_f64();

    let tr = std::time::Instant::now();
    let c = recompose(acc, &asl.sigma, &bsl.sigma, m, n);
    bd.recompose_s = tr.elapsed().as_secs_f64();
    (c, bd)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::gemm;
    use crate::ozaki::SliceEncoding;
    use crate::util::{prop, Rng};

    fn max_rel_err(c: &Matrix, a: &Matrix, b: &Matrix) -> f64 {
        // componentwise error against the double-double reference, scaled
        // by (|A||B|)_ij — the Grade A denominator.
        let c_ref = a.matmul_dd(b);
        let denom = a.abs().matmul_dd(&b.abs());
        let mut worst = 0.0f64;
        for i in 0..c.rows {
            for j in 0..c.cols {
                let d = denom.at(i, j);
                if d > 0.0 {
                    worst = worst.max((c.at(i, j) - c_ref.at(i, j)).abs() / d);
                }
            }
        }
        worst
    }

    #[test]
    fn pair_gemm_matches_naive() {
        let mut rng = Rng::new(30);
        let (m, k, n) = (5, 17, 7);
        let a = Matrix::uniform(m, k, -2.0, 2.0, &mut rng);
        let b = Matrix::uniform(k, n, -2.0, 2.0, &mut rng);
        let asl = slice_a(&a, 4, SliceEncoding::Unsigned);
        let bsl = slice_b(&b, 4, SliceEncoding::Unsigned);
        for t in 0..4 {
            for u in 0..4 {
                let mut out = vec![0i64; m * n];
                slice_pair_gemm(&asl, t, &bsl, u, &mut out);
                for i in 0..m {
                    for j in 0..n {
                        let mut expect = 0i64;
                        for l in 0..k {
                            expect += asl.slice_row(t, i)[l] as i64
                                * bsl.slice_row(u, j)[l] as i64;
                        }
                        assert_eq!(out[i * n + j], expect, "t={t} u={u} i={i} j={j}");
                    }
                }
            }
        }
    }

    #[test]
    fn row_ranged_pair_gemm_matches_full() {
        // Any row partition must reproduce the full-matrix result exactly.
        let mut rng = Rng::new(36);
        let (m, k, n) = (11, 23, 6);
        let a = Matrix::uniform(m, k, -2.0, 2.0, &mut rng);
        let b = Matrix::uniform(k, n, -2.0, 2.0, &mut rng);
        let asl = slice_a(&a, 3, SliceEncoding::Unsigned);
        let bsl = slice_b(&b, 3, SliceEncoding::Unsigned);
        let mut full = vec![0i64; m * n];
        slice_pair_gemm(&asl, 1, &bsl, 0, &mut full);
        for split in [1, 2, 3, 5, 11] {
            let mut parts = vec![0i64; m * n];
            let mut row0 = 0;
            while row0 < m {
                let rows = split.min(m - row0);
                slice_pair_gemm_rows(
                    &asl,
                    1,
                    &bsl,
                    0,
                    row0,
                    rows,
                    &mut parts[row0 * n..(row0 + rows) * n],
                );
                row0 += rows;
            }
            assert_eq!(parts, full, "split={split}");
        }
    }

    #[test]
    fn emulated_matches_fp64_at_7_slices() {
        let mut rng = Rng::new(31);
        for n in [8, 33, 64] {
            let a = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
            let b = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
            let c = emulated_gemm(&a, &b, &OzakiConfig::new(7));
            let e_emu = max_rel_err(&c, &a, &b);
            let e_nat = max_rel_err(&gemm(&a, &b), &a, &b);
            // FP64-comparable: within a small factor of native error.
            assert!(e_emu <= 8.0 * e_nat.max(f64::EPSILON), "n={n} emu={e_emu} nat={e_nat}");
        }
    }

    #[test]
    fn error_decreases_with_slices() {
        let mut rng = Rng::new(32);
        let n = 32;
        let a = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
        let b = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
        let mut last = f64::INFINITY;
        for s in [2, 3, 4, 5, 6] {
            let e = max_rel_err(&emulated_gemm(&a, &b, &OzakiConfig::new(s)), &a, &b);
            assert!(e < last, "s={s}: {e} !< {last}");
            last = e;
        }
    }

    #[test]
    fn signed_and_unsigned_agree_to_their_bits() {
        let mut rng = Rng::new(33);
        let n = 24;
        let a = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
        let b = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
        let cu = emulated_gemm(&a, &b, &OzakiConfig::with_encoding(7, SliceEncoding::Unsigned));
        let cs = emulated_gemm(&a, &b, &OzakiConfig::with_encoding(8, SliceEncoding::Signed));
        let eu = max_rel_err(&cu, &a, &b);
        let es = max_rel_err(&cs, &a, &b);
        assert!(eu < 1e-15 && es < 1e-15, "unsigned={eu} signed={es}");
    }

    #[test]
    fn wide_exponent_span_needs_more_slices() {
        // Test-2-flavoured input: slices sized by ESC recover accuracy.
        let mut rng = Rng::new(34);
        let n = 16;
        let mut a = Matrix::uniform(n, n, 1.0, 2.0, &mut rng);
        let mut b = Matrix::uniform(n, n, 1.0, 2.0, &mut rng);
        for j in 0..n {
            let sc = 2f64.powi((j as i32 - 8) * 5);
            for i in 0..n {
                *a.at_mut(i, j) *= sc;
                *b.at_mut(j, i) /= sc;
            }
        }
        let e7 = max_rel_err(&emulated_gemm(&a, &b, &OzakiConfig::new(7)), &a, &b);
        let e17 = max_rel_err(&emulated_gemm(&a, &b, &OzakiConfig::new(17)), &a, &b);
        assert!(e17 < 1e-15, "e17={e17}");
        assert!(e7 > 100.0 * e17, "e7={e7} should be much worse than e17={e17}");
    }

    #[test]
    fn negative_zero_inputs() {
        let a = Matrix::from_rows(2, 2, vec![-0.0, 1.0, 2.0, -0.0]);
        let b = Matrix::from_rows(2, 2, vec![3.0, -0.0, -0.0, 4.0]);
        let c = emulated_gemm(&a, &b, &OzakiConfig::new(7));
        let r = gemm(&a, &b);
        for (x, y) in c.data.iter().zip(&r.data) {
            assert_eq!(x.abs(), y.abs()); // -0 treated as 0 (§5.1)
        }
    }

    #[test]
    fn chunked_k_matches_one_pass() {
        // Satellite coverage for the large-k path: force chunking at small
        // k via the injectable chunk size and compare against the one-pass
        // result. Chunk sums commute with the compensated recompose only
        // up to final rounding, so the bound is a few component eps.
        let mut rng = Rng::new(37);
        for (m, k, n, kc) in [(9, 70, 8, 16), (5, 64, 5, 64), (4, 65, 6, 64), (7, 40, 7, 1)] {
            let a = Matrix::uniform(m, k, -2.0, 2.0, &mut rng);
            let b = Matrix::uniform(k, n, -2.0, 2.0, &mut rng);
            let one = emulated_gemm(&a, &b, &OzakiConfig::new(7));
            let chunked = emulated_gemm(&a, &b, &OzakiConfig::new(7).with_k_chunk(kc));
            let denom = a.abs().matmul_dd(&b.abs());
            for idx in 0..one.data.len() {
                let tol = 4.0 * (k as f64 + 4.0) * f64::EPSILON * denom.data[idx];
                let d = (chunked.data[idx] - one.data[idx]).abs();
                assert!(d <= tol, "kc={kc} idx={idx}: |{d}| > {tol}");
            }
        }
    }

    #[test]
    fn chunked_path_stays_grade_a() {
        // The chunked result must hold the same componentwise bound as the
        // one-pass pipeline, not merely agree with it.
        let mut rng = Rng::new(38);
        let (m, k, n) = (8, 96, 9);
        let a = Matrix::uniform(m, k, -3.0, 3.0, &mut rng);
        let b = Matrix::uniform(k, n, -3.0, 3.0, &mut rng);
        let c = emulated_gemm(&a, &b, &OzakiConfig::new(7).with_k_chunk(17));
        let e = max_rel_err(&c, &a, &b);
        let bound = (k as f64 + 4.0) * f64::EPSILON;
        assert!(e <= bound, "err {e} > {bound}");
    }

    #[test]
    fn k_chunk_is_clamped_to_exactness_cap() {
        // A chunk size beyond K_CHUNK would overflow the i32 accumulator;
        // the config clamps rather than trusting the caller.
        assert_eq!(OzakiConfig::new(7).with_k_chunk(usize::MAX).k_chunk(), K_CHUNK);
        assert_eq!(OzakiConfig::new(7).with_k_chunk(0).k_chunk(), 1);
        assert_eq!(OzakiConfig::new(7).k_chunk(), K_CHUNK);
    }

    #[test]
    fn prop_emulated_gemm_grade_a_uniform() {
        prop::check("emulated gemm componentwise error", 12, |rng| {
            let m = rng.int(2, 24) as usize;
            let k = rng.int(2, 40) as usize;
            let n = rng.int(2, 24) as usize;
            let a = Matrix::uniform(m, k, -3.0, 3.0, rng);
            let b = Matrix::uniform(k, n, -3.0, 3.0, rng);
            let c = emulated_gemm(&a, &b, &OzakiConfig::new(7));
            let e = max_rel_err(&c, &a, &b);
            let bound = (k as f64 + 4.0) * f64::EPSILON;
            prop::assert_that(e <= bound, format!("({m},{k},{n}): err {e} > {bound}"))
        });
    }

    #[test]
    fn prop_permutation_invariance() {
        // Fixed-point emulation is invariant to summation order (§4): a
        // simultaneous permutation of A's columns and B's rows must give
        // the *bitwise identical* result.
        prop::check("k-permutation invariance", 20, |rng| {
            let (m, k, n) = (6, 12, 5);
            let a = Matrix::uniform(m, k, -2.0, 2.0, rng);
            let b = Matrix::uniform(k, n, -2.0, 2.0, rng);
            let mut perm: Vec<usize> = (0..k).collect();
            rng.shuffle(&mut perm);
            let ap = Matrix::from_fn(m, k, |i, j| a.at(i, perm[j]));
            let bp = Matrix::from_fn(k, n, |i, j| b.at(perm[i], j));
            let c1 = emulated_gemm(&a, &b, &OzakiConfig::new(6));
            let c2 = emulated_gemm(&ap, &bp, &OzakiConfig::new(6));
            for (x, y) in c1.data.iter().zip(&c2.data) {
                if x.to_bits() != y.to_bits() {
                    return Err(format!("not bitwise invariant: {x} vs {y}"));
                }
            }
            Ok(())
        });
    }
}
