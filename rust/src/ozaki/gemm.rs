//! Exact INT8 slice-pair GEMM and the two emulated-DGEMM drivers.
//!
//! The slice-pair GEMM is the Tensor-Core workload of the paper: INT8
//! inputs, INT32 accumulation, exact integer arithmetic. Ozaki-I runs
//! `s(s+1)/2` of these (pairs with `t + u <= s-1`), which is where the
//! quadratic-in-slices compute cost comes from (§4) and why the unsigned
//! encoding's slice reduction translates into a 22% compute saving (§3).
//!
//! Both drivers execute their pair GEMMs on the runtime-dispatched
//! [`ozaki::kernel`](super::kernel) layer — AVX2 `maddubs`/`pmaddwd`
//! microkernels on packed panels where the CPU has them, the scalar
//! reference otherwise (`ADP_FORCE_SCALAR=1` pins it). Every kernel
//! computes the exact integer pair product, so kernel choice can never
//! change a bit of any result below.
//!
//! Two drivers execute that pair set, sharing one precomputed
//! [`PairSchedule`]:
//!
//! * **Level-major reference** ([`emulated_gemm`] and friends) — one
//!   matrix-wide backend batch per weight level `q`, feeding an `m x n`
//!   [`LevelAccumulator`]. Simple, and retained as the oracle every other
//!   schedule is property-tested against — but it rewrites and re-reads
//!   an `m*n` i64 buffer `s` times and re-streams every INT8 slice from
//!   memory once per pair, the memory-traffic pattern fused-kernel work
//!   (EmuGEMM; PAPERS.md) shows dominates emulation cost.
//! * **Fused tile engine** ([`fused_gemm`], [`fused_gemm_on`],
//!   [`ComputeBackend::fused_tile_gemm`]) — the output is partitioned
//!   into [`FUSED_MC`]×[`FUSED_NC`] tiles and **all** `s(s+1)/2` pairs of
//!   a tile run while its operand slice rows are cache-resident, with the
//!   per-tile level sums folded into a tile-sized compensated accumulator
//!   and the sigma descaling applied per tile. One pass over the output,
//!   one parallel region (work-stealing over row bands of tiles) instead
//!   of `s` barriers, and scratch from a pooled
//!   [`Workspace`](crate::backend::Workspace) — zero hot-path allocation.
//!
//! **Why tile-major preserves bitwise identity.** Per output element
//! `(i, j)` the arithmetic sequence is exactly the reference one: the
//! level-`q` pair sum is exact integer work (any pair/row/tile order
//! yields the identical i64), levels enter the two_sum compensation in
//! the same smallest-weight-first order, and the four descaling passes
//! plus the final `hi + lo` collapse read nothing outside the element
//! itself. Reordering elements (tile-major instead of matrix-wide) can
//! therefore not change a single bit — asserted by property test against
//! the level-major oracle across shapes, encodings, backends and forced
//! k-chunking.

use std::cell::RefCell;

use super::kernel::{self, KernelId, SliceKernel};
use super::recompose::{add_level_into, descale_tile, recompose, LevelAccumulator};
use super::schedule::PairSchedule;
use super::slicing::{slice_a, slice_b, SlicedMatrix};
use super::OzakiConfig;
use crate::backend::{ComputeBackend, SerialBackend, Workspace, WorkspacePool};
use crate::linalg::Matrix;

/// Largest k processed in one i32 accumulation pass: |digit| <= 128 so each
/// product is <= 2^14, and (2^17 - 1) summands reach at most
/// 2^31 - 2^14 < i32::MAX. (A full 2^17 could hit exactly 2^31 when every
/// product is (-128)*(-128) — one past i32::MAX.)
pub const K_CHUNK: usize = (1 << 17) - 1;

/// P[i,j] += sum_l a_t[i,l] * b_u[j,l] — exact integer GEMM of slice `t` of
/// A against slice `u` of B (B slices are stored transposed), over all of
/// A's rows. See [`slice_pair_gemm_rows`] for the row-ranged kernel.
pub fn slice_pair_gemm(a: &SlicedMatrix, t: usize, b: &SlicedMatrix, u: usize, out: &mut [i64]) {
    assert_eq!(out.len(), a.rows * b.rows);
    slice_pair_gemm_rows(a, t, b, u, 0, a.rows, out);
}

/// Rows `[row0, row0 + rows)` of the slice-pair GEMM, accumulating into
/// `out`, the row-major `rows x n` sub-buffer for exactly that row range.
/// Delegates to the full tile kernel with the complete column extent.
/// Disjoint row ranges may run concurrently: integer arithmetic makes any
/// row partition bitwise identical to the full-matrix call.
#[allow(clippy::too_many_arguments)]
pub fn slice_pair_gemm_rows(
    a: &SlicedMatrix,
    t: usize,
    b: &SlicedMatrix,
    u: usize,
    row0: usize,
    rows: usize,
    out: &mut [i64],
) {
    slice_pair_gemm_tile(a, t, b, u, row0, rows, 0, b.rows, out);
}

/// The `rows x cols` output tile at `(row0, col0)` of the slice-pair
/// GEMM, accumulating into `out`, the row-major `rows x cols` buffer for
/// exactly that tile. The inner accumulation is i32 (exact for
/// k <= K_CHUNK); `out` aggregates in i64 so multiple pairs of the same
/// weight level can share a buffer safely. Disjoint tiles may run
/// concurrently, and any tile partition is bitwise identical to the
/// full-matrix call — every accumulation is exact integer arithmetic.
///
/// Runs on the runtime-dispatched [`ozaki::kernel`](super::kernel): the
/// AVX2 microkernel matching the slice encoding where available, the
/// scalar reference otherwise (or under `ADP_FORCE_SCALAR=1`). Every
/// kernel computes the exact integer pair product, so the dispatch can
/// never change a bit of any result.
#[allow(clippy::too_many_arguments)]
pub fn slice_pair_gemm_tile(
    a: &SlicedMatrix,
    t: usize,
    b: &SlicedMatrix,
    u: usize,
    row0: usize,
    rows: usize,
    col0: usize,
    cols: usize,
    out: &mut [i64],
) {
    slice_pair_gemm_tile_on(kernel::active(a.encoding), a, t, b, u, row0, rows, col0, cols, out);
}

thread_local! {
    /// Per-thread panel scratch for the standalone (non-fused) tile entry
    /// point: the level-major reference and the grouped batch rounds call
    /// one pair at a time, so their panels cannot be pooled per tile —
    /// the buffers persist per thread instead, making warm runs
    /// allocation-free here too.
    static PAIR_PACK_SCRATCH: RefCell<(Vec<u8>, Vec<u8>)> =
        const { RefCell::new((Vec::new(), Vec::new())) };
}

/// [`slice_pair_gemm_tile`] on an explicit kernel (benches and the
/// oracle tests inject [`kernel::ScalarKernel`] or a specific SIMD
/// kernel; the dispatch wrapper passes the active one). The scalar
/// kernel runs straight off the slice tensors — no packing copy; SIMD
/// kernels pack the two panels into thread-local scratch first.
#[allow(clippy::too_many_arguments)]
pub fn slice_pair_gemm_tile_on(
    kern: &dyn SliceKernel,
    a: &SlicedMatrix,
    t: usize,
    b: &SlicedMatrix,
    u: usize,
    row0: usize,
    rows: usize,
    col0: usize,
    cols: usize,
    out: &mut [i64],
) {
    let k = a.cols;
    assert_eq!(a.cols, b.cols, "inner dimension mismatch");
    assert!(row0 + rows <= a.rows, "row range out of bounds");
    assert!(col0 + cols <= b.rows, "column range out of bounds");
    assert_eq!(out.len(), rows * cols);
    assert!(k <= K_CHUNK, "k chunking is handled by the gemm drivers");
    debug_assert_eq!(a.encoding, b.encoding, "slice-pair operands must share an encoding");
    if kern.id() == KernelId::Scalar {
        kernel::scalar::tile_unpacked(
            a.slice_rows(t, row0, rows),
            b.slice_rows(u, col0, cols),
            rows,
            cols,
            k,
            out,
        );
        return;
    }
    PAIR_PACK_SCRATCH.with(|cell| {
        let (apack, bpack) = &mut *cell.borrow_mut();
        let ab = kern.a_slice_bytes(rows, k);
        let bb = kern.b_slice_bytes(cols, k);
        if apack.len() < ab {
            apack.resize(ab, 0);
        }
        if bpack.len() < bb {
            bpack.resize(bb, 0);
        }
        kern.pack_a_slice(a, t, row0, rows, &mut apack[..ab]);
        kern.pack_b_slice(b, u, col0, cols, &mut bpack[..bb]);
        kern.pair_tile(&apack[..ab], &bpack[..bb], rows, cols, k, out);
    });
}

/// The distinct B slices of a pair set, packed once in a kernel's panel
/// layout over the full column extent. Built per backend batch by the
/// parallel level/grouped schedules so every row chunk of every pair
/// reuses the shared read-only panels instead of re-packing O(n·k)
/// bytes per (pair, chunk); `Sync`, so chunks on different pool threads
/// read it concurrently.
pub struct PackedBSlices {
    kern: &'static dyn SliceKernel,
    /// Columns packed (`b.rows`: B slice tensors store B transposed).
    n: usize,
    k: usize,
    /// Sorted distinct `u` values of the pair set.
    us: Vec<usize>,
    /// Sorted distinct `t` values of the pair set — hoisted here so the
    /// per-chunk A packing doesn't recompute it per row chunk.
    ts: Vec<usize>,
    stride: usize,
    buf: Vec<u8>,
}

impl PackedBSlices {
    /// Pack every B slice named by `pairs` (full column extent) in
    /// `kern`'s layout.
    pub fn pack(
        kern: &'static dyn SliceKernel,
        b: &SlicedMatrix,
        pairs: &[(usize, usize)],
    ) -> PackedBSlices {
        let (n, k) = (b.rows, b.cols);
        let mut us: Vec<usize> = pairs.iter().map(|&(_, u)| u).collect();
        us.sort_unstable();
        us.dedup();
        let mut ts: Vec<usize> = pairs.iter().map(|&(t, _)| t).collect();
        ts.sort_unstable();
        ts.dedup();
        let stride = kern.b_slice_bytes(n, k);
        let mut buf = vec![0u8; us.len() * stride];
        for (i, &u) in us.iter().enumerate() {
            kern.pack_b_slice(b, u, 0, n, &mut buf[i * stride..(i + 1) * stride]);
        }
        PackedBSlices { kern, n, k, us, ts, stride, buf }
    }

    /// The packed panel of slice `u` (must be in the pair set packed).
    pub fn panel(&self, u: usize) -> &[u8] {
        let i = self.us.binary_search(&u).expect("B slice was packed");
        &self.buf[i * self.stride..(i + 1) * self.stride]
    }
}

/// Every pair of `pairs` over output rows `[row0, row0 + rows)` against
/// pre-packed B panels, accumulating into `out` (the row-major
/// `rows x n` sub-buffer for exactly that row range). The row range's
/// distinct A slices are packed once into thread-local scratch and
/// reused by every pair — the level-major analog of the fused engine's
/// per-band A pack. Bitwise identical to calling
/// [`slice_pair_gemm_rows`] per pair (exact integer arithmetic).
pub fn slice_pairs_rows_on_packed(
    a: &SlicedMatrix,
    bp: &PackedBSlices,
    pairs: &[(usize, usize)],
    row0: usize,
    rows: usize,
    out: &mut [i64],
) {
    let kern = bp.kern;
    let k = a.cols;
    assert_eq!(k, bp.k, "inner dimension mismatch");
    assert!(row0 + rows <= a.rows, "row range out of bounds");
    assert_eq!(out.len(), rows * bp.n);
    assert!(k <= K_CHUNK, "k chunking is handled by the gemm drivers");
    PAIR_PACK_SCRATCH.with(|cell| {
        let (apack, _) = &mut *cell.borrow_mut();
        let ab = kern.a_slice_bytes(rows, k);
        let ts = &bp.ts;
        if apack.len() < ts.len() * ab {
            apack.resize(ts.len() * ab, 0);
        }
        for (i, &t) in ts.iter().enumerate() {
            kern.pack_a_slice(a, t, row0, rows, &mut apack[i * ab..(i + 1) * ab]);
        }
        for &(t, u) in pairs {
            let ti = ts.binary_search(&t).expect("A slice was packed");
            kern.pair_tile(&apack[ti * ab..(ti + 1) * ab], bp.panel(u), rows, bp.n, k, out);
        }
    });
}

/// Timing breakdown of one emulated GEMM (feeds the Fig 5 harness).
#[derive(Clone, Copy, Debug, Default)]
pub struct EmulationBreakdown {
    pub slice_s: f64,
    pub gemm_s: f64,
    pub recompose_s: f64,
    pub pairs: usize,
}

/// Full Ozaki-I emulated DGEMM: C ~= A * B with `cfg.slices` INT8 slices,
/// on the serial reference backend.
pub fn emulated_gemm(a: &Matrix, b: &Matrix, cfg: &OzakiConfig) -> Matrix {
    emulated_gemm_on(a, b, cfg, &SerialBackend)
}

/// As [`emulated_gemm`], dispatching the slice-pair schedule through the
/// given compute backend. Results are bitwise identical across backends.
pub fn emulated_gemm_on(
    a: &Matrix,
    b: &Matrix,
    cfg: &OzakiConfig,
    backend: &dyn ComputeBackend,
) -> Matrix {
    emulated_gemm_with_breakdown_on(a, b, cfg, backend).0
}

/// As [`emulated_gemm`], also returning the per-phase timing breakdown.
pub fn emulated_gemm_with_breakdown(
    a: &Matrix,
    b: &Matrix,
    cfg: &OzakiConfig,
) -> (Matrix, EmulationBreakdown) {
    emulated_gemm_with_breakdown_on(a, b, cfg, &SerialBackend)
}

/// Backend-dispatched emulation with the per-phase timing breakdown.
pub fn emulated_gemm_with_breakdown_on(
    a: &Matrix,
    b: &Matrix,
    cfg: &OzakiConfig,
    backend: &dyn ComputeBackend,
) -> (Matrix, EmulationBreakdown) {
    assert_eq!(a.cols, b.rows, "gemm shape mismatch");
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut bd = EmulationBreakdown { pairs: cfg.pair_count(), ..Default::default() };
    if k == 0 || m == 0 || n == 0 {
        return (Matrix::zeros(m, n), bd);
    }
    let kchunk = cfg.k_chunk();
    if k <= kchunk {
        return emulated_gemm_chunk(a, b, cfg, backend);
    }
    // Rare large-k path: exact i32 accumulation caps each pass at the
    // chunk size; chunk results are summed in f64 (same rounding class as
    // one pass). Every breakdown field — `pairs` included — accumulates
    // across chunks: each chunk really executes its own pair_count()
    // slice-pair GEMMs, and the Fig 5 GMAC/s rates divide by `pairs`.
    bd.pairs = 0;
    let mut c = Matrix::zeros(m, n);
    let mut k0 = 0;
    while k0 < k {
        let kc = kchunk.min(k - k0);
        let (cc, cbd) =
            emulated_gemm_chunk(&a.block(0, k0, m, kc), &b.block(k0, 0, kc, n), cfg, backend);
        c.add_assign(&cc);
        bd.slice_s += cbd.slice_s;
        bd.gemm_s += cbd.gemm_s;
        bd.recompose_s += cbd.recompose_s;
        bd.pairs += cbd.pairs;
        k0 += kc;
    }
    (c, bd)
}

fn emulated_gemm_chunk(
    a: &Matrix,
    b: &Matrix,
    cfg: &OzakiConfig,
    backend: &dyn ComputeBackend,
) -> (Matrix, EmulationBreakdown) {
    let s = cfg.slices;
    let (m, n) = (a.rows, b.cols);
    let mut bd = EmulationBreakdown { pairs: cfg.pair_count(), ..Default::default() };

    let ts = std::time::Instant::now();
    let asl = slice_a(a, s, cfg.encoding);
    let bsl = slice_b(b, s, cfg.encoding);
    bd.slice_s = ts.elapsed().as_secs_f64();

    let tg = std::time::Instant::now();
    let schedule = PairSchedule::for_config(cfg);
    let mut acc = LevelAccumulator::new(m * n);
    let mut pbuf = vec![0i64; m * n];
    // Pairs grouped by weight level q = t+u, accumulated smallest weight
    // first (matches python/compile/ozaki.py::recompose exactly) — both
    // from the shared precomputed schedule, so no per-level pair vectors
    // are rebuilt. Each level is one backend batch: the backend may run
    // its pairs in any order (exact integer arithmetic), but levels feed
    // the compensated accumulator strictly in schedule order.
    for (pairs, w) in schedule.levels() {
        pbuf.fill(0);
        backend.slice_pair_gemm_batch(&asl, &bsl, pairs, &mut pbuf);
        acc.add_level(&pbuf, w);
    }
    bd.gemm_s = tg.elapsed().as_secs_f64();

    let tr = std::time::Instant::now();
    let c = recompose(acc, &asl.sigma, &bsl.sigma, m, n);
    bd.recompose_s = tr.elapsed().as_secs_f64();
    (c, bd)
}

// ---------------------------------------------------------------------
// Fused tile engine (see module docs)
// ---------------------------------------------------------------------

/// Baseline output-tile height of the fused engine: one row band of A
/// slices plus the tile accumulators stay cache-resident while all
/// `s(s+1)/2` pairs run. The geometry that actually runs is the
/// per-(kernel, shape-bucket) [`TileShape`](super::tune::TileShape) from
/// [`tune::tile_shape_for`](super::tune::tile_shape_for); this constant
/// is its `TileShape::BASELINE` and the `ADP_TUNE=off` pin.
pub const FUSED_MC: usize = 64;
/// Baseline output-tile width of the fused engine (see [`FUSED_MC`]).
pub const FUSED_NC: usize = 64;

/// Fused tile-major emulated DGEMM on the serial reference backend with a
/// throwaway workspace pool — the convenience form of [`fused_gemm_on`].
pub fn fused_gemm(a: &Matrix, b: &Matrix, cfg: &OzakiConfig) -> Matrix {
    fused_gemm_on(a, b, cfg, &SerialBackend, &WorkspacePool::default())
}

/// Fused tile-major emulated DGEMM: slice once, then run every weight
/// level of every [`FUSED_MC`]×[`FUSED_NC`] output tile while the
/// operands are cache-resident, through
/// [`ComputeBackend::fused_tile_gemm`]. Bitwise identical to
/// [`emulated_gemm_on`] for every input, backend and chunking (see the
/// module docs for the argument); scratch comes from `workspaces`, so a
/// warm pool makes the hot path allocation-free apart from the result.
pub fn fused_gemm_on(
    a: &Matrix,
    b: &Matrix,
    cfg: &OzakiConfig,
    backend: &dyn ComputeBackend,
    workspaces: &WorkspacePool,
) -> Matrix {
    assert_eq!(a.cols, b.rows, "gemm shape mismatch");
    let (m, k, n) = (a.rows, a.cols, b.cols);
    if k == 0 || m == 0 || n == 0 {
        return Matrix::zeros(m, n);
    }
    let kchunk = cfg.k_chunk();
    if k <= kchunk {
        return fused_gemm_chunk(a, b, cfg, backend, workspaces);
    }
    // Rare large-k path: chunk results are summed in f64 in the same
    // ascending-chunk order as the level-major driver, so the chunked
    // fused result stays bitwise identical to the chunked reference.
    let mut c = Matrix::zeros(m, n);
    let mut k0 = 0;
    while k0 < k {
        let kc = kchunk.min(k - k0);
        let (ac, bc) = (a.block(0, k0, m, kc), b.block(k0, 0, kc, n));
        c.add_assign(&fused_gemm_chunk(&ac, &bc, cfg, backend, workspaces));
        k0 += kc;
    }
    c
}

fn fused_gemm_chunk(
    a: &Matrix,
    b: &Matrix,
    cfg: &OzakiConfig,
    backend: &dyn ComputeBackend,
    workspaces: &WorkspacePool,
) -> Matrix {
    let asl = slice_a(a, cfg.slices, cfg.encoding);
    let bsl = slice_b(b, cfg.slices, cfg.encoding);
    let schedule = PairSchedule::for_config(cfg);
    let mut c = Matrix::zeros(a.rows, b.cols);
    backend.fused_tile_gemm(&asl, &bsl, &schedule, workspaces, &mut c);
    c
}

/// Packing/reuse accounting of one fused run (folded into the
/// [`WorkspacePool`] counters, surfaced by `coordinator::Metrics`).
#[derive(Clone, Copy, Debug, Default)]
pub struct FusedTally {
    /// Output tiles executed.
    pub tiles: u64,
    /// Operand panel builds: one per A band + one per B column tile,
    /// each covering every slice of the operand.
    pub packs: u64,
    /// Pair kernel calls served from panels packed earlier in the same
    /// tile — `pair_count - 1` per tile. The amortization the packing
    /// layer exists for.
    pub reuses: u64,
    /// Panel-scratch reallocations (`ensure_pack` growths) — folded into
    /// the pool's fresh-allocation gauge so a warm run that regrows pack
    /// scratch cannot hide from the zero-fresh-allocation counter tests.
    pub pack_growths: u64,
}

impl FusedTally {
    pub fn merge(&mut self, o: FusedTally) {
        self.tiles += o.tiles;
        self.packs += o.packs;
        self.reuses += o.reuses;
        self.pack_growths += o.pack_growths;
    }
}

/// The serial reference fused schedule: row bands of `shape.mc` output
/// rows in order, column tiles in order within each band, one workspace
/// for the whole pass, on the runtime-dispatched kernel and the
/// autotuned tile geometry. The [`ComputeBackend::fused_tile_gemm`]
/// default runs this; parallel backends also use it as their
/// small-problem inline path (bitwise identical either way).
pub fn fused_tile_gemm_serial(
    a: &SlicedMatrix,
    b: &SlicedMatrix,
    schedule: &PairSchedule,
    workspaces: &WorkspacePool,
    c: &mut Matrix,
) {
    fused_tile_gemm_serial_on(kernel::active(a.encoding), a, b, schedule, workspaces, c);
}

/// [`fused_tile_gemm_serial`] on an explicit kernel (the ablation bench
/// and the oracle tests compare kernels through this seam), resolving
/// the tile geometry through the autotuner.
pub fn fused_tile_gemm_serial_on(
    kern: &dyn SliceKernel,
    a: &SlicedMatrix,
    b: &SlicedMatrix,
    schedule: &PairSchedule,
    workspaces: &WorkspacePool,
    c: &mut Matrix,
) {
    let shape = super::tune::tile_shape_for(kern.id(), a.rows, b.rows);
    fused_tile_gemm_serial_shaped(kern, a, b, schedule, workspaces, shape, c);
}

/// [`fused_tile_gemm_serial_on`] with an explicit tile geometry — the
/// seam the autotuner probes through, and the one the tile-shape
/// property tests drive directly. Every `shape` yields the bitwise
/// identical result (see [`fused_band`]); only performance differs.
pub fn fused_tile_gemm_serial_shaped(
    kern: &dyn SliceKernel,
    a: &SlicedMatrix,
    b: &SlicedMatrix,
    schedule: &PairSchedule,
    workspaces: &WorkspacePool,
    shape: super::tune::TileShape,
    c: &mut Matrix,
) {
    let n = b.rows;
    assert_eq!(c.rows, a.rows, "output rows mismatch");
    assert_eq!(c.cols, n, "output cols mismatch");
    if a.rows == 0 || n == 0 {
        return;
    }
    workspaces.record_dispatch(kern.id(), Some(shape));
    let mut ws = workspaces.checkout(shape.elems());
    let mut tally = FusedTally::default();
    for (bi, band) in c.data.chunks_mut(shape.mc * n).enumerate() {
        tally.merge(fused_band(kern, a, b, schedule, bi * shape.mc, shape, &mut ws, band));
    }
    workspaces.record_tiles(tally.tiles);
    workspaces.record_panels(tally.packs, tally.reuses);
    workspaces.record_pack_growth(tally.pack_growths);
}

/// One row band of the fused schedule: every `shape.nc`-wide column
/// tile of output rows `[row0, row0 + band.len()/n)`, left to right.
/// `band` is the contiguous row-major sub-slice of C for exactly those
/// rows. Disjoint bands may run concurrently — each tile's arithmetic
/// touches only its own elements.
///
/// This is where the packing layer earns its keep: the band's A slice
/// rows are packed **once** into the workspace's panel scratch and
/// reused by every column tile and every slice pair; each column tile
/// packs its B panel once and reuses it across all `s(s+1)/2` pairs.
/// Per output element the arithmetic sequence is exactly the level-major
/// reference one (every kernel computes the exact integer pair product;
/// levels feed the compensated accumulator smallest weight first; the
/// descale passes are per-element) — see the module docs for why that
/// makes any tile partition, any tile geometry and any kernel bitwise
/// identical.
#[allow(clippy::too_many_arguments)]
pub fn fused_band(
    kern: &dyn SliceKernel,
    a: &SlicedMatrix,
    b: &SlicedMatrix,
    schedule: &PairSchedule,
    row0: usize,
    shape: super::tune::TileShape,
    ws: &mut Workspace,
    band: &mut [f64],
) -> FusedTally {
    let n = b.rows;
    let k = a.cols;
    let s = schedule.slices();
    assert!(k <= K_CHUNK, "k chunking is handled by the fused gemm drivers");
    debug_assert!(n > 0 && band.len() % n == 0, "band must be whole output rows");
    debug_assert_eq!(s, a.s, "schedule must match the decomposition");
    let rows = band.len() / n;
    // A truncated schedule keeps only pairs with t + u <= s-1-depth, so
    // slice panels beyond index s-1-depth are never read: skip packing
    // them entirely.
    let s_used = s - schedule.truncation_depth();
    let ab = kern.a_slice_bytes(rows, k);
    let bb_max = kern.b_slice_bytes(shape.nc.min(n), k);
    assert!(ws.capacity() >= rows * shape.nc.min(n), "workspace too small for a band tile");
    let grew = ws.ensure_pack(s_used * ab, s_used * bb_max);
    let Workspace { pbuf, hi, lo, apack, bpack, rbuf: _ } = ws;
    let mut tally = FusedTally { pack_growths: grew as u64, ..FusedTally::default() };
    // Pack the band's A rows once — every column tile and every slice
    // pair below reads these panels.
    for t in 0..s_used {
        kern.pack_a_slice(a, t, row0, rows, &mut apack[t * ab..(t + 1) * ab]);
    }
    tally.packs += 1;
    let mut col0 = 0;
    while col0 < n {
        let cols = shape.nc.min(n - col0);
        let bb = kern.b_slice_bytes(cols, k);
        for u in 0..s_used {
            kern.pack_b_slice(b, u, col0, cols, &mut bpack[u * bb..(u + 1) * bb]);
        }
        tally.packs += 1;
        let e = rows * cols;
        let hi_t = &mut hi[..e];
        let lo_t = &mut lo[..e];
        let pb = &mut pbuf[..e];
        hi_t.fill(0.0);
        lo_t.fill(0.0);
        for (pairs, w) in schedule.levels() {
            pb.fill(0);
            for &(t, u) in pairs {
                kern.pair_tile(
                    &apack[t * ab..(t + 1) * ab],
                    &bpack[u * bb..(u + 1) * bb],
                    rows,
                    cols,
                    k,
                    pb,
                );
            }
            add_level_into(hi_t, lo_t, pb, w);
        }
        descale_tile(hi_t, lo_t, &a.sigma, &b.sigma, row0, rows, col0, cols);
        for i in 0..rows {
            let src = i * cols;
            let dst = i * n + col0;
            for j in 0..cols {
                band[dst + j] = hi_t[src + j] + lo_t[src + j];
            }
        }
        tally.tiles += 1;
        tally.reuses += (schedule.pair_count() as u64).saturating_sub(1);
        col0 += cols;
    }
    tally
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::gemm;
    use crate::ozaki::SliceEncoding;
    use crate::util::{prop, Rng};

    fn max_rel_err(c: &Matrix, a: &Matrix, b: &Matrix) -> f64 {
        // componentwise error against the double-double reference, scaled
        // by (|A||B|)_ij — the Grade A denominator.
        let c_ref = a.matmul_dd(b);
        let denom = a.abs().matmul_dd(&b.abs());
        let mut worst = 0.0f64;
        for i in 0..c.rows {
            for j in 0..c.cols {
                let d = denom.at(i, j);
                if d > 0.0 {
                    worst = worst.max((c.at(i, j) - c_ref.at(i, j)).abs() / d);
                }
            }
        }
        worst
    }

    #[test]
    fn pair_gemm_matches_naive() {
        let mut rng = Rng::new(30);
        let (m, k, n) = (5, 17, 7);
        let a = Matrix::uniform(m, k, -2.0, 2.0, &mut rng);
        let b = Matrix::uniform(k, n, -2.0, 2.0, &mut rng);
        let asl = slice_a(&a, 4, SliceEncoding::Unsigned);
        let bsl = slice_b(&b, 4, SliceEncoding::Unsigned);
        for t in 0..4 {
            for u in 0..4 {
                let mut out = vec![0i64; m * n];
                slice_pair_gemm(&asl, t, &bsl, u, &mut out);
                for i in 0..m {
                    for j in 0..n {
                        let mut expect = 0i64;
                        for l in 0..k {
                            expect += asl.slice_row(t, i)[l] as i64
                                * bsl.slice_row(u, j)[l] as i64;
                        }
                        assert_eq!(out[i * n + j], expect, "t={t} u={u} i={i} j={j}");
                    }
                }
            }
        }
    }

    #[test]
    fn row_ranged_pair_gemm_matches_full() {
        // Any row partition must reproduce the full-matrix result exactly.
        let mut rng = Rng::new(36);
        let (m, k, n) = (11, 23, 6);
        let a = Matrix::uniform(m, k, -2.0, 2.0, &mut rng);
        let b = Matrix::uniform(k, n, -2.0, 2.0, &mut rng);
        let asl = slice_a(&a, 3, SliceEncoding::Unsigned);
        let bsl = slice_b(&b, 3, SliceEncoding::Unsigned);
        let mut full = vec![0i64; m * n];
        slice_pair_gemm(&asl, 1, &bsl, 0, &mut full);
        for split in [1, 2, 3, 5, 11] {
            let mut parts = vec![0i64; m * n];
            let mut row0 = 0;
            while row0 < m {
                let rows = split.min(m - row0);
                slice_pair_gemm_rows(
                    &asl,
                    1,
                    &bsl,
                    0,
                    row0,
                    rows,
                    &mut parts[row0 * n..(row0 + rows) * n],
                );
                row0 += rows;
            }
            assert_eq!(parts, full, "split={split}");
        }
    }

    #[test]
    fn tile_ranged_pair_gemm_matches_full() {
        // Any 2-D tile partition must reproduce the full-matrix result
        // exactly (the fused-engine kernel invariant).
        let mut rng = Rng::new(39);
        let (m, k, n) = (11, 19, 10);
        let a = Matrix::uniform(m, k, -2.0, 2.0, &mut rng);
        let b = Matrix::uniform(k, n, -2.0, 2.0, &mut rng);
        let asl = slice_a(&a, 3, SliceEncoding::Unsigned);
        let bsl = slice_b(&b, 3, SliceEncoding::Unsigned);
        let mut full = vec![0i64; m * n];
        slice_pair_gemm(&asl, 2, &bsl, 0, &mut full);
        for (tr, tc) in [(1usize, 1usize), (2, 3), (4, 4), (11, 10), (3, 7)] {
            let mut got = vec![0i64; m * n];
            let mut row0 = 0;
            while row0 < m {
                let rows = tr.min(m - row0);
                let mut col0 = 0;
                while col0 < n {
                    let cols = tc.min(n - col0);
                    let mut tile = vec![0i64; rows * cols];
                    slice_pair_gemm_tile(&asl, 2, &bsl, 0, row0, rows, col0, cols, &mut tile);
                    for i in 0..rows {
                        for j in 0..cols {
                            got[(row0 + i) * n + col0 + j] += tile[i * cols + j];
                        }
                    }
                    col0 += cols;
                }
                row0 += rows;
            }
            assert_eq!(got, full, "tile {tr}x{tc}");
        }
    }

    fn assert_bitwise(c1: &Matrix, c2: &Matrix, what: &str) {
        assert_eq!((c1.rows, c1.cols), (c2.rows, c2.cols), "{what}: shape");
        for (x, y) in c1.data.iter().zip(&c2.data) {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: {x} vs {y}");
        }
    }

    #[test]
    fn fused_is_bitwise_identical_to_level_major() {
        // Multi-band / multi-tile shapes straddling the FUSED_MC/FUSED_NC
        // boundaries, both encodings.
        let mut rng = Rng::new(40);
        let pool = crate::backend::WorkspacePool::new();
        for (m, k, n) in [(1, 1, 1), (8, 12, 8), (65, 20, 63), (70, 9, 130), (129, 7, 64)] {
            let a = Matrix::uniform(m, k, -2.0, 2.0, &mut rng);
            let b = Matrix::uniform(k, n, -2.0, 2.0, &mut rng);
            for enc in [SliceEncoding::Unsigned, SliceEncoding::Signed] {
                let cfg = OzakiConfig::with_encoding(5, enc);
                let c_ref = emulated_gemm(&a, &b, &cfg);
                let c_fus = fused_gemm_on(&a, &b, &cfg, &SerialBackend, &pool);
                assert_bitwise(&c_ref, &c_fus, &format!("fused ({m},{k},{n}) {enc:?}"));
            }
        }
        let st = pool.stats();
        assert!(st.fused_tiles > 0 && st.checkouts > 0);
    }

    #[test]
    fn fused_chunked_k_is_bitwise_identical_to_level_major_chunked() {
        let mut rng = Rng::new(41);
        let (m, k, n) = (9, 70, 8);
        let a = Matrix::uniform(m, k, -2.0, 2.0, &mut rng);
        let b = Matrix::uniform(k, n, -2.0, 2.0, &mut rng);
        for kc in [16usize, 64, 1] {
            let cfg = OzakiConfig::new(6).with_k_chunk(kc);
            let c_ref = emulated_gemm(&a, &b, &cfg);
            let c_fus = fused_gemm(&a, &b, &cfg);
            assert_bitwise(&c_ref, &c_fus, &format!("fused chunked kc={kc}"));
        }
    }

    #[test]
    fn fused_empty_shapes() {
        let pool = crate::backend::WorkspacePool::new();
        let cfg = OzakiConfig::new(4);
        for (m, k, n) in [(0usize, 3usize, 2usize), (2, 0, 2), (2, 3, 0)] {
            let c = fused_gemm_on(
                &Matrix::zeros(m, k),
                &Matrix::zeros(k, n),
                &cfg,
                &SerialBackend,
                &pool,
            );
            assert_eq!((c.rows, c.cols), (m, n));
            assert!(c.data.iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn breakdown_pairs_accumulate_across_chunks() {
        // Satellite fix: the chunked-k path must report the pair GEMMs it
        // actually executed (one pair_count per chunk), not one chunk's.
        let mut rng = Rng::new(42);
        let (m, k, n) = (5, 70, 4);
        let a = Matrix::uniform(m, k, -2.0, 2.0, &mut rng);
        let b = Matrix::uniform(k, n, -2.0, 2.0, &mut rng);
        let cfg = OzakiConfig::new(7);
        let (_, bd_one) = emulated_gemm_with_breakdown(&a, &b, &cfg);
        assert_eq!(bd_one.pairs, cfg.pair_count(), "single pass runs pair_count pairs");
        let chunked = cfg.with_k_chunk(16); // ceil(70/16) = 5 chunks
        let (_, bd) = emulated_gemm_with_breakdown(&a, &b, &chunked);
        assert_eq!(bd.pairs, 5 * cfg.pair_count(), "pairs must accumulate across chunks");
    }

    #[test]
    fn emulated_matches_fp64_at_7_slices() {
        let mut rng = Rng::new(31);
        for n in [8, 33, 64] {
            let a = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
            let b = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
            let c = emulated_gemm(&a, &b, &OzakiConfig::new(7));
            let e_emu = max_rel_err(&c, &a, &b);
            let e_nat = max_rel_err(&gemm(&a, &b), &a, &b);
            // FP64-comparable: within a small factor of native error.
            assert!(e_emu <= 8.0 * e_nat.max(f64::EPSILON), "n={n} emu={e_emu} nat={e_nat}");
        }
    }

    #[test]
    fn error_decreases_with_slices() {
        let mut rng = Rng::new(32);
        let n = 32;
        let a = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
        let b = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
        let mut last = f64::INFINITY;
        for s in [2, 3, 4, 5, 6] {
            let e = max_rel_err(&emulated_gemm(&a, &b, &OzakiConfig::new(s)), &a, &b);
            assert!(e < last, "s={s}: {e} !< {last}");
            last = e;
        }
    }

    #[test]
    fn signed_and_unsigned_agree_to_their_bits() {
        let mut rng = Rng::new(33);
        let n = 24;
        let a = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
        let b = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
        let cu = emulated_gemm(&a, &b, &OzakiConfig::with_encoding(7, SliceEncoding::Unsigned));
        let cs = emulated_gemm(&a, &b, &OzakiConfig::with_encoding(8, SliceEncoding::Signed));
        let eu = max_rel_err(&cu, &a, &b);
        let es = max_rel_err(&cs, &a, &b);
        assert!(eu < 1e-15 && es < 1e-15, "unsigned={eu} signed={es}");
    }

    #[test]
    fn wide_exponent_span_needs_more_slices() {
        // Test-2-flavoured input: slices sized by ESC recover accuracy.
        let mut rng = Rng::new(34);
        let n = 16;
        let mut a = Matrix::uniform(n, n, 1.0, 2.0, &mut rng);
        let mut b = Matrix::uniform(n, n, 1.0, 2.0, &mut rng);
        for j in 0..n {
            let sc = 2f64.powi((j as i32 - 8) * 5);
            for i in 0..n {
                *a.at_mut(i, j) *= sc;
                *b.at_mut(j, i) /= sc;
            }
        }
        let e7 = max_rel_err(&emulated_gemm(&a, &b, &OzakiConfig::new(7)), &a, &b);
        let e17 = max_rel_err(&emulated_gemm(&a, &b, &OzakiConfig::new(17)), &a, &b);
        assert!(e17 < 1e-15, "e17={e17}");
        assert!(e7 > 100.0 * e17, "e7={e7} should be much worse than e17={e17}");
    }

    #[test]
    fn negative_zero_inputs() {
        let a = Matrix::from_rows(2, 2, vec![-0.0, 1.0, 2.0, -0.0]);
        let b = Matrix::from_rows(2, 2, vec![3.0, -0.0, -0.0, 4.0]);
        let c = emulated_gemm(&a, &b, &OzakiConfig::new(7));
        let r = gemm(&a, &b);
        for (x, y) in c.data.iter().zip(&r.data) {
            assert_eq!(x.abs(), y.abs()); // -0 treated as 0 (§5.1)
        }
    }

    #[test]
    fn chunked_k_matches_one_pass() {
        // Satellite coverage for the large-k path: force chunking at small
        // k via the injectable chunk size and compare against the one-pass
        // result. Chunk sums commute with the compensated recompose only
        // up to final rounding, so the bound is a few component eps.
        let mut rng = Rng::new(37);
        for (m, k, n, kc) in [(9, 70, 8, 16), (5, 64, 5, 64), (4, 65, 6, 64), (7, 40, 7, 1)] {
            let a = Matrix::uniform(m, k, -2.0, 2.0, &mut rng);
            let b = Matrix::uniform(k, n, -2.0, 2.0, &mut rng);
            let one = emulated_gemm(&a, &b, &OzakiConfig::new(7));
            let chunked = emulated_gemm(&a, &b, &OzakiConfig::new(7).with_k_chunk(kc));
            let denom = a.abs().matmul_dd(&b.abs());
            for idx in 0..one.data.len() {
                let tol = 4.0 * (k as f64 + 4.0) * f64::EPSILON * denom.data[idx];
                let d = (chunked.data[idx] - one.data[idx]).abs();
                assert!(d <= tol, "kc={kc} idx={idx}: |{d}| > {tol}");
            }
        }
    }

    #[test]
    fn chunked_path_stays_grade_a() {
        // The chunked result must hold the same componentwise bound as the
        // one-pass pipeline, not merely agree with it.
        let mut rng = Rng::new(38);
        let (m, k, n) = (8, 96, 9);
        let a = Matrix::uniform(m, k, -3.0, 3.0, &mut rng);
        let b = Matrix::uniform(k, n, -3.0, 3.0, &mut rng);
        let c = emulated_gemm(&a, &b, &OzakiConfig::new(7).with_k_chunk(17));
        let e = max_rel_err(&c, &a, &b);
        let bound = (k as f64 + 4.0) * f64::EPSILON;
        assert!(e <= bound, "err {e} > {bound}");
    }

    #[test]
    fn k_chunk_is_clamped_to_exactness_cap() {
        // A chunk size beyond K_CHUNK would overflow the i32 accumulator;
        // the config clamps rather than trusting the caller.
        assert_eq!(OzakiConfig::new(7).with_k_chunk(usize::MAX).k_chunk(), K_CHUNK);
        assert_eq!(OzakiConfig::new(7).with_k_chunk(0).k_chunk(), 1);
        assert_eq!(OzakiConfig::new(7).k_chunk(), K_CHUNK);
    }

    #[test]
    fn prop_emulated_gemm_grade_a_uniform() {
        prop::check("emulated gemm componentwise error", 12, |rng| {
            let m = rng.int(2, 24) as usize;
            let k = rng.int(2, 40) as usize;
            let n = rng.int(2, 24) as usize;
            let a = Matrix::uniform(m, k, -3.0, 3.0, rng);
            let b = Matrix::uniform(k, n, -3.0, 3.0, rng);
            let c = emulated_gemm(&a, &b, &OzakiConfig::new(7));
            let e = max_rel_err(&c, &a, &b);
            let bound = (k as f64 + 4.0) * f64::EPSILON;
            prop::assert_that(e <= bound, format!("({m},{k},{n}): err {e} > {bound}"))
        });
    }

    #[test]
    fn prop_permutation_invariance() {
        // Fixed-point emulation is invariant to summation order (§4): a
        // simultaneous permutation of A's columns and B's rows must give
        // the *bitwise identical* result.
        prop::check("k-permutation invariance", 20, |rng| {
            let (m, k, n) = (6, 12, 5);
            let a = Matrix::uniform(m, k, -2.0, 2.0, rng);
            let b = Matrix::uniform(k, n, -2.0, 2.0, rng);
            let mut perm: Vec<usize> = (0..k).collect();
            rng.shuffle(&mut perm);
            let ap = Matrix::from_fn(m, k, |i, j| a.at(i, perm[j]));
            let bp = Matrix::from_fn(k, n, |i, j| b.at(perm[i], j));
            let c1 = emulated_gemm(&a, &b, &OzakiConfig::new(6));
            let c2 = emulated_gemm(&ap, &bp, &OzakiConfig::new(6));
            for (x, y) in c1.data.iter().zip(&c2.data) {
                if x.to_bits() != y.to_bits() {
                    return Err(format!("not bitwise invariant: {x} vs {y}"));
                }
            }
            Ok(())
        });
    }
}
