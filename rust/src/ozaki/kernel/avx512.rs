//! AVX-512 slice-pair microkernels — the widest CPU analog of the
//! paper's INT8 tensor-core (IMMA) path: `vpdpbusd` (AVX-512 VNNI) is
//! the same u8×s8 dot-product-accumulate primitive IMMA / `dp4a` expose,
//! sixteen i32 lanes at a time.
//!
//! Both kernels compute the *exact* integer pair product `P_tu` for the
//! digits as stored, so their results are bitwise identical to the
//! scalar oracle by construction (exact integer arithmetic commutes with
//! any evaluation order); the property suites assert it anyway.
//!
//! # Panel formats
//!
//! Same shape family as the AVX2 kernels, widened to [`NR`] = 16 output
//! columns per 64-byte group:
//!
//! * **B panels** are k-interleaved and [`NR`]-wide:
//!   `[ceil(cols/NR)][ceil(k/G)][NR][G]`, one 64-byte group per
//!   (column-block, k-group) — a single zmm load feeds all `NR` output
//!   columns. `G` is 4 bytes for `vpdpbusd`, 2 i16 (4 bytes) for
//!   `vpmaddwd`.
//! * **A panels** stay row-major (one k-group is broadcast to all lanes
//!   per step). The VNNI kernel stores *two* u8 planes per slice — the
//!   positive and negative parts of each digit — and the `vpmaddwd`
//!   kernel stores sign-extended i16 rows.
//!
//! # No-overflow argument (the VNNI kernel)
//!
//! `vpdpbusd` multiplies four unsigned bytes `u` by four signed bytes
//! `s`, sums the four products, and accumulates into an i32 lane. Unlike
//! `vpmaddubsw` there is **no saturating i16 stage**: the four u8×s8
//! products are summed as intermediates that always fit
//! (`|u·s| <= 255·128 = 32640`, and the hardware forms the 4-term sum at
//! i32 width before accumulating; `vpdpbusds` is the *saturating*
//! variant, which this kernel deliberately does not use). Exactness
//! therefore reduces to the i32 accumulator bound alone:
//!
//! * Stored digits: unsigned encoding — leading slice in `[-64, 64]`,
//!   sub-leading in `[-128, 127]`; signed encoding — all slices in
//!   `[-127, 127]`. Every digit `d` splits as `d = d⁺ - d⁻` with
//!   `d⁺ = max(d, 0) ∈ [0, 127]` and `d⁻ = max(-d, 0) ∈ [0, 128]`, so
//!   the split serves *both* encodings.
//! * Per-lane plane totals: `|Σ d⁺·b| <= K_CHUNK·127·128` and
//!   `|Σ d⁻·b| <= K_CHUNK·128·128 = 2^31 - 2^14 < 2^31` — the same
//!   `K_CHUNK = 2^17 - 1` cap that already guarantees the scalar i32
//!   accumulator, so the i32 lanes never wrap for `k <= K_CHUNK`.
//! * The final lane-wise `acc⁺ - acc⁻` equals the true pair dot, which
//!   obeys the same bound, so the wrapping `vpsubd` is exact.
//!
//! The `vpmaddwd` kernel (AVX-512BW, for parts without VNNI) needs no
//! split: products of sign-extended i8 values are at most
//! `128·128 = 2^14`, one `vpmaddwd` pair sum is at most `2^15`, and the
//! per-lane totals obey the `K_CHUNK` bound above — the AVX2 `pmaddwd`
//! argument verbatim, at twice the width.

use std::arch::x86_64::*;

use super::{KernelId, SliceKernel};
use crate::ozaki::slicing::SlicedMatrix;

/// Output columns per packed B group (i32 lanes of one zmm register).
pub const NR: usize = 16;

pub static VNNI: VnniKernel = VnniKernel;
pub static PMADDWD512: Pmaddwd512Kernel = Pmaddwd512Kernel;

#[inline]
fn groups(k: usize, g: usize) -> usize {
    k.div_ceil(g)
}

/// u8×s8 pair kernel on `vpdpbusd` (AVX-512 VNNI) over the pos/neg digit
/// split (see the module docs for the no-overflow argument). Serves both
/// encodings — the split is valid for any digit in `[-128, 127]`.
pub struct VnniKernel;

impl SliceKernel for VnniKernel {
    fn id(&self) -> KernelId {
        KernelId::Avx512Vnni
    }

    fn a_slice_bytes(&self, rows: usize, k: usize) -> usize {
        2 * rows * groups(k, 4) * 4
    }

    fn b_slice_bytes(&self, cols: usize, k: usize) -> usize {
        cols.div_ceil(NR) * groups(k, 4) * 64
    }

    fn pack_a_slice(&self, a: &SlicedMatrix, t: usize, row0: usize, rows: usize, dst: &mut [u8]) {
        let k = a.cols;
        let rb = groups(k, 4) * 4;
        let plane = rows * rb;
        debug_assert_eq!(dst.len(), 2 * plane);
        dst.fill(0);
        let src = a.slice_rows(t, row0, rows);
        for i in 0..rows {
            let row = &src[i * k..(i + 1) * k];
            for (l, &dgt) in row.iter().enumerate() {
                let d = dgt as i32;
                dst[i * rb + l] = d.max(0) as u8;
                dst[plane + i * rb + l] = (-d).max(0) as u8;
            }
        }
    }

    fn pack_b_slice(&self, b: &SlicedMatrix, u: usize, col0: usize, cols: usize, dst: &mut [u8]) {
        let k = b.cols;
        let kg = groups(k, 4);
        let nb = cols.div_ceil(NR);
        debug_assert_eq!(dst.len(), nb * kg * 64);
        dst.fill(0);
        let src = b.slice_rows(u, col0, cols);
        for jb in 0..nb {
            let base = jb * kg * 64;
            for c in 0..NR {
                let j = jb * NR + c;
                if j >= cols {
                    break;
                }
                let row = &src[j * k..(j + 1) * k];
                for (l, &dgt) in row.iter().enumerate() {
                    dst[base + (l / 4) * 64 + c * 4 + (l % 4)] = dgt as u8;
                }
            }
        }
    }

    fn pair_tile(
        &self,
        apack: &[u8],
        bpack: &[u8],
        rows: usize,
        cols: usize,
        k: usize,
        out: &mut [i64],
    ) {
        debug_assert!(apack.len() >= self.a_slice_bytes(rows, k));
        debug_assert!(bpack.len() >= self.b_slice_bytes(cols, k));
        debug_assert_eq!(out.len(), rows * cols);
        // SAFETY: the kernel is only reachable through the dispatch layer
        // (or `available_kernels`), both of which gate on a cached
        // `is_x86_feature_detected!` for avx512f/bw/vnni; panel sizes are
        // checked above and every pointer stays inside the checked
        // extents.
        unsafe { vnni_tile(apack, bpack, rows, cols, k, out) }
    }
}

#[target_feature(enable = "avx512f,avx512bw,avx512vnni")]
unsafe fn vnni_tile(
    apack: &[u8],
    bpack: &[u8],
    rows: usize,
    cols: usize,
    k: usize,
    out: &mut [i64],
) {
    let kg = k.div_ceil(4);
    let rb = kg * 4;
    let plane = rows * rb;
    let nb = cols.div_ceil(NR);
    for i in 0..rows {
        let pos = apack.as_ptr().add(i * rb);
        let neg = apack.as_ptr().add(plane + i * rb);
        for jb in 0..nb {
            let bb = bpack.as_ptr().add(jb * kg * 64);
            let mut accp = _mm512_setzero_si512();
            let mut accn = _mm512_setzero_si512();
            for g in 0..kg {
                let ap = _mm512_set1_epi32(pos.add(g * 4).cast::<i32>().read_unaligned());
                let an = _mm512_set1_epi32(neg.add(g * 4).cast::<i32>().read_unaligned());
                let bv = _mm512_loadu_si512(bb.add(g * 64).cast());
                accp = _mm512_dpbusd_epi32(accp, ap, bv);
                accn = _mm512_dpbusd_epi32(accn, an, bv);
            }
            let diff = _mm512_sub_epi32(accp, accn);
            let mut lanes = [0i32; 16];
            _mm512_storeu_si512(lanes.as_mut_ptr().cast(), diff);
            let take = NR.min(cols - jb * NR);
            for (c, &v) in lanes.iter().take(take).enumerate() {
                out[i * cols + jb * NR + c] += v as i64;
            }
        }
    }
}

/// Sign-extended i16 pair kernel on 512-bit `vpmaddwd` (AVX-512BW) —
/// exact for any i8 digit range without a split pass. The fallback tier
/// for AVX-512 parts without VNNI; serves both encodings.
pub struct Pmaddwd512Kernel;

impl SliceKernel for Pmaddwd512Kernel {
    fn id(&self) -> KernelId {
        KernelId::Avx512Pmaddwd
    }

    fn a_slice_bytes(&self, rows: usize, k: usize) -> usize {
        rows * groups(k, 2) * 4
    }

    fn b_slice_bytes(&self, cols: usize, k: usize) -> usize {
        cols.div_ceil(NR) * groups(k, 2) * 64
    }

    fn pack_a_slice(&self, a: &SlicedMatrix, t: usize, row0: usize, rows: usize, dst: &mut [u8]) {
        let k = a.cols;
        let rb = groups(k, 2) * 4;
        debug_assert_eq!(dst.len(), rows * rb);
        dst.fill(0);
        let src = a.slice_rows(t, row0, rows);
        for i in 0..rows {
            let row = &src[i * k..(i + 1) * k];
            for (l, &dgt) in row.iter().enumerate() {
                let v = (dgt as i16).to_le_bytes();
                dst[i * rb + 2 * l] = v[0];
                dst[i * rb + 2 * l + 1] = v[1];
            }
        }
    }

    fn pack_b_slice(&self, b: &SlicedMatrix, u: usize, col0: usize, cols: usize, dst: &mut [u8]) {
        let k = b.cols;
        let kg = groups(k, 2);
        let nb = cols.div_ceil(NR);
        debug_assert_eq!(dst.len(), nb * kg * 64);
        dst.fill(0);
        let src = b.slice_rows(u, col0, cols);
        for jb in 0..nb {
            let base = jb * kg * 64;
            for c in 0..NR {
                let j = jb * NR + c;
                if j >= cols {
                    break;
                }
                let row = &src[j * k..(j + 1) * k];
                for (l, &dgt) in row.iter().enumerate() {
                    let v = (dgt as i16).to_le_bytes();
                    let off = base + (l / 2) * 64 + c * 4 + (l % 2) * 2;
                    dst[off] = v[0];
                    dst[off + 1] = v[1];
                }
            }
        }
    }

    fn pair_tile(
        &self,
        apack: &[u8],
        bpack: &[u8],
        rows: usize,
        cols: usize,
        k: usize,
        out: &mut [i64],
    ) {
        debug_assert!(apack.len() >= self.a_slice_bytes(rows, k));
        debug_assert!(bpack.len() >= self.b_slice_bytes(cols, k));
        debug_assert_eq!(out.len(), rows * cols);
        // SAFETY: as in `VnniKernel::pair_tile` — avx512f/bw presence is
        // gated by the dispatch layer, extents are checked above.
        unsafe { pmaddwd512_tile(apack, bpack, rows, cols, k, out) }
    }
}

#[target_feature(enable = "avx512f,avx512bw")]
unsafe fn pmaddwd512_tile(
    apack: &[u8],
    bpack: &[u8],
    rows: usize,
    cols: usize,
    k: usize,
    out: &mut [i64],
) {
    let kg = k.div_ceil(2);
    let rb = kg * 4;
    let nb = cols.div_ceil(NR);
    for i in 0..rows {
        let ar = apack.as_ptr().add(i * rb);
        for jb in 0..nb {
            let bb = bpack.as_ptr().add(jb * kg * 64);
            let mut acc = _mm512_setzero_si512();
            for g in 0..kg {
                let av = _mm512_set1_epi32(ar.add(g * 4).cast::<i32>().read_unaligned());
                let bv = _mm512_loadu_si512(bb.add(g * 64).cast());
                acc = _mm512_add_epi32(acc, _mm512_madd_epi16(av, bv));
            }
            let mut lanes = [0i32; 16];
            _mm512_storeu_si512(lanes.as_mut_ptr().cast(), acc);
            let take = NR.min(cols - jb * NR);
            for (c, &v) in lanes.iter().take(take).enumerate() {
                out[i * cols + jb * NR + c] += v as i64;
            }
        }
    }
}
