//! The scalar reference microkernel — the oracle every SIMD kernel must
//! match bit for bit.
//!
//! [`tile_unpacked`] is the original 2x4 register-blocked loop nest of
//! `slice_pair_gemm_tile`, extracted so it can run either directly on the
//! slice tensors (the dispatch fast path when the scalar kernel is
//! selected — no packing copy) or on packed plain-row panels through the
//! [`SliceKernel`] interface (so the packed-panel plumbing itself is
//! covered by the same oracle). Both call the identical arithmetic:
//! exact i32 accumulation chains (valid for `k <= K_CHUNK`), widened to
//! the caller's i64 tile buffer.

use super::{KernelId, SliceKernel};
use crate::ozaki::slicing::SlicedMatrix;

/// Reinterpret a byte panel as the i8 digits it stores (bit patterns are
/// preserved by packing; see [`ScalarKernel::pack_a_slice`]).
#[inline]
fn as_i8(b: &[u8]) -> &[i8] {
    // SAFETY: i8 and u8 have identical size/alignment and every bit
    // pattern is valid for both.
    unsafe { std::slice::from_raw_parts(b.as_ptr() as *const i8, b.len()) }
}

/// `out[i*cols + j] += sum_l at[i*k + l] * bu[j*k + l]` — the scalar
/// slice-pair tile GEMM on two contiguous row-major digit blocks (`at` is
/// `rows x k`, `bu` is `cols x k`; B slices are stored transposed, so both
/// operands walk k contiguously). Row-major x row-major(transposed) dot
/// kernel, 2x4 register blocked (8 independent i32 accumulator chains for
/// the auto-vectorizer). Exact for `k <= K_CHUNK`.
pub fn tile_unpacked(at: &[i8], bu: &[i8], rows: usize, cols: usize, k: usize, out: &mut [i64]) {
    debug_assert!(at.len() >= rows * k);
    debug_assert!(bu.len() >= cols * k);
    debug_assert_eq!(out.len(), rows * cols);
    let n = cols;
    let mut i = 0;
    while i + 2 <= rows {
        let a0 = &at[i * k..(i + 1) * k];
        let a1 = &at[(i + 1) * k..(i + 2) * k];
        let mut j = 0;
        while j + 4 <= n {
            let b0 = &bu[j * k..(j + 1) * k];
            let b1 = &bu[(j + 1) * k..(j + 2) * k];
            let b2 = &bu[(j + 2) * k..(j + 3) * k];
            let b3 = &bu[(j + 3) * k..(j + 4) * k];
            let mut c0 = [0i32; 4];
            let mut c1 = [0i32; 4];
            for l in 0..k {
                let (x0, x1) = (a0[l] as i32, a1[l] as i32);
                let y = [b0[l] as i32, b1[l] as i32, b2[l] as i32, b3[l] as i32];
                for r in 0..4 {
                    c0[r] += x0 * y[r];
                    c1[r] += x1 * y[r];
                }
            }
            for r in 0..4 {
                out[i * n + j + r] += c0[r] as i64;
                out[(i + 1) * n + j + r] += c1[r] as i64;
            }
            j += 4;
        }
        while j < n {
            let b0 = &bu[j * k..(j + 1) * k];
            let (mut c00, mut c10) = (0i32, 0i32);
            for l in 0..k {
                c00 += a0[l] as i32 * b0[l] as i32;
                c10 += a1[l] as i32 * b0[l] as i32;
            }
            out[i * n + j] += c00 as i64;
            out[(i + 1) * n + j] += c10 as i64;
            j += 1;
        }
        i += 2;
    }
    if i < rows {
        let a0 = &at[i * k..(i + 1) * k];
        for j in 0..n {
            let b0 = &bu[j * k..(j + 1) * k];
            let mut c = 0i32;
            for l in 0..k {
                c += a0[l] as i32 * b0[l] as i32;
            }
            out[i * n + j] += c as i64;
        }
    }
}

/// The reference kernel: plain row-major panels (packing is a straight
/// copy of the slice rows, no interleave, no padding) and the scalar loop
/// nest above.
pub struct ScalarKernel;

impl SliceKernel for ScalarKernel {
    fn id(&self) -> KernelId {
        KernelId::Scalar
    }

    fn a_slice_bytes(&self, rows: usize, k: usize) -> usize {
        rows * k
    }

    fn b_slice_bytes(&self, cols: usize, k: usize) -> usize {
        cols * k
    }

    fn pack_a_slice(&self, a: &SlicedMatrix, t: usize, row0: usize, rows: usize, dst: &mut [u8]) {
        let src = a.slice_rows(t, row0, rows);
        debug_assert_eq!(dst.len(), src.len());
        for (d, &s) in dst.iter_mut().zip(src) {
            *d = s as u8;
        }
    }

    fn pack_b_slice(&self, b: &SlicedMatrix, u: usize, col0: usize, cols: usize, dst: &mut [u8]) {
        let src = b.slice_rows(u, col0, cols);
        debug_assert_eq!(dst.len(), src.len());
        for (d, &s) in dst.iter_mut().zip(src) {
            *d = s as u8;
        }
    }

    fn pair_tile(
        &self,
        apack: &[u8],
        bpack: &[u8],
        rows: usize,
        cols: usize,
        k: usize,
        out: &mut [i64],
    ) {
        tile_unpacked(as_i8(apack), as_i8(bpack), rows, cols, k, out);
    }
}
