//! AVX2 slice-pair microkernels — the CPU stand-in for the paper's INT8
//! tensor-core (IMMA / dp4a) path.
//!
//! Both kernels compute the *exact* integer pair product `P_tu` for the
//! digits as stored, so their results are bitwise identical to the scalar
//! oracle by construction (exact integer arithmetic commutes with any
//! evaluation order); the property suites assert it anyway.
//!
//! # Panel formats
//!
//! The packing layer lays each operand slice out for the instruction
//! that consumes it, padded to the instruction's 2/4-element k-groups:
//!
//! * **B panels** (both kernels) are k-interleaved and [`NR`]-wide:
//!   `[ceil(cols/NR)][ceil(k/G)][NR][G]`, one 32-byte group per
//!   (column-block, k-group) — a single `vmovdqu` feeds all `NR` output
//!   columns. `G` is 4 bytes for `maddubs`, 2 i16 (4 bytes) for
//!   `pmaddwd`.
//! * **A panels** stay row-major (one k-group is broadcast to all lanes
//!   per step). The `maddubs` kernel stores *two* u8 planes per slice —
//!   the positive and negative parts of each digit — and the `pmaddwd`
//!   kernel stores sign-extended i16 rows.
//!
//! # Saturation-freedom proof (the `maddubs` kernel)
//!
//! `vpmaddubsw` multiplies unsigned bytes `u` by signed bytes `s` and
//! adds adjacent pairs with *saturating* i16 arithmetic, so it is exact
//! only while `u[0]*s[0] + u[1]*s[1]` stays inside `[-2^15, 2^15 - 1]`.
//! The digit bounds of the slicing layer make the split evaluation below
//! provably exact:
//!
//! * Stored digits: unsigned encoding — leading slice in `[-64, 64]`
//!   (6-bit window top plus the remap carry), sub-leading in
//!   `[-128, 127]` (full two's-complement range after the §3 remap);
//!   signed encoding — all slices in `[-127, 127]`.
//! * Each A digit is split as `d = d⁺ - d⁻` with
//!   `d⁺ = max(d, 0) ∈ [0, 127]` and `d⁻ = max(-d, 0) ∈ [0, 128]`, and
//!   the two maddubs passes run on the u8 planes `d⁺` and `d⁻` against
//!   the raw signed B digits `b ∈ [-128, 127]`:
//!   - positive plane: `d⁺[0]·b[0] + d⁺[1]·b[1] ∈ [-2·127·128, 2·127·127]
//!     = [-32512, 32258]` — strictly inside i16;
//!   - negative plane: `d⁻[0]·b[0] + d⁻[1]·b[1] ∈ [-2·128·128, 2·128·127]
//!     = [-32768, 32512]` — the minimum is exactly `i16::MIN`, which is
//!     representable, so saturation never fires.
//! * `vpmaddwd` against `1i16` then widens each pair sum to i32 exactly,
//!   and the per-lane i32 accumulators hold full per-column partial dot
//!   products: `|Σ d⁺·b| <= K_CHUNK·127·128` and
//!   `|Σ d⁻·b| <= K_CHUNK·128·128 = 2^31 - 2^14 < 2^31` — the same
//!   `K_CHUNK = 2^17 - 1` cap that already guarantees the scalar i32
//!   accumulator. The final lane-wise `acc⁺ - acc⁻` equals the true pair
//!   dot, which obeys the same bound, so the wrapping `vpsubd` is exact.
//!
//! The `pmaddwd` kernel needs no split: products of sign-extended i8
//! values are at most `128·128 = 2^14`, one `vpmaddwd` pair sum is at
//! most `2^15`, and the per-lane totals obey the `K_CHUNK` bound above.
//! It serves the signed encoding (whose per-slice sign bit leaves no
//! unsigned operand for `maddubs`) and doubles as a second independent
//! SIMD oracle for the property tests.

use std::arch::x86_64::*;

use super::{KernelId, SliceKernel};
use crate::ozaki::slicing::SlicedMatrix;

/// Output columns per packed B group (i32 lanes of one ymm register).
pub const NR: usize = 8;

pub static MADDUBS: MaddubsKernel = MaddubsKernel;
pub static PMADDWD: PmaddwdKernel = PmaddwdKernel;

#[inline]
fn groups(k: usize, g: usize) -> usize {
    k.div_ceil(g)
}

/// u8×s8 pair kernel on `vpmaddubsw` + `vpmaddwd` widening (see the
/// module docs for the exactness proof). Dispatched for the unsigned
/// encoding — the AVX2 analog of the paper's u8-slice IMMA argument.
pub struct MaddubsKernel;

impl SliceKernel for MaddubsKernel {
    fn id(&self) -> KernelId {
        KernelId::Avx2Maddubs
    }

    fn a_slice_bytes(&self, rows: usize, k: usize) -> usize {
        2 * rows * groups(k, 4) * 4
    }

    fn b_slice_bytes(&self, cols: usize, k: usize) -> usize {
        cols.div_ceil(NR) * groups(k, 4) * 32
    }

    fn pack_a_slice(&self, a: &SlicedMatrix, t: usize, row0: usize, rows: usize, dst: &mut [u8]) {
        let k = a.cols;
        let rb = groups(k, 4) * 4;
        let plane = rows * rb;
        debug_assert_eq!(dst.len(), 2 * plane);
        dst.fill(0);
        let src = a.slice_rows(t, row0, rows);
        for i in 0..rows {
            let row = &src[i * k..(i + 1) * k];
            for (l, &dgt) in row.iter().enumerate() {
                let d = dgt as i32;
                dst[i * rb + l] = d.max(0) as u8;
                dst[plane + i * rb + l] = (-d).max(0) as u8;
            }
        }
    }

    fn pack_b_slice(&self, b: &SlicedMatrix, u: usize, col0: usize, cols: usize, dst: &mut [u8]) {
        let k = b.cols;
        let kg = groups(k, 4);
        let nb = cols.div_ceil(NR);
        debug_assert_eq!(dst.len(), nb * kg * 32);
        dst.fill(0);
        let src = b.slice_rows(u, col0, cols);
        for jb in 0..nb {
            let base = jb * kg * 32;
            for c in 0..NR {
                let j = jb * NR + c;
                if j >= cols {
                    break;
                }
                let row = &src[j * k..(j + 1) * k];
                for (l, &dgt) in row.iter().enumerate() {
                    dst[base + (l / 4) * 32 + c * 4 + (l % 4)] = dgt as u8;
                }
            }
        }
    }

    fn pair_tile(
        &self,
        apack: &[u8],
        bpack: &[u8],
        rows: usize,
        cols: usize,
        k: usize,
        out: &mut [i64],
    ) {
        debug_assert!(apack.len() >= self.a_slice_bytes(rows, k));
        debug_assert!(bpack.len() >= self.b_slice_bytes(cols, k));
        debug_assert_eq!(out.len(), rows * cols);
        // SAFETY: the kernel is only reachable through the dispatch layer
        // (or `available_kernels`), both of which gate on a cached
        // `is_x86_feature_detected!("avx2")`; panel sizes are checked
        // above and every pointer stays inside the checked extents.
        unsafe { maddubs_tile(apack, bpack, rows, cols, k, out) }
    }
}

#[target_feature(enable = "avx2")]
unsafe fn maddubs_tile(
    apack: &[u8],
    bpack: &[u8],
    rows: usize,
    cols: usize,
    k: usize,
    out: &mut [i64],
) {
    let kg = k.div_ceil(4);
    let rb = kg * 4;
    let plane = rows * rb;
    let nb = cols.div_ceil(NR);
    let ones = _mm256_set1_epi16(1);
    for i in 0..rows {
        let pos = apack.as_ptr().add(i * rb);
        let neg = apack.as_ptr().add(plane + i * rb);
        for jb in 0..nb {
            let bb = bpack.as_ptr().add(jb * kg * 32);
            let mut accp = _mm256_setzero_si256();
            let mut accn = _mm256_setzero_si256();
            for g in 0..kg {
                let ap = _mm256_set1_epi32(pos.add(g * 4).cast::<i32>().read_unaligned());
                let an = _mm256_set1_epi32(neg.add(g * 4).cast::<i32>().read_unaligned());
                let bv = _mm256_loadu_si256(bb.add(g * 32) as *const __m256i);
                let wp = _mm256_madd_epi16(_mm256_maddubs_epi16(ap, bv), ones);
                let wn = _mm256_madd_epi16(_mm256_maddubs_epi16(an, bv), ones);
                accp = _mm256_add_epi32(accp, wp);
                accn = _mm256_add_epi32(accn, wn);
            }
            let diff = _mm256_sub_epi32(accp, accn);
            let mut lanes = [0i32; 8];
            _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, diff);
            let take = NR.min(cols - jb * NR);
            for (c, &v) in lanes.iter().take(take).enumerate() {
                out[i * cols + jb * NR + c] += v as i64;
            }
        }
    }
}

/// Sign-extended i16 pair kernel on `vpmaddwd` — exact for any i8 digit
/// range without a split pass. Dispatched for the signed encoding.
pub struct PmaddwdKernel;

impl SliceKernel for PmaddwdKernel {
    fn id(&self) -> KernelId {
        KernelId::Avx2Pmaddwd
    }

    fn a_slice_bytes(&self, rows: usize, k: usize) -> usize {
        rows * groups(k, 2) * 4
    }

    fn b_slice_bytes(&self, cols: usize, k: usize) -> usize {
        cols.div_ceil(NR) * groups(k, 2) * 32
    }

    fn pack_a_slice(&self, a: &SlicedMatrix, t: usize, row0: usize, rows: usize, dst: &mut [u8]) {
        let k = a.cols;
        let rb = groups(k, 2) * 4;
        debug_assert_eq!(dst.len(), rows * rb);
        dst.fill(0);
        let src = a.slice_rows(t, row0, rows);
        for i in 0..rows {
            let row = &src[i * k..(i + 1) * k];
            for (l, &dgt) in row.iter().enumerate() {
                let v = (dgt as i16).to_le_bytes();
                dst[i * rb + 2 * l] = v[0];
                dst[i * rb + 2 * l + 1] = v[1];
            }
        }
    }

    fn pack_b_slice(&self, b: &SlicedMatrix, u: usize, col0: usize, cols: usize, dst: &mut [u8]) {
        let k = b.cols;
        let kg = groups(k, 2);
        let nb = cols.div_ceil(NR);
        debug_assert_eq!(dst.len(), nb * kg * 32);
        dst.fill(0);
        let src = b.slice_rows(u, col0, cols);
        for jb in 0..nb {
            let base = jb * kg * 32;
            for c in 0..NR {
                let j = jb * NR + c;
                if j >= cols {
                    break;
                }
                let row = &src[j * k..(j + 1) * k];
                for (l, &dgt) in row.iter().enumerate() {
                    let v = (dgt as i16).to_le_bytes();
                    let off = base + (l / 2) * 32 + c * 4 + (l % 2) * 2;
                    dst[off] = v[0];
                    dst[off + 1] = v[1];
                }
            }
        }
    }

    fn pair_tile(
        &self,
        apack: &[u8],
        bpack: &[u8],
        rows: usize,
        cols: usize,
        k: usize,
        out: &mut [i64],
    ) {
        debug_assert!(apack.len() >= self.a_slice_bytes(rows, k));
        debug_assert!(bpack.len() >= self.b_slice_bytes(cols, k));
        debug_assert_eq!(out.len(), rows * cols);
        // SAFETY: as in `MaddubsKernel::pair_tile` — AVX2 presence is
        // gated by the dispatch layer, extents are checked above.
        unsafe { pmaddwd_tile(apack, bpack, rows, cols, k, out) }
    }
}

#[target_feature(enable = "avx2")]
unsafe fn pmaddwd_tile(
    apack: &[u8],
    bpack: &[u8],
    rows: usize,
    cols: usize,
    k: usize,
    out: &mut [i64],
) {
    let kg = k.div_ceil(2);
    let rb = kg * 4;
    let nb = cols.div_ceil(NR);
    for i in 0..rows {
        let ar = apack.as_ptr().add(i * rb);
        for jb in 0..nb {
            let bb = bpack.as_ptr().add(jb * kg * 32);
            let mut acc = _mm256_setzero_si256();
            for g in 0..kg {
                let av = _mm256_set1_epi32(ar.add(g * 4).cast::<i32>().read_unaligned());
                let bv = _mm256_loadu_si256(bb.add(g * 32) as *const __m256i);
                acc = _mm256_add_epi32(acc, _mm256_madd_epi16(av, bv));
            }
            let mut lanes = [0i32; 8];
            _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc);
            let take = NR.min(cols - jb * NR);
            for (c, &v) in lanes.iter().take(take).enumerate() {
                out[i * cols + jb * NR + c] += v as i64;
            }
        }
    }
}
