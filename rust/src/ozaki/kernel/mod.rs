//! Pluggable INT8 slice-pair microkernels with packed operand panels —
//! the CPU analog of the paper's tensor-core (IMMA) substrate.
//!
//! The paper's whole premise is that int8 matrix-multiply units are the
//! fast path for Ozaki-style emulated DGEMM, and its unsigned slicing
//! scheme exists precisely to maximize what each 8-bit product
//! contributes to a native dot-product instruction (§3; ozIMMU and
//! EmuGEMM in PAPERS.md show the win comes from feeding *packed* int8
//! panels to those instructions rather than scalar loops). On x86 the
//! analogous instructions are `vpdpbusd` (the AVX-512 VNNI u8×s8
//! dot-product-accumulate — the direct IMMA/`dp4a` counterpart),
//! `vpmaddubsw` (u8×s8 pair dot) and `vpmaddwd` (i16 pair dot); this
//! module puts them behind one seam:
//!
//! * [`SliceKernel`] — packed-panel slice-pair tile GEMM: a kernel owns
//!   its panel layout (`a_slice_bytes`/`b_slice_bytes` +
//!   `pack_a_slice`/`pack_b_slice`) and the compute on it (`pair_tile`).
//!   Panels are packed **once per fused tile/band and reused across all
//!   `s(s+1)/2` slice pairs**, with scratch drawn from the pooled
//!   [`Workspace`](crate::backend::Workspace) — the packing cost is
//!   amortized quadratically while the kernel streams contiguous
//!   32/64-byte groups.
//! * [`ScalarKernel`] — the reference loop nest extracted from the
//!   original `slice_pair_gemm_tile`, the oracle every other kernel must
//!   match **bitwise** (trivial for exact integer arithmetic, asserted
//!   by the property suites in `tests/kernel_oracle.rs`).
//! * [`avx2::MaddubsKernel`] / [`avx2::PmaddwdKernel`] — the AVX2
//!   kernels (x86_64 only), with the i16 saturation-freedom proof in the
//!   `avx2` module docs.
//! * [`avx512::VnniKernel`] / [`avx512::Pmaddwd512Kernel`] — the
//!   AVX-512 tier (x86_64 + a rustc new enough for the stabilized
//!   AVX-512 intrinsics, signalled by the `adp_avx512` cfg from
//!   build.rs), with the `vpdpbusd` no-overflow argument in the `avx512`
//!   module docs.
//!
//! # Dispatch
//!
//! [`active`] picks the kernel at runtime: CPUID detection is done once
//! and cached (`OnceLock`), preferring VNNI, then 512-bit `vpmaddwd`,
//! then the AVX2 kernel matching the encoding (unsigned → `maddubs`,
//! signed → `pmaddwd`), then scalar. Two env knobs override it (both
//! read once and cached — dispatch sits on the per-pair hot path):
//! `ADP_FORCE_SCALAR=1` pins the scalar reference end to end, and
//! `ADP_KERNEL=<label>` forces a specific tier (falling back to the
//! default dispatch with a stderr warning when the tier is unknown or
//! not runnable here) — the knobs the CI fallback/matrix jobs and A/B
//! perf runs use. Every integer-GEMM path in the repo funnels through
//! this dispatch: `slice_pair_gemm_tile` (hence the level-major
//! reference, both backends' batch schedules and the grouped
//! `ozaki::batched` rounds) and the fused tile engine
//! (`fused_tile_gemm_*`).

pub mod scalar;

#[cfg(target_arch = "x86_64")]
pub mod avx2;

#[cfg(all(target_arch = "x86_64", adp_avx512))]
pub mod avx512;

use std::sync::OnceLock;

use super::slicing::SlicedMatrix;
use super::SliceEncoding;

pub use scalar::ScalarKernel;

/// Identity of a dispatched kernel (exported to `Metrics` as a gauge).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KernelId {
    /// The scalar reference loop nest.
    Scalar,
    /// AVX2 `vpmaddubsw` + `vpmaddwd` widening (unsigned encoding).
    Avx2Maddubs,
    /// AVX2 sign-extended `vpmaddwd` (signed encoding).
    Avx2Pmaddwd,
    /// AVX-512BW sign-extended `vpmaddwd` (both encodings; the non-VNNI
    /// AVX-512 fallback tier).
    Avx512Pmaddwd,
    /// AVX-512 VNNI `vpdpbusd` over the pos/neg digit split (both
    /// encodings; the IMMA analog).
    Avx512Vnni,
}

impl KernelId {
    /// Every kernel identity, whether or not runnable on this machine —
    /// the `ADP_KERNEL` label namespace and the tuning-catalog key space.
    pub const ALL: [KernelId; 5] = [
        KernelId::Scalar,
        KernelId::Avx2Maddubs,
        KernelId::Avx2Pmaddwd,
        KernelId::Avx512Pmaddwd,
        KernelId::Avx512Vnni,
    ];

    pub fn label(self) -> &'static str {
        match self {
            KernelId::Scalar => "scalar",
            KernelId::Avx2Maddubs => "avx2-maddubs",
            KernelId::Avx2Pmaddwd => "avx2-pmaddwd",
            KernelId::Avx512Pmaddwd => "avx512-pmaddwd",
            KernelId::Avx512Vnni => "avx512-vnni",
        }
    }

    /// Inverse of [`KernelId::label`] (the `ADP_KERNEL` parser).
    pub fn parse(s: &str) -> Option<KernelId> {
        KernelId::ALL.into_iter().find(|id| id.label() == s)
    }
}

/// A packed-panel slice-pair tile GEMM microkernel.
///
/// Contract: `pair_tile` must accumulate the **exact** integer pair
/// product — `out[i*cols + j] += sum_l a_t[i, l] * b_u[j, l]` for the
/// digits as stored in the slice tensors — for any `k <= K_CHUNK`, so
/// every kernel is bitwise identical to [`ScalarKernel`] by
/// construction. Panels are opaque to callers: a kernel defines its own
/// layout via the size/pack methods and is the only reader of the bytes
/// it packed. Packed panels depend only on (operand, slice, row range,
/// k), never on the partner slice, which is what makes one pack
/// reusable across every slice pair of a tile.
pub trait SliceKernel: Send + Sync {
    fn id(&self) -> KernelId;

    /// Bytes one packed A slice of `rows` rows × `k` digits occupies.
    fn a_slice_bytes(&self, rows: usize, k: usize) -> usize;

    /// Bytes one packed B slice of `cols` columns × `k` digits occupies
    /// (B slice tensors store B transposed, so a "column" is a row).
    fn b_slice_bytes(&self, cols: usize, k: usize) -> usize;

    /// Pack rows `[row0, row0 + rows)` of slice `t` of A into `dst`
    /// (`dst.len() == a_slice_bytes(rows, k)`, fully overwritten).
    fn pack_a_slice(&self, a: &SlicedMatrix, t: usize, row0: usize, rows: usize, dst: &mut [u8]);

    /// Pack columns `[col0, col0 + cols)` of slice `u` of B into `dst`
    /// (`dst.len() == b_slice_bytes(cols, k)`, fully overwritten).
    fn pack_b_slice(&self, b: &SlicedMatrix, u: usize, col0: usize, cols: usize, dst: &mut [u8]);

    /// `out[i*cols + j] += dot(packed A row i, packed B column j)` over
    /// the full `k` extent; `out` is the row-major `rows x cols` i64
    /// tile accumulator.
    fn pair_tile(
        &self,
        apack: &[u8],
        bpack: &[u8],
        rows: usize,
        cols: usize,
        k: usize,
        out: &mut [i64],
    );
}

static SCALAR: ScalarKernel = ScalarKernel;

/// `ADP_FORCE_SCALAR=1` (or `true`/`on`) pins the scalar reference
/// kernel for the whole process. Read once and cached: dispatch sits on
/// the per-pair hot path.
pub fn force_scalar() -> bool {
    static FORCE: OnceLock<bool> = OnceLock::new();
    *FORCE.get_or_init(|| {
        matches!(
            std::env::var("ADP_FORCE_SCALAR").ok().as_deref(),
            Some("1") | Some("true") | Some("on")
        )
    })
}

#[cfg(target_arch = "x86_64")]
fn avx2_available() -> bool {
    static DETECTED: OnceLock<bool> = OnceLock::new();
    *DETECTED.get_or_init(|| is_x86_feature_detected!("avx2"))
}

#[cfg(all(target_arch = "x86_64", adp_avx512))]
fn avx512bw_available() -> bool {
    static DETECTED: OnceLock<bool> = OnceLock::new();
    *DETECTED
        .get_or_init(|| is_x86_feature_detected!("avx512f") && is_x86_feature_detected!("avx512bw"))
}

#[cfg(all(target_arch = "x86_64", adp_avx512))]
fn avx512_vnni_available() -> bool {
    static DETECTED: OnceLock<bool> = OnceLock::new();
    *DETECTED.get_or_init(|| avx512bw_available() && is_x86_feature_detected!("avx512vnni"))
}

#[cfg(target_arch = "x86_64")]
fn simd_kernel(encoding: SliceEncoding) -> Option<&'static dyn SliceKernel> {
    #[cfg(adp_avx512)]
    {
        // The VNNI kernel's pos/neg split is valid for any digit in
        // [-128, 127], so one kernel serves both encodings — as does the
        // sign-extended 512-bit pmaddwd fallback.
        if avx512_vnni_available() {
            return Some(&avx512::VNNI);
        }
        if avx512bw_available() {
            return Some(&avx512::PMADDWD512);
        }
    }
    if !avx2_available() {
        return None;
    }
    Some(match encoding {
        SliceEncoding::Unsigned => &avx2::MADDUBS,
        SliceEncoding::Signed => &avx2::PMADDWD,
    })
}

#[cfg(not(target_arch = "x86_64"))]
fn simd_kernel(_encoding: SliceEncoding) -> Option<&'static dyn SliceKernel> {
    None
}

/// The `ADP_KERNEL=<label>` override, validated once: `Some(id)` only
/// when the label parses *and* the tier is runnable on this machine
/// (otherwise a one-shot stderr warning and default dispatch).
fn kernel_override() -> Option<KernelId> {
    static OVERRIDE: OnceLock<Option<KernelId>> = OnceLock::new();
    *OVERRIDE.get_or_init(|| {
        let raw = std::env::var("ADP_KERNEL").ok()?;
        match KernelId::parse(&raw) {
            Some(id) if kernel_by_id(id).is_some() => Some(id),
            Some(id) => {
                eprintln!(
                    "ADP_KERNEL={raw}: kernel '{}' not available on this machine; \
                     using default dispatch",
                    id.label()
                );
                None
            }
            None => {
                eprintln!("ADP_KERNEL={raw}: unknown kernel label; using default dispatch");
                None
            }
        }
    })
}

/// The kernel for `id` when it is runnable on this machine.
pub fn kernel_by_id(id: KernelId) -> Option<&'static dyn SliceKernel> {
    available_kernels().iter().find(|k| k.id() == id).copied()
}

/// The kernel the runtime dispatch selects for `encoding` on this
/// machine: the scalar reference under `ADP_FORCE_SCALAR`, the forced
/// tier under a valid `ADP_KERNEL`, otherwise the widest available SIMD
/// tier (VNNI → AVX-512BW → AVX2 by encoding → scalar).
pub fn active(encoding: SliceEncoding) -> &'static dyn SliceKernel {
    if force_scalar() {
        return &SCALAR;
    }
    if let Some(kern) = kernel_override().and_then(kernel_by_id) {
        return kern;
    }
    simd_kernel(encoding).unwrap_or(&SCALAR)
}

/// [`KernelId`] of the dispatched kernel (the `Metrics` gauge value).
pub fn active_id(encoding: SliceEncoding) -> KernelId {
    active(encoding).id()
}

/// Every kernel runnable on this machine (scalar first). Benches, the
/// oracle test suite and the `adp kernels` subcommand iterate this to
/// compare / report all implementations.
pub fn available_kernels() -> &'static [&'static dyn SliceKernel] {
    static ALL: OnceLock<Vec<&'static dyn SliceKernel>> = OnceLock::new();
    ALL.get_or_init(|| {
        let mut v: Vec<&'static dyn SliceKernel> = vec![&SCALAR];
        #[cfg(target_arch = "x86_64")]
        {
            if avx2_available() {
                v.push(&avx2::MADDUBS);
                v.push(&avx2::PMADDWD);
            }
            #[cfg(adp_avx512)]
            {
                if avx512bw_available() {
                    v.push(&avx512::PMADDWD512);
                }
                if avx512_vnni_available() {
                    v.push(&avx512::VNNI);
                }
            }
        }
        v
    })
    .as_slice()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::ozaki::slicing::{slice_a, slice_b};
    use crate::util::Rng;

    #[test]
    fn labels_are_distinct_and_parse_round_trips() {
        for (i, a) in KernelId::ALL.iter().enumerate() {
            for b in &KernelId::ALL[i + 1..] {
                assert_ne!(a.label(), b.label());
            }
            assert_eq!(KernelId::parse(a.label()), Some(*a));
        }
        assert_eq!(KernelId::parse("avx1024-galactic"), None);
    }

    #[test]
    fn dispatch_is_consistent_with_availability() {
        // Whatever `active` picks must be in the advertised kernel set,
        // forcing scalar via the env (as the CI job does) must pin the
        // scalar reference for both encodings, and a valid `ADP_KERNEL`
        // must pin its tier (the CI matrix contract).
        let forced = std::env::var("ADP_KERNEL")
            .ok()
            .and_then(|s| KernelId::parse(&s))
            .filter(|&id| kernel_by_id(id).is_some());
        for enc in [SliceEncoding::Unsigned, SliceEncoding::Signed] {
            let id = active_id(enc);
            assert!(
                available_kernels().iter().any(|k| k.id() == id),
                "dispatched {id:?} not in the available set"
            );
            if force_scalar() {
                assert_eq!(id, KernelId::Scalar, "ADP_FORCE_SCALAR must pin the scalar kernel");
            } else if let Some(want) = forced {
                assert_eq!(id, want, "ADP_KERNEL must pin its tier");
            }
        }
        assert_eq!(available_kernels()[0].id(), KernelId::Scalar);
    }

    #[test]
    fn every_available_kernel_matches_the_naive_dot() {
        // Small smoke oracle (the heavy boundary/property suite lives in
        // tests/kernel_oracle.rs): pack + pair_tile of every kernel must
        // reproduce the naive i64 dot of the stored digits exactly.
        let mut rng = Rng::new(77);
        for (m, k, n, s) in [(1usize, 1usize, 1usize, 2usize), (3, 7, 5, 3), (9, 33, 12, 4)] {
            let a = Matrix::uniform(m, k, -2.0, 2.0, &mut rng);
            let b = Matrix::uniform(k, n, -2.0, 2.0, &mut rng);
            for enc in [SliceEncoding::Unsigned, SliceEncoding::Signed] {
                let asl = slice_a(&a, s, enc);
                let bsl = slice_b(&b, s, enc);
                for kern in available_kernels() {
                    for t in 0..s {
                        for u in 0..s {
                            let mut apack = vec![0u8; kern.a_slice_bytes(m, k)];
                            let mut bpack = vec![0u8; kern.b_slice_bytes(n, k)];
                            kern.pack_a_slice(&asl, t, 0, m, &mut apack);
                            kern.pack_b_slice(&bsl, u, 0, n, &mut bpack);
                            let mut out = vec![0i64; m * n];
                            kern.pair_tile(&apack, &bpack, m, n, k, &mut out);
                            for i in 0..m {
                                for j in 0..n {
                                    let mut want = 0i64;
                                    for l in 0..k {
                                        want += asl.slice_row(t, i)[l] as i64
                                            * bsl.slice_row(u, j)[l] as i64;
                                    }
                                    assert_eq!(
                                        out[i * n + j],
                                        want,
                                        "{:?} ({m},{k},{n}) {enc:?} t={t} u={u} i={i} j={j}",
                                        kern.id()
                                    );
                                }
                            }
                        }
                    }
                }
            }
        }
    }
}
