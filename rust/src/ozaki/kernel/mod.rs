//! Pluggable INT8 slice-pair microkernels with packed operand panels —
//! the CPU analog of the paper's tensor-core (IMMA) substrate.
//!
//! The paper's whole premise is that int8 matrix-multiply units are the
//! fast path for Ozaki-style emulated DGEMM, and its unsigned slicing
//! scheme exists precisely to maximize what each 8-bit product
//! contributes to a native dot-product instruction (§3; ozIMMU and
//! EmuGEMM in PAPERS.md show the win comes from feeding *packed* int8
//! panels to those instructions rather than scalar loops). On x86 the
//! analogous instructions are `vpmaddubsw` (u8×s8 pair dot) and
//! `vpmaddwd` (i16 pair dot); this module puts them behind one seam:
//!
//! * [`SliceKernel`] — packed-panel slice-pair tile GEMM: a kernel owns
//!   its panel layout (`a_slice_bytes`/`b_slice_bytes` +
//!   `pack_a_slice`/`pack_b_slice`) and the compute on it (`pair_tile`).
//!   Panels are packed **once per fused tile/band and reused across all
//!   `s(s+1)/2` slice pairs**, with scratch drawn from the pooled
//!   [`Workspace`](crate::backend::Workspace) — the packing cost is
//!   amortized quadratically while the kernel streams contiguous
//!   32-byte groups.
//! * [`ScalarKernel`] — the reference loop nest extracted from the
//!   original `slice_pair_gemm_tile`, the oracle every other kernel must
//!   match **bitwise** (trivial for exact integer arithmetic, asserted
//!   by the property suites in `tests/kernel_oracle.rs`).
//! * [`avx2::MaddubsKernel`] / [`avx2::PmaddwdKernel`] — the AVX2
//!   kernels (x86_64 only), with the i16 saturation-freedom proof in the
//!   `avx2` module docs.
//!
//! # Dispatch
//!
//! [`active`] picks the kernel at runtime: AVX2 detection is done once
//! and cached (`OnceLock`), the unsigned encoding routes to the
//! `maddubs` kernel and the signed encoding to `pmaddwd`, and setting
//! `ADP_FORCE_SCALAR=1` (checked once, also cached) pins the scalar
//! reference end to end — the knob the CI fallback job and A/B perf runs
//! use. Every integer-GEMM path in the repo funnels through this
//! dispatch: `slice_pair_gemm_tile` (hence the level-major reference,
//! both backends' batch schedules and the grouped `ozaki::batched`
//! rounds) and the fused tile engine (`fused_tile_gemm_*`).

pub mod scalar;

#[cfg(target_arch = "x86_64")]
pub mod avx2;

use std::sync::OnceLock;

use super::slicing::SlicedMatrix;
use super::SliceEncoding;

pub use scalar::ScalarKernel;

/// Identity of a dispatched kernel (exported to `Metrics` as a gauge).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KernelId {
    /// The scalar reference loop nest.
    Scalar,
    /// AVX2 `vpmaddubsw` + `vpmaddwd` widening (unsigned encoding).
    Avx2Maddubs,
    /// AVX2 sign-extended `vpmaddwd` (signed encoding).
    Avx2Pmaddwd,
}

impl KernelId {
    pub fn label(self) -> &'static str {
        match self {
            KernelId::Scalar => "scalar",
            KernelId::Avx2Maddubs => "avx2-maddubs",
            KernelId::Avx2Pmaddwd => "avx2-pmaddwd",
        }
    }
}

/// A packed-panel slice-pair tile GEMM microkernel.
///
/// Contract: `pair_tile` must accumulate the **exact** integer pair
/// product — `out[i*cols + j] += sum_l a_t[i, l] * b_u[j, l]` for the
/// digits as stored in the slice tensors — for any `k <= K_CHUNK`, so
/// every kernel is bitwise identical to [`ScalarKernel`] by
/// construction. Panels are opaque to callers: a kernel defines its own
/// layout via the size/pack methods and is the only reader of the bytes
/// it packed. Packed panels depend only on (operand, slice, row range,
/// k), never on the partner slice, which is what makes one pack
/// reusable across every slice pair of a tile.
pub trait SliceKernel: Send + Sync {
    fn id(&self) -> KernelId;

    /// Bytes one packed A slice of `rows` rows × `k` digits occupies.
    fn a_slice_bytes(&self, rows: usize, k: usize) -> usize;

    /// Bytes one packed B slice of `cols` columns × `k` digits occupies
    /// (B slice tensors store B transposed, so a "column" is a row).
    fn b_slice_bytes(&self, cols: usize, k: usize) -> usize;

    /// Pack rows `[row0, row0 + rows)` of slice `t` of A into `dst`
    /// (`dst.len() == a_slice_bytes(rows, k)`, fully overwritten).
    fn pack_a_slice(&self, a: &SlicedMatrix, t: usize, row0: usize, rows: usize, dst: &mut [u8]);

    /// Pack columns `[col0, col0 + cols)` of slice `u` of B into `dst`
    /// (`dst.len() == b_slice_bytes(cols, k)`, fully overwritten).
    fn pack_b_slice(&self, b: &SlicedMatrix, u: usize, col0: usize, cols: usize, dst: &mut [u8]);

    /// `out[i*cols + j] += dot(packed A row i, packed B column j)` over
    /// the full `k` extent; `out` is the row-major `rows x cols` i64
    /// tile accumulator.
    fn pair_tile(
        &self,
        apack: &[u8],
        bpack: &[u8],
        rows: usize,
        cols: usize,
        k: usize,
        out: &mut [i64],
    );
}

static SCALAR: ScalarKernel = ScalarKernel;

/// `ADP_FORCE_SCALAR=1` (or `true`/`on`) pins the scalar reference
/// kernel for the whole process. Read once and cached: dispatch sits on
/// the per-pair hot path.
pub fn force_scalar() -> bool {
    static FORCE: OnceLock<bool> = OnceLock::new();
    *FORCE.get_or_init(|| {
        matches!(
            std::env::var("ADP_FORCE_SCALAR").ok().as_deref(),
            Some("1") | Some("true") | Some("on")
        )
    })
}

#[cfg(target_arch = "x86_64")]
fn avx2_available() -> bool {
    static DETECTED: OnceLock<bool> = OnceLock::new();
    *DETECTED.get_or_init(|| is_x86_feature_detected!("avx2"))
}

#[cfg(target_arch = "x86_64")]
fn simd_kernel(encoding: SliceEncoding) -> Option<&'static dyn SliceKernel> {
    if !avx2_available() {
        return None;
    }
    Some(match encoding {
        SliceEncoding::Unsigned => &avx2::MADDUBS,
        SliceEncoding::Signed => &avx2::PMADDWD,
    })
}

#[cfg(not(target_arch = "x86_64"))]
fn simd_kernel(_encoding: SliceEncoding) -> Option<&'static dyn SliceKernel> {
    None
}

/// The kernel the runtime dispatch selects for `encoding` on this
/// machine: the AVX2 kernel matching the encoding when the CPU has AVX2
/// and `ADP_FORCE_SCALAR` is unset, the scalar reference otherwise.
pub fn active(encoding: SliceEncoding) -> &'static dyn SliceKernel {
    if force_scalar() {
        return &SCALAR;
    }
    simd_kernel(encoding).unwrap_or(&SCALAR)
}

/// [`KernelId`] of the dispatched kernel (the `Metrics` gauge value).
pub fn active_id(encoding: SliceEncoding) -> KernelId {
    active(encoding).id()
}

/// Every kernel runnable on this machine (scalar first). Benches and the
/// oracle test suite iterate this to compare all implementations.
pub fn available_kernels() -> &'static [&'static dyn SliceKernel] {
    static ALL: OnceLock<Vec<&'static dyn SliceKernel>> = OnceLock::new();
    ALL.get_or_init(|| {
        let mut v: Vec<&'static dyn SliceKernel> = vec![&SCALAR];
        #[cfg(target_arch = "x86_64")]
        {
            if avx2_available() {
                v.push(&avx2::MADDUBS);
                v.push(&avx2::PMADDWD);
            }
        }
        v
    })
    .as_slice()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::ozaki::slicing::{slice_a, slice_b};
    use crate::util::Rng;

    #[test]
    fn labels_are_distinct() {
        let ids = [KernelId::Scalar, KernelId::Avx2Maddubs, KernelId::Avx2Pmaddwd];
        for (i, a) in ids.iter().enumerate() {
            for b in &ids[i + 1..] {
                assert_ne!(a.label(), b.label());
            }
        }
    }

    #[test]
    fn dispatch_is_consistent_with_availability() {
        // Whatever `active` picks must be in the advertised kernel set,
        // and forcing scalar via the env (as the CI job does) must pin
        // the scalar reference for both encodings.
        for enc in [SliceEncoding::Unsigned, SliceEncoding::Signed] {
            let id = active_id(enc);
            assert!(
                available_kernels().iter().any(|k| k.id() == id),
                "dispatched {id:?} not in the available set"
            );
            if force_scalar() {
                assert_eq!(id, KernelId::Scalar, "ADP_FORCE_SCALAR must pin the scalar kernel");
            }
        }
        assert_eq!(available_kernels()[0].id(), KernelId::Scalar);
    }

    #[test]
    fn every_available_kernel_matches_the_naive_dot() {
        // Small smoke oracle (the heavy boundary/property suite lives in
        // tests/kernel_oracle.rs): pack + pair_tile of every kernel must
        // reproduce the naive i64 dot of the stored digits exactly.
        let mut rng = Rng::new(77);
        for (m, k, n, s) in [(1usize, 1usize, 1usize, 2usize), (3, 7, 5, 3), (9, 33, 12, 4)] {
            let a = Matrix::uniform(m, k, -2.0, 2.0, &mut rng);
            let b = Matrix::uniform(k, n, -2.0, 2.0, &mut rng);
            for enc in [SliceEncoding::Unsigned, SliceEncoding::Signed] {
                let asl = slice_a(&a, s, enc);
                let bsl = slice_b(&b, s, enc);
                for kern in available_kernels() {
                    for t in 0..s {
                        for u in 0..s {
                            let mut apack = vec![0u8; kern.a_slice_bytes(m, k)];
                            let mut bpack = vec![0u8; kern.b_slice_bytes(n, k)];
                            kern.pack_a_slice(&asl, t, 0, m, &mut apack);
                            kern.pack_b_slice(&bsl, u, 0, n, &mut bpack);
                            let mut out = vec![0i64; m * n];
                            kern.pair_tile(&apack, &bpack, m, n, k, &mut out);
                            for i in 0..m {
                                for j in 0..n {
                                    let mut want = 0i64;
                                    for l in 0..k {
                                        want += asl.slice_row(t, i)[l] as i64
                                            * bsl.slice_row(u, j)[l] as i64;
                                    }
                                    assert_eq!(
                                        out[i * n + j],
                                        want,
                                        "{:?} ({m},{k},{n}) {enc:?} t={t} u={u} i={i} j={j}",
                                        kern.id()
                                    );
                                }
                            }
                        }
                    }
                }
            }
        }
    }
}
