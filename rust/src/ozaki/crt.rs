//! Ozaki-II / CRT modular decomposition — the second scheme family.
//!
//! The slice-pair scheme (`gemm.rs`) multiplies positional INT8 digits
//! and pays one integer GEMM per retained digit pair: s(s+1)/2 launches
//! for s slices. The CRT scheme trades positional digits for **residues**:
//! each operand's fixed-point window integer (the same window the
//! slice-pair path uses, see `slicing::window_value`) is reduced modulo a
//! fixed basis of pairwise-coprime 8-bit moduli, one INT8 GEMM runs *per
//! modulus* — exact, because centered residues and their k-length dot
//! products stay inside the microkernels' proven range — and the Chinese
//! Remainder Theorem reconstructs the full product from the per-modulus
//! results. Kernel launches drop from quadratic to **linear**: `nm`
//! moduli cover the product range `2*k*2^(2*beta)` with
//! `nm ~= (2*beta + log2 k)/8`, versus `s*(s+1)/2` pairs for the same
//! window (`beta = 8*s - 2`); at s = 7, k = 2^17 that is 17 GEMMs
//! instead of 28, and the gap widens quadratically with s.
//!
//! Unlike the slice-pair schedule — which drops pair products below the
//! target precision (levels q > s-1) — the CRT product is the *complete*
//! window product: accuracy is never worse than slice-pair at the same
//! window, and on inputs where no window truncation occurs the two
//! schemes agree **bitwise** (the scheme-equivalence oracle in
//! `tests/crt_scheme.rs`). Reconstruction is exact integer arithmetic up
//! to the final double-double evaluation, so results are bitwise
//! reproducible across backends and thread counts by construction.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use super::gemm::{FusedTally, K_CHUNK};
use super::kernel::{self, SliceKernel};
use super::recompose::descale_tile;
use super::slicing::{crt_slice_a, crt_slice_b, SlicedMatrix};
use super::tune::{self, TileShape};
use crate::backend::{ComputeBackend, SerialBackend, Workspace, WorkspacePool};
use crate::dd::Dd;
use crate::linalg::Matrix;
use crate::util::sync as psync;

/// The modulus basis, largest first: 2^8, then the odd coprimes below it
/// in descending order (255 = 3·5·17, 253 = 11·23, 247 = 13·19,
/// 217 = 7·31; every other entry is prime). 34 entries totalling ~253.8
/// bits of range — enough for windows up to s_eq = 14 at full k-chunk
/// depth. Pairwise coprimality is asserted by unit test; descending order
/// makes every prefix the densest basis of its length, minimizing `nm`.
pub const CRT_MODULI: [i64; 34] = [
    256, 255, 253, 251, 247, 241, 239, 233, 229, 227, 223, 217, 211, 199, 197, 193, 191, 181, 179,
    173, 167, 163, 157, 151, 149, 139, 137, 131, 127, 113, 109, 107, 103, 101,
];

/// Centered (balanced) residue of `x` modulo `m`: the unique `r ≡ x
/// (mod m)` with `-m/2 <= r < m/2` for even m, `|r| <= (m-1)/2` for odd
/// m. For every basis modulus (<= 256) the result fits i8.
#[inline]
pub fn center(x: i64, m: i64) -> i64 {
    let r = x.rem_euclid(m);
    if 2 * r >= m {
        r - m
    } else {
        r
    }
}

/// `a^-1 mod m` by extended Euclid; panics if `gcd(a, m) != 1` (the basis
/// is pairwise coprime, so this is unreachable from [`CrtBasis`]).
fn mod_inverse(a: i64, m: i64) -> i64 {
    let (mut old_r, mut r) = (a.rem_euclid(m), m);
    let (mut old_s, mut s) = (1i64, 0i64);
    while r != 0 {
        let q = old_r / r;
        (old_r, r) = (r, old_r - q * r);
        (old_s, s) = (s, old_s - q * s);
    }
    assert_eq!(old_r, 1, "moduli must be pairwise coprime (gcd({a}, {m}) != 1)");
    old_s.rem_euclid(m)
}

/// CRT scheme parameters: the shared fixed-point window (`s_eq` — the
/// slice count the equivalent slice-pair configuration would use, so ESC
/// sizing is identical across schemes) plus the modulus count covering
/// that window's product range.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CrtConfig {
    /// Window width in 8-bit digit positions (== the equivalent
    /// unsigned slice count; window bound `|A_int| < 2^(8*s_eq - 2)`).
    pub s_eq: usize,
    /// Moduli used — one INT8 GEMM each (a `CRT_MODULI` prefix length).
    pub moduli: usize,
    /// Inner-dimension chunk bound (operands are split before slicing
    /// when `k` exceeds it, exactly like `OzakiConfig::k_chunk`).
    pub k_chunk: usize,
}

impl CrtConfig {
    /// Smallest basis covering the window `s_eq` at inner dimension `k`:
    /// the product magnitude is below `k_c * 2^(2*beta)` with
    /// `beta = 8*s_eq - 2` and `k_c = min(k, K_CHUNK)`, and unique
    /// centered reconstruction needs the basis range to exceed twice
    /// that (one extra guard bit is kept on top). Returns `None` when the
    /// window exceeds the basis (or the u128 digit-extraction gate):
    /// callers fall back to the slice-pair scheme.
    pub fn for_window(s_eq: usize, k: usize) -> Option<CrtConfig> {
        if s_eq == 0 || 8 * (s_eq as i32 - 1) + 7 >= 128 {
            return None;
        }
        let kc = k.clamp(1, K_CHUNK);
        let beta = 8 * s_eq as i32 - 2;
        let needed = 2.0 + (kc as f64).log2().ceil() + 2.0 * beta as f64;
        let mut bits = 0.0f64;
        let mut nm = 0usize;
        while bits < needed {
            if nm == CRT_MODULI.len() {
                return None;
            }
            bits += (CRT_MODULI[nm] as f64).log2();
            nm += 1;
        }
        Some(CrtConfig { s_eq, moduli: nm, k_chunk: K_CHUNK })
    }

    /// Window sized from a mantissa-bit requirement, mirroring
    /// `SliceEncoding::Unsigned.slices_for_bits` so ESC-driven selection
    /// produces the same window for both scheme families.
    pub fn for_bits(bits: i32, k: usize) -> Option<CrtConfig> {
        CrtConfig::for_window(super::SliceEncoding::Unsigned.slices_for_bits(bits), k)
    }

    /// Override the chunk bound (testing / experimentation). Clamped to
    /// the kernels' exactness cap; note the basis is *not* re-shrunk for
    /// smaller chunks — a conservative direction.
    pub fn with_k_chunk(mut self, k_chunk: usize) -> CrtConfig {
        self.k_chunk = k_chunk.clamp(1, K_CHUNK);
        self
    }

    pub fn k_chunk(&self) -> usize {
        self.k_chunk
    }

    /// Integer GEMMs per k-chunk (one per modulus) — the linear
    /// kernel-launch count, vs [`CrtConfig::pair_gemm_count`] quadratic.
    pub fn gemm_count(&self) -> usize {
        self.moduli
    }

    /// What the slice-pair scheme would launch for the same window.
    pub fn pair_gemm_count(&self) -> usize {
        self.s_eq * (self.s_eq + 1) / 2
    }
}

/// Precomputed reconstruction tables for a basis prefix: the Garner
/// mixed-radix inverses and the double-double mixed-radix weights.
/// Process-wide cached ([`CrtBasis::get`]) like `PairSchedule::get`.
pub struct CrtBasis {
    moduli: Vec<i64>,
    /// Triangular: `inv[p*(p-1)/2 + q] = m_q^-1 mod m_p` for `q < p`.
    inv: Vec<i64>,
    /// `wd[p]` = double-double of `prod_{q<p} m_q` (wd[0] = 1). Exact up
    /// to 106 bits (~13 moduli); beyond that relatively accurate to
    /// ~2^-104, which only matters for values too large to be exact
    /// anyway (see [`CrtBasis::reconstruct`]).
    wd: Vec<Dd>,
}

static BASIS_CACHE: OnceLock<Mutex<HashMap<usize, Arc<CrtBasis>>>> = OnceLock::new();

impl CrtBasis {
    pub fn new(nm: usize) -> CrtBasis {
        assert!((1..=CRT_MODULI.len()).contains(&nm), "basis length {nm} out of range");
        let moduli: Vec<i64> = CRT_MODULI[..nm].to_vec();
        let mut inv = Vec::with_capacity(nm * (nm - 1) / 2);
        for p in 1..nm {
            for q in 0..p {
                inv.push(mod_inverse(moduli[q], moduli[p]));
            }
        }
        let mut wd = Vec::with_capacity(nm);
        let mut w = Dd::from(1.0);
        for &m in &moduli {
            wd.push(w);
            w = w.mul(Dd::from(m as f64));
        }
        CrtBasis { moduli, inv, wd }
    }

    /// Shared basis for `nm` moduli (process-wide cache; reconstruction
    /// tables are pure functions of the prefix length).
    pub fn get(nm: usize) -> Arc<CrtBasis> {
        let cache = BASIS_CACHE.get_or_init(|| Mutex::new(HashMap::new()));
        let mut g = psync::lock(cache);
        g.entry(nm).or_insert_with(|| Arc::new(CrtBasis::new(nm))).clone()
    }

    pub fn for_config(cfg: &CrtConfig) -> Arc<CrtBasis> {
        CrtBasis::get(cfg.moduli)
    }

    pub fn moduli(&self) -> &[i64] {
        &self.moduli
    }

    pub fn len(&self) -> usize {
        self.moduli.len()
    }

    pub fn is_empty(&self) -> bool {
        self.moduli.is_empty()
    }

    #[inline]
    fn inv_at(&self, p: usize, q: usize) -> i64 {
        self.inv[p * (p - 1) / 2 + q]
    }

    /// Balanced-Garner reconstruction of one product element from its
    /// centered residues `res[p] = center(x mod m_p)`. `scratch` holds
    /// the mixed-radix digits (len >= basis length, caller-provided so
    /// the per-element loop allocates nothing).
    ///
    /// Mixed radix with *centered* digits `v_p` (|v_p| <= m_p/2):
    /// `x = sum_p v_p * prod_{q<p} m_q`. Centering makes the digit
    /// sequence contract: once the running remainder fits one modulus,
    /// every higher digit is exactly zero — so small products use few
    /// terms and reconstruct **exactly** in double-double; large ones
    /// (beyond ~106 bits) see only the dd representation error ~2^-104
    /// relative, far below the (k+4)*eps accuracy target. All integer
    /// steps are exact: `|u - v_q| <= 256`, times an inverse < 256 stays
    /// under 2^16.
    #[inline]
    pub fn reconstruct(&self, res: &[i64], scratch: &mut [i64]) -> Dd {
        let nm = self.moduli.len();
        debug_assert_eq!(res.len(), nm);
        debug_assert!(scratch.len() >= nm);
        for p in 0..nm {
            let m = self.moduli[p];
            let mut u = res[p];
            for q in 0..p {
                u = center((u - scratch[q]) * self.inv_at(p, q), m);
            }
            scratch[p] = u;
        }
        let mut acc = Dd::ZERO;
        for p in 0..nm {
            let v = scratch[p];
            if v != 0 {
                acc = acc.add(self.wd[p].mul(Dd::from(v as f64)));
            }
        }
        acc
    }
}

/// One fused row band of the CRT scheme, the linear-launch counterpart of
/// `gemm::fused_band`: per `shape.nc` column tile, run **one** integer
/// GEMM per modulus on the packed residue panels, reduce each i64 tile to
/// its centered residue plane, Garner-reconstruct every element into the
/// compensated hi/lo pair, and apply the shared sigma descaling. Operand
/// residues stay cache-resident across all moduli of a tile, same as the
/// slice-pair engine's pair reuse. Like the slice-pair band, every tile
/// geometry yields the bitwise identical result.
#[allow(clippy::too_many_arguments)]
pub fn crt_band(
    kern: &dyn SliceKernel,
    a: &SlicedMatrix,
    b: &SlicedMatrix,
    basis: &CrtBasis,
    row0: usize,
    shape: TileShape,
    ws: &mut Workspace,
    band: &mut [f64],
) -> FusedTally {
    let n = b.rows;
    let k = a.cols;
    let nm = basis.len();
    debug_assert_eq!(a.s, nm, "A residue planes must match the basis");
    debug_assert_eq!(b.s, nm, "B residue planes must match the basis");
    debug_assert_eq!(a.cols, b.cols, "inner dimensions must agree");
    assert!(k <= K_CHUNK, "k must be pre-chunked to the kernels' exact range");
    if band.is_empty() || n == 0 {
        return FusedTally::default();
    }
    let rows = band.len() / n;
    let ab = kern.a_slice_bytes(rows, k);
    let bb_max = kern.b_slice_bytes(shape.nc.min(n), k);
    assert!(ws.capacity() >= rows * shape.nc.min(n), "workspace too small for tile");
    let grew = ws.ensure_pack(nm * ab, nm * bb_max);
    let grew_res = ws.ensure_res(nm * rows * shape.nc.min(n));
    let Workspace { pbuf, hi, lo, apack, bpack, rbuf } = ws;
    let mut tally =
        FusedTally { pack_growths: (grew || grew_res) as u64, ..FusedTally::default() };
    // Pack this band's A residue planes once; reused by every column tile
    // and every modulus of the band.
    for p in 0..nm {
        kern.pack_a_slice(a, p, row0, rows, &mut apack[p * ab..(p + 1) * ab]);
    }
    tally.packs += 1;
    let mut res = [0i64; CRT_MODULI.len()];
    let mut digits = [0i64; CRT_MODULI.len()];
    let mut first_tile = true;
    let mut col0 = 0;
    while col0 < n {
        let cols = shape.nc.min(n - col0);
        let bb = kern.b_slice_bytes(cols, k);
        for p in 0..nm {
            kern.pack_b_slice(b, p, col0, cols, &mut bpack[p * bb..(p + 1) * bb]);
        }
        tally.packs += 1;
        let e = rows * cols;
        let pb = &mut pbuf[..e];
        // One exact integer GEMM per modulus (|residue| <= 128 keeps the
        // kernels' k <= K_CHUNK exactness bound), each i64 tile folded to
        // its centered residue plane.
        for (p, &mp) in basis.moduli().iter().enumerate() {
            pb.fill(0);
            kern.pair_tile(&apack[p * ab..(p + 1) * ab], &bpack[p * bb..(p + 1) * bb], rows, cols, k, pb);
            let plane = &mut rbuf[p * e..(p + 1) * e];
            for (r, &v) in plane.iter_mut().zip(pb.iter()) {
                *r = center(v, mp) as i32;
            }
        }
        // Per-element Garner + dd into the compensated pair, then the
        // shared sigma descaling — identical tail to the slice-pair tile.
        let hi_t = &mut hi[..e];
        let lo_t = &mut lo[..e];
        for idx in 0..e {
            for (p, r) in res[..nm].iter_mut().enumerate() {
                *r = rbuf[p * e + idx] as i64;
            }
            let v = basis.reconstruct(&res[..nm], &mut digits);
            hi_t[idx] = v.hi;
            lo_t[idx] = v.lo;
        }
        descale_tile(hi_t, lo_t, &a.sigma, &b.sigma, row0, rows, col0, cols);
        for i in 0..rows {
            let src = i * cols;
            let dst = i * n + col0;
            for j in 0..cols {
                band[dst + j] = hi_t[src + j] + lo_t[src + j];
            }
        }
        tally.tiles += 1;
        if !first_tile {
            // A panels packed once per band serve every later tile.
            tally.reuses += nm as u64;
        }
        first_tile = false;
        col0 += cols;
    }
    tally
}

/// Serial CRT tile engine over pre-sliced residues — the reference order
/// the backend trait's `crt_tile_gemm` defaults to.
pub fn crt_tile_gemm_serial_on(
    kern: &dyn SliceKernel,
    a: &SlicedMatrix,
    b: &SlicedMatrix,
    basis: &CrtBasis,
    workspaces: &WorkspacePool,
    c: &mut Matrix,
) {
    let n = b.rows;
    assert_eq!(c.rows, a.rows, "output rows mismatch");
    assert_eq!(c.cols, n, "output cols mismatch");
    if a.rows == 0 || n == 0 {
        return;
    }
    let shape = tune::tile_shape_for(kern.id(), a.rows, n);
    workspaces.record_dispatch(kern.id(), Some(shape));
    let mut ws = workspaces.checkout(shape.elems());
    let mut tally = FusedTally::default();
    for (bi, band) in c.data.chunks_mut(shape.mc * n).enumerate() {
        tally.merge(crt_band(kern, a, b, basis, bi * shape.mc, shape, &mut ws, band));
    }
    workspaces.record_tiles(tally.tiles);
    workspaces.record_panels(tally.packs, tally.reuses);
    workspaces.record_pack_growth(tally.pack_growths);
}

/// Serial CRT tile engine, slicing included.
pub fn crt_tile_gemm_serial(
    a: &SlicedMatrix,
    b: &SlicedMatrix,
    basis: &CrtBasis,
    workspaces: &WorkspacePool,
    c: &mut Matrix,
) {
    crt_tile_gemm_serial_on(kernel::active(a.encoding), a, b, basis, workspaces, c)
}

/// CRT emulated GEMM on a backend, chunking the inner dimension before
/// slicing when it exceeds `cfg.k_chunk` (exactly like `fused_gemm_on`;
/// chunk results are summed in FP64).
pub fn crt_gemm_on(
    a: &Matrix,
    b: &Matrix,
    cfg: &CrtConfig,
    backend: &dyn ComputeBackend,
    workspaces: &WorkspacePool,
) -> Matrix {
    assert_eq!(a.cols, b.rows, "gemm shape mismatch");
    let (m, k, n) = (a.rows, a.cols, b.cols);
    if m == 0 || n == 0 || k == 0 {
        return Matrix::zeros(m, n);
    }
    let kchunk = cfg.k_chunk();
    if k <= kchunk {
        return crt_gemm_chunk(a, b, cfg, backend, workspaces);
    }
    let mut c = Matrix::zeros(m, n);
    let mut k0 = 0;
    while k0 < k {
        let kc = kchunk.min(k - k0);
        let ac = a.block(0, k0, m, kc);
        let bc = b.block(k0, 0, kc, n);
        let cc = crt_gemm_chunk(&ac, &bc, cfg, backend, workspaces);
        c.add_assign(&cc);
        k0 += kc;
    }
    c
}

fn crt_gemm_chunk(
    a: &Matrix,
    b: &Matrix,
    cfg: &CrtConfig,
    backend: &dyn ComputeBackend,
    workspaces: &WorkspacePool,
) -> Matrix {
    let basis = CrtBasis::for_config(cfg);
    let asl = crt_slice_a(a, cfg.s_eq, &basis);
    let bsl = crt_slice_b(b, cfg.s_eq, &basis);
    let mut c = Matrix::zeros(a.rows, b.cols);
    backend.crt_tile_gemm(&asl, &bsl, &basis, workspaces, &mut c);
    c
}

/// Serial convenience wrapper.
pub fn crt_gemm(a: &Matrix, b: &Matrix, cfg: &CrtConfig) -> Matrix {
    crt_gemm_on(a, b, cfg, &SerialBackend, &WorkspacePool::new())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grading::grade::{measure, passes_grade_a};
    use crate::ozaki::gemm::emulated_gemm;
    use crate::ozaki::OzakiConfig;
    use crate::util::{prop, Rng};

    fn gcd(a: i64, b: i64) -> i64 {
        if b == 0 {
            a
        } else {
            gcd(b, a % b)
        }
    }

    #[test]
    fn moduli_pairwise_coprime_descending_and_i8_rangeable() {
        for (i, &m) in CRT_MODULI.iter().enumerate() {
            assert!(m > 1 && m <= 256, "modulus {m} out of the 8-bit kernel range");
            if i > 0 {
                assert!(m < CRT_MODULI[i - 1], "basis must be strictly descending");
            }
            for &m2 in &CRT_MODULI[..i] {
                assert_eq!(gcd(m2, m), 1, "moduli {m2} and {m} share a factor");
            }
        }
        let total: f64 = CRT_MODULI.iter().map(|&m| (m as f64).log2()).sum();
        assert!(total > 253.0, "basis range shrank: {total} bits");
    }

    #[test]
    fn center_is_balanced_for_both_parities() {
        for &m in &[256i64, 255, 101, 2, 3] {
            for x in -600..=600 {
                let r = center(x, m);
                assert_eq!((r - x).rem_euclid(m), 0, "center must preserve the class");
                if m % 2 == 0 {
                    assert!((-m / 2..m / 2).contains(&r), "m={m} x={x} r={r}");
                } else {
                    assert!((-(m - 1) / 2..=(m - 1) / 2).contains(&r), "m={m} x={x} r={r}");
                }
            }
        }
    }

    #[test]
    fn mod_inverse_inverts() {
        let mut rng = Rng::new(900);
        for _ in 0..200 {
            let m = CRT_MODULI[rng.int(0, CRT_MODULI.len() as i64 - 1) as usize];
            let mut a = rng.int(1, m - 1);
            while gcd(a, m) != 1 {
                a = rng.int(1, m - 1);
            }
            let inv = mod_inverse(a, m);
            assert_eq!((a * inv).rem_euclid(m), 1, "a={a} m={m} inv={inv}");
        }
    }

    #[test]
    fn basis_cache_shares_instances() {
        let b1 = CrtBasis::get(9);
        let b2 = CrtBasis::get(9);
        assert!(Arc::ptr_eq(&b1, &b2));
        assert_eq!(b1.len(), 9);
        assert!(!b1.is_empty());
        let b3 = CrtBasis::get(5);
        assert!(!Arc::ptr_eq(&b1, &b3));
    }

    #[test]
    fn for_window_is_linear_not_quadratic() {
        // The launch-count claim: one GEMM per modulus beats the pair
        // count for every window from s_eq = 5 up, at any k.
        for s_eq in 5..=14 {
            for k in [1usize, 256, K_CHUNK, 10 * K_CHUNK] {
                let cfg = CrtConfig::for_window(s_eq, k)
                    .unwrap_or_else(|| panic!("s_eq={s_eq} k={k} must be coverable"));
                assert!(
                    cfg.gemm_count() < cfg.pair_gemm_count(),
                    "s_eq={s_eq} k={k}: {} moduli vs {} pairs",
                    cfg.gemm_count(),
                    cfg.pair_gemm_count()
                );
            }
        }
        // FP64 default window at full chunk depth: 17 GEMMs vs 28 pairs.
        let cfg = CrtConfig::for_window(7, K_CHUNK).unwrap();
        assert_eq!((cfg.gemm_count(), cfg.pair_gemm_count()), (17, 28));
        // Beyond the basis: graceful None, never a panic.
        assert!(CrtConfig::for_window(15, K_CHUNK).is_none());
        assert!(CrtConfig::for_window(40, 16).is_none());
        assert_eq!(CrtConfig::for_bits(54, K_CHUNK), CrtConfig::for_window(7, K_CHUNK));
    }

    #[test]
    fn reconstruct_roundtrips_integers_exactly() {
        // Any |x| < 2^88 reconstructs exactly from its residues on a
        // 12-modulus basis (range ~95.8 bits > 89, weights exact in dd).
        let basis = CrtBasis::new(12);
        prop::check("balanced Garner roundtrip", 300, |rng| {
            let mag = rng.int(0, 87) as u32;
            let wide = ((rng.int(0, i64::MAX / 2) as i128) << 45) | rng.int(0, (1 << 45) - 1) as i128;
            let x = wide.rem_euclid(1i128 << mag) * if rng.f64() < 0.5 { -1 } else { 1 };
            let res: Vec<i64> =
                basis.moduli().iter().map(|&m| center(x.rem_euclid(m as i128) as i64, m)).collect();
            let mut scratch = [0i64; CRT_MODULI.len()];
            let v = basis.reconstruct(&res, &mut scratch);
            let got = v.hi as i128 + v.lo as i128;
            prop::assert_that(got == x, format!("x={x} got={got} (hi={} lo={})", v.hi, v.lo))?;
            // Balanced digits above the value's magnitude are exactly
            // zero — the property that makes small products exact.
            let used: usize = (0..12).rev().find(|&p| scratch[p] != 0).map_or(0, |p| p + 1);
            let capacity: f64 =
                basis.moduli()[..used.saturating_sub(1)].iter().map(|&m| (m as f64).log2()).sum();
            prop::assert_that(
                used == 0 || capacity < mag as f64 + 1.0,
                format!("x={x}: {used} digits used but |x| < 2^{mag}"),
            )
        });
    }

    #[test]
    fn crt_gemm_matches_fp64_grading_tolerance() {
        let mut rng = Rng::new(901);
        for (m, k, n) in [(1usize, 1usize, 1usize), (13, 40, 9), (65, 130, 70)] {
            let a = Matrix::uniform(m, k, -3.0, 3.0, &mut rng);
            let b = Matrix::uniform(k, n, -3.0, 3.0, &mut rng);
            let cfg = CrtConfig::for_window(7, k).unwrap();
            let c = crt_gemm(&a, &b, &cfg);
            let rep = measure(&a, &b, &c);
            assert!(
                passes_grade_a(&rep, k.max(4), 4.0),
                "({m},{k},{n}): CRT broke the grading tolerance: {rep:?}"
            );
        }
    }

    #[test]
    fn crt_bitwise_equals_slice_pair_on_exact_integers() {
        // On small-integer inputs the window digits occupy only the top
        // positions: the slice-pair schedule's truncated levels are all
        // zero and both schemes compute the exact product — so the final
        // f64 results must agree bit for bit.
        let mut rng = Rng::new(902);
        for (m, k, n) in [(7usize, 11usize, 5usize), (40, 64, 33)] {
            let a = Matrix::from_fn(m, k, |_, _| rng.int(-512, 512) as f64);
            let b = Matrix::from_fn(k, n, |_, _| rng.int(-512, 512) as f64);
            let crt_cfg = CrtConfig::for_window(7, k).unwrap();
            let c_crt = crt_gemm(&a, &b, &crt_cfg);
            let c_sp = emulated_gemm(&a, &b, &OzakiConfig::new(7));
            for (x, y) in c_crt.data.iter().zip(&c_sp.data) {
                assert_eq!(x.to_bits(), y.to_bits(), "CRT vs slice-pair diverged: {x} vs {y}");
            }
        }
    }

    #[test]
    fn chunked_k_stays_accurate() {
        let mut rng = Rng::new(903);
        let (m, k, n) = (9, 100, 8);
        let a = Matrix::uniform(m, k, -2.0, 2.0, &mut rng);
        let b = Matrix::uniform(k, n, -2.0, 2.0, &mut rng);
        let cfg = CrtConfig::for_window(7, k).unwrap().with_k_chunk(17);
        assert_eq!(cfg.k_chunk(), 17);
        let c = crt_gemm(&a, &b, &cfg);
        let rep = measure(&a, &b, &c);
        assert!(passes_grade_a(&rep, k, 4.0), "chunked CRT broke the tolerance: {rep:?}");
    }

    #[test]
    fn empty_and_degenerate_shapes() {
        let cfg = CrtConfig::for_window(7, 4).unwrap();
        let c = crt_gemm(&Matrix::zeros(0, 4), &Matrix::zeros(4, 3), &cfg);
        assert_eq!((c.rows, c.cols), (0, 3));
        let c = crt_gemm(&Matrix::zeros(2, 0), &Matrix::zeros(0, 3), &cfg);
        assert_eq!((c.rows, c.cols), (2, 3));
        assert!(c.data.iter().all(|&x| x == 0.0));
        // All-zero operands: residues stay zero, result is exact zero.
        let c = crt_gemm(&Matrix::zeros(3, 5), &Matrix::zeros(5, 2), &cfg);
        assert!(c.data.iter().all(|&x| x == 0.0));
    }
}
