//! Recomposition: scaled summation of slice-pair products back to FP64.
//!
//! Matches `python/compile/ozaki.py::recompose` operation-for-operation
//! (same grouping by q = t+u, same smallest-weight-first ordering, same
//! two_sum-compensated accumulation, same interleaved scale application)
//! so native and AOT results are bitwise identical.
//!
//! The weight-level accumulation is **compensated** (Dekker/Knuth two_sum):
//! level sums `S_q * 2^w` individually reach ~(|A||B|)_ij while the true
//! result can be far smaller after cancellation across levels; a plain f64
//! sum would leave an error of poly(s,k) * eps * (|A||B|)_ij, visibly above
//! the Grade A slope. Compensation reduces it to one final rounding.

use crate::linalg::Matrix;
use crate::util::bits::{exp2i, ldexp};

/// Compensated accumulator for the weight-level sums.
pub struct LevelAccumulator {
    pub hi: Vec<f64>,
    pub lo: Vec<f64>,
}

impl LevelAccumulator {
    pub fn new(len: usize) -> LevelAccumulator {
        LevelAccumulator { hi: vec![0.0; len], lo: vec![0.0; len] }
    }

    /// hi,lo += P_q * 2^w for one weight level q (see [`add_level_into`]).
    pub fn add_level(&mut self, pbuf: &[i64], weight_exp: i32) {
        add_level_into(&mut self.hi, &mut self.lo, pbuf, weight_exp);
    }
}

/// hi,lo += P_q * 2^w for one weight level q, on caller-owned buffers
/// (the fused tile engine and the pooled-workspace grouped pipeline feed
/// workspace slices here; [`LevelAccumulator`] delegates). P entries are
/// exact integers (|P| <= s * k * 2^14 < 2^53), so `P as f64 * 2^w` is
/// exact and two_sum captures the entire rounding residue of the add.
pub fn add_level_into(hi: &mut [f64], lo: &mut [f64], pbuf: &[i64], weight_exp: i32) {
    debug_assert_eq!(hi.len(), pbuf.len());
    debug_assert_eq!(lo.len(), pbuf.len());
    debug_assert!((-1074..=1023).contains(&weight_exp));
    let w = exp2i(weight_exp);
    for ((h, l), &p) in hi.iter_mut().zip(lo.iter_mut()).zip(pbuf) {
        let x = p as f64 * w;
        // two_sum(h, x) — branch-free Knuth
        let s = *h + x;
        let bb = s - *h;
        let e = (*h - (s - bb)) + (x - bb);
        *h = s;
        *l += e;
    }
}

/// Apply the per-row / per-column descaling 2^(-sigma_a[i] - sigma_b[j]) in
/// two interleaved exact power-of-two halves each (provably no spurious
/// intermediate overflow/underflow for any mix of row/col scales — the
/// running value never exceeds `true * 2^(ceil(sa/2)+ceil(sb/2))` with the
/// accumulator bounded by ~2^139; see DESIGN.md), then collapse hi + lo.
pub fn recompose(acc: LevelAccumulator, sigma_a: &[i32], sigma_b: &[i32], m: usize, n: usize) -> Matrix {
    let LevelAccumulator { mut hi, mut lo } = acc;
    recompose_slices(&mut hi, &mut lo, sigma_a, sigma_b, m, n)
}

/// [`recompose`] on caller-owned hi/lo buffers (the pooled-workspace
/// grouped pipeline recomposes straight out of its checkout). The buffers
/// are consumed as scratch — descaled in place — and the collapsed
/// `hi + lo` matrix is returned.
pub fn recompose_slices(
    hi: &mut [f64],
    lo: &mut [f64],
    sigma_a: &[i32],
    sigma_b: &[i32],
    m: usize,
    n: usize,
) -> Matrix {
    debug_assert_eq!(hi.len(), m * n);
    debug_assert_eq!(lo.len(), m * n);
    debug_assert_eq!(sigma_a.len(), m);
    debug_assert_eq!(sigma_b.len(), n);
    descale_tile(hi, lo, sigma_a, sigma_b, 0, m, 0, n);
    let data: Vec<f64> = hi.iter().zip(lo.iter()).map(|(h, l)| h + l).collect();
    Matrix { rows: m, cols: n, data }
}

/// Tile-ranged descaling: apply the four interleaved half-scale passes to
/// the `rows x cols` hi/lo tile covering output rows `[row0, row0+rows)`
/// and columns `[col0, col0+cols)`. `sigma_a`/`sigma_b` are the **full**
/// per-row/per-column exponent vectors; the tile indexes into them.
///
/// Every pass touches each element exactly once and reads nothing but
/// that element and its own row/column sigma, so the per-element multiply
/// sequence (pass 0 → 1 → 2 → 3, then the caller's `hi + lo` collapse) is
/// identical whether the output is descaled whole ([`recompose`]) or tile
/// by tile (the fused engine) — bitwise identical results by
/// construction.
#[allow(clippy::too_many_arguments)]
pub fn descale_tile(
    hi: &mut [f64],
    lo: &mut [f64],
    sigma_a: &[i32],
    sigma_b: &[i32],
    row0: usize,
    rows: usize,
    col0: usize,
    cols: usize,
) {
    debug_assert_eq!(hi.len(), rows * cols);
    debug_assert_eq!(lo.len(), rows * cols);
    debug_assert!(row0 + rows <= sigma_a.len());
    debug_assert!(col0 + cols <= sigma_b.len());
    for pass in 0..4 {
        for i in 0..rows {
            let sa = sigma_a[row0 + i];
            let ha = sa.div_euclid(2);
            let hrow = &mut hi[i * cols..(i + 1) * cols];
            let lrow = &mut lo[i * cols..(i + 1) * cols];
            match pass {
                0 => {
                    let f = ldexp(1.0, -ha);
                    for (h, l) in hrow.iter_mut().zip(lrow.iter_mut()) {
                        *h *= f;
                        *l *= f;
                    }
                }
                1 => {
                    for (j, (h, l)) in hrow.iter_mut().zip(lrow.iter_mut()).enumerate() {
                        let f = ldexp(1.0, -sigma_b[col0 + j].div_euclid(2));
                        *h *= f;
                        *l *= f;
                    }
                }
                2 => {
                    let f = ldexp(1.0, -(sa - ha));
                    for (h, l) in hrow.iter_mut().zip(lrow.iter_mut()) {
                        *h *= f;
                        *l *= f;
                    }
                }
                _ => {
                    for (j, (h, l)) in hrow.iter_mut().zip(lrow.iter_mut()).enumerate() {
                        let sb = sigma_b[col0 + j];
                        let f = ldexp(1.0, -(sb - sb.div_euclid(2)));
                        *h *= f;
                        *l *= f;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_level_is_exact_scaling() {
        let mut acc = LevelAccumulator::new(3);
        acc.add_level(&[1, -2, 3], 8);
        assert_eq!(acc.hi, vec![256.0, -512.0, 768.0]);
        acc.add_level(&[1, 0, 0], 0);
        assert_eq!(acc.hi[0], 257.0);
        assert_eq!(acc.lo, vec![0.0; 3]);
    }

    #[test]
    fn compensation_preserves_cancelled_bits() {
        // big + 1 - big: plain f64 loses the 1; the compensated pair keeps it.
        let mut acc = LevelAccumulator::new(1);
        acc.add_level(&[1 << 40], 60); // 2^100
        acc.add_level(&[1], 0); // + 1
        acc.add_level(&[-(1 << 40)], 60); // - 2^100
        let c = recompose(acc, &[0], &[0], 1, 1);
        assert_eq!(c.at(0, 0), 1.0);
    }

    #[test]
    fn recompose_applies_outer_scales() {
        let (m, n) = (2, 3);
        let sa = [10, -7];
        let sb = [3, 0, -20];
        let mut acc = LevelAccumulator::new(m * n);
        for i in 0..m {
            for j in 0..n {
                acc.hi[i * n + j] = ldexp(1.0, sa[i] + sb[j]);
            }
        }
        let c = recompose(acc, &sa, &sb, m, n);
        for v in &c.data {
            assert_eq!(*v, 1.0);
        }
    }

    #[test]
    fn tiled_descaling_is_bitwise_identical_to_whole() {
        // Descale a 5x7 accumulator whole, and again as 2x3 tiles: every
        // element must come out bitwise identical (the fused-engine
        // invariant).
        let (m, n) = (5usize, 7usize);
        let sa: Vec<i32> = (0..m as i32).map(|i| 40 * i - 60).collect();
        let sb: Vec<i32> = (0..n as i32).map(|j| 25 - 17 * j).collect();
        let fill = |idx: usize| ((idx * 37 % 19) as f64 - 9.0) * 1.5;
        let mut acc = LevelAccumulator::new(m * n);
        for idx in 0..m * n {
            acc.hi[idx] = fill(idx);
            acc.lo[idx] = fill(idx + 3) * 1e-18;
        }
        let whole = recompose(acc, &sa, &sb, m, n);
        let (tr, tc) = (2usize, 3usize);
        let mut row0 = 0;
        while row0 < m {
            let rows = tr.min(m - row0);
            let mut col0 = 0;
            while col0 < n {
                let cols = tc.min(n - col0);
                let mut hi = vec![0.0; rows * cols];
                let mut lo = vec![0.0; rows * cols];
                for i in 0..rows {
                    for j in 0..cols {
                        let idx = (row0 + i) * n + (col0 + j);
                        hi[i * cols + j] = fill(idx);
                        lo[i * cols + j] = fill(idx + 3) * 1e-18;
                    }
                }
                descale_tile(&mut hi, &mut lo, &sa, &sb, row0, rows, col0, cols);
                for i in 0..rows {
                    for j in 0..cols {
                        let got = hi[i * cols + j] + lo[i * cols + j];
                        let want = whole.at(row0 + i, col0 + j);
                        assert_eq!(got.to_bits(), want.to_bits(), "({},{})", row0 + i, col0 + j);
                    }
                }
                col0 += cols;
            }
            row0 += rows;
        }
    }

    #[test]
    fn recompose_extreme_mixed_scales_no_spurious_overflow() {
        let sa = [1120, -940];
        let sb = [-940, 1120];
        // Cells (0,0) and (1,1) have sigma sums of 180: representable acc,
        // representable result, but each single factor 2^-1120 / 2^+940
        // would over/underflow — the interleaved halves must not.
        let mut acc = LevelAccumulator::new(4);
        acc.hi[0] = ldexp(1.0, sa[0] + sb[0]);
        acc.hi[3] = ldexp(1.0, sa[1] + sb[1]);
        let c = recompose(acc, &sa, &sb, 2, 2);
        assert_eq!(c.at(0, 0), 1.0);
        assert_eq!(c.at(1, 1), 1.0);
        assert_eq!(c.at(0, 1), 0.0);
    }
}
