//! The decomposition-scheme seam: one trait over the two Ozaki families.
//!
//! Both schemes share everything upstream (ESC sizing, exception
//! fallbacks, the per-row window placement, the `ozaki::kernel`
//! microkernels, the workspace pool) and differ only in *what* integer
//! GEMMs run and *how* their results recombine:
//!
//! * [`SlicePairScheme`] — Ozaki-I positional digits, `s(s+1)/2` pair
//!   GEMMs under triangular truncation (`gemm::fused_gemm_on`);
//! * [`CrtScheme`] — Ozaki-II residues, one GEMM per modulus with CRT
//!   reconstruction (`crt::crt_gemm_on`), linear launch count for the
//!   same window.
//!
//! `AdpEngine` resolves a [`SchemeKind`] per request (ESC-sized for both
//! families from the same coarse bound, cost-compared by the heuristic)
//! and dispatches emulation through [`DecompositionScheme`], so adding a
//! third family is one more implementor, not a coordinator rewrite.

use super::crt::{crt_gemm_on, CrtConfig};
use super::gemm::fused_gemm_on;
use super::{OzakiConfig, SliceEncoding};
use crate::backend::{ComputeBackend, WorkspacePool};
use crate::linalg::Matrix;

/// Declarative scheme selection (plain data for configs/metrics/keys).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SchemeKind {
    /// Ozaki-I positional slice pairs (quadratic launch count).
    SlicePair,
    /// Ozaki-II modular/CRT residues (linear launch count).
    Crt,
}

impl SchemeKind {
    pub fn label(self) -> &'static str {
        match self {
            SchemeKind::SlicePair => "slice-pair",
            SchemeKind::Crt => "crt",
        }
    }
}

/// A concrete, fully-parameterized decomposition scheme: everything the
/// engine needs to run (and account for) one emulated GEMM.
pub trait DecompositionScheme {
    fn kind(&self) -> SchemeKind;

    fn label(&self) -> &'static str {
        self.kind().label()
    }

    /// Integer GEMM launches per k-chunk (the cost-model unit).
    fn integer_gemms(&self) -> usize;

    /// Effective mantissa bits of the scheme's window.
    fn effective_bits(&self) -> i32;

    /// Run the emulated GEMM on `backend`, drawing scratch from
    /// `workspaces`.
    fn gemm_on(
        &self,
        a: &Matrix,
        b: &Matrix,
        backend: &dyn ComputeBackend,
        workspaces: &WorkspacePool,
    ) -> Matrix;
}

/// Ozaki-I slice pairs — the default family, valid for every window.
#[derive(Clone, Copy, Debug)]
pub struct SlicePairScheme {
    pub cfg: OzakiConfig,
}

impl SlicePairScheme {
    pub fn new(cfg: OzakiConfig) -> SlicePairScheme {
        SlicePairScheme { cfg }
    }
}

impl DecompositionScheme for SlicePairScheme {
    fn kind(&self) -> SchemeKind {
        SchemeKind::SlicePair
    }

    fn integer_gemms(&self) -> usize {
        self.cfg.pair_count()
    }

    fn effective_bits(&self) -> i32 {
        self.cfg.encoding.effective_bits(self.cfg.slices)
    }

    fn gemm_on(
        &self,
        a: &Matrix,
        b: &Matrix,
        backend: &dyn ComputeBackend,
        workspaces: &WorkspacePool,
    ) -> Matrix {
        fused_gemm_on(a, b, &self.cfg, backend, workspaces)
    }
}

/// Ozaki-II/CRT — selectable whenever the window fits the modulus basis
/// ([`CrtConfig::for_window`] returned `Some`).
#[derive(Clone, Copy, Debug)]
pub struct CrtScheme {
    pub cfg: CrtConfig,
}

impl CrtScheme {
    pub fn new(cfg: CrtConfig) -> CrtScheme {
        CrtScheme { cfg }
    }
}

impl DecompositionScheme for CrtScheme {
    fn kind(&self) -> SchemeKind {
        SchemeKind::Crt
    }

    fn integer_gemms(&self) -> usize {
        self.cfg.gemm_count()
    }

    fn effective_bits(&self) -> i32 {
        // Same window as `s_eq` unsigned slices.
        SliceEncoding::Unsigned.effective_bits(self.cfg.s_eq)
    }

    fn gemm_on(
        &self,
        a: &Matrix,
        b: &Matrix,
        backend: &dyn ComputeBackend,
        workspaces: &WorkspacePool,
    ) -> Matrix {
        crt_gemm_on(a, b, &self.cfg, backend, workspaces)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::SerialBackend;
    use crate::ozaki::gemm::K_CHUNK;
    use crate::util::Rng;

    #[test]
    fn labels_and_counts() {
        let sp = SlicePairScheme::new(OzakiConfig::new(7));
        assert_eq!(sp.kind(), SchemeKind::SlicePair);
        assert_eq!(sp.label(), "slice-pair");
        assert_eq!(sp.integer_gemms(), 28);
        assert_eq!(sp.effective_bits(), 54);
        let crt = CrtScheme::new(CrtConfig::for_window(7, K_CHUNK).unwrap());
        assert_eq!(crt.kind(), SchemeKind::Crt);
        assert_eq!(crt.label(), "crt");
        assert_eq!(crt.integer_gemms(), 17);
        assert_eq!(crt.effective_bits(), 54);
        assert!(crt.integer_gemms() < sp.integer_gemms());
    }

    #[test]
    fn both_schemes_run_through_the_trait() {
        let mut rng = Rng::new(905);
        let a = Matrix::uniform(9, 14, -2.0, 2.0, &mut rng);
        let b = Matrix::uniform(14, 7, -2.0, 2.0, &mut rng);
        let pool = WorkspacePool::new();
        let schemes: [&dyn DecompositionScheme; 2] = [
            &SlicePairScheme::new(OzakiConfig::new(7)),
            &CrtScheme::new(CrtConfig::for_window(7, 14).unwrap()),
        ];
        let reference = crate::linalg::gemm::gemm(&a, &b);
        for sch in schemes {
            let c = sch.gemm_on(&a, &b, &SerialBackend, &pool);
            for (x, y) in c.data.iter().zip(&reference.data) {
                assert!(
                    (x - y).abs() <= 1e-12 * y.abs().max(1.0),
                    "{}: {x} vs {y}",
                    sch.label()
                );
            }
        }
    }
}
