//! FP64 → INT8 slice decomposition (§3 of the paper).
//!
//! Mirrors `python/compile/ozaki.py` exactly (same formulas, same rounding,
//! same remap order) so the native path and the AOT artifacts produce
//! bitwise-identical results.

use super::crt::{center, CrtBasis};
use super::SliceEncoding;
use crate::linalg::Matrix;
use crate::util::bits::{frexp_exponent, ldexp, ZERO_EXP};

/// One operand decomposed into INT8 slices.
///
/// Layout: `data[t * rows * cols + i * cols + j]` = digit `t` (0 = leading)
/// of element (i, j). For A this is row-major A itself; for B the tensor
/// holds **B transposed** (rows = n, cols = k) so the slice-pair GEMM walks
/// both operands contiguously.
#[derive(Clone, Debug)]
pub struct SlicedMatrix {
    pub s: usize,
    pub rows: usize,
    pub cols: usize,
    /// Per-row scaling exponents sigma (for B: per column of the original).
    pub sigma: Vec<i32>,
    pub data: Vec<i8>,
    pub encoding: SliceEncoding,
}

impl SlicedMatrix {
    #[inline]
    pub fn slice(&self, t: usize) -> &[i8] {
        &self.data[t * self.rows * self.cols..(t + 1) * self.rows * self.cols]
    }

    #[inline]
    pub fn slice_row(&self, t: usize, i: usize) -> &[i8] {
        let base = t * self.rows * self.cols + i * self.cols;
        &self.data[base..base + self.cols]
    }

    /// Rows `[row0, row0 + rows)` of slice `t`, as one contiguous
    /// row-major block — the tile-ranged accessor of the fused engine:
    /// a tile's operand rows (for B: its output columns, since B is
    /// stored transposed) are one cache-friendly slab.
    #[inline]
    pub fn slice_rows(&self, t: usize, row0: usize, rows: usize) -> &[i8] {
        debug_assert!(row0 + rows <= self.rows);
        let base = t * self.rows * self.cols + row0 * self.cols;
        &self.data[base..base + rows * self.cols]
    }

    /// Reconstruct element (i, j) — test/debug helper, O(s). Accumulates
    /// in double-double: exact for windows up to ~106 bits (s <= 13).
    pub fn reconstruct(&self, i: usize, j: usize) -> f64 {
        let rb = self.encoding.radix_bits();
        let mut acc = crate::dd::Dd::ZERO;
        for t in (0..self.s).rev() {
            let d = self.data[t * self.rows * self.cols + i * self.cols + j] as f64;
            acc = acc.add_f64(d * crate::util::bits::ldexp(1.0, rb * (self.s as i32 - 1 - t as i32)));
        }
        ldexp(acc.hi, -self.sigma[i]) + ldexp(acc.lo, -self.sigma[i])
    }
}

/// Row accessor the decomposition loop walks: A is sliced row-major as
/// stored; B is sliced as B^T **without materializing the transpose** —
/// the strided column walk happens inside the accessor instead of an
/// O(k·n) allocate-and-copy per decomposition on the hot path.
trait SliceSource {
    fn rows(&self) -> usize;
    fn cols(&self) -> usize;
    /// Element (i, l) of the logical row-major source.
    fn at(&self, i: usize, l: usize) -> f64;
}

/// A as-is: logical row i is the stored row i.
struct RowMajor<'a>(&'a Matrix);

impl SliceSource for RowMajor<'_> {
    fn rows(&self) -> usize {
        self.0.rows
    }
    fn cols(&self) -> usize {
        self.0.cols
    }
    #[inline(always)]
    fn at(&self, i: usize, l: usize) -> f64 {
        self.0.data[i * self.0.cols + l]
    }
}

/// B^T view: logical row i is stored column i of B, read with stride
/// `b.cols`.
struct Transposed<'a>(&'a Matrix);

impl SliceSource for Transposed<'_> {
    fn rows(&self) -> usize {
        self.0.cols
    }
    fn cols(&self) -> usize {
        self.0.rows
    }
    #[inline(always)]
    fn at(&self, i: usize, l: usize) -> f64 {
        self.0.data[l * self.0.cols + i]
    }
}

/// Decompose rows of A. `a` is (m, k); result tensor is (s, m, k) with
/// per-row scaling.
pub fn slice_a(a: &Matrix, s: usize, encoding: SliceEncoding) -> SlicedMatrix {
    slice_rows_impl(&RowMajor(a), s, encoding)
}

/// Decompose columns of B. `b` is (k, n); result tensor is (s, n, k) —
/// i.e. slices of B^T with per-column (of B) scaling. The transpose is
/// fused into the element walk (see [`SliceSource`]); digits and sigma
/// are identical to slicing a materialized `b.transpose()`.
pub fn slice_b(b: &Matrix, s: usize, encoding: SliceEncoding) -> SlicedMatrix {
    slice_rows_impl(&Transposed(b), s, encoding)
}

fn slice_rows_impl<S: SliceSource>(a: &S, s: usize, encoding: SliceEncoding) -> SlicedMatrix {
    let (m, k) = (a.rows(), a.cols());
    let rb = encoding.radix_bits();
    let mut sigma = vec![0i32; m];
    let mut data = vec![0i8; s * m * k];
    let mut digits = vec![0i32; s];

    // Hoisted digit weights: 2^(rb*(s-1-t)) and inverses are constant per
    // call; computing them per element (2s ldexp calls each) dominated the
    // slicing profile before hoisting (EXPERIMENTS.md §Perf #2).
    let w: Vec<f64> = (0..s).map(|t| ldexp(1.0, rb * (s as i32 - 1 - t as i32))).collect();
    let winv: Vec<f64> = (0..s).map(|t| ldexp(1.0, -(rb * (s as i32 - 1 - t as i32)))).collect();
    let mk = m * k;

    for i in 0..m {
        // Row max exponent (frexp convention, zeros excluded).
        let mut emax = ZERO_EXP;
        for l in 0..k {
            let e = frexp_exponent(a.at(i, l));
            if e > emax {
                emax = e;
            }
        }
        let emax_safe = if emax == ZERO_EXP { 0 } else { emax };
        // Window: |v| < 2^(rb*(s-1) + 6) => leading digit in [-64, 63],
        // <= 64 after the unsigned remap carry. (Same 6-bit top for the
        // signed encoding: its sub-leading digits are in [0,127] already.)
        let sig = rb * (s as i32 - 1) + 6 - emax_safe;
        sigma[i] = sig;
        // Row scale 2^sig in two exact halves (sig may exceed 1023).
        let h = sig.div_euclid(2);
        let (f1, f2) = (ldexp(1.0, h), ldexp(1.0, sig - h));

        // Fast path: pure-integer bit-field extraction in u128 (no serial
        // FP dependency chain). Valid while the window's top bit position
        // rb*(s-1)+6 fits u128; beyond that (s > 16) use the float path.
        let int_path = rb * (s as i32 - 1) + 7 < 128;
        for j in 0..k {
            let x = a.at(i, j);
            if x == 0.0 {
                continue; // digits stay zero
            }
            if int_path {
                // digits are rb-bit masked fields (leading < 64): in-range
                // by construction, incl. the +-1 remap carries.
                extract_digits_int(x, sig, rb, s, &mut digits);
                if encoding == SliceEncoding::Unsigned {
                    remap_unsigned(&mut digits);
                }
                for (t, &d) in digits.iter().enumerate() {
                    debug_assert!((-128..=127).contains(&d));
                    data[t * mk + i * k + j] = d as i8;
                }
            } else {
                let v = x * f1 * f2;
                extract_digits_w(v, &w, &winv, &mut digits);
                if encoding == SliceEncoding::Unsigned {
                    remap_unsigned(&mut digits);
                }
                for (t, &d) in digits.iter().enumerate() {
                    // Checked in release on this rare path — a wrapped
                    // digit would corrupt results silently.
                    assert!((-128..=127).contains(&d), "digit {d} out of s8 range");
                    data[t * mk + i * k + j] = d as i8;
                }
            }
        }
    }
    SlicedMatrix { s, rows: m, cols: k, sigma, data, encoding }
}

/// CRT residue planes of A's rows: plane `p` holds the centered residue
/// `A_int[i][l] mod m_p` of the same fixed-point window integer the
/// slice-pair path would decompose at `s_eq` unsigned slices (same sigma,
/// same truncation — see [`window_value`]). Result is a [`SlicedMatrix`]
/// with `s = basis.len()` so all kernel packing machinery applies
/// unchanged; planes are *not* positional digits and must only meet the
/// matching plane of the other operand.
pub fn crt_slice_a(a: &Matrix, s_eq: usize, basis: &CrtBasis) -> SlicedMatrix {
    crt_slice_impl(&RowMajor(a), s_eq, basis)
}

/// CRT residue planes of B's columns (stored as B^T, like [`slice_b`]).
pub fn crt_slice_b(b: &Matrix, s_eq: usize, basis: &CrtBasis) -> SlicedMatrix {
    crt_slice_impl(&Transposed(b), s_eq, basis)
}

fn crt_slice_impl<S: SliceSource>(a: &S, s_eq: usize, basis: &CrtBasis) -> SlicedMatrix {
    let (m, k) = (a.rows(), a.cols());
    let rb = 8i32; // the CRT window rides the unsigned 8-bit radix
    assert!(
        s_eq >= 1 && rb * (s_eq as i32 - 1) + 7 < 128,
        "CRT window must fit the u128 integer path (s_eq={s_eq})"
    );
    let nm = basis.len();
    let moduli = basis.moduli();
    let mut sigma = vec![0i32; m];
    let mut data = vec![0i8; nm * m * k];
    // Residue weight of digit position t in modulus p:
    // wpow[t*nm + p] = centered(2^(8*(s_eq-1-t)) mod m_p), |.| <= 128.
    let mut wpow = vec![0i64; s_eq * nm];
    for (p, &mp) in moduli.iter().enumerate() {
        let mut w = 1i64; // 2^0, the weight of the last digit t = s_eq-1
        for t in (0..s_eq).rev() {
            wpow[t * nm + p] = center(w, mp);
            w = (w << rb) % mp;
        }
    }
    let mk = m * k;
    let mask = (1u128 << rb) - 1;
    let mut fields = vec![0i64; s_eq];
    for i in 0..m {
        // Identical per-row window placement to `slice_rows_impl` at
        // (s_eq, Unsigned): same emax scan, same sigma formula.
        let mut emax = ZERO_EXP;
        for l in 0..k {
            let e = frexp_exponent(a.at(i, l));
            if e > emax {
                emax = e;
            }
        }
        let emax_safe = if emax == ZERO_EXP { 0 } else { emax };
        let sig = rb * (s_eq as i32 - 1) + 6 - emax_safe;
        sigma[i] = sig;
        for j in 0..k {
            let x = a.at(i, j);
            if x == 0.0 {
                continue; // residues stay zero
            }
            let (wv, neg) = window_value(x, sig);
            if wv == 0 {
                continue;
            }
            // Unsigned 8-bit fields of the window integer; the top field
            // takes the whole head (< 2^6 by the window bound).
            for (t, f) in fields.iter_mut().enumerate() {
                let lo = rb * (s_eq as i32 - 1 - t as i32);
                *f = ((wv >> lo) & mask) as i64;
            }
            fields[0] = (wv >> (rb * (s_eq as i32 - 1))) as i64;
            // Sign *before* centering: centering the magnitude and then
            // negating could produce -(-128) for m_0 = 256.
            let sgn = if neg { -1i64 } else { 1 };
            for (p, &mp) in moduli.iter().enumerate() {
                // |acc| <= s_eq * 255 * 128 < 2^20: i64-exact.
                let mut acc = 0i64;
                for (t, &f) in fields.iter().enumerate() {
                    acc += f * wpow[t * nm + p];
                }
                let r = center(sgn * acc, mp);
                debug_assert!((-128..=127).contains(&r));
                data[p * mk + i * k + j] = r as i8;
            }
        }
    }
    // Unsigned: the kernels' contract is "digits as stored"; centered
    // residues use the full i8 range either way, and every SIMD kernel is
    // oracle-tested exact on that full range.
    SlicedMatrix { s: nm, rows: m, cols: k, sigma, data, encoding: SliceEncoding::Unsigned }
}

/// MSB-first digit extraction on the **magnitude**, sign applied by
/// negating the digit vector (value-preserving). Exact in f64: each step
/// strips a *leading* bit field of |v|'s 53-bit significand — extracting
/// on the signed value instead would borrow (`floor(-eps) = -1`,
/// `r = 2^w - |v|`), which f64 cannot represent for elements far below the
/// row max and silently destroys their low bits.
/// Integer fast path: the window's integer content is the 53-bit
/// significand shifted to its window position; digits are plain bit
/// fields. Exactly equivalent to the float path (both truncate |v| at the
/// window ulp, toward zero) — asserted equivalent by unit test below.
#[inline]
fn extract_digits_int(x: f64, sig: i32, radix_bits: i32, s: usize, digits: &mut [i32]) {
    let (wv, neg) = window_value(x, sig);
    let mask = (1u128 << radix_bits) - 1;
    for (t, d) in digits.iter_mut().enumerate() {
        let lo = radix_bits * (s as i32 - 1 - t as i32);
        *d = ((wv >> lo) & mask) as i32;
    }
    // Leading digit: everything above level 1 (< 2^6 by the window bound,
    // so the rb-bit mask above was already wide enough; kept explicit).
    digits[0] = (wv >> (radix_bits * (s as i32 - 1))) as i32;
    if neg {
        for d in digits.iter_mut() {
            *d = -*d;
        }
    }
}

/// The fixed-point window integer of `x` at scale `sig`: the magnitude of
/// `|x| * 2^sig` truncated toward zero at the window ulp, plus the sign.
/// Shared normalization of the slice-pair digit extraction and the CRT
/// residue extraction — both schemes see the *identical* window integer,
/// which is what makes them agree exactly whenever no low bits are
/// truncated. Valid while the window's top bit position fits u128 (the
/// caller's `rb*(s-1)+7 < 128` gate).
#[inline]
pub(crate) fn window_value(x: f64, sig: i32) -> (u128, bool) {
    let bits = x.to_bits();
    let raw = ((bits >> 52) & 0x7FF) as i32;
    let mant_raw = bits & ((1u64 << 52) - 1);
    // Normalize the significand M to [2^52, 2^53) with |x| = M * 2^(e-53),
    // e the frexp exponent (handles subnormals exactly).
    let (mant, e) = if raw != 0 {
        (mant_raw | (1u64 << 52), raw - 1022)
    } else {
        let hb = 63 - mant_raw.leading_zeros() as i32;
        (mant_raw << (52 - hb), hb + 1 - 1074)
    };
    // |v| = mant * 2^shift in window coordinates.
    let shift = e - 53 + sig;
    let wv: u128 = if shift >= 0 {
        (mant as u128) << shift // top bit < rb*(s-1)+7 < 128 by caller check
    } else if shift > -64 {
        (mant >> (-shift).min(63)) as u128
    } else {
        0
    };
    (wv, x < 0.0)
}

#[inline]
fn extract_digits_w(v: f64, w: &[f64], winv: &[f64], digits: &mut [i32]) {
    let s = w.len();
    let av = v.abs();
    let lead = (av * winv[0]).floor();
    digits[0] = lead as i32;
    let mut r = av - lead * w[0];
    for t in 1..s {
        let d = (r * winv[t]).floor();
        r -= d * w[t];
        digits[t] = d as i32;
    }
    if v < 0.0 {
        for d in digits.iter_mut() {
            *d = -*d;
        }
    }
}

/// §3 two's-complement redistribution, LSB → MSB: a u8-magnitude digit in
/// [128, 255] becomes `d - 256` with a `+1` carry into the next-higher
/// slice (and symmetrically `d < -128` becomes `d + 256` with a `-1`
/// carry); bit patterns are preserved (e.g. 200_u8 ≡ -56_i8 = 0b11001000).
/// Carries cascade; the leading digit absorbs at most ±1 (headroom bit).
#[inline]
pub fn remap_unsigned(digits: &mut [i32]) {
    for t in (1..digits.len()).rev() {
        if digits[t] > 127 {
            digits[t] -= 256;
            digits[t - 1] += 1;
        } else if digits[t] < -128 {
            digits[t] += 256;
            digits[t - 1] -= 1;
        }
    }
}

/// The paper's Fig 1 worked example as a checked function: value
/// `hi*256 + lo_u8` re-expressed as `(hi+carry)*256 + lo_s8`.
pub fn fig1_remap(hi: i32, lo_u8: u8) -> (i32, i8) {
    let mut d = [hi, lo_u8 as i32];
    remap_unsigned(&mut d);
    (d[0], d[1] as i8)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::Rng;

    #[test]
    fn fig1_worked_example() {
        // 123*256 + 200 (u8)  ==  124*256 - 56 (s8); bit pattern preserved.
        let (hi, lo) = fig1_remap(123, 200);
        assert_eq!((hi, lo), (124, -56));
        assert_eq!(lo as u8, 200); // 0b11001000 either way
        // Case 1 of Fig 1: values in [0,127] pass through.
        assert_eq!(fig1_remap(9, 42), (9, 42));
    }

    #[test]
    fn remap_exhaustive_preserves_value_and_bits() {
        // Every u8 digit value, with every feasible carry state.
        for d in 0..=255i32 {
            let mut v = [0i32, d];
            remap_unsigned(&mut v);
            assert_eq!(v[0] * 256 + v[1], d, "value preserved");
            assert!((-128..=127).contains(&v[1]));
            assert_eq!(v[1] as i8 as u8, d as u8, "bit pattern preserved");
        }
    }

    #[test]
    fn remap_carry_cascade() {
        // 255 at every level: carries must ripple to the top.
        let mut v = [0i32, 255, 255, 255];
        let orig = 255 * (1 << 16) + 255 * (1 << 8) + 255;
        remap_unsigned(&mut v);
        let got = v[0] * (1 << 24) + v[1] * (1 << 16) + v[2] * (1 << 8) + v[3];
        assert_eq!(got, orig);
        for &d in &v[1..] {
            assert!((-128..=127).contains(&d));
        }
    }

    fn reconstruct_err(x: f64, s: usize, enc: SliceEncoding) -> f64 {
        let a = Matrix::from_rows(1, 1, vec![x]);
        let sl = slice_a(&a, s, enc);
        (sl.reconstruct(0, 0) - x).abs() / x.abs().max(f64::MIN_POSITIVE)
    }

    #[test]
    fn single_value_roundtrip_unsigned() {
        for s in 2..=8 {
            let tol = 2f64.powi(-(8 * s as i32 - 2) + 1);
            for &x in &[1.0, -1.0, 0.1, 123.456, -3.25e10, 7.7e-12, 0.999999] {
                let e = reconstruct_err(x, s, SliceEncoding::Unsigned);
                assert!(e <= tol, "x={x} s={s} err={e} tol={tol}");
            }
        }
    }

    #[test]
    fn full_fidelity_at_7_slices() {
        // 54 effective bits >= 53-bit significand: row-max elements round-trip
        // *exactly* at s=7 (unsigned).
        let mut rng = Rng::new(21);
        for _ in 0..200 {
            let x = rng.uniform(-10.0, 10.0);
            assert_eq!(reconstruct_err(x, 7, SliceEncoding::Unsigned), 0.0, "x={x}");
        }
    }

    #[test]
    fn signed_needs_eight() {
        let mut rng = Rng::new(22);
        for _ in 0..100 {
            let x = rng.uniform(-1.0, 1.0);
            assert_eq!(reconstruct_err(x, 8, SliceEncoding::Signed), 0.0, "x={x}");
        }
    }

    #[test]
    fn row_scaling_is_per_row() {
        let a = Matrix::from_rows(2, 2, vec![1.0, 0.5, 1e100, 2e100]);
        let sl = slice_a(&a, 7, SliceEncoding::Unsigned);
        assert_ne!(sl.sigma[0], sl.sigma[1]);
        for i in 0..2 {
            for j in 0..2 {
                assert_eq!(sl.reconstruct(i, j), a.at(i, j));
            }
        }
    }

    #[test]
    fn slice_rows_matches_per_row_accessor() {
        let mut rng = Rng::new(23);
        let a = Matrix::uniform(7, 5, -2.0, 2.0, &mut rng);
        let sl = slice_a(&a, 4, SliceEncoding::Unsigned);
        for t in 0..4 {
            assert_eq!(sl.slice_rows(t, 0, 7), sl.slice(t), "full range is the whole slice");
            for row0 in 0..7 {
                for rows in 0..=(7 - row0) {
                    let block = sl.slice_rows(t, row0, rows);
                    for i in 0..rows {
                        assert_eq!(
                            &block[i * 5..(i + 1) * 5],
                            sl.slice_row(t, row0 + i),
                            "t={t} row0={row0} rows={rows} i={i}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn slice_b_matches_transposed_slice_a() {
        // The fused-transpose satellite: slicing B through the strided
        // view must produce the identical tensor (digits, sigma, shape)
        // as slicing a materialized B^T — including wide exponent spans
        // and zeros, where the per-row emax scan matters most.
        let mut rng = Rng::new(26);
        for (kk, n) in [(1usize, 1usize), (7, 9), (16, 5)] {
            let mut b = Matrix::uniform(kk, n, -3.0, 3.0, &mut rng);
            if kk > 2 && n > 2 {
                *b.at_mut(1, 1) = 0.0;
                *b.at_mut(2, 0) *= 2f64.powi(200);
                *b.at_mut(0, 2) *= 2f64.powi(-180);
            }
            for enc in [SliceEncoding::Unsigned, SliceEncoding::Signed] {
                for s in [2usize, 5, 8] {
                    let sb = slice_b(&b, s, enc);
                    let sa = slice_a(&b.transpose(), s, enc);
                    assert_eq!((sb.rows, sb.cols, sb.s), (n, kk, s));
                    assert_eq!(sb.sigma, sa.sigma, "k={kk} n={n} {enc:?} s={s}");
                    assert_eq!(sb.data, sa.data, "k={kk} n={n} {enc:?} s={s}");
                }
            }
        }
    }

    #[test]
    fn zeros_give_zero_digits() {
        let a = Matrix::from_rows(1, 3, vec![0.0, -0.0, 5.0]);
        let sl = slice_a(&a, 4, SliceEncoding::Unsigned);
        for t in 0..4 {
            assert_eq!(sl.slice_row(t, 0)[0], 0);
            assert_eq!(sl.slice_row(t, 0)[1], 0, "negative zero treated as zero");
        }
    }

    #[test]
    fn subnormal_rows() {
        let tiny = f64::from_bits(123); // deep subnormal
        let a = Matrix::from_rows(1, 2, vec![tiny, 2.0 * tiny]);
        let sl = slice_a(&a, 7, SliceEncoding::Unsigned);
        assert_eq!(sl.reconstruct(0, 0), tiny);
        assert_eq!(sl.reconstruct(0, 1), 2.0 * tiny);
    }

    #[test]
    fn prop_int_and_float_extraction_agree() {
        // The integer fast path and the float path must produce identical
        // digit vectors for every input (both truncate |v| toward zero at
        // the window ulp).
        prop::check("int vs float digit extraction", 300, |rng| {
            let s = rng.int(2, 12) as usize;
            let rb = if rng.f64() < 0.5 { 8 } else { 7 };
            let e = rng.int(-1070, 1020) as i32;
            let x = rng.uniform(-2.0, 2.0) * crate::util::bits::ldexp(1.0, e);
            if x == 0.0 {
                return Ok(());
            }
            let emax = frexp_exponent(x);
            let sig = rb * (s as i32 - 1) + 6 - emax;
            let w: Vec<f64> = (0..s).map(|t| ldexp(1.0, rb * (s as i32 - 1 - t as i32))).collect();
            let winv: Vec<f64> =
                (0..s).map(|t| ldexp(1.0, -(rb * (s as i32 - 1 - t as i32)))).collect();
            let mut d_int = vec![0i32; s];
            let mut d_flt = vec![0i32; s];
            extract_digits_int(x, sig, rb, s, &mut d_int);
            let h = sig.div_euclid(2);
            let v = x * ldexp(1.0, h) * ldexp(1.0, sig - h);
            extract_digits_w(v, &w, &winv, &mut d_flt);
            prop::assert_that(
                d_int == d_flt,
                format!("x={x:e} s={s} rb={rb}: {d_int:?} vs {d_flt:?}"),
            )
        });
    }

    #[test]
    fn prop_slicing_within_tolerance() {
        prop::check("slicing relative error bound", 200, |rng| {
            let s = rng.int(2, 9) as usize;
            let enc = if rng.f64() < 0.5 { SliceEncoding::Unsigned } else { SliceEncoding::Signed };
            // exponents spread over a wide range
            let x = rng.uniform(-1.0, 1.0) * 2f64.powi(rng.int(-300, 300) as i32);
            if x == 0.0 {
                return Ok(());
            }
            let tol = 2f64.powi(-enc.effective_bits(s) + 1);
            let e = reconstruct_err(x, s, enc);
            prop::assert_that(e <= tol, format!("x={x} s={s} enc={enc:?} err={e} > tol={tol}"))
        });
    }

    #[test]
    fn prop_row_max_exact_roundtrip() {
        // The "full fidelity guarantee" of §4: the row-max element's entire
        // significand is captured whenever effective bits >= 53.
        prop::check("row-max exact at >=53 bits", 100, |rng| {
            let k = 8;
            let mut vals: Vec<f64> = (0..k).map(|_| rng.uniform(-4.0, 4.0)).collect();
            vals[3] = 8.5; // known max
            let a = Matrix::from_rows(1, k, vals.clone());
            let sl = slice_a(&a, 7, SliceEncoding::Unsigned);
            prop::assert_that(
                sl.reconstruct(0, 3) == 8.5,
                "row max must round-trip exactly",
            )
        });
    }

    #[test]
    fn leading_digit_headroom_never_overflows() {
        // Adversarial: values just below a power of two maximize the leading
        // digit; carry from below must stay within i8.
        let mut vals = vec![];
        for e in [-5, 0, 10] {
            let below = f64::from_bits((2f64.powi(e)).to_bits() - 1);
            vals.push(below);
            vals.push(-below);
            vals.push(2f64.powi(e));
        }
        let k = vals.len();
        let a = Matrix::from_rows(1, k, vals.clone());
        let row_max = vals.iter().fold(0.0f64, |m, v| m.max(v.abs()));
        for s in 2..=9 {
            let sl = slice_a(&a, s, SliceEncoding::Unsigned);
            // the assert in slice_rows_impl would have caught digit
            // overflow; verify reconstruction error stays bounded too
            // (window-relative: the bound is anchored at the row max).
            let tol = 2f64.powi(-(8 * s as i32 - 2) + 1) * row_max * 2.0;
            for j in 0..k {
                let err = (sl.reconstruct(0, j) - vals[j]).abs();
                assert!(err <= tol, "s={s} j={j} err={err} tol={tol}");
            }
        }
    }
}
