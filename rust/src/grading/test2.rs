//! Test 2: distinguish an O(n^3) floating-point implementation from an
//! O(n^3) fixed-point implementation (§6, implemented verbatim).
//!
//! The workload has a wide, permutation-protected exponent span; a
//! fixed-point implementation with a fixed bit budget loses the low-order
//! contributions once the span exceeds its window, while a floating-point
//! implementation (or one with guardrails and FP64 fallback, like ADP)
//! keeps the componentwise error at O(n) eps. The relative-error metric is
//! the paper's: diagonal entries against x^T x in extended precision,
//! off-diagonal against a reference O(n^3) product.

use super::generators::{test2_workload, Test2Workload};
use super::Multiplier;
use crate::dd;
use crate::linalg::Matrix;
use crate::util::Rng;

/// Error threshold (relative) above which the implementation is declared
/// fixed-point. Floating-point O(n^3) stays below ~n eps ~ 1e-13 here;
/// fixed-point failures jump above 1e-8 almost immediately.
const FIXED_POINT_THRESHOLD: f64 = 1e-9;

/// The paper's Fig 2 relative error for one (implementation, b) pair.
pub fn relative_error(w: &Test2Workload, c: &Matrix) -> f64 {
    let n = w.a.rows;
    let xtx = dd::dot(&w.x, &w.x);
    let c_ref = w.a.matmul_dd(&w.b);
    let mut worst = 0.0f64;
    for i in 0..n {
        for j in 0..n {
            let e = if i == j {
                (xtx.to_f64() - c.at(i, i)).abs() / xtx.to_f64()
            } else {
                let r = c_ref.at(i, j);
                if r == 0.0 {
                    continue;
                }
                (r - c.at(i, j)).abs() / r.abs()
            };
            worst = worst.max(e);
        }
    }
    worst
}

/// Run Test 2 at exponent-range parameter `b` and return the error.
pub fn run_at(n: usize, span_b: i32, seed: u64, mult: Multiplier) -> f64 {
    let mut rng = Rng::new(seed);
    let w = test2_workload(n, span_b, &mut rng);
    let c = mult(&w.a, &w.b);
    relative_error(&w, &c)
}

/// Test 2 verdict, sweeping b upward until the span stresses the window.
pub fn is_fixed_point(n: usize, seed: u64, mult: Multiplier) -> bool {
    for span_b in [8, 24, 48, 96] {
        if run_at(n, span_b, seed, mult) > FIXED_POINT_THRESHOLD {
            return true;
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm;
    use crate::ozaki::{emulated_gemm, OzakiConfig};

    #[test]
    fn native_gemm_is_floating_point() {
        let mut m = |a: &_, b: &_| gemm(a, b);
        assert!(!is_fixed_point(64, 5, &mut m));
    }

    #[test]
    fn fixed_slices_detected_as_fixed_point() {
        // Emulation pinned at 7 slices (no guardrails): the paper's solid
        // lines in Fig 2 — fails once b exceeds the window.
        let mut m = |a: &_, b: &_| emulated_gemm(a, b, &OzakiConfig::new(7));
        assert!(is_fixed_point(64, 5, &mut m));
    }

    #[test]
    fn error_grows_with_span_for_fixed_slices() {
        let mut m = |a: &_, b: &_| emulated_gemm(a, b, &OzakiConfig::new(7));
        let e_small = run_at(48, 2, 6, &mut m);
        let e_large = run_at(48, 60, 6, &mut m);
        assert!(e_small < 1e-12, "small span should be accurate: {e_small}");
        assert!(e_large > 1e-6, "large span should break the window: {e_large}");
    }

    #[test]
    fn enough_slices_recover_accuracy() {
        // ESC-sized slices (the dashed lines of Fig 2, before fallback is
        // even needed): b=40 span requires ~(53+81)/8 ~ 17 slices.
        let mut m = |a: &_, b: &_| emulated_gemm(a, b, &OzakiConfig::new(18));
        let e = run_at(48, 40, 7, &mut m);
        assert!(e < 1e-12, "e={e}");
    }
}
