//! BLAS grading tests of Demmel et al. (§6 of the paper).
//!
//! Two instruments:
//!
//! * **Algorithm discovery** — Tests 1–3 classify an unknown GEMM
//!   implementation along two axes: O(n^3) vs Strassen-like, and
//!   floating-point vs fixed-point. Test 2 (the one Fig 2 evaluates) is
//!   fully specified in the paper and implemented verbatim in [`test2`];
//!   Tests 1 and 3 are from an unpublished manuscript (paper ref [7],
//!   private communication) and are implemented here from the paper's
//!   stated discrimination criteria — see DESIGN.md §Substitutions.
//! * **Grading** — the Grade A componentwise criterion
//!   `|fl(AB) - AB| <= f(n) * eps * (|A||B|)` with `f(n)` at most linear
//!   ([`grade`]), plus the weaker Grade B/C norm-wise criteria.
//!
//! All reference products are computed in double-double (`crate::dd`).

pub mod generators;
pub mod grade;
pub mod test1;
pub mod test2;
pub mod test3;

use crate::linalg::Matrix;

/// A matrix-multiplication implementation under test.
pub type Multiplier<'a> = &'a mut dyn FnMut(&Matrix, &Matrix) -> Matrix;

/// Outcome of the discovery tree (§6).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AlgorithmClass {
    FloatingPointO3,
    FixedPointO3,
    FloatingPointStrassen,
    FixedPointStrassen,
}

/// Run the full discovery tree: Test 1, then Test 2 or Test 3.
pub fn discover(n: usize, seed: u64, mult: Multiplier) -> AlgorithmClass {
    let strassen_like = test1::is_strassen_like(n, seed, mult);
    if strassen_like {
        if test3::is_fixed_point_strassen(n, seed, mult) {
            AlgorithmClass::FixedPointStrassen
        } else {
            AlgorithmClass::FloatingPointStrassen
        }
    } else if test2::is_fixed_point(n, seed, mult) {
        AlgorithmClass::FixedPointO3
    } else {
        AlgorithmClass::FloatingPointO3
    }
}
