//! Accuracy grading criteria (Demmel et al., §6 Aspect A2).
//!
//! Grade A: componentwise `|fl(AB) - AB|_ij <= f(n) eps (|A||B|)_ij` with
//! `f(n)` at most linear. Grade B: mixed componentwise/norm-wise. Grade C:
//! norm-wise only (satisfiable by Strassen-like algorithms).

use crate::linalg::Matrix;

/// Componentwise error measurements of one product.
#[derive(Clone, Copy, Debug)]
pub struct ErrorReport {
    /// max_ij |C - C_ref| / (|A| |B|)_ij, in units of eps.
    pub max_comp_eps: f64,
    /// mean_ij of the same ratio, in units of eps.
    pub avg_comp_eps: f64,
    /// ||C - C_ref||_F / (|| |A||B| ||_F), in units of eps.
    pub normwise_eps: f64,
}

/// Measure componentwise and norm-wise error of `c` against the
/// double-double reference. Entries where (|A||B|)_ij == 0 must be exact.
pub fn measure(a: &Matrix, b: &Matrix, c: &Matrix) -> ErrorReport {
    let c_ref = a.matmul_dd(b);
    let denom = a.abs().matmul_dd(&b.abs());
    let mut max_r = 0.0f64;
    let mut sum_r = 0.0f64;
    let mut err_sq = 0.0f64;
    let mut den_sq = 0.0f64;
    let cnt = (c.rows * c.cols) as f64;
    for idx in 0..c.data.len() {
        let e = (c.data[idx] - c_ref.data[idx]).abs();
        let d = denom.data[idx];
        err_sq += e * e;
        den_sq += d * d;
        if d == 0.0 {
            assert_eq!(e, 0.0, "zero-denominator entry must be exact");
            continue;
        }
        let r = e / d;
        max_r = max_r.max(r);
        sum_r += r;
    }
    ErrorReport {
        max_comp_eps: max_r / f64::EPSILON,
        avg_comp_eps: (sum_r / cnt) / f64::EPSILON,
        normwise_eps: (err_sq.sqrt() / den_sq.sqrt().max(f64::MIN_POSITIVE)) / f64::EPSILON,
    }
}

/// Grade A compliance: max componentwise error <= slope * n * eps.
/// `slope` absorbs the modest constant in f(n); the criterion is about
/// *growth*, so callers checking a size sweep should use [`fits_grade_a`].
pub fn passes_grade_a(report: &ErrorReport, n: usize, slope: f64) -> bool {
    report.max_comp_eps <= slope * n as f64
}

/// Grade C (norm-wise) compliance with the same linear-growth budget.
pub fn passes_grade_c(report: &ErrorReport, n: usize, slope: f64) -> bool {
    report.normwise_eps <= slope * n as f64
}

/// Fit error growth over a size sweep: returns the least-squares exponent
/// `p` of `err ~ n^p`. Grade A requires p <= ~1 (linear); Strassen-like
/// error growth shows p noticeably above the O(n^3) implementations'.
pub fn growth_exponent(sizes: &[usize], errs_eps: &[f64]) -> f64 {
    assert_eq!(sizes.len(), errs_eps.len());
    let pts: Vec<(f64, f64)> = sizes
        .iter()
        .zip(errs_eps)
        .filter(|&(_, &e)| e > 0.0)
        .map(|(&n, &e)| ((n as f64).ln(), e.ln()))
        .collect();
    let n = pts.len() as f64;
    if n < 2.0 {
        return 0.0;
    }
    let sx: f64 = pts.iter().map(|p| p.0).sum();
    let sy: f64 = pts.iter().map(|p| p.1).sum();
    let sxx: f64 = pts.iter().map(|p| p.0 * p.0).sum();
    let sxy: f64 = pts.iter().map(|p| p.0 * p.1).sum();
    (n * sxy - sx * sy) / (n * sxx - sx * sx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{gemm, strassen};
    use crate::util::Rng;

    #[test]
    fn native_gemm_is_grade_a() {
        let mut rng = Rng::new(70);
        for n in [32, 64, 128] {
            let a = Matrix::uniform(n, n, 0.0, 1.0, &mut rng);
            let b = Matrix::uniform(n, n, 0.0, 1.0, &mut rng);
            let rep = measure(&a, &b, &gemm(&a, &b));
            assert!(passes_grade_a(&rep, n, 2.0), "n={n} rep={rep:?}");
        }
    }

    #[test]
    fn exact_product_reports_zero() {
        let a = Matrix::identity(8);
        let b = Matrix::identity(8);
        let rep = measure(&a, &b, &gemm(&a, &b));
        assert_eq!(rep.max_comp_eps, 0.0);
        assert_eq!(rep.avg_comp_eps, 0.0);
    }

    #[test]
    fn strassen_fails_componentwise_on_tiny_corner() {
        let mut rng = Rng::new(71);
        let n = 256;
        let (a, b) = crate::grading::generators::tiny_corner_pair(n, 2f64.powi(-30), &mut rng);
        let rep_s = measure(&a, &b, &strassen(&a, &b));
        let rep_g = measure(&a, &b, &gemm(&a, &b));
        assert!(passes_grade_a(&rep_g, n, 2.0), "gemm {rep_g:?}");
        assert!(!passes_grade_a(&rep_s, n, 16.0), "strassen should fail: {rep_s:?}");
        // ...but Strassen still passes the norm-wise Grade C criterion.
        assert!(passes_grade_c(&rep_s, n, 16.0), "strassen normwise {rep_s:?}");
    }

    #[test]
    fn growth_exponent_recovers_slope() {
        let sizes = [64usize, 128, 256, 512];
        let errs: Vec<f64> = sizes.iter().map(|&n| 0.3 * (n as f64).powf(0.5)).collect();
        let p = growth_exponent(&sizes, &errs);
        assert!((p - 0.5).abs() < 1e-9, "p={p}");
    }
}
