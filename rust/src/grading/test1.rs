//! Test 1: distinguish a conventional O(n^3) implementation from a
//! Strassen-like one (§6).
//!
//! Paper ref [7] is unpublished; we implement the stated discrimination
//! criterion: O(n^3) algorithms satisfy the componentwise bound
//! `|fl(AB) - AB| <= f(n) eps (|A||B|)` (Grade A), while Strassen-like
//! recombination injects errors of absolute size ~ eps * ||A|| * ||B||
//! into *small* entries of |A||B|. A magnitude staircase (tiny first row
//! of A / first column of B) makes that ratio blow up by ~delta^-2 for
//! Strassen while leaving O(n^3) implementations at O(n) eps.

use super::generators::tiny_corner_pair;
use super::grade::measure;
use super::Multiplier;
use crate::util::Rng;

/// Scale of the tiny row/column. delta^2 ~ 2^-60 leaves plenty of headroom
/// between the O(n^3) bound (~n eps) and the Strassen contamination
/// (~eps/delta^2 = 2^60 eps) without approaching underflow.
const DELTA: f64 = 1.0 / (1u64 << 30) as f64;

/// Componentwise-error threshold in units of n*eps separating the classes.
const THRESHOLD_SLOPE: f64 = 64.0;

pub fn is_strassen_like(n: usize, seed: u64, mult: Multiplier) -> bool {
    let mut rng = Rng::new(seed);
    let (a, b) = tiny_corner_pair(n, DELTA, &mut rng);
    let c = mult(&a, &b);
    let rep = measure(&a, &b, &c);
    rep.max_comp_eps > THRESHOLD_SLOPE * n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{gemm, strassen};
    use crate::ozaki::{emulated_gemm, OzakiConfig};

    #[test]
    fn classifies_native_gemm_as_o3() {
        let mut m = |a: &_, b: &_| gemm(a, b);
        assert!(!is_strassen_like(128, 1, &mut m));
        assert!(!is_strassen_like(256, 2, &mut m));
    }

    #[test]
    fn classifies_strassen_as_strassen() {
        let mut m = |a: &_, b: &_| strassen(a, b);
        assert!(is_strassen_like(256, 1, &mut m));
        assert!(is_strassen_like(512, 2, &mut m));
    }

    #[test]
    fn classifies_ozaki_as_o3() {
        // The emulated DGEMM is O(n^3): Test 1 must send it to Test 2.
        let mut m = |a: &_, b: &_| emulated_gemm(a, b, &OzakiConfig::new(9));
        assert!(!is_strassen_like(64, 3, &mut m));
    }
}
