//! Workload generators for the grading tests and benches.

use crate::linalg::Matrix;
use crate::util::Rng;

/// Uniform(lo, hi) pair of square matrices (the Fig 3/4 workload).
pub fn uniform_pair(n: usize, lo: f64, hi: f64, rng: &mut Rng) -> (Matrix, Matrix) {
    (Matrix::uniform(n, n, lo, hi, rng), Matrix::uniform(n, n, lo, hi, rng))
}

/// The Test 2 construction of Demmel et al. (§6, implemented verbatim).
///
/// Starting from `x ~ U(1,2)^n` and `D = diag(2^{j_1}, ..., 2^{j_n})` with
/// `j_{i+1} = -b + round(i * delta)`, `delta = 2b/(n-1)`, build
/// `A_{k,:} = x^T D P_k` and `B_{:,k} = P_k^{-1} D^{-1} x` where `P_k` is
/// the cyclic shift by k. The permutations prevent gaming the test by
/// rescaling; the diagonal of `A B` is exactly `x^T x`.
pub struct Test2Workload {
    pub a: Matrix,
    pub b: Matrix,
    pub x: Vec<f64>,
    pub span_b: i32,
}

pub fn test2_workload(n: usize, span_b: i32, rng: &mut Rng) -> Test2Workload {
    assert!(n >= 2);
    let x: Vec<f64> = (0..n).map(|_| rng.uniform(1.0, 2.0)).collect();
    let delta = 2.0 * span_b as f64 / (n as f64 - 1.0);
    let j: Vec<i32> = (0..n)
        .map(|i| -span_b + (i as f64 * delta).round() as i32)
        .collect();
    // xd = x^T D, dinvx = D^{-1} x
    let xd: Vec<f64> = (0..n)
        .map(|i| crate::util::bits::ldexp(x[i], j[i]))
        .collect();
    let dinvx: Vec<f64> = (0..n)
        .map(|i| crate::util::bits::ldexp(x[i], -j[i]))
        .collect();
    // A[k, c] = xd[(c + k) mod n]; B[r, k] = dinvx[(r + k) mod n].
    let a = Matrix::from_fn(n, n, |k, c| xd[(c + k) % n]);
    let b = Matrix::from_fn(n, n, |r, k| dinvx[(r + k) % n]);
    Test2Workload { a, b, x, span_b }
}

/// Default Test 2 exponent parameter: `b ~ floor(log2 sqrt(Omega)) -
/// ceil(log2 n) - 1` with Omega the FP64 overflow threshold (§6).
pub fn test2_default_b(n: usize) -> i32 {
    512 - (n as f64).log2().ceil() as i32 - 1
}

/// Magnitude-staircase workload for Test 1: uniform matrices with one tiny
/// row of A and one tiny column of B. The (0,0) entry of |A||B| is ~delta^2
/// while Strassen's recombination injects absolute errors of order
/// eps * max|A| * max|B| * n — blowing up the componentwise ratio there.
pub fn tiny_corner_pair(n: usize, delta: f64, rng: &mut Rng) -> (Matrix, Matrix) {
    let mut a = Matrix::uniform(n, n, 0.5, 1.0, rng);
    let mut b = Matrix::uniform(n, n, 0.5, 1.0, rng);
    for j in 0..n {
        *a.at_mut(0, j) *= delta;
        *b.at_mut(j, 0) *= delta;
    }
    (a, b)
}

/// Matrices laced with special values for the safety-scan tests (§5.1).
pub fn with_special_values(n: usize, kind: SpecialKind, rng: &mut Rng) -> (Matrix, Matrix) {
    let mut a = Matrix::uniform(n, n, -1.0, 1.0, rng);
    let b = Matrix::uniform(n, n, -1.0, 1.0, rng);
    let (i, j) = (rng.index(n), rng.index(n));
    *a.at_mut(i, j) = match kind {
        SpecialKind::Nan => f64::NAN,
        SpecialKind::PosInf => f64::INFINITY,
        SpecialKind::NegInf => f64::NEG_INFINITY,
        SpecialKind::NegZero => -0.0,
    };
    (a, b)
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpecialKind {
    Nan,
    PosInf,
    NegInf,
    NegZero,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dd;

    #[test]
    fn test2_diagonal_is_xtx() {
        let mut rng = Rng::new(60);
        let w = test2_workload(32, 20, &mut rng);
        let xtx = dd::dot(&w.x, &w.x).to_f64();
        // diagonal entries of AB equal x^T x *exactly* (in exact arithmetic):
        // compute one in double-double and compare.
        let bt = w.b.transpose();
        for k in [0usize, 7, 31] {
            let diag = dd::dot(w.a.row(k), bt.row(k)).to_f64();
            let rel = (diag - xtx).abs() / xtx;
            assert!(rel < 1e-25, "k={k} rel={rel}");
        }
    }

    #[test]
    fn test2_exponent_span_matches_b() {
        let mut rng = Rng::new(61);
        let w = test2_workload(64, 30, &mut rng);
        let mut emax = i32::MIN;
        let mut emin = i32::MAX;
        for &v in &w.a.data {
            let e = crate::util::bits::frexp_exponent(v);
            emax = emax.max(e);
            emin = emin.min(e);
        }
        // exponents of A span ~[-b, b] (+1 for the U(1,2) mantissa)
        assert!((emax - emin) >= 2 * 30 - 2, "span {} too small", emax - emin);
        assert!((emax - emin) <= 2 * 30 + 4);
    }

    #[test]
    fn test2_b_zero_degenerates_to_uniform() {
        let mut rng = Rng::new(62);
        let w = test2_workload(16, 0, &mut rng);
        for &v in &w.a.data {
            assert!((1.0..2.0).contains(&v));
        }
    }

    #[test]
    fn default_b_reasonable() {
        assert_eq!(test2_default_b(1024), 512 - 10 - 1);
        assert!(test2_default_b(64) > 490);
    }

    #[test]
    fn tiny_corner_shapes() {
        let mut rng = Rng::new(63);
        let (a, b) = tiny_corner_pair(16, 2f64.powi(-40), &mut rng);
        assert!(a.at(0, 3).abs() < 2f64.powi(-39));
        assert!(b.at(5, 0).abs() < 2f64.powi(-39));
        assert!(a.at(1, 3).abs() > 0.4);
    }
}
