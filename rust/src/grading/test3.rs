//! Test 3: distinguish a Strassen-like floating-point implementation from a
//! Strassen-like fixed-point implementation (§6).
//!
//! Like Test 1, the underlying manuscript (paper ref [7]) is unpublished;
//! the discrimination criterion follows the paper's description: apply the
//! wide-exponent-span Test 2 construction but judge with the *norm-wise*
//! (Grade C) criterion that Strassen-like floating-point algorithms do
//! satisfy (their error is ~ n*eps*||A||*||B|| ~ n*eps*||C|| here,
//! independent of the span b). A fixed-point core with window W drops
//! low-order exponent content, leaving a flat norm-wise error ~ 2^(2-W):
//! detectable whenever W is materially below FP64's 53 bits. (A W >= ~52
//! fixed-point core is *theoretically* indistinguishable from FP64 under
//! any norm-wise test — it carries FP64-grade precision.)

use super::generators::test2_workload;
use super::Multiplier;
use crate::util::Rng;

const FIXED_POINT_THRESHOLD: f64 = 1e-9;

/// Norm-wise relative error on the Test-2-style workload.
pub fn run_at(n: usize, span_b: i32, seed: u64, mult: Multiplier) -> f64 {
    let mut rng = Rng::new(seed);
    let w = test2_workload(n, span_b, &mut rng);
    let c = mult(&w.a, &w.b);
    let c_ref = w.a.matmul_dd(&w.b);
    c.sub(&c_ref).fro_norm() / c_ref.fro_norm()
}

pub fn is_fixed_point_strassen(n: usize, seed: u64, mult: Multiplier) -> bool {
    for span_b in [8, 24, 48, 96] {
        if run_at(n, span_b, seed, mult) > FIXED_POINT_THRESHOLD {
            return true;
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::strassen;
    use crate::ozaki::{emulated_gemm, OzakiConfig};

    #[test]
    fn float_strassen_passes_normwise() {
        let mut m = |a: &_, b: &_| strassen(a, b);
        assert!(!is_fixed_point_strassen(64, 8, &mut m));
    }

    #[test]
    fn fixed_point_under_strassen_shell_detected() {
        // A hypothetical Strassen built on a narrow fixed-point core (here
        // a 30-bit window, s = 4): the flat ~2^-28 norm-wise error is far
        // above the floating-point Strassen envelope.
        let mut m = |a: &_, b: &_| emulated_gemm(a, b, &OzakiConfig::new(4));
        assert!(is_fixed_point_strassen(64, 8, &mut m));
    }

    #[test]
    fn fp64_grade_window_is_indistinguishable() {
        // s = 7 gives a 54-bit window >= FP64's 53-bit significand: by
        // construction no norm-wise test can separate it from floating
        // point — it *is* FP64-grade. Documented limitation of Test 3.
        let mut m = |a: &_, b: &_| emulated_gemm(a, b, &OzakiConfig::new(7));
        assert!(!is_fixed_point_strassen(64, 8, &mut m));
    }
}
