//! Pluggable compute-backend layer — the seam between *what* the pipeline
//! computes and *how much hardware* it uses.
//!
//! Every compute-heavy loop in the repo (INT8 slice-pair GEMMs of the
//! Ozaki pipeline, FP64 tile GEMMs of the native path / Strassen / QR)
//! dispatches through [`ComputeBackend`] instead of open-coding scalar
//! loops. Two implementations ship today:
//!
//! * [`SerialBackend`] — the original single-threaded kernels, the
//!   deterministic reference;
//! * [`ParallelBackend`] — work-stealing over (t, u) slice pairs (split by
//!   output rows), over fused-engine tile bands, and over MC×NC FP64
//!   tiles on a shared token-budgeted [`pool::ThreadPool`], **bitwise
//!   identical** to serial by construction: integer accumulation is exact
//!   and the FP64 tile schedule preserves the per-element operation
//!   order.
//!
//! The emulated hot path enters through
//! [`ComputeBackend::fused_tile_gemm`] — the tile-major fused schedule
//! drawing scratch from a shared [`WorkspacePool`] (zero steady-state
//! allocation); the level-major `slice_pair_gemm_batch` entry points are
//! retained as the property-test oracle and for the grouped lockstep
//! pipeline.
//!
//! Below the backend seam sits a second, finer one: every INT8
//! slice-pair tile — fused bands, level batches, grouped rounds — runs
//! on the runtime-dispatched `ozaki::kernel` microkernels (scalar
//! reference or AVX2 packed-panel kernels, bitwise interchangeable), so
//! backends choose *how much hardware* while kernels choose *which
//! instructions*.
//!
//! The trait is the plug point for every future backend (GPU,
//! distributed sharding): implement `slice_pair_gemm_batch` and
//! `fp64_gemm_into` (plus `fused_tile_gemm` / `fp64_gemm_tile` if the
//! fused or tile kernels themselves change) and the whole stack —
//! `ozaki::gemm`, `linalg::{gemm, strassen, qr}`, the ADP engine and the
//! `GemmService` — picks it up through
//! [`AdpConfig`](crate::coordinator::AdpConfig) /
//! [`ServiceConfig`](crate::coordinator::ServiceConfig).

pub mod parallel;
pub mod pool;
pub mod serial;
pub mod workspace;

use std::sync::Arc;

use crate::linalg::Matrix;
use crate::ozaki::{CrtBasis, PairSchedule, SlicedMatrix};

pub use parallel::ParallelBackend;
pub use pool::ThreadPool;
pub use serial::SerialBackend;
pub use workspace::{Workspace, WorkspaceGuard, WorkspacePool, WorkspaceStats};

/// Minimum length of the `bpack` scratch passed to
/// [`ComputeBackend::fp64_gemm_tile`].
pub const PACK_SCRATCH_LEN: usize = crate::linalg::gemm::PACK_LEN;

/// One independent slice-pair batch of a grouped schedule: a weight level
/// of one problem, fanned together with other problems' levels through
/// [`ComputeBackend::slice_pair_gemm_batches`]. `out` is that problem's
/// row-major `a.rows x b.rows` i64 accumulator for the level.
pub struct SliceBatch<'a> {
    pub a: &'a SlicedMatrix,
    pub b: &'a SlicedMatrix,
    pub pairs: &'a [(usize, usize)],
    pub out: &'a mut [i64],
}

impl SliceBatch<'_> {
    /// Integer MACs of this batch (scheduling-cost estimate).
    pub fn ops(&self) -> usize {
        self.pairs.len() * self.a.rows * self.b.rows * self.a.cols
    }
}

/// A compute substrate for the two kernel families of the pipeline.
///
/// Contract: for identical inputs, every implementation must produce
/// **bitwise identical** outputs to [`SerialBackend`]. Integer batches are
/// exact so any schedule qualifies; FP64 implementations must preserve the
/// serial per-element operation order (see `linalg::gemm::gemm_tile`).
pub trait ComputeBackend: Send + Sync {
    fn name(&self) -> &'static str;

    /// Thread budget of this backend (1 for serial).
    fn threads(&self) -> usize {
        1
    }

    /// The shared thread pool, when this backend has one. Layers with
    /// task parallelism of their own (e.g. Strassen's seven independent
    /// products) fan out through it; `None` means run inline.
    fn pool(&self) -> Option<&ThreadPool> {
        None
    }

    /// Exact INT8 slice-pair GEMM batch of one weight level:
    /// `out[i*n + j] += sum_l a_t[i, l] * b_u[j, l]` for every `(t, u)` in
    /// `pairs`, with `out` a row-major `a.rows x b.rows` i64 accumulator.
    fn slice_pair_gemm_batch(
        &self,
        a: &SlicedMatrix,
        b: &SlicedMatrix,
        pairs: &[(usize, usize)],
        out: &mut [i64],
    );

    /// Run many *independent* slice-pair batches (distinct problems'
    /// levels of one grouped-GEMM round) as one schedule. The default
    /// runs them in submission order; parallel backends may interleave
    /// work across batches freely — every batch is exact integer
    /// accumulation into its own buffer, so any schedule is bitwise
    /// identical to the sequential one.
    fn slice_pair_gemm_batches(&self, batches: &mut [SliceBatch<'_>]) {
        for bt in batches.iter_mut() {
            self.slice_pair_gemm_batch(bt.a, bt.b, bt.pairs, bt.out);
        }
    }

    /// Fused tile-major emulated-GEMM schedule: for every
    /// `FUSED_MC`×`FUSED_NC` output tile, run **all** of the schedule's
    /// slice pairs while the operand slice rows are cache-resident,
    /// folding per-tile level sums into a workspace-held compensated
    /// accumulator and applying the sigma descaling per tile — one pass
    /// over the output instead of `s` matrix-wide level barriers. The
    /// default is the serial reference order
    /// ([`crate::ozaki::gemm::fused_tile_gemm_serial`]); parallel
    /// backends work-steal row bands of tiles in one parallel region,
    /// each thread owning one pooled workspace. Bitwise identical to the
    /// level-major reference for every implementation: all slice-pair
    /// arithmetic is exact integer work and the per-element level /
    /// descale order is unchanged (see `ozaki::gemm` module docs).
    fn fused_tile_gemm(
        &self,
        a: &SlicedMatrix,
        b: &SlicedMatrix,
        schedule: &PairSchedule,
        workspaces: &WorkspacePool,
        c: &mut Matrix,
    ) {
        crate::ozaki::gemm::fused_tile_gemm_serial(a, b, schedule, workspaces, c);
    }

    /// CRT-scheme counterpart of [`ComputeBackend::fused_tile_gemm`]:
    /// `a`/`b` hold centered residue planes (one per basis modulus), and
    /// each output tile runs one integer GEMM per modulus followed by the
    /// balanced-Garner reconstruction and the shared sigma descaling. The
    /// default is the serial reference order; parallel backends
    /// work-steal row bands exactly as for the slice-pair engine. Every
    /// step is exact integer arithmetic or a per-element FP sequence
    /// independent of the partition, so all implementations are bitwise
    /// identical.
    fn crt_tile_gemm(
        &self,
        a: &SlicedMatrix,
        b: &SlicedMatrix,
        basis: &CrtBasis,
        workspaces: &WorkspacePool,
        c: &mut Matrix,
    ) {
        crate::ozaki::crt::crt_tile_gemm_serial(a, b, basis, workspaces, c);
    }

    /// One MC×NC tile of the blocked FP64 GEMM: `tile += A[ic.., :] *
    /// B[:, jc..]` over the full k extent, `tile` a row-major `mc x nc`
    /// buffer and `bpack` a caller-owned packing scratch of at least
    /// [`PACK_SCRATCH_LEN`] (allocated once per pool thread, fully
    /// overwritten before use — never re-zeroed). [`ParallelBackend`]'s
    /// tile schedule dispatches through this method, making it the
    /// override point for a custom tile *kernel* (SIMD intrinsics,
    /// offload). [`SerialBackend`] does not use tiles at all — it runs
    /// the packed-panel serial nest of `linalg::gemm`, which shares the
    /// per-element operation order.
    #[allow(clippy::too_many_arguments)]
    fn fp64_gemm_tile(
        &self,
        a: &Matrix,
        b: &Matrix,
        ic: usize,
        jc: usize,
        mc: usize,
        nc: usize,
        bpack: &mut [f64],
        tile: &mut [f64],
    ) {
        crate::linalg::gemm::gemm_tile(a, b, ic, jc, mc, nc, bpack, tile);
    }

    /// `C = A*B + beta*C` through this backend's tile schedule (BLAS-style
    /// beta: 0 overwrites, 1 accumulates).
    fn fp64_gemm_into(&self, a: &Matrix, b: &Matrix, c: &mut Matrix, beta: f64);

    /// Convenience: `C = A * B`.
    fn fp64_gemm(&self, a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows, b.cols);
        self.fp64_gemm_into(a, b, &mut c, 0.0);
        c
    }
}

/// Declarative backend selection for configs (plain data, `Copy`, easy to
/// store in `ServiceConfig` / parse from a CLI flag).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendSpec {
    Serial,
    /// Work-stealing parallel backend; `threads = 0` means size to the
    /// machine.
    Parallel { threads: usize },
}

impl BackendSpec {
    /// Machine-sized parallel backend.
    pub fn auto() -> BackendSpec {
        BackendSpec::Parallel { threads: 0 }
    }

    /// Parse `"serial"`, `"parallel"`, or `"parallel:<threads>"`.
    pub fn parse(s: &str) -> Option<BackendSpec> {
        match s {
            "serial" => Some(BackendSpec::Serial),
            "parallel" => Some(BackendSpec::auto()),
            _ => {
                let threads = s.strip_prefix("parallel:")?.parse().ok()?;
                Some(BackendSpec::Parallel { threads })
            }
        }
    }

    /// Materialize the backend (shareable across service workers).
    pub fn build(self) -> Arc<dyn ComputeBackend> {
        match self {
            BackendSpec::Serial => Arc::new(SerialBackend),
            BackendSpec::Parallel { threads } => Arc::new(ParallelBackend::new(threads)),
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            BackendSpec::Serial => "serial",
            BackendSpec::Parallel { .. } => "parallel",
        }
    }

    /// Divide this spec's thread budget across `shards` service shards:
    /// each shard builds its own pool slice, so one shard saturating its
    /// backend cannot convoy another's. Serial stays serial; a
    /// machine-sized spec (`threads = 0`) resolves to the machine size
    /// first so the split is deterministic; every slice keeps at least
    /// one thread.
    pub fn shard_slice(self, shards: usize) -> BackendSpec {
        let shards = shards.max(1);
        if shards == 1 {
            return self;
        }
        match self {
            BackendSpec::Serial => BackendSpec::Serial,
            BackendSpec::Parallel { threads } => {
                let total = if threads == 0 {
                    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
                } else {
                    threads
                };
                BackendSpec::Parallel { threads: (total / shards).max(1) }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{gemm, gemm_into};
    use crate::ozaki::gemm::emulated_gemm_on;
    use crate::ozaki::{slice_a, slice_b, OzakiConfig, SliceEncoding};
    use crate::util::{prop, Rng};

    fn assert_bitwise(c1: &Matrix, c2: &Matrix, what: &str) -> prop::PropResult {
        for (x, y) in c1.data.iter().zip(&c2.data) {
            if x.to_bits() != y.to_bits() {
                return Err(format!("{what}: not bitwise identical ({x} vs {y})"));
            }
        }
        Ok(())
    }

    #[test]
    fn spec_parses_and_builds() {
        assert_eq!(BackendSpec::parse("serial"), Some(BackendSpec::Serial));
        assert_eq!(BackendSpec::parse("parallel"), Some(BackendSpec::Parallel { threads: 0 }));
        assert_eq!(BackendSpec::parse("parallel:3"), Some(BackendSpec::Parallel { threads: 3 }));
        assert_eq!(BackendSpec::parse("gpu"), None);
        assert_eq!(BackendSpec::Serial.build().threads(), 1);
        assert_eq!(BackendSpec::Parallel { threads: 3 }.build().threads(), 3);
    }

    #[test]
    fn shard_slice_divides_the_thread_budget() {
        assert_eq!(BackendSpec::Serial.shard_slice(4), BackendSpec::Serial);
        // One shard is the identity — including for machine-sized specs.
        assert_eq!(BackendSpec::auto().shard_slice(1), BackendSpec::auto());
        assert_eq!(
            BackendSpec::Parallel { threads: 8 }.shard_slice(2),
            BackendSpec::Parallel { threads: 4 }
        );
        // Slices never drop below one thread, however many shards.
        assert_eq!(
            BackendSpec::Parallel { threads: 2 }.shard_slice(16),
            BackendSpec::Parallel { threads: 1 }
        );
        // A machine-sized spec resolves before splitting: the result is a
        // concrete per-shard budget, never another machine-sized spec.
        match BackendSpec::auto().shard_slice(2) {
            BackendSpec::Parallel { threads } => assert!(threads >= 1),
            other => panic!("auto().shard_slice(2) must stay parallel, got {other:?}"),
        }
    }

    #[test]
    fn batch_matches_serial_pair_loop() {
        let mut rng = Rng::new(400);
        let (m, k, n, s) = (13, 29, 11, 5);
        let a = Matrix::uniform(m, k, -2.0, 2.0, &mut rng);
        let b = Matrix::uniform(k, n, -2.0, 2.0, &mut rng);
        let asl = slice_a(&a, s, SliceEncoding::Unsigned);
        let bsl = slice_b(&b, s, SliceEncoding::Unsigned);
        let pairs: Vec<(usize, usize)> =
            (0..s).flat_map(|t| (0..s - t).map(move |u| (t, u))).collect();
        let mut out_ser = vec![0i64; m * n];
        let mut out_par = vec![0i64; m * n];
        SerialBackend.slice_pair_gemm_batch(&asl, &bsl, &pairs, &mut out_ser);
        // cutoff 0: force the row-split schedule even at this tiny size
        let par = ParallelBackend::new(4).with_cutoff_ops(0);
        par.slice_pair_gemm_batch(&asl, &bsl, &pairs, &mut out_par);
        assert_eq!(out_ser, out_par);
    }

    #[test]
    fn fused_batches_match_sequential() {
        // The grouped-schedule entry point: independent batches of
        // different shapes fused into one parallel schedule must equal
        // the one-at-a-time serial results exactly.
        let mut rng = Rng::new(401);
        let par = ParallelBackend::new(4).with_cutoff_ops(0);
        let mk = |m: usize, k: usize, n: usize, s: usize, rng: &mut Rng| {
            let a = Matrix::uniform(m, k, -2.0, 2.0, rng);
            let b = Matrix::uniform(k, n, -2.0, 2.0, rng);
            (slice_a(&a, s, SliceEncoding::Unsigned), slice_b(&b, s, SliceEncoding::Unsigned))
        };
        let (a1, b1) = mk(9, 17, 7, 4, &mut rng);
        let (a2, b2) = mk(5, 23, 11, 3, &mut rng);
        let p1: Vec<(usize, usize)> = vec![(0, 0), (1, 2), (3, 0)];
        let p2: Vec<(usize, usize)> = vec![(2, 1), (0, 0)];
        let mut ser1 = vec![0i64; 9 * 7];
        let mut ser2 = vec![0i64; 5 * 11];
        SerialBackend.slice_pair_gemm_batch(&a1, &b1, &p1, &mut ser1);
        SerialBackend.slice_pair_gemm_batch(&a2, &b2, &p2, &mut ser2);
        let mut par1 = vec![0i64; 9 * 7];
        let mut par2 = vec![0i64; 5 * 11];
        {
            let mut batches = vec![
                SliceBatch { a: &a1, b: &b1, pairs: p1.as_slice(), out: par1.as_mut_slice() },
                SliceBatch { a: &a2, b: &b2, pairs: p2.as_slice(), out: par2.as_mut_slice() },
            ];
            par.slice_pair_gemm_batches(&mut batches);
        }
        assert_eq!(ser1, par1);
        assert_eq!(ser2, par2);
        // Empty fused schedule is a no-op on both implementations.
        par.slice_pair_gemm_batches(&mut []);
        SerialBackend.slice_pair_gemm_batches(&mut []);
    }

    #[test]
    fn prop_parallel_emulation_bitwise_identical_to_serial() {
        // The acceptance property of the backend layer: the parallel
        // schedule must never change a single bit of the emulated result.
        let par = ParallelBackend::new(4).with_cutoff_ops(0);
        prop::check("parallel == serial (emulated gemm)", 12, |rng| {
            let m = rng.int(1, 40) as usize;
            let k = rng.int(1, 64) as usize;
            let n = rng.int(1, 40) as usize;
            let s = rng.int(2, 9) as usize;
            let a = Matrix::uniform(m, k, -3.0, 3.0, rng);
            let b = Matrix::uniform(k, n, -3.0, 3.0, rng);
            let cfg = OzakiConfig::new(s);
            let c_ser = emulated_gemm_on(&a, &b, &cfg, &SerialBackend);
            let c_par = emulated_gemm_on(&a, &b, &cfg, &par);
            assert_bitwise(&c_ser, &c_par, "emulated gemm")
        });
    }

    #[test]
    fn prop_parallel_fp64_bitwise_identical_to_serial() {
        let par = ParallelBackend::new(4).with_cutoff_ops(0);
        prop::check("parallel == serial (fp64 gemm)", 12, |rng| {
            let m = rng.int(1, 90) as usize;
            let k = rng.int(1, 300) as usize;
            let n = rng.int(1, 90) as usize;
            let a = Matrix::uniform(m, k, -1.0, 1.0, rng);
            let b = Matrix::uniform(k, n, -1.0, 1.0, rng);
            let c_ser = gemm(&a, &b);
            let c_par = par.fp64_gemm(&a, &b);
            assert_bitwise(&c_ser, &c_par, "fp64 gemm")?;
            // beta = 1 accumulation path
            let mut acc_ser = Matrix::uniform(m, n, -1.0, 1.0, rng);
            let mut acc_par = acc_ser.clone();
            gemm_into(&a, &b, &mut acc_ser, 1.0);
            par.fp64_gemm_into(&a, &b, &mut acc_par, 1.0);
            assert_bitwise(&acc_ser, &acc_par, "fp64 gemm beta=1")
        });
    }

    #[test]
    fn prop_permutation_invariance_survives_parallel_dispatch() {
        // §4's fixed-point guarantee, now asserted *through the parallel
        // backend*: simultaneous k-permutations of A columns / B rows give
        // the bitwise identical result.
        let par = ParallelBackend::new(4).with_cutoff_ops(0);
        prop::check("parallel k-permutation invariance", 10, |rng| {
            let (m, k, n) = (6, 12, 5);
            let a = Matrix::uniform(m, k, -2.0, 2.0, rng);
            let b = Matrix::uniform(k, n, -2.0, 2.0, rng);
            let mut perm: Vec<usize> = (0..k).collect();
            rng.shuffle(&mut perm);
            let ap = Matrix::from_fn(m, k, |i, j| a.at(i, perm[j]));
            let bp = Matrix::from_fn(k, n, |i, j| b.at(perm[i], j));
            let cfg = OzakiConfig::new(6);
            let c1 = emulated_gemm_on(&a, &b, &cfg, &par);
            let c2 = emulated_gemm_on(&ap, &bp, &cfg, &par);
            assert_bitwise(&c1, &c2, "permutation invariance")
        });
    }

    #[test]
    fn empty_shapes_are_safe() {
        let par = ParallelBackend::new(2);
        let a = Matrix::zeros(0, 5);
        let b = Matrix::zeros(5, 3);
        let c = par.fp64_gemm(&a, &b);
        assert_eq!((c.rows, c.cols), (0, 3));
        let asl = slice_a(&Matrix::zeros(2, 4), 3, SliceEncoding::Unsigned);
        let bsl = slice_b(&Matrix::zeros(4, 0), 3, SliceEncoding::Unsigned);
        let mut out: Vec<i64> = vec![];
        par.slice_pair_gemm_batch(&asl, &bsl, &[(0, 0)], &mut out);
    }
}
