//! The deterministic single-threaded reference backend.

use super::ComputeBackend;
use crate::linalg::gemm::gemm_into;
use crate::linalg::Matrix;
use crate::ozaki::gemm::slice_pair_gemm;
use crate::ozaki::SlicedMatrix;

/// Runs every kernel inline on the calling thread with the original scalar
/// loop nests. This is the reference every other backend must match
/// bitwise, and the right choice for tiny problems where thread hand-off
/// costs more than the compute.
pub struct SerialBackend;

impl ComputeBackend for SerialBackend {
    fn name(&self) -> &'static str {
        "serial"
    }

    fn slice_pair_gemm_batch(
        &self,
        a: &SlicedMatrix,
        b: &SlicedMatrix,
        pairs: &[(usize, usize)],
        out: &mut [i64],
    ) {
        for &(t, u) in pairs {
            slice_pair_gemm(a, t, b, u, out);
        }
    }

    fn fp64_gemm_into(&self, a: &Matrix, b: &Matrix, c: &mut Matrix, beta: f64) {
        gemm_into(a, b, c, beta);
    }
}
