//! Scoped-thread worker pool shared by every [`super::ComputeBackend`].
//!
//! Design constraints (see DESIGN.md §Substitutions): no rayon/crossbeam
//! offline, and no `unsafe`. Helper threads are therefore `std::thread::scope`
//! threads — they may borrow the caller's stack (slices, packed operands)
//! with zero lifetime gymnastics — while the *pool* part is a global token
//! budget: one `ThreadPool` is shared by all service workers, and a call
//! only gets helper threads while tokens are available. Under full load
//! every worker degrades to running its work inline on its own thread, so
//! the machine is never oversubscribed by N workers × T helpers.
//!
//! Token acquisition never blocks, so nested/recursive use (e.g. Strassen
//! recursion over a parallel FP64 backend) cannot deadlock.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::util::sync as psync;

/// A token-budgeted scoped-thread pool. `threads` is the total thread
/// budget *including* the calling thread; `threads - 1` helper tokens are
/// shared by all concurrent callers.
pub struct ThreadPool {
    /// Helper-thread tokens currently available.
    extra: AtomicUsize,
    /// Total budget (callers always count as one thread of their own).
    threads: usize,
}

impl ThreadPool {
    pub fn new(threads: usize) -> ThreadPool {
        let t = threads.max(1);
        ThreadPool { extra: AtomicUsize::new(t - 1), threads: t }
    }

    /// Total thread budget (>= 1).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Helper tokens currently available (test/observability hook).
    pub fn available(&self) -> usize {
        self.extra.load(Ordering::Acquire)
    }

    /// Take up to `want` helper tokens without blocking.
    fn acquire(&self, want: usize) -> usize {
        if want == 0 {
            return 0;
        }
        let mut cur = self.extra.load(Ordering::Relaxed);
        loop {
            let take = want.min(cur);
            if take == 0 {
                return 0;
            }
            match self.extra.compare_exchange_weak(
                cur,
                cur - take,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => return take,
                Err(now) => cur = now,
            }
        }
    }

    fn release(&self, n: usize) {
        if n > 0 {
            self.extra.fetch_add(n, Ordering::AcqRel);
        }
    }

    /// Run `work` concurrently on the calling thread plus up to
    /// `threads - 1` scoped helper threads (fewer when other callers hold
    /// tokens; zero helpers means a plain inline call). `work` must pull
    /// its tasks from a shared queue — every thread runs the same closure.
    pub fn run<F: Fn() + Sync>(&self, work: F) {
        self.run_n(self.threads - 1, work);
    }

    /// As [`ThreadPool::run`], but never takes more than `max_helpers`
    /// helper tokens — callers with few tasks should not hoard the pool
    /// (or pay spawns) for threads that would find the queue empty.
    /// Tokens are restored even if `work` panics (drop guard), so one
    /// panicked request cannot silently serialize the shared pool.
    pub fn run_n<F: Fn() + Sync>(&self, max_helpers: usize, work: F) {
        let extra = self.acquire(max_helpers.min(self.threads - 1));
        if extra == 0 {
            work();
            return;
        }
        let _guard = ReleaseGuard { pool: self, n: extra };
        std::thread::scope(|scope| {
            for _ in 0..extra {
                scope.spawn(&work);
            }
            work();
        });
    }
}

/// Restores helper tokens on scope exit, panicking or not.
struct ReleaseGuard<'a> {
    pool: &'a ThreadPool,
    n: usize,
}

impl Drop for ReleaseGuard<'_> {
    fn drop(&mut self) {
        self.pool.release(self.n);
    }
}

/// Work-stealing drain: distribute `items` over the pool's threads, calling
/// `f` on each exactly once. Items are handed out dynamically (whichever
/// thread is free pulls the next one), so uneven task costs balance out.
pub fn drain<T: Send, F: Fn(T) + Sync>(pool: &ThreadPool, items: Vec<T>, f: F) {
    if items.len() <= 1 {
        for it in items {
            f(it);
        }
        return;
    }
    let max_helpers = items.len() - 1;
    let queue = Mutex::new(items);
    pool.run_n(max_helpers, || loop {
        let next = psync::lock(&queue).pop();
        match next {
            Some(it) => f(it),
            None => break,
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn drain_visits_every_item_once() {
        let pool = ThreadPool::new(4);
        let hits = AtomicU64::new(0);
        let sum = AtomicU64::new(0);
        drain(&pool, (1..=100u64).collect(), |x| {
            hits.fetch_add(1, Ordering::SeqCst);
            sum.fetch_add(x, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 100);
        assert_eq!(sum.load(Ordering::SeqCst), 5050);
    }

    #[test]
    fn tokens_are_restored_after_run() {
        let pool = ThreadPool::new(3);
        assert_eq!(pool.available(), 2);
        pool.run(|| {});
        assert_eq!(pool.available(), 2);
        drain(&pool, vec![1, 2, 3], |_| {});
        assert_eq!(pool.available(), 2);
    }

    #[test]
    fn single_thread_pool_runs_inline() {
        use std::sync::atomic::AtomicBool;
        let pool = ThreadPool::new(1);
        let tid = std::thread::current().id();
        let on_caller = AtomicBool::new(false);
        pool.run(|| {
            on_caller.store(std::thread::current().id() == tid, Ordering::SeqCst);
        });
        assert!(on_caller.load(Ordering::SeqCst));
    }

    #[test]
    fn tokens_survive_worker_panic() {
        let pool = ThreadPool::new(3);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(|| panic!("boom"));
        }));
        assert!(result.is_err());
        assert_eq!(pool.available(), 2, "panic must not leak helper tokens");
    }

    #[test]
    fn run_n_caps_token_grab() {
        let pool = ThreadPool::new(8);
        pool.run_n(1, || {
            // Inside a 1-helper run, at most one token may be taken.
            assert!(pool.available() >= 6);
        });
        assert_eq!(pool.available(), 7);
    }

    #[test]
    fn nested_runs_do_not_deadlock() {
        let pool = ThreadPool::new(2);
        let outer_done = AtomicU64::new(0);
        pool.run(|| {
            // Inner call while outer holds the helper token: must degrade
            // to inline execution, never block.
            pool.run(|| {});
            outer_done.fetch_add(1, Ordering::SeqCst);
        });
        assert!(outer_done.load(Ordering::SeqCst) >= 1);
        assert_eq!(pool.available(), 1);
    }
}
