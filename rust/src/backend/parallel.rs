//! Work-stealing multi-threaded backend, bitwise identical to serial.
//!
//! Three sources of intra-GEMM parallelism, all chosen so that the
//! *per-element* arithmetic sequence is exactly the serial one:
//!
//! * **INT8 slice-pair batches** — the output rows of a weight level are
//!   split into chunks; each chunk runs every (t, u) pair of the level
//!   serially into its disjoint row range. i64 accumulation is exact, so
//!   any row partition is bitwise identical to the serial schedule, and no
//!   cross-thread merge buffers are needed at all. Parallelism is
//!   independent of how many pairs the level has (even the single-pair
//!   level q = 0 scales across rows).
//! * **Fused tile bands** — the fused engine's row bands of output tiles
//!   (the autotuned tile height, shrunk for wide flat outputs so short
//!   matrices still fan out) drain through one work-stealing queue: a single parallel
//!   region per emulated GEMM instead of one barrier per weight level,
//!   each thread owning one pooled workspace (tile accumulators *and*
//!   the `ozaki::kernel` packed-panel scratch) for its whole run, on the
//!   runtime-dispatched SIMD/scalar kernel — exact integer arithmetic,
//!   so kernel choice changes no bits. Tiles
//!   write disjoint elements with the serial per-element op sequence, so
//!   any band partition or assignment is bitwise identical.
//! * **FP64 tiles** — the MC×NC tile grid of the blocked GEMM is drained
//!   by the pool; each tile accumulates over the full k extent in the same
//!   ascending panel order as the serial loop nest (see
//!   `linalg::gemm::gemm_tile`), and tiles are written back to C in a
//!   fixed order. Per C element the FP op sequence is unchanged, so
//!   results are bitwise identical to [`super::SerialBackend`] — the
//!   `prop_permutation_invariance` guarantee survives parallel dispatch.

use std::sync::Mutex;

use super::pool::{drain, ThreadPool};
use super::workspace::WorkspacePool;
use super::{ComputeBackend, SliceBatch, PACK_SCRATCH_LEN};
use crate::linalg::gemm::{apply_beta, load_tile, store_tile, tile_grid};
use crate::linalg::Matrix;
use crate::ozaki::crt::{crt_band, crt_tile_gemm_serial};
use crate::ozaki::gemm::{
    fused_band, fused_tile_gemm_serial, slice_pair_gemm_rows, slice_pairs_rows_on_packed,
    FusedTally, PackedBSlices,
};
use crate::ozaki::kernel::{self, KernelId};
use crate::ozaki::tune;
use crate::ozaki::{CrtBasis, PairSchedule, SlicedMatrix};
use crate::util::sync as psync;

/// Row-chunks per pool thread when splitting a slice-pair batch: >1 so the
/// dynamic queue can balance uneven chunk costs.
const CHUNKS_PER_THREAD: usize = 4;

/// Below this many MACs (integer) or element-products (FP64) a batch runs
/// inline on the caller: thread hand-off costs more than sub-millisecond
/// kernels, and the serial path is bitwise identical anyway.
const PARALLEL_CUTOFF_OPS: usize = 1 << 21;

pub struct ParallelBackend {
    pool: ThreadPool,
    cutoff_ops: usize,
}

impl ParallelBackend {
    /// `threads = 0` sizes the pool to the machine
    /// (`available_parallelism`).
    pub fn new(threads: usize) -> ParallelBackend {
        let t = if threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        } else {
            threads
        };
        ParallelBackend { pool: ThreadPool::new(t), cutoff_ops: PARALLEL_CUTOFF_OPS }
    }

    /// Override the inline-fallback threshold. `0` forces the parallel
    /// schedule for any size — used by the bitwise-equivalence tests so
    /// small inputs still exercise the split paths.
    pub fn with_cutoff_ops(mut self, ops: usize) -> ParallelBackend {
        self.cutoff_ops = ops;
        self
    }
}

/// One FP64 tile job: grid coordinates plus the owned accumulation buffer
/// (seeded from C, merged back on the coordinating thread).
struct TileJob {
    ic: usize,
    jc: usize,
    mc: usize,
    nc: usize,
    buf: Vec<f64>,
}

impl ComputeBackend for ParallelBackend {
    fn name(&self) -> &'static str {
        "parallel"
    }

    fn threads(&self) -> usize {
        self.pool.threads()
    }

    fn pool(&self) -> Option<&ThreadPool> {
        Some(&self.pool)
    }

    fn slice_pair_gemm_batch(
        &self,
        a: &SlicedMatrix,
        b: &SlicedMatrix,
        pairs: &[(usize, usize)],
        out: &mut [i64],
    ) {
        let (m, n) = (a.rows, b.rows);
        assert_eq!(out.len(), m * n);
        if m == 0 || n == 0 || pairs.is_empty() {
            return;
        }
        if pairs.len() * m * n * a.cols < self.cutoff_ops {
            for &(t, u) in pairs {
                slice_pair_gemm_rows(a, t, b, u, 0, m, out);
            }
            return;
        }
        let chunk_rows = m.div_ceil(self.pool.threads() * CHUNKS_PER_THREAD).max(2);
        let mut work: Vec<(usize, &mut [i64])> = Vec::new();
        let mut row0 = 0;
        for chunk in out.chunks_mut(chunk_rows * n) {
            work.push((row0, chunk));
            row0 += chunk.len() / n;
        }
        let kern = kernel::active(a.encoding);
        if kern.id() != KernelId::Scalar {
            // SIMD kernels pack panels: build each distinct B slice once
            // and share the read-only panels across every (pair, chunk) —
            // re-packing O(n·k) per pair per chunk would eat the SIMD win
            // on thin chunks. Exact integers: bitwise identical either way.
            let bp = PackedBSlices::pack(kern, b, pairs);
            drain(&self.pool, work, |(r0, chunk)| {
                let rows = chunk.len() / n;
                slice_pairs_rows_on_packed(a, &bp, pairs, r0, rows, chunk);
            });
            return;
        }
        drain(&self.pool, work, |(r0, chunk)| {
            let rows = chunk.len() / n;
            for &(t, u) in pairs {
                slice_pair_gemm_rows(a, t, b, u, r0, rows, chunk);
            }
        });
    }

    fn slice_pair_gemm_batches(&self, batches: &mut [SliceBatch<'_>]) {
        // One fused schedule for the whole round: every batch's output
        // rows are chunked exactly as in `slice_pair_gemm_batch`, and all
        // chunks across all problems drain through one work-stealing
        // queue, so a round with many small problems still fills the
        // machine. Integer accumulation into disjoint buffers keeps any
        // interleaving bitwise identical to the sequential default.
        let total_ops: usize = batches.iter().map(SliceBatch::ops).sum();
        if total_ops < self.cutoff_ops {
            for bt in batches.iter_mut() {
                for &(t, u) in bt.pairs {
                    slice_pair_gemm_rows(bt.a, t, bt.b, u, 0, bt.a.rows, bt.out);
                }
            }
            return;
        }
        // Pre-pack every batch's distinct B slices once (SIMD kernels
        // only; encodings — and hence kernels — may differ per batch in
        // mixed grouped rounds): all row chunks of a batch share its
        // read-only panels.
        let packs: Vec<Option<PackedBSlices>> = batches
            .iter()
            .map(|bt| {
                let kern = kernel::active(bt.a.encoding);
                if kern.id() == KernelId::Scalar || bt.a.rows == 0 || bt.b.rows == 0 {
                    None
                } else {
                    Some(PackedBSlices::pack(kern, bt.b, bt.pairs))
                }
            })
            .collect();
        type Chunk<'q> = (
            &'q SlicedMatrix,
            &'q SlicedMatrix,
            Option<&'q PackedBSlices>,
            &'q [(usize, usize)],
            usize,
            usize,
            &'q mut [i64],
        );
        let mut work: Vec<Chunk<'_>> = Vec::new();
        for (bt, pk) in batches.iter_mut().zip(&packs) {
            let (m, n) = (bt.a.rows, bt.b.rows);
            assert_eq!(bt.out.len(), m * n);
            if m == 0 || n == 0 || bt.pairs.is_empty() {
                continue;
            }
            let chunk_rows = m.div_ceil(self.pool.threads() * CHUNKS_PER_THREAD).max(2);
            let mut row0 = 0;
            for chunk in bt.out.chunks_mut(chunk_rows * n) {
                let rows = chunk.len() / n;
                work.push((bt.a, bt.b, pk.as_ref(), bt.pairs, n, row0, chunk));
                row0 += rows;
            }
        }
        drain(&self.pool, work, |(a, b, pk, pairs, n, row0, chunk)| {
            let rows = chunk.len() / n;
            match pk {
                Some(bp) => slice_pairs_rows_on_packed(a, bp, pairs, row0, rows, chunk),
                None => {
                    for &(t, u) in pairs {
                        slice_pair_gemm_rows(a, t, b, u, row0, rows, chunk);
                    }
                }
            }
        });
    }

    fn fused_tile_gemm(
        &self,
        a: &SlicedMatrix,
        b: &SlicedMatrix,
        schedule: &PairSchedule,
        workspaces: &WorkspacePool,
        c: &mut Matrix,
    ) {
        let (m, n) = (a.rows, b.rows);
        assert_eq!(c.rows, m, "output rows mismatch");
        assert_eq!(c.cols, n, "output cols mismatch");
        if m == 0 || n == 0 {
            return;
        }
        if schedule.pair_count() * m * n * a.cols < self.cutoff_ops {
            return fused_tile_gemm_serial(a, b, schedule, workspaces, c);
        }
        // One parallel region for the whole GEMM (instead of one barrier
        // per weight level): row bands of C — contiguous, disjoint `&mut`
        // slices — drain through a work-stealing queue, each band running
        // its column tiles left to right. Every thread owns one pooled
        // workspace for its entire run. Band height is the autotuned
        // tile height, shrunk when the row count alone cannot feed the
        // pool (wide, flat outputs must still fan out). Tiles write
        // disjoint output elements and every element's arithmetic is
        // independent of the tile partition, so any band height, tile
        // geometry and band-to-thread assignment is bitwise identical to
        // `fused_tile_gemm_serial`.
        let kern = kernel::active(a.encoding);
        let shape = tune::tile_shape_for(kern.id(), m, n);
        workspaces.record_dispatch(kern.id(), Some(shape));
        let band_rows =
            m.div_ceil(self.pool.threads() * CHUNKS_PER_THREAD).max(2).min(shape.mc).max(1);
        let mut bands: Vec<(usize, &mut [f64])> = Vec::new();
        for (bi, band) in c.data.chunks_mut(band_rows * n).enumerate() {
            bands.push((bi * band_rows, band));
        }
        let max_helpers = bands.len().saturating_sub(1);
        let queue = Mutex::new(bands);
        let tally = Mutex::new(FusedTally::default());
        self.pool.run_n(max_helpers, || {
            let mut ws = workspaces.checkout(shape.elems());
            let mut local = FusedTally::default();
            loop {
                let next = psync::lock(&queue).pop();
                let Some((row0, band)) = next else { break };
                local.merge(fused_band(kern, a, b, schedule, row0, shape, &mut ws, band));
            }
            psync::lock(&tally).merge(local);
        });
        let t = tally.into_inner().unwrap_or_else(|e| e.into_inner());
        workspaces.record_tiles(t.tiles);
        workspaces.record_panels(t.packs, t.reuses);
        workspaces.record_pack_growth(t.pack_growths);
    }

    fn crt_tile_gemm(
        &self,
        a: &SlicedMatrix,
        b: &SlicedMatrix,
        basis: &CrtBasis,
        workspaces: &WorkspacePool,
        c: &mut Matrix,
    ) {
        let (m, n) = (a.rows, b.rows);
        assert_eq!(c.rows, m, "output rows mismatch");
        assert_eq!(c.cols, n, "output cols mismatch");
        if m == 0 || n == 0 {
            return;
        }
        if basis.len() * m * n * a.cols < self.cutoff_ops {
            return crt_tile_gemm_serial(a, b, basis, workspaces, c);
        }
        // Same band schedule as `fused_tile_gemm`: disjoint row bands of C
        // drain through one work-stealing queue, each thread owning one
        // pooled workspace. Integer GEMMs, residue folds, and the
        // per-element Garner/descale tail are all independent of the band
        // partition, so any assignment is bitwise identical to serial.
        let kern = kernel::active(a.encoding);
        let shape = tune::tile_shape_for(kern.id(), m, n);
        workspaces.record_dispatch(kern.id(), Some(shape));
        let band_rows =
            m.div_ceil(self.pool.threads() * CHUNKS_PER_THREAD).max(2).min(shape.mc).max(1);
        let mut bands: Vec<(usize, &mut [f64])> = Vec::new();
        for (bi, band) in c.data.chunks_mut(band_rows * n).enumerate() {
            bands.push((bi * band_rows, band));
        }
        let max_helpers = bands.len().saturating_sub(1);
        let queue = Mutex::new(bands);
        let tally = Mutex::new(FusedTally::default());
        self.pool.run_n(max_helpers, || {
            let mut ws = workspaces.checkout(shape.elems());
            let mut local = FusedTally::default();
            loop {
                let next = psync::lock(&queue).pop();
                let Some((row0, band)) = next else { break };
                local.merge(crt_band(kern, a, b, basis, row0, shape, &mut ws, band));
            }
            psync::lock(&tally).merge(local);
        });
        let t = tally.into_inner().unwrap_or_else(|e| e.into_inner());
        workspaces.record_tiles(t.tiles);
        workspaces.record_panels(t.packs, t.reuses);
        workspaces.record_pack_growth(t.pack_growths);
    }

    fn fp64_gemm_into(&self, a: &Matrix, b: &Matrix, c: &mut Matrix, beta: f64) {
        if a.rows * b.cols * a.cols < self.cutoff_ops {
            return crate::linalg::gemm::gemm_into(a, b, c, beta);
        }
        assert_eq!(a.cols, b.rows, "gemm shape mismatch");
        assert_eq!(c.rows, a.rows);
        assert_eq!(c.cols, b.cols);
        apply_beta(c, beta);
        if a.rows == 0 || b.cols == 0 || a.cols == 0 {
            return;
        }
        let mut jobs: Vec<TileJob> = tile_grid(a.rows, b.cols)
            .into_iter()
            .map(|(ic, jc, mc, nc)| {
                let mut buf = Vec::with_capacity(mc * nc);
                load_tile(c, ic, jc, mc, nc, &mut buf);
                TileJob { ic, jc, mc, nc, buf }
            })
            .collect();
        {
            // Hand-rolled queue (not `drain`) so every pool thread owns
            // one PACK_SCRATCH_LEN packing buffer for its whole run, while
            // still dispatching through the overridable trait kernel.
            let work: Vec<&mut TileJob> = jobs.iter_mut().collect();
            let max_helpers = work.len().saturating_sub(1);
            let queue = Mutex::new(work);
            self.pool.run_n(max_helpers, || {
                let mut bpack = vec![0.0f64; PACK_SCRATCH_LEN];
                loop {
                    let next = psync::lock(&queue).pop();
                    let Some(job) = next else { break };
                    self.fp64_gemm_tile(
                        a,
                        b,
                        job.ic,
                        job.jc,
                        job.mc,
                        job.nc,
                        &mut bpack,
                        &mut job.buf,
                    );
                }
            });
        }
        // Merge in grid order. Tiles are disjoint, so this is pure
        // bookkeeping determinism, not a numerical requirement.
        for job in &jobs {
            store_tile(c, job.ic, job.jc, job.mc, job.nc, &job.buf);
        }
    }
}
