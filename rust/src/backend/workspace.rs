//! Pooled scratch workspaces for the emulated-GEMM hot paths.
//!
//! Every emulated GEMM needs the same three scratch buffers: an integer
//! level/tile accumulator (`pbuf`), and the compensated hi/lo pair the
//! weight levels fold into. Allocating them per request is pure hot-path
//! overhead — the fused tile engine needs only a tile's worth per thread,
//! and a service sees the same shapes over and over. The
//! [`WorkspacePool`] amortizes them: `checkout` hands back a pooled
//! [`Workspace`] (growing one only when no pooled buffer is big enough),
//! and the RAII [`WorkspaceGuard`] returns it on drop — panic or not —
//! so steady-state traffic performs **zero** hot-path heap allocation.
//!
//! The pool also carries the fused-engine observability counters
//! (checkouts, fresh allocations, fused tiles executed): it is the one
//! object already threaded through every layer that runs the engine
//! (`AdpEngine`, `ozaki::batched`, `GemmService`), so
//! `coordinator::Metrics` snapshots read straight from it
//! ([`WorkspacePool::stats`]).

use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::ozaki::kernel::KernelId;
use crate::ozaki::tune::TileShape;
use crate::util::faultinject;
use crate::util::sync as psync;

/// One reusable scratch set. Buffers are handed out **dirty** (whatever
/// the previous user left); every consumer fully initializes the prefix
/// it uses (`fill(0)` / full overwrite) before reading.
pub struct Workspace {
    /// Integer level/tile accumulator (one weight level of a tile or of a
    /// whole problem).
    pub pbuf: Vec<i64>,
    /// Compensated accumulator, high parts.
    pub hi: Vec<f64>,
    /// Compensated accumulator, low (error) parts.
    pub lo: Vec<f64>,
    /// Packed A-band panel scratch of the `ozaki::kernel` layer (all
    /// slices of one fused band, in the dispatched kernel's layout).
    pub apack: Vec<u8>,
    /// Packed B-panel scratch (all slices of one fused column tile).
    pub bpack: Vec<u8>,
    /// Centered residue planes of the CRT scheme (one `rows*cols` i32
    /// plane per modulus of one fused tile). Empty until a CRT run sizes
    /// it via [`Workspace::ensure_res`]; slice-pair runs never touch it.
    pub rbuf: Vec<i32>,
}

impl Workspace {
    /// Fresh workspace holding `elems` elements per buffer. Panel
    /// scratch starts empty and is sized by [`Workspace::ensure_pack`]
    /// on first use (its size depends on the dispatched kernel's
    /// layout, not on `elems`).
    pub fn with_capacity(elems: usize) -> Workspace {
        Workspace {
            pbuf: vec![0; elems],
            hi: vec![0.0; elems],
            lo: vec![0.0; elems],
            apack: Vec::new(),
            bpack: Vec::new(),
            rbuf: Vec::new(),
        }
    }

    /// Elements each accumulator buffer can hold.
    pub fn capacity(&self) -> usize {
        self.pbuf.len()
    }

    /// Grow every accumulator buffer to at least `elems` elements.
    /// Returns whether a reallocation happened (i.e. this checkout was
    /// not served from resident capacity).
    pub fn ensure(&mut self, elems: usize) -> bool {
        if self.pbuf.len() >= elems {
            return false;
        }
        self.pbuf.resize(elems, 0);
        self.hi.resize(elems, 0.0);
        self.lo.resize(elems, 0.0);
        true
    }

    /// Grow the packed-panel scratch to at least the given byte sizes.
    /// Returns whether a reallocation happened; once a pooled workspace
    /// has served a shape, warm runs never grow again — the
    /// zero-per-pair-packing-allocation property of the fused engine.
    pub fn ensure_pack(&mut self, a_bytes: usize, b_bytes: usize) -> bool {
        let mut grew = false;
        if self.apack.len() < a_bytes {
            self.apack.resize(a_bytes, 0);
            grew = true;
        }
        if self.bpack.len() < b_bytes {
            self.bpack.resize(b_bytes, 0);
            grew = true;
        }
        grew
    }

    /// Grow the CRT residue-plane scratch to at least `elems` i32
    /// entries. Returns whether a reallocation happened (same warm-run
    /// contract as [`Workspace::ensure_pack`]).
    pub fn ensure_res(&mut self, elems: usize) -> bool {
        if self.rbuf.len() >= elems {
            return false;
        }
        self.rbuf.resize(elems, 0);
        true
    }
}

/// Lifetime totals of a [`WorkspacePool`] (monotone counters).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WorkspaceStats {
    /// Workspaces handed out (pooled or fresh).
    pub checkouts: u64,
    /// Checkouts that had to allocate or grow a buffer. A warm pool
    /// serving repeat shapes keeps this flat.
    pub fresh_allocs: u64,
    /// Output tiles executed by the fused tile engine.
    pub fused_tiles: u64,
    /// Operand panel builds by the fused engine's packing layer (one per
    /// A band + one per B column tile, each covering every slice).
    pub panel_packs: u64,
    /// Slice-pair kernel calls served from already-packed panels (the
    /// `s(s+1)/2 - 1` pair calls after the first of every fused tile).
    /// Nonzero means the pack cost really is amortized across pairs.
    pub panel_reuses: u64,
    /// `KernelId::label()` of the most recently dispatched slice-pair
    /// kernel — what actually ran, on every path (fused, grouped, CRT),
    /// not what a planner chose. `""` before the first dispatch.
    pub kernel: &'static str,
    /// Tile height of the most recent fused dispatch (0 = none yet, or a
    /// level-major run with no tile geometry).
    pub tile_mc: usize,
    /// Tile width of the most recent fused dispatch (0 = see `tile_mc`).
    pub tile_nc: usize,
}

/// Thread-safe pool of [`Workspace`]s; share one per service via `Arc`.
///
/// Unbounded on purpose: residency is capped by the high-water mark of
/// *concurrent* checkouts (workers × pool threads), which the service
/// already bounds.
pub struct WorkspacePool {
    free: Mutex<Vec<Workspace>>,
    checkouts: AtomicU64,
    fresh_allocs: AtomicU64,
    fused_tiles: AtomicU64,
    panel_packs: AtomicU64,
    panel_reuses: AtomicU64,
    /// Last dispatched (kernel label, tile mc, tile nc); see
    /// [`WorkspacePool::record_dispatch`].
    dispatch: Mutex<(&'static str, usize, usize)>,
}

impl WorkspacePool {
    pub fn new() -> WorkspacePool {
        WorkspacePool {
            free: Mutex::new(Vec::new()),
            checkouts: AtomicU64::new(0),
            fresh_allocs: AtomicU64::new(0),
            fused_tiles: AtomicU64::new(0),
            panel_packs: AtomicU64::new(0),
            panel_reuses: AtomicU64::new(0),
            dispatch: Mutex::new(("", 0, 0)),
        }
    }

    /// Check out a workspace with room for `elems` elements per buffer.
    /// Best-fit from the free list (the smallest resident buffer that is
    /// big enough, so large buffers stay available for large requests);
    /// when nothing resident fits, the largest candidate is grown (or a
    /// fresh one built) and the fresh-allocation counter ticks. The free
    /// list is bounded by the concurrent-checkout high-water mark, so the
    /// O(len) scan is on a handful of entries. The guard returns the
    /// workspace on drop.
    pub fn checkout(&self, elems: usize) -> WorkspaceGuard<'_> {
        if faultinject::fires(faultinject::site::WORKSPACE_CHECKOUT) {
            panic!("injected fault: workspace checkout");
        }
        self.checkouts.fetch_add(1, Ordering::Relaxed);
        let pooled = {
            let mut g = psync::lock(&self.free);
            let mut best: Option<(usize, usize)> = None; // smallest fitting (idx, cap)
            let mut largest: Option<(usize, usize)> = None; // largest overall (idx, cap)
            for (i, w) in g.iter().enumerate() {
                let cap = w.capacity();
                let better_fit = match best {
                    None => cap >= elems,
                    Some((_, c)) => cap >= elems && cap < c,
                };
                if better_fit {
                    best = Some((i, cap));
                }
                let bigger = match largest {
                    None => true,
                    Some((_, c)) => cap > c,
                };
                if bigger {
                    largest = Some((i, cap));
                }
            }
            best.or(largest).map(|(i, _)| g.swap_remove(i))
        };
        let ws = match pooled {
            Some(mut w) => {
                if w.ensure(elems) {
                    self.fresh_allocs.fetch_add(1, Ordering::Relaxed);
                }
                w
            }
            None => {
                self.fresh_allocs.fetch_add(1, Ordering::Relaxed);
                Workspace::with_capacity(elems)
            }
        };
        WorkspaceGuard { pool: self, ws: Some(ws) }
    }

    /// Fold `n` executed fused tiles into the counters.
    pub fn record_tiles(&self, n: u64) {
        self.fused_tiles.fetch_add(n, Ordering::Relaxed);
    }

    /// Fold one fused run's packing accounting into the counters:
    /// `packs` operand panel builds, `reuses` pair kernel calls served
    /// from panels that were already packed.
    pub fn record_panels(&self, packs: u64, reuses: u64) {
        self.panel_packs.fetch_add(packs, Ordering::Relaxed);
        self.panel_reuses.fetch_add(reuses, Ordering::Relaxed);
    }

    /// Fold panel-scratch reallocations (`ensure_pack` growths inside a
    /// checked-out workspace) into the fresh-allocation gauge, so the
    /// zero-fresh-allocation warm-run criterion covers packing scratch
    /// too.
    pub fn record_pack_growth(&self, n: u64) {
        self.fresh_allocs.fetch_add(n, Ordering::Relaxed);
    }

    /// Record what a GEMM dispatch actually ran: the dispatched kernel
    /// and, for tile-engine paths, the (possibly autotuned) tile
    /// geometry. Every driver calls this at dispatch time — serial and
    /// parallel fused engines, the CRT planes, the grouped pipeline —
    /// so `coordinator::Metrics` reports the kernel that executed, not
    /// the one a planner intended. Level-major runs pass `None` (no
    /// tile geometry).
    pub fn record_dispatch(&self, kern: KernelId, shape: Option<TileShape>) {
        let (mc, nc) = shape.map_or((0, 0), |s| (s.mc, s.nc));
        *psync::lock(&self.dispatch) = (kern.label(), mc, nc);
    }

    /// Lifetime totals (see [`WorkspaceStats`]).
    pub fn stats(&self) -> WorkspaceStats {
        let (kernel, tile_mc, tile_nc) = *psync::lock(&self.dispatch);
        WorkspaceStats {
            checkouts: self.checkouts.load(Ordering::Relaxed),
            fresh_allocs: self.fresh_allocs.load(Ordering::Relaxed),
            fused_tiles: self.fused_tiles.load(Ordering::Relaxed),
            panel_packs: self.panel_packs.load(Ordering::Relaxed),
            panel_reuses: self.panel_reuses.load(Ordering::Relaxed),
            kernel,
            tile_mc,
            tile_nc,
        }
    }

    /// Workspaces currently resident in the free list.
    pub fn pooled(&self) -> usize {
        psync::lock(&self.free).len()
    }
}

impl Default for WorkspacePool {
    fn default() -> WorkspacePool {
        WorkspacePool::new()
    }
}

/// RAII checkout: derefs to the [`Workspace`], returns it to the pool on
/// drop (including during a panic unwind, so one poisoned request cannot
/// leak the pool's buffers).
pub struct WorkspaceGuard<'a> {
    pool: &'a WorkspacePool,
    ws: Option<Workspace>,
}

impl Deref for WorkspaceGuard<'_> {
    type Target = Workspace;
    fn deref(&self) -> &Workspace {
        self.ws.as_ref().expect("workspace present until drop")
    }
}

impl DerefMut for WorkspaceGuard<'_> {
    fn deref_mut(&mut self) -> &mut Workspace {
        self.ws.as_mut().expect("workspace present until drop")
    }
}

impl Drop for WorkspaceGuard<'_> {
    fn drop(&mut self) {
        if let Some(ws) = self.ws.take() {
            psync::lock(&self.pool.free).push(ws);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkout_return_reuse() {
        let pool = WorkspacePool::new();
        {
            let ws = pool.checkout(100);
            assert!(ws.capacity() >= 100);
            assert_eq!(pool.pooled(), 0, "checked-out workspace is not resident");
        }
        assert_eq!(pool.pooled(), 1, "guard returned the workspace");
        {
            let _ws = pool.checkout(80);
        }
        let st = pool.stats();
        assert_eq!(st.checkouts, 2);
        assert_eq!(st.fresh_allocs, 1, "second checkout fits in the pooled buffer");
        assert_eq!(pool.pooled(), 1);
    }

    #[test]
    fn undersized_pooled_workspace_grows_and_counts() {
        let pool = WorkspacePool::new();
        drop(pool.checkout(10));
        {
            let ws = pool.checkout(50);
            assert!(ws.capacity() >= 50);
        }
        assert_eq!(pool.stats().fresh_allocs, 2, "growth counts as a fresh allocation");
        drop(pool.checkout(50));
        assert_eq!(pool.stats().fresh_allocs, 2, "grown buffer now serves repeats");
    }

    #[test]
    fn checkout_is_best_fit_and_grows_the_largest() {
        let pool = WorkspacePool::new();
        // Seed the free list with a large and a small buffer.
        {
            let g_big = pool.checkout(1000);
            let g_small = pool.checkout(10);
            drop(g_big);
            drop(g_small);
        }
        assert_eq!(pool.stats().fresh_allocs, 2);
        // A small request must take the small buffer (best fit), leaving
        // the large one resident for a large request — zero new allocs.
        let small = pool.checkout(8);
        assert!(small.capacity() < 1000, "best fit must pick the small buffer");
        let big = pool.checkout(900);
        assert_eq!(big.capacity(), 1000, "large buffer stayed available");
        assert_eq!(pool.stats().fresh_allocs, 2, "no fresh allocation for either");
        drop(small);
        drop(big);
        // When nothing fits, the largest resident buffer is grown.
        let huge = pool.checkout(2000);
        assert!(huge.capacity() >= 2000);
        assert_eq!(pool.stats().fresh_allocs, 3, "growth ticks the counter once");
        drop(huge);
        assert_eq!(pool.pooled(), 2, "still two resident workspaces");
    }

    #[test]
    fn concurrent_checkouts_get_distinct_workspaces() {
        let pool = WorkspacePool::new();
        let g1 = pool.checkout(8);
        let g2 = pool.checkout(8);
        // Writing through one must not affect the other (distinct buffers).
        let (mut g1, mut g2) = (g1, g2);
        g1.pbuf[0] = 7;
        g2.pbuf[0] = 9;
        assert_ne!(g1.pbuf[0], g2.pbuf[0]);
        drop(g1);
        drop(g2);
        assert_eq!(pool.pooled(), 2);
        assert_eq!(pool.stats().fresh_allocs, 2);
    }

    #[test]
    fn guard_returns_workspace_on_panic() {
        let pool = WorkspacePool::new();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ws = pool.checkout(4);
            panic!("boom");
        }));
        assert!(r.is_err());
        assert_eq!(pool.pooled(), 1, "unwind must return the workspace");
    }

    #[test]
    fn tile_counter_accumulates() {
        let pool = WorkspacePool::new();
        pool.record_tiles(3);
        pool.record_tiles(4);
        assert_eq!(pool.stats().fused_tiles, 7);
    }

    #[test]
    fn panel_counters_accumulate() {
        let pool = WorkspacePool::new();
        pool.record_panels(2, 27);
        pool.record_panels(3, 27);
        let st = pool.stats();
        assert_eq!((st.panel_packs, st.panel_reuses), (5, 54));
    }

    #[test]
    fn dispatch_gauge_surfaces_kernel_and_tile_shape() {
        let pool = WorkspacePool::new();
        let st = pool.stats();
        assert_eq!((st.kernel, st.tile_mc, st.tile_nc), ("", 0, 0), "blank before any dispatch");
        pool.record_dispatch(KernelId::Scalar, Some(TileShape { mc: 64, nc: 128 }));
        let st = pool.stats();
        assert_eq!((st.kernel, st.tile_mc, st.tile_nc), ("scalar", 64, 128));
        // A level-major dispatch keeps the kernel but clears the geometry.
        pool.record_dispatch(KernelId::Scalar, None);
        let st = pool.stats();
        assert_eq!((st.kernel, st.tile_mc, st.tile_nc), ("scalar", 0, 0));
    }

    #[test]
    fn pack_growth_feeds_the_fresh_allocation_gauge() {
        // Panel-scratch growth inside a checked-out workspace must be
        // visible to the zero-fresh-allocation warm-run criterion.
        let pool = WorkspacePool::new();
        drop(pool.checkout(4));
        assert_eq!(pool.stats().fresh_allocs, 1);
        pool.record_pack_growth(1);
        assert_eq!(pool.stats().fresh_allocs, 2);
        pool.record_pack_growth(0);
        assert_eq!(pool.stats().fresh_allocs, 2, "no growth, no tick");
    }

    #[test]
    fn pack_scratch_grows_once_then_persists_through_the_pool() {
        let pool = WorkspacePool::new();
        {
            let mut ws = pool.checkout(16);
            assert!(ws.ensure_pack(100, 200), "first sizing must grow");
            assert!(!ws.ensure_pack(100, 200), "repeat sizing is a no-op");
            assert!(!ws.ensure_pack(40, 60), "smaller requests reuse the buffers");
            assert!(ws.apack.len() >= 100 && ws.bpack.len() >= 200);
        }
        // The returned workspace keeps its panel scratch: a warm checkout
        // of the same shape never grows again.
        let mut ws = pool.checkout(16);
        assert!(!ws.ensure_pack(100, 200), "warm pool must not regrow pack scratch");
    }

    #[test]
    fn res_scratch_grows_once_then_persists_through_the_pool() {
        let pool = WorkspacePool::new();
        {
            let mut ws = pool.checkout(16);
            assert!(ws.ensure_res(500), "first sizing must grow");
            assert!(!ws.ensure_res(500), "repeat sizing is a no-op");
            assert!(!ws.ensure_res(100), "smaller requests reuse the buffer");
            assert!(ws.rbuf.len() >= 500);
        }
        let mut ws = pool.checkout(16);
        assert!(!ws.ensure_res(500), "warm pool must not regrow residue scratch");
    }
}
