//! FP64 linear-algebra substrates: the dense matrix type, blocked native
//! GEMM (the cuBLAS-DGEMM analogue and ADP fallback target), Strassen
//! (the accuracy comparator of Fig 3), and blocked Householder QR (the
//! cuSOLVER `geqrf` analogue of §7.3).

pub mod gemm;
pub mod matrix;
pub mod qr;
pub mod strassen;
pub mod zgemm;

pub use gemm::{gemm, gemm_into};
pub use matrix::Matrix;
pub use qr::{blocked_qr, ComputeBackendGemm, GemmBackend, NativeGemm, Qr, QrStats};
pub use strassen::{strassen, strassen_on};
pub use zgemm::{zgemm, ZMatrix};
