//! Floating-point Strassen matrix multiplication.
//!
//! The accuracy comparator of Fig 3/Fig 4: Strassen-like algorithms satisfy
//! only norm-wise (Grade C) bounds, so their componentwise error grows
//! faster than the Grade A slope — which is exactly what the grading tests
//! detect. Simple reference implementation (the paper's words: "a simple
//! reference implementation that we include for comparison purposes").

use super::matrix::Matrix;
use crate::backend::{ComputeBackend, SerialBackend};

/// Below this size we switch to the blocked O(n^3) kernel.
const CUTOFF: usize = 64;

/// C = A * B via Strassen's seven-multiplication recursion on the serial
/// reference backend.
pub fn strassen(a: &Matrix, b: &Matrix) -> Matrix {
    strassen_on(a, b, &SerialBackend)
}

/// C = A * B via Strassen's seven-multiplication recursion, base-case
/// GEMMs dispatched through `backend`'s tile engine.
/// Handles arbitrary square power-of-two-padded shapes; inputs of other
/// shapes are zero-padded up to the next power of two >= CUTOFF.
pub fn strassen_on(a: &Matrix, b: &Matrix, backend: &dyn ComputeBackend) -> Matrix {
    assert_eq!(a.cols, b.rows);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let dim = m.max(k).max(n).next_power_of_two().max(CUTOFF);
    if m == dim && k == dim && n == dim {
        return strassen_square(a, b, backend);
    }
    let c = strassen_square(&a.pad_to(dim, dim), &b.pad_to(dim, dim), backend);
    c.block(0, 0, m, n)
}

fn strassen_square(a: &Matrix, b: &Matrix, backend: &dyn ComputeBackend) -> Matrix {
    let n = a.rows;
    if n <= CUTOFF {
        return backend.fp64_gemm(a, b);
    }
    let h = n / 2;
    let a11 = a.block(0, 0, h, h);
    let a12 = a.block(0, h, h, h);
    let a21 = a.block(h, 0, h, h);
    let a22 = a.block(h, h, h, h);
    let b11 = b.block(0, 0, h, h);
    let b12 = b.block(0, h, h, h);
    let b21 = b.block(h, 0, h, h);
    let b22 = b.block(h, h, h, h);

    let add = |x: &Matrix, y: &Matrix| {
        let mut z = x.clone();
        z.add_assign(y);
        z
    };

    // The seven products are independent. When the backend exposes a
    // thread pool they are fanned out as tasks (nested recursion degrades
    // to inline work once the pool's tokens are taken — never blocks);
    // this materializes all seven operand pairs up front, the memory cost
    // of the parallelism. Without a pool, keep the original streaming
    // order: one operand pair alive at a time. Each product's internal
    // arithmetic is schedule-invariant and the combination below always
    // runs in fixed order, so both arms are bitwise identical.
    let [m1, m2, m3, m4, m5, m6, m7] = if let Some(pool) = backend.pool() {
        let ops: [(Matrix, Matrix); 7] = [
            (add(&a11, &a22), add(&b11, &b22)),
            (add(&a21, &a22), b11.clone()),
            (a11.clone(), b12.sub(&b22)),
            (a22.clone(), b21.sub(&b11)),
            (add(&a11, &a12), b22.clone()),
            (a21.sub(&a11), add(&b11, &b12)),
            (a12.sub(&a22), add(&b21, &b22)),
        ];
        let mut slots: [Option<Matrix>; 7] = [None, None, None, None, None, None, None];
        {
            let work: Vec<(&mut Option<Matrix>, &(Matrix, Matrix))> =
                slots.iter_mut().zip(ops.iter()).collect();
            crate::backend::pool::drain(pool, work, |(slot, (x, y))| {
                *slot = Some(strassen_square(x, y, backend));
            });
        }
        slots.map(|m| m.expect("all products computed"))
    } else {
        // Separate statements so each pair of operand temporaries is
        // dropped before the next product starts.
        let m1 = strassen_square(&add(&a11, &a22), &add(&b11, &b22), backend);
        let m2 = strassen_square(&add(&a21, &a22), &b11, backend);
        let m3 = strassen_square(&a11, &b12.sub(&b22), backend);
        let m4 = strassen_square(&a22, &b21.sub(&b11), backend);
        let m5 = strassen_square(&add(&a11, &a12), &b22, backend);
        let m6 = strassen_square(&a21.sub(&a11), &add(&b11, &b12), backend);
        let m7 = strassen_square(&a12.sub(&a22), &add(&b21, &b22), backend);
        [m1, m2, m3, m4, m5, m6, m7]
    };

    // c11 = m1 + m4 - m5 + m7 ; c12 = m3 + m5
    // c21 = m2 + m4           ; c22 = m1 - m2 + m3 + m6
    let mut c = Matrix::zeros(n, n);
    let mut c11 = add(&m1, &m4);
    c11 = c11.sub(&m5);
    c11.add_assign(&m7);
    let c12 = add(&m3, &m5);
    let c21 = add(&m2, &m4);
    let mut c22 = m1.sub(&m2);
    c22.add_assign(&m3);
    c22.add_assign(&m6);
    c.set_block(0, 0, &c11);
    c.set_block(0, h, &c12);
    c.set_block(h, 0, &c21);
    c.set_block(h, h, &c22);
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::ParallelBackend;
    use crate::linalg::gemm::gemm;
    use crate::util::Rng;

    #[test]
    fn parallel_backend_is_bitwise_identical() {
        let mut rng = Rng::new(15);
        let a = Matrix::uniform(150, 150, -1.0, 1.0, &mut rng);
        let b = Matrix::uniform(150, 150, -1.0, 1.0, &mut rng);
        let c_ser = strassen(&a, &b);
        let par = ParallelBackend::new(3).with_cutoff_ops(0);
        let c_par = strassen_on(&a, &b, &par);
        for (x, y) in c_ser.data.iter().zip(&c_par.data) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn matches_gemm_power_of_two() {
        let mut rng = Rng::new(7);
        for n in [64, 128, 256] {
            let a = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
            let b = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
            let err = strassen(&a, &b).sub(&gemm(&a, &b)).max_abs();
            assert!(err < 1e-10 * n as f64, "n={n} err={err}");
        }
    }

    #[test]
    fn pads_odd_shapes() {
        let mut rng = Rng::new(8);
        let a = Matrix::uniform(70, 90, -1.0, 1.0, &mut rng);
        let b = Matrix::uniform(90, 50, -1.0, 1.0, &mut rng);
        let c = strassen(&a, &b);
        assert_eq!((c.rows, c.cols), (70, 50));
        let err = c.sub(&gemm(&a, &b)).max_abs();
        assert!(err < 1e-10, "err={err}");
    }

    #[test]
    fn strassen_error_exceeds_gemm_on_large_uniform() {
        // The very property Fig 3 demonstrates: componentwise error of
        // Strassen grows faster than the O(n^3) algorithm's.
        let mut rng = Rng::new(9);
        let n = 512;
        let a = Matrix::uniform(n, n, 0.0, 1.0, &mut rng);
        let b = Matrix::uniform(n, n, 0.0, 1.0, &mut rng);
        let c_ref = a.matmul_dd(&b);
        let abs_ref = a.abs().matmul_dd(&b.abs());
        let rel = |c: &Matrix| {
            let mut worst = 0.0f64;
            for i in 0..n {
                for j in 0..n {
                    let e = (c.at(i, j) - c_ref.at(i, j)).abs() / abs_ref.at(i, j);
                    worst = worst.max(e);
                }
            }
            worst
        };
        let e_gemm = rel(&gemm(&a, &b));
        let e_str = rel(&strassen(&a, &b));
        assert!(e_str > e_gemm, "strassen {e_str} vs gemm {e_gemm}");
    }
}
